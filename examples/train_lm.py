"""End-to-end training example: train a ~100M-parameter LM.

CPU-sized demonstration (finishes in a couple of minutes):

    PYTHONPATH=src python examples/train_lm.py --quick

Full ~100M-parameter run (a few hundred steps; use on real hardware or
leave running on CPU):

    PYTHONPATH=src python examples/train_lm.py

Everything rides the production driver (``repro.launch.train``):
deterministic sharded data pipeline, flash-attention + remat train step,
AdamW with cosine schedule, atomic checkpointing + resume, fault-tolerance
hooks.  The architecture is the assigned qwen2-0.5b family, width-reduced
to ~100M parameters.
"""

import argparse
import sys

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny CPU-sized run (smoke)")
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    if args.quick:
        argv = ["--arch", "qwen2-0.5b", "--reduced",
                "--steps", str(args.steps or 30),
                "--seq-len", "64", "--global-batch", "8",
                "--lr", "3e-3", "--warmup", "5",
                "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "10"]
    else:
        # ~100M params: qwen2-family, d_model 512, 8 layers, vocab 151936
        # (embeddings dominate at this scale, as in the real 0.5B).
        argv = ["--arch", "qwen2-0.5b", "--reduced",
                "--d-model", "512", "--num-layers", "8",
                "--steps", str(args.steps or 300),
                "--seq-len", "256", "--global-batch", "16",
                "--lr", "1e-3", "--warmup", "30", "--remat",
                "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100"]
    res = train_main(argv)
    if not res["loss_decreased"]:
        print("WARNING: loss did not decrease", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
