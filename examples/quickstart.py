"""Quickstart: the paper's semi-analytical power model in 30 lines.

    PYTHONPATH=src python examples/quickstart.py

Reproduces the headline results of Gomez & Patel et al. (tinyML'22):
centralized vs distributed on-sensor compute for AR/VR hand tracking.
"""

from repro.core import partition, system


def main():
    print("== Fig. 5a: system power, centralized vs distributed ==")
    cen = system.build_centralized("7nm")
    d77 = system.build_distributed("7nm", "7nm")
    d716 = system.build_distributed("7nm", "16nm")
    base = cen.avg_power
    for rep in (cen, d77, d716):
        print(f"  {rep.name:42s} {rep.avg_power*1e3:7.3f} mW "
              f"({rep.avg_power/base*100:5.1f}%)")
    print(f"  -> distributed saves {(1-d77.avg_power/base)*100:.1f}% "
          f"(paper: 24%), 16nm on-sensor {(1-d716.avg_power/base)*100:.1f}%"
          f" (paper: 16%)")

    print("\n== Fig. 5a: where the power goes (centralized) ==")
    for group, p in sorted(cen.breakdown().items(),
                           key=lambda kv: -kv[1]):
        print(f"  {group:20s} {p*1e3:7.3f} mW")

    print("\n== Fig. 5b: on-sensor memory hierarchy (16nm, 10 fps) ==")
    f5b = system.fig5b_comparison()
    print(f"  pure SRAM   : 1.000")
    print(f"  hybrid MRAM : {f5b['hybrid']:.3f} "
          f"(saving {f5b['_saving']*100:.1f}%, paper: 39%)")

    print("\n== Workload partition sweep (the paper's key knob) ==")
    pts = partition.sweep_partitions()
    best = min(pts, key=lambda p: p.avg_power)
    from repro.core.handtracking import build_detnet
    n_det = len(build_detnet().layers)
    print(f"  centralized (cut 0)        : {pts[0].avg_power*1e3:.3f} mW")
    print(f"  paper split (cut {n_det}, Fig. 2): "
          f"{pts[n_det].avg_power*1e3:.3f} mW")
    print(f"  layer-level optimum (cut {best.cut}) : "
          f"{best.avg_power*1e3:.3f} mW  <- beyond-paper finding")


if __name__ == "__main__":
    main()
