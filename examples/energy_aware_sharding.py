"""Beyond-paper: energy-aware multi-pod communication planning.

    PYTHONPATH=src python examples/energy_aware_sharding.py

Applies the paper's semi-analytical methodology to a 2-pod, 512-chip TPU
machine: the DOSC advisor (repro.core.dosc) ranks cross-pod gradient
reduction plans by time and energy — the exact two-tier reasoning the
paper applies to uTSV vs MIPI, applied to ICI vs DCN — and the TPU energy
model (repro.core.tpu_energy) prices full training steps from the compiled
dry-run artifacts.
"""

import json
import os

from repro.configs import get_config
from repro.core import dosc


def advisor_demo():
    print("== DOSC advisor: cross-pod gradient reduction plans ==")
    print("   (arch: phi4-mini-3.8b, 2 pods x 256 chips)")
    cfg = get_config("phi4-mini-3.8b")
    grads = cfg.param_count() / 512      # elements per chip (2D sharded)
    for objective in ("time", "energy"):
        ranked = dosc.advise(grad_elems_per_chip=grads, pods=2,
                             intra_pod_chips=256, objective=objective)
        print(f"\n  ranked by {objective}:")
        for c in ranked:
            print(f"    {c.plan.name:15s} t={c.t_comm_s*1e3:9.3f} ms  "
                  f"E={c.e_comm_j*1e3:8.4f} mJ/chip  "
                  f"DCN-edge={c.dcn_edge_bytes/2**20:8.2f} MiB")
    print("\n  -> hierarchical + compressed cross-pod traffic wins on both"
          "\n     axes: the paper's 'send the ROI, not the frame'.")


def energy_table():
    path = os.path.join(os.path.dirname(__file__), "..", "experiments",
                        "dryrun_results.json")
    if not os.path.exists(path):
        print("\n(no dry-run results yet: run "
              "python -m repro.launch.dryrun --all)")
        return
    rows = json.load(open(path))
    print("\n== per-step energy (Eq. 1/2 adapted, single pod) ==")
    print(f"  {'arch':22s}{'shape':13s}{'E/step (J)':>11s}"
          f"{'sys power (kW)':>15s}")
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r["status"] != "ok" or r["mesh"] != "16x16" \
                or r.get("tag", "baseline") != "baseline":
            continue
        e = r["energy_per_step_j"]["total"]
        print(f"  {r['arch']:22s}{r['shape']:13s}{e:11.2f}"
              f"{r['est_system_power_w']/1e3:15.2f}")


if __name__ == "__main__":
    advisor_demo()
    energy_table()
