"""Partition study: sweep the paper's optimization knobs.

    PYTHONPATH=src python examples/partition_study.py

Explores the design space the simulation framework was built for:
* partition point x on-sensor technology node,
* DetNet frame rate (the paper's 'ROI reuse' knob),
* SRAM vs hybrid MRAM on-sensor weight memory,
* sensitivity of the optimal cut to MIPI energy/byte.
"""

import dataclasses

from repro.core import partition, system
from repro.core.constants import MIPI, NUM_CAMERAS


def sweep_tech_nodes():
    print("== partition x on-sensor node ==")
    print(f"{'cut':>4s} {'7nm sensor (mW)':>16s} {'16nm sensor (mW)':>17s}")
    pts7 = partition.sweep_partitions(sensor_node="7nm")
    pts16 = partition.sweep_partitions(sensor_node="16nm")
    for i in range(0, len(pts7), 4):
        print(f"{i:4d} {pts7[i].avg_power*1e3:16.3f} "
              f"{pts16[i].avg_power*1e3:17.3f}")
    b7 = min(pts7, key=lambda p: p.avg_power)
    b16 = min(pts16, key=lambda p: p.avg_power)
    print(f"best: cut {b7.cut} @7nm ({b7.avg_power*1e3:.3f} mW), "
          f"cut {b16.cut} @16nm ({b16.avg_power*1e3:.3f} mW)")


def sweep_detnet_fps():
    print("\n== DetNet rate (ROI reuse) — paper section 3 ==")
    for fps in (5.0, 10.0, 15.0, 30.0):
        rep = system.build_distributed("7nm", "7nm", detnet_fps=fps)
        print(f"  DetNet @{fps:4.0f} fps: {rep.avg_power*1e3:7.3f} mW")


def sweep_memory_tech():
    print("\n== on-sensor weight memory tech (16nm sensors) ==")
    for mem in ("sram", "mram"):
        rep = system.build_distributed("7nm", "16nm",
                                       sensor_weight_mem=mem)
        onsensor = rep.group_power("sensor")
        print(f"  {mem:5s}: system {rep.avg_power*1e3:7.3f} mW, "
              f"on-sensor subsystem {onsensor*1e3:7.3f} mW")


def sweep_mipi_energy():
    print("\n== sensitivity: optimal cut vs MIPI energy/byte ==")
    for pj in (25.0, 50.0, 100.0, 200.0):
        # rebuild the sweep with a modified link (Eq. 5's E_byte)
        import repro.core.system as S
        import repro.core.partition as P
        orig = S.MIPI
        link = dataclasses.replace(orig, energy_per_byte=pj * 1e-12)
        S.MIPI = link
        P.MIPI = link
        try:
            pts = partition.sweep_partitions()
            best = min(pts, key=lambda p: p.avg_power)
            print(f"  MIPI {pj:5.0f} pJ/B: best cut {best.cut:2d}, "
                  f"{best.avg_power*1e3:7.3f} mW "
                  f"(centralized {pts[0].avg_power*1e3:7.3f} mW)")
        finally:
            S.MIPI = orig
            P.MIPI = orig


if __name__ == "__main__":
    sweep_tech_nodes()
    sweep_detnet_fps()
    sweep_memory_tech()
    sweep_mipi_energy()
