"""Partition study: sweep the paper's optimization knobs.

    PYTHONPATH=src python examples/partition_study.py

Explores the design space the simulation framework was built for, driving
the vectorized grid engine (`repro.core.sweep.evaluate_grid`) — every
section below is one batched device call instead of a scalar Python loop:

* partition point x on-sensor technology node,
* DetNet frame rate (the paper's 'ROI reuse' knob),
* SRAM vs hybrid MRAM on-sensor weight memory,
* sensitivity of the optimal cut to MIPI energy/byte (a first-class grid
  axis now — no more monkey-patching the link constants),
* the Pareto front over (power, latency, MIPI traffic) — the paper's
  three headline claims as one multi-objective picture,
* a streaming ~1M-config sweep (`stream.stream_grid`): the grid is
  never materialized — chunks are decoded/evaluated on device and
  folded into running argmin/top-k/front reductions,
* a constrained sweep: a latency budget + MIPI link cap compiled into
  the streaming chunk step (`constraints=`), filtering infeasible
  configurations before the front is extracted,
* architecture x partition co-design over a batched workload axis
  (`models=`: DetNet/KeyNet variants swept inside one compiled kernel),
* the session-level front (`scenarios=`): every configuration simulated
  through time-varying user-behavior traces with battery + thermal
  state, then time-to-empty maximized against peak case temperature,
* explicit evaluation-backend selection (`backend="pallas"` parity on
  a small grid) and scan-fused vs per-chunk dispatch timing on a large
  space (`scan_chunks=`, the `repro.core.backend` layer),
* gradient knob search: projected Adam driving jax.grad through the
  Eq. 1-11 kernel, cross-checked against a dense grid.

The scalar path (`partition.evaluate_cut`) renders the fully-annotated
report for the single winning configuration at the end.
"""

import numpy as np

from repro.core import optimize, pareto, partition, stream, sweep
from repro.core.constants import MIPI
from repro.core.handtracking import build_detnet, build_keynet

N_DET = len(build_detnet().layers)
N_ALL = N_DET + len(build_keynet().layers)


def sweep_tech_nodes():
    print("== partition x on-sensor node ==")
    res = sweep.evaluate_grid(sensor_nodes=("7nm", "16nm"))
    power = res.avg_power.reshape(N_ALL + 1, 2)     # (cut, sensor_node)
    print(f"{'cut':>4s} {'7nm sensor (mW)':>16s} {'16nm sensor (mW)':>17s}")
    for i in range(0, N_ALL + 1, 4):
        print(f"{i:4d} {power[i, 0]*1e3:16.3f} {power[i, 1]*1e3:17.3f}")
    b7, b16 = np.argmin(power[:, 0]), np.argmin(power[:, 1])
    print(f"best: cut {b7} @7nm ({power[b7, 0]*1e3:.3f} mW), "
          f"cut {b16} @16nm ({power[b16, 1]*1e3:.3f} mW)")


def sweep_detnet_fps():
    print("\n== DetNet rate (ROI reuse) — paper section 3 ==")
    rates = (5.0, 10.0, 15.0, 30.0)
    res = sweep.evaluate_grid(cuts=(N_DET,), detnet_fps=rates)
    for fps, p in zip(rates, res.avg_power.ravel()):
        print(f"  DetNet @{fps:4.0f} fps: {p*1e3:7.3f} mW")


def sweep_memory_tech():
    print("\n== on-sensor weight memory tech (16nm sensors) ==")
    res = sweep.evaluate_grid(cuts=(N_DET,), sensor_nodes=("16nm",),
                              weight_mems=("sram", "mram"))
    onsensor = (res.data["sensor_compute"]
                + res.data["sensor_memory"]).ravel()
    for mem, total, sub in zip(("sram", "mram"), res.avg_power.ravel(),
                               onsensor):
        print(f"  {mem:5s}: system {total*1e3:7.3f} mW, "
              f"on-sensor subsystem {sub*1e3:7.3f} mW")


def sweep_mipi_energy():
    print("\n== sensitivity: optimal cut vs MIPI energy/byte ==")
    # Eq. 5's E_byte as a grid axis: one call covers cuts x scales.
    pjs = (25.0, 50.0, 100.0, 200.0)
    scales = tuple(pj * 1e-12 / MIPI.energy_per_byte for pj in pjs)
    res = sweep.evaluate_grid(mipi_energy_scale=scales)
    power = res.avg_power.reshape(N_ALL + 1, len(scales))
    for k, pj in enumerate(pjs):
        best = int(np.argmin(power[:, k]))
        print(f"  MIPI {pj:5.0f} pJ/B: best cut {best:2d}, "
              f"{power[best, k]*1e3:7.3f} mW "
              f"(centralized {power[0, k]*1e3:7.3f} mW)")


def pareto_study():
    print("\n== Pareto front: power x latency x MIPI traffic ==")
    res = sweep.evaluate_grid(sensor_nodes=("7nm", "16nm"),
                              weight_mems=("sram", "mram"),
                              detnet_fps=(5.0, 10.0, 15.0, 30.0))
    front = pareto.pareto_front(res)   # NaN MRAM corners masked
    print(f"  {front.size} non-dominated of {res.n_configs} configs "
          f"(hypervolume {front.hypervolume():.3g})")
    print(f"  {'cut':>4s} {'sensor':>7s} {'wmem':>5s} {'dfps':>5s} "
          f"{'power mW':>9s} {'lat ms':>7s} {'MIPI MB/s':>10s}")
    knee = front.knee()
    for cfg in front.configs():
        mark = "  <- knee" if cfg == knee else ""
        print(f"  {cfg['cut']:4d} {cfg['sensor_node']:>7s} "
              f"{cfg['weight_mem']:>5s} {cfg['detnet_fps']:5.0f} "
              f"{cfg['avg_power']*1e3:9.3f} {cfg['latency']*1e3:7.3f} "
              f"{cfg['mipi_bytes_per_s']/1e6:10.3f}{mark}")


def knob_search():
    print("\n== gradient knob search (jax.grad through Eqs. 1-11) ==")
    bounds = {"detnet_fps": (5.0, 30.0), "camera_fps": (20.0, 60.0)}
    objective = {"avg_power": 1.0, "latency": 10.0}   # 1 mW ~ 0.1 ms
    res = optimize.optimize_knobs(bounds, objective, cut=N_DET,
                                  sensor_node="16nm", steps=200)
    gk, gv = optimize.grid_argmin(bounds, objective, cut=N_DET,
                                  sensor_node="16nm", n=41)
    print(f"  projected Adam : " + ", ".join(
        f"{k}={v:.2f}" for k, v in res.knobs.items())
        + f" -> objective {res.objective*1e3:.4f}")
    print(f"  41x41 grid     : " + ", ".join(
        f"{k}={v:.2f}" for k, v in gk.items())
        + f" -> objective {gv*1e3:.4f}")
    print(f"  at the optimum : {res.fields['avg_power']*1e3:.3f} mW, "
          f"{res.fields['latency']*1e3:.3f} ms")


def streaming_sweep():
    print("\n== streaming sweep: ~1M configs, O(chunk) host memory ==")
    # The same knobs at production resolution would not fit densely —
    # the streaming executor never materializes the grid.
    res = stream.stream_grid(
        sensor_nodes=("7nm", "16nm"), weight_mems=("sram", "mram"),
        detnet_fps=tuple(np.linspace(5.0, 30.0, 26)),
        keynet_fps=(15.0, 30.0), num_cameras=(2, 4),
        mipi_energy_scale=(1.0, 2.0),
        camera_fps=tuple(np.linspace(20.0, 60.0, 36)))
    best = res.argmin()
    print(f"  {res.n_configs:,} configs in {res.stats['total_s']:.1f}s "
          f"({res.stats['steady_configs_per_s']/1e6:.2f}M cfg/s steady, "
          f"{int(res.stats['n_chunks'])} chunks x {res.chunk_size:,})")
    print(f"  best: cut {best['cut']} @{best['sensor_node']}"
          f"/{best['weight_mem']} detfps={best['detnet_fps']:g} "
          f"camfps={best['camera_fps']:g} "
          f"-> {best['avg_power']*1e3:.3f} mW")
    print(f"  top-3 latency: " + ", ".join(
        f"cut {c['cut']}@{c['sensor_node']},cam{c['camera_fps']:g},"
        f"det{c['detnet_fps']:g}: {c['latency']*1e3:.2f}ms"
        for c in res.top_k("latency")[:3]))
    print(f"  exact Pareto front: {res.front_indices.size} members "
          f"(merged incrementally, grid never materialized)")


def constrained_sweep():
    print("\n== constrained streaming sweep: latency budget + link cap ==")
    # Feasibility predicates compile into the chunk step: infeasible
    # configurations are masked on-device before any reduction, so the
    # argmin / top-k / Pareto front below are over the feasible set only
    # (exactly what a dense post-filter would produce, without ever
    # materializing the grid).
    axes = dict(sensor_nodes=("7nm", "16nm"), weight_mems=("sram", "mram"),
                detnet_fps=tuple(np.linspace(5.0, 30.0, 26)),
                camera_fps=tuple(np.linspace(20.0, 60.0, 36)))
    budget = {"latency": ("<=", 12e-3),            # end-to-end budget
              "mipi_bytes_per_s": ("<=", 3e6)}     # link provisioning cap
    free = stream.stream_grid(**axes, prefetch=4)
    res = stream.stream_grid(**axes, constraints=budget, prefetch=4)
    n_free = free.finite_counts["avg_power"]
    n_feas = res.finite_counts["avg_power"]
    print(f"  feasible: {n_feas:,} of {n_free:,} valid configs "
          f"(latency <= 12 ms, MIPI <= 3 MB/s)")
    best_free, best = free.argmin(), res.argmin()
    print(f"  unconstrained best: cut {best_free['cut']} "
          f"{best_free['avg_power']*1e3:.3f} mW")
    print(f"  feasible best     : cut {best['cut']} "
          f"@{best['sensor_node']}/{best['weight_mem']} "
          f"detfps={best['detnet_fps']:g} -> "
          f"{best['avg_power']*1e3:.3f} mW")
    print(f"  feasible front    : {res.front_indices.size} members "
          f"(vs {free.front_indices.size} unconstrained) — filtered "
          f"before front extraction, on-device")
    # The same machinery drives the scalar-search API end to end (the
    # default 30 fps cameras bottom out at ~14.7 ms, so the budget here
    # is looser than the streaming sweep's, which also opened camera_fps):
    win = partition.optimal_partition(sensor_node=("7nm", "16nm"),
                                      constraints={"latency": 15e-3})
    print(f"  optimal_partition(constraints=...): {win.label}, "
          f"{win.latency*1e3:.2f} ms, {win.avg_power*1e3:.3f} mW")


def architecture_search():
    print("\n== batched workload axis: architecture x partition ==")
    det, key = build_detnet(), build_keynet()
    pairs = ((det, key), (det.scaled(0.5), key), (det, key.scaled(0.5)))
    res = sweep.evaluate_grid(models=pairs, sensor_nodes=("7nm", "16nm"),
                              detnet_fps=(10.0, 30.0))
    print(f"  {'model':>20s} {'best cut':>8s} {'mW':>8s}")
    for mi, name in enumerate(res.axes["model"]):
        power = res.avg_power[mi]
        flat = int(np.nanargmin(power))
        cut = np.unravel_index(flat, power.shape)[0]
        print(f"  {name:>20s} {cut:8d} {np.nanmin(power)*1e3:8.3f}")
    best = res.argmin()
    print(f"  winner: {best['model']} at cut {best['cut']} "
          f"({best['avg_power']*1e3:.3f} mW)")


def session_study():
    print("\n== session-level front: time-to-empty vs peak case temp ==")
    # Every (config, trace) pair runs the battery/thermal lax.scan
    # session simulator; the four session channels then drive the same
    # argmin/top-k/Pareto machinery as the static ones.
    axes = dict(sensor_nodes=("7nm", "16nm"),
                detnet_fps=(5.0, 15.0, 30.0))
    objectives = ("time_to_empty_s", "peak_case_temp_c")
    res = stream.stream_grid(**axes, scenarios="all", objectives=objectives,
                             maximize=("time_to_empty_s",))
    n_traces = len(res.axes["trace"])
    print(f"  {res.n_configs:,} (config x trace) pairs "
          f"({n_traces} user-behavior profiles)")
    front = res.pareto_front()
    print(f"  {'trace':>8s} {'cut':>4s} {'sensor':>7s} {'dfps':>5s} "
          f"{'empty h':>8s} {'peak C':>7s}")
    for cfg in front.configs():
        print(f"  {cfg['trace']:>8s} {cfg['cut']:4d} "
              f"{cfg['sensor_node']:>7s} {cfg['detnet_fps']:5.0f} "
              f"{cfg['time_to_empty_s']/3600:8.1f} "
              f"{cfg['peak_case_temp_c']:7.2f}")
    # Scalar search API: longest session that never exceeds 40 C.
    best = partition.optimal_partition(
        objective="time_to_empty_s", scenarios="all",
        sensor_node=("7nm", "16nm"), detnet_fps=(5.0, 15.0, 30.0),
        constraints={"peak_case_temp_c": ("<=", 40.0)})
    print(f"  optimal_partition(scenarios=...): {best.label} under "
          f"'{best.trace}' -> {best.session['time_to_empty_s']/3600:.1f} h, "
          f"peak {best.session['peak_case_temp_c']:.2f} C, "
          f"throttled {best.session['throttle_fraction']*100:.1f}%")


def backend_study():
    print("\n== evaluation backends: explicit selection + scan fusion ==")
    # Every engine runs the same decode -> evaluate -> fold contract
    # (repro.core.backend); backend= picks the lowering explicitly.
    # The Pallas backend fuses decode + Eq. 1-11 + block reductions
    # into one pallas_call — interpret mode on CPU (slow, parity-
    # checked here on a small grid; TPU is the lowering target).
    small = dict(sensor_nodes=("7nm", "16nm"), weight_mems=("sram",
                                                            "mram"))
    via_xla = sweep.evaluate_grid(**small)                # backend="xla"
    via_pallas = sweep.evaluate_grid(**small, backend="pallas")
    same = all(np.array_equal(via_xla.data[f], via_pallas.data[f],
                              equal_nan=True) for f in sweep.FIELDS)
    print(f"  backend='pallas' vs 'xla' on {via_xla.n_configs} configs: "
          f"{'bitwise identical' if same else 'DRIFTED'}")

    # Scan-fused dispatch on a large space: lax.scan folds K chunks per
    # device dispatch, so per-chunk dispatch overhead is paid once per
    # K.  Exact same results either way — only stats change.
    axes = dict(sensor_nodes=("7nm", "16nm"), weight_mems=("sram",
                                                           "mram"),
                detnet_fps=tuple(np.linspace(5.0, 30.0, 26)),
                camera_fps=tuple(np.linspace(20.0, 60.0, 36)))
    runs = {}
    for label, k in (("per-chunk (scan_chunks=1)", 1),
                     ("scan-fused (scan_chunks=8)", 8)):
        stream.stream_grid(**axes, chunk_size=1 << 14, scan_chunks=k)
        res = stream.stream_grid(**axes, chunk_size=1 << 14,
                                 scan_chunks=k)     # post-compile
        runs[k] = res
        s = res.stats
        print(f"  {label:27s}: {int(s['n_chunks']):3d} dispatches, "
              f"dispatch {s['dispatch_s']*1e3:6.1f} ms, "
              f"{s['configs_per_s']/1e6:.2f}M cfg/s")
    assert runs[1].argmin() == runs[8].argmin()
    print("  argmin identical across scan depths (always true)")


def report_winner():
    print("\n== full module report of the optimal configuration ==")
    best = partition.optimal_partition()      # array engine + scalar report
    print(f"  {best.label}: {best.avg_power*1e3:.3f} mW, "
          f"MIPI {best.mipi_bytes_per_s/1e6:.2f} MB/s, "
          f"on-sensor {best.sensor_macs_per_s/1e9:.2f} GMAC/s")
    for group, p in sorted(best.report.breakdown().items()):
        print(f"    {group:18s} {p*1e3:8.4f} mW")


if __name__ == "__main__":
    sweep_tech_nodes()
    sweep_detnet_fps()
    sweep_memory_tech()
    sweep_mipi_energy()
    pareto_study()
    streaming_sweep()
    constrained_sweep()
    architecture_search()
    session_study()
    backend_study()
    knob_search()
    report_winner()
