"""The paper's actual workload, end to end: DetNet -> ROI -> KeyNet.

    PYTHONPATH=src python examples/handtracking_pipeline.py

Runs the executable twins of the analytic layer tables on a synthetic
frame, on both datapaths:

* float32 (the aggregator's path), and
* the RBE-adapted int8 Pallas kernel path for pointwise convolutions
  (the on-sensor engine's 8-bit datapath, interpret mode on CPU),

then prices the frame with the semi-analytical power/latency models —
counts, power and latency all derived from the SAME layer tables.
"""

import time

import jax
import jax.numpy as jnp

from repro.core import latency
from repro.core.handtracking import build_detnet, build_keynet
from repro.models.cnn import HandCNN


def main():
    key = jax.random.key(0)
    frame = jax.random.uniform(key, (1, 240, 320, 1))   # downscaled frame

    det = HandCNN.detnet()
    det_params = det.init(key)
    t0 = time.time()
    det_out = det.apply(det_params, frame)
    print(f"DetNet: {det_out.shape} "
          f"({det.workload.total_macs/1e6:.0f} MMAC analytic == "
          f"{det.traced_macs()/1e6:.0f} MMAC traced) "
          f"in {time.time()-t0:.2f}s")

    # pick the max-confidence anchor as the 'hand'; crop a 96x96 ROI
    grid = det_out[0, :20 * 15 * 6].reshape(20, 15, 6)
    idx = jnp.unravel_index(jnp.argmax(grid[..., 0]), (20, 15))
    cy = int(idx[1]) * 16
    cx = int(idx[0]) * 16
    y0 = max(0, min(240 - 96, cy - 48))
    x0 = max(0, min(320 - 96, cx - 48))
    roi = jax.lax.dynamic_slice(frame, (0, y0, x0, 0), (1, 96, 96, 1))
    print(f"ROI at ({y0},{x0}) — {roi.size} B over MIPI vs "
          f"{frame.size} B raw ({frame.size/roi.size:.0f}x compression)")

    keynet = HandCNN.keynet()
    key_params = keynet.init(key)
    kp_f32 = keynet.apply(key_params, roi)
    kp_int8 = keynet.apply(key_params, roi, use_rbe_int8=True)
    err = float(jnp.linalg.norm(kp_f32 - kp_int8)
                / jnp.maximum(jnp.linalg.norm(kp_f32), 1e-9))
    print(f"KeyNet: {kp_f32.shape[1]//3} keypoints; int8-RBE path "
          f"rel err {err:.3%} (8-bit datapath, Pallas interpret)")

    print("\nSemi-analytical pricing of this exact pipeline:")
    from repro.core import system
    cen = system.build_centralized("7nm")
    dis = system.build_distributed("7nm", "7nm")
    lat = latency.latency_comparison()
    print(f"  power : centralized {cen.avg_power*1e3:.2f} mW vs "
          f"distributed {dis.avg_power*1e3:.2f} mW "
          f"(-{(1-dis.avg_power/cen.avg_power)*100:.1f}%)")
    print(f"  latency: centralized {lat['centralized_ms']:.2f} ms vs "
          f"distributed {lat['distributed_ms']:.2f} ms "
          f"(queue saving {lat['_queue_saving_ms']:.2f} ms, "
          f"readout saving {lat['_readout_saving_ms']:.2f} ms)")


if __name__ == "__main__":
    main()
