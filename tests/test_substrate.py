"""Tests for the distributed substrate: data pipeline, optimizer,
compression, checkpointing, fault tolerance, elastic replanning."""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_reduced_config
from repro.data import DataConfig, SyntheticLM, make_pipeline
from repro.optim import adamw
from repro.optim.compression import (CompressionConfig, Compressed,
                                     compress_with_feedback,
                                     compressed_bytes, decompress_tree,
                                     init_error_feedback)
from repro.runtime import (FaultToleranceController, FTConfig, replan_mesh,
                           rescale_batch)


class TestDataPipeline:
    def _cfg(self):
        return get_reduced_config("qwen2-0.5b")

    def test_deterministic_per_step(self):
        cfg = self._cfg()
        dc = DataConfig(seq_len=32, global_batch=4, seed=7)
        ds = SyntheticLM(cfg, dc)
        a, b = ds.batch_at(5), ds.batch_at(5)
        np.testing.assert_array_equal(a.tokens, b.tokens)
        c = ds.batch_at(6)
        assert not np.array_equal(a.tokens, c.tokens)

    def test_rank_sharding_disjoint_and_sized(self):
        cfg = self._cfg()
        batches = []
        for rank in range(4):
            dc = DataConfig(seq_len=16, global_batch=8, seed=1,
                            num_ranks=4, rank=rank)
            batches.append(SyntheticLM(cfg, dc).batch_at(0))
        assert all(b.tokens.shape == (2, 16) for b in batches)
        assert not np.array_equal(batches[0].tokens, batches[1].tokens)

    def test_labels_are_shifted_tokens(self):
        cfg = self._cfg()
        dc = DataConfig(seq_len=16, global_batch=2)
        b = SyntheticLM(cfg, dc).batch_at(0)
        np.testing.assert_array_equal(b.tokens[:, 1:], b.labels[:, :-1])

    def test_resume_replays_stream(self):
        cfg = self._cfg()
        dc = DataConfig(seq_len=16, global_batch=2, prefetch_depth=2)
        it1 = make_pipeline(cfg, dc, start_step=0)
        seq1 = [next(it1).tokens for _ in range(5)]
        it1.close()
        it2 = make_pipeline(cfg, dc, start_step=3)
        seq2 = [next(it2).tokens for _ in range(2)]
        it2.close()
        np.testing.assert_array_equal(seq1[3], seq2[0])
        np.testing.assert_array_equal(seq1[4], seq2[1])

    def test_tokens_in_vocab(self):
        cfg = self._cfg()
        b = SyntheticLM(cfg, DataConfig(seq_len=64,
                                        global_batch=2)).batch_at(0)
        assert b.tokens.min() >= 0 and b.tokens.max() < cfg.vocab_size


class TestAdamW:
    def test_descends_quadratic(self):
        cfg = adamw.AdamWConfig(lr=0.1, warmup_steps=0, total_steps=100,
                                weight_decay=0.0)
        params = {"w": jnp.asarray([[3.0, -2.0]])}
        state = adamw.init(cfg, params)
        for _ in range(60):
            grads = jax.tree.map(lambda p: 2 * p, params)
            params, state, _ = adamw.apply(cfg, params, grads, state)
        assert float(jnp.abs(params["w"]).max()) < 0.5

    def test_grad_clip(self):
        g, norm = adamw.clip_by_global_norm(
            {"a": jnp.full((10,), 100.0)}, 1.0)
        assert float(norm) > 100
        assert adamw.global_norm(g) == pytest.approx(1.0, rel=1e-4)

    def test_cosine_schedule_shape(self):
        cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                                min_lr_ratio=0.1)
        lr0 = float(adamw.cosine_lr(cfg, jnp.int32(0)))
        lr10 = float(adamw.cosine_lr(cfg, jnp.int32(10)))
        lr100 = float(adamw.cosine_lr(cfg, jnp.int32(100)))
        assert lr0 == pytest.approx(0.0)
        assert lr10 == pytest.approx(1.0, abs=0.02)
        assert lr100 == pytest.approx(0.1, abs=0.02)

    def test_bf16_moments_supported(self):
        cfg = adamw.AdamWConfig(moment_dtype="bfloat16")
        params = {"w": jnp.ones((4, 4))}
        st = adamw.init(cfg, params)
        assert st.mu["w"].dtype == jnp.bfloat16


class TestCompression:
    def test_roundtrip_int8_close(self):
        g = {"w": jax.random.normal(jax.random.key(0), (64, 64))}
        ef = init_error_feedback(g)
        comp, ef = compress_with_feedback(
            g, ef, CompressionConfig(kind="int8"))
        back = decompress_tree(comp)
        rel = float(jnp.linalg.norm(back["w"] - g["w"])
                    / jnp.linalg.norm(g["w"]))
        assert rel < 0.02

    def test_error_feedback_reinjects_residual(self):
        """With EF, the *sum* of transmitted gradients converges to the sum
        of true gradients (unbiasedness over time)."""
        cfg = CompressionConfig(kind="int8", error_feedback=True)
        g = {"w": jnp.full((32,), 0.001)}     # tiny grads: heavy quant err
        ef = init_error_feedback(g)
        total_sent = jnp.zeros((32,))
        n = 50
        for _ in range(n):
            comp, ef = compress_with_feedback(g, ef, cfg)
            total_sent = total_sent + decompress_tree(comp)["w"]
        true_total = g["w"] * n
        rel = float(jnp.linalg.norm(total_sent - true_total)
                    / jnp.linalg.norm(true_total))
        assert rel < 0.05

    def test_bytes_accounting(self):
        g = {"w": jnp.zeros((1000,))}
        assert compressed_bytes(g, CompressionConfig("int8")) == 1000
        assert compressed_bytes(g, CompressionConfig("bf16")) == 2000
        assert compressed_bytes(g, CompressionConfig("none")) == 4000


class TestCheckpoint:
    def _state(self):
        return {"params": {"w": jnp.arange(12, dtype=jnp.bfloat16
                                           ).reshape(3, 4),
                           "b": jnp.ones((4,), jnp.float32)},
                "step": jnp.int32(7)}

    def test_save_restore_roundtrip_bf16(self, tmp_path):
        cm = CheckpointManager(str(tmp_path))
        state = self._state()
        cm.save(10, state, metadata={"loss": 1.5})
        back = cm.restore(10, state)
        np.testing.assert_array_equal(
            np.asarray(back["params"]["w"], np.float32),
            np.asarray(state["params"]["w"], np.float32))
        assert back["params"]["w"].dtype == jnp.bfloat16
        assert cm.metadata(10)["loss"] == 1.5

    def test_latest_and_retention(self, tmp_path):
        cm = CheckpointManager(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            cm.save(s, self._state())
        assert cm.latest_step() == 4
        assert cm.all_steps() == [3, 4]

    def test_atomic_no_tmp_left(self, tmp_path):
        cm = CheckpointManager(str(tmp_path))
        cm.save(1, self._state())
        assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))

    def test_shape_mismatch_rejected(self, tmp_path):
        cm = CheckpointManager(str(tmp_path))
        cm.save(1, self._state())
        bad = self._state()
        bad["params"]["w"] = jnp.zeros((5, 5), jnp.bfloat16)
        with pytest.raises(ValueError):
            cm.restore(1, bad)

    def test_restore_into_shapedtypestructs(self, tmp_path):
        """Restoring into abstract shapes (fresh job) works."""
        cm = CheckpointManager(str(tmp_path))
        state = self._state()
        cm.save(2, state)
        like = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        back = cm.restore(2, like)
        assert back["params"]["w"].shape == (3, 4)


class TestFaultTolerance:
    def test_detects_dead_worker(self):
        ft = FaultToleranceController(4, FTConfig(
            heartbeat_interval_s=1.0, missed_heartbeats_fatal=3))
        for w in range(4):
            ft.heartbeat(w, now=0.0)
        ft.heartbeat(0, now=10.0)
        ft.heartbeat(1, now=10.0)
        ft.heartbeat(2, now=10.0)     # worker 3 silent since t=0
        ev = ft.tick(now=10.0)
        assert ev["kind"] == "restart_from_checkpoint"
        assert ev["lost"] == [3]
        assert ft.alive_count() == 3

    def test_straggler_eviction_needs_patience(self):
        cfg = FTConfig(straggler_factor=1.5, straggler_patience=3)
        ft = FaultToleranceController(4, cfg)
        for w in range(4):
            ft.heartbeat(w, now=0.0)
        for step in range(3):
            for w in range(4):
                ft.report_step(w, step, 2.0 if w == 2 else 1.0)
            ev = ft.tick(now=0.1)
            if step < 2:
                assert ev is None
        assert ev["kind"] == "evict_stragglers"
        assert ev["evicted"] == [2]

    def test_healthy_cluster_no_events(self):
        ft = FaultToleranceController(3)
        for w in range(3):
            ft.heartbeat(w, now=0.0)
            ft.report_step(w, 0, 1.0)
        assert ft.tick(now=1.0) is None


class TestElastic:
    def test_replan_keeps_model_axis(self):
        plan = replan_mesh(240, model=16)
        assert plan.shape == (15, 16)
        assert plan.dropped_chips == 0

    def test_replan_drops_remainder(self):
        plan = replan_mesh(250, model=16)
        assert plan.shape == (15, 16)
        assert plan.dropped_chips == 10

    def test_replan_multipod(self):
        plan = replan_mesh(512, model=16, pods=2)
        assert plan.axes == ("pod", "data", "model")
        assert plan.shape == (2, 16, 16)

    def test_degenerate_small_cluster(self):
        plan = replan_mesh(12, model=16)
        assert plan.chips <= 12

    def test_rescale_batch(self):
        assert rescale_batch(256, 16, 15, keep_global=True) == 256
        assert rescale_batch(256, 16, 8, keep_global=False) == 128


class TestTrainDriverEndToEnd:
    def test_tiny_train_run_loss_decreases(self, tmp_path):
        from repro.launch.train import main
        res = main(["--arch", "qwen2-0.5b", "--reduced", "--steps", "12",
                    "--seq-len", "32", "--global-batch", "4",
                    "--lr", "1e-2", "--warmup", "2",
                    "--ckpt-dir", str(tmp_path), "--ckpt-every", "6"])
        assert res["steps"] == 12
        assert res["loss_decreased"], (res["first_loss"],
                                       res["last_loss"])

    def test_resume_from_checkpoint(self, tmp_path):
        from repro.launch.train import main
        main(["--arch", "qwen2-0.5b", "--reduced", "--steps", "6",
              "--seq-len", "32", "--global-batch", "4",
              "--ckpt-dir", str(tmp_path), "--ckpt-every", "3"])
        res = main(["--arch", "qwen2-0.5b", "--reduced", "--steps", "9",
                    "--seq-len", "32", "--global-batch", "4",
                    "--ckpt-dir", str(tmp_path), "--ckpt-every", "3",
                    "--resume"])
        assert res["steps"] == 3   # resumed at 6, ran to 9
