"""Evaluation-backend layer: registry, parity matrix, scan fusion.

Every registered backend must reproduce the reference 10,880-grid
``StreamResult`` deliverables — argmin, top-k, channel bounds,
feasibility counts, and the exact Pareto front — against the dense
path, including the Pallas backend in interpret mode and scan-fused
dispatch (``scan_chunks`` ∈ {1, 4}) with a non-dividing chunk size.
The XLA/Pallas lowerings agree bitwise on this grid (asserted); the
documented contract is ≤1e-6.
"""

import numpy as np
import pytest

import jax

from repro.core import backend as B
from repro.core import pareto, partition, stream, sweep
from repro.core.handtracking import build_detnet, build_keynet

# The 10,880-config reference grid (lockstep with tests/test_stream.py
# and benchmarks/sweep_bench.py::GRID).
REFERENCE_GRID = dict(
    agg_nodes=("7nm", "16nm"),
    sensor_nodes=("7nm", "16nm"),
    weight_mems=("sram", "mram"),
    detnet_fps=(5.0, 10.0, 15.0, 20.0, 30.0),
    keynet_fps=(15.0, 30.0),
    num_cameras=(2, 4),
    mipi_energy_scale=(1.0, 2.0),
)

TOP_K = 4


@pytest.fixture(scope="module")
def dense():
    return sweep.evaluate_grid(**REFERENCE_GRID)


@pytest.fixture(scope="module")
def dense_front(dense):
    return pareto.pareto_front(dense)


class TestRegistry:
    def test_available_backends(self):
        names = B.available_backends()
        assert "xla" in names and "pallas" in names

    def test_default_is_xla(self):
        assert B.get_backend(None).name == "xla"
        assert B.get_backend().name == B.DEFAULT_BACKEND == "xla"

    def test_unknown_backend_raises_naming_available(self):
        with pytest.raises(ValueError, match="xla"):
            B.get_backend("cuda")
        with pytest.raises(ValueError, match="unknown"):
            stream.stream_grid(cuts=(0, 1), backend="nope")
        with pytest.raises(ValueError, match="unknown"):
            sweep.evaluate_grid(cuts=(0, 1), backend="nope")

    def test_pallas_registers_lazily(self):
        be = B.get_backend("pallas")
        assert be.name == "pallas"
        assert B.get_backend("pallas") is be

    def test_optimal_partition_validates_backend(self):
        with pytest.raises(ValueError, match="unknown"):
            partition.optimal_partition(backend="nope")
        with pytest.raises(ValueError, match="scalar"):
            partition.optimal_partition(engine="scalar", backend="xla")

    def test_optimal_partition_backend_plumbing(self):
        ref = partition.optimal_partition(sensor_node=("7nm", "16nm"))
        via = partition.optimal_partition(sensor_node=("7nm", "16nm"),
                                          backend="xla")
        assert via.cut == ref.cut and via.avg_power == ref.avg_power

    def test_scalar_fallback_rejects_explicit_backend(self):
        """A custom TechNode outside the registry falls back to the
        scalar engine, which must not silently ignore backend=."""
        import dataclasses

        from repro.core.constants import TECH_NODES
        custom = dataclasses.replace(TECH_NODES["7nm"])
        assert partition.optimal_partition(sensor_node=custom).cut >= 0
        with pytest.raises(ValueError, match="scalar"):
            partition.optimal_partition(sensor_node=custom, backend="xla")

    def test_pallas_falls_back_to_one_device_on_multidevice_hosts(self):
        """An auto-derived multi-device list must not crash a non-pmap
        backend; an explicit one must raise clearly."""
        import os
        import subprocess
        import sys

        code = """
import jax
from repro.core import stream, sweep
assert len(jax.local_devices()) == 2
res = stream.stream_grid(cuts=(0, 1, 2), backend="pallas")
assert res.n_devices == 1
assert res.argmin() == sweep.evaluate_grid(cuts=(0, 1, 2)).argmin()
try:
    stream.stream_grid(cuts=(0, 1, 2), backend="pallas",
                       devices=jax.local_devices())
except ValueError as e:
    assert "pmap" in str(e)
else:
    raise SystemExit("explicit multi-device pallas should raise")
print("PALLAS-FALLBACK-OK")
"""
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + " --xla_force_host_platform_device_count=2")
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.join(os.path.dirname(__file__), "..", "src")]
            + env.get("PYTHONPATH", "").split(os.pathsep))
        out = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, timeout=600)
        assert out.returncode == 0, out.stderr[-2000:]
        assert "PALLAS-FALLBACK-OK" in out.stdout


# The full matrix: every backend × scan depth must reproduce the dense
# deliverables with a chunk size that does not divide the grid.
@pytest.fixture(scope="module",
                params=[(be, k) for be in ("xla", "pallas")
                        for k in (1, 4)],
                ids=lambda p: f"{p[0]}-scan{p[1]}")
def streamed(request, dense):
    be, scan = request.param
    return stream.stream_grid(**REFERENCE_GRID, chunk_size=997,
                              top_k=TOP_K, track="all", backend=be,
                              scan_chunks=scan)


class TestBackendParityMatrix:
    def test_argmin_every_channel(self, streamed, dense):
        for field in sweep.FIELDS:
            assert streamed.argmin(field) == dense.argmin(field), field

    def test_top_k(self, streamed, dense):
        for obj in streamed.objectives:
            assert streamed.top_k(obj) == dense.top_k(obj, TOP_K), obj

    def test_pareto_front(self, streamed, dense_front):
        sf = streamed.pareto_front()
        assert np.array_equal(sf.indices, dense_front.indices)
        assert np.array_equal(sf.values, dense_front.values)

    def test_counts_and_bounds(self, streamed, dense):
        for field in sweep.FIELDS:
            assert streamed.finite_counts[field] == \
                int(np.isfinite(dense.data[field]).sum()), field
            assert streamed.channel_bounds(field) == \
                dense.channel_bounds(field), field

    def test_scan_depth_recorded(self, streamed):
        assert streamed.stats["scan_chunks"] in (1.0, 4.0)
        assert "dispatch_s" in streamed.stats
        assert "steps_per_s" in streamed.stats


class TestScanFusion:
    def test_auto_scan_kicks_in_on_many_steps(self, dense):
        # 10,880 / 256 ≈ 43 raw steps -> auto K > 1.
        res = stream.stream_grid(**REFERENCE_GRID, chunk_size=256)
        assert res.stats["scan_chunks"] > 1.0
        assert res.argmin() == dense.argmin()

    def test_small_grids_stay_unfused(self):
        res = stream.stream_grid(cuts=(0, 1, 2))
        assert res.stats["scan_chunks"] == 1.0

    def test_scan_clamped_to_step_count(self, dense):
        res = stream.stream_grid(**REFERENCE_GRID, chunk_size=4096,
                                 scan_chunks=64)
        assert res.stats["scan_chunks"] <= 3.0
        assert res.argmin() == dense.argmin()

    def test_scan_with_constraints_and_prefetch(self, dense):
        budget = {"latency":
                  float(np.nanquantile(dense.data["latency"], 0.4))}
        res = stream.stream_grid(**REFERENCE_GRID, chunk_size=997,
                                 scan_chunks=4, prefetch=4,
                                 constraints=budget)
        dc = dense.constrain(budget)
        assert res.argmin() == dc.argmin()
        cf, dcf = res.pareto_front(), pareto.pareto_front(dc)
        assert np.array_equal(cf.indices, dcf.indices)
        assert np.array_equal(cf.values, dcf.values)


class TestDenseBackend:
    def test_evaluate_grid_pallas_matches_xla(self):
        kw = dict(sensor_nodes=("7nm", "16nm"),
                  weight_mems=("sram", "mram"), detnet_fps=(5.0, 30.0))
        a = sweep.evaluate_grid(**kw)
        b = sweep.evaluate_grid(**kw, backend="pallas")
        for f in sweep.FIELDS:
            assert np.array_equal(a.data[f], b.data[f], equal_nan=True), f

    def test_pallas_stacked_models(self):
        det, key = build_detnet(), build_keynet()
        pairs = ((det, key), (det.scaled(0.5), key))
        a = sweep.evaluate_grid(models=pairs, detnet_fps=(10.0, 30.0))
        b = stream.stream_grid(models=pairs, detnet_fps=(10.0, 30.0),
                               chunk_size=31, backend="pallas")
        for o in b.objectives:
            assert a.argmin(o) == b.argmin(o), o

    def test_pallas_maximize_and_d1(self, dense):
        rm = stream.stream_grid(
            **REFERENCE_GRID, chunk_size=997, backend="pallas",
            objectives=("avg_power", "sensor_macs_per_s"),
            maximize=("sensor_macs_per_s",))
        macs = dense.data["sensor_macs_per_s"]
        best = rm.top_k("sensor_macs_per_s")[0]
        assert best["sensor_macs_per_s"] == float(np.nanmax(macs))
        r1 = stream.stream_grid(cuts=(0, 17, 33), backend="pallas",
                                objectives=("avg_power",))
        one = sweep.evaluate_grid(cuts=(0, 17, 33))
        assert r1.argmin() == one.argmin()


class TestPallasKernelOracle:
    def test_chunk_partials_match_xla_reference(self):
        """The fused pallas_call must reproduce every block partial of
        the shared reference expression (`backend.chunk_partials`)."""
        from repro.kernels import sweep_grid

        import jax.numpy as jnp
        from jax.experimental import enable_x64

        S, axis_vals, _ = sweep.build_axes(sensor_nodes=("7nm", "16nm"),
                                           weight_mems=("sram", "mram"))
        shape = tuple(a.size for a in axis_vals)
        n_total = int(np.prod(shape))
        spec = B.ChunkSpec(
            S=S, shape=shape, n_total=n_total, chunk=96,
            fields=tuple(pareto.DEFAULT_OBJECTIVES), d=3, k=4,
            sign=(1.0, 1.0, 1.0), cons_static=(), hist_bins=0,
            survivor_cap=96, small_index=True)
        with enable_x64():
            axvals = tuple(map(jnp.asarray, axis_vals))
            filt = pareto.build_dominance_filter(
                np.empty((0, 3)), 3, spec.filter_rows, spec.filter_bins)
            aux = {"filter": jax.tree_util.tree_map(jnp.asarray, filt)}
            ref = sweep_grid.chunk_partials_ref(spec, axvals, aux,
                                                jnp.int64(32))
            got = sweep_grid.build_chunk_call(spec, interpret=True)(
                axvals, aux, jnp.int64(32))
        for key in ref:
            assert np.array_equal(np.asarray(ref[key]),
                                  np.asarray(got[key]),
                                  equal_nan=True), key


class TestInt64Decode:
    """Satellite: >2^31-config spaces must not overflow int32 anywhere
    in the flat-index arithmetic (synthetic 10^10-config shape)."""

    SHAPE = (10,) * 10          # 10^10 configs — far beyond int32

    def test_numpy_decode_matches_unravel_index(self):
        flat = np.array([0, 2**31 - 1, 2**31, 2**33 + 12345,
                         10**10 - 1], np.int64)
        ours = sweep.decode_flat_index(self.SHAPE, flat)
        ref = np.unravel_index(flat, self.SHAPE)
        for a, b in zip(ours, ref):
            assert np.array_equal(a, b)

    def test_int32_input_is_promoted(self):
        # A narrow flat-index array on a huge shape must be widened
        # before the stride arithmetic, not wrapped.
        flat32 = np.array([7, 2**31 - 1], np.int32)
        ours = sweep.decode_flat_index(self.SHAPE, flat32)
        ref = np.unravel_index(flat32.astype(np.int64), self.SHAPE)
        for a, b in zip(ours, ref):
            assert np.array_equal(a, b)
            assert a.dtype == np.int64

    def test_traced_decode_beyond_int32(self):
        import jax.numpy as jnp
        from jax.experimental import enable_x64

        with enable_x64():
            flat = jnp.asarray([2**33 + 12345, 10**10 - 1], jnp.int64)
            ours = [np.asarray(c)
                    for c in jax.jit(
                        lambda f: sweep.decode_flat_index(self.SHAPE, f)
                    )(flat)]
        ref = np.unravel_index(np.asarray([2**33 + 12345, 10**10 - 1]),
                               self.SHAPE)
        for a, b in zip(ours, ref):
            assert np.array_equal(a, b)

    def test_python_int_decode(self):
        assert sweep.decode_flat_index(self.SHAPE, 10**10 - 1) == (9,) * 10

    def test_chunk_start_arithmetic_stays_int64(self):
        """The executor's ChunkSpec must refuse int32 decode once the
        index space (plus the per-dispatch overshoot) nears 2^31."""
        spec = B.ChunkSpec(
            S=None, shape=self.SHAPE, n_total=10**10, chunk=1 << 17,
            fields=("avg_power",), d=1, k=4, sign=(1.0,),
            cons_static=(), hist_bins=0, survivor_cap=64,
            small_index=False)
        assert spec.padded >= spec.chunk
        # config_from_flat round-trips a >int32 flat index exactly.
        from collections import OrderedDict
        axes = OrderedDict((f"ax{i}", tuple(range(10)))
                           for i in range(10))
        cfg = sweep.config_from_flat(self.SHAPE, axes, 2**33 + 12345)
        strides = [10**i for i in reversed(range(10))]
        expect = [(2**33 + 12345) // s % 10 for s in strides]
        assert [cfg[f"ax{i}"] for i in range(10)] == expect


class TestJobSignature:
    """Tentpole satellite: the resumable-sweep signature must change
    with anything that changes reduction semantics, and must *not*
    change with knobs that only shape the traced computation."""

    @staticmethod
    def _spec(**overrides):
        import dataclasses
        S, axis_vals, _ = sweep.build_axes(
            sensor_nodes=("7nm", "16nm"), weight_mems=("sram", "mram"))
        shape = tuple(a.size for a in axis_vals)
        spec = B.ChunkSpec(
            S=S, shape=shape, n_total=int(np.prod(shape)), chunk=96,
            fields=tuple(pareto.DEFAULT_OBJECTIVES), d=3, k=4,
            sign=(1.0, 1.0, 1.0), cons_static=(), hist_bins=0,
            survivor_cap=96, small_index=True)
        return dataclasses.replace(spec, **overrides), axis_vals

    def _sig(self, spec=None, axis_vals=None, backend=None,
             scan_chunks=1, cons=(), hist_ranges=None, **overrides):
        if spec is None:
            spec, av = self._spec(**overrides)
            axis_vals = av if axis_vals is None else axis_vals
        return B.job_signature(spec, backend, scan_chunks, cons,
                               axis_vals, hist_ranges)

    def test_deterministic_across_rebuilds(self):
        """Rebuilding the identical spec from scratch (fresh model
        stack arrays included) yields the identical signature."""
        assert self._sig() == self._sig()
        assert len(self._sig()) == 64        # sha256 hexdigest

    def test_semantic_knobs_change_the_signature(self):
        base = self._sig()
        assert self._sig(chunk=64) != base
        assert self._sig(k=5) != base
        assert self._sig(hist_bins=8) != base
        assert self._sig(sign=(1.0, 1.0, -1.0)) != base
        assert self._sig(scan_chunks=4) != base
        assert self._sig(backend="pallas") != base
        assert self._sig(cons=(("latency", "<=", 1e-3),)) != base
        assert self._sig(hist_ranges={"avg_power": (0.0, 1.0)}) != base

    def test_axis_values_change_the_signature(self):
        spec, axis_vals = self._spec()
        base = self._sig(spec=spec, axis_vals=axis_vals)
        bumped = list(axis_vals)
        bumped[-1] = np.asarray(bumped[-1]) * 2.0
        assert self._sig(spec=spec, axis_vals=tuple(bumped)) != base

    def test_trace_only_knobs_do_not_invalidate(self):
        """survivor_cap / small_index shape only the traced computation
        (overflow falls back to an exact host re-derivation), so they
        must not orphan existing checkpoints."""
        base = self._sig()
        assert self._sig(survivor_cap=48) == base
        assert self._sig(small_index=False) == base

    def test_default_backend_is_canonicalized(self):
        """backend=None and the explicit default name must agree, so a
        resume that spells the default out loud still matches."""
        assert self._sig(backend=None) == \
            self._sig(backend=B.DEFAULT_BACKEND)
