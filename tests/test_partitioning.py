"""Unit tests for the sharding rules (no multi-device mesh needed: rules
are pure functions of mesh metadata + shapes)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.launch import partitioning as pt


class FakeMesh:
    """Duck-typed mesh: the rules only read axis_names and shape."""

    def __init__(self, shape: dict):
        self._shape = shape

    @property
    def axis_names(self):
        return tuple(self._shape)

    @property
    def shape(self):
        return self._shape


MESH = FakeMesh({"data": 16, "model": 16})
MESH_MP = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _spec(path_str, shape, dtype=jnp.bfloat16, mesh=MESH):
    class K:
        def __init__(self, key):
            self.key = key
    path = tuple(K(p) for p in path_str.split("/"))
    leaf = jax.ShapeDtypeStruct(shape, dtype)
    return pt.param_spec(mesh, path, leaf)


class TestParamRules:
    def test_embed_vocab_sharded(self):
        # vocab on model; d picks up the FSDP data shard (272 MB tensor)
        assert _spec("embed/table", (151936, 896)) == P("model", "data")

    def test_unembed_vocab_sharded(self):
        assert _spec("unembed/w", (896, 151936)) == P("data", "model")

    def test_small_embed_no_fsdp(self):
        assert _spec("embed/table", (2048, 896)) == P("model", None)

    def test_attention_heads_sharded_when_divisible(self):
        # 32 heads % 16 == 0 -> heads on model
        s = _spec("blocks/0/mixer/w_q", (32, 4096, 32, 128))
        assert s[2] == "model"
        assert s[0] is None    # stacked scan dim never sharded

    def test_attention_heads_fallback_when_indivisible(self):
        # 14 heads % 16 != 0 -> falls back to a divisible dim
        s = _spec("blocks/0/mixer/w_q", (24, 896, 14, 64))
        assert "model" not in (s[2],)

    def test_expert_dim_sharded(self):
        s = _spec("blocks/0/ffn/w_up", (35, 128, 7168, 4864))
        assert s[1] == "model"

    def test_expert_fsdp_on_contraction_dim(self):
        # w_up (E, d, f): contraction dim d gets the data shard (§Perf)
        s = _spec("blocks/0/ffn/w_up", (35, 128, 7168, 4864))
        assert s[2] == "data"
        # w_down (E, f, d): contraction dim f gets it
        s2 = _spec("blocks/0/ffn/w_down", (35, 128, 4864, 7168))
        assert s2[2] == "data"

    def test_router_replicated(self):
        s = _spec("blocks/0/ffn/router", (35, 7168, 128), jnp.float32)
        assert all(x is None for x in s)

    def test_norms_replicated(self):
        s = _spec("blocks/0/norm1/scale", (32, 4096), jnp.float32)
        assert all(x is None for x in s)

    def test_small_tensors_no_fsdp(self):
        s = _spec("blocks/0/mixer/w_k", (24, 896, 2, 64))
        assert "data" not in tuple(s)


class TestBatchAndCacheRules:
    def test_batch_axes_single_vs_multipod(self):
        assert pt.batch_axes(MESH) == ("data",)
        assert pt.batch_axes(MESH_MP) == ("pod", "data")

    def test_every_cell_has_consistent_input_spec(self):
        """Every (arch x shape) input spec builds without error and batch
        dims only shard when divisible."""
        from repro.launch import specs
        for arch in ("qwen2-0.5b", "jamba-v0.1-52b"):
            cfg = get_config(arch)
            for shape in SHAPES.values():
                b = specs.input_specs(cfg, shape)
                sh = pt.batch_pspec(MESH, b)
                for spec, leaf in zip(
                        jax.tree.leaves(sh, is_leaf=lambda x: isinstance(
                            x, type(P()))),
                        jax.tree.leaves(b)):
                    # no axis may be assigned to a non-divisible dim
                    for i, ax in enumerate(spec):
                        if ax is None:
                            continue
                        axes = ax if isinstance(ax, tuple) else (ax,)
                        n = 1
                        for a in axes:
                            n *= MESH.shape[a]
                        assert leaf.shape[i] % n == 0


class TestAnalyticStateBytes:
    def test_state_bytes_match_hand_calc(self):
        from repro.launch.dryrun import _analytic_state_bytes
        from jax.sharding import NamedSharding
        # needs a real (1-device) mesh for NamedSharding — use specs only

        class FakeSharding:
            def __init__(self, spec, mesh):
                self.spec = spec
                self.mesh = mesh

        leaf = jax.ShapeDtypeStruct((16, 1024, 1024), jnp.bfloat16)
        sh = FakeSharding(P(None, "model", "data"), MESH)
        got = _analytic_state_bytes([sh], [leaf], 256)
        want = 16 * 1024 * 1024 * 2 / (16 * 16)
        assert got == pytest.approx(want)
