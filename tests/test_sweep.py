"""Scalar <-> vectorized parity for the design-space engine.

The array path (``repro.core.sweep.evaluate_grid``) must reproduce the
scalar dataclass path (``partition.evaluate_cut`` / ``system.build_*``)
to <=1e-6 relative error across a sampled grid — same equations, two
execution strategies.
"""

import numpy as np
import pytest

from repro.core import latency, partition, sweep, system
from repro.core.arrays import model_arrays
from repro.core.handtracking import build_detnet, build_keynet

REL_TOL = 1e-6

N_DET = len(build_detnet().layers)
N_ALL = N_DET + len(build_keynet().layers)

# A sampled grid covering every cut regime and every knob — every kernel
# axis takes at least two values so no rate/term mixup can hide behind a
# default.
CUTS = (0, 1, 5, N_DET, N_DET + 3, N_ALL)
NODES = ("7nm", "16nm")
WMEMS = ("sram", "mram")
DET_FPS = (10.0, 30.0)
KEY_FPS = (15.0, 30.0)
NCAMS = (1, 4)
MIPI_SCALES = (1.0, 2.0)
CAM_FPS = (30.0, 60.0)


def scalar_groups(report: system.SystemReport) -> dict[str, float]:
    """Map the scalar per-module breakdown onto the kernel's field names."""
    bd = report.breakdown()

    def g(pred):
        return sum(v for k, v in bd.items() if pred(k))

    return {
        "camera": g(lambda k: k == "camera"),
        "utsv": g(lambda k: k.startswith("utsv")),
        "mipi": g(lambda k: k.startswith("mipi")),
        "sensor_compute": g(lambda k: k.startswith("sensor")
                            and k.endswith("compute")),
        "sensor_memory": g(lambda k: k.startswith("sensor")
                           and k.endswith("memory")),
        "agg_compute": g(lambda k: k == "agg.compute"),
        "agg_memory": g(lambda k: k == "agg.memory"),
    }


def assert_rel(a: float, b: float, what: str):
    denom = max(abs(a), abs(b), 1e-30)
    assert abs(a - b) / denom <= REL_TOL, f"{what}: scalar={a} vec={b}"


class TestGridScalarParity:
    @pytest.fixture(scope="class")
    def grid(self):
        return sweep.evaluate_grid(
            cuts=CUTS, agg_nodes=NODES, sensor_nodes=NODES,
            weight_mems=WMEMS, detnet_fps=DET_FPS, keynet_fps=KEY_FPS,
            num_cameras=NCAMS, mipi_energy_scale=MIPI_SCALES,
            camera_fps=CAM_FPS)

    def test_full_sampled_grid_parity(self, grid):
        """Every grid cell matches the scalar path (or is NaN exactly when
        the scalar path would refuse the configuration)."""
        checked = invalid = 0
        for idx in np.ndindex(grid.shape):
            cfg = {name: vals[i]
                   for (name, vals), i in zip(grid.axes.items(), idx)}
            flat = int(np.ravel_multi_index(idx, grid.shape))
            vec_power = float(grid.avg_power.ravel()[flat])
            mram_invalid = (cfg["weight_mem"] == "mram"
                            and cfg["sensor_node"] == "7nm"
                            and cfg["cut"] > 0)
            if mram_invalid:
                assert np.isnan(vec_power), cfg
                with pytest.raises(ValueError):
                    partition.evaluate_cut(
                        cfg["cut"], agg_node=cfg["agg_node"],
                        sensor_node=cfg["sensor_node"],
                        sensor_weight_mem=cfg["weight_mem"],
                        detnet_fps=cfg["detnet_fps"],
                        keynet_fps=cfg["keynet_fps"],
                        num_cameras=int(cfg["num_cameras"]),
                        camera_fps=cfg["camera_fps"],
                        mipi_energy_scale=cfg["mipi_energy_scale"])
                invalid += 1
                continue
            pt = partition.evaluate_cut(
                cfg["cut"], agg_node=cfg["agg_node"],
                sensor_node=cfg["sensor_node"],
                sensor_weight_mem=cfg["weight_mem"],
                detnet_fps=cfg["detnet_fps"],
                keynet_fps=cfg["keynet_fps"],
                num_cameras=int(cfg["num_cameras"]),
                camera_fps=cfg["camera_fps"],
                mipi_energy_scale=cfg["mipi_energy_scale"])
            assert_rel(pt.avg_power, vec_power, f"avg_power @ {cfg}")
            assert_rel(pt.mipi_bytes_per_s,
                       float(grid.data["mipi_bytes_per_s"].ravel()[flat]),
                       f"mipi_bytes_per_s @ {cfg}")
            assert_rel(pt.sensor_macs_per_s,
                       float(grid.data["sensor_macs_per_s"].ravel()[flat]),
                       f"sensor_macs_per_s @ {cfg}")
            checked += 1
        assert checked > 100 and invalid > 0  # both regimes exercised

    def test_group_breakdown_parity_at_key_cuts(self):
        """Per-group powers match module-list groups at the three regimes
        the paper discusses (centralized, paper split, full on-sensor)."""
        for cut in (0, N_DET, N_ALL):
            pt = partition.evaluate_cut(cut, sensor_node="16nm")
            vec = sweep.evaluate_one(cut, sensor_node="16nm")
            for field, scalar_val in scalar_groups(pt.report).items():
                assert_rel(scalar_val, vec[field], f"{field} @ cut {cut}")

    def test_breakdown_fields_sum_to_total(self, grid):
        parts = sum(grid.data[f] for f in
                    ("camera", "utsv", "mipi", "sensor_compute",
                     "sensor_memory", "agg_compute", "agg_memory"))
        valid = ~np.isnan(grid.avg_power)
        np.testing.assert_allclose(parts[valid], grid.avg_power[valid],
                                   rtol=1e-12)


class TestBuilderParity:
    def test_matches_build_centralized(self):
        for node in NODES:
            rep = system.build_centralized(node)
            vec = sweep.evaluate_one(0, agg_node=node)
            assert_rel(rep.avg_power, vec["avg_power"],
                       f"centralized[{node}]")

    def test_matches_build_distributed(self):
        for agg in NODES:
            for sen in NODES:
                for mem in ("sram",) if sen == "7nm" else WMEMS:
                    rep = system.build_distributed(
                        agg, sen, sensor_weight_mem=mem)
                    vec = sweep.evaluate_one(
                        N_DET, agg_node=agg, sensor_node=sen,
                        sensor_weight_mem=mem)
                    assert_rel(rep.avg_power, vec["avg_power"],
                               f"distributed[{agg},{sen},{mem}]")
                    assert_rel(rep.group_power("sensor"),
                               vec["sensor_compute"] + vec["sensor_memory"],
                               f"on-sensor subsystem [{agg},{sen},{mem}]")


class TestOptimizer:
    def test_engines_agree_on_optimal_cut(self):
        """Array-engine argmin lands on the same cut as the scalar sweep,
        and `optimal_partition` (array-backed by default) returns it."""
        pts = partition.sweep_partitions()
        scalar_best = min(pts, key=lambda p: p.avg_power)
        grid = sweep.evaluate_grid()          # all cuts, defaults
        assert grid.argmin()["cut"] == scalar_best.cut
        best = partition.optimal_partition()
        assert best.cut == scalar_best.cut
        assert best.avg_power == min(p.avg_power for p in pts)

    def test_paper_boundary_beats_centralized_and_full_onsensor(self):
        """The paper's DetNet/KeyNet boundary remains a local optimum of
        the grid: cheaper than both extremes (the layer-level sweep may
        do even better — a beyond-paper finding the seed already pins)."""
        power = sweep.evaluate_grid().avg_power.ravel()
        assert power[N_DET] < power[0]
        assert power[N_DET] < power[N_ALL]
        best = partition.optimal_partition()
        assert best.avg_power <= power[N_DET] * (1 + 1e-12)

    def test_both_engines_reject_mram_without_test_vehicle(self):
        """The array engine must not quietly return the one valid
        centralized point when every cut > 0 is invalid — it raises like
        the scalar sweep does."""
        for engine in ("array", "scalar"):
            with pytest.raises(ValueError, match="MRAM"):
                partition.optimal_partition(engine=engine,
                                            sensor_node="7nm",
                                            sensor_weight_mem="mram")

    def test_invalid_mram_cut0_is_valid(self):
        """Centralized configs never build a sensor site, so MRAM on a
        node without a test vehicle is only invalid for cut > 0."""
        grid = sweep.evaluate_grid(cuts=(0, 1), sensor_nodes=("7nm",),
                                   weight_mems=("mram",))
        power = grid.avg_power.ravel()
        assert np.isfinite(power[0]) and np.isnan(power[1])


class TestLatencyChannel:
    """The kernel's ``latency`` channel is ``latency.cut_latency`` lowered
    onto the cycle prefix-sums — scalar and vector must agree ≤1e-6."""

    def test_sampled_grid_parity_with_cut_latency(self):
        grid = sweep.evaluate_grid(
            cuts=CUTS, agg_nodes=NODES, sensor_nodes=NODES,
            detnet_fps=DET_FPS, keynet_fps=KEY_FPS, num_cameras=NCAMS,
            camera_fps=CAM_FPS)
        lat = grid.latency
        for idx in np.ndindex(grid.shape):
            cfg = {name: vals[i]
                   for (name, vals), i in zip(grid.axes.items(), idx)}
            scalar = latency.cut_latency(
                cfg["cut"], agg_node=cfg["agg_node"],
                sensor_node=cfg["sensor_node"],
                num_cameras=int(cfg["num_cameras"]),
                camera_fps=cfg["camera_fps"],
                detnet_fps=cfg["detnet_fps"],
                keynet_fps=cfg["keynet_fps"]).total
            assert_rel(scalar, float(lat[idx]), f"latency @ {cfg}")

    def test_partition_point_latency_matches_grid(self):
        for cut in (0, N_DET, N_ALL):
            pt = partition.evaluate_cut(cut, sensor_node="16nm",
                                        num_cameras=2)
            vec = sweep.evaluate_one(cut, sensor_node="16nm",
                                     num_cameras=2)
            assert_rel(pt.latency, vec["latency"], f"latency @ cut {cut}")

    def test_cut0_reduces_to_centralized_helper(self):
        """At the defaults (30/10 fps = detnet_every 3), the generalized
        model reproduces the topology-specific helper exactly."""
        assert latency.cut_latency(0, agg_node="7nm").total == \
            pytest.approx(
                latency.centralized_latency("7nm", detnet_every=3).total,
                rel=1e-12)

    def test_paper_cut_close_to_distributed_helper(self):
        """The generalized model adds only the tiny amortized DetNet-output
        payload the distributed helper ignores."""
        gen = latency.cut_latency(N_DET, sensor_node="16nm").total
        ref = latency.distributed_latency(sensor_node="16nm",
                                          detnet_every=3).total
        assert gen == pytest.approx(ref, rel=1e-4)
        assert gen >= ref   # the extra payload can only add time

    def test_distributed_beats_centralized_on_latency(self):
        """Paper §1: the DOSC topology claims latency benefits too."""
        lat = sweep.evaluate_grid().latency.ravel()
        assert lat[N_DET] < lat[0]

    def test_invalid_corners_poison_all_objective_channels(self):
        grid = sweep.evaluate_grid(cuts=(0, 1), sensor_nodes=("7nm",),
                                   weight_mems=("mram",))
        for field in ("avg_power", "latency", "mipi_bytes_per_s",
                      "sensor_macs_per_s"):
            col = grid.data[field].ravel()
            assert np.isfinite(col[0]), field      # centralized: valid
            assert np.isnan(col[1]), field         # cut>0: poisoned


class TestEngineMechanics:
    def test_grid_shape_and_axes(self):
        grid = sweep.evaluate_grid(cuts=(0, N_DET), agg_nodes=NODES,
                                   detnet_fps=(5.0, 10.0, 15.0))
        assert grid.shape == (2, 2, 1, 1, 3, 1, 1, 1, 1)
        assert grid.n_configs == 12
        assert grid.axes["detnet_fps"] == (5.0, 10.0, 15.0)
        for f in sweep.FIELDS:
            assert grid.data[f].shape == grid.shape

    def test_x64_scoping_leaves_global_config_untouched(self):
        import jax.numpy as jnp
        sweep.evaluate_grid(cuts=(0,))
        assert jnp.asarray(1.0).dtype == jnp.float32

    def test_model_arrays_cached(self):
        assert model_arrays() is model_arrays()

    def test_rejects_bad_axes(self):
        with pytest.raises(ValueError):
            sweep.evaluate_grid(cuts=(N_ALL + 1,))
        with pytest.raises(ValueError):
            sweep.evaluate_grid(weight_mems=("flash",))
        with pytest.raises(KeyError):
            sweep.evaluate_grid(agg_nodes=("3nm",))
        with pytest.raises(ValueError, match="num_cameras"):
            sweep.evaluate_grid(num_cameras=(0,))

    def test_argmin_on_all_nan_grid_is_informative(self):
        grid = sweep.evaluate_grid(cuts=(1, 2), sensor_nodes=("7nm",),
                                   weight_mems=("mram",))
        with pytest.raises(ValueError, match="invalid"):
            grid.argmin()

    def test_argmin_all_nan_names_the_invalid_axes(self):
        """The error must say *which* axis values are fully invalid, not
        just that a nanargmin failed."""
        grid = sweep.evaluate_grid(cuts=(1, 2), sensor_nodes=("7nm",),
                                   weight_mems=("mram",))
        with pytest.raises(ValueError) as ei:
            grid.argmin()
        msg = str(ei.value)
        assert "weight_mem='mram'" in msg and "sensor_node='7nm'" in msg
        with pytest.raises(ValueError, match="mram"):
            grid.top_k()
        with pytest.raises(ValueError, match="mram"):
            grid.channel_bounds("avg_power")

    def test_pareto_front_all_invalid_raises(self):
        from repro.core import pareto
        grid = sweep.evaluate_grid(cuts=(1, 2), sensor_nodes=("7nm",),
                                   weight_mems=("mram",))
        with pytest.raises(ValueError, match="invalid"):
            pareto.pareto_front(grid)

    def test_top_k_matches_stable_argsort(self):
        grid = sweep.evaluate_grid(sensor_nodes=("7nm", "16nm"),
                                   weight_mems=("sram", "mram"))
        got = grid.top_k("avg_power", 5)
        vals = grid.avg_power.ravel().copy()
        vals[np.isnan(vals)] = np.inf
        order = np.argsort(vals, kind="stable")[:5]
        assert [c["avg_power"] for c in got] == [float(vals[i])
                                                for i in order]
        assert got[0] == grid.argmin() | {"avg_power": got[0]["avg_power"]}

    def test_config_at_uses_arithmetic_decode(self):
        """config_at must agree with decode_flat_index (the streamer's
        shared decode) — no coordinate meshes involved."""
        grid = sweep.evaluate_grid(cuts=(0, 5, 9), sensor_nodes=("7nm",
                                                                 "16nm"),
                                   detnet_fps=(5.0, 30.0))
        for flat in (0, 5, grid.n_configs - 1):
            idx = sweep.decode_flat_index(grid.shape, flat)
            expect = {name: vals[i] for (name, vals), i
                      in zip(grid.axes.items(), idx)}
            assert grid.config_at(flat) == expect


class TestConstraintHelpers:
    """Dense-side constraint machinery (the host twin of the streaming
    executor's compiled predicates)."""

    @pytest.fixture(scope="class")
    def grid(self):
        return sweep.evaluate_grid(sensor_nodes=("7nm", "16nm"),
                                   weight_mems=("sram", "mram"),
                                   detnet_fps=(5.0, 30.0))

    def test_constrain_masks_every_channel(self, grid):
        budget = float(np.nanmedian(grid.data["latency"]))
        con = grid.constrain({"latency": budget})
        with np.errstate(invalid="ignore"):
            feas = grid.data["latency"] <= budget
        for field in sweep.FIELDS:
            expect = feas & np.isfinite(grid.data[field])
            assert np.array_equal(np.isfinite(con.data[field]), expect), \
                field

    def test_constrain_argmin_is_feasible_best(self, grid):
        budget = float(np.nanquantile(grid.data["avg_power"], 0.5))
        con = grid.constrain([("avg_power", ">=", budget)])
        best = con.argmin("avg_power")
        assert best["avg_power"] >= budget
        vals = grid.avg_power.ravel()
        with np.errstate(invalid="ignore"):
            feasible = vals[vals >= budget]
        assert best["avg_power"] == float(feasible.min())

    def test_empty_constraints_identity(self, grid):
        assert grid.constrain(None) is grid
        assert grid.constrain(()) is grid

    def test_constraint_mask_matches_ops(self, grid):
        mask = sweep.constraint_mask(grid.data,
                                     ["mipi_bytes_per_s < 1e7",
                                      ("latency", ">", 0.0)])
        with np.errstate(invalid="ignore"):
            expect = ((grid.data["mipi_bytes_per_s"] < 1e7)
                      & (grid.data["latency"] > 0.0))
        assert np.array_equal(mask, expect)

    def test_nan_rows_never_feasible(self, grid):
        mask = sweep.constraint_mask(grid.data, {"latency": np.inf})
        assert not mask[np.isnan(grid.data["latency"])].any()
