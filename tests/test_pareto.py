"""Pareto-front extraction: dominance correctness, NaN-corner masking,
knee point, and hypervolume — all against brute-force oracles."""

import numpy as np
import pytest

from repro.core import pareto, sweep
from repro.core.handtracking import build_detnet, build_keynet

N_DET = len(build_detnet().layers)
N_ALL = N_DET + len(build_keynet().layers)


def brute_force_mask(points: np.ndarray) -> np.ndarray:
    """O(n^2) Python-loop oracle for the non-dominated set (minimize)."""
    pts = np.asarray(points, float)
    n = pts.shape[0]
    mask = np.zeros(n, bool)
    for i in range(n):
        if not np.isfinite(pts[i]).all():
            continue
        dominated = False
        for k in range(n):
            if k == i or not np.isfinite(pts[k]).all():
                continue
            if (pts[k] <= pts[i]).all() and (pts[k] < pts[i]).any():
                dominated = True
                break
        mask[i] = not dominated
    return mask


class TestDominance:
    def test_hand_built_front(self):
        pts = np.array([
            [1.0, 5.0],    # front
            [2.0, 3.0],    # front
            [4.0, 1.0],    # front
            [2.0, 4.0],    # dominated by (2, 3)
            [5.0, 5.0],    # dominated by everything
            [4.0, 1.0],    # duplicate of a front point: kept (ties survive)
        ])
        np.testing.assert_array_equal(
            pareto.non_dominated_mask(pts),
            [True, True, True, False, False, True])

    def test_single_objective_is_argmin(self):
        pts = np.array([[3.0], [1.0], [2.0], [1.0]])
        np.testing.assert_array_equal(pareto.non_dominated_mask(pts),
                                      [False, True, False, True])

    def test_matches_brute_force_random(self):
        rng = np.random.default_rng(7)
        for d in (2, 3, 4):
            # Coarse integer grid => plenty of ties and duplicates.
            pts = rng.integers(0, 6, size=(600, d)).astype(float)
            np.testing.assert_array_equal(pareto.non_dominated_mask(pts),
                                          brute_force_mask(pts))

    def test_chunking_boundary(self):
        rng = np.random.default_rng(1)
        pts = rng.normal(size=(pareto._CHUNK + 3, 3))
        np.testing.assert_array_equal(pareto.non_dominated_mask(pts),
                                      brute_force_mask(pts))

    def test_nan_rows_never_on_front(self):
        pts = np.array([[np.nan, 0.0], [0.0, np.inf], [1.0, 1.0]])
        np.testing.assert_array_equal(pareto.non_dominated_mask(pts),
                                      [False, False, True])
        assert not pareto.non_dominated_mask(
            np.full((4, 2), np.nan)).any()

    def test_rejects_bad_shape(self):
        with pytest.raises(ValueError):
            pareto.non_dominated_mask(np.zeros(5))


class TestFrontOverGrid:
    @pytest.fixture(scope="class")
    def grid(self):
        # Mixes valid and invalid (7nm + MRAM, cut > 0) corners.
        return sweep.evaluate_grid(sensor_nodes=("7nm", "16nm"),
                                   weight_mems=("sram", "mram"),
                                   detnet_fps=(5.0, 10.0, 30.0))

    def test_front_is_exact_nondominated_set(self, grid):
        front = pareto.pareto_front(grid)
        V = np.stack([grid.data[o].ravel()
                      for o in pareto.DEFAULT_OBJECTIVES], axis=1)
        expect = np.flatnonzero(brute_force_mask(V))
        assert sorted(front.indices.tolist()) == sorted(expect.tolist())
        assert 0 < front.size < grid.n_configs

    def test_nan_corners_masked(self, grid):
        assert np.isnan(grid.avg_power).any()          # fixture has them
        assert np.isnan(grid.latency).any()            # poisoned channels
        assert np.isnan(grid.mipi_bytes_per_s).any()
        front = pareto.pareto_front(grid)
        assert np.isfinite(front.values).all()
        for cfg in front.configs():
            assert not (cfg["weight_mem"] == "mram"
                        and cfg["sensor_node"] == "7nm" and cfg["cut"] > 0)

    def test_front_sorted_and_configs_roundtrip(self, grid):
        front = pareto.pareto_front(grid)
        assert (np.diff(front.values[:, 0]) >= 0).all()
        cfgs = front.configs()
        assert len(cfgs) == front.size
        # config_at + channel lookup reproduces the stored values
        for cfg, flat, vals in zip(cfgs, front.indices, front.values):
            assert cfg["avg_power"] == pytest.approx(
                float(grid.avg_power.ravel()[flat]))
            assert vals[0] == pytest.approx(cfg["avg_power"])

    def test_front_members_are_mutually_nondominated(self, grid):
        front = pareto.pareto_front(grid)
        assert pareto.non_dominated_mask(front.values).all()

    def test_maximize_flips_orientation(self, grid):
        f = pareto.pareto_front(grid,
                                objectives=("avg_power",
                                            "sensor_macs_per_s"),
                                maximize=("sensor_macs_per_s",))
        V = np.stack([grid.data["avg_power"].ravel(),
                      -grid.data["sensor_macs_per_s"].ravel()], axis=1)
        expect = np.flatnonzero(brute_force_mask(V))
        assert sorted(f.indices.tolist()) == sorted(expect.tolist())

    def test_single_objective_front_is_argmin(self, grid):
        f = pareto.pareto_front(grid, objectives=("avg_power",))
        assert float(f.values[0, 0]) == pytest.approx(
            float(np.nanmin(grid.avg_power)))

    def test_rejects_bad_arguments(self, grid):
        with pytest.raises(ValueError, match="unknown objective"):
            pareto.pareto_front(grid, objectives=("avg_power", "nope"))
        with pytest.raises(ValueError, match="maximize"):
            pareto.pareto_front(grid, objectives=("avg_power",),
                                maximize=("latency",))
        with pytest.raises(ValueError):
            pareto.pareto_front(grid, objectives=())


class TestKnee:
    def test_obvious_elbow(self):
        # Extremes win one axis each; the middle point is the compromise.
        pts = np.array([[0.0, 1.0], [0.15, 0.2], [1.0, 0.0]])
        assert pareto.knee_point(pts) == 1

    def test_scale_invariant(self):
        pts = np.array([[0.0, 1.0], [0.15, 0.2], [1.0, 0.0]])
        scaled = pts * np.array([1e-3, 1e9])   # wildly different units
        assert pareto.knee_point(scaled) == pareto.knee_point(pts)

    def test_front_knee_returns_config(self):
        grid = sweep.evaluate_grid(sensor_nodes=("16nm",))
        knee = pareto.pareto_front(grid).knee()
        assert set(pareto.DEFAULT_OBJECTIVES) <= set(knee)
        assert 0 <= knee["cut"] <= N_ALL

    def test_rejects_empty(self):
        with pytest.raises(ValueError):
            pareto.knee_point(np.zeros((0, 2)))


class TestHypervolume:
    def test_single_point_is_box_volume(self):
        assert pareto.hypervolume([[1.0, 1.0, 1.0]],
                                  [2.0, 3.0, 4.0]) == pytest.approx(6.0)

    def test_2d_staircase_union(self):
        pts = [[1.0, 3.0], [2.0, 2.0], [3.0, 1.0]]
        # union of the three boxes against ref (4, 4): 1 + 2 + 3
        assert pareto.hypervolume(pts, [4.0, 4.0]) == pytest.approx(6.0)

    def test_3d_matches_inclusion_exclusion(self):
        a, b = [1.0, 2.0, 3.0], [2.0, 1.0, 2.0]
        ref = [4.0, 4.0, 4.0]
        va = (4 - 1) * (4 - 2) * (4 - 3)
        vb = (4 - 2) * (4 - 1) * (4 - 2)
        vab = (4 - 2) * (4 - 2) * (4 - 3)   # componentwise max
        assert pareto.hypervolume([a, b], ref) == pytest.approx(
            va + vb - vab)

    def test_dominated_and_out_of_ref_points_add_nothing(self):
        base = pareto.hypervolume([[1.0, 1.0]], [3.0, 3.0])
        more = pareto.hypervolume([[1.0, 1.0], [2.0, 2.0], [5.0, 0.5]],
                                  [3.0, 3.0])
        assert more == pytest.approx(base)
        assert pareto.hypervolume([[4.0, 4.0]], [3.0, 3.0]) == 0.0

    def test_adding_a_front_point_grows_hv(self):
        ref = [4.0, 4.0]
        assert (pareto.hypervolume([[1.0, 3.0], [3.0, 1.0], [1.8, 1.8]],
                                   ref)
                > pareto.hypervolume([[1.0, 3.0], [3.0, 1.0]], ref))

    def test_front_default_ref_positive_and_ref_override(self):
        grid = sweep.evaluate_grid(sensor_nodes=("7nm", "16nm"))
        front = pareto.pareto_front(grid)
        assert front.hypervolume() > 0
        ref = {o: float(np.nanmax(grid.data[o]) * 2)
               for o in front.objectives}
        assert front.hypervolume(ref) > front.hypervolume()

    def test_rejects_mismatched_ref(self):
        with pytest.raises(ValueError):
            pareto.hypervolume([[1.0, 2.0]], [3.0, 3.0, 3.0])

    def test_exact_slicer_bounded_above_1000_points_at_3d(self):
        """d>=3 fronts beyond HV_EXACT_MAX_POINTS non-dominated points
        must raise a clear error instead of silently hanging in the
        exponential slicer; d<=2 sweeps stay unbounded."""
        n = pareto.HV_EXACT_MAX_POINTS + 100
        t = np.linspace(0.01, 0.99, n)
        shell3 = np.stack([t, 1.0 - t, 1.0 + np.cos(7.0 * t)], axis=1)
        assert pareto.non_dominated_mask(shell3).sum() > \
            pareto.HV_EXACT_MAX_POINTS
        with pytest.raises(ValueError, match="exceeds the exact"):
            pareto.hypervolume(shell3, [3.0, 3.0, 3.0])
        # Dominated bulk does not count against the bound.
        bulk = np.concatenate([shell3[:4],
                               np.full((n, 3), 2.5)], axis=0)
        assert pareto.hypervolume(bulk, [3.0, 3.0, 3.0]) > 0.0
        # 2-D stays an O(n log n) sweep with no cap.
        shell2 = np.stack([t, 1.0 - t], axis=1)
        assert pareto.hypervolume(shell2, [2.0, 2.0]) > 0.0


class TestLargeGridPreCull:
    """The sampled dominance-filter pre-cull in pareto_front (engaged
    above 2^16 rows) must be invisible: exactly the direct mask's front."""

    class _FakeResult:
        def __init__(self, V):
            self.data = {"a": V[:, 0], "b": V[:, 1], "c": V[:, 2]}
            self.shape = (V.shape[0],)
            self.axes = {"x": tuple(range(V.shape[0]))}

        def config_at(self, i):
            return {"x": i}

    @pytest.fixture(scope="class")
    def big(self):
        rng = np.random.default_rng(11)
        V = rng.random((150_000, 3)) ** 2
        V[rng.random(150_000) < 0.02] = np.nan
        return V

    def test_matches_direct_mask(self, big):
        front = pareto.pareto_front(self._FakeResult(big),
                                    objectives=("a", "b", "c"))
        ref = np.flatnonzero(pareto.non_dominated_mask(big))
        order = np.argsort(big[ref][:, 0], kind="stable")
        assert np.array_equal(front.indices, ref[order])
        assert np.array_equal(front.values, big[ref][order])

    def test_matches_direct_mask_maximize(self, big):
        front = pareto.pareto_front(self._FakeResult(big),
                                    objectives=("a", "b", "c"),
                                    maximize=("b",))
        sgn = np.array([1.0, -1.0, 1.0])
        ref = np.flatnonzero(pareto.non_dominated_mask(big * sgn))
        order = np.argsort(big[ref][:, 0], kind="stable")
        assert np.array_equal(front.indices, ref[order])

    def test_duplicate_heavy_ties_survive(self):
        rng = np.random.default_rng(3)
        base = rng.random((5_000, 3))
        V = np.repeat(base, 16, axis=0)          # 80_000 rows, 16x dups
        V = np.concatenate([V, base])            # > 2^16 engages pre-cull
        front = pareto.pareto_front(self._FakeResult(V),
                                    objectives=("a", "b", "c"))
        ref = np.flatnonzero(pareto.non_dominated_mask(V))
        assert set(front.indices.tolist()) == set(ref.tolist())
