"""Per-architecture smoke tests (deliverable f).

For each of the 10 assigned architectures: instantiate a REDUCED config of
the same family, run one forward and one train step (loss + grads) on CPU,
assert output shapes and absence of NaNs.  Full configs are exercised only
via the dry-run (ShapeDtypeStruct, no allocation).
"""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced_config
from repro.models import transformer as T
from repro.models.transformer import Batch

B, S = 2, 32


def _batch(cfg, key):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    if cfg.frontend_stub:
        emb = jax.random.normal(key, (B, S, cfg.d_model),
                                jnp.float32) * 0.1
        return Batch(embeds=emb.astype(jnp.bfloat16), labels=toks)
    return Batch(tokens=toks, labels=toks)


@pytest.fixture(scope="module")
def key():
    return jax.random.key(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_forward_shape_and_finite(self, arch, key):
        cfg = get_reduced_config(arch)
        params = T.init_params(cfg, key)
        batch = _batch(cfg, key)
        logits = T.forward(cfg, params, batch)
        assert logits.shape == (B, S, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    def test_train_step_loss_and_grads_finite(self, arch, key):
        cfg = get_reduced_config(arch)
        params = T.init_params(cfg, key)
        batch = _batch(cfg, key)
        loss, grads = jax.value_and_grad(
            lambda p: T.loss_fn(cfg, p, batch))(params)
        assert bool(jnp.isfinite(loss))
        for leaf in jax.tree.leaves(grads):
            assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))

    def test_decode_step_shapes(self, arch, key):
        cfg = get_reduced_config(arch)
        params = T.init_params(cfg, key)
        cache = T.init_cache(cfg, B, S)
        if cfg.frontend_stub:
            b1 = Batch(embeds=jnp.zeros((B, 1, cfg.d_model), jnp.bfloat16))
        else:
            b1 = Batch(tokens=jnp.zeros((B, 1), jnp.int32))
        logits, cache2 = T.decode_step(cfg, params, cache, b1, jnp.int32(0))
        assert logits.shape == (B, 1, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
        assert jax.tree.structure(cache) == jax.tree.structure(cache2)

    def test_full_config_matches_assignment(self, arch, key):
        """The exact published numbers from the assignment table."""
        cfg = get_config(arch)
        expected = {
            "phi4-mini-3.8b": (32, 3072, 24, 8, 8192, 200064),
            "qwen2-0.5b": (24, 896, 14, 2, 4864, 151936),
            "codeqwen1.5-7b": (32, 4096, 32, 32, 13440, 92416),
            "gemma2-2b": (26, 2304, 8, 4, 9216, 256000),
            "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
            "deepseek-v2-236b": (60, 5120, 128, 128, 1536, 102400),
            "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
            "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
            "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
            "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
        }[arch]
        got = (cfg.num_layers, cfg.d_model, cfg.num_heads,
               cfg.num_kv_heads, cfg.d_ff, cfg.vocab_size)
        assert got == expected


class TestArchSpecifics:
    def test_moe_configs(self):
        arctic = get_config("arctic-480b")
        assert (arctic.moe.num_experts, arctic.moe.top_k) == (128, 2)
        assert arctic.moe.dense_residual
        ds = get_config("deepseek-v2-236b")
        assert (ds.moe.num_experts, ds.moe.top_k) == (160, 6)
        assert ds.moe.num_shared_experts == 2
        assert ds.attention_kind == "mla" and ds.kv_lora_rank == 512
        jm = get_config("jamba-v0.1-52b")
        assert (jm.moe.num_experts, jm.moe.top_k) == (16, 2)
        assert jm.moe.every_k_layers == 2

    def test_jamba_1_7_interleave(self):
        jm = get_config("jamba-v0.1-52b")
        kinds = [jm.layer_kind(i) for i in range(jm.num_layers)]
        assert kinds.count("attn") == 4            # 1:7 over 32 layers
        assert all(kinds[i] == "attn" for i in (4, 12, 20, 28))

    def test_gemma2_local_global_alternation(self):
        g = get_config("gemma2-2b")
        kinds = [g.layer_kind(i) for i in range(g.num_layers)]
        assert kinds[::2] == ["attn_local"] * 13
        assert kinds[1::2] == ["attn_global"] * 13
        assert g.attn_logit_softcap == 50.0
        assert g.final_logit_softcap == 30.0

    def test_xlstm_mixed_blocks(self):
        x = get_config("xlstm-350m")
        kinds = {x.layer_kind(i) for i in range(x.num_layers)}
        assert kinds == {"mlstm", "slstm"}

    def test_param_counts_roughly_match_names(self):
        """Analytic count should land near the billed model size."""
        expectations = {
            "phi4-mini-3.8b": (3.0e9, 5.0e9),
            "qwen2-0.5b": (0.3e9, 0.7e9),
            "codeqwen1.5-7b": (6.0e9, 8.5e9),
            "gemma2-2b": (2.0e9, 3.5e9),
            "arctic-480b": (400e9, 560e9),
            "deepseek-v2-236b": (180e9, 280e9),
            "jamba-v0.1-52b": (40e9, 65e9),
        }
        for arch, (lo, hi) in expectations.items():
            n = get_config(arch).param_count()
            assert lo < n < hi, f"{arch}: {n/1e9:.1f}B outside [{lo/1e9}," \
                                f"{hi/1e9}]B"

    def test_moe_active_params_much_smaller(self):
        for arch in ("arctic-480b", "deepseek-v2-236b", "jamba-v0.1-52b"):
            cfg = get_config(arch)
            assert cfg.param_count(active_only=True) \
                < 0.35 * cfg.param_count()


class TestDecodeConsistency:
    """Token-by-token decode must reproduce the full forward pass."""

    @pytest.mark.parametrize("arch", [
        "phi4-mini-3.8b", "gemma2-2b", "xlstm-350m", "musicgen-large",
        "qwen2-vl-2b",
    ])
    def test_decode_matches_forward(self, arch, key):
        cfg = dataclasses.replace(get_reduced_config(arch), dtype="float32")
        params = T.init_params(cfg, key)
        s = 12
        toks = jax.random.randint(key, (B, s), 0, cfg.vocab_size)
        if cfg.frontend_stub:
            emb = jax.random.normal(key, (B, s, cfg.d_model),
                                    jnp.float32) * 0.1
            batch = Batch(embeds=emb)
        else:
            batch = Batch(tokens=toks)
        full = T.forward(cfg, params, batch)
        cache = T.init_cache(cfg, B, s)
        for t in range(s):
            b1 = (Batch(embeds=batch.embeds[:, t:t + 1])
                  if cfg.frontend_stub else Batch(tokens=toks[:, t:t + 1]))
            lg, cache = T.decode_step(cfg, params, cache, b1, jnp.int32(t))
            assert float(jnp.max(jnp.abs(lg[:, 0] - full[:, t]))) < 1e-3

    @pytest.mark.parametrize("arch", [
        "arctic-480b", "deepseek-v2-236b", "jamba-v0.1-52b",
    ])
    def test_moe_decode_matches_forward_dropless(self, arch, key):
        """With dropless capacity the MoE paths agree exactly; with finite
        capacity they may differ only via documented drops."""
        cfg = get_reduced_config(arch)
        moe = dataclasses.replace(cfg.moe, capacity_factor=64.0)
        cfg = dataclasses.replace(cfg, dtype="float32", moe=moe)
        params = T.init_params(cfg, key)
        s = 8
        toks = jax.random.randint(key, (B, s), 0, cfg.vocab_size)
        full = T.forward(cfg, params, Batch(tokens=toks))
        cache = T.init_cache(cfg, B, s)
        for t in range(s):
            lg, cache = T.decode_step(cfg, params, cache,
                                      Batch(tokens=toks[:, t:t + 1]),
                                      jnp.int32(t))
            assert float(jnp.max(jnp.abs(lg[:, 0] - full[:, t]))) < 1e-3
