"""Property-based tests (hypothesis) on the system's core invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import energy as E
from repro.core import hlo_analysis as H
from repro.core import rbe
from repro.core.constants import (DPS_CAMERA, MIPI, RBE, SRAM_16NM, UTSV)
from repro.core.workloads import LayerKind, LayerSpec
from repro.kernels.rbe_matmul import quantize_rowwise

MAX_EX = 25


class TestEnergyInvariants:
    @given(bytes_=st.floats(1, 1e9), fps=st.floats(1, 120))
    @settings(max_examples=MAX_EX, deadline=None)
    def test_comm_energy_linear_in_bytes(self, bytes_, fps):
        assert E.comm_energy(2 * bytes_, MIPI) == pytest.approx(
            2 * E.comm_energy(bytes_, MIPI))
        # uTSV is always cheaper per byte than MIPI (Table 2)
        assert E.comm_energy(bytes_, UTSV) < E.comm_energy(bytes_, MIPI)

    @given(fps=st.floats(1, 120), t_sense=st.floats(1e-4, 8e-3),
           t_comm=st.floats(1e-7, 2e-3))
    @settings(max_examples=MAX_EX, deadline=None)
    def test_camera_energy_positive_and_monotone_in_readout(
            self, fps, t_sense, t_comm):
        e1 = E.camera_energy(DPS_CAMERA, fps, t_sense, t_comm)
        e2 = E.camera_energy(DPS_CAMERA, fps, t_sense, t_comm * 2)
        assert e1 > 0
        # longer readout window always costs energy (P_rd > P_off)
        if 1 / fps >= t_sense + 2 * t_comm:
            assert e2 >= e1

    @given(fps=st.floats(1, 120), cap=st.integers(1024, 1 << 24),
           duty=st.floats(0, 1))
    @settings(max_examples=MAX_EX, deadline=None)
    def test_leakage_bounded_by_always_on(self, fps, cap, duty):
        """Eq. 11 leakage is bounded by the always-on leakage."""
        t_proc = duty / fps
        e = E.memory_leakage_energy(t_proc, fps, cap, SRAM_16NM)
        e_on = cap * SRAM_16NM.leak_on / fps
        e_ret = cap * SRAM_16NM.leak_ret / fps
        assert e_ret - 1e-18 <= e <= e_on + 1e-18

    @given(macs=st.integers(1, 10**10))
    @settings(max_examples=MAX_EX, deadline=None)
    def test_compute_energy_linear(self, macs):
        from repro.core.constants import NODE_7NM
        assert E.compute_energy(macs, NODE_7NM.e_mac) == pytest.approx(
            macs * NODE_7NM.e_mac)


class TestRBEInvariants:
    layer_st = st.builds(
        LayerSpec,
        name=st.just("l"),
        kind=st.sampled_from(list(LayerKind)),
        macs=st.integers(10**3, 10**9),
        weight_bytes=st.integers(16, 10**7),
        in_act_bytes=st.integers(16, 10**7),
        out_act_bytes=st.integers(16, 10**7),
    )

    @given(layer=layer_st, scale=st.floats(0.05, 1.0))
    @settings(max_examples=MAX_EX, deadline=None)
    def test_throughput_never_exceeds_scaled_peak(self, layer, scale):
        eff = rbe.mac_per_cycle(layer, RBE, scale=scale)
        assert 0 < eff <= RBE.peak_mac_per_cycle * scale + 1e-9

    @given(layer=layer_st)
    @settings(max_examples=MAX_EX, deadline=None)
    def test_weight_stream_at_least_once(self, layer):
        """Weights are fetched at least once per inference."""
        assert rbe.weight_stream_bytes(layer) >= layer.weight_bytes


class TestQuantizationInvariants:
    @given(rows=st.integers(1, 16), cols=st.integers(2, 64),
           scale=st.floats(0.01, 100.0), seed=st.integers(0, 2**30))
    @settings(max_examples=MAX_EX, deadline=None)
    def test_int8_roundtrip_error_bound(self, rows, cols, scale, seed):
        x = np.asarray(jax.random.normal(
            jax.random.key(seed), (rows, cols))) * scale
        q, s = quantize_rowwise(jnp.asarray(x), axis=-1)
        back = np.asarray(q, np.float32) * np.asarray(s)[:, None]
        # error per element bounded by half a quantization step
        amax = np.abs(x).max(axis=-1)
        bound = amax / 127 * 0.5 + 1e-6
        assert (np.abs(back - x).max(axis=-1) <= bound + 1e-5).all()

    @given(rows=st.integers(1, 8), cols=st.integers(2, 32),
           seed=st.integers(0, 2**30))
    @settings(max_examples=MAX_EX, deadline=None)
    def test_int8_range(self, rows, cols, seed):
        x = jax.random.normal(jax.random.key(seed), (rows, cols)) * 1e3
        q, _ = quantize_rowwise(x, axis=-1)
        assert int(jnp.max(jnp.abs(q.astype(jnp.int32)))) <= 127


class TestAttentionInvariants:
    @given(seed=st.integers(0, 2**30), s=st.sampled_from([32, 64]),
           h=st.sampled_from([2, 4]))
    @settings(max_examples=10, deadline=None)
    def test_output_in_value_hull(self, seed, s, h):
        """Attention outputs are convex combinations of value rows."""
        from repro.models.attention import blockwise_attention
        ks = jax.random.split(jax.random.key(seed), 3)
        q = jax.random.normal(ks[0], (1, s, h, 16))
        k = jax.random.normal(ks[1], (1, s, h, 16))
        v = jax.random.normal(ks[2], (1, s, h, 16))
        out = blockwise_attention(q, k, v, causal=True, q_block=16,
                                  kv_block=16)
        vmin = jnp.min(v, axis=1, keepdims=True) - 1e-4
        vmax = jnp.max(v, axis=1, keepdims=True) + 1e-4
        assert bool(jnp.all(out >= vmin) and jnp.all(out <= vmax))

    @given(seed=st.integers(0, 2**30))
    @settings(max_examples=10, deadline=None)
    def test_causality(self, seed):
        """Perturbing future tokens never changes past outputs."""
        from repro.models.attention import blockwise_attention
        ks = jax.random.split(jax.random.key(seed), 3)
        q = jax.random.normal(ks[0], (1, 64, 2, 16))
        k = jax.random.normal(ks[1], (1, 64, 2, 16))
        v = jax.random.normal(ks[2], (1, 64, 2, 16))
        o1 = blockwise_attention(q, k, v, causal=True, q_block=16,
                                 kv_block=16)
        k2 = k.at[:, 40:].set(9.0)
        v2 = v.at[:, 40:].set(-9.0)
        o2 = blockwise_attention(q, k2, v2, causal=True, q_block=16,
                                 kv_block=16)
        np.testing.assert_allclose(o1[:, :40], o2[:, :40], atol=1e-5)


class TestHLOParserInvariants:
    @given(dims=st.lists(st.integers(1, 64), min_size=0, max_size=4),
           dtype=st.sampled_from(["f32", "bf16", "s8", "u32"]),
           op=st.sampled_from(sorted(H.COLLECTIVE_OPS)),
           group=st.integers(2, 64))
    @settings(max_examples=MAX_EX, deadline=None)
    def test_synthetic_collective_lines(self, dims, dtype, op, group):
        shape = f"{dtype}[{','.join(map(str, dims))}]"
        groups = "{{" + ",".join(map(str, range(group))) + "}}"
        line = (f"  %x.1 = {shape} {op}(%y), "
                f"replica_groups={groups}, dimensions={{0}}\n")
        s = H.parse_collectives(line)
        assert len(s.ops) == 1
        o = s.ops[0]
        nbytes = int(np.prod(dims)) if dims else 1
        per = {"f32": 4, "bf16": 2, "s8": 1, "u32": 4}[dtype]
        assert o.payload_bytes == nbytes * per
        assert o.group_size == group
        assert o.wire_bytes <= 2 * o.payload_bytes
