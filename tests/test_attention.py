"""Correctness tests for attention paths: blockwise vs full-softmax oracle,
windows, softcap, GQA grouping, MLA (incl. absorbed decode), M-RoPE."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as A
from repro.models.common import ModelConfig
from repro.models.layers import apply_mrope, apply_rope


def _qkv(key, b, sq, skv, h, kv, dh, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (b, sq, h, dh), dtype)
    k = jax.random.normal(k2, (b, skv, kv, dh), dtype)
    v = jax.random.normal(k3, (b, skv, kv, dh), dtype)
    return q, k, v


class TestBlockwiseAttention:
    @pytest.mark.parametrize("s,qb,kb", [(64, 16, 16), (64, 64, 64),
                                         (128, 32, 64), (96, 32, 32)])
    def test_matches_oracle_causal(self, s, qb, kb):
        q, k, v = _qkv(jax.random.key(0), 2, s, s, 4, 2, 16)
        got = A.blockwise_attention(q, k, v, causal=True, q_block=qb,
                                    kv_block=kb)
        want = A.full_attention_reference(q, k, v, causal=True)
        np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)

    @pytest.mark.parametrize("window", [8, 16, 40])
    def test_matches_oracle_windowed(self, window):
        q, k, v = _qkv(jax.random.key(1), 2, 64, 64, 4, 4, 16)
        got = A.blockwise_attention(q, k, v, causal=True, window=window,
                                    q_block=16, kv_block=16)
        want = A.full_attention_reference(q, k, v, causal=True,
                                          window=window)
        np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)

    def test_matches_oracle_softcap(self):
        q, k, v = _qkv(jax.random.key(2), 1, 64, 64, 2, 1, 16)
        got = A.blockwise_attention(q, k, v, causal=True, logit_softcap=50.0,
                                    q_block=16, kv_block=16)
        want = A.full_attention_reference(q, k, v, causal=True,
                                          logit_softcap=50.0)
        np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)

    def test_block_pair_pruning_skips_out_of_window(self):
        """Window pruning must reduce the statically enumerated pairs."""
        full = len(A._block_pairs(8, 8, 16, 16, 0, causal=True, window=0))
        pruned = len(A._block_pairs(8, 8, 16, 16, 0, causal=True,
                                    window=16))
        assert pruned < full
        assert full == 8 * 9 // 2

    def test_decode_attention_matches_last_row(self):
        q, k, v = _qkv(jax.random.key(3), 2, 16, 16, 4, 2, 16)
        want = A.full_attention_reference(q, k, v, causal=True)[:, -1:]
        got = A.decode_attention(q[:, -1:], k, v, cache_len=16)
        np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)

    def test_decode_attention_respects_cache_len(self):
        q, k, v = _qkv(jax.random.key(4), 1, 1, 32, 2, 2, 8)
        # junk beyond cache_len must not affect the result
        got_a = A.decode_attention(q, k, v, cache_len=10)
        k2 = k.at[:, 10:].set(1e3)
        v2 = v.at[:, 10:].set(-1e3)
        got_b = A.decode_attention(q, k2, v2, cache_len=10)
        np.testing.assert_allclose(got_a, got_b, atol=1e-6)


def _mla_cfg():
    return ModelConfig(
        name="mla-test", family="moe", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=256,
        attention_kind="mla", q_lora_rank=32, kv_lora_rank=16,
        qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16, dtype="float32")


class TestMLA:
    def test_forward_shapes(self):
        cfg = _mla_cfg()
        params = A.mla_init(jax.random.key(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.key(1), (2, 32, cfg.d_model))
        pos = jnp.broadcast_to(jnp.arange(32), (2, 32))
        y = A.mla_forward(cfg, params, x, pos, q_block=16, kv_block=16)
        assert y.shape == x.shape

    def test_decode_matches_prefill(self):
        cfg = _mla_cfg()
        params = A.mla_init(jax.random.key(0), cfg, jnp.float32)
        s = 12
        x = jax.random.normal(jax.random.key(1), (2, s, cfg.d_model)) * 0.3
        pos = jnp.broadcast_to(jnp.arange(s), (2, s))
        full = A.mla_forward(cfg, params, x, pos, q_block=4, kv_block=4)
        cache = A.mla_init_cache(cfg, 2, s, jnp.float32)
        for t in range(s):
            y, cache = A.mla_decode(cfg, params, x[:, t:t + 1], cache,
                                    jnp.int32(t), absorb=False)
            np.testing.assert_allclose(y[:, 0], full[:, t], atol=1e-4,
                                       rtol=1e-4)

    def test_absorbed_equals_naive_decode(self):
        """The §Perf optimization must be numerically equivalent."""
        cfg = _mla_cfg()
        params = A.mla_init(jax.random.key(0), cfg, jnp.float32)
        s = 8
        x = jax.random.normal(jax.random.key(2), (2, s, cfg.d_model)) * 0.3
        c1 = A.mla_init_cache(cfg, 2, s, jnp.float32)
        c2 = A.mla_init_cache(cfg, 2, s, jnp.float32)
        for t in range(s):
            y1, c1 = A.mla_decode(cfg, params, x[:, t:t + 1], c1,
                                  jnp.int32(t), absorb=False)
            y2, c2 = A.mla_decode(cfg, params, x[:, t:t + 1], c2,
                                  jnp.int32(t), absorb=True)
            np.testing.assert_allclose(y1, y2, atol=1e-4, rtol=1e-4)

    def test_cache_is_compressed(self):
        """MLA's point: the cache holds kv_lora + rope dims, not H*dh."""
        cfg = _mla_cfg()
        cache = A.mla_init_cache(cfg, 2, 16, jnp.float32)
        assert cache.c_kv.shape == (2, 16, cfg.kv_lora_rank)
        assert cache.k_pe.shape == (2, 16, cfg.qk_rope_dim)
        full_kv_floats = 2 * 16 * cfg.num_heads * cfg.v_head_dim * 2
        mla_floats = cache.c_kv.size + cache.k_pe.size
        assert mla_floats < 0.25 * full_kv_floats


class TestRoPE:
    def test_rope_preserves_norm(self):
        x = jax.random.normal(jax.random.key(0), (2, 8, 4, 32))
        pos = jnp.broadcast_to(jnp.arange(8), (2, 8))
        y = apply_rope(x, pos, 1e4)
        np.testing.assert_allclose(jnp.linalg.norm(y, axis=-1),
                                   jnp.linalg.norm(x, axis=-1),
                                   atol=1e-4, rtol=1e-4)

    def test_rope_relative_property(self):
        """<rope(q,i), rope(k,j)> depends only on i - j."""
        q = jax.random.normal(jax.random.key(1), (1, 1, 1, 32))
        k = jax.random.normal(jax.random.key(2), (1, 1, 1, 32))

        def dot_at(i, j):
            qi = apply_rope(q, jnp.full((1, 1), i), 1e4)
            kj = apply_rope(k, jnp.full((1, 1), j), 1e4)
            return float(jnp.sum(qi * kj))

        assert dot_at(5, 3) == pytest.approx(dot_at(12, 10), abs=1e-4)
        assert dot_at(5, 3) != pytest.approx(dot_at(5, 4), abs=1e-3)

    def test_mrope_matches_rope_for_text(self):
        """With t=h=w positions, M-RoPE must equal plain RoPE."""
        x = jax.random.normal(jax.random.key(3), (2, 8, 4, 32))
        pos = jnp.broadcast_to(jnp.arange(8), (2, 8))
        pos3 = jnp.broadcast_to(pos[None], (3, 2, 8))
        y1 = apply_rope(x, pos, 1e4)
        y2 = apply_mrope(x, pos3, 1e4, (4, 6, 6))
        np.testing.assert_allclose(y1, y2, atol=1e-5)

    def test_mrope_distinguishes_spatial_positions(self):
        x = jax.random.normal(jax.random.key(4), (1, 4, 2, 32))
        t = jnp.zeros((1, 4), jnp.int32)
        h = jnp.arange(4)[None]
        w = jnp.zeros((1, 4), jnp.int32)
        y = apply_mrope(x, jnp.stack([t, h, w]), 1e4, (4, 6, 6))
        y0 = apply_mrope(x, jnp.stack([t, w, w]), 1e4, (4, 6, 6))
        assert float(jnp.max(jnp.abs(y - y0))) > 1e-3
