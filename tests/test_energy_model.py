"""Unit tests for the faithful semi-analytical power model (Eqs. 1-11)."""

import math

import pytest

from repro.core import energy as E
from repro.core import system
from repro.core.constants import (DPS_CAMERA, MIPI, NODE_7NM, NODE_16NM,
                                  SRAM_16NM, MRAM_16NM, UTSV, T_SENSE_S)


class TestEquations:
    def test_eq5_comm_energy(self):
        # Table 2: MIPI 100 pJ/B, uTSV 5 pJ/B
        assert E.comm_energy(1e6, MIPI) == pytest.approx(1e6 * 100e-12)
        assert E.comm_energy(1e6, UTSV) == pytest.approx(1e6 * 5e-12)

    def test_eq6_comm_time(self):
        # full VGA RAW10 frame over MIPI at 0.5 GB/s
        assert E.comm_time(384000, MIPI) == pytest.approx(768e-6)
        assert E.comm_time(384000, UTSV) == pytest.approx(3.84e-6)

    def test_eq4_off_time_clamps(self):
        assert E.camera_off_time(30.0, 5e-3, 1e-3) == pytest.approx(
            1 / 30 - 6e-3)
        assert E.camera_off_time(1000.0, 5e-3, 1e-3) == 0.0

    def test_eq3_camera_energy_components(self):
        t_comm = E.comm_time(384000, MIPI)
        e = E.camera_energy(DPS_CAMERA, 30.0, T_SENSE_S, t_comm)
        expected = (15e-3 * T_SENSE_S + 36e-3 * t_comm
                    + 1.5e-3 * (1 / 30 - T_SENSE_S - t_comm))
        assert e == pytest.approx(expected)

    def test_eq3_utsv_reduces_camera_energy(self):
        """The paper's claim (2): uTSV shortens the 36 mW readout window."""
        t_mipi = E.comm_time(384000, MIPI)
        t_utsv = E.comm_time(384000, UTSV)
        e_mipi = E.camera_energy(DPS_CAMERA, 30.0, T_SENSE_S, t_mipi)
        e_utsv = E.camera_energy(DPS_CAMERA, 30.0, T_SENSE_S, t_utsv)
        assert e_utsv < e_mipi

    def test_eq7_compute(self):
        assert E.compute_energy(1e9, NODE_7NM.e_mac) == pytest.approx(
            1e9 * NODE_7NM.e_mac)
        assert NODE_16NM.e_mac > NODE_7NM.e_mac  # node scaling

    def test_eq8_memory_access(self):
        e = E.memory_access_energy(1000, 500, SRAM_16NM)
        assert e == pytest.approx(1000 * SRAM_16NM.e_read
                                  + 500 * SRAM_16NM.e_write)

    def test_eq11_leakage_states(self):
        cap = 1 << 20  # 1 MiB
        # fully busy: only on-state leakage
        e_busy = E.memory_leakage_energy(1 / 30, 30.0, cap, SRAM_16NM)
        assert e_busy == pytest.approx(cap * SRAM_16NM.leak_on / 30)
        # fully idle: only retention leakage
        e_idle = E.memory_leakage_energy(0.0, 30.0, cap, SRAM_16NM)
        assert e_idle == pytest.approx(cap * SRAM_16NM.leak_ret / 30)
        # MRAM retains with zero leakage
        assert E.memory_leakage_energy(0.0, 30.0, cap, MRAM_16NM) == 0.0

    def test_eq1_eq2_aggregation(self):
        mods = [E.ModuleEnergy("a", "g1", 1e-3, 30.0),
                E.ModuleEnergy("b", "g2", 2e-3, 10.0)]
        assert E.total_energy_per_frame(mods) == pytest.approx(3e-3)
        assert E.average_power(mods) == pytest.approx(30e-3 + 20e-3)
        bd = E.power_breakdown(mods)
        assert bd["g1"] == pytest.approx(30e-3)
        assert bd["g2"] == pytest.approx(20e-3)


class TestPaperHeadlines:
    """The three quantitative claims of Fig. 5 (reproduction targets)."""

    def test_fig5a_distributed_7nm_saves_24pct(self):
        r = system.fig5a_comparison()
        assert r["_saving_7nm"] == pytest.approx(0.24, abs=0.02)

    def test_fig5a_distributed_16nm_saves_16pct(self):
        r = system.fig5a_comparison()
        assert r["_saving_16nm"] == pytest.approx(0.16, abs=0.02)

    def test_fig5b_hybrid_mram_saves_39pct(self):
        r = system.fig5b_comparison()
        assert r["_saving"] == pytest.approx(0.39, abs=0.02)

    def test_cameras_and_mipi_dominate_centralized(self):
        """Paper: 'the cameras and MIPIs dominate the power dissipation of
        the centralized compute system.'"""
        cen = system.build_centralized("7nm")
        bd = cen.breakdown()
        cam_mipi = bd["camera"] + bd["mipi"]
        assert cam_mipi / cen.avg_power > 0.5

    def test_memory_increases_slightly_when_distributed(self):
        """Paper: 'the total memory energy consumption slightly increases in
        the distributed computing system due to the duplication of the
        weight storage memory in each sensor.'"""
        cen = system.build_centralized("7nm")
        dis = system.build_distributed("7nm", "7nm")
        mem_c = cen.group_power("agg.memory")
        mem_d = dis.group_power("agg.memory") + dis.group_power(
            "sensor0.memory", "sensor1.memory", "sensor2.memory",
            "sensor3.memory")
        assert mem_d > mem_c                       # increases...
        assert (mem_d - mem_c) < 0.10 * cen.avg_power  # ...slightly

    def test_mipi_power_collapses_when_distributed(self):
        """The power gain is 'mainly due to the decreased usage of the
        energy-hungry serial interface (MIPI)'."""
        cen = system.build_centralized("7nm")
        dis = system.build_distributed("7nm", "7nm")
        mipi_c = cen.group_power("mipi")
        mipi_d = dis.group_power("mipi")
        assert mipi_d < 0.1 * mipi_c

    def test_distributed_beats_centralized_even_at_16nm(self):
        """Conclusion: 'a significant reduction in the system power remains
        when the on-sensor processor is implemented in an older technology
        node.'"""
        cen = system.build_centralized("7nm")
        dis = system.build_distributed("7nm", "16nm")
        assert dis.avg_power < cen.avg_power


class TestSystemStructure:
    def test_mram_unavailable_at_7nm(self):
        with pytest.raises(ValueError):
            system.build_distributed("7nm", "7nm", sensor_weight_mem="mram")

    def test_power_scales_with_cameras(self):
        p2 = system.build_centralized("7nm", num_cameras=2).avg_power
        p4 = system.build_centralized("7nm", num_cameras=4).avg_power
        assert p4 > p2

    def test_detnet_fps_knob(self):
        """DetNet rate is the paper's extra optimization knob."""
        lo = system.build_distributed("7nm", "7nm", detnet_fps=5.0).avg_power
        hi = system.build_distributed("7nm", "7nm", detnet_fps=30.0).avg_power
        assert lo < hi
