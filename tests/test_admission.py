"""Admission queue: deadlines, timeout contracts, concurrency, fairness.

Direct tests of `repro.runtime.admission` — the multi-tenant bounded
queue underneath the sweep service.  The fairness properties here are
the load-bearing ones: deficit round-robin converges to the weight
share under sustained overload, priority aging bounds starvation, and
per-tenant pending caps shed one greedy tenant without touching the
others.  Everything runs queue-level (plain strings as items), so the
whole module is executor-free and fast.
"""

import threading
import time

import pytest

from repro.runtime import (AdmissionQueue, BackpressureError, Deadline,
                           TenantPolicy)


# ---------------------------------------------------------------------------
# Deadline edge cases
# ---------------------------------------------------------------------------


class TestDeadlineEdges:
    def test_earliest_all_none(self):
        ds = [Deadline.after(None) for _ in range(3)]
        assert Deadline.earliest(*ds).at is None

    def test_earliest_mixed_ignores_none(self):
        none_d = Deadline.after(None)
        tight = Deadline.after(1.0)
        loose = Deadline.after(100.0)
        assert Deadline.earliest(none_d, loose, tight,
                                 none_d).at == tight.at

    def test_earliest_is_order_independent(self):
        a, b = Deadline.after(5.0), Deadline.after(2.0)
        assert Deadline.earliest(a, b).at == Deadline.earliest(b, a).at

    def test_remaining_goes_negative_once_overdue(self):
        d = Deadline(at=time.monotonic() - 1.0)
        assert d.expired()
        assert d.remaining_s() < 0.0


# ---------------------------------------------------------------------------
# TenantPolicy validation
# ---------------------------------------------------------------------------


class TestTenantPolicy:
    def test_weight_must_be_positive(self):
        with pytest.raises(ValueError, match="weight"):
            TenantPolicy(weight=0.0)
        with pytest.raises(ValueError, match="weight"):
            TenantPolicy(weight=-1.0)

    def test_max_pending_must_be_at_least_one(self):
        with pytest.raises(ValueError, match="max_pending"):
            TenantPolicy(max_pending=0)
        TenantPolicy(max_pending=1)        # boundary is legal

    def test_aging_validated(self):
        with pytest.raises(ValueError, match="aging_s"):
            AdmissionQueue(4, aging_s=0.0)


# ---------------------------------------------------------------------------
# take_batch timeout contract
# ---------------------------------------------------------------------------


class TestTakeBatchTimeout:
    def test_empty_queue_times_out_in_bounded_time(self):
        q = AdmissionQueue(4)
        t0 = time.monotonic()
        assert q.take_batch(timeout=0.05) == []
        elapsed = time.monotonic() - t0
        assert 0.04 <= elapsed < 2.0

    def test_paused_queue_with_items_still_times_out(self):
        # Pause must gate claiming even when the backlog is non-empty:
        # this is the race the service-level pause() depends on (a
        # worker already blocked in take_batch must not claim a
        # post-pause submit).
        q = AdmissionQueue(4)
        q.pause()
        q.offer("a")
        assert q.take_batch(timeout=0.05) == []
        assert q.depth == 1                # item stayed admitted
        q.resume()
        assert q.take_batch(timeout=0.05) == ["a"]

    def test_offer_wakes_blocked_consumer(self):
        q = AdmissionQueue(4)
        got = {}

        def consume():
            got["batch"] = q.take_batch(timeout=5.0)

        th = threading.Thread(target=consume)
        th.start()
        time.sleep(0.05)
        q.offer("late")
        th.join(5.0)
        assert got["batch"] == ["late"]

    def test_resume_wakes_blocked_consumer(self):
        q = AdmissionQueue(4)
        q.offer("a")
        q.pause()
        got = {}

        def consume():
            got["batch"] = q.take_batch(timeout=5.0)

        th = threading.Thread(target=consume)
        th.start()
        time.sleep(0.05)
        q.resume()
        th.join(5.0)
        assert got["batch"] == ["a"]


# ---------------------------------------------------------------------------
# Concurrency: offer / readmit / remove racing a draining consumer
# ---------------------------------------------------------------------------


class TestConcurrentMutation:
    def test_no_item_lost_or_duplicated_under_contention(self):
        """Hammer offer/readmit/remove from many threads against a
        draining consumer; conservation must hold exactly: every item
        is claimed once, removed once, or rejected at the door."""
        q = AdmissionQueue(64)
        n_threads, per_thread = 8, 50
        offered, rejected = [], []
        removed = []
        claimed = []
        stop = threading.Event()
        lock = threading.Lock()

        def producer(tid):
            for i in range(per_thread):
                item = f"t{tid}-{i}"
                try:
                    if i % 10 == 3:
                        q.readmit(item)
                        with lock:
                            offered.append(item)
                    else:
                        q.offer(item)
                        with lock:
                            offered.append(item)
                    if i % 7 == 5 and q.remove(item):
                        with lock:
                            removed.append(item)
                except BackpressureError:
                    with lock:
                        rejected.append(item)

        def consumer():
            while not stop.is_set() or q.depth:
                for item in q.take_batch(timeout=0.01):
                    with lock:
                        claimed.append(item)
                    q.release()

        cons = [threading.Thread(target=consumer) for _ in range(2)]
        prods = [threading.Thread(target=producer, args=(t,))
                 for t in range(n_threads)]
        for th in cons + prods:
            th.start()
        for th in prods:
            th.join(30.0)
        stop.set()
        for th in cons:
            th.join(30.0)
        assert q.depth == 0
        assert len(claimed) == len(set(claimed)), "item claimed twice"
        assert set(claimed) | set(removed) == set(offered)
        assert not (set(claimed) & set(removed))

    def test_remove_of_claimed_item_fails(self):
        q = AdmissionQueue(4)
        q.offer("a")
        assert q.take_batch(timeout=0.1) == ["a"]
        assert q.remove("a") is False


# ---------------------------------------------------------------------------
# Deficit round-robin fairness
# ---------------------------------------------------------------------------


class TestWeightedFairness:
    def test_single_tenant_degenerates_to_fifo(self):
        q = AdmissionQueue(16)
        for i in range(6):
            q.offer(f"i{i}")
        order = [q.take_batch(timeout=0.01)[0] for _ in range(6)]
        assert order == [f"i{i}" for i in range(6)]

    def test_overloaded_tenants_converge_to_weight_share(self):
        """Tenants at weights 1:3 with both backlogs always non-empty:
        claimed work splits 25%/75% within 10% (the fairness gate)."""
        q = AdmissionQueue(4096,
                           tenants={"small": TenantPolicy(weight=1.0),
                                    "big": TenantPolicy(weight=3.0)})
        for i in range(600):
            q.offer(f"s{i}", tenant="small")
            q.offer(f"b{i}", tenant="big")
        counts = {"small": 0, "big": 0}
        for _ in range(400):               # both stay backlogged
            (item,) = q.take_batch(timeout=0.1)
            tenant = "small" if item.startswith("s") else "big"
            counts[tenant] += 1
            q.release(tenant)
        share_big = counts["big"] / 400.0
        assert abs(share_big - 0.75) <= 0.10, counts

    def test_idle_tenant_does_not_hoard_credit(self):
        """A tenant that drains and comes back starts from zero credit:
        it cannot burst past its weight share with banked deficit."""
        q = AdmissionQueue(256, tenants={"a": TenantPolicy(weight=1.0),
                                         "b": TenantPolicy(weight=1.0)})
        q.offer("a0", tenant="a")
        assert q.take_batch(timeout=0.1) == ["a0"]   # a drains, leaves
        q.release("a")
        for i in range(40):
            q.offer(f"a{i + 1}", tenant="a")
            q.offer(f"b{i}", tenant="b")
        counts = {"a": 0, "b": 0}
        for _ in range(40):
            (item,) = q.take_batch(timeout=0.1)
            counts[item[0]] += 1
            q.release(item[0])
        assert abs(counts["a"] - counts["b"]) <= 4, counts


# ---------------------------------------------------------------------------
# Priority classes with aging
# ---------------------------------------------------------------------------


class TestPriorityAging:
    def test_higher_priority_claims_first_within_tenant(self):
        q = AdmissionQueue(8)
        q.offer("low", priority=0)
        q.offer("high", priority=5)
        q.offer("mid", priority=2)
        order = [q.take_batch(timeout=0.01)[0] for _ in range(3)]
        assert order == ["high", "mid", "low"]

    def test_same_class_serves_fifo(self):
        q = AdmissionQueue(8)
        for i in range(4):
            q.offer(f"p{i}", priority=1)
        order = [q.take_batch(timeout=0.01)[0] for _ in range(4)]
        assert order == [f"p{i}" for i in range(4)]

    def test_starved_request_ages_past_fresh_high_priority(self):
        """A low-priority request gains one class per aging_s waited,
        so it eventually outranks fresh high-priority arrivals — the
        no-starvation gate."""
        q = AdmissionQueue(8, aging_s=0.02)
        q.offer("starved", priority=0)
        time.sleep(0.09)                   # ages ≥ 4 classes
        q.offer("fresh-high", priority=2)
        assert q.take_batch(timeout=0.1) == ["starved"]

    def test_without_aging_window_high_priority_wins(self):
        q = AdmissionQueue(8, aging_s=30.0)
        q.offer("old-low", priority=0)
        q.offer("fresh-high", priority=2)
        assert q.take_batch(timeout=0.1) == ["fresh-high"]


# ---------------------------------------------------------------------------
# Per-tenant pending caps
# ---------------------------------------------------------------------------


class TestTenantCaps:
    def test_cap_rejects_naming_tenant_with_retry_hint(self):
        q = AdmissionQueue(16,
                           tenants={"greedy": TenantPolicy(
                               weight=1.0, max_pending=2)})
        q.offer("g0", tenant="greedy")
        q.offer("g1", tenant="greedy")
        with pytest.raises(BackpressureError) as ei:
            q.offer("g2", tenant="greedy")
        err = ei.value
        assert err.tenant == "greedy"
        assert "greedy" in str(err)
        assert err.queue_depth == 2 and err.capacity == 2
        assert err.retry_after_s is not None and err.retry_after_s > 0
        # Other tenants are unaffected by the greedy tenant's cap.
        q.offer("other", tenant="quiet")

    def test_in_flight_counts_against_cap_until_release(self):
        q = AdmissionQueue(16, tenants={"t": TenantPolicy(
            weight=1.0, max_pending=1)})
        q.offer("x", tenant="t")
        assert q.take_batch(timeout=0.1) == ["x"]
        assert q.pending("t") == 1         # claimed but not released
        with pytest.raises(BackpressureError):
            q.offer("y", tenant="t")
        q.release("t")
        q.offer("y", tenant="t")           # slot freed

    def test_set_tenant_updates_policy(self):
        q = AdmissionQueue(16)
        q.set_tenant("t", weight=2.0, max_pending=1)
        assert q.policy("t") == TenantPolicy(2.0, 1)
        q.offer("x", tenant="t")
        with pytest.raises(BackpressureError):
            q.offer("y", tenant="t")

    def test_readmit_bypasses_tenant_cap(self):
        q = AdmissionQueue(16, tenants={"t": TenantPolicy(
            weight=1.0, max_pending=1)})
        q.offer("x", tenant="t")
        q.readmit("recovered", tenant="t")     # recovery must not shed
        assert q.snapshot() == ["recovered", "x"]


# ---------------------------------------------------------------------------
# Fusion scan across tenants
# ---------------------------------------------------------------------------


class TestCrossTenantFusion:
    def test_followers_claimed_across_tenants_in_arrival_order(self):
        q = AdmissionQueue(16, tenants={"a": TenantPolicy(1.0),
                                        "b": TenantPolicy(1.0)})
        q.offer("a-x1", tenant="a")
        q.offer("b-x2", tenant="b")
        q.offer("a-y1", tenant="a")
        q.offer("b-x3", tenant="b")
        same = lambda head, other: other.split("-")[1][0] == \
            head.split("-")[1][0]
        batch = q.take_batch(timeout=0.1, compatible=same)
        assert batch == ["a-x1", "b-x2", "b-x3"]
        # Each claimed entry charges in-flight to its own tenant.
        assert q.pending("a") == 2         # a-x1 in flight + a-y1 queued
        assert q.pending("b") == 2         # b-x2, b-x3 in flight


# ---------------------------------------------------------------------------
# retry_after_s estimation (completion rate, executors-aware fallback)
# ---------------------------------------------------------------------------


class TestRetryAfterEstimate:
    def _estimate(self, q):
        with pytest.raises(BackpressureError) as ei:
            q.offer("rejected")
        return ei.value.retry_after_s

    def test_no_history_defaults_to_one_second(self):
        q = AdmissionQueue(1)
        q.offer("x")
        assert self._estimate(q) == 1.0

    def test_completion_rate_is_the_primary_signal(self):
        # One completion every 0.2s, one item ahead -> ~0.4s.  The
        # executors knob must NOT divide this: parallel workers'
        # completions already interleave in the observed stream.
        q = AdmissionQueue(1, executors=8)
        t0 = time.monotonic()
        q._done_times.extend([t0 - 0.4, t0 - 0.2, t0])
        q.offer("x")
        assert self._estimate(q) == pytest.approx(0.4, rel=0.05)

    def test_claim_rate_fallback_divides_by_executors(self):
        # Before any completion lands, the claim rate stands in — but
        # a single dispatcher feeding an N-wide pool claims on one
        # thread's clock, so the interval is divided by the width.
        t0 = time.monotonic()
        estimates = {}
        for width in (1, 4):
            q = AdmissionQueue(1, executors=width)
            q._claim_times.extend([t0 - 0.8, t0 - 0.4, t0])
            q.offer("x")
            estimates[width] = self._estimate(q)
        assert estimates[1] == pytest.approx(0.8, rel=0.05)
        assert estimates[4] == pytest.approx(0.2, rel=0.05)

    def test_estimate_is_clamped_to_sane_bounds(self):
        t0 = time.monotonic()
        slow = AdmissionQueue(1)
        slow._done_times.extend([t0 - 500.0, t0])
        slow.offer("x")
        assert self._estimate(slow) == 60.0
        fast = AdmissionQueue(1)
        fast._done_times.extend([t0 - 1e-4, t0])
        fast.offer("x")
        assert self._estimate(fast) == 0.05
