"""Tests for the HLO collective parser + roofline assembly + DOSC advisor."""

import pytest

from repro.core import dosc, hlo_analysis as H, roofline, tpu_energy
from repro.core.constants import TPU_V5E

SAMPLE_HLO = """
HloModule jit_step, entry_computation_layout={...}

ENTRY %main (p0: bf16[256,4096]) -> bf16[256,4096] {
  %p0 = bf16[256,4096]{1,0} parameter(0)
  %all-reduce.1 = bf16[256,4096]{1,0} all-reduce(%p0), replica_groups={{0,1,2,3}}, to_apply=%add
  %all-gather.2 = f32[1024,128]{1,0} all-gather(%ag_in), replica_groups=[16,32]<=[512], dimensions={0}
  %rs = f32[64,128]{1,0} reduce-scatter(%x), replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}
  %a2a = bf16[8,64]{1,0} all-to-all(%y), replica_groups={{0,1}}, dimensions={0}
  %cp = u8[1024]{0} collective-permute(%z), source_target_pairs={{0,1},{1,0}}
  %fusion.3 = bf16[256,4096]{1,0} fusion(%all-reduce.1), kind=kLoop
  ROOT %done = bf16[256,4096]{1,0} copy(%fusion.3)
}
"""


class TestHLOParse:
    def test_finds_all_collectives(self):
        s = H.parse_collectives(SAMPLE_HLO)
        codes = sorted(o.opcode for o in s.ops)
        assert codes == ["all-gather", "all-reduce", "all-to-all",
                         "collective-permute", "reduce-scatter"]

    def test_payload_bytes(self):
        s = H.parse_collectives(SAMPLE_HLO)
        by = s.by_opcode()
        assert by["all-reduce"]["payload_bytes"] == 256 * 4096 * 2
        assert by["all-gather"]["payload_bytes"] == 1024 * 128 * 4
        assert by["collective-permute"]["payload_bytes"] == 1024

    def test_group_sizes(self):
        s = H.parse_collectives(SAMPLE_HLO)
        sizes = {o.opcode: o.group_size for o in s.ops}
        assert sizes["all-reduce"] == 4
        assert sizes["all-gather"] == 32      # iota [16,32]<=[512]
        assert sizes["reduce-scatter"] == 8
        assert sizes["all-to-all"] == 2

    def test_wire_bytes_ring_formulas(self):
        s = H.parse_collectives(SAMPLE_HLO)
        ar = next(o for o in s.ops if o.opcode == "all-reduce")
        assert ar.wire_bytes == pytest.approx(2 * 3 / 4 * ar.payload_bytes)
        ag = next(o for o in s.ops if o.opcode == "all-gather")
        assert ag.wire_bytes == pytest.approx(31 / 32 * ag.payload_bytes)

    def test_ignores_non_collectives(self):
        s = H.parse_collectives(SAMPLE_HLO)
        assert all(o.opcode in H.COLLECTIVE_OPS for o in s.ops)

    def test_count_op(self):
        assert H.count_op(SAMPLE_HLO, "fusion") == 1
        assert H.count_op(SAMPLE_HLO, "all-reduce") == 1

    def test_empty_text(self):
        s = H.parse_collectives("")
        assert s.total_payload_bytes == 0
        assert s.total_wire_bytes == 0.0


class TestRoofline:
    def _terms(self):
        s = H.parse_collectives(SAMPLE_HLO)
        cost = {"flops": 1e12, "bytes accessed": 1e9}
        return roofline.build_terms("testarch", "train_4k", "16x16", 256,
                                    cost, s, model_flops_global=200e12)

    def test_terms_seconds(self):
        t = self._terms()
        assert t.t_compute == pytest.approx(1e12 / TPU_V5E.peak_flops_bf16)
        assert t.t_memory == pytest.approx(1e9 / TPU_V5E.hbm_bandwidth)
        assert t.t_collective > 0

    def test_dominant_and_bounds(self):
        t = self._terms()
        assert t.dominant in ("compute", "memory", "collective")
        assert t.t_bound == max(t.t_compute, t.t_memory, t.t_collective)
        assert t.t_serial >= t.t_bound

    def test_useful_ratio(self):
        t = self._terms()
        assert t.useful_flops_ratio == pytest.approx(
            200e12 / (1e12 * 256))

    def test_table_formatting(self):
        tbl = roofline.format_table([self._terms()])
        assert "testarch" in tbl and "dominant" in tbl


class TestTPUEnergy:
    def test_tier_split(self):
        s = H.parse_collectives(SAMPLE_HLO)
        ici, dcn = tpu_energy.split_tiers(s, intra_pod_chips=16)
        # the 32-wide all-gather spans pods; everything else fits in 16
        assert dcn == pytest.approx(
            next(o for o in s.ops if o.group_size == 32).wire_bytes)
        assert ici > 0

    def test_step_energy_positive_and_decomposes(self):
        s = H.parse_collectives(SAMPLE_HLO)
        cost = {"flops": 1e12, "bytes accessed": 1e9}
        t = roofline.build_terms("a", "s", "m", 256, cost, s, 2e14)
        e = tpu_energy.step_energy(t, s, intra_pod_chips=256)
        assert e.total == pytest.approx(sum(e.breakdown().values()))
        assert e.avg_power_w > 0


class TestDOSCAdvisor:
    def test_hierarchical_beats_flat_across_pods(self):
        """The paper's insight: route bulk traffic over the cheap tier."""
        ranked = dosc.advise(grad_elems_per_chip=50e6, pods=2,
                             intra_pod_chips=256, objective="time")
        flat = next(c for c in ranked if c.plan.name == "flat-ar-f32")
        hier = next(c for c in ranked if c.plan.name == "hier-f32")
        assert hier.t_comm_s < flat.t_comm_s

    def test_compression_reduces_dcn_bytes(self):
        ranked = dosc.advise(grad_elems_per_chip=50e6, pods=2,
                             intra_pod_chips=256)
        f32 = next(c for c in ranked if c.plan.name == "hier-f32")
        int8 = next(c for c in ranked if c.plan.name == "hier-int8-ef")
        assert int8.dcn_bytes == pytest.approx(f32.dcn_bytes / 4)

    def test_single_pod_has_no_dcn(self):
        ranked = dosc.advise(grad_elems_per_chip=50e6, pods=1,
                             intra_pod_chips=256)
        assert all(c.dcn_bytes == 0 for c in ranked
                   if c.plan.hierarchical)

    def test_energy_objective_prefers_compressed(self):
        ranked = dosc.advise(grad_elems_per_chip=50e6, pods=2,
                             intra_pod_chips=256, objective="energy")
        assert ranked[0].plan.dcn_dtype_bytes <= 2
