"""End-to-end behaviour tests for the paper's system.

Ties the layers together: the semi-analytical model's internal consistency,
the full train -> checkpoint -> elastic-restore -> serve lifecycle, and the
DOSC two-tier exchange with compressed gradients.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import ARCH_IDS, get_reduced_config
from repro.core import dosc, energy as E, system
from repro.data import DataConfig, SyntheticLM
from repro.models import transformer as T
from repro.models.transformer import Batch
from repro.optim import adamw
from repro.optim.compression import (CompressionConfig,
                                     compress_with_feedback,
                                     decompress_tree, init_error_feedback)
from repro.runtime import FaultToleranceController, FTConfig, replan_mesh


class TestPowerModelSystemLevel:
    def test_energy_and_power_views_consistent(self):
        """Eq. 1 x fps == Eq. 2 for every module of both topologies."""
        for rep in (system.build_centralized("7nm"),
                    system.build_distributed("7nm", "16nm")):
            for m in rep.modules:
                assert m.avg_power == pytest.approx(
                    m.energy_per_frame * m.fps)
            assert rep.avg_power == pytest.approx(
                sum(m.avg_power for m in rep.modules))

    def test_breakdown_sums_to_total(self):
        rep = system.build_distributed("7nm", "7nm")
        assert sum(rep.breakdown().values()) == pytest.approx(
            rep.avg_power)

    def test_distributed_dominates_across_fps_range(self):
        """The paper's conclusion holds across operating points, not just
        the headline configuration."""
        for fps in (15.0, 30.0, 60.0):
            cen = system.build_centralized("7nm", camera_fps=fps)
            dis = system.build_distributed("7nm", "7nm", camera_fps=fps)
            assert dis.avg_power < cen.avg_power, fps


class TestTrainCheckpointServeLifecycle:
    """One model goes through the whole production lifecycle."""

    def test_full_lifecycle(self, tmp_path):
        cfg = dataclasses.replace(get_reduced_config("qwen2-0.5b"),
                                  dtype="float32")
        opt_cfg = adamw.AdamWConfig(lr=5e-3, warmup_steps=2,
                                    total_steps=20)
        key = jax.random.key(0)
        params = T.init_params(cfg, key)
        opt_state = adamw.init(opt_cfg, params)
        ds = SyntheticLM(cfg, DataConfig(seq_len=32, global_batch=4))

        @jax.jit
        def step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: T.loss_fn(cfg, p, batch))(params)
            params, opt_state, _ = adamw.apply(opt_cfg, params, grads,
                                               opt_state)
            return params, opt_state, loss

        # --- train 8 steps, checkpoint at 5 ---
        cm = CheckpointManager(str(tmp_path))
        losses = []
        for i in range(8):
            b = ds.batch_at(i)
            batch = Batch(tokens=jnp.asarray(b.tokens),
                          labels=jnp.asarray(b.labels))
            params, opt_state, loss = step(params, opt_state, batch)
            losses.append(float(loss))
            if i == 4:
                cm.save(5, {"p": params, "o": opt_state})
        assert losses[-1] < losses[0]

        # --- simulate failure + elastic restore on "fewer chips" ---
        plan = replan_mesh(available_chips=12, model=4)
        assert plan.chips <= 12
        restored = cm.restore(5, {"p": params, "o": opt_state})
        # resume training from the checkpoint: deterministic data replay
        p2, o2 = restored["p"], restored["o"]
        for i in range(5, 8):
            b = ds.batch_at(i)
            batch = Batch(tokens=jnp.asarray(b.tokens),
                          labels=jnp.asarray(b.labels))
            p2, o2, loss2 = step(p2, o2, batch)
        # the recovered run reaches the same state as the original
        for a, b_ in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            np.testing.assert_allclose(a, b_, atol=1e-5, rtol=1e-5)

        # --- serve from the trained params ---
        cache = T.init_cache(cfg, 2, 8)
        toks = jnp.zeros((2, 1), jnp.int32)
        logits, cache = T.decode_step(cfg, p2, cache, Batch(tokens=toks),
                                      jnp.int32(0))
        assert logits.shape == (2, 1, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_failure_detection_triggers_restart_plan(self):
        ft = FaultToleranceController(8, FTConfig(
            heartbeat_interval_s=1.0, missed_heartbeats_fatal=2))
        for w in range(8):
            ft.heartbeat(w, now=0.0)
        for w in range(7):
            ft.heartbeat(w, now=5.0)
        ev = ft.tick(now=5.0)
        assert ev["kind"] == "restart_from_checkpoint"
        plan = replan_mesh(available_chips=ev["survivors"] * 32, model=16)
        assert plan.chips <= ev["survivors"] * 32


class TestDOSCTwoTierExchange:
    """Simulated 2-pod gradient exchange with compression + EF: the
    training-loop version of the paper's 'ROI over MIPI'."""

    def test_compressed_hierarchical_exchange_converges(self):
        rng = np.random.default_rng(0)
        true_grad = {"w": jnp.asarray(rng.normal(size=(256,)) * 1e-3,
                                      jnp.float32)}
        cfg = CompressionConfig(kind="int8", error_feedback=True)
        # two pods compute slightly different local grads; exchange the
        # compressed mean across the 'DCN' and check the applied updates
        # track the true mean over time
        ef_a = init_error_feedback(true_grad)
        ef_b = init_error_feedback(true_grad)
        applied = jnp.zeros((256,))
        n = 30
        for i in range(n):
            noise_a = jnp.asarray(rng.normal(size=(256,)) * 1e-4)
            noise_b = jnp.asarray(rng.normal(size=(256,)) * 1e-4)
            ga = {"w": true_grad["w"] + noise_a}
            gb = {"w": true_grad["w"] + noise_b}
            ca, ef_a = compress_with_feedback(ga, ef_a, cfg)
            cb, ef_b = compress_with_feedback(gb, ef_b, cfg)
            mean = (decompress_tree(ca)["w"]
                    + decompress_tree(cb)["w"]) / 2
            applied = applied + mean
        rel = float(jnp.linalg.norm(applied / n - true_grad["w"])
                    / jnp.linalg.norm(true_grad["w"]))
        assert rel < 0.1

    def test_advisor_matches_manual_ranking(self):
        ranked = dosc.advise(grad_elems_per_chip=1e8, pods=4,
                             intra_pod_chips=256, objective="time")
        names = [c.plan.name for c in ranked]
        assert names.index("hier-bf16") < names.index("flat-ar-f32")
        assert ranked[0].t_comm_s <= ranked[-1].t_comm_s


class TestAllArchsServeOneToken:
    """Every assigned architecture can serve a token end to end."""

    @pytest.mark.parametrize("arch", ARCH_IDS)
    def test_one_token(self, arch):
        cfg = get_reduced_config(arch)
        key = jax.random.key(1)
        params = T.init_params(cfg, key)
        cache = T.init_cache(cfg, 1, 4)
        if cfg.frontend_stub:
            b = Batch(embeds=jnp.zeros((1, 1, cfg.d_model), jnp.bfloat16))
        else:
            b = Batch(tokens=jnp.zeros((1, 1), jnp.int32))
        logits, _ = T.decode_step(cfg, params, cache, b, jnp.int32(0))
        assert logits.shape == (1, 1, cfg.vocab_size)
