"""CheckpointManager: atomicity, dtype fidelity, retention, raw restore.

The checkpoint layer underwrites every recovery path of the streaming
executor (kill-resume parity, elastic replan, graceful degradation), so
its core guarantees are pinned directly here:

* a crash at *any* point mid-save never corrupts or shadows the latest
  durable checkpoint (writes land in a ``.tmp`` dir renamed into place);
* bf16 and other ``ml_dtypes`` leaves round-trip bit-exactly (npz cannot
  hold them natively, so they travel as raw bytes + manifest dtype);
* retention keeps exactly the ``keep`` most recent steps;
* ``restore_items`` returns ``{path: array}`` without a like-tree, for
  state with data-dependent shapes (the executor's Pareto-front rows).
"""

import json
import os

import numpy as np
import pytest

import jax.numpy as jnp
from jax.experimental import enable_x64

from repro.checkpoint import CheckpointManager


def _restore(mgr, step, like):
    """``restore`` places leaves as jnp arrays; keep 64-bit dtypes
    intact (the executor itself restores via ``restore_items``, which
    stays in numpy and never downcasts)."""
    with enable_x64():
        return mgr.restore(step, like=like)


def _state(step: int):
    rng = np.random.default_rng(step)
    return {
        "carry": {
            "min_val": rng.random(3),
            "min_idx": rng.integers(0, 1000, 3),
        },
        "front_values": rng.random((step + 1, 3)),
    }


def _assert_tree_equal(a, b):
    assert sorted(a) == sorted(b)
    for k in a:
        if isinstance(a[k], dict):
            _assert_tree_equal(a[k], b[k])
        else:
            assert np.array_equal(np.asarray(a[k]), np.asarray(b[k])), k


class TestRoundTrip:
    def test_save_restore_like_tree(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        state = _state(3)
        mgr.save(3, state, metadata={"next_flat": 12})
        got = _restore(mgr, 3, state)
        _assert_tree_equal(state, got)
        assert mgr.metadata(3)["next_flat"] == 12

    def test_restore_items_without_like_tree(self, tmp_path):
        """Data-dependent shapes (Pareto front rows) restore by path."""
        mgr = CheckpointManager(str(tmp_path))
        state = _state(5)
        mgr.save(5, state)
        items = mgr.restore_items(5)
        assert set(items) == {"carry/min_val", "carry/min_idx",
                              "front_values"}
        assert np.array_equal(items["front_values"],
                              state["front_values"])
        assert items["front_values"].shape == (6, 3)
        assert np.array_equal(items["carry/min_idx"],
                              state["carry"]["min_idx"])

    def test_bf16_round_trips_bitwise(self, tmp_path):
        """npz can't store bf16; the manager must anyway (raw bytes)."""
        mgr = CheckpointManager(str(tmp_path))
        vals = jnp.asarray(
            np.random.default_rng(0).random(64), jnp.bfloat16)
        mgr.save(0, {"w": vals})
        got = mgr.restore_items(0)["w"]
        assert got.dtype == jnp.bfloat16
        assert np.asarray(vals).tobytes() == got.tobytes()

    def test_restore_rejects_shape_mismatch(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(0, {"x": np.zeros(4)})
        with pytest.raises(ValueError, match="shape mismatch"):
            mgr.restore(0, like={"x": np.zeros(5)})
        with pytest.raises(ValueError, match="leaves"):
            mgr.restore(0, like={"x": np.zeros(4), "y": np.zeros(1)})


class TestAtomicity:
    """A crash at any point mid-save leaves the previous step intact."""

    def test_crash_during_array_write(self, tmp_path, monkeypatch):
        mgr = CheckpointManager(str(tmp_path))
        good = _state(1)
        mgr.save(1, good, metadata={"next_flat": 8})

        def boom(*a, **kw):
            raise OSError("disk full (injected)")

        monkeypatch.setattr(np, "savez", boom)
        with pytest.raises(OSError):
            mgr.save(2, _state(2))
        monkeypatch.undo()

        # The failed step is invisible; the prior one is untouched.
        assert mgr.all_steps() == [1]
        assert mgr.latest_step() == 1
        _assert_tree_equal(good, _restore(mgr, 1, good))

    def test_crash_during_rename(self, tmp_path, monkeypatch):
        """Crash after the payload is written but before the atomic
        rename: the ``.tmp`` debris must never be listed as a step."""
        mgr = CheckpointManager(str(tmp_path))
        good = _state(1)
        mgr.save(1, good)

        real_rename = os.rename

        def boom(src, dst):
            if src.endswith(".tmp"):
                raise OSError("killed before rename (injected)")
            return real_rename(src, dst)

        monkeypatch.setattr(os, "rename", boom)
        with pytest.raises(OSError):
            mgr.save(2, _state(2))
        monkeypatch.undo()

        assert os.path.isdir(str(tmp_path / "step_000000002.tmp"))
        assert mgr.all_steps() == [1]
        _assert_tree_equal(good, _restore(mgr, 1, good))
        # A retry of the same step succeeds over the debris.
        mgr.save(2, _state(2))
        assert mgr.all_steps() == [1, 2]

    def test_fresh_manager_sweeps_crash_debris(self, tmp_path):
        """A restart over a spool left by a SIGKILL'd process clears
        ``*.tmp`` debris (the only artifact an atomic-rename crash can
        leave) — long-lived service spools must not accumulate orphan
        dirs across crash/restart cycles."""
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, _state(1))
        debris = tmp_path / "step_000000007.tmp"
        debris.mkdir()
        (debris / "arrays.npz").write_bytes(b"torn write")
        mgr2 = CheckpointManager(str(tmp_path))
        assert not debris.exists()
        assert mgr2.all_steps() == [1]

    def test_manifestless_dir_is_not_a_step(self, tmp_path):
        """A foreign/truncated step dir without manifest.json is not a
        checkpoint (the executor's resume scan must skip it)."""
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(4, _state(4))
        os.makedirs(str(tmp_path / "step_000000009"))
        assert mgr.all_steps() == [4]
        assert mgr.latest_step() == 4

    def test_resave_same_step_replaces(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(0, {"x": np.zeros(3)})
        mgr.save(0, {"x": np.ones(3)})
        assert np.array_equal(mgr.restore_items(0)["x"], np.ones(3))


class TestRetention:
    def test_keep_prunes_oldest(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        for s in range(5):
            mgr.save(s, _state(s))
        assert mgr.all_steps() == [3, 4]
        # Survivors remain fully restorable.
        _assert_tree_equal(_state(4), _restore(mgr, 4, _state(4)))

    def test_keep_zero_disables_pruning(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=0)
        for s in range(4):
            mgr.save(s, _state(s))
        assert mgr.all_steps() == [0, 1, 2, 3]


class TestManifest:
    def test_manifest_records_paths_shapes_dtypes(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(7, _state(7), metadata={"signature": "abc"})
        with open(str(tmp_path / "step_000000007" /
                      "manifest.json")) as f:
            man = json.load(f)
        assert man["step"] == 7
        assert man["metadata"] == {"signature": "abc"}
        by_path = {e["path"]: e for e in man["leaves"]}
        assert by_path["front_values"]["shape"] == [8, 3]
        assert by_path["carry/min_idx"]["dtype"] == "int64"
