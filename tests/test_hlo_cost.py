"""Validation of the trip-count-aware HLO static cost analyzer."""

import jax
import jax.numpy as jnp
import pytest

from repro.core import hlo_cost


def _compile(fn, *specs):
    return jax.jit(fn).lower(*specs).compile()


class TestHLOCost:
    def test_plain_matmul_exact(self):
        m, n, k = 128, 256, 512
        c = _compile(lambda a, b: a @ b,
                     jax.ShapeDtypeStruct((m, k), jnp.float32),
                     jax.ShapeDtypeStruct((k, n), jnp.float32))
        cost = hlo_cost.analyze(c.as_text())
        assert cost.flops == pytest.approx(2 * m * n * k, rel=0.01)

    def test_scan_multiplies_by_trip_count(self):
        """XLA's own cost_analysis counts while bodies once; ours doesn't."""
        m = 128
        reps = 8

        def g(a, bs):
            def body(x, b):
                return x @ b, ()
            y, _ = jax.lax.scan(body, a, bs)
            return y

        c = _compile(g, jax.ShapeDtypeStruct((m, m), jnp.float32),
                     jax.ShapeDtypeStruct((reps, m, m), jnp.float32))
        cost = hlo_cost.analyze(c.as_text())
        want = reps * 2 * m ** 3
        assert cost.flops == pytest.approx(want, rel=0.02)
        xla = c.cost_analysis().get("flops", 0)
        assert xla < want / 2   # demonstrates the undercount we fix
        assert cost.unknown_trip_whiles == 0

    def test_nested_scan_multiplies(self):
        m, r1, r2 = 64, 3, 5

        def g(a, bs):
            def outer(x, b_outer):
                def inner(y, _):
                    return y @ b_outer, ()
                y, _ = jax.lax.scan(inner, x, None, length=r2)
                return y, ()
            y, _ = jax.lax.scan(outer, a, bs)
            return y

        c = _compile(g, jax.ShapeDtypeStruct((m, m), jnp.float32),
                     jax.ShapeDtypeStruct((r1, m, m), jnp.float32))
        cost = hlo_cost.analyze(c.as_text())
        assert cost.flops == pytest.approx(r1 * r2 * 2 * m ** 3, rel=0.05)

    def test_bytes_scale_with_scan(self):
        m, reps = 256, 4

        def g(a, bs):
            def body(x, b):
                return x + b, ()
            y, _ = jax.lax.scan(body, a, bs)
            return y

        c = _compile(g, jax.ShapeDtypeStruct((m, m), jnp.float32),
                     jax.ShapeDtypeStruct((reps, m, m), jnp.float32))
        cost = hlo_cost.analyze(c.as_text())
        # each iteration reads carry + slice and writes carry
        want_min = reps * 2 * m * m * 4
        assert cost.bytes >= want_min

    def test_conv_flops(self):
        # depthwise conv: 2 * out_elems * window
        x = jax.ShapeDtypeStruct((1, 64, 32), jnp.float32)   # NWC
        w = jax.ShapeDtypeStruct((4, 1, 32), jnp.float32)    # WIO grouped

        def f(x, w):
            return jax.lax.conv_general_dilated(
                x, w, (1,), "VALID",
                dimension_numbers=("NWC", "WIO", "NWC"),
                feature_group_count=32)

        c = _compile(f, x, w)
        cost = hlo_cost.analyze(c.as_text())
        out_elems = 61 * 32
        assert cost.flops_by_op.get("convolution", 0) == pytest.approx(
            2 * out_elems * 4, rel=0.01)

    def test_elementwise_counted(self):
        c = _compile(lambda a: jnp.tanh(a) * 2 + 1,
                     jax.ShapeDtypeStruct((128, 128), jnp.float32))
        cost = hlo_cost.analyze(c.as_text())
        assert cost.flops >= 128 * 128       # at least one op per element

    def test_empty_text(self):
        cost = hlo_cost.analyze("")
        assert cost.flops == 0 and cost.bytes == 0
