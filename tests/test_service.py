"""Sweep service: admission control, fusion, deadlines, crash recovery.

The service (`repro.core.service.SweepService`) wraps the streaming
executor in a long-lived server; everything it adds on top must be
*exactness-preserving*: a served request returns bitwise what a solo
`stream_grid` call would, fusion slices each member's deliverables
exactly out of the stacked dispatch, a deadline or cancel yields the
executor's consistent prefix snapshot (never garbage), and a SIGKILL'd
server restarted over the same spool resumes to bitwise-identical
results.  Backpressure is reject-at-the-door: admitted work is never
dropped and submission never deadlocks.
"""

import json
import os
import signal
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.core import pareto, stream, sweep
from repro.core.service import (CancelledError, ServiceClosedError,
                                SweepRequest, SweepService, _fusable,
                                _fused_request)
from repro.runtime import (AdmissionQueue, BackpressureError, Deadline,
                           FaultInjector, FaultPlan)

# A smaller grid than test_stream's reference (1,632 configs with the
# default cut axis) so multi-request scenarios stay fast; chunk 97 does
# not divide it, exercising the ragged tail through the service path.
GRID = dict(
    agg_nodes=("7nm", "16nm"),
    sensor_nodes=("7nm", "16nm"),
    detnet_fps=(10.0, 20.0, 30.0),
    keynet_fps=(30.0, 45.0),
    num_cameras=(2.0, 4.0),
)
CHUNK = 97
TOP_K = 4
OBJS = pareto.DEFAULT_OBJECTIVES


@pytest.fixture(scope="module")
def dense():
    return sweep.evaluate_grid(**GRID)


@pytest.fixture(scope="module")
def dense_front(dense):
    return pareto.pareto_front(dense)


@pytest.fixture(scope="module")
def solo(dense):
    """The reference solo run every served request must reproduce."""
    return stream.stream_grid(**GRID, track="all", chunk_size=CHUNK,
                              top_k=TOP_K)


def _request(**kw):
    kw.setdefault("grid", GRID)
    kw.setdefault("track", "all")
    kw.setdefault("chunk_size", CHUNK)
    kw.setdefault("top_k", TOP_K)
    return SweepRequest(**kw)


def _assert_bitwise(res, ref):
    """Bitwise equality on every deliverable of two stream results."""
    assert res.min_val == ref.min_val
    assert res.min_idx == ref.min_idx
    assert res.finite_counts == ref.finite_counts
    assert res.channel_min == ref.channel_min
    assert res.channel_max == ref.channel_max
    assert np.array_equal(res.topk_idx, ref.topk_idx)
    assert np.array_equal(res.topk_val, ref.topk_val)
    assert np.array_equal(res.front_indices, ref.front_indices)
    assert np.array_equal(res.front_values, ref.front_values)


# ---------------------------------------------------------------------------
# Admission primitives
# ---------------------------------------------------------------------------


class TestDeadline:
    def test_none_never_expires(self):
        d = Deadline.after(None)
        assert not d.expired()
        assert d.remaining_s() is None

    def test_expiry_and_remaining(self):
        d = Deadline.after(0.0)
        assert d.expired()
        assert d.remaining_s() <= 0.0
        far = Deadline.after(60.0)
        assert not far.expired()
        assert 0.0 < far.remaining_s() <= 60.0

    def test_earliest_picks_tightest(self):
        a, b = Deadline.after(10.0), Deadline.after(60.0)
        assert Deadline.earliest(a, b, Deadline.after(None)).at == a.at
        assert Deadline.earliest(Deadline.after(None)).at is None
        assert Deadline.earliest().at is None


class TestAdmissionQueue:
    def test_capacity_validated(self):
        with pytest.raises(ValueError):
            AdmissionQueue(0)

    def test_offer_rejects_at_capacity_with_fields(self):
        q = AdmissionQueue(2)
        q.offer("a")
        q.offer("b")
        with pytest.raises(BackpressureError) as ei:
            q.offer("c")
        assert ei.value.queue_depth == 2
        assert ei.value.capacity == 2
        assert q.depth == 2            # rejected item was not enqueued
        assert q.snapshot() == ["a", "b"]

    def test_take_batch_fifo_and_timeout(self):
        q = AdmissionQueue(4)
        assert q.take_batch(timeout=0.01) == []
        q.offer("a")
        q.offer("b")
        assert q.take_batch(timeout=0.01) == ["a"]
        assert q.take_batch(timeout=0.01) == ["b"]

    def test_take_batch_claims_compatible_followers(self):
        q = AdmissionQueue(8)
        for item in ("a1", "b1", "a2", "a3", "b2"):
            q.offer(item)
        same = lambda head, other: other[0] == head[0]
        batch = q.take_batch(timeout=0.01, compatible=same, max_batch=3)
        assert batch == ["a1", "a2", "a3"]
        # Incompatible items keep their FIFO order.
        assert q.snapshot() == ["b1", "b2"]

    def test_take_batch_respects_max_batch(self):
        q = AdmissionQueue(8)
        for item in ("a1", "a2", "a3"):
            q.offer(item)
        batch = q.take_batch(timeout=0.01,
                             compatible=lambda h, o: True, max_batch=2)
        assert batch == ["a1", "a2"]
        assert q.snapshot() == ["a3"]

    def test_readmit_prepends_and_bypasses_capacity(self):
        q = AdmissionQueue(1)
        q.offer("new")
        q.readmit("recovered")         # full queue must still accept it
        assert q.snapshot() == ["recovered", "new"]

    def test_remove(self):
        q = AdmissionQueue(4)
        q.offer("a")
        assert q.remove("a") is True
        assert q.remove("a") is False
        assert q.depth == 0


# ---------------------------------------------------------------------------
# Request validation & fusion rules (pure functions — no executor)
# ---------------------------------------------------------------------------


class TestSweepRequest:
    def test_unknown_grid_axis_rejected(self):
        with pytest.raises(ValueError, match="unknown grid axes"):
            _request(grid={"not_an_axis": (1, 2)}).normalized()

    def test_json_round_trip(self):
        req = _request(constraints={"avg_power": 1.0},
                       deadline_s=2.5).normalized()
        clone = SweepRequest.from_json(
            json.loads(json.dumps(req.to_json())))
        assert clone == req

    def test_deadlines_never_fuse(self):
        a, b = _request(), _request(deadline_s=5.0)
        assert not _fusable(a, b)
        assert not _fusable(b, a)
        assert _fusable(a, _request())

    def test_sense_conflict_never_fuses(self):
        a = _request(objectives=OBJS, maximize=())
        b = _request(objectives=OBJS, maximize=(OBJS[0],))
        assert not _fusable(a, b)

    def test_front_containment_rules(self):
        head = _request(objectives=OBJS)                 # wants the front
        sub = _request(objectives=OBJS[:1], need_front=False)
        assert _fusable(head, sub)
        # A follower wanting a *different* front cannot ride along.
        assert not _fusable(head, _request(objectives=OBJS[:1]))
        # A no-front head cannot carry a front-wanting follower.
        assert not _fusable(_request(need_front=False), _request())

    def test_fused_request_covers_members(self):
        a = _request(objectives=OBJS[:2], track=("detnet_power",),
                     top_k=2)
        b = _request(objectives=OBJS[:1], need_front=False, top_k=6)
        fused = _fused_request([a, b])
        assert fused.objectives == tuple(OBJS[:2])   # head order first
        assert fused.top_k == 6
        assert fused.need_front
        assert fused.deadline_s is None


# ---------------------------------------------------------------------------
# The service itself
# ---------------------------------------------------------------------------


class TestServiceBasics:
    def test_served_request_bitwise_parity(self, solo):
        with SweepService() as svc:
            t = svc.submit(_request())
            res = t.result(timeout=600)
        assert not res.partial
        assert res.stats["fraction_complete"] == 1.0
        assert t.state == "done" and t.done()
        _assert_bitwise(res, solo)

    def test_plan_and_step_cache_hit_on_resubmit(self, solo):
        with SweepService() as svc:
            r1 = svc.submit(_request()).result(timeout=600)
            r2 = svc.submit(_request()).result(timeout=600)
            health = svc.health()
        _assert_bitwise(r1, r2)
        # Second submission resolves to the same content signature: the
        # plan LRU hits, and the plan's cached ChunkSpec makes the
        # compiled-step LRU hit (no recompilation across requests).
        assert health["plan_cache"]["misses"] == 1
        assert health["plan_cache"]["hits"] == 1
        assert health["step_cache"]["hits"] >= 1

    def test_health_surface_is_jsonable(self):
        with SweepService(capacity=3) as svc:
            svc.submit(_request()).result(timeout=600)
            health = svc.health()
        json.dumps(health)      # the whole surface must serialize
        assert health["capacity"] == 3
        assert health["queue_depth"] == 0
        assert health["counters"]["admitted"] == 1
        assert health["counters"]["completed"] == 1
        assert health["counters"]["executions"] == 1
        for key in ("retries", "restarts", "elastic_replans",
                    "stragglers", "deadline_expired"):
            assert key in health["counters"], key
        tid = next(iter(health["requests"]))
        assert health["requests"][tid]["state"] == "done"
        assert health["requests"][tid]["progress"] == 1.0

    def test_submit_after_close_raises(self):
        svc = SweepService()
        svc.close()
        with pytest.raises(RuntimeError, match="shut down"):
            svc.submit(_request())

    def test_malformed_request_rejected_before_admission(self):
        with SweepService() as svc:
            with pytest.raises(ValueError):
                svc.submit(_request(grid={"bogus_axis": (1,)}))
            assert svc.health()["counters"]["admitted"] == 0


class TestFusion:
    def test_compatible_requests_fuse_to_one_dispatch(self, solo, dense,
                                                      dense_front):
        with SweepService(capacity=8) as svc:
            svc.pause()        # let the backlog build deterministically
            ta = svc.submit(_request())
            tb = svc.submit(_request(top_k=2))
            tc = svc.submit(_request(objectives=OBJS[:1],
                                     need_front=False, track=None))
            svc.resume()
            ra = ta.result(timeout=600)
            rb = tb.result(timeout=600)
            rc = tc.result(timeout=600)
            counters = svc.health()["counters"]
        assert counters["executions"] == 1
        assert counters["fused_requests"] == 3
        for r in (ra, rb, rc):
            assert r.stats["fused_members"] == 3.0

        # Member A asked for the full reference request: bitwise parity.
        _assert_bitwise(ra, solo)
        # Member B differs only in top-k: its table is the first two
        # columns of the head's.
        assert np.array_equal(rb.topk_idx, solo.topk_idx[:, :2])
        assert np.array_equal(rb.topk_val, solo.topk_val[:, :2])
        assert np.array_equal(rb.front_indices, solo.front_indices)
        # Member C narrowed to one objective and no front.
        assert rc.objectives == tuple(OBJS[:1])
        assert rc.front_indices.size == 0
        obj = OBJS[0]
        assert rc.argmin(obj) == dense.argmin(obj)
        assert rc.top_k(obj) == dense.top_k(obj, TOP_K)

    def test_incompatible_requests_do_not_fuse(self):
        with SweepService(capacity=8) as svc:
            svc.pause()
            ta = svc.submit(_request())
            tb = svc.submit(_request(maximize=(OBJS[0],),
                                     need_front=False))
            svc.resume()
            ta.result(timeout=600)
            tb.result(timeout=600)
            counters = svc.health()["counters"]
        assert counters["executions"] == 2
        assert counters["fused_requests"] == 0


class TestBackpressure:
    def test_reject_at_capacity_without_dropping_work(self, solo):
        with SweepService(capacity=2) as svc:
            svc.pause()
            ta = svc.submit(_request())
            tb = svc.submit(_request(top_k=2))
            with pytest.raises(BackpressureError) as ei:
                svc.submit(_request())
            assert ei.value.queue_depth == 2
            assert ei.value.capacity == 2
            counters = svc.health()["counters"]
            assert counters["rejected"] == 1
            assert counters["admitted"] == 2
            svc.resume()
            # Rejection must not have disturbed the admitted work.
            ra = ta.result(timeout=600)
            tb.result(timeout=600)
        _assert_bitwise(ra, solo)


class TestIdempotentSubmit:
    def test_same_client_id_returns_same_ticket(self, solo):
        with SweepService() as svc:
            t1 = svc.submit(_request(), client_id="cid-1")
            t2 = svc.submit(_request(), client_id="cid-1")
            assert t1 is t2
            res = t2.result(timeout=600)
            counters = svc.health()["counters"]
        _assert_bitwise(res, solo)
        assert counters["deduped"] == 1
        assert counters["admitted"] == 1
        assert counters["executions"] == 1

    def test_same_client_id_different_request_rejected(self):
        with SweepService() as svc:
            svc.pause()
            svc.submit(_request(), client_id="cid-2")
            with pytest.raises(ValueError, match="already used"):
                svc.submit(_request(top_k=TOP_K + 1), client_id="cid-2")

    def test_rejected_submit_does_not_burn_the_client_id(self):
        with SweepService(capacity=1) as svc:
            svc.pause()
            svc.submit(_request())
            with pytest.raises(BackpressureError):
                svc.submit(_request(top_k=2), client_id="cid-3")
            svc.resume()
            svc.tickets()[0].result(timeout=600)
            # The id must be reusable: the rejection rolled back its
            # reservation instead of poisoning future submits.
            t = svc.submit(_request(top_k=2), client_id="cid-3")
            t.result(timeout=600)

    def test_finished_request_recovered_with_result(self, tmp_path,
                                                    solo):
        """A DONE request journals its result; a fresh service over the
        same spool re-attaches the idempotent client id to the finished
        ticket without re-executing."""
        spool = str(tmp_path / "spool")
        with SweepService(spool_dir=spool) as svc:
            first = svc.submit(_request(), client_id="cid-4")
            r1 = first.result(timeout=600)
        with SweepService(spool_dir=spool) as svc2:
            counters = svc2.health()["counters"]
            assert counters["recovered_finished"] == 1
            t = svc2.submit(_request(), client_id="cid-4")
            assert t.done() and t.state == "done"
            r2 = t.result(timeout=10)
            assert svc2.health()["counters"]["executions"] == 0
        _assert_bitwise(r1, solo)
        _assert_bitwise(r2, solo)

    def test_tenant_and_priority_round_trip(self):
        req = _request(tenant="alice", priority=3).normalized()
        clone = SweepRequest.from_json(
            json.loads(json.dumps(req.to_json())))
        assert clone.tenant == "alice" and clone.priority == 3
        assert clone == req


class TestServiceShutdown:
    def test_queued_ticket_fails_fast_when_service_closes(self):
        """`Ticket.result()` must never hang on a ticket nothing will
        ever finish: closing the service fails leftovers with
        ServiceClosedError instead of leaving waiters blocked."""
        svc = SweepService()
        svc.pause()
        t = svc.submit(_request())
        svc.close(drain=False)
        with pytest.raises(ServiceClosedError, match="service closed"):
            t.result(timeout=30)
        assert t.done()

    def test_closed_queued_ticket_resumes_on_restarted_spool(self,
                                                             tmp_path,
                                                             solo):
        """The fail-fast close keeps the journal state pre-shutdown, so
        a service restarted over the same spool still recovers and
        finishes the request."""
        spool = str(tmp_path / "spool")
        svc = SweepService(spool_dir=spool)
        svc.pause()
        t = svc.submit(_request())
        svc.close(drain=False)
        with pytest.raises(ServiceClosedError):
            t.result(timeout=30)
        with SweepService(spool_dir=spool) as svc2:
            assert svc2.health()["counters"]["recovered"] == 1
            res = svc2.tickets()[0].result(timeout=600)
        _assert_bitwise(res, solo)


class TestDeadlinesAndCancel:
    def test_deadline_returns_consistent_partial_snapshot(self, dense):
        # A 2 s straggle injected at chunk 1 guarantees the 0.8 s
        # deadline lapses mid-sweep regardless of host speed.
        inj = FaultInjector(FaultPlan(straggle={1: 2.0}))
        with SweepService(fault_injector=inj) as svc:
            t = svc.submit(_request(deadline_s=0.8))
            res = t.result(timeout=600)
            counters = svc.health()["counters"]
        assert res.partial
        frac = res.stats["fraction_complete"]
        assert 0.0 < frac < 1.0
        assert counters["deadline_expired"] == 1
        assert t.state == "done"
        # Prefix consistency: the snapshot is the exact reduction over
        # the first `base` flat configs, not an arbitrary mix.
        base = round(frac * dense.data[OBJS[0]].size)
        for field in OBJS:
            prefix = np.asarray(dense.data[field]).ravel()[:base]
            assert res.min_val[field] == float(np.nanmin(prefix)), field
            assert res.min_idx[field] == int(np.nanargmin(prefix)), field
            assert res.finite_counts[field] == \
                int(np.isfinite(prefix).sum()), field

    def test_cancel_before_execution(self):
        with SweepService() as svc:
            svc.pause()
            t = svc.submit(_request())
            t.cancel()
            svc.resume()
            assert t.done()
            assert t.state == "cancelled"
            with pytest.raises(CancelledError):
                t.result(timeout=10)

    def test_cancel_mid_run_yields_partial(self):
        inj = FaultInjector(FaultPlan(straggle={1: 1.0}))
        with SweepService(fault_injector=inj) as svc:
            t = svc.submit(_request())
            # Wait for the first chunk to land (the injected 1 s
            # straggle on chunk 1 then holds the run open) so the
            # cancel is observably mid-sweep, not pre-dispatch.
            deadline = time.monotonic() + 120
            while t.progress == 0.0 and time.monotonic() < deadline:
                time.sleep(0.01)
            t.cancel()
            res = t.result(timeout=600)
            counters = svc.health()["counters"]
        assert t.state == "cancelled"
        assert res.partial
        assert 0.0 < res.stats["fraction_complete"] < 1.0
        assert counters["cancelled"] == 1


class TestServiceCrashRecovery:
    """SIGKILL the server mid-request; a fresh service over the same
    spool must re-admit the journaled request, resume from the newest
    checkpoint, and deliver the bitwise solo-run answer."""

    _COMMON = """
import sys
import numpy as np
from repro.core import stream
from repro.core.service import SweepService, SweepRequest
GRID = dict(agg_nodes=("7nm","16nm"), sensor_nodes=("7nm","16nm"),
            detnet_fps=(10.,20.,30.), keynet_fps=(30.,45.),
            num_cameras=(2.,4.))
REQ = SweepRequest(grid=GRID, track="all", chunk_size=97, top_k=4)
"""

    KILL = _COMMON + """
from repro.runtime import FaultInjector, FaultPlan
inj = FaultInjector(FaultPlan(kill_at=4))
svc = SweepService(spool_dir=sys.argv[1], capacity=4,
                   checkpoint_every_steps=1, fault_injector=inj)
svc.submit(REQ).result(timeout=600)
print("UNREACHABLE")
"""

    RESUME = _COMMON + """
import json
svc = SweepService(spool_dir=sys.argv[1], capacity=4,
                   checkpoint_every_steps=1)
ts = svc.tickets()
assert len(ts) == 1, [t.id for t in ts]
assert svc.health()["counters"]["recovered"] == 1
res = ts[0].result(timeout=600)
svc.close()
assert not res.partial
assert res.stats["resumed_from_step"] > 0, res.stats
ref = stream.stream_grid(**GRID, track="all", chunk_size=97, top_k=4)
assert res.min_val == ref.min_val and res.min_idx == ref.min_idx
assert res.finite_counts == ref.finite_counts
assert np.array_equal(res.topk_idx, ref.topk_idx)
assert np.array_equal(res.topk_val, ref.topk_val)
assert np.array_equal(res.front_indices, ref.front_indices)
assert np.array_equal(res.front_values, ref.front_values)
print(json.dumps({"resumed_from_step": res.stats["resumed_from_step"],
                  "ok": True}))
"""

    @staticmethod
    def _run(code: str, spool: str) -> subprocess.CompletedProcess:
        env = dict(os.environ)
        # Pin the child to one device: earlier test modules import
        # repro.launch.dryrun, which writes a 512-device
        # ``XLA_FLAGS`` into os.environ at import time.  Inherited
        # unpinned, that collapses this 17-dispatch job into a single
        # sharded dispatch and ``kill_at=4`` never fires.  Appending
        # wins (last flag takes effect), mirroring test_stream /
        # test_elastic.
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + " --xla_force_host_platform_device_count=1")
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.join(os.path.dirname(__file__), "..", "src")]
            + env.get("PYTHONPATH", "").split(os.pathsep))
        return subprocess.run([sys.executable, "-c", code, spool],
                              env=env, capture_output=True, text=True,
                              timeout=600)

    def test_sigkill_restart_resumes_bitwise(self, tmp_path):
        spool = str(tmp_path / "spool")
        out1 = self._run(self.KILL, spool)
        assert out1.returncode == -signal.SIGKILL, \
            (out1.returncode, out1.stderr[-2000:])
        assert "UNREACHABLE" not in out1.stdout
        out2 = self._run(self.RESUME, spool)
        assert out2.returncode == 0, out2.stderr[-2000:]
        payload = json.loads(out2.stdout.strip().splitlines()[-1])
        assert payload["ok"] is True
        assert payload["resumed_from_step"] > 0


class TestCLI:
    def test_module_entry_point_serves_requests(self, tmp_path):
        """`python -m repro.service` over a request file: one JSON
        summary per request plus a health snapshot."""
        reqfile = tmp_path / "reqs.jsonl"
        reqfile.write_text(json.dumps(
            _request(track=None).to_json()) + "\n")
        env = dict(os.environ)
        # Pin device count (see TestServiceCrashRecovery._run).
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + " --xla_force_host_platform_device_count=1")
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.join(os.path.dirname(__file__), "..", "src")]
            + env.get("PYTHONPATH", "").split(os.pathsep))
        out = subprocess.run(
            [sys.executable, "-m", "repro.service",
             "--spool", str(tmp_path / "spool"),
             "--requests", str(reqfile), "--timeout-s", "600"],
            env=env, capture_output=True, text=True, timeout=600)
        assert out.returncode == 0, out.stderr[-2000:]
        lines = [json.loads(l) for l in out.stdout.strip().splitlines()]
        assert lines[0]["state"] == "done"
        assert lines[0]["fraction_complete"] == 1.0
        assert "argmin" in lines[0]
        assert lines[-1]["health"]["counters"]["completed"] == 1
