"""Elastic replanning: mesh shrink policy and degraded-mode parity.

`repro.runtime.elastic` is the graceful-degradation half of the fault
story: when a worker dies the pool shrinks (never silently to zero),
and the streaming executor replans the remaining chunk ranges onto the
survivors.  The edge cases pinned here: dropping the last worker is a
loud error, an unidentifiable loss drops the tail worker, and a replan
all the way down to ONE device reproduces the multi-device carry
bitwise (degradation must never change answers).
"""

import os
import subprocess
import sys

import pytest

from repro.runtime import MeshPlan, drop_worker, replan_mesh, rescale_batch


class TestDropWorker:
    def test_drop_middle_preserves_order(self):
        assert drop_worker(("d0", "d1", "d2", "d3"), 1) == \
            ("d0", "d2", "d3")

    def test_drop_first_and_last(self):
        pool = ("d0", "d1", "d2")
        assert drop_worker(pool, 0) == ("d1", "d2")
        assert drop_worker(pool, 2) == ("d0", "d1")

    def test_out_of_range_index_drops_last(self):
        # An unidentifiable lost worker must still shrink the pool.
        pool = ("d0", "d1", "d2")
        assert drop_worker(pool, 99) == ("d0", "d1")
        assert drop_worker(pool, -3) == ("d0", "d1")

    def test_drop_last_worker_raises_clear_error(self):
        with pytest.raises(ValueError,
                           match="cannot drop the last worker"):
            drop_worker(("d0",), 0)

    def test_empty_pool_raises(self):
        with pytest.raises(ValueError,
                           match="cannot drop the last worker"):
            drop_worker((), 0)

    def test_repeated_drops_stop_at_one(self):
        pool = tuple(f"d{i}" for i in range(4))
        while len(pool) > 1:
            pool = drop_worker(pool, 0)
        assert pool == ("d3",)
        with pytest.raises(ValueError):
            drop_worker(pool, 0)


class TestReplanMesh:
    def test_model_axis_kept_when_chips_allow(self):
        plan = replan_mesh(48, model=16)
        assert plan == MeshPlan(("data", "model"), (3, 16), 0)
        assert plan.chips == 48

    def test_remainder_chips_become_spares(self):
        plan = replan_mesh(50, model=16)
        assert plan.shape == (3, 16)
        assert plan.dropped_chips == 2

    def test_degenerate_shrinks_model_to_power_of_two(self):
        plan = replan_mesh(6, model=16)
        assert plan.axes == ("data", "model")
        assert plan.shape == (1, 4)
        assert plan.dropped_chips == 2

    def test_pod_axis_preserved(self):
        plan = replan_mesh(64, model=16, pods=2)
        assert plan.axes == ("pod", "data", "model")
        assert plan.shape == (2, 2, 16)
        assert plan.dropped_chips == 0

    def test_single_chip(self):
        plan = replan_mesh(1, model=16)
        assert plan.shape == (1, 1)
        assert plan.chips == 1


class TestRescaleBatch:
    def test_keep_global_means_more_accumulation(self):
        assert rescale_batch(256, old_data=8, new_data=6) == 256

    def test_scale_with_data_axis_keeps_per_chip(self):
        assert rescale_batch(256, old_data=8, new_data=6,
                             keep_global=False) == 192


class TestSingleDeviceDegradation:
    """Losing a device on a 2-device mesh replans onto ONE device; the
    carry contract must make the degraded run bitwise-identical to the
    dense reference (subprocess so the forced host-device count cannot
    leak into other tests)."""

    @staticmethod
    def _run(code: str, n_devices: int) -> subprocess.CompletedProcess:
        env = dict(os.environ)
        env["XLA_FLAGS"] = (
            env.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={n_devices}")
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.join(os.path.dirname(__file__), "..", "src")]
            + env.get("PYTHONPATH", "").split(os.pathsep))
        return subprocess.run([sys.executable, "-c", code], env=env,
                              capture_output=True, text=True,
                              timeout=600)

    def test_replan_to_one_device_bitwise(self):
        code = """
import numpy as np
from repro.core import pareto, stream, sweep
from repro.runtime import FaultInjector, FaultPlan
GRID = dict(agg_nodes=("7nm","16nm"), sensor_nodes=("7nm","16nm"),
            detnet_fps=(10.,20.,30.), keynet_fps=(30.,45.),
            num_cameras=(2.,4.))
dense = sweep.evaluate_grid(**GRID)
inj = FaultInjector(FaultPlan(lose_device=(2, 0)))
res = stream.stream_grid(**GRID, chunk_size=128, top_k=4, track="all",
                         fault_injector=inj)
assert res.n_devices == 2, res.n_devices
assert inj.injected["device_lost"] == 1
assert res.stats["elastic_replans"] == 1.0, res.stats
assert res.stats["chunks_reissued"] > 0.0, res.stats
for f in sweep.FIELDS:
    assert res.argmin(f) == dense.argmin(f), f
    assert res.finite_counts[f] == \\
        int(np.isfinite(dense.data[f]).sum()), f
for o in res.objectives:
    assert res.top_k(o) == dense.top_k(o, 4), o
df = pareto.pareto_front(dense); sf = res.pareto_front()
assert np.array_equal(df.indices, sf.indices)
assert np.array_equal(df.values, sf.values)
print("DEGRADE-OK")
"""
        out = self._run(code, n_devices=2)
        assert out.returncode == 0, out.stderr[-2000:]
        assert "DEGRADE-OK" in out.stdout
