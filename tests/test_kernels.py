"""Per-kernel validation: shape/dtype sweeps against the pure-jnp oracles.

All Pallas kernels run in interpret mode (CPU executes the kernel body);
the TPU is the lowering target.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import (flash_attention,
                                           flash_attention_ref)
from repro.kernels.rbe_matmul import (dequant_matmul_ref, quantize_rowwise,
                                      rbe_matmul, rbe_matmul_raw,
                                      rbe_matmul_ref)
from repro.kernels.rmsnorm import rmsnorm, rmsnorm_ref


class TestFlashAttentionKernel:
    @pytest.mark.parametrize("b,s,h,kv,d,bq,bk", [
        (2, 256, 4, 2, 128, 64, 128),      # GQA
        (1, 128, 8, 8, 128, 32, 32),       # MHA
        (2, 256, 4, 1, 128, 128, 64),      # MQA, uneven blocks
        (1, 512, 2, 2, 256, 128, 128),     # big head dim (gemma-2-ish)
    ])
    def test_matches_oracle(self, b, s, h, kv, d, bq, bk):
        ks = jax.random.split(jax.random.key(0), 3)
        q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
        k = jax.random.normal(ks[1], (b, s, kv, d), jnp.float32)
        v = jax.random.normal(ks[2], (b, s, kv, d), jnp.float32)
        out = flash_attention(q, k, v, block_q=bq, block_kv=bk)
        ref = flash_attention_ref(q, k, v)
        np.testing.assert_allclose(out, ref, atol=5e-6, rtol=5e-6)

    @pytest.mark.parametrize("window", [32, 100])
    def test_sliding_window(self, window):
        ks = jax.random.split(jax.random.key(1), 3)
        q = jax.random.normal(ks[0], (1, 256, 4, 128), jnp.float32)
        k = jax.random.normal(ks[1], (1, 256, 2, 128), jnp.float32)
        v = jax.random.normal(ks[2], (1, 256, 2, 128), jnp.float32)
        out = flash_attention(q, k, v, window=window, block_q=64,
                              block_kv=64)
        ref = flash_attention_ref(q, k, v, window=window)
        np.testing.assert_allclose(out, ref, atol=5e-6, rtol=5e-6)

    def test_logit_softcap(self):
        ks = jax.random.split(jax.random.key(2), 3)
        q = jax.random.normal(ks[0], (1, 128, 4, 128), jnp.float32) * 3
        k = jax.random.normal(ks[1], (1, 128, 4, 128), jnp.float32) * 3
        v = jax.random.normal(ks[2], (1, 128, 4, 128), jnp.float32)
        out = flash_attention(q, k, v, logit_softcap=50.0, block_q=32,
                              block_kv=32)
        ref = flash_attention_ref(q, k, v, logit_softcap=50.0)
        np.testing.assert_allclose(out, ref, atol=5e-5, rtol=5e-5)

    def test_bfloat16_io(self):
        ks = jax.random.split(jax.random.key(3), 3)
        q = jax.random.normal(ks[0], (1, 128, 2, 128), jnp.bfloat16)
        k = jax.random.normal(ks[1], (1, 128, 2, 128), jnp.bfloat16)
        v = jax.random.normal(ks[2], (1, 128, 2, 128), jnp.bfloat16)
        out = flash_attention(q, k, v, block_q=64, block_kv=64)
        ref = flash_attention_ref(q, k, v)
        assert out.dtype == jnp.bfloat16
        np.testing.assert_allclose(out.astype(jnp.float32),
                                   ref.astype(jnp.float32),
                                   atol=2e-2, rtol=2e-2)

    def test_matches_model_flash_vjp_path(self):
        """Kernel and lowering-path flash must agree (same algorithm)."""
        from repro.models.flash import flash_attention as model_flash
        ks = jax.random.split(jax.random.key(4), 3)
        q = jax.random.normal(ks[0], (2, 128, 4, 128), jnp.float32)
        k = jax.random.normal(ks[1], (2, 128, 2, 128), jnp.float32)
        v = jax.random.normal(ks[2], (2, 128, 2, 128), jnp.float32)
        a = flash_attention(q, k, v, block_q=32, block_kv=64)
        b = model_flash(q, k, v, q_block=32, kv_block=64)
        np.testing.assert_allclose(a, b, atol=5e-6, rtol=5e-6)


class TestRBEMatmulKernel:
    @pytest.mark.parametrize("m,k,n,bm,bn,bk", [
        (128, 128, 128, 128, 128, 128),
        (256, 512, 384, 128, 128, 128),
        (512, 256, 128, 256, 128, 256),
    ])
    def test_matches_integer_oracle_exactly(self, m, k, n, bm, bn, bk):
        ks = jax.random.split(jax.random.key(0), 2)
        x_q = jax.random.randint(ks[0], (m, k), -127, 128, jnp.int8)
        w_q = jax.random.randint(ks[1], (k, n), -127, 128, jnp.int8)
        sx = jnp.abs(jax.random.normal(ks[0], (m,))) + 0.1
        sw = jnp.abs(jax.random.normal(ks[1], (n,))) + 0.1
        out = rbe_matmul_raw(x_q, w_q, sx, sw, block_m=bm, block_n=bn,
                             block_k=bk)
        ref = rbe_matmul_ref(x_q, w_q, sx, sw)
        np.testing.assert_allclose(out, ref, rtol=1e-6)

    def test_quantization_error_bounded(self):
        """End-to-end float -> int8 -> float error stays at the expected
        8-bit level (the RBE's operating point)."""
        ks = jax.random.split(jax.random.key(1), 2)
        x = jax.random.normal(ks[0], (256, 256), jnp.float32)
        w = jax.random.normal(ks[1], (256, 256), jnp.float32)
        out = rbe_matmul(x, w)
        ref = dequant_matmul_ref(x, w)
        rel = float(jnp.linalg.norm(out - ref) / jnp.linalg.norm(ref))
        assert rel < 0.02, rel

    def test_quantize_roundtrip(self):
        x = jax.random.normal(jax.random.key(2), (64, 128)) * 5
        q, s = quantize_rowwise(x, axis=-1)
        assert q.dtype == jnp.int8
        back = q.astype(jnp.float32) * s[:, None]
        assert float(jnp.max(jnp.abs(back - x))) < float(
            jnp.max(jnp.abs(x))) / 127 + 1e-5

    def test_int8_saturation(self):
        q, s = quantize_rowwise(jnp.asarray([[1e6, -1e6, 0.5]]), axis=-1)
        assert int(q.max()) == 127 and int(q.min()) == -127


class TestRMSNormKernel:
    @pytest.mark.parametrize("shape,block_rows", [
        ((4, 64, 256), 64),
        ((2, 128, 512), 256),
        ((16, 896), 8),
        ((3, 7, 384), 4),      # rows not a power of two
    ])
    def test_matches_oracle(self, shape, block_rows):
        ks = jax.random.split(jax.random.key(0), 2)
        x = jax.random.normal(ks[0], shape, jnp.float32)
        scale = jax.random.normal(ks[1], (shape[-1],), jnp.float32) * 0.1
        out = rmsnorm(x, scale, block_rows=block_rows)
        ref = rmsnorm_ref(x, scale)
        np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-5)

    def test_bfloat16(self):
        x = jax.random.normal(jax.random.key(1), (64, 256), jnp.bfloat16)
        scale = jnp.zeros((256,), jnp.float32)
        out = rmsnorm(x, scale)
        ref = rmsnorm_ref(x, scale)
        np.testing.assert_allclose(out.astype(jnp.float32),
                                   ref.astype(jnp.float32), atol=2e-2)

    def test_matches_model_layer(self):
        from repro.models.layers import rmsnorm as model_rmsnorm
        x = jax.random.normal(jax.random.key(2), (8, 32, 128))
        scale = jax.random.normal(jax.random.key(3), (128,)) * 0.1
        a = rmsnorm(x, scale)
        b = model_rmsnorm({"scale": scale}, x)
        np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)
