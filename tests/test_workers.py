"""Worker pool: lease board transitions, range parity, kill-reclaim.

The scale-out path (`repro.runtime.workers`) must add *zero* numeric
semantics: a job drained by any number of worker processes folds to a
result bitwise-identical to one solo `stream_grid` call.  The lease
board is the only coordination state — claims, steals, reclaims and
completion all go through `board.json` under a flock — so these tests
drive the board directly (state machine), drain a job in-process
(parity), and finally SIGKILL a live worker mid-lease (chaos) to prove
the reclaim path reissues from the carry snapshot and still lands the
exact answer.
"""

import json
import os
import signal
import time

import numpy as np
import pytest

from repro.core import stream
from repro.core.service import SweepRequest, SweepService
from repro.runtime import workers as wk

# 2 * 2 * 12 * 2 * 2 = 192 configs; chunk 31 with scan_chunks=1 gives a
# 31-config lease quantum -> 7 dispatch steps, so a multi-lease board
# has interior boundaries that must respect flat_range alignment.
GRID = dict(
    agg_nodes=("7nm", "16nm"),
    sensor_nodes=("7nm", "16nm"),
    detnet_fps=tuple(float(f) for f in range(5, 65, 5)),
    keynet_fps=(30.0, 45.0),
    num_cameras=(2.0, 4.0),
)
CHUNK = 31
TOP_K = 4


def _request(**kw):
    kw.setdefault("grid", GRID)
    kw.setdefault("track", "all")
    kw.setdefault("chunk_size", CHUNK)
    kw.setdefault("scan_chunks", 1)
    kw.setdefault("top_k", TOP_K)
    return SweepRequest(**kw)


@pytest.fixture(scope="module")
def solo():
    return stream.stream_grid(**GRID, track="all", chunk_size=CHUNK,
                              scan_chunks=1, top_k=TOP_K)


def _assert_bitwise(res, ref):
    assert res.min_val == ref.min_val
    assert res.min_idx == ref.min_idx
    assert res.finite_counts == ref.finite_counts
    assert np.array_equal(res.topk_idx, ref.topk_idx)
    assert np.array_equal(res.topk_val, ref.topk_val)
    assert np.array_equal(res.front_indices, ref.front_indices)
    assert np.array_equal(res.front_values, ref.front_values)


# ---------------------------------------------------------------------------
# Lease board state machine (no execution)
# ---------------------------------------------------------------------------


class TestLeaseBoard:
    def _board(self, tmp_path, **kw):
        handle = wk.dispatch_job(str(tmp_path), _request(), **kw)
        return handle, handle.board

    def test_dispatch_tiles_the_flat_space_aligned(self, tmp_path):
        handle, board = self._board(tmp_path, n_leases=5)
        doc = board.read()
        leases = doc["leases"]
        assert leases[0]["start"] == 0
        assert leases[-1]["stop"] == handle.n_total
        q = doc["quantum"]
        for prev, cur in zip(leases, leases[1:]):
            assert prev["stop"] == cur["start"]       # contiguous tiling
            assert cur["start"] % q == 0              # aligned interior cut
        assert all(ls["state"] == "free" for ls in leases)

    def test_dispatch_is_idempotent_by_signature(self, tmp_path):
        h1, board = self._board(tmp_path, n_leases=3)
        assert board.claim("w-a", ttl=60.0) is not None
        h2 = wk.dispatch_job(str(tmp_path), _request(), n_leases=3)
        assert h2.job_dir == h1.job_dir
        # Reattach keeps the existing board — the claim survived.
        assert h2.board.read()["leases"][0]["state"] == "leased"

    def test_claim_heartbeat_steal(self, tmp_path):
        _, board = self._board(tmp_path, n_leases=2)
        lease = board.claim("w-a", ttl=0.05)
        assert lease["i"] == 0 and lease["attempt"] == 1
        assert board.heartbeat(0, "w-a", 0.25)
        time.sleep(0.1)                       # let the heartbeat go stale
        stolen = board.claim("w-b", ttl=0.05)
        assert stolen["i"] == 0 and stolen["attempt"] == 2
        # The old owner learns about the steal on its next beat ...
        assert not board.heartbeat(0, "w-a")
        # ... and its late fail() must not clobber the thief's lease.
        board.fail(0, "w-a", "boom")
        assert board.read()["leases"][0]["state"] == "leased"
        assert board.read()["leases"][0]["wid"] == "w-b"

    def test_fail_frees_then_attempt_cap_fails_terminally(self, tmp_path):
        _, board = self._board(tmp_path, n_leases=1, max_attempts=2)
        lease = board.claim("w-a", ttl=60.0)
        board.fail(lease["i"], "w-a", "transient")
        assert board.read()["leases"][0]["state"] == "free"
        lease = board.claim("w-a", ttl=60.0)
        assert lease["attempt"] == 2
        board.fail(lease["i"], "w-a", "again")
        assert board.read()["leases"][0]["state"] == "failed"
        assert board.claim("w-a", ttl=60.0) is None
        st = board.poll()
        assert not st["done"] and len(st["failed"]) == 1
        assert "again" in st["failed"][0]["error"]

    def test_done_wins_over_steal(self, tmp_path):
        _, board = self._board(tmp_path, n_leases=1)
        board.claim("w-a", ttl=0.05)
        time.sleep(0.1)
        board.claim("w-b", ttl=0.05)          # steal
        # The straggler completes anyway: deterministic execution means
        # its part is byte-identical, so "done" is accepted.
        board.complete(0, "w-a", {"fake": "part"})
        doc = board.read()
        assert doc["leases"][0]["state"] == "done"
        with open(board.part_path(0)) as f:
            assert json.load(f) == {"fake": "part"}

    def test_cancel_flag_round_trip(self, tmp_path):
        handle, board = self._board(tmp_path)
        assert not board.cancelled()
        handle.cancel()
        assert board.cancelled()
        # Re-dispatch (idempotent reattach) clears the stale flag.
        wk.dispatch_job(str(tmp_path), _request())
        assert not board.cancelled()


# ---------------------------------------------------------------------------
# In-process drain: parity and checkpoint-resume on reclaim
# ---------------------------------------------------------------------------


class TestWorkerDrain:
    def test_once_drain_is_bitwise_exact(self, tmp_path, solo):
        handle = wk.dispatch_job(str(tmp_path), _request(), n_leases=5)
        assert wk.worker_loop(str(tmp_path), wid="w-test", once=True) == 0
        st = handle.poll()
        assert st["done"] and st["fraction"] == 1.0
        res = handle.result()
        _assert_bitwise(res, solo)
        assert res.stats["n_parts"] == 5.0
        snap = handle.snapshot()
        assert snap["fraction_complete"] == 1.0
        assert snap["best"] is not None

    def test_reclaim_resumes_from_carry_snapshot(self, tmp_path, solo):
        """A lease abandoned mid-range (owner died after checkpointing)
        is reclaimed and *resumed* — the finished prefix is not
        recomputed — and the fold is still bitwise-exact."""
        handle = wk.dispatch_job(str(tmp_path), _request(), n_leases=2,
                                 checkpoint_every_steps=1)
        board = handle.board
        lease = board.claim("w-dead", ttl=0.05)
        plan = handle.plan
        stops = [0]

        def stop_after_two():
            stops[0] += 1
            return stops[0] > 2

        part = stream.stream_grid(
            plan=plan,
            flat_range=(lease["start"], lease["stop"]),
            checkpoint_dir=board.ckpt_dir(lease["i"]),
            checkpoint_every_steps=1,
            should_stop=stop_after_two)
        assert part.partial                   # died mid-lease
        assert os.listdir(board.ckpt_dir(lease["i"]))
        time.sleep(0.1)                       # heartbeat goes stale
        reclaimed = board.claim("w-heir", ttl=0.05)
        assert reclaimed["i"] == lease["i"]
        assert reclaimed["attempt"] == 2
        assert wk.run_lease(board, reclaimed, "w-heir", ttl=60.0)
        with open(board.part_path(reclaimed["i"])) as f:
            stats = json.load(f)["stats"]
        assert stats["resumed_from_step"] > 0
        assert wk.worker_loop(str(tmp_path), wid="w-rest", once=True) == 0
        _assert_bitwise(handle.result(), solo)


# ---------------------------------------------------------------------------
# Pooled service path
# ---------------------------------------------------------------------------


class TestPooledService:
    def test_service_dispatches_to_pool_bitwise(self, tmp_path, solo):
        svc = SweepService(capacity=4, snapshot_every_s=0.0, workers=2,
                           spool_dir=str(tmp_path / "spool"))
        try:
            t = svc.submit(_request())
            res = t.result(timeout=600)
            _assert_bitwise(res, solo)
            assert res.stats["n_parts"] >= 2.0
            assert svc.counters["pooled_executions"] == 1
            assert svc.health()["workers"]["n"] == 2
            # Snapshot path: the coordinator folds finished parts into
            # progress snapshots of the executor's shape.
            if t.snapshot is not None:
                assert 0.0 <= t.snapshot["fraction_complete"] <= 1.0
                assert t.snapshot["partial"] is True
        finally:
            svc.close()

    def test_deadline_requests_bypass_the_pool(self, tmp_path, solo):
        svc = SweepService(capacity=4, snapshot_every_s=0.0, workers=1,
                           spool_dir=str(tmp_path / "spool"))
        try:
            t = svc.submit(_request(deadline_s=600.0))
            res = t.result(timeout=600)
            _assert_bitwise(res, solo)
            assert svc.counters["pooled_executions"] == 0
        finally:
            svc.close()


# ---------------------------------------------------------------------------
# Chaos: SIGKILL a live worker mid-lease (the reclaim gate)
# ---------------------------------------------------------------------------


class TestWorkerKillReclaim:
    def test_sigkill_one_of_three_workers_reclaims_bitwise(
            self, tmp_path, solo):
        spool = str(tmp_path / "spool")
        os.makedirs(spool)
        handle = wk.dispatch_job(spool, _request(), n_leases=6,
                                 checkpoint_every_steps=1)
        ttl = 2.0
        with wk.WorkerPool(spool, 3, ttl_s=ttl, respawn=False) as pool:
            victim = None
            deadline = time.monotonic() + 300
            while victim is None and time.monotonic() < deadline:
                st = handle.poll()
                if st["done"]:
                    break
                for ls in st["leases"]:
                    if ls["state"] == "leased" \
                            and ls["owner"] in pool.pids():
                        victim = int(ls["owner"])
                        break
                time.sleep(0.02)
            assert victim is not None, "no worker claimed a lease"
            os.kill(victim, signal.SIGKILL)
            deadline = time.monotonic() + 300
            while time.monotonic() < deadline:
                st = handle.poll()
                assert not st["failed"], st["failed"]
                if st["done"]:
                    break
                time.sleep(0.1)
            st = handle.poll()
            assert st["done"], f"job did not drain: {st['states']}"
        # The killed worker's lease went stale and was reissued: at
        # least one lease needed a second attempt ...
        assert max(int(ls["attempt"]) for ls in st["leases"]) >= 2
        # ... and the fold is still exactly the solo answer.
        _assert_bitwise(handle.result(), solo)
