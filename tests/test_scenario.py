"""Oracle, parity and property tests for the session scenario engine.

The scenario engine (``repro.core.scenario``) advances battery
state-of-charge and a lumped-thermal RC node through piecewise-constant
user-behavior traces, re-evaluating the Eq. 1-11 kernel each step.  Its
correctness contract is pinned here four ways:

* **closed-form oracles** — the exact RC step response and the linear /
  Peukert battery drain admit analytic session solutions for constant
  traces; the engine must match them to <= 1e-6 relative;
* **bitwise parity** — the batched ``lax.scan`` kernel against the
  python-loop reference (``simulate_session``), and the constant-trace
  degeneracy against the plain static ``evaluate_grid``;
* **engine parity** — streaming argmin / top-k / Pareto / constraints
  over the session channels match the dense grid exactly;
* **properties** (hypothesis, guarded) — monotonicity and trace
  re-segmentation invariance, plus deterministic spot-checks of the
  same properties so they run even without hypothesis installed.
"""

import dataclasses
import math

import numpy as np
import pytest

from jax.experimental import enable_x64

from repro.core import pareto, partition, scenario as SC, stream, sweep
from repro.core.constants import (DEFAULT_BATTERY, DEFAULT_THERMAL,
                                  BatterySpec, ThermalSpec)

# This file mixes plain tests with hypothesis properties, so a
# module-level importorskip (the test_property.py pattern) would skip
# the oracles too; instead the decorators degrade to pytest skips.
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_a, **_k):
        return lambda f: f

    class _StrategyStub:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _StrategyStub()

MAX_EX = 10
RTOL = 1e-6


def _single(duration_s=600.0, **kw):
    """A one-trace ScenarioSet around a single constant full-rate phase."""
    tr = SC.ScenarioTrace("const", (SC.Phase(float(duration_s)),))
    return SC.ScenarioSet(traces=(tr,), throttle=False, **kw)


def _small_grid(sset, **kw):
    kw.setdefault("cuts", (0, 11))
    kw.setdefault("detnet_fps", (5.0, 30.0))
    return sweep.evaluate_grid(scenarios=sset, **kw)


class TestClosedFormOracles:
    """Constant-trace sessions against their analytic solutions."""

    def test_thermal_step_matches_exponential(self):
        """N exact RC substeps compose to the continuous solution."""
        th = DEFAULT_THERMAL
        tau = th.r_th_k_per_w * th.c_th_j_per_k
        P, D = 0.15, 600.0
        with enable_x64():
            temp = th.ambient_c
            for _ in range(16):
                temp = float(SC.thermal_step(temp, P, D / 16, th))
        ref = th.ambient_c + P * th.r_th_k_per_w * (1.0 - math.exp(-D / tau))
        assert temp == pytest.approx(ref, rel=1e-12)

    def test_peak_temp_closed_form(self):
        """peak_case_temp_c == amb + P*R*(1 - exp(-D/tau)) to <= 1e-6."""
        D = 600.0
        r = _small_grid(_single(D))
        th = DEFAULT_THERMAL
        tau = th.r_th_k_per_w * th.c_th_j_per_k
        P = r.data["avg_power"][..., 0]
        ref = th.ambient_c + P * th.r_th_k_per_w * (1.0 - np.exp(-D / tau))
        got = r.data["peak_case_temp_c"][..., 0]
        np.testing.assert_allclose(got, ref, rtol=RTOL)

    def test_battery_linear_drain_is_bitwise(self):
        """peukert == 1.0 -> exponent exactly 0.0 -> drain == power."""
        assert DEFAULT_BATTERY.peukert == 1.0
        with enable_x64():
            for p in (0.019, 0.37, 2.5):
                assert float(SC.effective_drain_w(p, DEFAULT_BATTERY)) == p

    def test_time_to_empty_linear_oracle_both_regimes(self):
        """tte == soc0 * capacity / P, in-session crossing *and*
        cyclic extrapolation (constant drain makes them coincide)."""
        for capacity_j in (1.0, DEFAULT_BATTERY.capacity_j):
            bat = dataclasses.replace(DEFAULT_BATTERY, name=f"c{capacity_j}",
                                      capacity_j=capacity_j)
            r = _small_grid(_single(600.0, battery=bat))
            P = r.data["avg_power"][..., 0]
            ref = bat.soc0 * capacity_j / P
            got = r.data["time_to_empty_s"][..., 0]
            np.testing.assert_allclose(got, ref, rtol=RTOL)
            # the tiny battery really does empty mid-session (crossing
            # regime), the default one does not (extrapolation regime)
            if capacity_j == 1.0:
                assert (got < 600.0).all()
            else:
                assert (got > 600.0).all()

    def test_time_to_empty_peukert_oracle(self):
        """Nonlinear drain: tte == soc0 * capacity / P**k for p_ref=1."""
        bat = dataclasses.replace(DEFAULT_BATTERY, name="pk", peukert=1.2,
                                  p_ref_w=1.0)
        r = _small_grid(_single(600.0, battery=bat))
        P = r.data["avg_power"][..., 0]
        ref = bat.soc0 * bat.capacity_j / P ** 1.2
        got = r.data["time_to_empty_s"][..., 0]
        np.testing.assert_allclose(got, ref, rtol=RTOL)

    def test_session_energy_oracle(self):
        """session_energy_j == P * D for a constant trace."""
        D = 600.0
        r = _small_grid(_single(D))
        np.testing.assert_allclose(r.data["session_energy_j"][..., 0],
                                   r.data["avg_power"][..., 0] * D,
                                   rtol=RTOL)

    def test_idle_battery_never_empties(self):
        """Zero drain -> time_to_empty_s == +inf (sentinel survives the
        NaN-poisoning arithmetic)."""
        sset = _single(60.0)
        r = sweep.evaluate_grid(cuts=(0,), detnet_fps=(1e-12,),
                                keynet_fps=(1e-12,), camera_fps=(1e-12,),
                                scenarios=sset)
        # power is tiny but nonzero, so check the sentinel via a direct
        # zero-power finalize instead of a grid corner
        import jax.numpy as jnp
        with enable_x64():
            carry = SC._init_carry(sset)
            carry = (jnp.float64(60.0),) + carry[1:]
            out = SC._finalize(carry, jnp.float64(0.0), sset.battery)
            assert float(out["time_to_empty_s"]) == np.inf
        assert np.isfinite(r.data["time_to_empty_s"]).all()


class TestScanLoopParity:
    """The batched lax.scan kernel vs the python-loop reference twin."""

    def _check(self, sset, **cfg):
        sim = SC.simulate_session(scenarios=sset, **cfg)
        r = sweep.evaluate_grid(
            cuts=(cfg.get("cut", 0),),
            detnet_fps=(cfg.get("detnet_fps", 10.0),),
            scenarios=sset)
        for f in sweep.SCENARIO_FIELDS:
            assert sim[f] == float(r.data[f].ravel()[0]), f

    def test_bitwise_parity_multiphase(self):
        sset = SC.ScenarioSet(traces=(SC.PROFILES["commute"],))
        self._check(sset, cut=11, detnet_fps=10.0)

    def test_bitwise_parity_with_throttle_active(self):
        """Throttle feedback engaged (onset just above ambient): the
        temperature-dependent rate rescaling must still be bitwise
        between the scan and the loop."""
        th = dataclasses.replace(DEFAULT_THERMAL, throttle_onset_c=25.05,
                                 throttle_gain_per_c=2.0)
        sset = SC.ScenarioSet(traces=(SC.PROFILES["gaming"],), thermal=th)
        sim = SC.simulate_session(scenarios=sset, cut=11, detnet_fps=30.0)
        assert sim["throttle_fraction"] > 0.0     # feedback really engaged
        self._check(sset, cut=11, detnet_fps=30.0)

    def test_trajectory_arrays_consistent(self):
        sim = SC.simulate_session(scenarios="commute", cut=11)
        n = len(sim["t_s"])
        assert len(sim["soc"]) == len(sim["temp_c"]) == n
        assert (np.diff(sim["soc"]) <= 0).all()       # battery only drains
        assert sim["energy_j"][-1] == sim["session_energy_j"]


class TestConstantTraceDegeneracy:
    """A single constant phase with throttling off must reproduce the
    static kernel bitwise — including its NaN validity pattern."""

    KW = dict(sensor_nodes=("7nm", "16nm"), weight_mems=("sram", "mram"),
              detnet_fps=(5.0, 30.0))

    def test_static_channels_bitwise(self):
        r_static = sweep.evaluate_grid(**self.KW)
        r_scen = sweep.evaluate_grid(scenarios=_single(600.0), **self.KW)
        assert tuple(r_scen.axes)[-1] == "trace"
        for f in sweep.FIELDS:
            assert np.array_equal(r_static.data[f],
                                  r_scen.data[f][..., 0],
                                  equal_nan=True), f

    def test_session_channels_inherit_validity(self):
        r_static = sweep.evaluate_grid(**self.KW)
        r_scen = sweep.evaluate_grid(scenarios=_single(600.0), **self.KW)
        nan = np.isnan(r_static.data["avg_power"])
        for f in sweep.SCENARIO_FIELDS:
            assert np.array_equal(np.isnan(r_scen.data[f][..., 0]), nan), f

    def test_unthrottled_session_never_throttles(self):
        r = sweep.evaluate_grid(scenarios=_single(600.0), **self.KW)
        tf = r.data["throttle_fraction"]
        assert (tf[np.isfinite(tf)] == 0.0).all()


class TestStreamParity:
    """Streaming reductions over session channels vs the dense grid."""

    KW = dict(sensor_nodes=("7nm", "16nm"), weight_mems=("sram",),
              detnet_fps=(5.0, 15.0, 30.0))
    OBJ = ("time_to_empty_s", "peak_case_temp_c")

    @pytest.fixture(scope="class")
    def dense(self):
        return sweep.evaluate_grid(scenarios="all", **self.KW)

    @pytest.fixture(scope="class")
    def streamed(self):
        return stream.stream_grid(objectives=self.OBJ,
                                  maximize=("time_to_empty_s",),
                                  scenarios="all", chunk_size=64, top_k=5,
                                  **self.KW)

    def test_argmin_matches_dense_bitwise(self, dense, streamed):
        win = streamed.argmin("peak_case_temp_c")
        assert win["peak_case_temp_c"] == np.nanmin(
            dense.data["peak_case_temp_c"])
        assert win["trace"] in SC.PROFILES

    def test_top_k_maximize_matches_dense(self, dense, streamed):
        tte = dense.data["time_to_empty_s"]
        want = np.sort(tte[np.isfinite(tte)])[::-1][:5]
        got = [p["time_to_empty_s"]
               for p in streamed.top_k("time_to_empty_s")]
        np.testing.assert_array_equal(got, want)

    def test_constrained_stream_matches_dense(self, dense):
        res = stream.stream_grid(
            objectives=self.OBJ, maximize=("time_to_empty_s",),
            constraints={"peak_case_temp_c": ("<=", 40.0)},
            scenarios="all", chunk_size=64, **self.KW)
        tte = dense.data["time_to_empty_s"]
        feas = np.where(dense.data["peak_case_temp_c"] <= 40.0, tte, np.nan)
        best = res.top_k("time_to_empty_s")[0]["time_to_empty_s"]
        assert best == np.nanmax(feas[np.isfinite(feas)])

    def test_pareto_front_matches_dense(self, dense, streamed):
        ref = pareto.pareto_front(dense, objectives=self.OBJ,
                                  maximize=("time_to_empty_s",))
        got = streamed.pareto_front()
        assert ref.objectives == got.objectives == self.OBJ
        ref_pts = {tuple(v) for v in np.asarray(ref.values)}
        got_pts = {tuple(v) for v in np.asarray(got.values)}
        assert got_pts == ref_pts


class TestErrorMessages:
    """The channel-listing / gating error contracts."""

    def test_stream_session_objective_requires_scenarios(self):
        with pytest.raises(ValueError,
                           match="session channels require scenarios="):
            stream.stream_grid(objectives=("time_to_empty_s",),
                               detnet_fps=(5.0,))

    def test_parse_constraints_lists_session_channels(self):
        with pytest.raises(ValueError, match="require scenarios=") as ei:
            sweep.parse_constraints({"bogus": 1.0})
        for f in sweep.SCENARIO_FIELDS:
            assert f in str(ei.value)

    def test_all_nan_session_channel_names_axis_values(self):
        r = sweep.evaluate_grid(cuts=(5, 11), sensor_nodes=("7nm",),
                                weight_mems=("mram",),
                                scenarios=_single(60.0))
        with pytest.raises(ValueError, match="weight_mem='mram'") as ei:
            r.argmin("time_to_empty_s")
        assert "time_to_empty_s" in str(ei.value)

    def test_pallas_backend_rejects_scenarios(self):
        with pytest.raises(ValueError,
                           match="does not support scenario sweeps"):
            sweep.evaluate_grid(cuts=(0,), scenarios=_single(60.0),
                                backend="pallas")

    def test_partition_session_objective_requires_scenarios(self):
        with pytest.raises(ValueError, match="session channel"):
            partition.optimal_partition(objective="time_to_empty_s")

    def test_partition_unknown_objective_lists_session_channels(self):
        with pytest.raises(ValueError, match="time_to_empty_s"):
            partition.optimal_partition(objective="bogus")

    def test_unknown_profile_and_trace(self):
        with pytest.raises(ValueError, match="unknown scenario profile"):
            SC.as_scenario_set("afk")
        with pytest.raises(KeyError, match="unknown trace"):
            SC.as_scenario_set("all").only("afk")

    def test_scenario_set_validation(self):
        with pytest.raises(ValueError, match="at least one trace"):
            SC.ScenarioSet(traces=())
        tr = SC.PROFILES["steady"]
        with pytest.raises(ValueError, match="duplicate"):
            SC.ScenarioSet(traces=(tr, tr))
        with pytest.raises(ValueError, match="steps_per_phase"):
            SC.ScenarioSet(traces=(tr,), steps_per_phase=0)


class TestPartitionScenario:
    """optimal_partition at session level."""

    KW = dict(sensor_node=("7nm", "16nm"), detnet_fps=(5.0, 15.0, 30.0))

    def test_maximize_tte_under_temp_constraint(self):
        p = partition.optimal_partition(
            objective="time_to_empty_s", scenarios="all",
            constraints={"peak_case_temp_c": ("<=", 40.0)}, **self.KW)
        assert p.trace in SC.PROFILES
        assert set(p.session) == set(sweep.SCENARIO_FIELDS)
        assert p.session["peak_case_temp_c"] <= 40.0

    def test_stream_route_matches_dense(self, monkeypatch):
        dense = partition.optimal_partition(
            objective="time_to_empty_s", scenarios="all", **self.KW)
        monkeypatch.setattr(partition, "STREAM_THRESHOLD", 8)
        streamed = partition.optimal_partition(
            objective="time_to_empty_s", scenarios="all", **self.KW)
        assert (streamed.cut, streamed.trace) == (dense.cut, dense.trace)
        assert streamed.session == dense.session

    def test_static_objective_still_minimized(self):
        p = partition.optimal_partition(objective="avg_power",
                                        scenarios="steady")
        assert p.trace == "steady"
        assert p.session is not None
        # plain searches keep the session slots empty
        q = partition.optimal_partition(objective="avg_power")
        assert q.trace is None and q.session is None


def _tte_along(axis_vals, sset=None, **axis_kw):
    """time_to_empty_s as a 1-D array along one opened grid axis."""
    r = sweep.evaluate_grid(cuts=(11,), scenarios=sset or _single(600.0),
                            **axis_kw)
    return np.squeeze(r.data["time_to_empty_s"])


class TestSessionProperties:
    """Monotonicity / invariance — deterministic spot-checks that always
    run, plus hypothesis generalizations when available."""

    def test_tte_monotone_in_power_draw_det(self):
        tte = _tte_along(None, mipi_energy_scale=(0.5, 1.0, 2.0, 4.0))
        assert (np.diff(tte) <= 0).all()

    def test_resegmentation_invariance_det(self):
        ref = _small_grid(_single(256.0))
        split = SC.ScenarioSet(traces=(SC.ScenarioTrace(
            "const", (SC.Phase(128.0), SC.Phase(128.0))),), throttle=False)
        r2 = _small_grid(split)
        for f in sweep.SCENARIO_FIELDS:
            np.testing.assert_allclose(r2.data[f], ref.data[f], rtol=1e-9,
                                       err_msg=f)

    def test_peak_temp_monotone_in_ambient_det(self):
        peaks = []
        for amb in (15.0, 25.0, 35.0):
            th = dataclasses.replace(DEFAULT_THERMAL, ambient_c=amb)
            r = _small_grid(_single(600.0, thermal=th))
            peaks.append(r.data["peak_case_temp_c"])
        assert (peaks[1] > peaks[0]).all() and (peaks[2] > peaks[1]).all()

    @given(lo=st.floats(0.25, 4.0), step=st.floats(0.1, 4.0))
    @settings(max_examples=MAX_EX, deadline=None)
    def test_tte_monotone_in_power_draw(self, lo, step):
        """More MIPI energy per byte -> more power -> no longer runtime."""
        tte = _tte_along(None, mipi_energy_scale=(lo, lo + step))
        assert tte[1] <= tte[0]

    @given(frac=st.sampled_from([0.25, 0.5, 0.75]),
           dur=st.sampled_from([128.0, 256.0, 512.0]))
    @settings(max_examples=MAX_EX, deadline=None)
    def test_resegmentation_invariance(self, frac, dur):
        """Splitting a constant phase at a dyadic point is physically a
        no-op (the RC step is exact); channels agree to 1e-9."""
        ref = _small_grid(_single(dur))
        split = SC.ScenarioSet(traces=(SC.ScenarioTrace(
            "const", (SC.Phase(frac * dur), SC.Phase((1 - frac) * dur)),)),
            throttle=False)
        r2 = _small_grid(split)
        for f in sweep.SCENARIO_FIELDS:
            np.testing.assert_allclose(r2.data[f], ref.data[f], rtol=1e-9,
                                       err_msg=f)

    @given(amb=st.floats(0.0, 40.0), delta=st.floats(0.5, 15.0))
    @settings(max_examples=MAX_EX, deadline=None)
    def test_peak_temp_monotone_in_ambient(self, amb, delta):
        lo = _small_grid(_single(600.0, thermal=dataclasses.replace(
            DEFAULT_THERMAL, ambient_c=amb)))
        hi = _small_grid(_single(600.0, thermal=dataclasses.replace(
            DEFAULT_THERMAL, ambient_c=amb + delta)))
        assert (hi.data["peak_case_temp_c"]
                > lo.data["peak_case_temp_c"]).all()
