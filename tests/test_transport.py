"""Networked sweep service: framing, reconnect, idempotency, streaming.

The wire path (`repro.runtime.transport.SweepServer` +
`repro.core.client.SweepClient`) must add *zero* semantics on top of
the in-process service: a networked result decodes bitwise-identical
to a solo `stream_grid` run, a retried submit after a dropped
connection (or a full server SIGKILL + restart over the same spool)
attaches to the existing ticket instead of executing twice, and
overload rejections carry the same `BackpressureError` fields the
in-process API raises — queue depth, capacity, tenant, retry-after.
"""

import json
import os
import signal
import socket
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.core import stream
from repro.core.client import AuthenticationError, RemoteError, SweepClient
from repro.core.service import SweepRequest, SweepService
from repro.runtime import BackpressureError, SweepServer
from repro.runtime import transport

# Two chunks of 97 over 192 configs: enough steps that the progress
# stream emits at least one consistent prefix snapshot before the
# final frame.
GRID = dict(
    agg_nodes=("7nm", "16nm"),
    sensor_nodes=("7nm", "16nm"),
    detnet_fps=tuple(float(f) for f in range(5, 65, 5)),
    keynet_fps=(30.0, 45.0),
    num_cameras=(2.0, 4.0),
)
CHUNK = 97
TOP_K = 4


def _request(**kw):
    kw.setdefault("grid", GRID)
    kw.setdefault("track", "all")
    kw.setdefault("chunk_size", CHUNK)
    kw.setdefault("top_k", TOP_K)
    return SweepRequest(**kw)


def _assert_bitwise(res, ref):
    assert res.min_val == ref.min_val
    assert res.min_idx == ref.min_idx
    assert res.finite_counts == ref.finite_counts
    assert np.array_equal(res.topk_idx, ref.topk_idx)
    assert np.array_equal(res.topk_val, ref.topk_val)
    assert np.array_equal(res.front_indices, ref.front_indices)
    assert np.array_equal(res.front_values, ref.front_values)


# ---------------------------------------------------------------------------
# Framing and addressing (no server)
# ---------------------------------------------------------------------------


class TestFraming:
    def _pair(self):
        return socket.socketpair()

    def test_round_trip_including_non_finite(self):
        a, b = self._pair()
        try:
            msg = {"op": "x", "v": [1.5, float("nan"), float("inf")],
                   "s": "naïve"}
            a.sendall(transport.encode_frame(msg))
            out = transport.read_frame(b)
            assert out["op"] == "x" and out["s"] == "naïve"
            assert out["v"][0] == 1.5
            assert np.isnan(out["v"][1]) and np.isinf(out["v"][2])
        finally:
            a.close(), b.close()

    def test_clean_eof_returns_none(self):
        a, b = self._pair()
        a.close()
        try:
            assert transport.read_frame(b) is None
        finally:
            b.close()

    def test_torn_frame_raises_connection_error(self):
        a, b = self._pair()
        try:
            frame = transport.encode_frame({"op": "x"})
            a.sendall(frame[: len(frame) - 2])
            a.close()
            with pytest.raises(ConnectionError):
                transport.read_frame(b)
        finally:
            b.close()

    def test_oversized_announcement_rejected_before_allocation(self):
        a, b = self._pair()
        try:
            a.sendall(transport._LEN.pack(2 ** 31))
            with pytest.raises(ConnectionError, match="cap"):
                transport.read_frame(b, max_frame=1024)
        finally:
            a.close(), b.close()

    def test_encode_rejects_oversized_payload(self):
        with pytest.raises(ValueError, match="exceeds"):
            transport.encode_frame(
                {"blob": "x" * (transport.MAX_FRAME + 1)})

    def test_parse_address(self):
        assert transport.parse_address("127.0.0.1:9000") == \
            ("tcp", "127.0.0.1", 9000)
        assert transport.parse_address(":9000") == \
            ("tcp", "127.0.0.1", 9000)
        assert transport.parse_address("/tmp/x.sock") == \
            ("unix", "/tmp/x.sock", None)
        assert transport.parse_address("./rel.sock") == \
            ("unix", "./rel.sock", None)


# ---------------------------------------------------------------------------
# Live server over a Unix socket (one service per module — compile once)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def served(tmp_path_factory):
    sock = str(tmp_path_factory.mktemp("net") / "svc.sock")
    svc = SweepService(capacity=8, snapshot_every_s=0.0)
    svc.set_tenant("capped", weight=1.0, max_pending=1)
    server = SweepServer(svc, unix_path=sock, heartbeat_s=0.1,
                         own_service=True).start()
    yield server
    server.close(drain=False, timeout=10.0)


@pytest.fixture(scope="module")
def solo():
    return stream.stream_grid(**GRID, track="all", chunk_size=CHUNK,
                              top_k=TOP_K)


@pytest.fixture()
def client(served):
    with SweepClient(served.address, reconnect_timeout_s=10.0) as cli:
        yield cli


class TestNetworkedService:
    def test_ping_and_health(self, client):
        out = client.ping()
        assert out["pong"] is True
        assert out["protocol"] == transport.PROTOCOL
        assert "counters" in client.health()

    def test_result_is_bitwise_identical_with_snapshots(self, client,
                                                        solo):
        snaps = []
        t = client.submit(_request())
        res = t.result(timeout=600, on_progress=snaps.append)
        _assert_bitwise(res, solo)
        assert not res.partial
        assert len(snaps) >= 1
        fracs = [s["fraction_complete"] for s in snaps]
        assert fracs == sorted(fracs)          # consistent prefix only
        assert all(0.0 < f <= 1.0 for f in fracs)
        assert all("best" in s and s["partial"] for s in snaps)

    def test_resubmit_same_client_id_dedupes(self, client):
        t1 = client.submit(_request(), client_id="idem-1")
        t2 = client.submit(_request(), client_id="idem-1")
        assert t1.id == t2.id
        res1 = t1.result(timeout=600)
        res2 = t2.result(timeout=600)
        _assert_bitwise(res1, res2)
        assert client.health()["counters"]["deduped"] >= 1

    def test_same_client_id_different_request_rejected(self, client):
        client.submit(_request(), client_id="idem-2")
        with pytest.raises(ValueError, match="already used"):
            client.submit(_request(top_k=TOP_K + 1),
                          client_id="idem-2")

    def test_unknown_ticket_is_not_found(self, client):
        with pytest.raises(RemoteError) as ei:
            client.status("nope-404")
        assert ei.value.kind == "not_found"

    def test_unknown_op_is_bad_request(self, served):
        s = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        s.connect(served.address)
        try:
            transport.client_handshake(s)  # consume the greeting
            s.sendall(transport.encode_frame({"op": "frobnicate",
                                              "rid": "r1"}))
            out = transport.read_frame(s)
            assert out["error"] == "bad_request"
            assert out["rid"] == "r1"
        finally:
            s.close()

    def test_backpressure_fields_survive_the_wire(self, client,
                                                  served):
        served.service.pause()
        try:
            # tenant "capped" allows one pending request; the second
            # must reject naming the tenant with a retry hint.
            ok = client.submit(_request(tenant="capped"),
                               client_id="bp-1")
            with pytest.raises(BackpressureError) as ei:
                client.submit(_request(tenant="capped",
                                       chunk_size=CHUNK + 3),
                              client_id="bp-2")
            err = ei.value
            assert err.tenant == "capped"
            assert err.queue_depth == 1 and err.capacity == 1
            assert err.retry_after_s is not None
            assert "retry after" in str(err)
            client.cancel(ok.id)
        finally:
            served.service.resume()

    def test_client_reconnects_transparently(self, client):
        assert client.ping()["pong"] is True
        # Sever the connection behind the client's back; the next call
        # must reconnect and succeed without surfacing an error.
        client._sock.shutdown(socket.SHUT_RDWR)
        client._sock.close()
        assert client.ping()["pong"] is True
        assert client.counters["reconnects"] >= 2

    def test_watch_streams_deltas_and_counts_wire_bytes(self, served):
        """After the first full snapshot a watch ships per-chunk
        deltas; the client reassembles full snapshots from them, and
        both sides account the wire bytes."""
        with SweepClient(served.address) as cli:
            snaps = []
            # chunk 31 -> 7 dispatch steps: several progress frames, so
            # at least one must ride the delta encoding.
            t = cli.submit(_request(chunk_size=31))
            res = t.result(timeout=600, on_progress=snaps.append)
            assert not res.partial
            assert len(snaps) >= 2
            for s in snaps:     # every reassembled snap is *full*
                assert {"fraction_complete", "front_size", "partial",
                        "best", "front"} <= set(s)
            fracs = [s["fraction_complete"] for s in snaps]
            assert fracs == sorted(fracs)
            assert res.stats["watch_wire_bytes"] > 0
            tr = cli.health()["transport"]
            assert tr["watch_snapshot_bytes"] > 0
            assert tr["watch_delta_bytes"] > 0
            assert tr["bytes_out"] > tr["bytes_in"] > 0

    def test_watch_timeout_is_a_timeout_not_a_disconnect(self, client,
                                                         served):
        served.service.pause()
        try:
            t = client.submit(_request(chunk_size=CHUNK + 5),
                              client_id="slow-1")
            with pytest.raises(TimeoutError):
                t.result(timeout=0.3)
            # The connection survived: an immediate ping reuses it.
            before = client.counters["reconnects"]
            client.ping()
            assert client.counters["reconnects"] == before
            client.cancel(t.id)
        finally:
            served.service.resume()


# ---------------------------------------------------------------------------
# Chaos: SIGKILL the listening server mid-request (the chaos gate)
# ---------------------------------------------------------------------------


class TestServerKillReconnect:
    """SIGKILL a listening server while a connected client waits on a
    result; a fresh server over the same spool + socket must let the
    client reconnect, dedupe its idempotent resubmit onto the recovered
    ticket, resume from the checkpoint and deliver the bitwise solo
    answer."""

    @staticmethod
    def _start_server(sock_path: str, spool: str):
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + " --xla_force_host_platform_device_count=1")
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.join(os.path.dirname(__file__), "..", "src")]
            + env.get("PYTHONPATH", "").split(os.pathsep))
        proc = subprocess.Popen(
            [sys.executable, "-m", "repro.service",
             "--unix", sock_path, "--spool", spool,
             "--checkpoint-every-steps", "1"],
            env=env, stdout=subprocess.PIPE,
            stderr=subprocess.DEVNULL, text=True)
        ready = json.loads(proc.stdout.readline())
        assert ready["listening"] == sock_path, ready
        return proc

    def test_kill_reconnect_dedupe_bitwise(self, tmp_path):
        sock_path = str(tmp_path / "svc.sock")
        spool = str(tmp_path / "spool")
        server_a = self._start_server(sock_path, spool)
        cli = SweepClient(sock_path, reconnect_timeout_s=240.0,
                          heartbeat_grace_s=8.0)
        # A job wide enough that the kill can never race completion:
        # 3840 configs at chunk 31 -> 124 steps, each one checkpointed
        # (fsync'd) before its progress frame goes out, so when the
        # first frame arrives the server still has seconds of work
        # left — even a heavily-loaded host can deliver the SIGKILL
        # mid-execution, and any observed progress is backed by a
        # durable checkpoint to resume from.  The solo reference runs
        # the same chunk size: this grid has near-tied front points
        # whose channel values drift by an ulp across chunk lowerings,
        # so bitwise parity is only defined lowering-for-lowering.
        kill_grid = dict(
            GRID,
            detnet_fps=tuple(float(f) for f in range(5, 65, 1)),
            keynet_fps=(30.0, 37.5, 45.0, 52.5))
        ref = stream.stream_grid(**kill_grid, track="all",
                                 chunk_size=31, top_k=TOP_K)
        ticket = cli.submit(
            _request(grid=kill_grid, chunk_size=31),
            client_id="chaos-1")
        first_id = ticket.id
        seen = {"frac": 0.0}
        box = {}

        def wait_result():
            try:
                box["res"] = ticket.result(
                    timeout=600,
                    on_progress=lambda s: seen.__setitem__(
                        "frac", s["fraction_complete"]))
            except BaseException as e:     # surfaced by the assert
                box["err"] = e

        th = threading.Thread(target=wait_result)
        th.start()
        deadline = time.monotonic() + 300
        while seen["frac"] == 0.0 and th.is_alive() \
                and time.monotonic() < deadline:
            time.sleep(0.02)
        assert seen["frac"] > 0.0, "no progress before kill"
        server_a.kill()                    # SIGKILL: no drain, no close
        server_a.wait(30)
        server_b = self._start_server(sock_path, spool)
        try:
            th.join(600)
            assert "err" not in box, repr(box.get("err"))
            res = box["res"]
            # Idempotent dedupe: the re-attach resubmit landed on the
            # journal-recovered ticket, not a new execution.
            assert ticket.id == first_id
            assert res.stats["resumed_from_step"] > 0
            _assert_bitwise(res, ref)
            assert cli.counters["reconnects"] >= 2
        finally:
            cli.close()
            server_b.send_signal(signal.SIGTERM)
            server_b.wait(60)


# ---------------------------------------------------------------------------
# Shared-secret HMAC handshake
# ---------------------------------------------------------------------------


@pytest.fixture(scope="class")
def auth_served(tmp_path_factory):
    sock = str(tmp_path_factory.mktemp("auth") / "svc.sock")
    svc = SweepService(capacity=4, snapshot_every_s=0.0)
    server = SweepServer(svc, unix_path=sock, heartbeat_s=0.1,
                         own_service=True,
                         auth_token="open-sesame").start()
    yield server
    server.close(drain=False, timeout=10.0)


class TestAuthHandshake:
    def test_right_token_is_accepted(self, auth_served):
        with SweepClient(auth_served.address,
                         auth="open-sesame") as cli:
            assert cli.ping()["pong"] is True

    def test_missing_token_fails_fast_without_retry(self, auth_served):
        # A hopeless credential must not burn the reconnect budget:
        # AuthenticationError is not a ConnectionError.
        with SweepClient(auth_served.address,
                         reconnect_timeout_s=60.0) as cli:
            t0 = time.monotonic()
            with pytest.raises(AuthenticationError,
                               match="auth token"):
                cli.ping()
            assert time.monotonic() - t0 < 5.0

    def test_wrong_token_is_rejected_before_any_json_parse(
            self, auth_served):
        before = auth_served.counters["auth_failures"]
        with SweepClient(auth_served.address, auth="wrong") as cli:
            with pytest.raises(AuthenticationError, match="rejected"):
                cli.ping()
        # The server never read a frame: rejection happened at the
        # 32-byte MAC, and the failure is accounted.
        assert auth_served.counters["auth_failures"] > before

    def test_unauthenticated_frame_never_reaches_the_parser(
            self, auth_served):
        s = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        s.connect(auth_served.address)
        try:
            greeting = transport._recv_exact(
                s, 4 + 1 + transport._NONCE_LEN)
            assert greeting[:4] == transport.MAGIC
            assert greeting[4] & transport._FLAG_AUTH
            # Answer with garbage the length of a MAC, then try to
            # speak the protocol: the server hangs up instead of
            # parsing the frame.
            s.sendall(b"\x00" * transport._MAC_LEN)
            verdict = s.recv(1)
            assert verdict in (b"", b"\x00")
            # EOF, reset, or a pipe broken mid-send — never a reply
            # frame (BrokenPipeError just means the hang-up already
            # reached us before the write).
            try:
                s.sendall(transport.encode_frame({"op": "ping",
                                                  "rid": "r1"}))
                assert transport.read_frame(s) is None
            except ConnectionError:
                pass
        finally:
            s.close()


# ---------------------------------------------------------------------------
# Hedged submit across replicas (idempotent dedup)
# ---------------------------------------------------------------------------


class TestHedgedSubmit:
    def test_hedged_legs_dedupe_onto_one_execution(self, tmp_path,
                                                   solo):
        svc = SweepService(capacity=8, snapshot_every_s=0.0)
        sa = str(tmp_path / "a.sock")
        sb = str(tmp_path / "b.sock")
        server_a = SweepServer(svc, unix_path=sa,
                               heartbeat_s=0.1).start()
        server_b = SweepServer(svc, unix_path=sb,
                               heartbeat_s=0.1).start()
        try:
            with SweepClient([sa, sb]) as cli:
                t = cli.submit(_request(), client_id="hedge-1",
                               hedge_s=0.0)
                res = t.result(timeout=600)
                _assert_bitwise(res, solo)
                assert cli.counters["hedged_submits"] == 1
            # Both legs raced the same client_id into one service:
            # at most one execution, the loser deduplicated.
            assert svc.counters["executions"] == 1
        finally:
            server_a.close(drain=False, timeout=10.0)
            server_b.close(drain=False, timeout=10.0)
            svc.close()

    def test_hedge_survives_a_dead_replica(self, tmp_path, solo):
        svc = SweepService(capacity=8, snapshot_every_s=0.0)
        sa = str(tmp_path / "dead.sock")     # never listening
        sb = str(tmp_path / "live.sock")
        server_b = SweepServer(svc, unix_path=sb,
                               heartbeat_s=0.1).start()
        try:
            with SweepClient([sa, sb], connect_timeout_s=1.0,
                             reconnect_timeout_s=6.0,
                             backoff_max_s=0.2) as cli:
                t = cli.submit(_request(), client_id="hedge-2",
                               hedge_s=0.05)
                # The watch also fails over: the client rotates off
                # the dead primary to the live replica.
                res = t.result(timeout=600)
                _assert_bitwise(res, solo)
                assert cli.counters["failovers"] >= 1
            assert svc.counters["executions"] == 1
        finally:
            server_b.close(drain=False, timeout=10.0)
            svc.close()
