"""Tests for the runnable hand-tracking CNNs and the latency model."""

import jax
import jax.numpy as jnp
import pytest

from repro.core import latency
from repro.core.handtracking import build_detnet, build_keynet
from repro.models.cnn import HandCNN


class TestHandCNN:
    def test_detnet_geometry_matches_table(self):
        """The executable model must have exactly the analytic MACs —
        the link between the power model's counts and real compute."""
        cnn = HandCNN.detnet()
        assert cnn.traced_macs() == build_detnet().total_macs

    def test_keynet_geometry_matches_table(self):
        cnn = HandCNN.keynet()
        assert cnn.traced_macs() == build_keynet().total_macs

    def test_detnet_runs(self):
        cnn = HandCNN.detnet()
        params = cnn.init(jax.random.key(0))
        x = jax.random.normal(jax.random.key(1), (1, 240, 320, 1)) * 0.5
        out = cnn.apply(params, x)
        # concatenated cls+box heads over the 20x15 anchor grid
        assert out.shape == (1, 20 * 15 * (6 + 24))
        assert bool(jnp.all(jnp.isfinite(out)))

    def test_keynet_runs_and_outputs_keypoints(self):
        cnn = HandCNN.keynet()
        params = cnn.init(jax.random.key(0))
        x = jax.random.normal(jax.random.key(1), (2, 96, 96, 1)) * 0.5
        out = cnn.apply(params, x)
        assert out.shape == (2, 21 * 3)     # 21 keypoints x (x, y, z)

    def test_rbe_int8_path_close_to_float(self):
        """Routing pointwise convs + FC through the int8 kernel stays
        within 8-bit quantization error of the float model."""
        cnn = HandCNN.keynet()
        params = cnn.init(jax.random.key(0))
        x = jax.random.normal(jax.random.key(1), (1, 96, 96, 1)) * 0.5
        ref = cnn.apply(params, x, use_rbe_int8=False)
        got = cnn.apply(params, x, use_rbe_int8=True)
        rel = float(jnp.linalg.norm(got - ref)
                    / jnp.maximum(jnp.linalg.norm(ref), 1e-9))
        assert rel < 0.15, rel

    def test_param_count_matches_table(self):
        cnn = HandCNN.detnet()
        params = cnn.init(jax.random.key(0))
        n_w = sum(p["w"].size for p in params)
        assert n_w == build_detnet().total_weight_bytes  # 8-bit: 1 B/param


class TestLatency:
    def test_distributed_cuts_readout_latency(self):
        """Paper claim (2): uTSV readout is ~200x faster than MIPI."""
        c = latency.centralized_latency()
        d = latency.distributed_latency()
        assert d.t_readout < c.t_readout / 100

    def test_distributed_total_latency_lower(self):
        """Paper §1: latency benefits of the DOSC architecture."""
        r = latency.latency_comparison()
        assert r["distributed_ms"] < r["centralized_ms"]
        assert r["_saving"] > 0

    def test_latency_breakdown_sums(self):
        c = latency.centralized_latency()
        assert c.total == pytest.approx(
            c.t_expose + c.t_readout + c.t_detnet + c.t_comm_roi
            + c.t_queue + c.t_keynet)

    def test_queue_is_the_structural_win(self):
        """The aggregator queue shrinks from N x (det+key) to N x key."""
        r = latency.latency_comparison()
        assert r["_queue_saving_ms"] > r["_readout_saving_ms"]

    def test_slower_sensor_node_still_latency_competitive(self):
        d16 = latency.distributed_latency(sensor_node="16nm")
        c = latency.centralized_latency()
        # 16nm sensors are slower but the readout win keeps total below
        # centralized + one frame period
        assert d16.total < c.total + 1 / 30
