"""Integration test of the dry-run machinery on a small 8-device mesh.

Runs in a SUBPROCESS with ``XLA_FLAGS=--xla_force_host_platform_device_
count=8`` so the main pytest process keeps seeing 1 device (per the
assignment: only the dry-run may fake the device count).
"""

import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced_config
from repro.core import hlo_cost
from repro.launch import partitioning as pt, specs, steps
from repro.launch.mesh import make_debug_mesh
from repro.models import transformer as T
from repro.models.transformer import Batch
from repro.optim import adamw

assert len(jax.devices()) == 8
mesh = make_debug_mesh(data=2, model=4)
out = {}

for arch in ["qwen2-0.5b", "arctic-480b", "jamba-v0.1-52b",
             "deepseek-v2-236b", "gemma2-2b", "xlstm-350m"]:
    cfg = get_reduced_config(arch, d_model=64, vocab_size=256)
    key = jax.random.key(0)
    params = T.init_params(cfg, key)
    toks = jax.random.randint(key, (4, 32), 0, cfg.vocab_size)
    batch = Batch(tokens=toks, labels=toks)

    # unsharded reference loss
    ref = float(T.loss_fn(cfg, params, batch))

    with jax.set_mesh(mesh):
        p_shapes = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
        p_shard = pt.params_shardings(mesh, p_shapes)
        b_shard = pt.batch_spec(mesh, jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch))
        params_s = jax.tree.map(jax.device_put, params, p_shard)
        batch_s = jax.tree.map(jax.device_put, batch, b_shard)
        fn = jax.jit(lambda p, b: T.loss_fn(cfg, p, b),
                     in_shardings=(p_shard, b_shard))
        got = float(fn(params_s, batch_s))
        # collect collectives to prove the program is actually distributed
        txt = fn.lower(p_shapes, jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
            batch)).compile().as_text()
        cost = hlo_cost.analyze(txt)
    out[arch] = {
        "ref": ref, "sharded": got,
        "rel_err": abs(got - ref) / max(abs(ref), 1e-9),
        "has_collectives": bool(cost.collectives.ops),
    }

print("RESULT_JSON:" + json.dumps(out))
"""


@pytest.fixture(scope="module")
def results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = next(l for l in proc.stdout.splitlines()
                if l.startswith("RESULT_JSON:"))
    return json.loads(line[len("RESULT_JSON:"):])


class TestShardedExecution:
    def test_all_archs_ran(self, results):
        assert len(results) == 6

    @pytest.mark.parametrize("arch", [
        "qwen2-0.5b", "arctic-480b", "jamba-v0.1-52b",
        "deepseek-v2-236b", "gemma2-2b", "xlstm-350m"])
    def test_sharded_loss_matches_unsharded(self, results, arch):
        """The distributed program must compute the same loss as the
        single-device program (up to bf16 reduction-order noise)."""
        r = results[arch]
        assert r["rel_err"] < 2e-2, r

    def test_programs_are_actually_distributed(self, results):
        assert any(r["has_collectives"] for r in results.values())
