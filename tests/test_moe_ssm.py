"""Correctness tests for MoE routing/dispatch and the SSM blocks."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import moe as M
from repro.models import ssm
from repro.models.common import ModelConfig, MoEConfig


def _moe_cfg(e=8, k=2, cf=64.0, shared=0, residual=False):
    return ModelConfig(
        name="moe-test", family="moe", num_layers=2, d_model=32,
        num_heads=4, num_kv_heads=4, d_ff=64, vocab_size=64,
        dtype="float32",
        moe=MoEConfig(num_experts=e, top_k=k, d_ff_expert=48,
                      capacity_factor=cf, num_shared_experts=shared,
                      dense_residual=residual))


class TestMoE:
    def test_matches_dense_oracle_when_dropless(self):
        cfg = _moe_cfg(cf=64.0)
        params = M.moe_init(jax.random.key(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.key(1), (2, 16, 32))
        got, aux = M.moe_apply(cfg, params, x)
        want = M.moe_ref(cfg, params, x)
        np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-4)
        assert aux.shape == ()

    def test_shared_and_residual_branches(self):
        cfg = _moe_cfg(shared=2, residual=True)
        params = M.moe_init(jax.random.key(0), cfg, jnp.float32)
        assert "shared" in params and "residual" in params
        x = jax.random.normal(jax.random.key(1), (2, 16, 32))
        got, _ = M.moe_apply(cfg, params, x)
        want = M.moe_ref(cfg, params, x)
        np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-4)

    def test_capacity_drops_reduce_output(self):
        """With capacity factor ~0 every token is dropped -> routed output
        contribution becomes zero."""
        cfg = _moe_cfg(cf=64.0)
        params = M.moe_init(jax.random.key(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.key(1), (1, 8, 32))
        full, _ = M.moe_apply(cfg, params, x)
        tiny = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=1e-9))
        # capacity floor is 1 slot, so not exactly zero — but must differ
        dropped, _ = M.moe_apply(tiny, params, x)
        assert float(jnp.max(jnp.abs(full - dropped))) > 1e-4

    def test_grads_flow_to_router_and_experts(self):
        cfg = _moe_cfg()
        params = M.moe_init(jax.random.key(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.key(1), (2, 16, 32))

        def loss(p):
            y, aux = M.moe_apply(cfg, p, x)
            return jnp.sum(y ** 2) + aux

        g = jax.grad(loss)(params)
        assert float(jnp.sum(jnp.abs(g["router"]))) > 0
        assert float(jnp.sum(jnp.abs(g["w_up"]))) > 0
        assert float(jnp.sum(jnp.abs(g["w_down"]))) > 0

    def test_aux_loss_prefers_balance(self):
        """Uniform routing should give a lower aux loss than collapsed."""
        cfg = _moe_cfg(e=4, k=1)
        params = M.moe_init(jax.random.key(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.key(5), (4, 64, 32))
        _, aux_rand = M.moe_apply(cfg, params, x)
        # collapse the router to one expert
        p2 = dict(params)
        p2["router"] = jnp.zeros_like(params["router"]).at[:, 0].set(10.0)
        _, aux_collapsed = M.moe_apply(cfg, p2, x)
        assert float(aux_collapsed) > float(aux_rand)


def _ssm_cfg(kind="mamba"):
    return ModelConfig(
        name="ssm-test", family="ssm", num_layers=2, d_model=32,
        num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=64,
        dtype="float32", block_pattern=(kind,),
        ssm_state_dim=8, ssm_conv_width=4, ssm_expand=2)


class TestMamba:
    def test_decode_matches_forward(self):
        cfg = _ssm_cfg("mamba")
        params = ssm.mamba_init(jax.random.key(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.key(1), (2, 10, 32)) * 0.3
        full = ssm.mamba_forward(cfg, params, x)
        st = ssm.mamba_init_state(cfg, 2, jnp.float32)
        for t in range(10):
            y, st = ssm.mamba_decode(cfg, params, x[:, t:t + 1], st)
            np.testing.assert_allclose(y[:, 0], full[:, t], atol=1e-4,
                                       rtol=1e-4)

    def test_causality(self):
        """Future inputs must not affect past outputs."""
        cfg = _ssm_cfg("mamba")
        params = ssm.mamba_init(jax.random.key(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.key(1), (1, 12, 32))
        y1 = ssm.mamba_forward(cfg, params, x)
        x2 = x.at[:, 8:].set(99.0)
        y2 = ssm.mamba_forward(cfg, params, x2)
        np.testing.assert_allclose(y1[:, :8], y2[:, :8], atol=1e-5)

    def test_state_is_o1(self):
        cfg = _ssm_cfg("mamba")
        st = ssm.mamba_init_state(cfg, 2, jnp.float32)
        di = cfg.ssm_expand * cfg.d_model
        assert st.ssm.shape == (2, di, cfg.ssm_state_dim)
        assert st.conv.shape == (2, cfg.ssm_conv_width - 1, di)


class TestXLSTM:
    def test_mlstm_decode_matches_parallel(self):
        cfg = _ssm_cfg("mlstm")
        params = ssm.mlstm_init(jax.random.key(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.key(1), (2, 8, 32)) * 0.3
        full = ssm.mlstm_block_forward(cfg, params, x)
        st = ssm.mlstm_init_state(cfg, 2)
        for t in range(8):
            y, st = ssm.mlstm_block_decode(cfg, params, x[:, t:t + 1], st)
            np.testing.assert_allclose(y[:, 0], full[:, t], atol=1e-3,
                                       rtol=1e-3)

    def test_mlstm_blockwise_block_size_invariance(self):
        cfg = _ssm_cfg("mlstm")
        params = ssm.mlstm_init(jax.random.key(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.key(1), (2, 32, 32)) * 0.3
        di, h, hd = ssm._mlstm_dims(cfg)
        up = x @ params["w_up"]
        xin, _ = jnp.split(up, 2, axis=-1)
        q = (xin @ params["w_q"]).reshape(2, 32, h, hd)
        k = (xin @ params["w_k"]).reshape(2, 32, h, hd)
        v = (xin @ params["w_v"]).reshape(2, 32, h, hd)
        x32 = xin.astype(jnp.float32)
        li = x32 @ params["w_ig"] + params["b_ig"]
        lf = jax.nn.log_sigmoid(x32 @ params["w_fg"] + params["b_fg"])
        a = ssm.mlstm_parallel(q, k, v, li, lf, q_block=8, kv_block=8)
        b = ssm.mlstm_parallel(q, k, v, li, lf, q_block=32, kv_block=32)
        np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)

    def test_slstm_decode_matches_forward(self):
        cfg = _ssm_cfg("slstm")
        params = ssm.slstm_init(jax.random.key(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.key(1), (2, 8, 32)) * 0.3
        full = ssm.slstm_block_forward(cfg, params, x)
        st = ssm.slstm_init_state(cfg, 2)
        for t in range(8):
            y, st = ssm.slstm_block_decode(cfg, params, x[:, t:t + 1], st)
            np.testing.assert_allclose(y[:, 0], full[:, t], atol=1e-4,
                                       rtol=1e-4)

    def test_slstm_causality(self):
        cfg = _ssm_cfg("slstm")
        params = ssm.slstm_init(jax.random.key(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.key(1), (1, 10, 32))
        y1 = ssm.slstm_block_forward(cfg, params, x)
        y2 = ssm.slstm_block_forward(cfg, params, x.at[:, 7:].set(5.0))
        np.testing.assert_allclose(y1[:, :7], y2[:, :7], atol=1e-5)
