"""Streaming executor: exact dense parity, stacked workloads, sharding.

The streaming path (`repro.core.stream.stream_grid`) must reproduce the
dense path (`repro.core.sweep.evaluate_grid`) *exactly* — argmin, top-k,
Pareto front, and validity counts — on the 10,880-config reference grid,
across chunk sizes including ones that do not divide the grid.  Stacked
workload batches are pinned to <=1e-6 against their single-model grids
(the two lowerings may differ in the last ulp; observed ~1e-16).
"""

import os
import signal
import subprocess
import sys

import numpy as np
import pytest

from repro.core import pareto, partition, stream, sweep
from repro.core.arrays import stacked_model_arrays
from repro.core.handtracking import build_detnet, build_keynet
from repro.core.workloads import NNWorkload
from repro.runtime import FaultInjector, FaultPlan, RetryPolicy

# The 10,880-config reference grid — keep in lockstep with
# benchmarks/sweep_bench.py::GRID (pinned here rather than imported so
# the test suite stays runnable without the benchmarks tree on sys.path).
REFERENCE_GRID = dict(
    agg_nodes=("7nm", "16nm"),
    sensor_nodes=("7nm", "16nm"),
    weight_mems=("sram", "mram"),
    detnet_fps=(5.0, 10.0, 15.0, 20.0, 30.0),
    keynet_fps=(15.0, 30.0),
    num_cameras=(2, 4),
    mipi_energy_scale=(1.0, 2.0),
)

TOP_K = 4


@pytest.fixture(scope="module")
def dense():
    return sweep.evaluate_grid(**REFERENCE_GRID)


@pytest.fixture(scope="module")
def dense_front(dense):
    return pareto.pareto_front(dense)


# Chunk sizes: smaller than / close to / larger than the grid, and ones
# that do not divide 10,880 (997 is prime; 4096 leaves a remainder).
@pytest.fixture(scope="module", params=(997, 4096, 16384))
def streamed(request, dense):
    return stream.stream_grid(**REFERENCE_GRID, chunk_size=request.param,
                              top_k=TOP_K, track="all")


class TestStreamDenseParity:
    def test_grid_shape_matches(self, streamed, dense):
        assert streamed.shape == dense.shape
        assert streamed.n_configs == dense.n_configs == 10_880

    def test_argmin_exact_every_channel(self, streamed, dense):
        for field in sweep.FIELDS:
            assert streamed.argmin(field) == dense.argmin(field), field

    def test_top_k_exact(self, streamed, dense):
        for obj in streamed.objectives:
            assert streamed.top_k(obj) == dense.top_k(obj, TOP_K), obj

    def test_pareto_front_exact(self, streamed, dense_front):
        sf = streamed.pareto_front()
        assert np.array_equal(sf.indices, dense_front.indices)
        assert np.array_equal(sf.values, dense_front.values)

    def test_validity_counts_exact(self, streamed, dense):
        for field in sweep.FIELDS:
            expect = int(np.isfinite(dense.data[field]).sum())
            assert streamed.finite_counts[field] == expect, field
        # The grid mixes valid and invalid corners; both kinds exist.
        assert 0 < streamed.finite_counts["avg_power"] < streamed.n_configs

    def test_channel_bounds_exact(self, streamed, dense):
        for field in sweep.FIELDS:
            assert streamed.channel_bounds(field) == \
                dense.channel_bounds(field), field

    def test_hypervolume_matches_dense_default_ref(self, streamed,
                                                   dense_front):
        # channel_bounds parity makes even the default-reference
        # hypervolume identical across the two paths.
        assert streamed.pareto_front().hypervolume() == \
            pytest.approx(dense_front.hypervolume(), rel=1e-12)

    def test_config_at_roundtrip(self, streamed, dense):
        for flat in (0, 1234, streamed.n_configs - 1):
            assert streamed.config_at(flat) == dense.config_at(flat)


class TestStreamMechanics:
    def test_histograms_match_dense(self, dense):
        res = stream.stream_grid(**REFERENCE_GRID, chunk_size=777,
                                 hist_bins=16)
        for field in res.objectives:
            counts, edges = res.hist[field]
            vals = dense.data[field].ravel()
            vals = vals[np.isfinite(vals)]
            expect = np.histogram(np.clip(vals, edges[0], edges[-1]),
                                  bins=edges)[0]
            assert np.array_equal(counts, expect), field
            assert counts.sum() == vals.size

    def test_explicit_hist_ranges(self, dense):
        res = stream.stream_grid(cuts=(0, 17, 33), hist_bins=4,
                                 hist_ranges={"avg_power": (0.0, 1.0)})
        counts, edges = res.hist["avg_power"]
        assert edges[0] == 0.0 and edges[-1] == 1.0
        assert counts.sum() == 3

    def test_chunk_larger_than_grid(self, dense):
        res = stream.stream_grid(**REFERENCE_GRID, chunk_size=1 << 20)
        assert res.argmin() == dense.argmin()
        assert res.stats["n_chunks"] == 1

    def test_single_config_grid(self):
        res = stream.stream_grid(cuts=(17,))
        one = sweep.evaluate_one(17)
        assert res.n_configs == 1
        assert res.argmin()["avg_power"] == pytest.approx(one["avg_power"])

    def test_top_k_truncated_on_tiny_grids(self):
        res = stream.stream_grid(cuts=(0, 1, 2), top_k=8)
        got = res.top_k("avg_power")
        assert len(got) == 3          # fewer valid configs than k
        vals = [c["avg_power"] for c in got]
        assert vals == sorted(vals)

    def test_untracked_channel_is_informative(self):
        res = stream.stream_grid(cuts=(0, 1))
        with pytest.raises(ValueError, match="track"):
            res.argmin("camera")
        with pytest.raises(ValueError, match="objectives"):
            res.top_k("camera")

    def test_all_invalid_raises_naming_axes(self):
        res = stream.stream_grid(cuts=(1, 2), sensor_nodes=("7nm",),
                                 weight_mems=("mram",))
        with pytest.raises(ValueError, match="invalid") as ei:
            res.argmin()
        assert "mram" in str(ei.value)

    def test_maximize_objective(self, dense):
        res = stream.stream_grid(
            **REFERENCE_GRID, chunk_size=3333,
            objectives=("avg_power", "sensor_macs_per_s"),
            maximize=("sensor_macs_per_s",))
        macs = dense.data["sensor_macs_per_s"].ravel()
        best = res.top_k("sensor_macs_per_s")[0]
        assert best["sensor_macs_per_s"] == float(np.nanmax(macs))
        sf = res.pareto_front()
        assert np.isfinite(sf.values).all() and sf.size > 0

    def test_rejects_bad_parameters(self):
        with pytest.raises(ValueError, match="objective"):
            stream.stream_grid(cuts=(0,), objectives=())
        with pytest.raises(ValueError, match="unknown"):
            stream.stream_grid(cuts=(0,), objectives=("nope",))
        with pytest.raises(ValueError, match="maximize"):
            stream.stream_grid(cuts=(0,), maximize=("latency",),
                               objectives=("avg_power",))
        with pytest.raises(ValueError):
            stream.stream_grid(cuts=(99,))

    def test_memory_is_chunked_not_dense(self):
        """The streamed result retains O(front + k) state only — no
        channel array anywhere near the grid size."""
        res = stream.stream_grid(**REFERENCE_GRID, chunk_size=512)
        footprint = (res.front_values.size + res.front_indices.size
                     + res.topk_val.size + res.topk_idx.size)
        assert footprint < res.n_configs / 10
        assert not hasattr(res, "data")


class TestStackedWorkloads:
    @pytest.fixture(scope="class")
    def pairs(self):
        det, key = build_detnet(), build_keynet()
        short_key = NNWorkload(name="KeyNetShort", layers=key.layers[:4],
                               input_bytes=key.layers[0].in_act_bytes,
                               output_bytes=key.layers[3].out_act_bytes)
        return ((det, key), (det.scaled(0.5), key), (det, short_key))

    @pytest.fixture(scope="class")
    def stacked(self, pairs):
        return sweep.evaluate_grid(models=pairs, sensor_nodes=("7nm",
                                                               "16nm"),
                                   detnet_fps=(10.0, 30.0))

    def test_each_model_matches_its_single_grid(self, pairs, stacked):
        """Satellite requirement: stacked rows reproduce the single-model
        evaluate_grid to <=1e-6 (observed: bitwise on this lowering)."""
        for mi, (det, key) in enumerate(pairs):
            single = sweep.evaluate_grid(detnet=det, keynet=key,
                                         sensor_nodes=("7nm", "16nm"),
                                         detnet_fps=(10.0, 30.0))
            n_cuts = len(det.layers) + len(key.layers) + 1
            for field in sweep.FIELDS:
                a = stacked.data[field][mi, :n_cuts]
                b = single.data[field]
                both = np.isfinite(a) & np.isfinite(b)
                assert (np.isfinite(a) == np.isfinite(b)).all()
                denom = np.maximum(np.abs(b[both]), 1e-30)
                assert (np.abs(a[both] - b[both]) / denom <= 1e-6).all(), \
                    (field, mi)

    def test_padded_cuts_are_poisoned(self, pairs, stacked):
        """Cuts beyond a model's own range address padding and must NaN
        every channel (the docs/equations.md padded-cut mask)."""
        det, short_key = pairs[2]
        n_cuts = len(det.layers) + len(short_key.layers) + 1
        for field in sweep.FIELDS:
            assert np.isnan(stacked.data[field][2, n_cuts:]).all(), field

    def test_model_axis_in_result(self, stacked):
        assert list(stacked.axes)[0] == "model"
        assert stacked.axes["model"] == ("DetNet+KeyNet",
                                         "DetNetx0.5+KeyNet",
                                         "DetNet+KeyNetShort")
        best = stacked.argmin()
        assert best["model"] in stacked.axes["model"]

    def test_streamed_stack_matches_dense_stack(self, pairs, stacked):
        res = stream.stream_grid(models=pairs, sensor_nodes=("7nm", "16nm"),
                                 detnet_fps=(10.0, 30.0), chunk_size=97)
        for obj in res.objectives:
            d, s = stacked.argmin(obj), res.argmin(obj)
            assert {k: v for k, v in d.items() if k != obj} == \
                {k: v for k, v in s.items() if k != obj}
            assert s[obj] == pytest.approx(d[obj], rel=1e-12)
        assert res.finite_counts["avg_power"] == \
            int(np.isfinite(stacked.avg_power).sum())
        # The two lowerings of a *stacked* batch may differ in the last
        # ulp (single-model grids are pinned exactly in
        # TestStreamDenseParity), which can flip near-tie front
        # membership — compare fronts semantically instead: per-member
        # channel values and the dominated hypervolume.
        sf = res.pareto_front()
        df = pareto.pareto_front(stacked)
        for flat, vals in zip(sf.indices, sf.values):
            dvals = [float(stacked.data[o].ravel()[flat])
                     for o in res.objectives]
            np.testing.assert_allclose(vals, dvals, rtol=1e-9)
        ref = {o: stacked.channel_bounds(o)[1] * 1.01
               for o in res.objectives}
        assert sf.hypervolume(ref) == pytest.approx(df.hypervolume(ref),
                                                    rel=1e-6)

    def test_stacked_model_arrays_validation(self, pairs):
        S = stacked_model_arrays(pairs)
        assert S.n_models == 3
        assert S.n_cuts.tolist() == [34, 34, 23]   # det 18 + key 4 + 1
        assert S.n_cuts_max == 34
        with pytest.raises(ValueError):
            stacked_model_arrays(())

    def test_models_exclusive_with_single_model_args(self, pairs):
        with pytest.raises(ValueError, match="models"):
            sweep.evaluate_grid(models=pairs, detnet=build_detnet())


class TestShardedStream:
    def test_pmap_sharding_matches_dense(self):
        """Force 4 host devices in a subprocess and pin the pmap-sharded
        stream to the dense result (argmin + top-k + front, exact)."""
        code = """
import numpy as np
from repro.core import pareto, stream, sweep
GRID = dict(agg_nodes=("7nm","16nm"), sensor_nodes=("7nm","16nm"),
            weight_mems=("sram","mram"), detnet_fps=(5.,10.,30.))
dense = sweep.evaluate_grid(**GRID)
res = stream.stream_grid(**GRID, chunk_size=64)
assert res.n_devices == 4, res.n_devices
assert all(res.argmin(f) == dense.argmin(f) for f in res.objectives)
assert all(res.top_k(o) == dense.top_k(o, 4) for o in res.objectives)
df = pareto.pareto_front(dense); sf = res.pareto_front()
assert np.array_equal(df.indices, sf.indices)
assert np.array_equal(df.values, sf.values)
print("SHARDED-OK")
"""
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + " --xla_force_host_platform_device_count=4")
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.join(os.path.dirname(__file__), "..", "src")]
            + env.get("PYTHONPATH", "").split(os.pathsep))
        out = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, timeout=600)
        assert out.returncode == 0, out.stderr[-2000:]
        assert "SHARDED-OK" in out.stdout


class TestOptimalPartitionRouting:
    def test_sequence_knobs_search_the_grid(self):
        best = partition.optimal_partition(sensor_node=("7nm", "16nm"),
                                           detnet_fps=(5.0, 10.0, 30.0))
        grid = sweep.evaluate_grid(sensor_nodes=("7nm", "16nm"),
                                   detnet_fps=(5.0, 10.0, 30.0))
        win = grid.argmin()
        assert best.cut == win["cut"]
        assert best.avg_power == pytest.approx(win["avg_power"], rel=1e-9)

    def test_huge_spaces_route_through_streamer(self, monkeypatch):
        monkeypatch.setattr(partition, "STREAM_THRESHOLD", 8)
        via_stream = partition.optimal_partition(
            sensor_node=("7nm", "16nm"), detnet_fps=(5.0, 10.0, 30.0))
        monkeypatch.setattr(partition, "STREAM_THRESHOLD", 1 << 20)
        via_dense = partition.optimal_partition(
            sensor_node=("7nm", "16nm"), detnet_fps=(5.0, 10.0, 30.0))
        assert via_stream.cut == via_dense.cut
        assert via_stream.avg_power == via_dense.avg_power

    def test_cuts_axis_and_latency_objective(self):
        best = partition.optimal_partition(objective="latency",
                                           cuts=(0, 17, 33),
                                           sensor_node=("7nm", "16nm"))
        assert best.cut in (0, 17, 33)

    def test_scalar_call_unchanged(self):
        a = partition.optimal_partition()
        b = partition.optimal_partition(sensor_node="7nm")
        assert a.cut == b.cut and a.avg_power == b.avg_power

    def test_sequences_reject_scalar_engine(self):
        with pytest.raises(ValueError, match="array"):
            partition.optimal_partition(engine="scalar",
                                        sensor_node=("7nm", "16nm"))

    def test_multi_knob_path_keeps_mram_vehicle_guard(self):
        """Opening a sequence knob must not bypass the scalar path's
        MRAM-vehicle rejection by quietly returning the one valid
        centralized point."""
        with pytest.raises(ValueError, match="MRAM"):
            partition.optimal_partition(sensor_weight_mem="mram",
                                        sensor_node="7nm",
                                        detnet_fps=(5.0, 10.0))
        # ... but a mixed axis with at least one valid combination is a
        # legitimate grid search.
        best = partition.optimal_partition(
            sensor_weight_mem=("sram", "mram"), sensor_node="7nm")
        assert best.cut >= 0

    def test_cuts_accepts_a_generator(self):
        best = partition.optimal_partition(cuts=(c for c in (0, 17, 33)))
        assert best.cut in (0, 17, 33)

    def test_evaluate_one_rejects_sequence_knobs(self):
        with pytest.raises(ValueError, match="scalar"):
            sweep.evaluate_one(17, detnet_fps=(5.0, 30.0))

    def test_unknown_knobs_raise_not_silently_drop(self):
        """A misspelled knob (e.g. the grid API's plural spelling) must
        not be swallowed by the multi-knob search path."""
        with pytest.raises(TypeError, match="sensor_nodes"):
            partition.optimal_partition(sensor_nodes=("7nm", "16nm"))
        with pytest.raises(TypeError, match="sensro_node"):
            partition.optimal_partition(sensro_node="7nm",
                                        detnet_fps=(5.0, 10.0))


class TestAsyncPipeline:
    """Satellite: the double-buffered pipeline must change nothing —
    exact argmin/top-k/front/count parity across prefetch depths
    {0, 1, 4} (0 = fully synchronous reference path) with a non-dividing
    chunk size."""

    @pytest.fixture(scope="class", params=(0, 1, 4))
    def piped(self, request):
        return stream.stream_grid(**REFERENCE_GRID, chunk_size=997,
                                  top_k=TOP_K, track="all",
                                  prefetch=request.param)

    def test_argmin_and_topk_exact(self, piped, dense):
        for field in sweep.FIELDS:
            assert piped.argmin(field) == dense.argmin(field), field
        for obj in piped.objectives:
            assert piped.top_k(obj) == dense.top_k(obj, TOP_K), obj

    def test_front_and_counts_exact(self, piped, dense, dense_front):
        sf = piped.pareto_front()
        assert np.array_equal(sf.indices, dense_front.indices)
        assert np.array_equal(sf.values, dense_front.values)
        for field in sweep.FIELDS:
            assert piped.finite_counts[field] == \
                int(np.isfinite(dense.data[field]).sum()), field

    def test_prefetch_recorded_in_stats(self, piped):
        assert piped.stats["prefetch"] in (0.0, 1.0, 4.0)
        assert "host_merge_s" in piped.stats
        assert "device_wait_s" in piped.stats

    def test_consumer_exception_reaps_producer(self, monkeypatch):
        """A host-merge failure must propagate promptly and must not
        leave the producer thread wedged in q.put."""
        import threading

        def boom(*a, **k):
            raise RuntimeError("merge exploded")

        monkeypatch.setattr(stream, "_merge_into_front", boom)
        with pytest.raises(RuntimeError, match="merge exploded"):
            stream.stream_grid(**REFERENCE_GRID, chunk_size=997,
                               prefetch=2)
        assert not [t for t in threading.enumerate()
                    if t.name == "stream-producer" and t.is_alive()]


class TestConstraints:
    """Satellite: device-masked constraint predicates must equal a host
    post-filter of the dense grid (``SweepResult.constrain``) exactly."""

    @pytest.fixture(scope="class")
    def budgets(self, dense):
        return {
            "latency":
                float(np.nanquantile(dense.data["latency"], 0.4)),
            "mipi_bytes_per_s":
                ("<=",
                 float(np.nanquantile(dense.data["mipi_bytes_per_s"],
                                      0.7))),
        }

    @pytest.fixture(scope="class")
    def constrained(self, budgets):
        return stream.stream_grid(**REFERENCE_GRID, chunk_size=997,
                                  constraints=budgets, prefetch=4)

    @pytest.fixture(scope="class")
    def dense_constrained(self, dense, budgets):
        return dense.constrain(budgets)

    def test_front_matches_host_postfilter(self, constrained,
                                           dense_constrained):
        df = pareto.pareto_front(dense_constrained)
        sf = constrained.pareto_front()
        assert np.array_equal(df.indices, sf.indices)
        assert np.array_equal(df.values, sf.values)

    def test_argmin_topk_bounds_feasible_only(self, constrained,
                                              dense_constrained):
        for obj in constrained.objectives:
            assert constrained.argmin(obj) == dense_constrained.argmin(obj)
            assert constrained.top_k(obj) == \
                dense_constrained.top_k(obj, 4), obj
            assert constrained.channel_bounds(obj) == \
                dense_constrained.channel_bounds(obj), obj

    def test_feasible_counts_exact(self, constrained, dense_constrained):
        for obj in constrained.objectives:
            expect = int(np.isfinite(dense_constrained.data[obj]).sum())
            assert constrained.finite_counts[obj] == expect, obj
        n = constrained.n_configs
        assert 0 < constrained.finite_counts["avg_power"] < n

    def test_constraint_channels_tracked_automatically(self):
        res = stream.stream_grid(cuts=(0, 17, 33),
                                 objectives=("avg_power",),
                                 constraints={"latency": 1.0})
        assert "latency" in res.min_val     # auto-tracked for the mask
        assert res.constraints == (("latency", "<=", 1.0),)

    def test_spec_forms_equivalent(self):
        a = sweep.parse_constraints({"latency": 1e-3})
        b = sweep.parse_constraints([("latency", "<=", 1e-3)])
        c = sweep.parse_constraints(["latency <= 1e-3"])
        assert a == b == c == (("latency", "<=", 0.001),)
        assert sweep.parse_constraints(None) == ()

    def test_rejects_bad_specs(self):
        with pytest.raises(ValueError, match="unknown constraint"):
            sweep.parse_constraints({"nope": 1.0})
        with pytest.raises(ValueError, match="op"):
            sweep.parse_constraints([("latency", "==", 1.0)])
        with pytest.raises(ValueError, match="parse"):
            sweep.parse_constraints(["latency ?? 3"])

    def test_all_infeasible_raises_naming_constraints(self):
        res = stream.stream_grid(cuts=(0, 1, 2),
                                 constraints={"latency": -1.0})
        with pytest.raises(ValueError, match="constraint"):
            res.argmin()
        with pytest.raises(ValueError, match="constraint"):
            res.channel_bounds("avg_power")

    def test_optimal_partition_constraint_plumbing(self, dense, budgets):
        best = partition.optimal_partition(
            sensor_node=("7nm", "16nm"),
            constraints={"latency": budgets["latency"]})
        grid = sweep.evaluate_grid(sensor_nodes=("7nm", "16nm"))
        win = grid.constrain({"latency": budgets["latency"]}).argmin()
        assert best.cut == win["cut"]
        assert best.latency <= budgets["latency"]

    def test_optimal_partition_infeasible_raises(self):
        with pytest.raises(ValueError, match="constraint"):
            partition.optimal_partition(constraints={"latency": -1.0})
        with pytest.raises(ValueError, match="constraint"):
            partition.optimal_partition(detnet_fps=(5.0, 10.0),
                                        constraints={"latency": -1.0})


class TestSurvivorOverflowFallback:
    def test_tiny_cap_forces_exact_host_fallback(self, dense_front,
                                                 monkeypatch):
        """A survivor-capacity overflow must fall back to an exact host
        re-derivation of the chunk, never silently truncate the front."""
        monkeypatch.setattr(stream, "_SURVIVOR_CAP", 8)
        res = stream.stream_grid(**REFERENCE_GRID, chunk_size=2048)
        assert res.stats["fallback_chunks"] > 0
        sf = res.pareto_front()
        assert np.array_equal(sf.indices, dense_front.indices)
        assert np.array_equal(sf.values, dense_front.values)


class TestDecodeHelper:
    def test_roundtrip_against_unravel_index(self):
        shape = (3, 5, 2, 7)
        flat = np.arange(np.prod(shape))
        ours = sweep.decode_flat_index(shape, flat)
        ref = np.unravel_index(flat, shape)
        for a, b in zip(ours, ref):
            assert np.array_equal(a, b)

    def test_scalar_decode(self):
        assert sweep.decode_flat_index((4, 6), 17) == (2, 5)

    def test_config_at_bounds(self, dense):
        with pytest.raises(IndexError):
            dense.config_at(dense.n_configs)


class TestMergeFronts:
    def test_merge_is_exact_and_order_independent(self):
        rng = np.random.default_rng(7)
        V = rng.random((300, 3))
        I = np.arange(300, dtype=np.int64)
        whole = pareto.non_dominated_mask(V)
        for cut_at in (1, 57, 150, 299):
            va, ia = pareto.merge_fronts(
                np.empty((0, 3)), np.empty(0, np.int64),
                V[:cut_at], I[:cut_at], None)
            vb, ib = pareto.merge_fronts(va, ia, V[cut_at:], I[cut_at:],
                                         None)
            assert set(ib.tolist()) == set(I[whole].tolist())

    def test_sign_orients_dominance(self):
        V = np.array([[1.0, 1.0], [2.0, 2.0]])
        _, idx_min = pareto.merge_fronts(np.empty((0, 2)),
                                         np.empty(0, np.int64),
                                         V, np.array([0, 1]), None)
        _, idx_max = pareto.merge_fronts(np.empty((0, 2)),
                                         np.empty(0, np.int64),
                                         V, np.array([0, 1]),
                                         np.array([-1.0, -1.0]))
        assert idx_min.tolist() == [0]
        assert idx_max.tolist() == [1]

    def test_length_mismatch_raises(self):
        with pytest.raises(ValueError):
            pareto.merge_fronts(np.empty((0, 2)), np.empty(0, np.int64),
                                np.ones((2, 2)), np.array([0]), None)


class _Abort(Exception):
    """Non-retryable sentinel: aborts a run without touching the retry
    or restart machinery (models an operator kill / preemption that the
    executor must *not* paper over in-process)."""


class _AbortAt:
    """Injector raising :class:`_Abort` once at a given chunk ordinal."""

    def __init__(self, ordinal: int):
        self.ordinal = ordinal
        self.fired = False

    def __call__(self, chunk_ordinal, flat_start):
        if not self.fired and chunk_ordinal >= self.ordinal:
            self.fired = True
            raise _Abort(f"aborted at chunk {chunk_ordinal}")


def _assert_full_parity(res, dense, dense_front):
    """Bitwise parity on every deliverable vs the dense reference."""
    for field in sweep.FIELDS:
        assert res.argmin(field) == dense.argmin(field), field
        assert res.finite_counts[field] == \
            int(np.isfinite(dense.data[field]).sum()), field
        assert res.channel_bounds(field) == \
            dense.channel_bounds(field), field
    for obj in res.objectives:
        assert res.top_k(obj) == dense.top_k(obj, TOP_K), obj
    sf = res.pareto_front()
    assert np.array_equal(sf.indices, dense_front.indices)
    assert np.array_equal(sf.values, dense_front.values)


class TestFaultToleranceAndResume:
    """Tentpole: checkpointed carries, retrying executor, deterministic
    fault injection.  Every recovery path must deliver *bitwise* the
    dense-path results — fault tolerance that changes answers is worse
    than none."""

    CKPT_KW = dict(chunk_size=997, top_k=TOP_K, track="all")

    def test_transient_faults_retry_to_exact_parity(self, dense,
                                                    dense_front):
        """raise-on-chunk-k plus seeded transient errors: bounded
        in-place retries must converge with untouched results."""
        inj = FaultInjector(FaultPlan(fail_chunks=(2,),
                                      transient_rate=0.2, seed=7))
        res = stream.stream_grid(**REFERENCE_GRID, **self.CKPT_KW,
                                 fault_injector=inj)
        assert inj.injected["transient"] >= 1
        assert res.stats["retries"] == inj.injected["transient"]
        _assert_full_parity(res, dense, dense_front)

    def test_retries_exhausted_raises(self):
        """A chunk that keeps failing must surface the fault, not spin."""

        class _AlwaysFail:
            def __call__(self, chunk_ordinal, flat_start):
                from repro.runtime import TransientDeviceError
                raise TransientDeviceError("permanent (injected)")

        policy = RetryPolicy(max_retries=1, max_restarts=1,
                             backoff_s=0.0)
        from repro.runtime import TransientDeviceError
        with pytest.raises(TransientDeviceError):
            stream.stream_grid(**REFERENCE_GRID, **self.CKPT_KW,
                               retry_policy=policy,
                               fault_injector=_AlwaysFail())

    def test_abort_resume_bitwise_parity(self, dense, dense_front,
                                         tmp_path):
        """Kill at an arbitrary chunk boundary; the resumed run must
        pick up from the checkpoint cursor and deliver bitwise-identical
        results."""
        ckpt = str(tmp_path / "ckpt")
        with pytest.raises(_Abort):
            stream.stream_grid(**REFERENCE_GRID, **self.CKPT_KW,
                               checkpoint_dir=ckpt,
                               checkpoint_every_steps=1,
                               fault_injector=_AbortAt(3))
        res = stream.stream_grid(**REFERENCE_GRID, **self.CKPT_KW,
                                 checkpoint_dir=ckpt,
                                 checkpoint_every_steps=1)
        assert res.stats["resumed_from_step"] > 0
        _assert_full_parity(res, dense, dense_front)

    def test_resume_mid_scan_chunks_macro_step(self, dense, dense_front,
                                               tmp_path):
        """With scan fusion one macro step covers several chunks; the
        checkpoint cursor must land on macro-step boundaries and resume
        exactly."""
        ckpt = str(tmp_path / "ckpt")
        kw = dict(chunk_size=997, scan_chunks=4, top_k=TOP_K,
                  track="all")
        with pytest.raises(_Abort):
            stream.stream_grid(**REFERENCE_GRID, **kw,
                               checkpoint_dir=ckpt,
                               checkpoint_every_steps=1,
                               fault_injector=_AbortAt(8))
        res = stream.stream_grid(**REFERENCE_GRID, **kw,
                                 checkpoint_dir=ckpt,
                                 checkpoint_every_steps=1)
        assert res.stats["resumed_from_step"] > 0
        assert res.stats["resumed_from_step"] % 4 == 0
        _assert_full_parity(res, dense, dense_front)

    def test_resume_from_completed_run(self, dense, dense_front,
                                       tmp_path):
        """The terminal snapshot makes a finished sweep re-runnable
        without recomputation and without corrupting the answers."""
        ckpt = str(tmp_path / "ckpt")
        stream.stream_grid(**REFERENCE_GRID, **self.CKPT_KW,
                           checkpoint_dir=ckpt)
        res = stream.stream_grid(**REFERENCE_GRID, **self.CKPT_KW,
                                 checkpoint_dir=ckpt)
        assert res.stats["resumed_from_step"] > 0
        _assert_full_parity(res, dense, dense_front)

    def test_stale_signature_rejected_loudly(self, tmp_path):
        """A checkpoint from a different sweep spec must fail with a
        clear error, never silently merge."""
        ckpt = str(tmp_path / "ckpt")
        stream.stream_grid(**REFERENCE_GRID, **self.CKPT_KW,
                           checkpoint_dir=ckpt)
        with pytest.raises(ValueError, match="different sweep job"):
            stream.stream_grid(**REFERENCE_GRID, chunk_size=997,
                               top_k=TOP_K + 1, track="all",
                               checkpoint_dir=ckpt)

    def test_straggler_detector_flags_injected_delay(self, dense):
        """An injected dispatch delay past the warmup window must be
        counted (trigger ordinal is after the detector's 3-sample
        warmup)."""
        inj = FaultInjector(FaultPlan(straggle={24: 1.0}))
        policy = RetryPolicy(straggler_factor=4.0, straggler_window=32)
        res = stream.stream_grid(**REFERENCE_GRID, chunk_size=256,
                                 retry_policy=policy, fault_injector=inj)
        assert inj.injected["straggle"] == 1
        assert res.stats["stragglers"] >= 1
        assert res.argmin() == dense.argmin()

    def test_stats_expose_resilience_counters(self, dense):
        res = stream.stream_grid(**REFERENCE_GRID, chunk_size=997)
        # Deterministically zero on a fault-free run without checkpoints.
        for key in ("retries", "restarts", "resumed_from_step",
                    "checkpoint_write_s", "checkpoints_written",
                    "chunks_reissued", "elastic_replans"):
            assert res.stats[key] == 0.0, key
        # Load-dependent observations: a busy CI host can legitimately
        # produce slow dispatches, so only presence is pinned.
        for key in ("stragglers", "step_timeouts"):
            assert res.stats[key] >= 0.0, key

    def test_checkpoint_counters_in_stats(self, tmp_path):
        res = stream.stream_grid(**REFERENCE_GRID, **self.CKPT_KW,
                                 checkpoint_dir=str(tmp_path / "c"),
                                 checkpoint_every_steps=2)
        assert res.stats["checkpoints_written"] >= 2
        assert res.stats["checkpoint_write_s"] > 0.0

    def test_optimal_partition_checkpoint_plumbing(self, monkeypatch,
                                                   tmp_path):
        """``optimal_partition(checkpoint_dir=...)`` must reach the
        streaming route and leave durable checkpoints behind."""
        monkeypatch.setattr(partition, "STREAM_THRESHOLD", 8)
        ckpt = str(tmp_path / "ckpt")
        best = partition.optimal_partition(
            sensor_node=("7nm", "16nm"), detnet_fps=(5.0, 10.0, 30.0),
            checkpoint_dir=ckpt, checkpoint_every_s=0.0)
        assert os.path.isdir(ckpt) and os.listdir(ckpt)
        monkeypatch.setattr(partition, "STREAM_THRESHOLD", 1 << 20)
        ref = partition.optimal_partition(
            sensor_node=("7nm", "16nm"), detnet_fps=(5.0, 10.0, 30.0))
        assert best.cut == ref.cut
        assert best.avg_power == ref.avg_power


class TestShardedFaultTolerance:
    """Recovery under pmap sharding: elastic replan on device loss, and
    SIGKILL kill-resume parity (each in a 4-host-device subprocess)."""

    @staticmethod
    def _run(code: str) -> subprocess.CompletedProcess:
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + " --xla_force_host_platform_device_count=4")
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.join(os.path.dirname(__file__), "..", "src")]
            + env.get("PYTHONPATH", "").split(os.pathsep))
        return subprocess.run([sys.executable, "-c", code], env=env,
                              capture_output=True, text=True,
                              timeout=600)

    def test_device_loss_triggers_elastic_replan(self):
        code = """
import numpy as np
from repro.core import pareto, stream, sweep
from repro.runtime import FaultInjector, FaultPlan
GRID = dict(agg_nodes=("7nm","16nm"), sensor_nodes=("7nm","16nm"),
            weight_mems=("sram","mram"), detnet_fps=(5.,10.,15.,20.,30.),
            keynet_fps=(15.,30.), num_cameras=(2,4),
            mipi_energy_scale=(1.,2.))
dense = sweep.evaluate_grid(**GRID)
inj = FaultInjector(FaultPlan(lose_device=(5, 2)))
res = stream.stream_grid(**GRID, chunk_size=256, top_k=4, track="all",
                         fault_injector=inj)
assert res.n_devices == 4, res.n_devices
assert inj.injected["device_lost"] == 1
assert res.stats["elastic_replans"] == 1.0, res.stats
assert res.stats["chunks_reissued"] > 0.0, res.stats
assert all(res.argmin(f) == dense.argmin(f) for f in res.objectives)
assert all(res.top_k(o) == dense.top_k(o, 4) for o in res.objectives)
df = pareto.pareto_front(dense); sf = res.pareto_front()
assert np.array_equal(df.indices, sf.indices)
assert np.array_equal(df.values, sf.values)
print("ELASTIC-OK")
"""
        out = self._run(code)
        assert out.returncode == 0, out.stderr[-2000:]
        assert "ELASTIC-OK" in out.stdout

    def test_sigkill_resume_bitwise_parity(self, tmp_path):
        """SIGKILL a sharded sweep mid-flight; a fresh process must
        resume from the durable snapshot and match the dense path
        bitwise."""
        ckpt = str(tmp_path / "ckpt")
        common = f"""
import numpy as np
from repro.core import pareto, stream, sweep
GRID = dict(agg_nodes=("7nm","16nm"), sensor_nodes=("7nm","16nm"),
            weight_mems=("sram","mram"), detnet_fps=(5.,10.,15.,20.,30.),
            keynet_fps=(15.,30.), num_cameras=(2,4),
            mipi_energy_scale=(1.,2.))
KW = dict(chunk_size=256, top_k=4, track="all",
          checkpoint_dir={ckpt!r}, checkpoint_every_steps=1)
"""
        kill = common + """
from repro.runtime import FaultInjector, FaultPlan
inj = FaultInjector(FaultPlan(kill_at=24))
stream.stream_grid(**GRID, **KW, fault_injector=inj)
print("UNREACHABLE")
"""
        resume = common + """
dense = sweep.evaluate_grid(**GRID)
res = stream.stream_grid(**GRID, **KW)
assert res.n_devices == 4, res.n_devices
assert res.stats["resumed_from_step"] > 0, res.stats
assert all(res.argmin(f) == dense.argmin(f) for f in res.objectives)
assert all(res.top_k(o) == dense.top_k(o, 4) for o in res.objectives)
df = pareto.pareto_front(dense); sf = res.pareto_front()
assert np.array_equal(df.indices, sf.indices)
assert np.array_equal(df.values, sf.values)
print("RESUME-OK", res.stats["resumed_from_step"])
"""
        out1 = self._run(kill)
        assert out1.returncode == -signal.SIGKILL, \
            (out1.returncode, out1.stderr[-2000:])
        assert "UNREACHABLE" not in out1.stdout
        out2 = self._run(resume)
        assert out2.returncode == 0, out2.stderr[-2000:]
        assert "RESUME-OK" in out2.stdout


class _StopAfter:
    """``should_stop`` hook returning True after ``n`` dispatches."""

    def __init__(self, n: int):
        self.n = n
        self.calls = 0

    def __call__(self) -> bool:
        self.calls += 1
        return self.calls > self.n


class TestCooperativeStop:
    """Cooperative cancellation: ``should_stop`` is polled between chunk
    dispatches; a halted run returns a *consistent prefix snapshot*
    (``partial=True``) — the exact reductions over flat configs
    ``[0, base)`` — never an error and never a torn mix of chunks."""

    KW = dict(chunk_size=997, top_k=TOP_K, track="all")

    @pytest.mark.parametrize("prefetch", (0, 2))
    def test_partial_snapshot_is_exact_prefix(self, dense, prefetch):
        res = stream.stream_grid(**REFERENCE_GRID, **self.KW,
                                 prefetch=prefetch,
                                 should_stop=_StopAfter(3))
        assert res.partial
        frac = res.stats["fraction_complete"]
        assert 0.0 < frac < 1.0
        base = round(frac * dense.data["avg_power"].size)
        assert base == 3 * 997      # stopped before the 4th dispatch
        for field in sweep.FIELDS:
            prefix = np.asarray(dense.data[field]).ravel()[:base]
            assert res.min_val[field] == float(np.nanmin(prefix)), field
            assert res.min_idx[field] == int(np.nanargmin(prefix)), field
            assert res.finite_counts[field] == \
                int(np.isfinite(prefix).sum()), field
            assert res.channel_min[field] == float(np.nanmin(prefix))
            assert res.channel_max[field] == float(np.nanmax(prefix))

    def test_never_stopping_hook_is_a_noop(self, dense, dense_front):
        res = stream.stream_grid(**REFERENCE_GRID, **self.KW,
                                 should_stop=lambda: False)
        assert not res.partial
        assert res.stats["fraction_complete"] == 1.0
        _assert_full_parity(res, dense, dense_front)

    def test_on_progress_monotonic_to_one(self):
        seen = []
        res = stream.stream_grid(**REFERENCE_GRID, **self.KW,
                                 on_progress=seen.append)
        assert seen == sorted(seen)
        assert seen[-1] == 1.0
        assert len(seen) == res.stats["n_chunks"]

    def test_partial_checkpoint_then_resume_completes(self, dense,
                                                      dense_front,
                                                      tmp_path):
        """A halted run leaves a durable snapshot at its stop cursor; a
        later run over the same checkpoint dir finishes the sweep
        bitwise-exactly."""
        ckpt = str(tmp_path / "ckpt")
        part = stream.stream_grid(**REFERENCE_GRID, **self.KW,
                                  checkpoint_dir=ckpt,
                                  checkpoint_every_steps=1,
                                  should_stop=_StopAfter(3))
        assert part.partial
        res = stream.stream_grid(**REFERENCE_GRID, **self.KW,
                                 checkpoint_dir=ckpt,
                                 checkpoint_every_steps=1)
        assert not res.partial
        assert res.stats["resumed_from_step"] == 3
        _assert_full_parity(res, dense, dense_front)

    def test_keyboard_interrupt_reaps_producer(self, monkeypatch):
        """Ctrl-C in the consumer loop must still signal and join the
        producer thread — the satellite fix for the orphaned
        ``stream-producer`` after KeyboardInterrupt."""
        import threading

        def boom(*a, **k):
            raise KeyboardInterrupt

        monkeypatch.setattr(stream, "_merge_into_front", boom)
        with pytest.raises(KeyboardInterrupt):
            stream.stream_grid(**REFERENCE_GRID, chunk_size=997,
                               prefetch=2)
        assert not [t for t in threading.enumerate()
                    if t.name == "stream-producer" and t.is_alive()]


class TestPlanReuse:
    """``plan_stream`` + ``stream_grid(plan=)``: the resolved plan is
    the service's cache currency — running through a pre-resolved plan
    must be bitwise-identical to the keyword path, and the content
    signature must be stable across resolutions."""

    def test_plan_path_bitwise_equals_keyword_path(self, dense,
                                                   dense_front):
        plan = stream.plan_stream(**REFERENCE_GRID, chunk_size=997,
                                  top_k=TOP_K, track="all")
        res = stream.stream_grid(plan=plan)
        _assert_full_parity(res, dense, dense_front)

    def test_signature_stable_across_resolutions(self):
        kw = dict(chunk_size=997, top_k=TOP_K, track="all")
        p1 = stream.plan_stream(**REFERENCE_GRID, **kw)
        p2 = stream.plan_stream(**REFERENCE_GRID, **kw)
        assert p1.signature == p2.signature
        p3 = stream.plan_stream(**REFERENCE_GRID, chunk_size=997,
                                top_k=TOP_K + 1, track="all")
        assert p3.signature != p1.signature
