"""Gradient-based knob search: jax.grad vs finite differences through the
Eq. 1-11 kernel, and projected-Adam recovery of dense-grid minima."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from repro.core import optimize, sweep
from repro.core.handtracking import build_detnet, build_keynet

N_DET = len(build_detnet().layers)
N_ALL = N_DET + len(build_keynet().layers)
CONFIG = dict(cut=N_DET, agg_node="7nm", sensor_node="16nm",
              weight_mem="sram")


def central_diff(objective, knob, x0, eps, **config):
    hi = optimize.evaluate(objective, {knob: x0 + eps}, **config)
    lo = optimize.evaluate(objective, {knob: x0 - eps}, **config)
    return (hi - lo) / (2 * eps)


class TestGradient:
    @pytest.mark.parametrize("knob,x0,eps", [
        ("mipi_energy_scale", 1.0, 1e-4),
        ("detnet_fps", 10.0, 1e-3),
        ("keynet_fps", 30.0, 1e-3),
        ("camera_fps", 30.0, 1e-3),
    ])
    def test_grad_avg_power_matches_finite_differences(self, knob, x0, eps):
        """The issue's acceptance check: d avg_power / d knob from jax.grad
        agrees with a float64 central difference."""
        _, g = optimize.gradient("avg_power", {knob: x0}, **CONFIG)
        fd = central_diff("avg_power", knob, x0, eps, **CONFIG)
        assert g[knob] == pytest.approx(fd, rel=1e-5, abs=1e-18)

    def test_grad_weighted_objective_matches_fd(self):
        obj = {"avg_power": 1.0, "latency": 10.0}
        _, g = optimize.gradient(obj, {"camera_fps": 30.0}, **CONFIG)
        fd = central_diff(obj, "camera_fps", 30.0, 1e-3, **CONFIG)
        assert g["camera_fps"] == pytest.approx(fd, rel=1e-5)

    def test_mipi_power_gradient_is_eq5_slope(self):
        """d P / d mipi_energy_scale is exactly the MIPI power at scale 1
        (Eq. 5 is linear in the energy/byte)."""
        v, g = optimize.gradient("avg_power", {"mipi_energy_scale": 1.0},
                                 **CONFIG)
        fields = optimize.evaluate_fields({"mipi_energy_scale": 1.0},
                                          **CONFIG)
        assert g["mipi_energy_scale"] == pytest.approx(fields["mipi"],
                                                       rel=1e-9)
        assert g["mipi_energy_scale"] > 0
        assert v == pytest.approx(fields["avg_power"], rel=1e-12)

    def test_gradient_default_point_respects_pinned_knobs(self):
        """gradient() with knobs omitted must evaluate at config-pinned
        knob values, not the global defaults."""
        v, _ = optimize.gradient("avg_power", cut=N_DET, detnet_fps=15.0)
        assert v == pytest.approx(
            optimize.evaluate("avg_power", cut=N_DET, detnet_fps=15.0),
            rel=1e-12)

    def test_raw_objective_fn_is_differentiable(self):
        f = optimize.objective_fn("avg_power", **CONFIG)
        with enable_x64():
            g = jax.grad(lambda s: f({"mipi_energy_scale": s}))(
                jnp.asarray(1.0))
            assert np.isfinite(float(g))


class TestProjectedAdam:
    def test_monotone_knob_rides_projection_to_bound(self):
        """Pure power is monotone in detnet_fps: the optimum sits on the
        lower bound, and the dense grid agrees."""
        res = optimize.optimize_knobs({"detnet_fps": (5.0, 30.0)},
                                      "avg_power", steps=120, **CONFIG)
        gk, gv = optimize.grid_argmin({"detnet_fps": (5.0, 30.0)},
                                      "avg_power", n=26, **CONFIG)
        assert res.knobs["detnet_fps"] == pytest.approx(5.0, abs=1e-6)
        assert res.knobs["detnet_fps"] == pytest.approx(gk["detnet_fps"],
                                                        abs=1.0)
        assert res.objective <= gv * (1 + 1e-9)

    def test_recovers_dense_grid_optimum_2d(self):
        """The acceptance criterion: gradient search lands on the dense-grid
        optimum of a weighted (power, latency) objective over two knobs, to
        within grid resolution."""
        bounds = {"detnet_fps": (5.0, 30.0), "camera_fps": (20.0, 60.0)}
        obj = {"avg_power": 1.0, "latency": 10.0}
        n = 41
        res = optimize.optimize_knobs(bounds, obj, steps=250, **CONFIG)
        gk, gv = optimize.grid_argmin(bounds, obj, n=n, **CONFIG)
        for k in bounds:
            spacing = (bounds[k][1] - bounds[k][0]) / (n - 1)
            assert abs(res.knobs[k] - gk[k]) <= spacing, (k, res.knobs, gk)
        # The continuous optimum can only improve on the grid's resolution.
        assert res.objective <= gv * (1 + 1e-9)

    def test_trajectory_improves_and_fields_consistent(self):
        res = optimize.optimize_knobs(
            {"camera_fps": (20.0, 60.0)},
            {"avg_power": 1.0, "latency": 10.0}, steps=100, **CONFIG)
        assert res.trajectory.shape == (101,)
        assert res.objective <= res.trajectory[0]
        assert res.objective == pytest.approx(
            res.fields["avg_power"] + 10.0 * res.fields["latency"],
            rel=1e-9)
        # within bounds
        assert 20.0 <= res.knobs["camera_fps"] <= 60.0

    def test_init_is_respected_and_projected(self):
        res = optimize.optimize_knobs({"detnet_fps": (5.0, 30.0)},
                                      steps=5, init={"detnet_fps": 500.0},
                                      **CONFIG)
        assert 5.0 <= res.knobs["detnet_fps"] <= 30.0


class TestValidation:
    def test_rejects_unknown_knob_objective_config(self):
        with pytest.raises(ValueError, match="unknown knobs"):
            optimize.optimize_knobs({"warp_factor": (0, 1)}, cut=N_DET)
        with pytest.raises(ValueError, match="objective channels"):
            optimize.objective_fn("speed_of_light", cut=N_DET)
        with pytest.raises(ValueError, match="unknown config"):
            optimize.objective_fn("avg_power", cut=N_DET, sensor_mem="x")
        with pytest.raises(ValueError, match="cut"):
            optimize.objective_fn("avg_power", cut=N_ALL + 5)
        with pytest.raises(ValueError, match="degenerate"):
            optimize.optimize_knobs({"detnet_fps": (5.0, 5.0)}, cut=N_DET)
        with pytest.raises(ValueError):
            optimize.optimize_knobs({}, cut=N_DET)

    def test_rejects_mram_without_test_vehicle_eagerly(self):
        with pytest.raises(ValueError, match="MRAM"):
            optimize.objective_fn("avg_power", cut=N_DET,
                                  sensor_node="7nm", weight_mem="mram")
        # ...but centralized (cut 0) never builds a sensor site
        optimize.objective_fn("avg_power", cut=0, sensor_node="7nm",
                              weight_mem="mram")

    def test_evaluate_matches_grid_engine(self):
        v = optimize.evaluate("avg_power", {"detnet_fps": 12.5}, **CONFIG)
        ref = sweep.evaluate_one(N_DET, sensor_node="16nm",
                                 detnet_fps=12.5)["avg_power"]
        assert v == pytest.approx(ref, rel=1e-12)
