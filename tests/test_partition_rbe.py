"""Tests for the partition optimizer and the RBE roofline model (Fig. 2/4)."""

import pytest

from repro.core import partition, rbe
from repro.core.constants import RBE
from repro.core.handtracking import build_detnet, build_keynet
from repro.core.workloads import LayerKind, conv2d, depthwise, pointwise


class TestRBERoofline:
    """Fig. 4: 'layer performance is almost completely bounded by the weight
    streaming'; conv near peak > pointwise > depthwise."""

    def test_kind_ordering_at_same_shape(self):
        c = conv2d("c", 40, 30, 96, 96, k=3)
        p = pointwise("p", 40, 30, 96, 96)
        d = depthwise("d", 40, 30, 96)
        mc = rbe.mac_per_cycle(c, RBE)
        mp = rbe.mac_per_cycle(p, RBE)
        md = rbe.mac_per_cycle(d, RBE)
        assert mc > mp > md

    def test_conv_near_peak(self):
        c = conv2d("c", 40, 30, 96, 96, k=3)
        assert rbe.mac_per_cycle(c, RBE) > 0.85 * RBE.peak_mac_per_cycle

    def test_never_exceeds_peak(self):
        for layer in build_detnet().layers + build_keynet().layers:
            assert rbe.mac_per_cycle(layer, RBE) <= RBE.peak_mac_per_cycle

    def test_quarter_scale_on_sensor(self):
        c = conv2d("c", 40, 30, 96, 96, k=3)
        full = rbe.mac_per_cycle(c, RBE, scale=1.0)
        quarter = rbe.mac_per_cycle(c, RBE, scale=0.25)
        assert quarter == pytest.approx(full * 0.25, rel=1e-6)

    def test_weight_stream_bound_layers_exist(self):
        """Some layers of the real workload must sit on the bandwidth roof
        (the paper's observation: 'several layers are memory-bounded by
        weight streaming')."""
        pts = (rbe.roofline_points(build_detnet())
               + rbe.roofline_points(build_keynet()))
        assert any(p.bound == "weight-stream" for p in pts)

    def test_processing_time_positive_and_sane(self):
        from repro.core.constants import NODE_16NM
        t = rbe.processing_time_s(build_detnet(), NODE_16NM, scale=0.25)
        # a sensor-class engine should take milliseconds, not seconds
        assert 1e-3 < t < 0.1


class TestPartition:
    def test_paper_split_saves_about_24pct(self):
        pts = partition.sweep_partitions()
        n_det = len(build_detnet().layers)
        saving = 1 - pts[n_det].avg_power / pts[0].avg_power
        assert saving == pytest.approx(0.24, abs=0.02)

    def test_paper_split_beats_centralized_and_full_onsensor(self):
        pts = partition.sweep_partitions()
        n_det = len(build_detnet().layers)
        paper = pts[n_det].avg_power
        assert paper < pts[0].avg_power      # beats centralized
        assert paper < pts[-1].avg_power     # beats everything-on-sensor

    def test_sweep_optimum_at_least_paper_split(self):
        """Layer-level sweep may beat the model-boundary split (a
        beyond-paper finding), but can never be worse."""
        pts = partition.sweep_partitions()
        n_det = len(build_detnet().layers)
        best = min(pts, key=lambda p: p.avg_power)
        assert best.avg_power <= pts[n_det].avg_power

    def test_mipi_traffic_monotone_through_boundary(self):
        """Crossing into the pipeline sharply cuts MIPI traffic vs
        centralized."""
        pts = partition.sweep_partitions()
        n_det = len(build_detnet().layers)
        assert pts[n_det].mipi_bytes_per_s < 0.05 * pts[0].mipi_bytes_per_s

    def test_optimal_partition_helper(self):
        best = partition.optimal_partition()
        pts = partition.sweep_partitions()
        assert best.avg_power == min(p.avg_power for p in pts)

    def test_centralized_cut_matches_system_builder(self):
        from repro.core import system
        cut0 = partition.evaluate_cut(0).avg_power
        cen = system.build_centralized("7nm").avg_power
        assert cut0 == pytest.approx(cen, rel=0.02)
