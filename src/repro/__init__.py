"""repro: Distributed On-Sensor Compute (DOSC) power-estimation framework.

A JAX/TPU production framework reproducing and extending Gomez & Patel et
al., "Distributed On-Sensor Compute System for AR/VR Devices" (tinyML'22).
"""

__version__ = "1.0.0"
