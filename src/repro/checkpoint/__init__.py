"""Atomic, resharding-tolerant checkpointing."""

from .manager import CheckpointManager  # noqa: F401
