"""Checkpointing: atomic, resharding-tolerant, retention-managed.

Layout (one directory per step):

    <root>/step_000123/
        manifest.json      # step, pytree paths, shapes, dtypes, shards
        arrays.npz         # raw little-endian buffers, one entry per leaf

Properties:
* **Atomicity** — writes land in ``step_X.tmp`` and are renamed only
  after fsync; a crash mid-save never corrupts the latest checkpoint.
* **Resharding / elasticity** — leaves are stored unsharded (gathered);
  ``restore`` device_puts them under *any* target sharding, so a job can
  restart on a different mesh shape (elastic scale-up/down).
* **dtype fidelity** — bf16 and other ml_dtypes are stored as raw bytes
  with the dtype name in the manifest (npz cannot hold bf16 natively).
* **Retention** — ``keep`` most-recent checkpoints are retained.
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


@dataclasses.dataclass
class CheckpointManager:
    root: str
    keep: int = 3

    def __post_init__(self):
        os.makedirs(self.root, exist_ok=True)
        # Sweep *.tmp debris from crashed saves (the rename is atomic,
        # so debris is the only artifact a SIGKILL can leave).  Matters
        # for long-lived spools — e.g. the sweep service's per-job
        # checkpoint dirs — where crash/restart cycles would otherwise
        # accumulate orphaned step dirs forever.  Checkpoint roots are
        # single-writer (job-signature keyed), so no live save can own
        # a tmp dir while this manager is being constructed.
        for name in os.listdir(self.root):
            if name.startswith("step_") and name.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.root, name),
                              ignore_errors=True)

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:09d}")

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.root):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    step = int(name.split("_")[1])
                except (IndexError, ValueError):
                    continue
                # A crash can only ever leave *.tmp debris (the rename
                # is atomic), but guard against foreign/truncated dirs:
                # a step without its manifest is not a checkpoint.
                if os.path.exists(os.path.join(self._step_dir(step),
                                               "manifest.json")):
                    out.append(step)
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ------------------------------------------------------------------
    def save(self, step: int, state: Any, metadata: dict | None = None
             ) -> str:
        """Atomically persist a pytree of arrays."""
        leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(
            state)
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "metadata": metadata or {},
                    "leaves": []}
        buffers = {}
        for i, (path, leaf) in enumerate(leaves_with_paths):
            arr = np.asarray(jax.device_get(leaf))
            key = f"leaf_{i:05d}"
            manifest["leaves"].append({
                "key": key,
                "path": _path_str(path),
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
            })
            # raw bytes: npz can't store ml_dtypes (bf16) natively
            buffers[key] = np.frombuffer(
                arr.tobytes(), np.uint8).reshape(-1)
        npz_path = os.path.join(tmp, "arrays.npz")
        np.savez(npz_path, **buffers)
        with open(npz_path, "rb") as f:
            os.fsync(f.fileno())
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        # fsync the parent so the rename itself survives a crash
        dfd = os.open(self.root, os.O_RDONLY)
        try:
            os.fsync(dfd)
        finally:
            os.close(dfd)
        self._enforce_retention()
        return final

    def _enforce_retention(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------
    def restore(self, step: int, like: Any, shardings: Any | None = None
                ) -> Any:
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs).  ``shardings`` (optional pytree) places leaves
        on the current mesh — pass shardings for a *different* mesh than
        the one that saved to perform an elastic reshard-restore."""
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        buffers = np.load(os.path.join(d, "arrays.npz"))
        arrays = []
        for entry in manifest["leaves"]:
            raw = buffers[entry["key"]].tobytes()
            dt = jnp.dtype(entry["dtype"])
            arr = np.frombuffer(raw, dt).reshape(entry["shape"])
            arrays.append(arr)
        leaves_like, treedef = jax.tree_util.tree_flatten(like)
        if len(arrays) != len(leaves_like):
            raise ValueError(
                f"checkpoint has {len(arrays)} leaves, target structure "
                f"has {len(leaves_like)}")
        for got, want in zip(arrays, leaves_like):
            if tuple(got.shape) != tuple(want.shape):
                raise ValueError(
                    f"shape mismatch: ckpt {got.shape} vs {want.shape}")
        if shardings is not None:
            flat_sh = treedef.flatten_up_to(shardings)
            arrays = [jax.device_put(a, s)
                      for a, s in zip(arrays, flat_sh)]
        else:
            arrays = [jnp.asarray(a) for a in arrays]
        return jax.tree_util.tree_unflatten(treedef, arrays)

    def restore_items(self, step: int) -> dict[str, np.ndarray]:
        """Restore a checkpoint as ``{path: array}`` without a like-tree.

        For callers whose state has data-dependent shapes (e.g. the
        streaming executor's Pareto-front buffers, whose row count is
        unknowable before restore): leaves come back as host numpy
        arrays keyed by their saved pytree path, with shapes and dtypes
        exactly as stored.
        """
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        buffers = np.load(os.path.join(d, "arrays.npz"))
        out: dict[str, np.ndarray] = {}
        for entry in manifest["leaves"]:
            raw = buffers[entry["key"]].tobytes()
            dt = jnp.dtype(entry["dtype"])
            out[entry["path"]] = np.frombuffer(raw, dt).reshape(
                entry["shape"])
        return out

    def metadata(self, step: int) -> dict:
        with open(os.path.join(self._step_dir(step),
                               "manifest.json")) as f:
            return json.load(f)["metadata"]
