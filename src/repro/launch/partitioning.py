"""Parameter/input/cache sharding rules for the production meshes.

Logical plan:
* batch dims ride ("pod", "data") (all pod+data axes present in the mesh);
* feature/head/expert/vocab dims ride "model" (tensor/expert parallelism);
* large weights additionally shard a second dim over "data" (FSDP-style
  2D sharding) so optimizer state for the 100B+ cells fits per-chip HBM;
* decode KV caches shard the sequence dim over "model" (and over "data"
  too when the batch can't use it, e.g. ``long_500k`` with batch 1).

Rules are name-based with a divisibility check; any dim not divisible by
its axis size is replicated (recorded by ``explain()``).
"""

from __future__ import annotations

import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

# params above this size get a second (FSDP) shard dim over "data"
FSDP_THRESHOLD_BYTES = 32 * (1 << 20)


def batch_axes(mesh) -> tuple[str, ...]:
    return tuple(n for n in ("pod", "data") if n in mesh.axis_names)


def _axsize(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
    return "/".join(parts)


# name-pattern -> index of the dim (negative = from the end) to put on
# "model".  Applied to the *unstacked* trailing dims.
_MODEL_DIM_RULES: list[tuple[str, int]] = [
    (r"embed/table$", -2),          # (V, d) -> vocab
    (r"unembed/w$", -1),            # (d, V) -> vocab
    (r"w_q$", -2), (r"w_k$", -2), (r"w_v$", -2),   # (d, H, hd) -> heads
    (r"b_q$", -2), (r"b_k$", -2), (r"b_v$", -2),   # (H, hd)
    (r"w_o$", -3),                  # (H, hd, d) -> heads
    (r"w_gate$", -1), (r"w_up$", -1),   # (d, f) / (E, d, f) -> f
    (r"w_down$", -2),               # (f, d) / (E, f, d) -> f
    (r"in_proj$", -1), (r"out_proj$", -2),         # mamba
    (r"conv_w$", -1), (r"conv_b$", -1),
    (r"w_bcdt$", -2), (r"dt_proj$", -1), (r"dt_bias$", -1),
    (r"A_log$", -2), (r"/D$", -1),
    (r"w_dq$", -1), (r"w_uq$", -2),                # MLA
    (r"w_dkv$", -1), (r"w_kr$", -1),
    (r"w_uk$", -2), (r"w_uv$", -2),
    (r"router$", None),             # replicated (tiny, fp32)
]

# MoE expert tensors: expert dim (first trailing dim) on "model".
_EXPERT_RE = re.compile(r"ffn/(w_gate|w_up|w_down)$")
_NORM_RE = re.compile(r"(norm|scale|b_ig|b_fg|b_z|b_i|b_f|b_o)")


def _stacked_prefix(path_s: str, ndim: int, shape) -> int:
    """Number of leading stack dims (scan-over-repeats) to skip."""
    return 1 if re.search(r"blocks/\d+/", path_s) else 0


def param_spec(mesh, path, leaf) -> P:
    path_s = _path_str(path)
    shape = leaf.shape
    ndim = len(shape)
    model_n = _axsize(mesh, "model")
    data_n = _axsize(mesh, "data")
    skip = _stacked_prefix(path_s, ndim, shape)
    spec: list = [None] * ndim

    if _NORM_RE.search(path_s) or ndim <= skip:
        return P(*spec)

    # --- choose the model dim ---
    model_dim: Optional[int] = None
    if _EXPERT_RE.search(path_s):
        model_dim = skip  # expert dim
    else:
        for pat, rel in _MODEL_DIM_RULES:
            if re.search(pat, path_s):
                if rel is None:
                    return P(*spec)     # explicitly replicated
                cand = ndim + rel
                if cand >= skip:
                    model_dim = cand
                break
    if model_dim is None:
        # fallback: largest trailing dim divisible by model axis
        cands = [i for i in range(skip, ndim) if shape[i] % model_n == 0]
        if cands:
            model_dim = max(cands, key=lambda i: shape[i])
    if model_dim is not None and shape[model_dim] % model_n == 0 \
            and model_n > 1:
        spec[model_dim] = "model"
    else:
        model_dim = None

    # --- FSDP second dim over "data" for large tensors ---
    size_bytes = leaf.size * jnp.dtype(leaf.dtype).itemsize
    if data_n > 1 and size_bytes >= FSDP_THRESHOLD_BYTES:
        cands = [i for i in range(skip, ndim)
                 if i != model_dim and shape[i] % data_n == 0]
        if cands:
            if _EXPERT_RE.search(path_s):
                # experts: FSDP the *contraction* dim (d for w_up/w_gate,
                # f for w_down = always the dim right after the expert
                # dim) so the partial-sum MoE path contracts locally and
                # never gathers weights (§Perf, see models/moe.py).
                fsdp_dim = min(cands)
            else:
                fsdp_dim = max(cands, key=lambda i: shape[i])
            spec[fsdp_dim] = "data"
    return P(*spec)


def params_shardings(mesh, params_shapes) -> Any:
    """PartitionSpec pytree (as NamedShardings) for a params shape-tree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, param_spec(mesh, path, leaf)),
        params_shapes)


def explain(mesh, params_shapes) -> list[str]:
    """Human-readable sharding decisions incl. replication fallbacks."""
    lines = []

    def visit(path, leaf):
        spec = param_spec(mesh, path, leaf)
        lines.append(f"{_path_str(path):60s} {str(leaf.shape):24s} "
                     f"-> {spec}")
        return leaf

    jax.tree_util.tree_map_with_path(visit, params_shapes)
    return lines


# ---------------------------------------------------------------------------
# Inputs / caches
# ---------------------------------------------------------------------------


def batch_pspec(mesh, batch_shapes) -> Any:
    """Raw PartitionSpecs for a Batch (tokens/embeds/positions/labels)."""
    baxes = batch_axes(mesh)
    full = 1
    for a in baxes:
        full *= mesh.shape[a]

    def spec_for(path, leaf):
        path_s = _path_str(path)
        nd = len(leaf.shape)
        if nd == 0:
            return P()
        if "positions" in path_s and nd == 3:   # (3, B, S)
            ok = leaf.shape[1] % full == 0
            return P(None, baxes if ok else None, None)
        spec = [None] * nd
        if leaf.shape[0] % full == 0:
            spec[0] = baxes
        return P(*spec)

    return jax.tree_util.tree_map_with_path(spec_for, batch_shapes)


def batch_spec(mesh, batch_shapes) -> Any:
    """NamedShardings for a Batch."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        batch_pspec(mesh, batch_shapes))


def cache_spec(mesh, cache_shapes, global_batch: int) -> Any:
    """Decode-cache shardings.

    Caches are stacked (R, B, ...) pytrees.  The batch dim shards over the
    batch axes when divisible; the sequence dim of KV caches shards over
    "model" (plus any batch axes the batch couldn't use — the ``long_500k``
    batch=1 case).  SSM states shard their feature dim over "model".
    """
    baxes = batch_axes(mesh)
    # batch shardable only if divisible by the full batch-axes product
    full = 1
    for a in baxes:
        full *= mesh.shape[a]
    batch_ok = global_batch % full == 0
    leftover = () if batch_ok else baxes   # give unused axes to seq dim

    def spec_for(path, leaf):
        path_s = _path_str(path)
        shape = leaf.shape
        nd = len(shape)
        skip = 1 if re.search(r"blocks/\d+", path_s) else 0
        spec = [None] * nd
        if nd <= skip:
            return NamedSharding(mesh, P(*spec))
        if batch_ok:
            spec[skip] = baxes
        # KV caches: (R, B, S, KV, hd) / MLA (R, B, S, r): shard S
        is_kv = nd - skip >= 3 and shape[skip + 1] > 1024
        if is_kv:
            seq_axes = tuple(leftover) + ("model",)
            n = 1
            for a in seq_axes:
                n *= mesh.shape[a]
            if shape[skip + 1] % n == 0:
                spec[skip + 1] = seq_axes
        else:
            # SSM state: shard the largest model-divisible trailing dim
            model_n = _axsize(mesh, "model")
            cands = [i for i in range(skip + 1, nd)
                     if shape[i] % model_n == 0 and shape[i] >= model_n]
            if cands and model_n > 1:
                spec[max(cands, key=lambda i: shape[i])] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(spec_for, cache_shapes)


def replicated(mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
