"""Jittable step functions: train_step, prefill_step, serve_step."""

from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.common import ModelConfig
from repro.optim import adamw

Array = jax.Array


class TrainState(NamedTuple):
    params: Any
    opt: adamw.AdamWState


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig,
                    remat: bool = True):
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def train_step(params, opt_state, batch: T.Batch):
        loss, grads = jax.value_and_grad(
            lambda p: T.loss_fn(cfg, p, batch, remat=remat))(params)
        params, opt_state, metrics = adamw.apply(opt_cfg, params, grads,
                                                 opt_state)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    """(params, batch) -> last-position logits (B, V).

    Serving prefill: run the full-sequence stack; only the final position's
    logits are needed to emit the first token.  (Cache writes are covered by
    the decode cells; prefill isolates the sequence-parallel compute.)
    """

    def prefill_step(params, batch: T.Batch):
        h, _ = T.hidden_states(cfg, params, batch)
        return T._logits(cfg, params, h[:, -1:])

    return prefill_step


def make_serve_step(cfg: ModelConfig, mla_absorb: bool = False):
    """(params, cache, batch, pos) -> (logits (B,1,V), cache)."""

    def serve_step(params, cache, batch: T.Batch, pos):
        return T.decode_step(cfg, params, cache, batch, pos,
                             mla_absorb=mla_absorb)

    return serve_step
