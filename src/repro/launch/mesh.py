"""Production mesh builders.

Single pod: (16, 16) over ("data", "model") — 256 v5e chips.
Multi pod:  (2, 16, 16) over ("pod", "data", "model") — 512 chips; the
"pod" axis is the DCN tier of the DOSC two-tier link model.

Functions, not module constants: importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before any jax import).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_debug_mesh(data: int = 2, model: int = 4) -> jax.sharding.Mesh:
    """Small mesh for CPU integration tests (8 host devices)."""
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


def mesh_chips(mesh: jax.sharding.Mesh) -> int:
    return mesh.devices.size


def intra_pod_chips(mesh: jax.sharding.Mesh) -> int:
    """Chips per pod = product of non-pod axes."""
    n = mesh.devices.size
    if "pod" in mesh.axis_names:
        n //= mesh.shape["pod"]
    return n
