"""Launchers: production meshes, sharding rules, step builders, dry-run.

Note: ``repro.launch.dryrun`` sets ``XLA_FLAGS`` for 512 host devices at
import time — never import it from tests or benchmarks; run it as
``python -m repro.launch.dryrun``.
"""

from . import mesh, partitioning, specs, steps  # noqa: F401
