import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the production mesh (16x16 single pod / 2x16x16 multi-pod);
  2. lowers the mode-appropriate step (train_step / prefill_step /
     serve_step) with ShapeDtypeStruct inputs and full sharding rules;
  3. compiles it (``.lower().compile()`` must succeed — sharding
     mismatches, compile-time OOM or unsupported collectives are bugs);
  4. records ``memory_analysis()`` (proves it fits), ``cost_analysis()``
     (FLOPs/bytes for §Roofline), the parsed collective schedule, and the
     derived three-term roofline into a JSON results file.

Usage:
    python -m repro.launch.dryrun --arch phi4-mini-3.8b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]

The XLA_FLAGS line above MUST stay the first statement: jax locks the
device count on first init, and smoke tests must keep seeing 1 device.
"""

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp   # noqa: E402

from repro.configs import ARCH_IDS, SHAPES, cell_is_runnable, get_config  # noqa: E402
from repro.core import hlo_cost, roofline, tpu_energy  # noqa: E402
from repro.launch import partitioning as pt  # noqa: E402
from repro.launch import specs, steps  # noqa: E402
from repro.launch.mesh import (intra_pod_chips, make_production_mesh,  # noqa: E402
                               mesh_chips)
from repro.optim import adamw  # noqa: E402

DEFAULT_OUT = "experiments/dryrun_results.json"


def _analytic_state_bytes(shard_tree, shape_tree, chips: int) -> float:
    """Per-device bytes for a sharded state tree (analytic, from specs)."""
    total = 0.0
    for sh, leaf in zip(jax.tree.leaves(shard_tree),
                        jax.tree.leaves(shape_tree)):
        n = leaf.size * jnp.dtype(leaf.dtype).itemsize
        spec = sh.spec
        div = 1
        for i, ax in enumerate(spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            for a in axes:
                div *= sh.mesh.shape[a]
        total += n / div
    return total


def _make_mesh(multi_pod: bool, mesh_shape: str | None):
    if mesh_shape:
        dims = tuple(int(x) for x in mesh_shape.split("x"))
        axes = (("pod", "data", "model") if len(dims) == 3
                else ("data", "model"))
        return jax.make_mesh(
            dims, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(dims))
    return make_production_mesh(multi_pod=multi_pod)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               mla_absorb: bool = False, donate: bool = True,
               mesh_shape: str | None = None,
               cfg_overrides: dict | None = None):
    """Lower + compile one cell; returns (compiled, context dict)."""
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    mesh = _make_mesh(multi_pod, mesh_shape)
    chips = mesh_chips(mesh)
    p_shapes = specs.params_specs(cfg)
    with jax.set_mesh(mesh):
        p_shard = pt.params_shardings(mesh, p_shapes)
        batch_shapes = specs.input_specs(cfg, shape)
        b_shard = pt.batch_spec(mesh, batch_shapes)
        if shape.mode == "train":
            opt_cfg = adamw.AdamWConfig(
                moment_dtype=specs.moment_dtype_for(cfg))
            o_shapes = specs.opt_specs(opt_cfg, p_shapes)
            o_shard = adamw.AdamWState(
                step=pt.replicated(mesh),
                mu=jax.tree.map(lambda s: s, p_shard),
                nu=jax.tree.map(lambda s: s, p_shard))
            fn = steps.make_train_step(cfg, opt_cfg)
            jfn = jax.jit(fn,
                          in_shardings=(p_shard, o_shard, b_shard),
                          out_shardings=(p_shard, o_shard, None),
                          donate_argnums=(0, 1) if donate else ())
            lowered = jfn.lower(p_shapes, o_shapes, batch_shapes)
            state_bytes = (_analytic_state_bytes(p_shard, p_shapes, chips)
                           + 2 * _analytic_state_bytes(p_shard, o_shapes.mu,
                                                       chips))
        elif shape.mode == "prefill":
            fn = steps.make_prefill_step(cfg)
            jfn = jax.jit(fn, in_shardings=(p_shard, b_shard))
            lowered = jfn.lower(p_shapes, batch_shapes)
            state_bytes = _analytic_state_bytes(p_shard, p_shapes, chips)
        else:  # decode
            c_shapes = specs.cache_specs(cfg, shape)
            c_shard = pt.cache_spec(mesh, c_shapes, shape.global_batch)
            fn = steps.make_serve_step(cfg, mla_absorb=mla_absorb)
            jfn = jax.jit(fn,
                          in_shardings=(p_shard, c_shard, b_shard, None),
                          out_shardings=(None, c_shard),
                          donate_argnums=(1,) if donate else ())
            lowered = jfn.lower(p_shapes, c_shapes, batch_shapes,
                                specs.pos_spec())
            state_bytes = (_analytic_state_bytes(p_shard, p_shapes, chips)
                           + _analytic_state_bytes(c_shard, c_shapes,
                                                   chips))
        compiled = lowered.compile()
    ctx = dict(cfg=cfg, shape=shape, mesh=mesh, chips=chips,
               state_bytes_per_device=state_bytes)
    return lowered, compiled, ctx


def analyse_cell(arch: str, shape_name: str, multi_pod: bool,
                 lowered, compiled, ctx,
                 vmem_credit: bool = False) -> dict:
    cfg, shape, mesh = ctx["cfg"], ctx["shape"], ctx["mesh"]
    chips = ctx["chips"]
    xla_cost = compiled.cost_analysis()
    try:
        mem = compiled.memory_analysis()
        mem_d = {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
        }
    except Exception as e:   # pragma: no cover
        mem_d = {"error": str(e)}
    # trip-count-aware static analysis (XLA's cost_analysis counts each
    # while body once — see repro.core.hlo_cost)
    hc = hlo_cost.analyze(compiled.as_text(),
                          vmem_credit_depth=2 if vmem_credit else None)
    colls = hc.collectives
    tokens = specs.tokens_per_step(cfg, shape)
    mf = cfg.model_flops(tokens, decode=shape.mode != "train")
    terms = roofline.build_terms(
        arch, shape_name, "2x16x16" if multi_pod else "16x16", chips,
        {"flops": hc.flops, "bytes accessed": hc.bytes}, colls, mf)
    energy = tpu_energy.step_energy(terms, colls, intra_pod_chips(mesh))
    return {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "mode": shape.mode, "chips": chips,
        "status": "ok",
        "cost_analysis": {
            "flops_per_device": hc.flops,
            "bytes_per_device": hc.bytes,
            "flops_by_op": dict(hc.flops_by_op),
            "bytes_top": hlo_cost.top_bytes_breakdown(hc),
            "xla_reported_flops": xla_cost.get("flops"),
            "xla_reported_bytes": xla_cost.get("bytes accessed"),
            "unknown_trip_whiles": hc.unknown_trip_whiles,
        },
        "memory_analysis": mem_d,
        "state_bytes_per_device": ctx["state_bytes_per_device"],
        "collectives": colls.by_opcode(),
        "collective_wire_bytes": colls.total_wire_bytes,
        "roofline": terms.to_dict(),
        "energy_per_step_j": energy.breakdown() | {"total": energy.total},
        "est_system_power_w": tpu_energy.system_power_w(energy, chips),
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             mla_absorb: bool = False, mesh_shape: str | None = None,
             cfg_overrides: dict | None = None, tag: str = "baseline",
             vmem_credit: bool = False) -> dict:
    runnable, reason = cell_is_runnable(arch, shape_name)
    mesh_name = mesh_shape or ("2x16x16" if multi_pod else "16x16")
    if not runnable:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "tag": tag, "status": "skipped", "reason": reason}
    t0 = time.time()
    try:
        lowered, compiled, ctx = lower_cell(
            arch, shape_name, multi_pod, mla_absorb=mla_absorb,
            mesh_shape=mesh_shape, cfg_overrides=cfg_overrides)
        row = analyse_cell(arch, shape_name, multi_pod, lowered, compiled,
                           ctx, vmem_credit=vmem_credit)
        row["mesh"] = mesh_name
        row["tag"] = tag
        row["compile_seconds"] = round(time.time() - t0, 1)
        return row
    except Exception as e:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "tag": tag,
                "status": "error", "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-2000:],
                "compile_seconds": round(time.time() - t0, 1)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--mla-absorb", action="store_true")
    ap.add_argument("--mesh-shape", default=None,
                    help="override, e.g. 32x8 or 2x32x8 (§Perf)")
    ap.add_argument("--moe-partial-sum", action="store_true")
    ap.add_argument("--attn-p-bf16", action="store_true")
    ap.add_argument("--fsdp-threshold-mb", type=float, default=None,
                    help="params above this get a second data-axis shard; "
                    "use a huge value to disable FSDP (§Perf)")
    ap.add_argument("--vmem-credit", action="store_true",
                    help="price inner-loop bodies as VMEM-fused Pallas "
                    "kernels (block I/O only) — §Perf projection")
    ap.add_argument("--seq-parallel", action="store_true",
                    help="Megatron-style sequence parallelism (§Perf)")
    ap.add_argument("--tag", default="baseline",
                    help="label for this variant in the results file")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    overrides = {}
    if args.moe_partial_sum:
        overrides["moe_partial_sum"] = True
    if args.attn_p_bf16:
        overrides["attn_p_bf16"] = True
    if args.seq_parallel:
        overrides["seq_parallel"] = True
    if args.fsdp_threshold_mb is not None:
        pt.FSDP_THRESHOLD_BYTES = int(args.fsdp_threshold_mb * 2**20)

    cells: list[tuple[str, str, bool]] = []
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                for m in meshes:
                    cells.append((a, s, m))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        for m in meshes:
            cells.append((args.arch, args.shape, m))

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"], r.get("tag", "baseline"))
            for r in results if r.get("status") == "ok"}

    for arch, shape_name, mp in cells:
        mesh_name = args.mesh_shape or ("2x16x16" if mp else "16x16")
        if (arch, shape_name, mesh_name, args.tag) in done:
            print(f"[skip-cached] {arch} x {shape_name} x {mesh_name} "
                  f"[{args.tag}]")
            continue
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name} "
              f"[{args.tag}] ...", flush=True)
        row = run_cell(arch, shape_name, mp, mla_absorb=args.mla_absorb,
                       mesh_shape=args.mesh_shape, cfg_overrides=overrides,
                       tag=args.tag, vmem_credit=args.vmem_credit)
        results = [r for r in results
                   if not (r["arch"] == arch and r["shape"] == shape_name
                           and r["mesh"] == mesh_name
                           and r.get("tag", "baseline") == args.tag)]
        results.append(row)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        if row["status"] == "ok":
            rf = row["roofline"]
            print(f"  ok in {row['compile_seconds']}s: "
                  f"dominant={rf['dominant']} "
                  f"t_bound={rf['t_bound']*1e3:.2f}ms "
                  f"roofline={rf['roofline_fraction']*100:.1f}% "
                  f"state/dev={row['state_bytes_per_device']/2**30:.2f}GiB",
                  flush=True)
            print(f"  memory_analysis: {row['memory_analysis']}")
            print(f"  cost_analysis: {row['cost_analysis']}")
        else:
            print(f"  {row['status']}: "
                  f"{row.get('reason') or row.get('error')}", flush=True)

    n_ok = sum(1 for r in results if r["status"] == "ok")
    n_err = sum(1 for r in results if r["status"] == "error")
    n_skip = sum(1 for r in results if r["status"] == "skipped")
    print(f"\n== dry-run summary: {n_ok} ok, {n_skip} skipped "
          f"(documented), {n_err} errors -> {args.out}")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
