"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``input_specs(cfg, shape)`` returns the Batch spec for an (arch x shape)
cell; ``params_specs`` / ``cache_specs`` / ``opt_specs`` give the state
trees.  Modality-stub archs (audio/vlm) get precomputed frame/patch
embeddings instead of token ids, per the assignment.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.shapes import InputShape
from repro.models import transformer as T
from repro.models.common import ModelConfig
from repro.optim import adamw

SDS = jax.ShapeDtypeStruct


def input_specs(cfg: ModelConfig, shape: InputShape) -> T.Batch:
    b = shape.global_batch
    s = shape.seq_len if shape.mode != "decode" else 1
    dtype = jnp.dtype(cfg.dtype)
    tokens = embeds = positions = labels = None
    if cfg.frontend_stub:
        embeds = SDS((b, s, cfg.d_model), dtype)
    else:
        tokens = SDS((b, s), jnp.int32)
    if cfg.mrope_sections:
        positions = SDS((3, b, s), jnp.int32)
    if shape.mode == "train":
        labels = SDS((b, s), jnp.int32)
    return T.Batch(tokens=tokens, embeds=embeds, positions=positions,
                   labels=labels)


def params_specs(cfg: ModelConfig) -> Any:
    return jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.key(0)))


def opt_specs(opt_cfg: adamw.AdamWConfig, params_shapes: Any) -> Any:
    return jax.eval_shape(
        functools.partial(adamw.init, opt_cfg), params_shapes)


def cache_specs(cfg: ModelConfig, shape: InputShape) -> Any:
    return jax.eval_shape(
        lambda: T.init_cache(cfg, shape.global_batch, shape.seq_len))


def pos_spec() -> SDS:
    return SDS((), jnp.int32)


def tokens_per_step(cfg: ModelConfig, shape: InputShape) -> int:
    if shape.mode == "decode":
        return shape.global_batch           # one new token per sequence
    return shape.global_batch * shape.seq_len


def moment_dtype_for(cfg: ModelConfig) -> str:
    """bf16 AdamW moments for the 100B+ cells so a single v5e pod holds the
    optimizer (12 B/param fp32 moments would exceed 16 GiB/chip at 480B on
    256 chips).  Recorded in EXPERIMENTS.md §Dry-run."""
    return "bfloat16" if cfg.param_count() > 100e9 else "float32"
