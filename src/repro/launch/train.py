"""End-to-end training driver.

Usage (CPU-sized example — the quickstart trains a reduced config):

    PYTHONPATH=src python -m repro.launch.train \
        --arch qwen2-0.5b --reduced --steps 50 --seq-len 128 \
        --global-batch 8 --ckpt-dir /tmp/ckpt

On real hardware the same driver runs the full config under the
production mesh (``--mesh single|multi``); on this CPU container the full
configs are exercised via the dry-run instead.

The loop integrates every substrate layer: sharded deterministic data
pipeline, jitted train step (flash attention + remat + chunked xent),
AdamW, atomic checkpointing with resume, fault-tolerance controller hooks,
and step-time/energy telemetry from the semi-analytical model.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_reduced_config
from repro.data import DataConfig, make_pipeline
from repro.launch import steps as steps_mod
from repro.models import transformer as T
from repro.models.transformer import Batch
from repro.optim import adamw
from repro.runtime import FaultToleranceController, FTConfig


def build_argparser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized config of the same family")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--d-model", type=int, default=0,
                    help="override reduced d_model (0 = default)")
    ap.add_argument("--num-layers", type=int, default=0)
    return ap


def main(argv=None) -> dict:
    args = build_argparser().parse_args(argv)
    overrides = {}
    if args.d_model:
        overrides["d_model"] = args.d_model
    if args.num_layers:
        overrides["num_layers"] = args.num_layers
    cfg = (get_reduced_config(args.arch, **overrides) if args.reduced
           else get_config(args.arch))
    opt_cfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=args.warmup,
                                total_steps=args.steps)

    params = T.init_params(cfg, jax.random.key(args.seed))
    opt_state = adamw.init(opt_cfg, params)
    n_params = T.param_count(params)
    print(f"[train] arch={cfg.name} params={n_params/1e6:.2f}M "
          f"layers={cfg.num_layers} d={cfg.d_model}")

    start_step = 0
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if ckpt and args.resume:
        latest = ckpt.latest_step()
        if latest is not None:
            state = ckpt.restore(latest, {"params": params,
                                          "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            start_step = latest
            print(f"[train] resumed from step {latest}")

    dc = DataConfig(seq_len=args.seq_len, global_batch=args.global_batch,
                    seed=args.seed)
    pipeline = make_pipeline(cfg, dc, start_step=start_step)
    step_fn = jax.jit(steps_mod.make_train_step(cfg, opt_cfg,
                                                remat=args.remat),
                      donate_argnums=(0, 1))

    ft = FaultToleranceController(num_workers=1, cfg=FTConfig())
    losses, times = [], []
    t_wall = time.time()
    for step in range(start_step, args.steps):
        batch_np = next(pipeline)
        batch = Batch(tokens=jnp.asarray(batch_np.tokens),
                      labels=jnp.asarray(batch_np.labels))
        t0 = time.time()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        ft.heartbeat(0, now=time.time())
        ft.report_step(0, step, dt)
        losses.append(loss)
        times.append(dt)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"[train] step={step:5d} loss={loss:.4f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"dt={dt*1e3:.0f}ms")
        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, {"params": params, "opt": opt_state},
                      metadata={"loss": loss})
    pipeline.close()
    result = {
        "first_loss": losses[0], "last_loss": losses[-1],
        "loss_decreased": losses[-1] < losses[0],
        "steps": len(losses),
        "mean_step_s": float(np.mean(times[1:])) if len(times) > 1 else 0,
        "wall_s": time.time() - t_wall,
    }
    print(f"[train] done: loss {result['first_loss']:.4f} -> "
          f"{result['last_loss']:.4f} in {result['wall_s']:.1f}s")
    return result


if __name__ == "__main__":
    main()
