"""LLM **token**-serving driver: prefill + decode with a KV cache.

.. note::
   This is the transformer-workload scaffolding (unrelated to the
   paper's design-space sweeps) — it serves *tokens* from the
   ``repro.models`` stack used by the dry-run/system tests.  The
   **sweep server** — the persistent co-design service with admission
   control, deadlines and crash recovery — is ``python -m
   repro.service`` (:mod:`repro.core.service`).  This module was
   renamed from ``launch/serve.py`` so the two can never be confused.

CPU-sized example:

    PYTHONPATH=src python -m repro.launch.token_serve \
        --arch qwen2-0.5b --reduced --batch 4 --prompt-len 32 --gen 16

Implements the token-serve loop: one jitted prefill (builds the cache
for the prompt), then jitted single-token decode steps with greedy/
temperature sampling against the shared cache.  The decode path is exactly
what the ``decode_32k`` / ``long_500k`` dry-run cells lower.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced_config
from repro.models import transformer as T
from repro.models.transformer import Batch


def prefill(cfg, params, cache, tokens):
    """Sequential prefill via the decode path (cache-exact)."""
    b, s = tokens.shape
    step = jax.jit(lambda p, c, tok, pos: T.decode_step(
        cfg, p, c, Batch(tokens=tok), pos))
    logits = None
    for t in range(s):
        logits, cache = step(params, cache, tokens[:, t:t + 1],
                             jnp.int32(t))
    return logits, cache


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_reduced_config(args.arch) if args.reduced \
        else get_config(args.arch)
    if cfg.frontend_stub:
        raise SystemExit(f"{cfg.name} is a modality-stub backbone; "
                         "serve text archs here")
    key = jax.random.key(args.seed)
    params = T.init_params(cfg, key)
    max_len = args.prompt_len + args.gen
    cache = T.init_cache(cfg, args.batch, max_len)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)

    t0 = time.time()
    logits, cache = prefill(cfg, params, cache, prompts)
    t_prefill = time.time() - t0

    decode = jax.jit(lambda p, c, tok, pos: T.decode_step(
        cfg, p, c, Batch(tokens=tok), pos))
    toks = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    generated = [toks]
    t0 = time.time()
    for i in range(args.gen - 1):
        pos = jnp.int32(args.prompt_len + i)
        logits, cache = decode(params, cache, toks, pos)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            toks = jax.random.categorical(
                sub, logits[:, -1] / args.temperature)[:, None]
        else:
            toks = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        generated.append(toks)
    t_decode = time.time() - t0
    out = jnp.concatenate(generated, axis=1)
    tput = args.batch * (args.gen - 1) / max(t_decode, 1e-9)
    print(f"[serve] prefill {args.prompt_len} toks in {t_prefill:.2f}s; "
          f"decoded {args.gen-1} toks/seq x {args.batch} seqs "
          f"({tput:.1f} tok/s)")
    print(f"[serve] sample output ids: {np.asarray(out[0])[:12]}")
    return {"tokens": np.asarray(out), "decode_tok_per_s": float(tput),
            "prefill_s": t_prefill}


if __name__ == "__main__":
    main()
