"""RBE accelerator throughput model — reproduces the roofline of Fig. 4.

The paper observes (via GVSoC): "layer performance is almost completely
bounded by the weight streaming in the accelerator.  The RBE demonstrates
close to peak performance on full convolutional benchmarks, with diminishing
performance for pointwise kernels, and even further decrease when doing
depthwise kernels."

We model the effective throughput of layer *j* as a two-term roofline:

    (MAC/cycle)_j = min( util(kind_j) * PEAK,
                         AI_w(j) * weight_port_bytes_per_cycle )

where ``AI_w`` is the layer's MACs-per-weight-byte *as streamed* (weights are
re-fetched once per output tile, the DORY-style tiling determined by the L1
size), and ``util`` is the engine's structural efficiency for the layer kind
(depthwise layers cannot fill the input-channel parallelism of the engine).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Sequence

from .constants import RBE, RBESpec, TechNode
from .workloads import LayerKind, LayerSpec, NNWorkload

# L1 tile budget used by the DORY-style tiling: how many output activation
# bytes fit per tile before weights must be re-streamed.
L1_TILE_BYTES = 48 * 1024


def _util(kind: LayerKind, spec: RBESpec) -> float:
    return {
        LayerKind.CONV: spec.util_conv,
        LayerKind.POINTWISE: spec.util_pointwise,
        LayerKind.DEPTHWISE: spec.util_depthwise,
        LayerKind.FC: spec.util_fc,
    }[kind]


@functools.lru_cache(maxsize=65536)
def weight_stream_bytes(layer: LayerSpec,
                        l1_tile_bytes: int = L1_TILE_BYTES) -> int:
    """Total weight bytes streamed from L2-weight for one inference of the
    layer: weights are re-fetched once per output tile."""
    n_tiles = max(1, math.ceil(layer.out_act_bytes / l1_tile_bytes))
    return layer.weight_bytes * n_tiles


@functools.lru_cache(maxsize=4096)
def total_weight_stream_bytes(workload: NNWorkload,
                              l1_tile_bytes: int = L1_TILE_BYTES) -> int:
    """Streamed weight bytes for one inference of the whole network
    (the per-layer reduction Eq. 8 consumes on every evaluation)."""
    return sum(weight_stream_bytes(l, l1_tile_bytes)
               for l in workload.layers)


def streamed_intensity(layer: LayerSpec,
                       l1_tile_bytes: int = L1_TILE_BYTES) -> float:
    """MACs per *streamed* weight byte (x-axis of the Fig. 4 roofline)."""
    return layer.macs / max(weight_stream_bytes(layer, l1_tile_bytes), 1)


def mac_per_cycle(layer: LayerSpec, spec: RBESpec = RBE,
                  scale: float = 1.0,
                  l1_tile_bytes: int = L1_TILE_BYTES) -> float:
    """Effective MAC/cycle for a layer (Eq. 9's (MAC/cycle)_j term).

    ``scale`` shrinks the engine (the paper's on-sensor processor has 1/4 the
    aggregator's compute capability).
    """
    peak = spec.peak_mac_per_cycle * scale * _util(layer.kind, spec)
    bw_bound = streamed_intensity(layer, l1_tile_bytes) * \
        spec.weight_port_bytes_per_cycle * scale
    return max(1e-9, min(peak, bw_bound))


def processing_time_s(workload: NNWorkload, node: TechNode,
                      spec: RBESpec = RBE, scale: float = 1.0) -> float:
    """Eq. 9: T_processing = sum_j #MAC_j / (MAC/cycle)_j / f_clk."""
    cycles = sum(l.macs / mac_per_cycle(l, spec, scale)
                 for l in workload.layers)
    return cycles / node.f_clk


@dataclasses.dataclass(frozen=True)
class RooflinePoint:
    """One layer's position on the Fig. 4 roofline plot."""

    layer: str
    kind: str
    intensity_mac_per_byte: float   # streamed-weight arithmetic intensity
    mac_per_cycle: float
    peak_fraction: float
    bound: str                      # "compute" | "weight-stream"


def roofline_points(workload: NNWorkload, spec: RBESpec = RBE,
                    scale: float = 1.0) -> list[RooflinePoint]:
    pts = []
    for l in workload.layers:
        eff = mac_per_cycle(l, spec, scale)
        peak = spec.peak_mac_per_cycle * scale
        bw_bound = streamed_intensity(l) * spec.weight_port_bytes_per_cycle \
            * scale
        bound = "weight-stream" if bw_bound < peak * _util(l.kind, spec) \
            else "compute"
        pts.append(RooflinePoint(
            layer=l.name, kind=l.kind.value,
            intensity_mac_per_byte=streamed_intensity(l),
            mac_per_cycle=eff, peak_fraction=eff / peak, bound=bound,
        ))
    return pts
