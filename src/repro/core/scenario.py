"""Session simulator: time-varying traces with battery + thermal state.

Every other engine in the repo evaluates one *static* operating point of
the Eq. 1-11 model.  Real AR/VR sessions duty-cycle: the inference rates
and active camera count follow user activity, dissipated power heats the
case, heat throttles the compute rates, and the battery drains
("Draining our Glass" measures exactly this coupling on Google Glass).
This module adds that session axis without forking the evaluation stack:

* A **scenario trace** is a piecewise-constant schedule of knob
  multipliers: per-:class:`Phase` DetNet/KeyNet rate scales, a camera
  frame-rate scale and an active-camera fraction, each held for
  ``duration_s``.  :data:`PROFILES` names a few user-behavior traces
  (``"steady"``, ``"commute"``, ``"workday"``, ``"gaming"``).
* A :class:`ScenarioSet` bundles traces with a :class:`BatterySpec` and
  :class:`ThermalSpec` and a time resolution; :func:`scenario_stack`
  lowers it against a stacked model lowering into a
  :class:`ScenarioStack` — a drop-in for
  :class:`repro.core.arrays.StackedModelArrays` that the backend layer
  evaluates through the *same* chunk contract
  (:mod:`repro.core.backend`), with **trace as one more batched grid
  axis**.
* The per-configuration kernel runs a ``lax.scan`` over the trace
  steps.  Each step re-evaluates the Eq. 1-11 kernel at the phase's
  scaled knobs (times the current throttle factor), then advances two
  state variables — battery state-of-charge and one lumped-thermal RC
  node — using the *exact* RC step response, so the discretization
  introduces no integration error and the closed-form oracles of
  ``tests/test_scenario.py`` hold to float precision.

Four session channels join the static kernel fields as first-class
sweep objectives/constraints (``sweep.SCENARIO_FIELDS``):

* ``session_energy_j``   — integral of system power over the trace;
* ``time_to_empty_s``    — when the battery crosses empty (exact linear
  interpolation inside the crossing step; if the session ends first,
  the whole-session average drain extrapolates cyclically);
* ``peak_case_temp_c``   — max of the RC node temperature;
* ``throttle_fraction``  — fraction of session time spent throttled.

All four inherit validity from ``avg_power``: invalid grid corners
(MRAM with no test vehicle, padded cuts) are NaN, exactly like the
static channels, so argmin/top-k/Pareto/constraint machinery needs no
special cases.  ``evaluate_grid(scenarios=...)``,
``stream_grid(scenarios=...)`` and ``optimal_partition(scenarios=...)``
all route through here.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Iterable, Mapping, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from . import arrays as A
from . import sweep as SW
from .constants import (CAMERA_FPS, DEFAULT_BATTERY, DEFAULT_THERMAL,
                        DETNET_FPS, KEYNET_FPS, NUM_CAMERAS, BatterySpec,
                        ThermalSpec)

#: Default number of ``lax.scan`` steps each phase is subdivided into.
#: The RC update is exact per step, so substeps only matter for how
#: often the throttle factor is refreshed against the rising
#: temperature (piecewise-constant-rate approximation of the feedback).
DEFAULT_STEPS_PER_PHASE = 4


# ---------------------------------------------------------------------------
# Trace description
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Phase:
    """One piecewise-constant segment of a scenario trace.

    The scales multiply the swept base knobs, so one trace composes with
    every grid axis: a config with ``detnet_fps=10`` in a phase with
    ``detnet_scale=0.5`` runs DetNet at 5 fps.  ``cameras_active`` is
    the *fraction* of the configured cameras powered during the phase.
    """

    duration_s: float
    detnet_scale: float = 1.0
    keynet_scale: float = 1.0
    camera_fps_scale: float = 1.0
    cameras_active: float = 1.0


@dataclasses.dataclass(frozen=True)
class ScenarioTrace:
    """A named sequence of :class:`Phase` segments."""

    name: str
    phases: tuple[Phase, ...]

    @property
    def duration_s(self) -> float:
        return float(sum(p.duration_s for p in self.phases))


def _idle(duration_s):
    return Phase(duration_s, detnet_scale=0.25, keynet_scale=0.25,
                 camera_fps_scale=0.5, cameras_active=0.5)


#: Named user-behavior traces (Snippet-2-style VR session profiles,
#: "Draining our Glass"-style duty cycles).  All compose with the grid
#: knobs, so e.g. ``num_cameras=8`` under ``"commute"`` still idles at
#: half the cameras during the idle phases.
PROFILES: Mapping[str, ScenarioTrace] = {
    "steady": ScenarioTrace("steady", (Phase(1800.0),)),
    "commute": ScenarioTrace("commute", (
        _idle(420.0),
        Phase(900.0),                                   # navigate, full rate
        Phase(180.0, detnet_scale=1.5, keynet_scale=1.2),   # interaction burst
        _idle(300.0),
    )),
    "workday": ScenarioTrace("workday", (
        _idle(1200.0),
        Phase(240.0),                                   # notification burst
        _idle(1200.0),
        Phase(240.0, detnet_scale=1.25),
        _idle(900.0),
    )),
    "gaming": ScenarioTrace("gaming", (
        Phase(300.0),                                   # lobby
        Phase(1200.0, detnet_scale=1.5, keynet_scale=1.2,
              camera_fps_scale=1.2),                    # match, high rate
        Phase(120.0, detnet_scale=0.5, keynet_scale=0.5,
              camera_fps_scale=0.5, cameras_active=0.5),    # cooldown
    )),
}


@dataclasses.dataclass(frozen=True)
class ScenarioSet:
    """Hashable bundle of traces + device dynamics for one sweep.

    This is what the ``scenarios=`` knob of ``evaluate_grid`` /
    ``stream_grid`` / ``optimal_partition`` lowers to (see
    :func:`as_scenario_set` for the accepted shorthands).  The traces
    become the values of the trailing ``trace`` grid axis, in order.
    """

    traces: tuple[ScenarioTrace, ...]
    battery: BatterySpec = DEFAULT_BATTERY
    thermal: ThermalSpec = DEFAULT_THERMAL
    steps_per_phase: int = DEFAULT_STEPS_PER_PHASE
    throttle: bool = True

    def __post_init__(self):
        if not self.traces:
            raise ValueError("a ScenarioSet needs at least one trace")
        names = [t.name for t in self.traces]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate trace names: {names}")
        if self.steps_per_phase < 1:
            raise ValueError("steps_per_phase must be >= 1")

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(t.name for t in self.traces)

    def only(self, name: str) -> "ScenarioSet":
        """The same set restricted to one named trace (winner rendering)."""
        for t in self.traces:
            if t.name == name:
                return dataclasses.replace(self, traces=(t,))
        raise KeyError(f"unknown trace {name!r}; have {self.names}")


def as_scenario_set(spec) -> ScenarioSet:
    """Lower the ``scenarios=`` knob into a canonical :class:`ScenarioSet`.

    Accepted: a :class:`ScenarioSet` (returned as-is), a profile name
    from :data:`PROFILES` (or ``"all"`` for every profile), a
    :class:`ScenarioTrace`, or an iterable mixing names and traces.
    """
    if isinstance(spec, ScenarioSet):
        return spec
    if isinstance(spec, str):
        spec = tuple(PROFILES) if spec == "all" else (spec,)
    elif isinstance(spec, ScenarioTrace):
        spec = (spec,)
    traces = []
    for t in spec:
        if isinstance(t, ScenarioTrace):
            traces.append(t)
        elif isinstance(t, str):
            if t not in PROFILES:
                raise ValueError(f"unknown scenario profile {t!r}; "
                                 f"have {tuple(PROFILES)}")
            traces.append(PROFILES[t])
        else:
            raise TypeError(f"scenarios entries must be trace names or "
                            f"ScenarioTrace, got {type(t).__name__}")
    return ScenarioSet(traces=tuple(traces))


# ---------------------------------------------------------------------------
# State-update physics (shared by the scan body and the reference loop)
# ---------------------------------------------------------------------------


def throttle_factor(temp_c, thermal: ThermalSpec):
    """Rate multiplier of the throttle law at case temperature ``temp_c``.

    ``clip(1 - gain * max(0, T - onset), floor, 1)`` — exactly 1.0 at or
    below the onset temperature (``max(0, .)`` yields an exact 0.0), so
    an unthrottled session multiplies the rates by exactly 1.0.
    """
    over = jnp.maximum(0.0, temp_c - thermal.throttle_onset_c)
    return jnp.clip(1.0 - thermal.throttle_gain_per_c * over,
                    thermal.throttle_floor, 1.0)


def thermal_step(temp_c, power_w, dt_s, thermal: ThermalSpec):
    """Exact RC step response under constant power for ``dt_s`` seconds:
    ``T' = T_ss + (T - T_ss) * exp(-dt / tau)`` with
    ``T_ss = T_amb + P * R`` and ``tau = R * C``.  Exact integration is
    what makes the closed-form thermal oracle and the re-segmentation
    invariance of ``tests/test_scenario.py`` hold."""
    t_ss = thermal.ambient_c + power_w * thermal.r_th_k_per_w
    decay = jnp.exp(-dt_s / (thermal.r_th_k_per_w * thermal.c_th_j_per_k))
    return t_ss + (temp_c - t_ss) * decay


def effective_drain_w(power_w, battery: BatterySpec):
    """Peukert-corrected drain power ``P * (P / p_ref) ** (peukert - 1)``.
    At ``peukert == 1`` the exponent is exactly 0.0, so the correction
    factor is exactly 1.0 and the drain stays bitwise linear."""
    return power_w * (power_w / battery.p_ref_w) ** (battery.peukert - 1.0)


def _make_step(base_fn, sset: ScenarioSet):
    """The per-step state update ``(carry, cfg, x) -> carry``.

    One function object serves both the ``lax.scan`` body of the batched
    kernel and the jitted python-loop reference of
    :func:`simulate_session` — the scan-vs-loop parity test holds
    because there is literally one copy of this code.

    ``carry = (t, soc, temp, peak, throttled_s, energy, tte)``;
    ``cfg = (model_i, cut, agg_i, sen_i, wm_i, det_fps, key_fps, ncam,
    mipi_scale, cam_fps)``; ``x = (dt, det_scale, key_scale, cam_scale,
    cams_active)`` is one row of the step tables.
    """
    bat, th = sset.battery, sset.thermal

    def step(carry, cfg, x):
        t, soc, temp, peak, throttled_s, energy, tte = carry
        dt, dsc, ksc, csc, act = x
        (model_i, cut, agg_i, sen_i, wm_i, det_fps, key_fps, ncam,
         mipi_scale, cam_fps) = cfg
        thr = throttle_factor(temp, th) if sset.throttle else jnp.float64(1.0)
        out = base_fn(model_i, cut, agg_i, sen_i, wm_i,
                      det_fps * (dsc * thr), key_fps * (ksc * thr),
                      ncam * act, mipi_scale, cam_fps * csc)
        power = out["avg_power"]
        drain = effective_drain_w(power, bat)
        soc_new = soc - drain * dt / bat.capacity_j
        temp_new = thermal_step(temp, power, dt, th)
        # Zero-duration steps (phase-count padding across the traces of
        # one set) are bitwise no-ops on every state variable.
        live = dt > 0.0
        soc_new = jnp.where(live, soc_new, soc)
        temp_new = jnp.where(live, temp_new, temp)
        # Exact in-step linear crossing: at most one crossing per
        # session (soc is non-increasing), so a plain select suffices.
        cross = (soc > 0.0) & (soc_new <= 0.0)
        tte = jnp.where(cross, t + soc * bat.capacity_j / drain, tte)
        return (t + dt, soc_new, temp_new, jnp.maximum(peak, temp_new),
                throttled_s + dt * (thr < 1.0), energy + power * dt, tte)

    return step


def _finalize(carry, static_power, bat: BatterySpec):
    """Map the final scan carry to the four session channels.

    Adding ``static_power * 0.0`` poisons every channel on invalid grid
    corners (NaN propagates; a finite power adds an exact 0.0, and
    ``inf + 0.0 == inf`` keeps the never-empties sentinel intact).
    """
    t_end, soc_end, _, peak, throttled_s, energy, tte = carry
    poison = static_power * 0.0
    drained = bat.soc0 - soc_end
    # No in-session crossing: extrapolate the whole-session average
    # drain cyclically (sessions repeat back-to-back until empty).
    extrap = jnp.where(drained > 0.0, t_end * bat.soc0 / drained, jnp.inf)
    tte = jnp.where(jnp.isfinite(tte), tte, extrap)
    return {
        "session_energy_j": energy + poison,
        "time_to_empty_s": tte + poison,
        "peak_case_temp_c": peak + poison,
        "throttle_fraction": (jnp.where(t_end > 0.0, throttled_s
                                        / jnp.where(t_end > 0.0, t_end, 1.0),
                                        0.0) + poison),
    }


def _init_carry(sset: ScenarioSet):
    f64 = jnp.float64
    th = sset.thermal
    return (f64(0.0), f64(sset.battery.soc0), f64(th.ambient_c),
            f64(th.ambient_c), f64(0.0), f64(0.0), f64(np.inf))


# ---------------------------------------------------------------------------
# Lowering: ScenarioSet -> step tables -> drop-in kernel stack
# ---------------------------------------------------------------------------


def _step_tables(sset: ScenarioSet) -> tuple[np.ndarray, ...]:
    """Lower the trace set to dense ``(n_traces, n_steps)`` step tables
    ``(dt, det_scale, key_scale, cam_scale, cams_active)``.  Each phase
    is split into ``steps_per_phase`` equal substeps; traces with fewer
    phases pad with zero-duration steps (exact no-ops in the scan)."""
    K = sset.steps_per_phase
    n_steps = max(len(t.phases) for t in sset.traces) * K
    tabs = [np.zeros((len(sset.traces), n_steps)) for _ in range(5)]
    for ti in range(5):
        if ti > 0:
            tabs[ti][:] = 1.0       # neutral scales in the padding
    for r, trace in enumerate(sset.traces):
        for p, ph in enumerate(trace.phases):
            cols = slice(p * K, (p + 1) * K)
            tabs[0][r, cols] = ph.duration_s / K
            tabs[1][r, cols] = ph.detnet_scale
            tabs[2][r, cols] = ph.keynet_scale
            tabs[3][r, cols] = ph.camera_fps_scale
            tabs[4][r, cols] = ph.cameras_active
    return tuple(tabs)


@dataclasses.dataclass(frozen=True, eq=False)
class ScenarioStack:
    """A scenario-wrapped model lowering — drop-in for
    :class:`repro.core.arrays.StackedModelArrays` in the backend layer.

    The backend contract only needs two hooks: ``vmapped_kernel()``
    (``sweep.vmapped_kernel`` dispatches here when present) and
    ``fields`` (``sweep.kernel_fields``); everything else — node lookup,
    cut ranges, model names — delegates to the wrapped stack, so
    ``build_axes`` validation and the stream executor run unchanged.
    Hashes by identity (``eq=False``) like the stack it wraps, which
    keeps the compiled-step and dense-eval caches keyed correctly;
    checkpoint signatures hash it by *content* (``backend._hash_update``
    recurses through dataclass fields), so a changed trace or battery
    invalidates resume state exactly like a changed model table.
    """

    S: A.StackedModelArrays
    sset: ScenarioSet
    step_tables: tuple[np.ndarray, ...]

    #: Marker the backend support gate checks (``getattr`` duck-check,
    #: so plain model stacks need no changes).
    is_scenario = True

    @property
    def fields(self) -> tuple[str, ...]:
        return SW.FIELDS + SW.SCENARIO_FIELDS

    @property
    def n_traces(self) -> int:
        return len(self.sset.traces)

    def vmapped_kernel(self):
        return jax.vmap(_make_session_fn(self))

    def __getattr__(self, name):
        if name.startswith("__"):
            raise AttributeError(name)
        return getattr(object.__getattribute__(self, "S"), name)


@functools.lru_cache(maxsize=16)
def scenario_stack(S: A.StackedModelArrays,
                   sset: ScenarioSet) -> ScenarioStack:
    """Lower (and cache) one scenario set against one model stack.

    Cached on ``(S identity, set content)`` — ``stack_model_arrays`` is
    itself cached, so repeated sweeps over the same workloads + traces
    reuse the compiled kernels downstream (``backend.cached_dense_eval``
    and ``cached_step`` key on the stack object's identity).
    """
    return ScenarioStack(S=S, sset=sset, step_tables=_step_tables(sset))


def _make_session_fn(stack: ScenarioStack):
    """Close the per-configuration session kernel over one scenario stack.

    Signature: the ten static-config coordinates of
    ``sweep._make_config_fn`` plus a trailing ``trace_i`` — exactly the
    argument list ``backend.decode_gather`` produces once ``build_axes``
    appends the trace axis.  Emits every static field (evaluated once at
    the base knobs, so a constant trace degenerates bitwise to the
    static kernel) plus the four session channels.
    """
    base_fn = SW._make_config_fn(stack.S)
    step = _make_step(base_fn, stack.sset)
    init = _init_carry(stack.sset)
    tables = stack.step_tables
    j = jnp.asarray

    def session_fn(model_i, cut, agg_i, sen_i, wm_i, det_fps, key_fps, ncam,
                   mipi_scale, cam_fps, trace_i):
        static = base_fn(model_i, cut, agg_i, sen_i, wm_i, det_fps, key_fps,
                         ncam, mipi_scale, cam_fps)
        cfg = (model_i, cut, agg_i, sen_i, wm_i, det_fps, key_fps, ncam,
               mipi_scale, cam_fps)
        xs = tuple(j(tab)[trace_i] for tab in tables)
        carry = jax.lax.scan(
            lambda c, x: (step(c, cfg, x), None), init, xs)[0]
        out = dict(static)
        out.update(_finalize(carry, static["avg_power"],
                             stack.sset.battery))
        return out

    return session_fn


# ---------------------------------------------------------------------------
# Reference python-loop simulator (docs, tests, trajectory rendering)
# ---------------------------------------------------------------------------


def simulate_session(scenarios="steady", trace: str | None = None,
                     cut: int = 0, agg_node="7nm", sensor_node="7nm",
                     sensor_weight_mem: str = "sram",
                     detnet_fps: float = DETNET_FPS,
                     keynet_fps: float = KEYNET_FPS,
                     num_cameras: float = NUM_CAMERAS,
                     mipi_energy_scale: float = 1.0,
                     camera_fps: float = CAMERA_FPS,
                     detnet=None, keynet=None) -> dict:
    """Simulate one configuration through one trace, step by step.

    The reference twin of the batched ``lax.scan`` kernel: a host python
    loop over the *same* jitted step function (:func:`_make_step`), so
    its final state is bitwise the scan path's — pinned by
    ``tests/test_scenario.py``.  Returns per-step trajectory arrays
    (``t_s``, ``soc``, ``temp_c``, ``power_w``, ``throttle``) plus the
    four session channels, for session plots and oracle checks.
    """
    sset = as_scenario_set(scenarios)
    if trace is None:
        trace = sset.traces[0].name
    sset = sset.only(trace)
    with enable_x64():
        S = A.stack_model_arrays((A.model_arrays(detnet, keynet),))
        stack = scenario_stack(S, sset)
        base_fn = SW._make_config_fn(S)
        step = jax.jit(_make_step(base_fn, sset))
        wm_i = A.WEIGHT_MEM_KINDS.index(sensor_weight_mem)
        cfg = tuple(map(jnp.asarray, (
            0, int(cut), S.node_index(agg_node), S.node_index(sensor_node),
            wm_i, float(detnet_fps), float(keynet_fps), float(num_cameras),
            float(mipi_energy_scale), float(camera_fps))))
        carry = _init_carry(sset)
        rows = np.stack(stack.step_tables, axis=-1)[0]   # (n_steps, 5)
        traj = {"t_s": [0.0], "soc": [float(carry[1])],
                "temp_c": [float(carry[2])], "energy_j": [0.0],
                "throttle": []}
        for x in rows:
            thr = (float(throttle_factor(carry[2], sset.thermal))
                   if sset.throttle else 1.0)
            carry = step(carry, cfg, tuple(map(jnp.float64, x)))
            traj["t_s"].append(float(carry[0]))
            traj["soc"].append(float(carry[1]))
            traj["temp_c"].append(float(carry[2]))
            traj["energy_j"].append(float(carry[5]))
            traj["throttle"].append(thr)
        out = {k: np.asarray(v) for k, v in traj.items()}
        # Recover per-step power from the energy accumulator differences
        # (NaN across zero-duration padding steps).
        dt = np.diff(out["t_s"])
        with np.errstate(invalid="ignore", divide="ignore"):
            out["power_w"] = np.where(
                dt > 0, np.diff(out["energy_j"]) / np.where(dt > 0, dt, 1.0),
                np.nan)
        static = base_fn(*cfg)
        final = _finalize(carry, static["avg_power"], sset.battery)
        out.update({k: float(v) for k, v in final.items()})
        out["final_carry"] = tuple(float(v) for v in carry)
    return out
