"""Sweep-as-a-service: a crash-safe, persistent co-design server.

One-shot :func:`repro.core.stream.stream_grid` calls pay spec
resolution and (on a cold process) step compilation per call, and give
a caller no admission control, no deadlines and no recovery story.
This module wraps the streaming executor in a **long-lived service**
for many concurrent design-space queries — the request-driven shape of
ROADMAP item 2:

* **Bounded admission with explicit backpressure** — requests enter
  through :class:`repro.runtime.admission.AdmissionQueue`; once the
  backlog reaches ``capacity`` further submissions are rejected with
  :class:`repro.runtime.admission.BackpressureError` (never unbounded
  buffering, never a blocking deadlock, and admitted work is never
  dropped).
* **Compiled-plan reuse** — resolved :class:`repro.core.stream.
  StreamPlan` objects are held in an LRU keyed by their content
  ``signature`` (:func:`repro.core.backend.job_signature`).  The
  :class:`~repro.core.backend.ChunkSpec` inside a plan hashes its
  model stack by identity, so *only* re-submitting the same plan
  object makes :func:`repro.core.backend.cached_step` return the
  already-compiled chunk step — the plan cache is what turns repeat
  queries compile-free across requests.
* **Per-request deadlines and cooperative cancel** — each request's
  :class:`~repro.runtime.admission.Deadline` (and its
  :meth:`Ticket.cancel`) is wired into ``stream_grid(should_stop=)``,
  polled between chunk dispatches: an overdue or cancelled request
  stops within one chunk and returns the executor's consistent prefix
  snapshot as a ``partial=True`` :class:`~repro.core.stream.
  StreamResult` (argmin/top-k/front so far + ``fraction_complete``)
  instead of an error.
* **Crash recovery** — with a ``spool_dir``, every request is
  journaled (atomic tmp+rename JSON) and executions checkpoint under
  ``spool/ckpt/<signature>`` through the PR 6 carry contract.  A
  SIGKILL'd server restarted over the same spool re-admits queued and
  in-flight requests and resumes them from the newest snapshot with
  **bitwise-identical** final results.
* **Retry / graceful degradation** — transient dispatch faults retry
  with exponential backoff (:class:`repro.runtime.RetryPolicy`), dead
  device shards trigger the elastic replan
  (:func:`repro.runtime.elastic.drop_worker`) down to single-device
  execution, all inside the executor; the service aggregates the
  resilience counters across requests.
* **Request fusion** — compatible queued requests (same model stack,
  axes, backend, chunk geometry, constraints and histogram spec —
  typically differing only in objectives, tracked channels or top-k)
  are claimed atomically and fused into **one** stacked dispatch; each
  member's exact deliverables are sliced back out of the fused result.
  Fusion is exactness-first: per-channel argmin/top-k slice exactly,
  the shared Pareto front is only handed to members whose objective
  tuple equals the fused tuple, and requests carrying deadlines never
  fuse (one member's deadline must not truncate another's answer).
* **Health surface** — :meth:`SweepService.health` reports liveness,
  queue depth/capacity, per-request state + progress, plan/step cache
  hit rates and the aggregated resilience counters.

Run it in-process (``with SweepService(...) as svc: svc.submit(...)``)
or as ``python -m repro.service`` (see :func:`main`) for a
spool-backed batch server.  Deterministic recovery-path coverage lives
in ``tests/test_service.py`` and the ``benchmarks/run.py --smoke`` CI
gates, driven by :class:`repro.runtime.FaultInjector`.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import threading
import time
from collections import OrderedDict
from typing import Any, Mapping, Optional, Sequence

import numpy as np

from ..runtime.admission import AdmissionQueue, BackpressureError, Deadline
from ..runtime.fault_tolerance import RetryPolicy
from . import backend as B
from . import pareto as P
from . import stream as ST
from . import sweep as SW

#: Grid-axis keyword arguments a :class:`SweepRequest` may carry (the
#: axis surface of :func:`repro.core.stream.plan_stream`).
GRID_KEYS = frozenset({
    "cuts", "agg_nodes", "sensor_nodes", "weight_mems", "detnet_fps",
    "keynet_fps", "num_cameras", "mipi_energy_scale", "camera_fps",
    "detnet", "keynet", "model", "models", "scenarios",
})

#: Ticket lifecycle states.
QUEUED, RUNNING, DONE, FAILED, CANCELLED = (
    "queued", "running", "done", "failed", "cancelled")


class _PoolPreempted(Exception):
    """Internal: shutdown hit a pooled execution — leave the tickets
    unfinished (``close()`` journals them still-RUNNING) so recovery
    over the same spool reattaches to the lease board."""


class _PoolCancelled(Exception):
    """Internal: every member of a pooled execution cancelled."""


class CancelledError(RuntimeError):
    """The request was cancelled before any chunk was dispatched."""


class ServiceClosedError(RuntimeError):
    """The service shut down (or its worker died) before the request
    finished.  Raised by :meth:`Ticket.result` instead of hanging
    forever on a ticket nothing will ever complete; with a spool the
    request's journal keeps its pre-shutdown state, so a later service
    over the same spool resumes it."""


@dataclasses.dataclass(frozen=True)
class SweepRequest:
    """One design-space query against the sweep service.

    ``grid`` holds the axis arguments of
    :func:`repro.core.stream.stream_grid` (see :data:`GRID_KEYS`); the
    remaining fields mirror the executor's sweep-defining knobs plus
    the service-level ones: ``deadline_s`` (seconds from *submission*
    after which the request returns its consistent ``partial=True``
    snapshot), ``need_front`` (set ``False`` when the Pareto front is
    not wanted — it widens fusion eligibility), and ``fuse`` (opt out
    of being batched with compatible requests).  ``tenant`` and
    ``priority`` are scheduling metadata for the multi-tenant
    admission queue: weighted fair scheduling across tenants, higher
    ``priority`` claimed first within one (with aging, so low-priority
    work never starves) — they never affect results or fusion
    eligibility.  Requests built only from JSON-able values (axis
    tuples, profile names, numbers) are journaled and survive a server
    crash; requests embedding live model objects still run but are not
    recoverable.
    """

    grid: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    objectives: Sequence[str] = P.DEFAULT_OBJECTIVES
    maximize: Sequence[str] = ()
    track: Optional[Sequence[str] | str] = None
    constraints: Any = None
    top_k: int = 4
    hist_bins: int = 0
    hist_ranges: Optional[Mapping] = None
    chunk_size: int = ST.DEFAULT_CHUNK
    scan_chunks: Optional[int] = None
    backend: Optional[str] = None
    deadline_s: Optional[float] = None
    need_front: bool = True
    fuse: bool = True
    tenant: str = "default"
    priority: int = 0

    def normalized(self) -> "SweepRequest":
        """Canonical form: tuples for sequences, validated grid keys,
        constraints pre-parsed to ``((field, op, bound), ...)``."""
        bad = set(self.grid) - GRID_KEYS
        if bad:
            raise ValueError(f"unknown grid axes {sorted(bad)}; valid "
                             f"axes are {sorted(GRID_KEYS)}")
        grid = {k: (tuple(v) if isinstance(v, (list, tuple)) else v)
                for k, v in self.grid.items()}
        track = self.track
        if track is not None and track != "all":
            track = tuple(track)
        hr = self.hist_ranges
        if hr is not None:
            hr = {k: (float(lo), float(hi)) for k, (lo, hi) in hr.items()}
        return dataclasses.replace(
            self, grid=grid, objectives=tuple(self.objectives),
            maximize=tuple(self.maximize), track=track,
            constraints=SW.parse_constraints(self.constraints),
            top_k=int(self.top_k), hist_bins=int(self.hist_bins),
            hist_ranges=hr, chunk_size=int(self.chunk_size),
            deadline_s=(None if self.deadline_s is None
                        else float(self.deadline_s)),
            tenant=str(self.tenant), priority=int(self.priority))

    # -- journal serialization ------------------------------------------

    def to_json(self) -> dict:
        """JSON-able dict (raises ``TypeError`` when the request embeds
        live model objects — such requests are volatile by design)."""
        d = dataclasses.asdict(self.normalized())
        json.dumps(d)       # fail fast on non-journalable payloads
        return d

    @classmethod
    def from_json(cls, d: Mapping) -> "SweepRequest":
        return cls(**d).normalized()


def plan_kwargs(req: SweepRequest) -> dict:
    """The exact :func:`repro.core.stream.plan_stream` kwargs a request
    resolves to.  Shared by the in-process executor and the worker-pool
    processes (:mod:`repro.runtime.workers`) so both sides derive the
    same plan — and therefore the same ``plan.signature`` — from one
    journaled request."""
    kw = dict(req.grid)
    kw.update(chunk_size=req.chunk_size, top_k=req.top_k,
              objectives=req.objectives, maximize=req.maximize,
              track=req.track, constraints=req.constraints,
              hist_bins=req.hist_bins, hist_ranges=req.hist_ranges,
              backend=req.backend, scan_chunks=req.scan_chunks)
    return kw


def _request_fields(req: SweepRequest, kfields: tuple) -> tuple:
    """The tracked-field tuple a solo run of ``req`` would reduce —
    mirrors :func:`repro.core.stream.plan_stream`'s field resolution."""
    objectives = tuple(req.objectives)
    if req.track == "all":
        extra: tuple = kfields
    else:
        extra = tuple(req.track) if req.track is not None else ()
    extra = extra + tuple(f for f, _, _ in SW.parse_constraints(
        req.constraints))
    return objectives + tuple(dict.fromkeys(
        f for f in extra if f not in objectives))


def _fusion_key(req: SweepRequest):
    """Hashable identity of everything fused requests must share: the
    grid axes / model stack, backend, chunk geometry, constraints and
    histogram spec.  ``None`` when the request cannot be keyed (never
    fuses)."""
    try:
        grid_key = tuple(sorted(req.grid.items()))
        hr = req.hist_ranges
        hr_key = tuple(sorted(hr.items())) if hr else None
        key = (grid_key, req.backend, req.chunk_size, req.scan_chunks,
               tuple(req.constraints or ()), req.hist_bins, hr_key)
        hash(key)
        return key
    except TypeError:
        return None


def _fusable(a: SweepRequest, b: SweepRequest) -> bool:
    """Can ``b`` ride ``a``'s dispatch with exact per-member results?

    Requires the shared :func:`_fusion_key`, agreeing min/max senses on
    shared objectives, no deadlines (one member's deadline must never
    truncate another's answer), and — when the head wants a Pareto
    front — follower objectives contained in the head's (the fused
    front is computed over the head's exact objective tuple)."""
    if a.deadline_s is not None or b.deadline_s is not None:
        return False
    ka = _fusion_key(a)
    if ka is None or ka != _fusion_key(b):
        return False
    for o in set(a.objectives) & set(b.objectives):
        if (o in a.maximize) != (o in b.maximize):
            return False
    if a.need_front:
        if not set(b.objectives) <= set(a.objectives):
            return False
        if b.need_front and tuple(b.objectives) != tuple(a.objectives):
            return False
    elif b.need_front:
        return False
    return True


def _fused_request(reqs: Sequence[SweepRequest]) -> SweepRequest:
    """One request whose reductions cover every member exactly: union
    objectives (head order first), union maximize/track, max top-k."""
    head = reqs[0]
    objectives = list(head.objectives)
    for r in reqs[1:]:
        objectives.extend(o for o in r.objectives if o not in objectives)
    maximize = tuple(o for o in objectives
                     if any(o in r.maximize for r in reqs))
    if any(r.track == "all" for r in reqs):
        track: Any = "all"
    else:
        seen: list = []
        for r in reqs:
            seen.extend(t for t in (r.track or ()) if t not in seen)
        track = tuple(seen) or None
    return dataclasses.replace(
        head, objectives=tuple(objectives), maximize=maximize,
        track=track, top_k=max(r.top_k for r in reqs),
        need_front=any(r.need_front for r in reqs), deadline_s=None)


class Ticket:
    """Handle to one submitted request: state, progress, cancel, and
    the (possibly partial) :class:`~repro.core.stream.StreamResult`.

    Thread-safe; returned by :meth:`SweepService.submit`.  ``state``
    walks ``queued → running → done | failed | cancelled``.
    """

    def __init__(self, tid: str, seq: int, request: SweepRequest,
                 service: "SweepService",
                 client_id: Optional[str] = None):
        self.id = tid
        self.seq = seq
        self.request = request
        self.client_id = client_id
        self.tenant = request.tenant
        self.deadline = Deadline.after(request.deadline_s)
        self.state = QUEUED
        self.progress = 0.0
        self.signature: Optional[str] = None
        self.snapshot: Optional[dict] = None
        self._service = service
        self._done = threading.Event()
        self._cancel = threading.Event()
        self._snap_seq = 0
        self._snap_cond = threading.Condition()
        self._result: Optional[ST.StreamResult] = None
        self._error: Optional[BaseException] = None

    @property
    def cancelled(self) -> bool:
        return self._cancel.is_set()

    def done(self) -> bool:
        return self._done.is_set()

    def cancel(self) -> None:
        """Cooperative cancel: a queued request is withdrawn before it
        runs; a running one stops within one chunk dispatch and still
        delivers its consistent ``partial=True`` snapshot."""
        self._cancel.set()
        self._service._cancel_queued(self)

    def result(self, timeout: Optional[float] = None) -> ST.StreamResult:
        """Block for the outcome.  Raises :class:`TimeoutError` when
        not finished within ``timeout``, :class:`ServiceClosedError`
        when the service shuts down (or its worker dies) with the
        ticket still unfinished — never a silent forever-hang —
        re-raises the request's failure, and returns the partial
        snapshot for deadline-expired or mid-run-cancelled requests."""
        deadline = (None if timeout is None
                    else time.monotonic() + timeout)
        while not self._done.is_set():
            remaining = (None if deadline is None
                         else deadline - time.monotonic())
            if remaining is not None and remaining <= 0:
                raise TimeoutError(
                    f"request {self.id} not finished within {timeout}s "
                    f"(state {self.state}, "
                    f"progress {self.progress:.0%})")
            svc = self._service
            if (svc is not None and not svc._worker.is_alive()
                    and not self._done.is_set()):
                raise ServiceClosedError(
                    f"service closed with request {self.id} still "
                    f"{self.state} — nothing will finish it; restart "
                    f"a service over the same spool to resume")
            self._done.wait(0.1 if remaining is None
                            else min(0.1, remaining))
        if self._result is None and self._error is not None:
            raise self._error
        return self._result

    # -- incremental progress snapshots ---------------------------------

    def _update_snapshot(self, snap: dict) -> None:
        with self._snap_cond:
            self.snapshot = snap
            self.progress = float(snap.get("fraction_complete",
                                           self.progress))
            self._snap_seq += 1
            self._snap_cond.notify_all()

    def wait_snapshot(self, last_seq: int = 0,
                      timeout: Optional[float] = None):
        """Block until a progress snapshot newer than ``last_seq``
        lands (or the ticket finishes, or ``timeout``).  Returns
        ``(seq, snapshot)`` — pass ``seq`` back in to long-poll the
        next one; ``snapshot`` is a JSON-able consistent prefix
        summary (``fraction_complete``, running per-objective best,
        front size).  The transport's ``watch`` op streams these to
        subscribed clients."""
        with self._snap_cond:
            if self._snap_seq <= last_seq and not self._done.is_set():
                self._snap_cond.wait(timeout)
            return self._snap_seq, self.snapshot

    def summary(self) -> dict:
        return {"id": self.id, "state": self.state,
                "progress": round(float(self.progress), 4),
                "cancelled": self.cancelled,
                "partial": bool(self._result.partial
                                if self._result is not None else False),
                "signature": (self.signature or "")[:16]}


class SweepService:
    """Persistent crash-safe sweep server over :func:`stream_grid`.

    ``spool_dir`` enables the crash-recovery contract: request journal
    under ``<spool>/requests`` and per-job checkpoints under
    ``<spool>/ckpt/<signature>``; a new service over the same spool
    re-admits unfinished requests (``recover=False`` to skip) and
    resumes them bitwise-exactly.  ``capacity`` caps the admission
    backlog (:class:`~repro.runtime.admission.BackpressureError`
    beyond it).  ``fuse`` enables compatible-request fusion (at most
    ``max_fuse`` members per dispatch).  ``retry_policy`` /
    ``fault_injector`` / ``prefetch`` / ``checkpoint_every_*`` pass
    through to the executor per execution.  All public methods are
    thread-safe; one daemon worker thread executes requests FIFO.
    """

    def __init__(self, spool_dir: Optional[str] = None,
                 capacity: int = 16,
                 fuse: bool = True,
                 max_fuse: int = 8,
                 plan_cache_size: int = 16,
                 keep_finished: int = 256,
                 prefetch: int = ST.DEFAULT_PREFETCH,
                 checkpoint_every_s: float = ST.DEFAULT_CHECKPOINT_EVERY_S,
                 checkpoint_every_steps: Optional[int] = None,
                 checkpoint_keep: int = 3,
                 retry_policy: Optional[RetryPolicy] = None,
                 fault_injector=None,
                 recover: bool = True,
                 poll_s: float = 0.05,
                 tenants: Optional[Mapping] = None,
                 aging_s: float = 30.0,
                 snapshot_every_s: float = 0.5,
                 workers: int = 0,
                 worker_ttl_s: float = 10.0,
                 lease_splits: Optional[int] = None):
        self._own_spool = workers > 0 and spool_dir is None
        if self._own_spool:
            import tempfile
            spool_dir = tempfile.mkdtemp(prefix="sweep-spool-")
        self.spool_dir = spool_dir
        self._queue = AdmissionQueue(capacity,
                                     tenants=dict(tenants or {}),
                                     aging_s=aging_s,
                                     executors=max(1, int(workers)))
        self._snapshot_every_s = float(snapshot_every_s)
        self._fuse = bool(fuse)
        self._max_fuse = max(1, int(max_fuse))
        self._plan_cache_size = max(1, int(plan_cache_size))
        self._keep_finished = max(1, int(keep_finished))
        self._prefetch = prefetch
        self._ckpt_every_s = checkpoint_every_s
        self._ckpt_every_steps = checkpoint_every_steps
        self._ckpt_keep = checkpoint_keep
        self._retry_policy = retry_policy
        self._fault_injector = fault_injector
        self._poll_s = float(poll_s)

        self._lock = threading.Lock()
        self._journal_lock = threading.Lock()
        self._plans: "OrderedDict[str, ST.StreamPlan]" = OrderedDict()
        self._tickets: "OrderedDict[str, Ticket]" = OrderedDict()
        self._by_client: dict = {}
        self._running: dict = {}
        self._seq = 0
        self._t0 = time.monotonic()
        self._shutdown = threading.Event()
        self._paused = threading.Event()
        self.counters = {
            "admitted": 0, "rejected": 0, "completed": 0, "failed": 0,
            "cancelled": 0, "deadline_expired": 0, "fused_requests": 0,
            "executions": 0, "recovered": 0, "recovered_finished": 0,
            "deduped": 0, "plan_hits": 0,
            "plan_misses": 0,
            # Aggregated executor resilience counters:
            "retries": 0, "restarts": 0, "chunks_reissued": 0,
            "elastic_replans": 0, "checkpoints_written": 0,
            "stragglers": 0, "step_timeouts": 0,
            # Worker-pool counters (stay 0 without ``workers=``):
            "pooled_executions": 0, "leases_reissued": 0,
        }
        if spool_dir is not None:
            os.makedirs(self._requests_dir, exist_ok=True)
            if recover:
                self._recover()
        self._pool = None
        self._lease_splits = lease_splits
        if workers > 0:
            from ..runtime import workers as WK
            self._pool = WK.WorkerPool(self.spool_dir, int(workers),
                                       ttl_s=float(worker_ttl_s))
        self._worker = threading.Thread(target=self._run_worker,
                                        daemon=True,
                                        name="sweep-service-worker")
        self._worker.start()

    # -- lifecycle -------------------------------------------------------

    def __enter__(self) -> "SweepService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self, drain: bool = False,
              timeout: Optional[float] = 60.0) -> None:
        """Stop the worker.  ``drain=True`` first waits for the backlog
        to empty; otherwise an in-flight request is preempted within
        one chunk (its ticket gets the partial snapshot and, when
        spooled, its journal stays unfinished so a later service over
        the same spool resumes it).  Tickets left unfinished when the
        worker exits fail fast with :class:`ServiceClosedError` —
        their journal keeps the pre-shutdown state, so recovery over
        the same spool still resumes them."""
        if drain:
            while (self._queue.depth or self._running) \
                    and not self._shutdown.is_set():
                time.sleep(self._poll_s)
        self._shutdown.set()
        self._worker.join(timeout)
        if self._pool is not None:
            self._pool.stop()
            if self._own_spool:
                import shutil
                shutil.rmtree(self.spool_dir, ignore_errors=True)
        for t in self.tickets():
            if not t.done():
                pre_state = t.state
                self._finish(
                    t, FAILED,
                    error=ServiceClosedError(
                        f"service closed with request {t.id} still "
                        f"{pre_state} — restart a service over the "
                        f"same spool to resume it"),
                    journal_state=pre_state)

    def pause(self) -> None:
        """Stop claiming new requests (admission stays open) — the
        deterministic knob backpressure/fusion tests are built on.
        Pausing at the queue level closes the race where a worker
        already blocked inside ``take_batch`` claims a submit that
        lands after ``pause()`` returns."""
        self._paused.set()
        self._queue.pause()

    def resume(self) -> None:
        self._queue.resume()
        self._paused.clear()

    # -- submission ------------------------------------------------------

    def submit(self, request: SweepRequest,
               client_id: Optional[str] = None) -> Ticket:
        """Admit one request.  Raises
        :class:`~repro.runtime.admission.BackpressureError` when the
        backlog is at capacity or the tenant's pending cap is hit (the
        request is NOT enqueued), and ``ValueError`` on malformed
        requests — both before any state is journaled.

        ``client_id`` makes the submit **idempotent**: resubmitting
        the same id returns the existing ticket — queued, running or
        already finished, including finished requests recovered from
        the journal after a server restart — instead of executing
        twice.  The id is validated against the original request
        (``ValueError`` on reuse with a different one); this is what
        lets :class:`repro.core.client.SweepClient` blindly retry a
        submit whose response was lost to a dropped connection or a
        server crash."""
        if self._shutdown.is_set():
            raise ServiceClosedError("service is shut down")
        req = request.normalized()
        with self._lock:
            if client_id is not None:
                existing = self._by_client.get(client_id)
                if existing is not None:
                    if existing.request != req:
                        raise ValueError(
                            f"client id {client_id!r} was already used "
                            f"for a different request "
                            f"({existing.id}) — idempotent retries "
                            f"must resubmit the identical request")
                    self.counters["deduped"] += 1
                    return existing
            self._seq += 1
            seq = self._seq
            t = Ticket(f"req-{seq:06d}", seq, req, self,
                       client_id=client_id)
            if client_id is not None:
                self._by_client[client_id] = t
        try:
            self._queue.offer(t, tenant=req.tenant,
                              priority=req.priority)
        except BackpressureError:
            with self._lock:
                self.counters["rejected"] += 1
                if client_id is not None \
                        and self._by_client.get(client_id) is t:
                    del self._by_client[client_id]
            raise
        self._remember(t)
        self._journal(t)
        with self._lock:
            self.counters["admitted"] += 1
        return t

    def set_tenant(self, name: str, weight: float = 1.0,
                   max_pending: Optional[int] = None) -> None:
        """Register (or update) one tenant's fairness policy — DRR
        weight and optional queued+in-flight pending cap."""
        self._queue.set_tenant(name, weight=weight,
                               max_pending=max_pending)

    def get(self, ticket_id: str) -> Optional[Ticket]:
        with self._lock:
            return self._tickets.get(ticket_id)

    def tickets(self) -> list:
        with self._lock:
            return list(self._tickets.values())

    # -- health ----------------------------------------------------------

    def health(self) -> dict:
        """Liveness + queue depth + per-request progress + cache and
        resilience counters (everything JSON-able)."""
        with self._lock:
            counters = dict(self.counters)
            tickets = {tid: t.summary()
                       for tid, t in self._tickets.items()}
            plan_cache = {"size": len(self._plans),
                          "capacity": self._plan_cache_size,
                          "hits": counters.pop("plan_hits"),
                          "misses": counters.pop("plan_misses")}
            running = sorted(self._running)
        workers = (None if self._pool is None else
                   {"n": self._pool.n, "alive": self._pool.alive(),
                    "pids": self._pool.pids()})
        return {
            "workers": workers,
            "alive": self._worker.is_alive()
            and not self._shutdown.is_set(),
            "paused": self._paused.is_set(),
            "uptime_s": round(time.monotonic() - self._t0, 3),
            "queue_depth": self._queue.depth,
            "capacity": self._queue.capacity,
            "in_flight": running,
            "requests": tickets,
            "counters": counters,
            "plan_cache": plan_cache,
            "step_cache": B.step_cache_stats(),
        }

    # -- internals: journal & recovery ----------------------------------

    @property
    def _requests_dir(self) -> str:
        return os.path.join(self.spool_dir, "requests")

    def _ckpt_dir(self, signature: str) -> str:
        return os.path.join(self.spool_dir, "ckpt", signature[:24])

    def _journal(self, t: Ticket, state: Optional[str] = None) -> None:
        """Atomically persist one ticket's journal entry (no-op without
        a spool or for non-JSON-able requests).  ``state`` overrides
        the ticket state — used to leave a shutdown-preempted request
        marked unfinished so recovery re-admits it.  Finished DONE
        entries embed the exact result (:func:`repro.core.stream.
        result_to_json`) so an idempotent resubmit after a server
        restart re-attaches and gets the bitwise-identical answer
        without re-executing."""
        if self.spool_dir is None:
            return
        # One ticket can be journaled concurrently (the submitting
        # thread right after admission, the worker as it claims): the
        # lock keeps the shared tmp path from racing os.replace, and
        # reading t.state *inside* the lock makes the last writer
        # persist the freshest state.
        with self._journal_lock:
            journal_state = state or t.state
            try:
                payload = {"id": t.id, "seq": t.seq,
                           "state": journal_state,
                           "signature": t.signature,
                           "client_id": t.client_id,
                           "request": t.request.to_json(),
                           "error": (str(t._error)
                                     if t._error is not None else None)}
                if journal_state == DONE and t._result is not None:
                    payload["result"] = ST.result_to_json(t._result)
            except TypeError:
                return      # volatile request (live model objects)
            path = os.path.join(self._requests_dir, f"{t.id}.json")
            tmp = path + ".tmp"
            with open(tmp, "w") as fh:
                json.dump(payload, fh)
                fh.flush()
                os.fsync(fh.fileno())
            os.replace(tmp, path)

    def _recover(self) -> None:
        """Re-admit every journaled request left queued or running by a
        previous (possibly SIGKILL'd) service over this spool —
        original admission order, bypassing the capacity cap (admitted
        work is never dropped)."""
        entries = []
        for name in sorted(os.listdir(self._requests_dir)):
            if not name.endswith(".json"):
                continue
            try:
                with open(os.path.join(self._requests_dir, name)) as fh:
                    entries.append(json.load(fh))
            except (OSError, ValueError):
                continue        # torn foreign write: skip, never crash
        self._seq = max([int(e.get("seq", 0)) for e in entries],
                        default=0)
        pending = [e for e in entries
                   if e.get("state") in (QUEUED, RUNNING)]
        pending.sort(key=lambda e: int(e.get("seq", 0)))
        for e in reversed(pending):     # readmit prepends: reverse seq
            try:
                req = SweepRequest.from_json(e["request"])
            except (TypeError, ValueError, KeyError):
                continue
            t = Ticket(e["id"], int(e.get("seq", 0)), req, self,
                       client_id=e.get("client_id"))
            t.signature = e.get("signature")
            self._queue.readmit(t, tenant=req.tenant,
                                priority=req.priority)
            self._remember(t)
            self._journal(t)
            self.counters["recovered"] += 1
        # Finished requests with a journaled result come back as DONE
        # tickets (never re-executed): an idempotent resubmit from a
        # client that crashed mid-wait re-attaches and reads the exact
        # persisted answer.
        for e in entries:
            if e.get("state") != DONE or e.get("result") is None:
                continue
            try:
                req = SweepRequest.from_json(e["request"])
                res = ST.result_from_json(e["result"])
            except (TypeError, ValueError, KeyError):
                continue
            t = Ticket(e["id"], int(e.get("seq", 0)), req, self,
                       client_id=e.get("client_id"))
            t.signature = e.get("signature")
            t.state = DONE
            t.progress = float(res.stats.get("fraction_complete", 1.0))
            t._result = res
            t._done.set()
            self._remember(t)
            self.counters["recovered_finished"] += 1

    def _remember(self, t: Ticket) -> None:
        with self._lock:
            self._tickets[t.id] = t
            if t.client_id is not None:
                self._by_client[t.client_id] = t
            while len(self._tickets) > self._keep_finished:
                for tid, old in self._tickets.items():
                    if old.done():
                        del self._tickets[tid]
                        if old.client_id is not None and \
                                self._by_client.get(old.client_id) \
                                is old:
                            del self._by_client[old.client_id]
                        break
                else:
                    break       # nothing evictable: keep them all

    def _cancel_queued(self, t: Ticket) -> None:
        if self._queue.remove(t):
            self._finish(t, CANCELLED,
                         error=CancelledError(
                             f"request {t.id} cancelled before "
                             f"execution"))

    def _finish(self, t: Ticket, state: str, result=None, error=None,
                journal_state: Optional[str] = None) -> None:
        t.state = state
        t._result = result
        t._error = error
        with self._lock:
            key = {DONE: "completed", FAILED: "failed",
                   CANCELLED: "cancelled"}[state]
            self.counters[key] += 1
        self._journal(t, state=journal_state)
        t._done.set()
        with t._snap_cond:          # wake watchers blocked on progress
            t._snap_cond.notify_all()

    # -- internals: planning --------------------------------------------

    def _plan_for(self, req: SweepRequest) -> ST.StreamPlan:
        """Resolve (or fetch) the content-signature-keyed plan — the
        LRU that keeps :func:`repro.core.backend.cached_step` hitting
        across requests for byte-identical jobs."""
        plan = ST.plan_stream(**plan_kwargs(req))
        with self._lock:
            cached = self._plans.get(plan.signature)
            if cached is not None:
                self.counters["plan_hits"] += 1
                self._plans.move_to_end(plan.signature)
                return cached
            self.counters["plan_misses"] += 1
            self._plans[plan.signature] = plan
            while len(self._plans) > self._plan_cache_size:
                self._plans.popitem(last=False)
        return plan

    # -- internals: execution -------------------------------------------

    def _run_worker(self) -> None:
        while not self._shutdown.is_set():
            if self._paused.is_set():
                time.sleep(self._poll_s)
                continue
            compat = self._compatible if self._fuse else None
            batch = self._queue.take_batch(timeout=self._poll_s,
                                           compatible=compat,
                                           max_batch=self._max_fuse)
            if batch:
                try:
                    self._execute(batch)
                finally:
                    # Return the claimed in-flight slots so per-tenant
                    # pending caps see the true outstanding count.
                    for t in batch:
                        self._queue.release(t.tenant)

    def _compatible(self, head: Ticket, other: Ticket) -> bool:
        return (head.request.fuse and other.request.fuse
                and not other.cancelled
                and _fusable(head.request, other.request))

    def _execute(self, batch: list) -> None:
        members = []
        for t in batch:
            if t.cancelled:
                self._finish(t, CANCELLED,
                             error=CancelledError(
                                 f"request {t.id} cancelled before "
                                 f"execution"))
            else:
                members.append(t)
        if not members:
            return
        fused = (_fused_request([t.request for t in members])
                 if len(members) > 1 else members[0].request)
        try:
            plan = self._plan_for(fused)
        except Exception as e:
            for t in members:
                self._finish(t, FAILED, error=e)
            return
        deadline = Deadline.earliest(*[t.deadline for t in members])
        cause = {"why": None}

        def should_stop() -> bool:
            if deadline.expired():
                cause["why"] = "deadline"
                return True
            if all(t.cancelled for t in members):
                cause["why"] = "cancel"
                return True
            if self._shutdown.is_set():
                cause["why"] = "shutdown"
                return True
            return False

        def on_progress(frac: float) -> None:
            for t in members:
                t.progress = frac

        def on_snapshot(snap: dict) -> None:
            for t in members:
                t._update_snapshot(snap)

        for t in members:
            t.state = RUNNING
            t.signature = plan.signature
            self._journal(t)
        with self._lock:
            self.counters["executions"] += 1
            if len(members) > 1:
                self.counters["fused_requests"] += len(members)
            for t in members:
                self._running[t.id] = t
        use_pool = (self._pool is not None
                    and self._fault_injector is None
                    and all(t.request.deadline_s is None
                            for t in members))
        if use_pool:
            try:
                fused.to_json()
            except TypeError:
                use_pool = False    # volatile request: run in-process
        try:
            if use_pool:
                res = self._execute_pooled(fused, plan, should_stop,
                                           cause, on_progress,
                                           on_snapshot)
            else:
                res = ST.stream_grid(
                    plan=plan, prefetch=self._prefetch,
                    checkpoint_dir=(self._ckpt_dir(plan.signature)
                                    if self.spool_dir is not None
                                    else None),
                    checkpoint_every_s=self._ckpt_every_s,
                    checkpoint_every_steps=self._ckpt_every_steps,
                    checkpoint_keep=self._ckpt_keep,
                    retry_policy=self._retry_policy,
                    fault_injector=self._fault_injector,
                    should_stop=should_stop, on_progress=on_progress,
                    on_snapshot=on_snapshot,
                    snapshot_every_s=self._snapshot_every_s)
        except _PoolPreempted:
            # Shutdown mid-pooled-run: leave the tickets unfinished —
            # close() fails them with journal state RUNNING, and a new
            # service over this spool reattaches to the lease board.
            return
        except _PoolCancelled:
            for t in members:
                self._finish(t, CANCELLED,
                             error=CancelledError(
                                 f"request {t.id} cancelled during "
                                 f"pooled execution"))
            return
        except Exception as e:
            for t in members:
                self._finish(t, FAILED, error=e)
            return
        finally:
            with self._lock:
                for t in members:
                    self._running.pop(t.id, None)
        with self._lock:
            for key in ("retries", "restarts", "chunks_reissued",
                        "elastic_replans", "checkpoints_written",
                        "stragglers", "step_timeouts"):
                self.counters[key] += int(res.stats.get(key, 0))
            if res.partial and cause["why"] == "deadline":
                self.counters["deadline_expired"] += len(members)
        preempted = res.partial and cause["why"] == "shutdown"
        for t in members:
            out = (self._member_result(fused, plan, res, t.request,
                                       len(members))
                   if len(members) > 1 else res)
            t.progress = res.stats["fraction_complete"]
            if t.cancelled:
                self._finish(t, CANCELLED, result=out)
            else:
                # A shutdown-preempted request still delivers its
                # partial snapshot, but its journal stays RUNNING so a
                # later service over this spool resumes it to
                # completion from the terminal checkpoint.
                self._finish(t, DONE, result=out,
                             journal_state=(RUNNING if preempted
                                            else None))

    def _execute_pooled(self, fused: SweepRequest, plan: ST.StreamPlan,
                        should_stop, cause, on_progress,
                        on_snapshot) -> ST.StreamResult:
        """Run one (possibly fused) request on the worker pool: split
        the flat-index space into chunk-range leases on the shared
        spool, let the workers stream them, fold the parts into one
        bitwise-exact result (:func:`repro.core.stream.merge_results`).
        The coordinator only polls the lease board: it respawns dead
        workers (whose leases are reclaimed from their own carry
        checkpoints) and synthesizes progress snapshots from finished
        parts."""
        from ..runtime import workers as WK
        handle = WK.dispatch_job(
            self.spool_dir, fused, plan=plan,
            n_leases=(self._lease_splits
                      if self._lease_splits is not None
                      else max(2 * self._pool.n, 4)),
            checkpoint_every_steps=self._ckpt_every_steps,
            prefetch=self._prefetch)
        last_snap = 0.0
        while True:
            st = handle.poll()
            if st["failed"]:
                handle.cancel()
                errs = "; ".join(
                    f"lease {ls['i']} [{ls['start']}, {ls['stop']}): "
                    f"{ls.get('error')}" for ls in st["failed"])
                raise RuntimeError(f"pooled execution of "
                                   f"{plan.signature[:12]} failed: {errs}")
            if st["done"]:
                break
            if should_stop():
                if cause["why"] == "cancel":
                    handle.cancel()
                    self._await_quiesce(handle)
                    raise _PoolCancelled()
                raise _PoolPreempted()
            self._pool.ensure()
            on_progress(float(st["fraction"]))
            now = time.monotonic()
            if now - last_snap >= self._snapshot_every_s:
                last_snap = now
                on_snapshot(handle.snapshot(st))
            time.sleep(self._poll_s)
        res = handle.result()
        with self._lock:
            self.counters["pooled_executions"] += 1
            self.counters["leases_reissued"] += sum(
                max(0, int(ls["attempt"]) - 1) for ls in st["leases"])
        return res

    def _await_quiesce(self, handle, timeout: float = 30.0) -> None:
        """After a pooled cancel: wait (bounded) until no lease is
        still leased — workers notice the cancel flag within one
        heartbeat cycle and abort cooperatively."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            if handle.poll()["states"].get("leased", 0) == 0:
                return
            time.sleep(self._poll_s)

    @staticmethod
    def _member_result(fused: SweepRequest, plan: ST.StreamPlan,
                       res: ST.StreamResult, req: SweepRequest,
                       n_members: int) -> ST.StreamResult:
        """Slice one member's exact deliverables out of the fused
        result: per-channel argmin/count/bounds dicts restrict to the
        member's tracked fields, top-k rows select the member's
        objectives (first ``top_k`` columns of the fused k-best
        table), and the shared front is handed over only when the
        member's objective tuple equals the fused tuple (otherwise the
        member asked for ``need_front=False`` and gets an empty
        front)."""
        obj_idx = [fused.objectives.index(o) for o in req.objectives]
        mfields = _request_fields(req, plan.kfields)
        if tuple(req.objectives) == tuple(fused.objectives):
            front_i, front_v = res.front_indices, res.front_values
        else:
            front_i = np.empty((0,), np.int64)
            front_v = np.empty((0, len(req.objectives)))
        hist = None
        if res.hist is not None:
            hist = {f: res.hist[f] for f in req.objectives}
        stats = dict(res.stats, fused_members=float(n_members))
        return dataclasses.replace(
            res,
            objectives=tuple(req.objectives),
            maximize=tuple(o for o in req.objectives
                           if o in req.maximize),
            min_val={f: res.min_val[f] for f in mfields},
            min_idx={f: res.min_idx[f] for f in mfields},
            finite_counts={f: res.finite_counts[f] for f in mfields},
            channel_min={f: res.channel_min[f] for f in mfields},
            channel_max={f: res.channel_max[f] for f in mfields},
            topk_idx=res.topk_idx[obj_idx][:, :req.top_k],
            topk_val=res.topk_val[obj_idx][:, :req.top_k],
            front_indices=front_i, front_values=front_v,
            hist=hist, stats=stats)


# ---------------------------------------------------------------------------
# CLI: python -m repro.service
# ---------------------------------------------------------------------------


def _result_summary(t: Ticket) -> dict:
    out = t.summary()
    if t.state == DONE and t._result is not None:
        r = t._result
        field = r.objectives[0]
        try:
            out["argmin"] = {k: (float(v) if isinstance(v, (int, float))
                                 else str(v))
                             for k, v in r.argmin(field).items()}
        except ValueError as e:     # all-infeasible (or empty partial)
            out["argmin_error"] = str(e)
        out["fraction_complete"] = r.stats["fraction_complete"]
        out["configs_per_s"] = round(r.stats["configs_per_s"], 1)
    elif t._error is not None:
        out["error"] = str(t._error)
    return out


def _serve(svc: "SweepService", listen: Optional[str],
           unix: Optional[str],
           auth_token: Optional[str] = None) -> int:
    """Networked mode: serve ``svc`` over TCP or a Unix socket until
    SIGTERM/SIGINT, then drain gracefully.  Prints one JSON ready line
    (``{"listening": <address>}``) once the socket is bound, so
    supervisors and tests can wait for startup."""
    import signal

    from ..runtime.transport import SweepServer, parse_address

    if unix is not None:
        server = SweepServer(svc, unix_path=unix, own_service=True,
                             auth_token=auth_token)
    else:
        kind, host, port = parse_address(listen)
        if kind != "tcp":
            raise SystemExit(f"--listen wants HOST:PORT, got {listen!r}")
        server = SweepServer(svc, host=host, port=port,
                             own_service=True, auth_token=auth_token)
    stop = threading.Event()
    for signum in (signal.SIGTERM, signal.SIGINT):
        signal.signal(signum, lambda *_: stop.set())
    server.start()
    print(json.dumps({"listening": server.address}), flush=True)
    try:
        while not stop.is_set():
            stop.wait(0.2)
    finally:
        server.close(drain=True)
    print(json.dumps({"health": svc.health()}))
    return 0


def main(argv: Optional[Sequence[str]] = None) -> int:
    """Spool-backed sweep server.  Batch mode (default): recover + run
    journaled requests, then requests from ``--requests`` (a JSON-lines
    file of :meth:`SweepRequest.to_json` payloads), print one JSON
    summary per finished request plus the final health snapshot.
    Networked mode (``--listen HOST:PORT`` or ``--unix PATH``): serve
    the framed-JSON protocol of :mod:`repro.runtime.transport` until
    SIGTERM/SIGINT, then drain gracefully."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.service",
        description="Persistent crash-safe sweep server over "
                    "repro.core.stream.stream_grid.")
    ap.add_argument("--spool", default=None,
                    help="spool directory (journal + checkpoints); "
                         "restarting over the same spool resumes "
                         "unfinished requests bitwise-exactly")
    ap.add_argument("--requests", default=None,
                    help="JSON-lines file of SweepRequest payloads to "
                         "submit")
    ap.add_argument("--capacity", type=int, default=16)
    ap.add_argument("--checkpoint-every-steps", type=int, default=None)
    ap.add_argument("--timeout-s", type=float, default=None,
                    help="per-request result timeout")
    ap.add_argument("--listen", default=None, metavar="HOST:PORT",
                    help="serve the framed-JSON protocol on a TCP "
                         "socket (port 0 picks a free port, printed "
                         "in the ready line)")
    ap.add_argument("--unix", default=None, metavar="PATH",
                    help="serve on a Unix-domain socket at PATH")
    ap.add_argument("--tenant", action="append", default=[],
                    metavar="NAME:WEIGHT[:MAX_PENDING]",
                    help="register a tenant fairness policy "
                         "(repeatable)")
    ap.add_argument("--workers", type=int, default=0,
                    help="spawn N worker processes over the spool and "
                         "run eligible requests via chunk-range "
                         "leasing (0 = in-process execution)")
    ap.add_argument("--worker-ttl-s", type=float, default=10.0,
                    help="lease heartbeat TTL: a worker silent this "
                         "long is presumed dead and its range is "
                         "reissued from its carry checkpoint")
    ap.add_argument("--lease-splits", type=int, default=None,
                    help="lease count per job (default 2x workers, "
                         "min 4)")
    ap.add_argument("--auth-token", default=None,
                    help="shared secret for the socket handshake "
                         "(clients must pass auth=; unauthenticated "
                         "connections are rejected before any JSON "
                         "is parsed)")
    args = ap.parse_args(argv)

    svc = SweepService(spool_dir=args.spool, capacity=args.capacity,
                       checkpoint_every_steps=args.checkpoint_every_steps,
                       workers=args.workers,
                       worker_ttl_s=args.worker_ttl_s,
                       lease_splits=args.lease_splits)
    for spec in args.tenant:
        parts = spec.split(":")
        svc.set_tenant(parts[0],
                       weight=float(parts[1]) if len(parts) > 1 else 1.0,
                       max_pending=(int(parts[2]) if len(parts) > 2
                                    else None))
    if args.listen or args.unix:
        return _serve(svc, args.listen, args.unix,
                      auth_token=args.auth_token)
    try:
        tickets = svc.tickets()     # recovered work first
        if args.requests:
            with open(args.requests) as fh:
                for line in fh:
                    line = line.strip()
                    if not line:
                        continue
                    tickets.append(svc.submit(
                        SweepRequest.from_json(json.loads(line))))
        for t in tickets:
            try:
                t.result(args.timeout_s)
            except Exception:
                pass
            print(json.dumps(_result_summary(t)))
        print(json.dumps({"health": svc.health()}))
    finally:
        svc.close()
    return 0


if __name__ == "__main__":      # pragma: no cover
    sys.exit(main())
