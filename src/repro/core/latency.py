"""End-to-end frame latency for the two topologies (paper §1: the DOSC
system claims "significant benefits in terms of communication costs,
latency constraints and privacy").

Latency of one hand-tracking result, per camera frame, for an N-camera
rig.  The key structural difference:

* **centralized** — the aggregator serializes ALL cameras' work
  (N x (DetNet_amortized + KeyNet)) behind each result, and the raw frame
  crosses the slow MIPI first;
* **distributed** — DetNet runs *in parallel* on the N sensors (each at
  1/4 the aggregator's throughput), only the ROI crosses MIPI, and the
  aggregator's queue holds KeyNets only.

Uses the same Eq. 6 / Eq. 9 building blocks as the power model — one more
consumer of the semi-analytical counts.

Two granularities live here:

* :func:`centralized_latency` / :func:`distributed_latency` — the paper's
  two named topologies, with an integer ``detnet_every`` ROI-reuse knob.
* :func:`cut_latency` — the *generalized* per-cut model for any partition
  index over the concatenated DetNet ++ KeyNet layer list, parameterized by
  the same fps knobs as the power model.  This is the scalar reference for
  the vectorized ``latency`` channel of
  :func:`repro.core.sweep.evaluate_grid` (the cycle prefix-sums of
  :mod:`repro.core.arrays` are its lowering); ``tests/test_sweep.py`` pins
  the two to ≤1e-6 relative parity.
"""

from __future__ import annotations

import dataclasses

from . import energy as E
from . import rbe
from .arrays import RATE_DETNET, RATE_KEYNET, mipi_payloads
from .constants import (CAMERA_FPS, DETNET_FPS, KEYNET_FPS, MIPI,
                        NUM_CAMERAS, ON_SENSOR_SCALE, RBE, T_SENSE_S,
                        TECH_NODES, UTSV, TechNode)
from .handtracking import (FULL_FRAME_BYTES, ROI_BYTES, build_detnet,
                           build_keynet)
from .workloads import NNWorkload


@dataclasses.dataclass(frozen=True)
class LatencyBreakdown:
    name: str
    t_expose: float
    t_readout: float
    t_detnet: float        # amortized per frame (ROI reuse), own camera
    t_comm_roi: float
    t_queue: float         # other cameras' work serialized ahead of us
    t_keynet: float

    @property
    def total(self) -> float:
        return (self.t_expose + self.t_readout + self.t_detnet
                + self.t_comm_roi + self.t_queue + self.t_keynet)


def _node(x) -> TechNode:
    return TECH_NODES[x] if isinstance(x, str) else x


def centralized_latency(agg_node: str | TechNode = "7nm",
                        detnet_every: int = 3,
                        num_cameras: int = NUM_CAMERAS
                        ) -> LatencyBreakdown:
    node = _node(agg_node)
    det, key = build_detnet(), build_keynet()
    t_det = rbe.processing_time_s(det, node) / detnet_every
    t_key = rbe.processing_time_s(key, node)
    return LatencyBreakdown(
        name=f"centralized[A={node.name}]",
        t_expose=T_SENSE_S,
        t_readout=E.comm_time(FULL_FRAME_BYTES, MIPI),
        t_detnet=t_det,
        t_comm_roi=0.0,     # crop is local to the aggregator
        t_queue=(num_cameras - 1) * (t_det + t_key),
        t_keynet=t_key,
    )


def distributed_latency(agg_node: str | TechNode = "7nm",
                        sensor_node: str | TechNode = "7nm",
                        detnet_every: int = 3,
                        num_cameras: int = NUM_CAMERAS
                        ) -> LatencyBreakdown:
    agg, sen = _node(agg_node), _node(sensor_node)
    det, key = build_detnet(), build_keynet()
    t_key = rbe.processing_time_s(key, agg)
    return LatencyBreakdown(
        name=f"distributed[A={agg.name},O={sen.name}]",
        t_expose=T_SENSE_S,
        t_readout=E.comm_time(FULL_FRAME_BYTES, UTSV),
        t_detnet=rbe.processing_time_s(det, sen, scale=ON_SENSOR_SCALE)
        / detnet_every,     # parallel per sensor: no cross-camera queue
        t_comm_roi=E.comm_time(ROI_BYTES, MIPI),
        t_queue=(num_cameras - 1) * t_key,   # aggregator runs KeyNet only
        t_keynet=t_key,
    )


@dataclasses.dataclass(frozen=True)
class CutLatency:
    """Per-result latency decomposition for one partition cut.

    All times are seconds on the critical path of one hand-tracking result.
    ``t_detnet`` / ``t_comm_mipi`` are amortized by the ROI-reuse ratio
    ``min(1, detnet_fps / camera_fps)`` — DetNet work (and the payloads it
    produces) only lands on the critical path when DetNet actually runs.
    """

    cut: int
    t_expose: float
    t_readout: float       # full frame over the camera-side link (Eq. 6)
    t_detnet: float        # sensor prefix + aggregator suffix, amortized
    t_comm_mipi: float     # cut payloads over MIPI (DetNet-rate amortized)
    t_queue: float         # other cameras' aggregator work ahead of us
    t_keynet: float        # sensor prefix + aggregator suffix

    @property
    def total(self) -> float:
        return (self.t_expose + self.t_readout + self.t_detnet
                + self.t_comm_mipi + self.t_queue + self.t_keynet)


def _cycles(layers, scale: float) -> float:
    """Eq. 9 cycle count for a span of layers at one engine scale."""
    return sum(l.macs / rbe.mac_per_cycle(l, RBE, scale) for l in layers)


def cut_latency(cut: int,
                agg_node: str | TechNode = "7nm",
                sensor_node: str | TechNode = "7nm",
                detnet: NNWorkload | None = None,
                keynet: NNWorkload | None = None,
                num_cameras: int = NUM_CAMERAS,
                camera_fps: float = CAMERA_FPS,
                detnet_fps: float = DETNET_FPS,
                keynet_fps: float = KEYNET_FPS) -> CutLatency:
    """End-to-end result latency for an arbitrary partition cut.

    Generalizes :func:`centralized_latency` (``cut == 0``) and
    :func:`distributed_latency` (``cut == len(DetNet)``) to every layer
    boundary, with the integer ``detnet_every`` knob replaced by the
    continuous amortization ratio ``min(1, detnet_fps / camera_fps)``.  At
    ``cut == 0`` it reduces *exactly* to the centralized helper (for
    ``detnet_every == camera_fps / detnet_fps``); at the paper's split it
    additionally counts the tiny amortized DetNet-output payload that the
    topology-specific helper ignores.

    This is the scalar reference implementation of the grid engine's
    ``latency`` channel; both consume the payload plan of
    :func:`repro.core.arrays.mipi_payloads`.
    """
    agg, sen = _node(agg_node), _node(sensor_node)
    det = detnet or build_detnet()
    key = keynet or build_keynet()
    n_det = len(det.layers)
    n_all = n_det + len(key.layers)
    if not 0 <= cut <= n_all:
        raise ValueError(f"cut {cut} outside [0, {n_all}]")
    cd = min(cut, n_det)               # DetNet layers on-sensor
    ck = max(0, cut - n_det)           # KeyNet layers on-sensor
    amort = min(1.0, detnet_fps / camera_fps)

    t_det_sen = _cycles(det.layers[:cd], ON_SENSOR_SCALE) / sen.f_clk * amort
    t_det_agg = _cycles(det.layers[cd:], 1.0) / agg.f_clk * amort
    t_key_sen = _cycles(key.layers[:ck], ON_SENSOR_SCALE) / sen.f_clk
    t_key_agg = _cycles(key.layers[ck:], 1.0) / agg.f_clk

    # Cut payloads crossing MIPI on the critical path.  Camera-rate payloads
    # (the centralized raw frame) ARE the readout and are counted there.
    pay = {RATE_DETNET: 0.0, RATE_KEYNET: 0.0}
    for nbytes, tag in mipi_payloads(cut, det, key):
        if tag in pay:
            pay[tag] += nbytes
    t_comm = (pay[RATE_DETNET] * amort + pay[RATE_KEYNET]) / MIPI.bandwidth

    return CutLatency(
        cut=cut,
        t_expose=T_SENSE_S,
        t_readout=E.comm_time(FULL_FRAME_BYTES, UTSV if cut > 0 else MIPI),
        t_detnet=t_det_sen + t_det_agg,
        t_comm_mipi=t_comm,
        t_queue=(num_cameras - 1) * (t_det_agg + t_key_agg),
        t_keynet=t_key_sen + t_key_agg,
    )


def latency_comparison(**kw) -> dict[str, float]:
    c = centralized_latency(**kw)
    d = distributed_latency(**kw)
    return {
        "centralized_ms": c.total * 1e3,
        "distributed_ms": d.total * 1e3,
        "_saving": 1.0 - d.total / c.total,
        "_readout_saving_ms": (c.t_readout - d.t_readout) * 1e3,
        "_queue_saving_ms": (c.t_queue - d.t_queue) * 1e3,
    }
