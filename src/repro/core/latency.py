"""End-to-end frame latency for the two topologies (paper §1: the DOSC
system claims "significant benefits in terms of communication costs,
latency constraints and privacy").

Latency of one hand-tracking result, per camera frame, for an N-camera
rig.  The key structural difference:

* **centralized** — the aggregator serializes ALL cameras' work
  (N x (DetNet_amortized + KeyNet)) behind each result, and the raw frame
  crosses the slow MIPI first;
* **distributed** — DetNet runs *in parallel* on the N sensors (each at
  1/4 the aggregator's throughput), only the ROI crosses MIPI, and the
  aggregator's queue holds KeyNets only.

Uses the same Eq. 6 / Eq. 9 building blocks as the power model — one more
consumer of the semi-analytical counts.
"""

from __future__ import annotations

import dataclasses

from . import energy as E
from . import rbe
from .constants import (MIPI, NUM_CAMERAS, ON_SENSOR_SCALE, T_SENSE_S,
                        TECH_NODES, UTSV, TechNode)
from .handtracking import (FULL_FRAME_BYTES, ROI_BYTES, build_detnet,
                           build_keynet)


@dataclasses.dataclass(frozen=True)
class LatencyBreakdown:
    name: str
    t_expose: float
    t_readout: float
    t_detnet: float        # amortized per frame (ROI reuse), own camera
    t_comm_roi: float
    t_queue: float         # other cameras' work serialized ahead of us
    t_keynet: float

    @property
    def total(self) -> float:
        return (self.t_expose + self.t_readout + self.t_detnet
                + self.t_comm_roi + self.t_queue + self.t_keynet)


def _node(x) -> TechNode:
    return TECH_NODES[x] if isinstance(x, str) else x


def centralized_latency(agg_node: str | TechNode = "7nm",
                        detnet_every: int = 3,
                        num_cameras: int = NUM_CAMERAS
                        ) -> LatencyBreakdown:
    node = _node(agg_node)
    det, key = build_detnet(), build_keynet()
    t_det = rbe.processing_time_s(det, node) / detnet_every
    t_key = rbe.processing_time_s(key, node)
    return LatencyBreakdown(
        name=f"centralized[A={node.name}]",
        t_expose=T_SENSE_S,
        t_readout=E.comm_time(FULL_FRAME_BYTES, MIPI),
        t_detnet=t_det,
        t_comm_roi=0.0,     # crop is local to the aggregator
        t_queue=(num_cameras - 1) * (t_det + t_key),
        t_keynet=t_key,
    )


def distributed_latency(agg_node: str | TechNode = "7nm",
                        sensor_node: str | TechNode = "7nm",
                        detnet_every: int = 3,
                        num_cameras: int = NUM_CAMERAS
                        ) -> LatencyBreakdown:
    agg, sen = _node(agg_node), _node(sensor_node)
    det, key = build_detnet(), build_keynet()
    t_key = rbe.processing_time_s(key, agg)
    return LatencyBreakdown(
        name=f"distributed[A={agg.name},O={sen.name}]",
        t_expose=T_SENSE_S,
        t_readout=E.comm_time(FULL_FRAME_BYTES, UTSV),
        t_detnet=rbe.processing_time_s(det, sen, scale=ON_SENSOR_SCALE)
        / detnet_every,     # parallel per sensor: no cross-camera queue
        t_comm_roi=E.comm_time(ROI_BYTES, MIPI),
        t_queue=(num_cameras - 1) * t_key,   # aggregator runs KeyNet only
        t_keynet=t_key,
    )


def latency_comparison(**kw) -> dict[str, float]:
    c = centralized_latency(**kw)
    d = distributed_latency(**kw)
    return {
        "centralized_ms": c.total * 1e3,
        "distributed_ms": d.total * 1e3,
        "_saving": 1.0 - d.total / c.total,
        "_readout_saving_ms": (c.t_readout - d.t_readout) * 1e3,
        "_queue_saving_ms": (c.t_queue - d.t_queue) * 1e3,
    }
