"""Three-term TPU roofline from compiled dry-run artifacts.

    compute term    = HLO_FLOPs        / (chips x peak_FLOP/s)
    memory term     = HLO_bytes        / (chips x HBM_bw)
    collective term = collective_bytes / (chips x link_bw)

All terms are *seconds per step*; the dominant (largest) term is the
bottleneck, and ``max_term / sum-ish`` gives the achievable fraction.  This
is the paper's Fig. 4 methodology lifted from the RBE (weight-streaming
roofline) to the TPU (HBM + ICI roofline).

Notes on sources:
* FLOPs/bytes come from ``compiled.cost_analysis()`` — these are *per-device*
  numbers in SPMD mode (the program is the per-device program), so the
  "/chips" division is already materialized; we keep the formulas explicit.
* collective bytes come from :mod:`repro.core.hlo_analysis` over the HLO text.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

from .constants import TPU_V5E, TPUChipSpec
from .hlo_analysis import CollectiveSummary


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    # raw counts (per device unless noted)
    hlo_flops: float
    hlo_bytes: float
    collective_payload_bytes: float
    collective_wire_bytes: float
    model_flops_global: float       # 6*N*D (dense) or 6*N_active*D (MoE)
    # seconds
    t_compute: float
    t_memory: float
    t_collective: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)  # type: ignore[arg-type]

    @property
    def t_bound(self) -> float:
        """Lower-bound step time: perfectly-overlapped execution."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def t_serial(self) -> float:
        """Upper-bound step time: zero overlap."""
        return self.t_compute + self.t_memory + self.t_collective

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs x chips): how much of the compiled
        compute is 'useful' — catches remat / redundancy waste."""
        total_hlo = self.hlo_flops * self.chips
        return self.model_flops_global / total_hlo if total_hlo else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Achievable MFU at the bound: useful FLOPs / (chips x peak x t)."""
        if self.t_bound <= 0:
            return 0.0
        return (self.model_flops_global
                / (self.chips * TPU_V5E.peak_flops_bf16 * self.t_bound))

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(dominant=self.dominant, t_bound=self.t_bound,
                 t_serial=self.t_serial,
                 useful_flops_ratio=self.useful_flops_ratio,
                 roofline_fraction=self.roofline_fraction)
        return d


def build_terms(arch: str, shape: str, mesh: str, chips: int,
                cost: dict, collectives: CollectiveSummary,
                model_flops_global: float,
                chip: TPUChipSpec = TPU_V5E,
                per_device_cost: bool = True) -> RooflineTerms:
    """Assemble roofline terms from compiled artifacts.

    ``cost`` is ``compiled.cost_analysis()`` (flops / bytes accessed).
    In SPMD mode the compiled module is the per-device program, so its
    counts are already per-chip (``per_device_cost=True``).
    """
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    if not per_device_cost:
        flops /= chips
        byts /= chips
    wire = collectives.total_wire_bytes
    payload = collectives.total_payload_bytes
    return RooflineTerms(
        arch=arch, shape=shape, mesh=mesh, chips=chips,
        hlo_flops=flops, hlo_bytes=byts,
        collective_payload_bytes=payload, collective_wire_bytes=wire,
        model_flops_global=model_flops_global,
        t_compute=flops / chip.peak_flops_bf16,
        t_memory=byts / chip.hbm_bandwidth,
        t_collective=wire / chip.ici_link_bandwidth,
    )


def format_table(rows: list[RooflineTerms]) -> str:
    hdr = (f"{'arch':22s} {'shape':12s} {'mesh':10s} "
           f"{'t_comp(ms)':>10s} {'t_mem(ms)':>10s} {'t_coll(ms)':>10s} "
           f"{'dominant':>10s} {'useful':>7s} {'roofl%':>7s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r.arch:22s} {r.shape:12s} {r.mesh:10s} "
            f"{r.t_compute*1e3:10.3f} {r.t_memory*1e3:10.3f} "
            f"{r.t_collective*1e3:10.3f} {r.dominant:>10s} "
            f"{r.useful_flops_ratio:7.3f} {r.roofline_fraction*100:6.2f}%")
    return "\n".join(lines)
