"""Reconnecting, idempotent client for the networked sweep service.

:class:`SweepClient` talks the framed-JSON protocol of
:class:`repro.runtime.transport.SweepServer` and makes every request
path survive the failures a long outer search loop (the SplitNets-
style co-design driver) actually hits:

* **Idempotent submits** — every submit carries a client-generated
  request id (``uuid4`` unless you pass one).  The service
  deduplicates the id against its live index *and its journal*, so a
  retried submit after a dropped connection — or a full server
  SIGKILL + restart over the same spool — attaches to the existing
  ticket (or its recovered finished result) instead of executing
  twice.  Blind retry is therefore always safe.
* **Automatic reconnect** — every call runs a reconnect-and-resend
  loop with capped exponential backoff plus full jitter
  (``backoff_s`` doubling to ``backoff_max_s`` over
  ``reconnect_timeout_s``); in-flight ``result()`` waits re-attach by
  resubmitting the idempotent id and resuming the watch stream.
* **Explicit backpressure** — an overloaded server answers with a
  ``backpressure`` error frame; the client re-raises it as the same
  :class:`repro.runtime.admission.BackpressureError` the in-process
  API throws, with ``queue_depth`` / ``capacity`` / ``retry_after_s``
  / ``tenant`` carried over the wire.  Overload is *not* retried
  automatically — the retry-after hint is the caller's pacing signal.
* **Incremental progress** — ``result(on_progress=...)`` subscribes
  to the server's consistent prefix snapshots (``fraction_complete``,
  running per-objective best, front size) while waiting, and the
  final result decodes through the exact JSON codec
  (:func:`repro.core.stream.result_from_json`) — bitwise-identical to
  the in-process path.

Server-side request failures surface as :class:`RemoteError` (or the
mapped :class:`~repro.core.service.CancelledError` /
:class:`~repro.core.service.ServiceClosedError`); connection loss
that outlasts ``reconnect_timeout_s`` raises ``ConnectionError``.
"""

from __future__ import annotations

import queue as _queue
import random
import socket
import threading
import time
import uuid
from typing import Callable, Optional, Sequence, Union

from ..runtime.admission import BackpressureError
from ..runtime.transport import AuthenticationError  # re-export
from ..runtime import transport as T
from . import service as CS
from . import stream as ST


class RemoteError(RuntimeError):
    """The server answered with an error frame (``kind`` preserves the
    wire error kind)."""

    def __init__(self, kind: str, message: str):
        self.kind = kind
        super().__init__(f"{kind}: {message}")


def _raise_error_frame(frame: dict) -> None:
    kind = frame.get("error")
    msg = frame.get("message", "")
    if kind == "backpressure":
        raise BackpressureError(
            int(frame.get("queue_depth", 0)),
            int(frame.get("capacity", 0)),
            reason=msg or "admission queue full",
            tenant=frame.get("tenant"),
            retry_after_s=frame.get("retry_after_s"))
    if kind == "cancelled":
        raise CS.CancelledError(msg)
    if kind == "closed":
        raise CS.ServiceClosedError(msg)
    if kind == "timeout":
        raise TimeoutError(msg)
    if kind == "bad_request":
        raise ValueError(msg)
    raise RemoteError(kind or "internal", msg)


class RemoteTicket:
    """Client-side handle to one submitted request — the networked
    mirror of :class:`repro.core.service.Ticket`.  ``client_id`` is
    the idempotency key: every retry path resubmits it, and the
    service guarantees at-most-one execution per id."""

    def __init__(self, client: "SweepClient", request: CS.SweepRequest,
                 client_id: str, ticket_id: str, state: str):
        self._client = client
        self.request = request
        self.client_id = client_id
        self.id = ticket_id
        self.state = state

    def status(self) -> dict:
        out = self._client._call({"op": "status", "id": self.id})
        self.state = out.get("state", self.state)
        return out

    def cancel(self) -> dict:
        return self._client._call({"op": "cancel", "id": self.id})

    def result(self, timeout: Optional[float] = None,
               on_progress: Optional[Callable] = None
               ) -> ST.StreamResult:
        """Block for the outcome, surviving connection loss and server
        restarts: each (re)attempt resubmits the idempotent
        ``client_id`` (attaching to the live ticket, the recovered
        journal entry, or a fresh execution resumed from the
        checkpoint spool) and then watches the progress stream.
        ``on_progress`` receives each consistent prefix snapshot dict.
        The decoded final result is bitwise-identical to the
        in-process path."""
        deadline = (None if timeout is None
                    else time.monotonic() + timeout)
        wire_before = self._client.counters.get("bytes_in", 0)
        while True:
            remaining = (None if deadline is None
                         else deadline - time.monotonic())
            if remaining is not None and remaining <= 0:
                raise TimeoutError(
                    f"request {self.id} not finished within {timeout}s")
            try:
                # Re-attach first: the idempotent resubmit finds the
                # live ticket, the journal-recovered finished result,
                # or (after an unplanned kill) re-admits the request
                # to resume from its checkpoint spool.
                sub = self._client._call(
                    {"op": "submit",
                     "request": self.request.to_json(),
                     "client_id": self.client_id})
                self.id = sub["id"]
                self.state = sub.get("state", self.state)
                final = self._client._call(
                    {"op": "watch", "id": self.id,
                     "timeout": remaining},
                    on_event=self._on_event(on_progress))
            except ConnectionError:
                # _call already spent a full reconnect budget; without
                # a result deadline that is the giving-up point, with
                # one we keep re-attaching while time remains.
                if deadline is None or time.monotonic() >= deadline:
                    raise
                self._client._backoff_once()
                continue
            except RemoteError as e:
                if e.kind == "not_found":
                    # The server lost the ticket (restart without a
                    # spool): loop back to the idempotent resubmit.
                    self._client._backoff_once()
                    continue
                raise
            self.state = final.get("state", self.state)
            res = ST.result_from_json(final["result"])
            # Wire bytes this wait cost (submit + watch stream + final
            # frame) — the delta-streaming savings show up right here.
            res.stats["watch_wire_bytes"] = float(
                self._client.counters.get("bytes_in", 0) - wire_before)
            return res

    def _on_event(self, on_progress):
        held = {"snap": None}

        def handle(frame: dict) -> None:
            self.state = frame.get("state", self.state)
            if "snapshot" in frame:
                held["snap"] = dict(frame["snapshot"])
            elif "delta" in frame:
                # Per-chunk delta frames: fold into the held baseline
                # (the server always re-baselines a fresh watch, so
                # the first frame is never a delta).
                held["snap"] = ST.apply_result_delta(held["snap"],
                                                     frame["delta"])
            else:
                return
            if on_progress is not None:
                on_progress(held["snap"])
        return handle


class SweepClient:
    """Socket client for a :class:`~repro.runtime.transport.
    SweepServer` at ``address`` (``"host:port"`` for TCP, a filesystem
    path for a Unix socket) — or a *sequence* of replica addresses,
    in which case connection failures rotate through them (failover)
    and :meth:`submit` can hedge across them (``hedge_s=``).

    One connection, created lazily and replaced transparently: every
    call retries connect/send/receive failures with capped exponential
    backoff + full jitter until ``reconnect_timeout_s`` is exhausted
    (then ``ConnectionError``).  ``heartbeat_grace_s`` bounds how long
    a blocking call waits without hearing *anything* (data, progress
    or heartbeat frames) before declaring the connection dead — keep
    it a few multiples of the server's ``heartbeat_s``.  ``auth``
    answers the server's HMAC challenge (see ``--auth-token``); a
    missing or rejected token raises :class:`AuthenticationError`
    immediately — credentials are never retried.  Thread-safe per
    instance only if each thread uses its own client.
    """

    def __init__(self, address: Union[str, Sequence[str]],
                 connect_timeout_s: float = 5.0,
                 reconnect_timeout_s: float = 60.0,
                 backoff_s: float = 0.05,
                 backoff_max_s: float = 2.0,
                 heartbeat_grace_s: float = 10.0,
                 max_frame: int = T.MAX_FRAME,
                 auth: Optional[str] = None,
                 rng: Optional[random.Random] = None):
        addrs = ([address] if isinstance(address, str)
                 else list(address))
        if not addrs:
            raise ValueError("need at least one server address")
        self.addresses = tuple(addrs)
        self.address = addrs[0]
        self._addr_i = 0
        self._connect_timeout_s = float(connect_timeout_s)
        self._reconnect_timeout_s = float(reconnect_timeout_s)
        self._backoff_s = float(backoff_s)
        self._backoff_max_s = float(backoff_max_s)
        self._grace_s = float(heartbeat_grace_s)
        self._max_frame = int(max_frame)
        self._auth = auth
        self._rng = rng if rng is not None else random.Random()
        self._sock: Optional[socket.socket] = None
        self._rid = 0
        self._attempt = 0
        self.counters = {"reconnects": 0, "retries": 0, "calls": 0,
                         "failovers": 0, "hedged_submits": 0,
                         "bytes_in": 0}

    # -- lifecycle -------------------------------------------------------

    def __enter__(self) -> "SweepClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None

    # -- public API --------------------------------------------------------

    def ping(self) -> dict:
        return self._call({"op": "ping"})

    def health(self) -> dict:
        return self._call({"op": "health"})["health"]

    def submit(self, request: CS.SweepRequest,
               client_id: Optional[str] = None,
               hedge_s: Optional[float] = None) -> RemoteTicket:
        """Submit one request; returns a :class:`RemoteTicket`.
        ``client_id`` defaults to a fresh ``uuid4`` — keep the
        returned ticket's id to re-attach from another process.
        Raises :class:`~repro.runtime.admission.BackpressureError`
        (with the server's retry-after hint) on overload — overload is
        never retried blindly.

        ``hedge_s`` (with multiple replica addresses) *hedges* the
        submit: the primary gets a head start of ``hedge_s`` seconds,
        then each further replica is raced in ``hedge_s`` stagger; the
        first answer wins.  All legs share one idempotent
        ``client_id``, so the service executes at most once no matter
        how many legs land — the loser is deduplicated, never run."""
        cid = client_id or f"cli-{uuid.uuid4().hex}"
        payload = {"op": "submit", "request": request.to_json(),
                   "client_id": cid}
        if hedge_s is not None and len(self.addresses) > 1:
            out = self._hedged_call(payload, float(hedge_s))
        else:
            out = self._call(payload)
        return RemoteTicket(self, request.normalized(), cid,
                            out["id"], out.get("state", "queued"))

    def _hedged_call(self, payload: dict, hedge_s: float) -> dict:
        """Race one call across every replica address with ``hedge_s``
        stagger; first successful response wins, later legs are
        abandoned (their submits deduplicate server-side).  Raises the
        first leg error only when every leg failed."""
        self.counters["hedged_submits"] += 1
        results: "_queue.Queue" = _queue.Queue()
        won = threading.Event()

        def leg(addr: str, delay: float) -> None:
            if delay > 0 and won.wait(delay):
                results.put(("skipped", None))
                return
            try:
                with SweepClient(
                        addr, auth=self._auth,
                        connect_timeout_s=self._connect_timeout_s,
                        reconnect_timeout_s=self._reconnect_timeout_s,
                        backoff_s=self._backoff_s,
                        backoff_max_s=self._backoff_max_s,
                        heartbeat_grace_s=self._grace_s,
                        max_frame=self._max_frame) as c:
                    results.put(("ok", c._call(dict(payload))))
            except Exception as e:
                results.put(("err", e))

        threads = [threading.Thread(target=leg, args=(a, i * hedge_s),
                                    daemon=True)
                   for i, a in enumerate(self.addresses)]
        for th in threads:
            th.start()
        first_err: Optional[Exception] = None
        for _ in threads:
            kind, val = results.get()
            if kind == "ok":
                won.set()
                return val
            if kind == "err" and first_err is None:
                first_err = val
        won.set()
        raise first_err if first_err is not None else ConnectionError(
            f"no replica of {self.addresses} answered the hedged "
            f"submit")

    def status(self, ticket_id: str) -> dict:
        return self._call({"op": "status", "id": ticket_id})

    def cancel(self, ticket_id: str) -> dict:
        return self._call({"op": "cancel", "id": ticket_id})

    def result(self, ticket: RemoteTicket,
               timeout: Optional[float] = None,
               on_progress: Optional[Callable] = None
               ) -> ST.StreamResult:
        return ticket.result(timeout=timeout, on_progress=on_progress)

    # -- internals: one call = send + frames until non-hb reply ----------

    def _connect(self) -> socket.socket:
        kind, host, port = T.parse_address(self.address)
        if kind == "unix":
            s = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            target: object = host
        else:
            s = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
            target = (host, port)
        s.settimeout(self._connect_timeout_s)
        s.connect(target)
        try:
            T.client_handshake(s, auth=self._auth)
        except BaseException:
            s.close()
            raise
        s.settimeout(self._grace_s)
        return s

    def _rotate(self) -> None:
        """After a connection failure: point at the next replica
        address (no-op with a single address)."""
        if len(self.addresses) > 1:
            self._addr_i = (self._addr_i + 1) % len(self.addresses)
            self.address = self.addresses[self._addr_i]
            self.counters["failovers"] += 1

    def _backoff_once(self) -> None:
        """One capped-exponential, full-jitter sleep (shared by the
        call loop and :meth:`RemoteTicket.result`'s re-attach loop)."""
        delay = min(self._backoff_max_s,
                    self._backoff_s * (2.0 ** self._attempt))
        self._attempt += 1
        self.counters["retries"] += 1
        time.sleep(self._rng.uniform(0.0, delay))

    def _drop(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None

    def _call(self, payload: dict,
              on_event: Optional[Callable] = None) -> dict:
        """Send one request and return its final response frame,
        reconnecting and resending on connection failure until
        ``reconnect_timeout_s`` is exhausted.  Heartbeat frames reset
        the liveness clock; ``on_event`` sees every intermediate frame
        (progress + heartbeats).  Only safe because every operation is
        idempotent server-side (submits via client ids, the rest
        read-only or at-most-once by nature)."""
        self.counters["calls"] += 1
        give_up = time.monotonic() + self._reconnect_timeout_s
        self._attempt = 0
        while True:
            final = None
            try:
                if self._sock is None:
                    self._sock = self._connect()
                    self.counters["reconnects"] += 1
                self._rid += 1
                rid = f"r{self._rid}"
                self._sock.sendall(
                    T.encode_frame(dict(payload, rid=rid)))
                while final is None:
                    frame = T.read_frame(self._sock, self._max_frame,
                                         self.counters)
                    if frame is None:
                        raise ConnectionError("server closed the "
                                              "connection")
                    if frame.get("rid") not in (None, rid):
                        continue        # stale frame from a prior call
                    if on_event is not None:
                        on_event(frame)
                    if frame.get("hb") or "snapshot" in frame \
                            or "delta" in frame:
                        continue        # liveness / streaming frames
                    final = frame
            except AuthenticationError:
                self._drop()
                raise           # a bad credential never heals by retry
            except (ConnectionError, BrokenPipeError, socket.timeout,
                    OSError) as e:
                self._drop()
                self._rotate()
                if time.monotonic() >= give_up:
                    raise ConnectionError(
                        f"could not reach sweep server at "
                        f"{self.address} within "
                        f"{self._reconnect_timeout_s}s: {e}") from e
                self._backoff_once()
                continue
            # Error frames raise OUTSIDE the except scope above: a
            # server-reported TimeoutError is an OSError subclass and
            # must never be mistaken for a connection failure.
            self._attempt = 0
            if final.get("error"):
                _raise_error_frame(final)
            return final
