"""Trip-count-aware static cost analysis over compiled (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts each computation ONCE — a program whose
layers live inside a ``lax.scan`` (a ``while`` op) reports one layer's
FLOPs.  This module re-derives per-step counts honestly:

* computations are parsed from the HLO text;
* a call-graph walk assigns each computation a **multiplicity** — while
  bodies multiply by the loop's ``known_trip_count`` (XLA records it in
  ``backend_config``), fusions/calls inherit the caller's multiplicity;
* per-instruction costs:
    - ``dot``:  2 x out_elems x prod(contracting dims)   (from real shapes)
    - ``convolution``: 2 x out_elems x window x chan/group
    - arithmetic elementwise: out_elems
    - bytes: operands + outputs for memory-moving ops; fusion internals are
      charged at the fusion's call-site I/O (what a fused kernel reads and
      writes);
* collectives are returned as a :class:`CollectiveSummary` with payloads
  scaled by multiplicity — fixing the same undercount for comm bytes.

This is the TPU analogue of the paper's GVSoC step: a static,
whole-program extraction of #ops / #bytes / #link-bytes that the
semi-analytical layer then turns into roofline terms and energy.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

from .hlo_analysis import (COLLECTIVE_OPS, CollectiveOp, CollectiveSummary,
                           _DTYPE_BYTES, _GROUPS_IOTA_RE, _GROUPS_RE,
                           _SHAPE_RE)

# ---------------------------------------------------------------------------
# text parsing
# ---------------------------------------------------------------------------

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\((.*)\)\s+->")
_INSTR = re.compile(
    r"^\s+(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*"
    r"(\([^)]*\)|\S+)\s+"      # tuple shape (single-level) or tensor shape
    r"([\w\-]+)\(")
_TRIP = re.compile(r'known_trip_count\\?":\{\\?"n\\?":\\?"(\d+)')
_DOT_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_WINDOW = re.compile(r"window=\{[^}]*size=([\dx]+)")
_FGC = re.compile(r"feature_group_count=(\d+)")
_CALLS = re.compile(r"calls=%?([\w\.\-]+)")
_BODY = re.compile(r"body=%?([\w\.\-]+)")
_COND = re.compile(r"condition=%?([\w\.\-]+)")
_TO_APPLY = re.compile(r"to_apply=%?([\w\.\-]+)")

_ARITH_OPS = frozenset((
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "tanh", "log", "rsqrt", "sqrt", "negate", "abs",
    "compare", "select", "and", "or", "xor", "floor", "ceil", "sign",
    "exponential-minus-one", "log-plus-one", "atan2", "clamp", "convert",
    "cosine", "sine", "reduce", "reduce-window",
))

_BYTE_OPS = frozenset((
    "dot", "convolution", "copy", "transpose", "reshape", "reduce",
    "broadcast", "dynamic-slice", "dynamic-update-slice", "scatter",
    "gather", "concatenate", "pad", "sort", "convert", "slice", "iota",
    "reduce-window", "select-and-scatter", "rng", "cholesky",
    "triangular-solve",
)) | set(COLLECTIVE_OPS) | {f"{c}-start" for c in COLLECTIVE_OPS}

_SKIP_OPS = frozenset((
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "tuple-select",
    "get-dimension-size", "custom-call", "while", "call", "conditional",
    "fusion", "opt-barrier",
))


def _shape_elems_bytes(shape_text: str) -> Tuple[int, int]:
    """(elements, bytes) across all shape tokens in ``shape_text``."""
    elems = total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        total += n * _DTYPE_BYTES[dtype]
    return elems, total


@dataclasses.dataclass
class _Instr:
    name: str
    out_shape: str
    opcode: str
    line: str
    operands: Tuple[str, ...]


@dataclasses.dataclass
class _Comp:
    name: str
    is_entry: bool
    instrs: List[_Instr]


_OPERANDS_RE = re.compile(r"%([\w\.\-]+)")


def _parse_computations(text: str) -> Tuple[Dict[str, _Comp], str,
                                            Dict[str, str]]:
    comps: Dict[str, _Comp] = {}
    shapes: Dict[str, str] = {}
    entry = ""
    cur: Optional[_Comp] = None
    for line in text.splitlines():
        if not line.startswith(" "):
            m = _COMP_HDR.match(line)
            if m:
                cur = _Comp(name=m.group(2), is_entry=bool(m.group(1)),
                            instrs=[])
                comps[cur.name] = cur
                if cur.is_entry:
                    entry = cur.name
                # record parameter shapes: "pname: shape, pname2: shape"
                for pm in re.finditer(r"([\w\.\-]+):\s*((?:\([^)]*\)|"
                                      r"[\w\[\]\{\},]+))", m.group(3) or ""):
                    shapes[pm.group(1)] = pm.group(2)
            elif line.startswith("}"):
                cur = None
            continue
        if cur is None:
            continue
        mi = _INSTR.match(line)
        if not mi:
            continue
        name, out_shape, opcode = mi.group(1), mi.group(2), mi.group(3)
        # operand names: everything inside the first (...) after opcode
        rest = line[mi.end():]
        depth, i = 1, 0
        while i < len(rest) and depth:
            if rest[i] == "(":
                depth += 1
            elif rest[i] == ")":
                depth -= 1
            i += 1
        ops = tuple(_OPERANDS_RE.findall(rest[:i]))
        instr = _Instr(name, out_shape.strip(), opcode, line, ops)
        cur.instrs.append(instr)
        shapes[name] = out_shape.strip()
    return comps, entry, shapes


# ---------------------------------------------------------------------------
# cost walk
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class HLOCost:
    flops: float = 0.0
    bytes: float = 0.0
    collectives: CollectiveSummary = dataclasses.field(
        default_factory=lambda: CollectiveSummary([]))
    flops_by_op: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    bytes_by_op: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    unrolled_whiles: int = 0
    unknown_trip_whiles: int = 0


def _dot_flops(instr: _Instr, shapes: Dict[str, str]) -> float:
    out_elems, _ = _shape_elems_bytes(instr.out_shape)
    m = _DOT_CONTRACT.search(instr.line)
    contract = 1
    if m and instr.operands:
        lhs_shape = shapes.get(instr.operands[0], "")
        sm = _SHAPE_RE.search(lhs_shape)
        if sm and sm.group(2):
            dims = [int(d) for d in sm.group(2).split(",")]
            for idx_s in m.group(1).split(","):
                if idx_s.strip():
                    idx = int(idx_s)
                    if idx < len(dims):
                        contract *= dims[idx]
    return 2.0 * out_elems * contract


def _conv_flops(instr: _Instr, shapes: Dict[str, str]) -> float:
    out_elems, _ = _shape_elems_bytes(instr.out_shape)
    window = 1
    m = _WINDOW.search(instr.line)
    if m:
        for w in m.group(1).split("x"):
            window *= int(w)
    # channels per group: lhs feature dim / feature_group_count (depthwise
    # convs — the only ones in this codebase — give 1)
    return 2.0 * out_elems * window


def _group_size_from_line(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip()]
        return max(1, len(ids))
    return 1


def _fusion_io_bytes(comp: _Comp, operands: Tuple[str, ...],
                     out_shape: str, shapes: Dict[str, str]) -> float:
    """Slice-aware I/O bytes for one fusion call site.

    A fusion parameter consumed only by ``dynamic-slice`` is charged at the
    slice size (the scan-over-stacked-params pattern would otherwise charge
    the full stacked tensor once per iteration); a fusion whose root is a
    ``dynamic-update-slice`` is charged at the update size (in-place
    accumulation into a scan carry).
    """
    # param index -> instr name, and slice charges
    param_names: Dict[int, str] = {}
    by_name: Dict[str, _Instr] = {}
    used_by: Dict[str, List[_Instr]] = defaultdict(list)
    root: Optional[_Instr] = None
    for ins in comp.instrs:
        by_name[ins.name] = ins
        if ins.opcode == "parameter":
            m = re.search(r"parameter\((\d+)\)", ins.line)
            if m:
                param_names[int(m.group(1))] = ins.name
        for o in ins.operands:
            used_by[o].append(ins)
        if "ROOT" in ins.line:
            root = ins
    # walk through bitcast/copy chains to the real root producer
    seen = 0
    while root is not None and root.opcode in ("bitcast", "copy", "tuple") \
            and root.operands and seen < 8:
        root = by_name.get(root.operands[0], root)
        seen += 1
        if root.opcode not in ("bitcast", "copy", "tuple"):
            break
    total = 0.0
    for idx, opnd in enumerate(operands):
        pname = param_names.get(idx)
        users = used_by.get(pname, []) if pname else []
        if users and all(u.opcode in ("dynamic-slice", "gather")
                         for u in users):
            total += sum(_shape_elems_bytes(u.out_shape)[1] for u in users)
        else:
            total += _shape_elems_bytes(shapes.get(opnd, ""))[1]
    if root is not None and root.opcode == "dynamic-update-slice" \
            and len(root.operands) >= 2:
        # charge the update tensor, not the full buffer
        upd = root.operands[1]
        total += _shape_elems_bytes(shapes.get(upd, ""))[1]
    else:
        total += _shape_elems_bytes(out_shape)[1]
    return total


def analyze(text: str, vmem_credit_depth: Optional[int] = None) -> HLOCost:
    """Static cost walk.

    ``vmem_credit_depth``: if set (e.g. 2), instructions nested inside
    >= that many ``while`` levels are assumed to execute inside a fused
    TPU kernel whose intermediates live in VMEM: their HBM byte charges
    are dropped EXCEPT block loads/stores (dynamic-slice /
    dynamic-update-slice / gather) and collectives.  FLOPs are always
    charged in full.  In this codebase depth >= 2 is exactly the inner
    loop of blockwise attention / mLSTM / the Mamba scan — the bodies the
    Pallas kernels fuse — so this mode prices the kernel-deployed program
    (§Perf 'pallas-credit' rows).
    """
    comps, entry, shapes = _parse_computations(text)
    cost = HLOCost()
    if not entry:
        return cost
    coll_ops: List[CollectiveOp] = []
    _SLICE_OPS = ("dynamic-slice", "dynamic-update-slice", "gather")

    # multiplicity-aware walk; fusion bodies contribute flops only
    def walk(comp_name: str, mult: float, in_fusion: bool,
             depth: int = 0):
        comp = comps.get(comp_name)
        if comp is None:
            return
        credited = (vmem_credit_depth is not None
                    and depth >= vmem_credit_depth)
        for ins in comp.instrs:
            op = ins.opcode
            base = op[:-6] if op.endswith("-start") else op
            if base in COLLECTIVE_OPS:
                if op.endswith("-done") or "-done(" in ins.line:
                    continue
                _, payload = _shape_elems_bytes(ins.out_shape)
                if op.endswith("-start") and payload:
                    payload //= 2 if base != "all-gather" else 1
                g = _group_size_from_line(ins.line)
                coll_ops.append(CollectiveOp(base, int(payload * mult), g))
                _, b = _shape_elems_bytes(ins.out_shape)
                cost.bytes += b * mult
                cost.bytes_by_op[base] += b * mult
                continue
            if op == "while":
                tm = _TRIP.search(ins.line)
                trips = int(tm.group(1)) if tm else 1
                if not tm:
                    cost.unknown_trip_whiles += 1
                cost.unrolled_whiles += 1
                bm = _BODY.search(ins.line)
                cm = _COND.search(ins.line)
                if bm:
                    walk(bm.group(1), mult * trips, in_fusion, depth + 1)
                if cm:
                    walk(cm.group(1), mult * trips, in_fusion, depth + 1)
                continue
            if op == "fusion":
                cm = _CALLS.search(ins.line)
                body = comps.get(cm.group(1)) if cm else None
                if cm:
                    walk(cm.group(1), mult, True, depth)
                if credited:
                    # VMEM-resident fused body: charge only block I/O
                    if body is not None:
                        io = 0.0
                        for bins in body.instrs:
                            if bins.opcode in _SLICE_OPS:
                                io += _shape_elems_bytes(bins.out_shape)[1]
                            if bins.opcode == "dynamic-update-slice" and \
                                    len(bins.operands) > 1:
                                io += _shape_elems_bytes(
                                    shapes.get(bins.operands[1], ""))[1]
                        cost.bytes += io * mult
                        cost.bytes_by_op["vmem-block-io"] += io * mult
                    continue
                if not in_fusion:
                    if body is not None:
                        io = _fusion_io_bytes(body, ins.operands,
                                              ins.out_shape, shapes)
                    else:
                        _, ob = _shape_elems_bytes(ins.out_shape)
                        io = ob + sum(
                            _shape_elems_bytes(shapes.get(o, ""))[1]
                            for o in ins.operands)
                    cost.bytes += io * mult
                    cost.bytes_by_op["fusion"] += io * mult
                continue
            if op in ("call", "conditional", "async-start"):
                cm = _CALLS.search(ins.line) or _TO_APPLY.search(ins.line)
                if cm:
                    walk(cm.group(1), mult, in_fusion, depth)
                continue
            # ---- flops ----
            if op == "dot":
                f = _dot_flops(ins, shapes) * mult
                cost.flops += f
                cost.flops_by_op["dot"] += f
            elif op == "convolution":
                f = _conv_flops(ins, shapes) * mult
                cost.flops += f
                cost.flops_by_op["convolution"] += f
            elif op in _ARITH_OPS:
                elems, _ = _shape_elems_bytes(ins.out_shape)
                cost.flops += elems * mult
                cost.flops_by_op["elementwise"] += elems * mult
            # ---- bytes (top level only; fusion internals via call site) --
            if credited and op not in _SLICE_OPS:
                continue
            if not in_fusion and op in _BYTE_OPS and op not in _SKIP_OPS:
                _, ob = _shape_elems_bytes(ins.out_shape)
                if op == "dynamic-slice":
                    io = 2.0 * ob            # read slice + write out
                elif op == "dynamic-update-slice":
                    ub = _shape_elems_bytes(
                        shapes.get(ins.operands[1], "")
                    )[1] if len(ins.operands) > 1 else ob
                    io = 2.0 * ub            # read update + write window
                else:
                    ib = 0.0
                    for o in ins.operands:
                        _, b = _shape_elems_bytes(shapes.get(o, ""))
                        ib += b
                    io = ib + ob
                cost.bytes += io * mult
                cost.bytes_by_op[op] += io * mult

    walk(entry, 1.0, False)
    cost.collectives = CollectiveSummary(coll_ops)
    return cost


def top_bytes_breakdown(cost: HLOCost, n: int = 6) -> dict:
    items = sorted(cost.bytes_by_op.items(), key=lambda kv: -kv[1])[:n]
    return {k: v for k, v in items}
