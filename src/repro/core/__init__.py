"""Core: the paper's semi-analytical DOSC power model + TPU adaptation."""

from . import (arrays, constants, dosc, energy, handtracking,  # noqa: F401
               hlo_analysis, latency, optimize, pareto, partition, rbe,
               roofline, sweep, system, tpu_energy, workloads)
