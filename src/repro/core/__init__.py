"""Core: the paper's semi-analytical DOSC power model + TPU adaptation."""

from . import (constants, dosc, energy, handtracking, hlo_analysis,  # noqa: F401
               partition, rbe, roofline, system, tpu_energy, workloads)
