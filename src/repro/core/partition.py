"""Workload partition optimizer across the distributed compute hierarchy.

The paper's central system knob: where to cut the CV pipeline between the
on-sensor processor and the aggregator.  The hand-tracking pipeline is

    raw frame -> DetNet -> (boxes back to sensor) -> ROI crop -> KeyNet -> kp

and every layer boundary is a legal cut.  For cut index ``k`` over the
concatenated layer list (DetNet ++ KeyNet):

* ``k == 0``                  — fully centralized (Fig. 1a): the raw frame
  crosses MIPI at camera rate (the aggregator needs it for the ROI crop).
* ``0 < k < len(DetNet)``     — DetNet is split: the cut activation crosses
  MIPI at DetNet rate, *and* the ROI crop still has to cross at KeyNet rate
  (the raw frame only exists on-sensor; box coords return over MIPI, tiny).
* ``k == len(DetNet)``        — the paper's choice (Fig. 2): only the ROI
  (at KeyNet rate) + DetNet outputs (at DetNet rate) cross MIPI.
* ``k > len(DetNet)``         — KeyNet is split: the KeyNet cut activation
  crosses at KeyNet rate; ROI stays on-sensor.

**Two evaluation paths share these semantics.**  This module is the
*scalar* path: :func:`evaluate_cut` assembles the full, named
``ModuleEnergy`` list for one configuration (the per-module report behind
the Fig. 5 stacked bars) and is the single-config convenience/validation
wrapper of the model.  Grid-scale exploration belongs to the *array* path,
:func:`repro.core.sweep.evaluate_grid`, which evaluates the identical
Eqs. 1-11 for an arbitrary (cut × node × memory × rate × ...) cartesian
product in one jit/vmap device call.  Both paths derive what crosses MIPI
at each cut from :func:`repro.core.arrays.mipi_payloads`, so they cannot
drift; ``tests/test_sweep.py`` pins them to ≤1e-6 relative parity.
:func:`optimal_partition` uses the array engine to locate the minimum of
any single objective channel (power, latency, or MIPI traffic) and the
scalar path to render its report; trade-offs *across* the channels are
the domain of :mod:`repro.core.pareto` (exact fronts) and
:mod:`repro.core.optimize` (gradient search over the continuous knobs).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from . import energy as E
from .arrays import RATE_CAMERA, RATE_DETNET, RATE_KEYNET, mipi_payloads
from .constants import (CAMERA_FPS, DETNET_FPS, KEYNET_FPS, MIPI,
                        NUM_CAMERAS, ON_SENSOR_SCALE, SENSOR_L1_BYTES,
                        T_SENSE_S, TECH_NODES, UTSV, TechNode)
from .constants import BOX_COORDS_BYTES  # noqa: F401  (re-export)
from .handtracking import FULL_FRAME_BYTES, build_detnet, build_keynet
from .latency import cut_latency
from .system import (Deployment, ProcessorSite, SystemReport,
                     _camera_modules, _link_modules, _resolve_node,
                     replicate_site_modules, MemKind)
from .workloads import NNWorkload

#: SweepResult channels / PartitionPoint attributes ``optimal_partition``
#: can minimize (the paper's three headline objectives).
OBJECTIVES = ("avg_power", "latency", "mipi_bytes_per_s")

#: Session-level channels, available when ``scenarios=`` is passed (the
#: battery/thermal session simulator of :mod:`repro.core.scenario`).
#: All are minimized except ``time_to_empty_s``, which is maximized.
SESSION_OBJECTIVES = ("session_energy_j", "time_to_empty_s",
                      "peak_case_temp_c", "throttle_fraction")

#: Objective channels where "optimal" means the *largest* value.
_MAXIMIZED = ("time_to_empty_s",)

#: Grid size above which ``optimal_partition`` routes the search through
#: the streaming executor (`repro.core.stream.stream_grid`) instead of
#: materializing a dense grid.
STREAM_THRESHOLD = 1 << 20

#: evaluate_cut kwarg for each sweep axis name (the winner of a grid /
#: stream search is rendered through the scalar path with these).
_AXIS_TO_KWARG = {"agg_node": "agg_node", "sensor_node": "sensor_node",
                  "weight_mem": "sensor_weight_mem",
                  "detnet_fps": "detnet_fps", "keynet_fps": "keynet_fps",
                  "num_cameras": "num_cameras",
                  "mipi_energy_scale": "mipi_energy_scale",
                  "camera_fps": "camera_fps"}


@dataclasses.dataclass(frozen=True)
class PartitionPoint:
    """One fully-evaluated partition cut: the three objective scalars
    (``avg_power`` W, ``latency`` s, ``mipi_bytes_per_s`` B/s) plus the
    named per-module :class:`~repro.core.system.SystemReport`."""

    cut: int
    label: str
    avg_power: float
    mipi_bytes_per_s: float
    sensor_macs_per_s: float
    latency: float
    report: SystemReport
    #: Winning trace name and session channel dict (the four
    #: :data:`SESSION_OBJECTIVES` values) — populated only by scenario
    #: searches (``optimal_partition(..., scenarios=...)``).
    trace: str | None = None
    session: dict | None = None


def _sub_workload(wl: NNWorkload, lo: int, hi: int,
                  name: str) -> NNWorkload | None:
    layers = wl.layers[lo:hi]
    if not layers:
        return None
    return NNWorkload(name=name, layers=tuple(layers),
                      input_bytes=layers[0].in_act_bytes,
                      output_bytes=layers[-1].out_act_bytes)


def evaluate_cut(cut: int,
                 agg_node: str | TechNode = "7nm",
                 sensor_node: str | TechNode = "7nm",
                 sensor_weight_mem: MemKind = "sram",
                 detnet: NNWorkload | None = None,
                 keynet: NNWorkload | None = None,
                 num_cameras: int = NUM_CAMERAS,
                 camera_fps: float = CAMERA_FPS,
                 detnet_fps: float = DETNET_FPS,
                 keynet_fps: float = KEYNET_FPS,
                 mipi_energy_scale: float = 1.0) -> PartitionPoint:
    """Build the full Eq.1/2 module list for one partition point.

    This is the scalar, fully-annotated single-config path; for sweeps use
    :func:`repro.core.sweep.evaluate_grid`.  ``mipi_energy_scale``
    multiplies the MIPI energy/byte (the Eq. 5 sensitivity knob) without
    touching the link bandwidth.
    """
    detnet = detnet or build_detnet()
    keynet = keynet or build_keynet()
    agg_n = _resolve_node(agg_node)
    sen_n = _resolve_node(sensor_node)
    n_det = len(detnet.layers)
    n_all = n_det + len(keynet.layers)
    if not 0 <= cut <= n_all:
        raise ValueError(f"cut {cut} outside [0, {n_all}]")
    if num_cameras < 1:
        raise ValueError("num_cameras must be >= 1")
    mipi = MIPI if mipi_energy_scale == 1.0 else dataclasses.replace(
        MIPI, energy_per_byte=MIPI.energy_per_byte * mipi_energy_scale)

    mods: list[E.ModuleEnergy] = []
    centralized = cut == 0
    cam_link = mipi if centralized else UTSV
    mods += _camera_modules(num_cameras, readout_link=cam_link,
                            fps=camera_fps, t_sense=T_SENSE_S)
    if not centralized:
        mods += _link_modules(num_cameras, UTSV, FULL_FRAME_BYTES,
                              camera_fps, tag="utsv")

    # ---- what crosses MIPI (shared plan with the array engine) ----
    rate_of = {RATE_CAMERA: camera_fps, RATE_DETNET: detnet_fps,
               RATE_KEYNET: keynet_fps}
    payload_plan = mipi_payloads(cut, detnet, keynet)
    mipi_payload_rates = [(b, rate_of[tag]) for b, tag in payload_plan]
    for i, (b, r) in enumerate(mipi_payload_rates):
        mods += _link_modules(num_cameras, mipi, b, r, tag=f"mipi.{i}")

    # ---- sensor-side deployment (identical per camera: build once) ----
    sensor_wls: list[tuple[NNWorkload, float]] = []
    det_s = _sub_workload(detnet, 0, min(cut, n_det), "DetNet.sensor")
    if det_s:
        sensor_wls.append((det_s, detnet_fps))
    key_s = _sub_workload(keynet, 0, max(0, cut - n_det), "KeyNet.sensor")
    if key_s:
        sensor_wls.append((key_s, keynet_fps))
    if not centralized:
        sensor0 = Deployment(
            site=ProcessorSite(name="sensor0", node=sen_n,
                               scale=ON_SENSOR_SCALE,
                               weight_mem=sensor_weight_mem,
                               l1_bytes=SENSOR_L1_BYTES),
            workloads=list(sensor_wls),
            extra_buffer_bytes=detnet.input_bytes,
        ).modules()
        mods += replicate_site_modules(sensor0, "sensor0", num_cameras)

    # ---- aggregator-side deployment ----
    agg_wls: list[tuple[NNWorkload, float]] = []
    det_a = _sub_workload(detnet, min(cut, n_det), n_det, "DetNet.agg")
    if det_a:
        agg_wls.append((det_a, detnet_fps * num_cameras))
    key_a = _sub_workload(keynet, max(0, cut - n_det), len(keynet.layers),
                          "KeyNet.agg")
    if key_a:
        agg_wls.append((key_a, keynet_fps * num_cameras))
    in_buf = max(b for b, _ in mipi_payload_rates) * num_cameras
    if agg_wls:
        mods += Deployment(
            site=ProcessorSite(name="agg", node=agg_n, scale=1.0),
            workloads=agg_wls,
            extra_buffer_bytes=in_buf,
        ).modules()

    label = ("centralized" if centralized else
             "paper-split(DetNet|KeyNet)" if cut == n_det else
             f"cut@{cut}")
    rep = SystemReport(name=f"partition[{label}]", modules=mods)
    mipi_rate = sum(b * r for b, r in mipi_payload_rates) * num_cameras
    sensor_macs = sum(w.total_macs * f for w, f in sensor_wls) * num_cameras
    lat = cut_latency(cut, agg_node=agg_n, sensor_node=sen_n,
                      detnet=detnet, keynet=keynet,
                      num_cameras=num_cameras, camera_fps=camera_fps,
                      detnet_fps=detnet_fps, keynet_fps=keynet_fps)
    return PartitionPoint(cut=cut, label=label, avg_power=rep.avg_power,
                          mipi_bytes_per_s=mipi_rate,
                          sensor_macs_per_s=sensor_macs,
                          latency=lat.total, report=rep)


def sweep_partitions(**kw) -> list[PartitionPoint]:
    """Scalar sweep over every cut, with full per-module reports.

    For grids beyond a single axis (or when reports are not needed) use
    :func:`repro.core.sweep.evaluate_grid`, which is orders of magnitude
    faster per configuration.
    """
    detnet = kw.get("detnet") or build_detnet()
    keynet = kw.get("keynet") or build_keynet()
    kw["detnet"], kw["keynet"] = detnet, keynet
    n_all = len(detnet.layers) + len(keynet.layers)
    return [evaluate_cut(c, **kw) for c in range(n_all + 1)]


def _registry_name(node: str | TechNode) -> str | None:
    """Registry key for a node, or None if it isn't the registered object."""
    if isinstance(node, str):
        return node if node in TECH_NODES else None
    return node.name if TECH_NODES.get(node.name) is node else None


def _is_axis(v) -> bool:
    return isinstance(v, (list, tuple, np.ndarray))


def optimal_partition(engine: str = "array",
                      objective: str = "avg_power",
                      constraints=None, backend: str | None = None,
                      scenarios=None,
                      checkpoint_dir: str | None = None,
                      checkpoint_every_s: float | None = None,
                      **kw) -> PartitionPoint:
    """Optimal partition point along one objective (Fig. 2 generalized).

    ``objective`` selects which channel is minimized over the cut axis —
    one of :data:`OBJECTIVES` (``avg_power`` reproduces the paper's power
    sweep; ``latency`` and ``mipi_bytes_per_s`` are the other two headline
    claims).  For trade-offs *between* the objectives use
    :func:`repro.core.pareto.pareto_front` instead of a scalar argmin.

    ``constraints`` restricts the search to feasible configurations
    (see :func:`repro.core.sweep.parse_constraints` — e.g.
    ``constraints={"latency": 1e-3}`` for a latency budget, or
    ``("mipi_bytes_per_s <= 1e9",)`` for a link cap).  On the dense grid
    engines the predicates post-filter the channels
    (``SweepResult.constrain``); on the streaming path they are compiled
    into the chunk step, so huge constrained searches stay
    memory-bounded.  Raises :class:`ValueError` when no configuration is
    feasible.

    Any knob may also be a *sequence* (e.g. ``sensor_node=("7nm",
    "16nm")``, ``detnet_fps=np.linspace(5, 30, 50)``, or an explicit
    ``cuts=`` axis) — the search then runs over the full cartesian grid
    of all sequence-valued knobs × every cut.  Grids up to
    :data:`STREAM_THRESHOLD` configurations are evaluated densely; larger
    spaces route through the streaming executor
    (:func:`repro.core.stream.stream_grid`), so the search stays
    memory-bounded no matter how many knobs are opened up.  Only the
    winner is rendered through the scalar path.

    With scalar knobs, ``engine="array"`` (default) evaluates the cut
    axis with the vectorized grid engine; ``engine="scalar"`` forces the
    full scalar sweep.  Custom ``TechNode`` objects outside the registry
    fall back to the scalar engine automatically.

    ``backend`` selects the evaluation backend for the array engines —
    any name in :func:`repro.core.backend.available_backends` (``None``
    -> ``"xla"``; ``"pallas"`` routes through the fused Pallas grid
    kernel).  Every engine choice resolves through that registry, so an
    unknown backend raises immediately naming the available ones;
    ``engine="scalar"`` evaluates no grids and rejects an explicit
    backend.

    ``scenarios`` runs the search at *session* level: every configuration
    is simulated through the given user-behavior traces (a
    :class:`~repro.core.scenario.ScenarioSet`, profile name(s), or
    ``"all"`` — see :func:`repro.core.scenario.as_scenario_set`), the
    trace becomes one more search axis, and ``objective`` may then be any
    of :data:`SESSION_OBJECTIVES` (``time_to_empty_s`` is maximized, the
    rest minimized).  The returned point carries the winning ``trace``
    name and a ``session`` dict with all four session channels.
    Constraints may mix static and session channels (e.g. maximize
    ``time_to_empty_s`` subject to ``peak_case_temp_c <= 40``).

    ``checkpoint_dir`` (with optional ``checkpoint_every_s``) makes the
    *streaming* route fault-tolerant: searches above
    :data:`STREAM_THRESHOLD` configurations periodically snapshot their
    running reductions there and resume bitwise-identically after a
    crash (see :func:`repro.core.stream.stream_grid`).  Dense and
    scalar searches finish in one pass and ignore the knobs.
    """
    if objective not in OBJECTIVES + SESSION_OBJECTIVES:
        raise ValueError(f"unknown objective {objective!r}; "
                         f"have {OBJECTIVES} plus the session channels "
                         f"{SESSION_OBJECTIVES} (which require scenarios=)")
    if objective in SESSION_OBJECTIVES and scenarios is None:
        raise ValueError(
            f"objective {objective!r} is a session channel; pass "
            f"scenarios= (a ScenarioSet, profile name, or 'all' — see "
            f"repro.core.scenario)")
    sset = None
    if scenarios is not None:
        from . import scenario as _scenario
        sset = _scenario.as_scenario_set(scenarios)
    from . import backend as _backend
    if backend is not None and engine == "scalar":
        raise ValueError("backend= applies to the array/streaming "
                         "engines; engine='scalar' evaluates none")
    _backend.get_backend(backend)   # fail fast, naming available backends
    known = set(_AXIS_TO_KWARG.values()) | {"detnet", "keynet", "cuts"}
    unknown_kw = sorted(set(kw) - known)
    if unknown_kw:
        # The grid branch rebuilds its evaluate_cut call from the axis
        # map, so a misspelled knob would otherwise be dropped silently.
        raise TypeError(f"unknown knobs {unknown_kw}; have {sorted(known)}")
    from . import sweep as _sweep

    cons = _sweep.parse_constraints(constraints)

    def constrained_best(res):
        if cons:
            res = res.constrain(cons)
            # isnan (not isfinite): time_to_empty_s is legitimately +inf
            # for configurations that drain nothing.
            if np.isnan(res.data[objective]).all():
                raise ValueError(
                    "no configuration satisfies constraints ("
                    + ", ".join(f"{f} {op} {v:g}" for f, op, v in cons)
                    + ") — loosen the constraints or widen the knobs")
        if objective in _MAXIMIZED:
            neg = dataclasses.replace(
                res, data={**dict(res.data),
                           objective: -np.asarray(res.data[objective])})
            win = neg.argmin(objective)
            win[objective] = -win[objective]
            return win
        return res.argmin(objective)

    cuts = kw.pop("cuts", None)
    if cuts is not None:
        cuts = tuple(cuts)        # may be a generator: materialize once
    multi = cuts is not None or sset is not None or any(
        _is_axis(v) for k, v in kw.items() if k not in ("detnet", "keynet"))
    if multi:
        if engine != "array":
            raise ValueError("sequence-valued knobs (cuts= or scenarios=) "
                             "require engine='array'")
        axes = _sweep.scalar_axes(kw)
        for name in ("agg_nodes", "sensor_nodes"):
            bad = [n for n in axes[name] if _registry_name(n) is None]
            if bad:
                raise ValueError(f"{name} entries outside the TECH_NODES "
                                 f"registry not supported in a grid "
                                 f"search: {bad}")
        # Same eager guard as the scalar path: if *every* (sensor node,
        # weight mem) combination lacks a test vehicle, all cut > 0
        # corners are NaN and the argmin would quietly return the one
        # valid centralized point instead of surfacing the error.
        if all(m == "mram" and _resolve_node(n).mram is None
               for m in axes["weight_mems"] for n in axes["sensor_nodes"]):
            raise ValueError(
                "no MRAM test vehicle at any requested sensor node "
                f"{tuple(_resolve_node(n).name for n in axes['sensor_nodes'])}"
                " — every distributed (cut > 0) configuration is invalid")
        n_det = len((kw.get("detnet") or build_detnet()).layers)
        n_key = len((kw.get("keynet") or build_keynet()).layers)
        n_cuts = (len(list(cuts)) if cuts is not None
                  else n_det + n_key + 1)
        n_configs = n_cuts
        for name in ("agg_nodes", "sensor_nodes", "weight_mems",
                     "detnet_fps", "keynet_fps", "num_cameras",
                     "mipi_energy_scale", "camera_fps"):
            n_configs *= len(axes[name])
        if sset is not None:
            n_configs *= len(sset.traces)
        if n_configs > STREAM_THRESHOLD:
            from . import stream as _stream
            ckpt_kw = {}
            if checkpoint_dir is not None:
                ckpt_kw["checkpoint_dir"] = checkpoint_dir
                if checkpoint_every_s is not None:
                    ckpt_kw["checkpoint_every_s"] = checkpoint_every_s
            maximize = ((objective,) if objective in _MAXIMIZED else ())
            sres = _stream.stream_grid(
                cuts=cuts, objectives=(objective,), maximize=maximize,
                constraints=cons, backend=backend, scenarios=sset,
                **ckpt_kw, **axes)
            # StreamResult.argmin is always natural-orientation
            # minimization; under maximize= the best point is the head
            # of the (sign-flipped) top-k heap.
            win = (sres.top_k(objective)[0] if maximize
                   else sres.argmin(objective))
        else:
            win = constrained_best(_sweep.evaluate_grid(
                cuts=cuts, backend=backend, scenarios=sset, **axes))
        scalar_kw = {_AXIS_TO_KWARG[name]: win[name]
                     for name in _AXIS_TO_KWARG}
        scalar_kw["num_cameras"] = int(scalar_kw["num_cameras"])
        point = evaluate_cut(int(win["cut"]), detnet=kw.get("detnet"),
                             keynet=kw.get("keynet"), **scalar_kw)
        if sset is not None:
            # Re-simulate the winning (config, trace) pair through the
            # dense engine to attach all four session channels.
            r1 = _sweep.evaluate_grid(
                cuts=(int(win["cut"]),), scenarios=sset.only(win["trace"]),
                detnet=kw.get("detnet"), keynet=kw.get("keynet"),
                backend=backend,
                agg_nodes=(win["agg_node"],),
                sensor_nodes=(win["sensor_node"],),
                weight_mems=(win["weight_mem"],),
                detnet_fps=(float(win["detnet_fps"]),),
                keynet_fps=(float(win["keynet_fps"]),),
                num_cameras=(float(win["num_cameras"]),),
                mipi_energy_scale=(float(win["mipi_energy_scale"]),),
                camera_fps=(float(win["camera_fps"]),))
            session = {f: float(r1.data[f].ravel()[0])
                       for f in _sweep.SCENARIO_FIELDS}
            point = dataclasses.replace(point, trace=str(win["trace"]),
                                        session=session)
        return point

    agg = _registry_name(kw.get("agg_node", "7nm"))
    sen = _registry_name(kw.get("sensor_node", "7nm"))
    # Keep the engines interchangeable: the scalar sweep raises for an
    # MRAM request on a node with no test vehicle (every cut > 0 is
    # invalid), so the array path must not quietly return the one valid
    # centralized point instead.
    if (kw.get("sensor_weight_mem", "sram") == "mram"
            and _resolve_node(kw.get("sensor_node", "7nm")).mram is None):
        raise ValueError(
            f"no MRAM test vehicle at "
            f"{_resolve_node(kw.get('sensor_node', '7nm')).name}")
    if engine == "array" and agg is not None and sen is not None:
        res = _sweep.evaluate_grid(backend=backend, **_sweep.scalar_axes(kw))
        return evaluate_cut(constrained_best(res)["cut"], **kw)
    if backend is not None:
        # Custom TechNodes outside the registry fall back to the scalar
        # engine, which evaluates no grids — an explicit backend request
        # must not be silently ignored there.
        raise ValueError(
            "backend= cannot be honored: these knobs fall back to the "
            "scalar engine (custom TechNode outside the registry)")
    points = sweep_partitions(**kw)
    if cons:
        # The scalar path only carries the objective scalars, so
        # constraint channels must be PartitionPoint attributes.
        for field, _, _ in cons:
            if not hasattr(points[0], field):
                raise ValueError(
                    f"constraint channel {field!r} is not available on "
                    f"the scalar engine; use engine='array'")
        points = [p for p in points
                  if all(_sweep.CONSTRAINT_OPS[op](getattr(p, f), v)
                         for f, op, v in cons)]
        if not points:
            raise ValueError(
                "no cut satisfies constraints ("
                + ", ".join(f"{f} {op} {v:g}" for f, op, v in cons) + ")")
    return min(points, key=lambda p: getattr(p, objective))
