"""Workload partition optimizer across the distributed compute hierarchy.

The paper's central system knob: where to cut the CV pipeline between the
on-sensor processor and the aggregator.  The hand-tracking pipeline is

    raw frame -> DetNet -> (boxes back to sensor) -> ROI crop -> KeyNet -> kp

and every layer boundary is a legal cut.  For cut index ``k`` over the
concatenated layer list (DetNet ++ KeyNet):

* ``k == 0``                  — fully centralized (Fig. 1a): the raw frame
  crosses MIPI at camera rate (the aggregator needs it for the ROI crop).
* ``0 < k < len(DetNet)``     — DetNet is split: the cut activation crosses
  MIPI at DetNet rate, *and* the ROI crop still has to cross at KeyNet rate
  (the raw frame only exists on-sensor; box coords return over MIPI, tiny).
* ``k == len(DetNet)``        — the paper's choice (Fig. 2): only the ROI
  (at KeyNet rate) + DetNet outputs (at DetNet rate) cross MIPI.
* ``k > len(DetNet)``         — KeyNet is split: the KeyNet cut activation
  crosses at KeyNet rate; ROI stays on-sensor.

The optimizer evaluates Eq. 1/2 for every cut and returns the sweep — the
reproduction target is that the minimum lands exactly on the paper's
DetNet/KeyNet boundary.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from . import energy as E
from .constants import (CAMERA_FPS, DETNET_FPS, KEYNET_FPS, MIPI, NUM_CAMERAS,
                        ON_SENSOR_SCALE, T_SENSE_S, UTSV, TechNode)
from .handtracking import (FULL_FRAME_BYTES, ROI_BYTES, build_detnet,
                           build_keynet)
from .system import (Deployment, ProcessorSite, SystemReport,
                     _camera_modules, _link_modules, _resolve_node, MemKind)
from .workloads import NNWorkload

BOX_COORDS_BYTES = 64   # detection boxes returned sensor-ward (per frame)


@dataclasses.dataclass(frozen=True)
class PartitionPoint:
    cut: int
    label: str
    avg_power: float
    mipi_bytes_per_s: float
    sensor_macs_per_s: float
    report: SystemReport


def _sub_workload(wl: NNWorkload, lo: int, hi: int,
                  name: str) -> NNWorkload | None:
    layers = wl.layers[lo:hi]
    if not layers:
        return None
    return NNWorkload(name=name, layers=tuple(layers),
                      input_bytes=layers[0].in_act_bytes,
                      output_bytes=layers[-1].out_act_bytes)


def evaluate_cut(cut: int,
                 agg_node: str | TechNode = "7nm",
                 sensor_node: str | TechNode = "7nm",
                 sensor_weight_mem: MemKind = "sram",
                 detnet: NNWorkload | None = None,
                 keynet: NNWorkload | None = None,
                 num_cameras: int = NUM_CAMERAS,
                 camera_fps: float = CAMERA_FPS,
                 detnet_fps: float = DETNET_FPS,
                 keynet_fps: float = KEYNET_FPS) -> PartitionPoint:
    """Build the full Eq.1/2 module list for one partition point."""
    detnet = detnet or build_detnet()
    keynet = keynet or build_keynet()
    agg_n = _resolve_node(agg_node)
    sen_n = _resolve_node(sensor_node)
    n_det = len(detnet.layers)
    n_all = n_det + len(keynet.layers)
    assert 0 <= cut <= n_all

    mods: list[E.ModuleEnergy] = []
    centralized = cut == 0
    cam_link = MIPI if centralized else UTSV
    mods += _camera_modules(num_cameras, readout_link=cam_link,
                            fps=camera_fps, t_sense=T_SENSE_S)
    if not centralized:
        mods += _link_modules(num_cameras, UTSV, FULL_FRAME_BYTES,
                              camera_fps, tag="utsv")

    # ---- what crosses MIPI ----
    mipi_payloads: list[tuple[float, float]] = []   # (bytes, rate)
    if centralized:
        mipi_payloads.append((FULL_FRAME_BYTES, camera_fps))
    elif cut < n_det:
        act = detnet.layers[cut - 1].out_act_bytes if cut > 0 else 0
        mipi_payloads.append((act, detnet_fps))
        mipi_payloads.append((BOX_COORDS_BYTES, detnet_fps))  # boxes back
        mipi_payloads.append((ROI_BYTES, keynet_fps))         # crop forward
    elif cut == n_det:
        mipi_payloads.append((detnet.output_bytes, detnet_fps))
        mipi_payloads.append((ROI_BYTES, keynet_fps))
    else:
        act = keynet.layers[cut - n_det - 1].out_act_bytes
        mipi_payloads.append((act, keynet_fps))
        mipi_payloads.append((detnet.output_bytes, detnet_fps))
    for i, (b, r) in enumerate(mipi_payloads):
        mods += _link_modules(num_cameras, MIPI, b, r, tag=f"mipi.{i}")

    # ---- sensor-side deployment ----
    sensor_wls: list[tuple[NNWorkload, float]] = []
    det_s = _sub_workload(detnet, 0, min(cut, n_det), "DetNet.sensor")
    if det_s:
        sensor_wls.append((det_s, detnet_fps))
    key_s = _sub_workload(keynet, 0, max(0, cut - n_det), "KeyNet.sensor")
    if key_s:
        sensor_wls.append((key_s, keynet_fps))
    if not centralized:
        for i in range(num_cameras):
            mods += Deployment(
                site=ProcessorSite(name=f"sensor{i}", node=sen_n,
                                   scale=ON_SENSOR_SCALE,
                                   weight_mem=sensor_weight_mem,
                                   l1_bytes=16 * 1024),
                workloads=[(w, f) for w, f in sensor_wls],
                extra_buffer_bytes=detnet.input_bytes,
            ).modules()

    # ---- aggregator-side deployment ----
    agg_wls: list[tuple[NNWorkload, float]] = []
    det_a = _sub_workload(detnet, min(cut, n_det), n_det, "DetNet.agg")
    if det_a:
        agg_wls.append((det_a, detnet_fps * num_cameras))
    key_a = _sub_workload(keynet, max(0, cut - n_det), len(keynet.layers),
                          "KeyNet.agg")
    if key_a:
        agg_wls.append((key_a, keynet_fps * num_cameras))
    in_buf = (FULL_FRAME_BYTES if centralized else
              max(b for b, _ in mipi_payloads)) * num_cameras
    if agg_wls:
        mods += Deployment(
            site=ProcessorSite(name="agg", node=agg_n, scale=1.0),
            workloads=agg_wls,
            extra_buffer_bytes=in_buf,
        ).modules()

    label = ("centralized" if centralized else
             "paper-split(DetNet|KeyNet)" if cut == n_det else
             f"cut@{cut}")
    rep = SystemReport(name=f"partition[{label}]", modules=mods)
    mipi_rate = sum(b * r for b, r in mipi_payloads) * num_cameras
    sensor_macs = sum(w.total_macs * f for w, f in sensor_wls) * num_cameras
    return PartitionPoint(cut=cut, label=label, avg_power=rep.avg_power,
                          mipi_bytes_per_s=mipi_rate,
                          sensor_macs_per_s=sensor_macs, report=rep)


def sweep_partitions(**kw) -> list[PartitionPoint]:
    detnet = kw.get("detnet") or build_detnet()
    keynet = kw.get("keynet") or build_keynet()
    kw["detnet"], kw["keynet"] = detnet, keynet
    n_all = len(detnet.layers) + len(keynet.layers)
    return [evaluate_cut(c, **kw) for c in range(n_all + 1)]


def optimal_partition(**kw) -> PartitionPoint:
    """The paper's claim: the optimum sits at the DetNet/KeyNet boundary."""
    return min(sweep_partitions(**kw), key=lambda p: p.avg_power)
