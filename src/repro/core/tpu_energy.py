"""TPU adaptation of the paper's semi-analytical energy model (Eq. 1/2).

The paper sums per-module energies — cameras, links, compute, memory — with
counts extracted by GVSoC.  On a TPU pod the same decomposition is:

    E_step =  HLO_FLOPs   x E_flop                      (Eq. 7 analogue)
            + HBM_bytes   x E_hbm_byte                  (Eq. 8 analogue)
            + ICI_bytes   x E_ici_byte                  (Eq. 5, cheap tier)
            + DCN_bytes   x E_dcn_byte                  (Eq. 5, MIPI tier)
            + P_idle      x max(0, T_step - T_busy)     (Eq. 11 analogue)

per chip, with counts taken from the compiled dry-run (cost_analysis + HLO
collective parse).  The host input pipeline plays the camera's role: a fixed
per-byte ingest cost at the data-delivery rate.

This module powers the energy-aware partition advisor in
:mod:`repro.core.dosc` — the paper's technique as a framework feature.
"""

from __future__ import annotations

import dataclasses

from .constants import TPU_V5E, TPUChipSpec
from .hlo_analysis import CollectiveSummary
from .roofline import RooflineTerms


@dataclasses.dataclass(frozen=True)
class StepEnergy:
    """Per-chip, per-step energy breakdown (joules)."""

    e_compute: float
    e_hbm: float
    e_ici: float
    e_dcn: float
    e_idle: float
    t_step: float

    @property
    def total(self) -> float:
        return (self.e_compute + self.e_hbm + self.e_ici + self.e_dcn
                + self.e_idle)

    @property
    def avg_power_w(self) -> float:
        """Eq. 2 analogue: energy x step rate."""
        return self.total / self.t_step if self.t_step > 0 else 0.0

    def breakdown(self) -> dict[str, float]:
        return {"compute": self.e_compute, "hbm": self.e_hbm,
                "ici": self.e_ici, "dcn": self.e_dcn, "idle": self.e_idle}


def split_tiers(collectives: CollectiveSummary,
                intra_pod_chips: int) -> tuple[float, float]:
    """Split collective wire bytes into (ICI, DCN) tiers by group size.

    Collectives whose participating groups fit inside one pod ride the
    cheap ICI tier (the paper's uTSV); groups spanning more devices than a
    pod holds must traverse the inter-pod DCN tier (the paper's MIPI).
    """
    ici = dcn = 0.0
    for group_size, wire in collectives.by_group_size().items():
        if group_size <= intra_pod_chips:
            ici += wire
        else:
            dcn += wire
    return ici, dcn


def step_energy(terms: RooflineTerms, collectives: CollectiveSummary,
                intra_pod_chips: int,
                t_step: float | None = None,
                chip: TPUChipSpec = TPU_V5E) -> StepEnergy:
    """Eq. 1 analogue for one training/serving step on one chip.

    ``t_step`` defaults to the roofline bound (perfect overlap); pass a
    measured/estimated step time to account for idle (Eq. 10/11 analogue:
    idle arises when a chip waits — stragglers, pipeline bubbles, input
    stalls).
    """
    ici_b, dcn_b = split_tiers(collectives, intra_pod_chips)
    t_busy = terms.t_bound
    t = t_step if t_step is not None else t_busy
    e_idle = chip.idle_power * max(0.0, t - t_busy)
    # idle_power also burns during busy time as a baseline floor:
    e_idle += chip.idle_power * t_busy
    return StepEnergy(
        e_compute=terms.hlo_flops * chip.e_per_flop,
        e_hbm=terms.hlo_bytes * chip.e_hbm_per_byte,
        e_ici=ici_b * chip.e_ici_per_byte,
        e_dcn=dcn_b * chip.e_dcn_per_byte,
        e_idle=e_idle,
        t_step=t,
    )


def system_power_w(e: StepEnergy, chips: int) -> float:
    """Whole-machine average power (Eq. 2 over all chip 'modules')."""
    return e.avg_power_w * chips
