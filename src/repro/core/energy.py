"""Semi-analytical energy equations — faithful implementations of Eqs. 3-11.

Every function cites the equation it implements.  Units: joules, seconds,
bytes, watts.  The equations are deliberately simple ("semi-analytical"): all
workload-dependent complexity lives in the *counts* fed into them, which the
paper extracts with GVSoC/DORY and we extract either from
:mod:`repro.core.workloads` layer tables (faithful path) or from compiled XLA
HLO (TPU-adapted path, :mod:`repro.core.tpu_energy`).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from .constants import CameraPower, LinkSpec, MemorySpec


# ---------------------------------------------------------------------------
# Eq. 5 / Eq. 6 — communication links
# ---------------------------------------------------------------------------


def comm_energy(a_size_bytes: float, link: LinkSpec) -> float:
    """Eq. 5:  E_comm = A_size * E_byte_comm."""
    return a_size_bytes * link.energy_per_byte


def comm_time(a_size_bytes: float, link: LinkSpec) -> float:
    """Eq. 6:  T_comm = A_size / BW_comm."""
    return a_size_bytes / link.bandwidth


# ---------------------------------------------------------------------------
# Eq. 3 / Eq. 4 — camera
# ---------------------------------------------------------------------------


def camera_off_time(fps: float, t_sense: float, t_comm: float) -> float:
    """Eq. 4:  T_off = 1/fps - T_sense - T_comm  (clamped at 0)."""
    return max(0.0, 1.0 / fps - t_sense - t_comm)


def camera_energy(power: CameraPower, fps: float, t_sense: float,
                  t_comm: float) -> float:
    """Eq. 3:  E_ca = P_sense*T_sense + P_rd*T_comm + P_off*T_off.

    ``t_comm`` is the readout time, which depends on the interface between
    the camera and the compute module (Eq. 6) — this is where the uTSV's
    200x bandwidth advantage over MIPI shortens the 36 mW readout window.
    """
    t_off = camera_off_time(fps, t_sense, t_comm)
    return (power.sense * t_sense + power.read * t_comm + power.idle * t_off)


# ---------------------------------------------------------------------------
# Eq. 7 — compute
# ---------------------------------------------------------------------------


def compute_energy(num_macs: float, e_mac: float) -> float:
    """Eq. 7:  E_comp = #MACs * E_MAC."""
    return num_macs * e_mac


# ---------------------------------------------------------------------------
# Eq. 8 — memory access
# ---------------------------------------------------------------------------


def memory_access_energy(read_bytes: float, write_bytes: float,
                         mem: MemorySpec) -> float:
    """Eq. 8:  E_rw = #Read * E_byte_read + #Write * E_byte_write."""
    return read_bytes * mem.e_read + write_bytes * mem.e_write


# ---------------------------------------------------------------------------
# Eq. 9 / Eq. 10 / Eq. 11 — leakage with On / Retention / Off states
# ---------------------------------------------------------------------------


def idle_time(fps: float, t_processing: float) -> float:
    """Eq. 10:  T_idle = 1/fps - T_processing  (clamped at 0)."""
    return max(0.0, 1.0 / fps - t_processing)


def memory_leakage_energy(t_processing: float, fps: float,
                          capacity_bytes: float, mem: MemorySpec) -> float:
    """Eq. 11:  E_lk = T_proc * Lk_on + T_idle * Lk_ret_off   (per frame).

    ``Lk`` scales with the memory instance capacity.  For SRAM the idle
    state is data-retentive drowsy mode (``leak_ret``); for STT-MRAM it is a
    true power-off (leak_ret == 0) because the array is non-volatile.
    """
    t_idle = idle_time(fps, t_processing)
    return capacity_bytes * (mem.leak_on * t_processing
                             + mem.leak_ret * t_idle)


# ---------------------------------------------------------------------------
# Eq. 1 / Eq. 2 — module aggregation
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ModuleEnergy:
    """Per-frame energy of one module instance plus its operating rate.

    Eq. 2 multiplies each module's per-frame energy by the fps *at which that
    module operates* — the paper's key knob for running DetNet at a lower
    rate than the camera.
    """

    name: str
    group: str            # breakdown key: "camera", a link tag ("mipi.0",
                          # "utsv"), or "<site>.compute" / "<site>.memory"
    energy_per_frame: float
    fps: float

    @property
    def avg_power(self) -> float:
        """Eq. 2 contribution:  P = E_frame * fps."""
        return self.energy_per_frame * self.fps


def total_energy_per_frame(modules: list[ModuleEnergy]) -> float:
    """Eq. 1:  E_total = sum over module energies (per frame)."""
    return sum(m.energy_per_frame for m in modules)


def average_power(modules: list[ModuleEnergy]) -> float:
    """Eq. 2:  P_avg = sum over module energies x module fps."""
    return sum(m.avg_power for m in modules)


def power_breakdown(modules: list[ModuleEnergy]) -> dict[str, float]:
    """Average power per module group (the stacked bars of Fig. 5)."""
    out: dict[str, float] = {}
    for m in modules:
        out[m.group] = out.get(m.group, 0.0) + m.avg_power
    return out
