"""System topology assembly: centralized vs distributed on-sensor compute.

Builds the full module list (cameras, links, processors, memories) for the
two architectures of Fig. 1 and evaluates Eq. 1/2 over them.  The returned
:class:`SystemReport` carries the per-group breakdown used to reproduce the
stacked bars of Fig. 5a and the on-sensor subsystem split of Fig. 5b.
"""

from __future__ import annotations

import dataclasses
from typing import Literal, Sequence

from . import energy as E
from . import rbe
from .constants import (AGG_L1_BYTES, CAMERA_FPS, DETNET_FPS, DPS_CAMERA,
                        KEYNET_FPS, L1_ENERGY_SCALE, MIPI, NUM_CAMERAS,
                        ON_SENSOR_SCALE, RBE, SENSOR_L1_BYTES, T_SENSE_S,
                        TECH_NODES, UTSV, CameraPower, LinkSpec, MemorySpec,
                        TechNode)
from .handtracking import (FULL_FRAME_BYTES, ROI_BYTES, build_detnet,
                           build_keynet)
from .workloads import NNWorkload

MemKind = Literal["sram", "mram"]


@dataclasses.dataclass(frozen=True)
class ProcessorSite:
    """One compute site (an on-sensor processor or the aggregator)."""

    name: str
    node: TechNode
    scale: float                      # compute capability vs full RBE
    weight_mem: MemKind = "sram"
    l1_bytes: int = AGG_L1_BYTES

    def weight_mem_spec(self) -> MemorySpec:
        if self.weight_mem == "mram":
            if self.node.mram is None:
                raise ValueError(f"no MRAM test vehicle at {self.node.name}")
            return self.node.mram
        return self.node.sram

    def l1_spec(self) -> MemorySpec:
        # L1 is a small, faster SRAM: cheaper per-byte access than L2.
        return dataclasses.replace(
            self.node.sram,
            name=f"L1-{self.node.name}",
            e_read=self.node.sram.e_read * L1_ENERGY_SCALE,
            e_write=self.node.sram.e_write * L1_ENERGY_SCALE)


@dataclasses.dataclass(frozen=True)
class Deployment:
    """A set of networks running on one processor site, each at its own fps."""

    site: ProcessorSite
    workloads: Sequence[tuple[NNWorkload, float]]   # (network, fps)
    extra_buffer_bytes: int = 0     # e.g. raw-frame input buffers (L2 act)

    # ---- derived ----
    def t_processing_per_frame(self, wl: NNWorkload) -> float:
        """Eq. 9 for one inference of ``wl`` on this site."""
        return rbe.processing_time_s(wl, self.site.node, RBE, self.site.scale)

    def duty_processing_per_second(self) -> float:
        """Total accelerator-busy seconds per second (all networks)."""
        return sum(self.t_processing_per_frame(wl) * fps
                   for wl, fps in self.workloads)

    def l2_weight_capacity(self) -> int:
        """Paper: 'The L2 weight memories were sized to hold the full
        weights of the models.'"""
        return sum(wl.total_weight_bytes for wl, _ in self.workloads)

    def l2_act_capacity(self) -> int:
        peak = max((wl.peak_act_bytes for wl, _ in self.workloads), default=0)
        return peak + self.extra_buffer_bytes

    def modules(self) -> list[E.ModuleEnergy]:
        """Compute + memory modules for Eq. 1/2 (per-second accounting).

        We evaluate at fps=1 with per-second energies so that multiple
        networks at different rates on one shared site aggregate exactly.
        """
        site = self.site
        node = site.node
        sram = node.sram
        wspec = site.weight_mem_spec()
        l1 = site.l1_spec()
        mods: list[E.ModuleEnergy] = []

        # --- Eq. 7: compute ---
        macs_per_s = sum(wl.total_macs * fps for wl, fps in self.workloads)
        mods.append(E.ModuleEnergy(
            name=f"{site.name}.compute", group=f"{site.name}.compute",
            energy_per_frame=E.compute_energy(macs_per_s, node.e_mac),
            fps=1.0))

        # --- Eq. 8: memory accesses (per second) ---
        w_read = act_read = act_write = 0.0
        for wl, fps in self.workloads:
            w_read += rbe.total_weight_stream_bytes(wl) * fps
            act_read += wl.total_act_traffic_bytes / 2 * fps
            act_write += wl.total_act_traffic_bytes / 2 * fps
        # L1 sees every streamed byte once more (L2 -> L1 -> engine).
        l1_traffic = w_read + act_read + act_write

        mods.append(E.ModuleEnergy(
            name=f"{site.name}.l2w.rw", group=f"{site.name}.memory",
            energy_per_frame=E.memory_access_energy(w_read, 0.0, wspec),
            fps=1.0))
        mods.append(E.ModuleEnergy(
            name=f"{site.name}.l2a.rw", group=f"{site.name}.memory",
            energy_per_frame=E.memory_access_energy(act_read, act_write,
                                                    sram),
            fps=1.0))
        mods.append(E.ModuleEnergy(
            name=f"{site.name}.l1.rw", group=f"{site.name}.memory",
            energy_per_frame=E.memory_access_energy(l1_traffic / 2,
                                                    l1_traffic / 2, l1),
            fps=1.0))

        # --- Eq. 9/10/11: leakage (per second: fps=1, T window = 1 s) ---
        t_proc = min(1.0, self.duty_processing_per_second())
        for cap, spec, tag in (
                (self.l2_weight_capacity(), wspec, "l2w"),
                (self.l2_act_capacity(), sram, "l2a"),
                (site.l1_bytes, l1, "l1")):
            mods.append(E.ModuleEnergy(
                name=f"{site.name}.{tag}.leak", group=f"{site.name}.memory",
                energy_per_frame=E.memory_leakage_energy(
                    t_proc, 1.0, cap, spec),
                fps=1.0))
        return mods


@dataclasses.dataclass
class SystemReport:
    name: str
    modules: list[E.ModuleEnergy]

    @property
    def avg_power(self) -> float:
        return E.average_power(self.modules)

    def breakdown(self) -> dict[str, float]:
        return E.power_breakdown(self.modules)

    def group_power(self, *prefixes: str) -> float:
        return sum(p for g, p in self.breakdown().items()
                   if any(g.startswith(pre) for pre in prefixes))


# ---------------------------------------------------------------------------
# Topology builders
# ---------------------------------------------------------------------------


def _camera_modules(n: int, readout_link: LinkSpec,
                    frame_bytes: int = FULL_FRAME_BYTES,
                    fps: float = CAMERA_FPS,
                    power: CameraPower = DPS_CAMERA,
                    t_sense: float = T_SENSE_S) -> list[E.ModuleEnergy]:
    """Cameras (Eq. 3): readout window set by the camera-side interface."""
    t_comm = E.comm_time(frame_bytes, readout_link)
    e = E.camera_energy(power, fps, t_sense, t_comm)
    return [E.ModuleEnergy(name=f"camera{i}", group="camera",
                           energy_per_frame=e, fps=fps) for i in range(n)]


def _link_modules(n: int, link: LinkSpec, payload_bytes: float, fps: float,
                  tag: str) -> list[E.ModuleEnergy]:
    e = E.comm_energy(payload_bytes, link)
    return [E.ModuleEnergy(name=f"{tag}{i}", group=tag,
                           energy_per_frame=e, fps=fps) for i in range(n)]


def _resolve_node(node: str | TechNode) -> TechNode:
    return TECH_NODES[node] if isinstance(node, str) else node


def replicate_site_modules(base: list[E.ModuleEnergy], base_site: str,
                           count: int) -> list[E.ModuleEnergy]:
    """Replicate one site's module list across ``count`` identical sites.

    The per-camera sensor deployments are identical except for the site
    name, so the (layer-reduction-heavy) module list is built once and
    copies are relabelled — ``base_site`` ("sensor0") becomes "sensor1",
    "sensor2", ... in both the module name and its breakdown group.
    """
    if not base_site.endswith("0"):
        raise ValueError(f"base_site {base_site!r} must name replica 0 "
                         "(end in '0') so siblings can be derived")
    if count <= 0:
        return []
    out = list(base)
    for i in range(1, count):
        site = base_site[:-1] + str(i)
        out += [dataclasses.replace(m,
                                    name=m.name.replace(base_site, site, 1),
                                    group=m.group.replace(base_site, site, 1))
                for m in base]
    return out


def build_centralized(agg_node: str | TechNode = "7nm",
                      detnet: NNWorkload | None = None,
                      keynet: NNWorkload | None = None,
                      num_cameras: int = NUM_CAMERAS,
                      camera_fps: float = CAMERA_FPS,
                      detnet_fps: float = DETNET_FPS,
                      keynet_fps: float = KEYNET_FPS,
                      t_sense: float = T_SENSE_S) -> SystemReport:
    """Fig. 1(a): full frames cross MIPI; everything runs on the aggregator.

    The aggregator's L2 activation memory additionally buffers the incoming
    raw frames from all cameras.
    """
    detnet = detnet or build_detnet()
    keynet = keynet or build_keynet()
    node = _resolve_node(agg_node)
    mods: list[E.ModuleEnergy] = []
    mods += _camera_modules(num_cameras, readout_link=MIPI, fps=camera_fps,
                            t_sense=t_sense)
    mods += _link_modules(num_cameras, MIPI, FULL_FRAME_BYTES, camera_fps,
                          tag="mipi")
    agg = Deployment(
        site=ProcessorSite(name="agg", node=node, scale=1.0),
        workloads=[(detnet, detnet_fps * num_cameras),
                   (keynet, keynet_fps * num_cameras)],
        extra_buffer_bytes=FULL_FRAME_BYTES * num_cameras,
    )
    mods += agg.modules()
    return SystemReport(name=f"centralized[A={node.name}]", modules=mods)


def build_distributed(agg_node: str | TechNode = "7nm",
                      sensor_node: str | TechNode = "7nm",
                      sensor_weight_mem: MemKind = "sram",
                      detnet: NNWorkload | None = None,
                      keynet: NNWorkload | None = None,
                      num_cameras: int = NUM_CAMERAS,
                      camera_fps: float = CAMERA_FPS,
                      detnet_fps: float = DETNET_FPS,
                      keynet_fps: float = KEYNET_FPS,
                      t_sense: float = T_SENSE_S) -> SystemReport:
    """Fig. 1(b): DetNet on-sensor; only the ROI crosses MIPI.

    * Cameras read out over uTSV (100 GB/s) -> short 36 mW readout window.
    * Each sensor duplicates the DetNet weight memory (the paper's noted
      leakage cost of distribution).
    * MIPI carries the 96x96 ROI at KeyNet rate plus tiny DetNet outputs.
    """
    detnet = detnet or build_detnet()
    keynet = keynet or build_keynet()
    agg = _resolve_node(agg_node)
    sen = _resolve_node(sensor_node)
    mods: list[E.ModuleEnergy] = []
    mods += _camera_modules(num_cameras, readout_link=UTSV, fps=camera_fps,
                            t_sense=t_sense)
    mods += _link_modules(num_cameras, UTSV, FULL_FRAME_BYTES, camera_fps,
                          tag="utsv")
    # MIPI now carries ROI crops (at KeyNet rate) + DetNet outputs (tiny).
    mods += _link_modules(num_cameras, MIPI, ROI_BYTES, keynet_fps,
                          tag="mipi")
    mods += _link_modules(num_cameras, MIPI, detnet.output_bytes, detnet_fps,
                          tag="mipi-det")
    # The per-camera sensor deployments are identical: build once, relabel.
    sensor0 = Deployment(
        site=ProcessorSite(name="sensor0", node=sen,
                           scale=ON_SENSOR_SCALE,
                           weight_mem=sensor_weight_mem,
                           l1_bytes=SENSOR_L1_BYTES),
        workloads=[(detnet, detnet_fps)],
        extra_buffer_bytes=detnet.input_bytes,
    ).modules()
    mods += replicate_site_modules(sensor0, "sensor0", num_cameras)
    aggd = Deployment(
        site=ProcessorSite(name="agg", node=agg, scale=1.0),
        workloads=[(keynet, keynet_fps * num_cameras)],
        extra_buffer_bytes=ROI_BYTES * num_cameras,
    )
    mods += aggd.modules()
    return SystemReport(
        name=(f"distributed[A={agg.name},O={sen.name},"
              f"wmem={sensor_weight_mem}]"),
        modules=mods)


# ---------------------------------------------------------------------------
# Fig. 5 headline comparisons
# ---------------------------------------------------------------------------


def fig5a_comparison() -> dict[str, float]:
    """Normalized system power for the Fig. 5a bars.

    Returns powers normalized to centralized[A=7nm] — the paper's
    normalization — for the three systems shown.
    """
    cen = build_centralized("7nm")
    dis77 = build_distributed("7nm", "7nm")
    dis716 = build_distributed("7nm", "16nm")
    base = cen.avg_power
    return {
        "centralized[A=7nm]": 1.0,
        "distributed[A=7nm,O=7nm]": dis77.avg_power / base,
        "distributed[A=7nm,O=16nm]": dis716.avg_power / base,
        "_saving_7nm": 1.0 - dis77.avg_power / base,
        "_saving_16nm": 1.0 - dis716.avg_power / base,
    }


def fig5b_comparison(sensor_node: str = "16nm",
                     fps: float = 10.0) -> dict[str, float]:
    """On-sensor processor+memory power, pure-SRAM vs hybrid MRAM (Fig. 5b).

    Normalized to the pure-SRAM hierarchy; the paper runs the on-sensor
    processor at 10 fps in 16 nm.
    """
    def onsensor_power(weight_mem: MemKind) -> float:
        rep = build_distributed("7nm", sensor_node,
                                sensor_weight_mem=weight_mem,
                                detnet_fps=fps)
        return rep.group_power("sensor")

    sram = onsensor_power("sram")
    hybrid = onsensor_power("mram")
    return {
        "sram": 1.0,
        "hybrid": hybrid / sram,
        "_saving": 1.0 - hybrid / sram,
    }
