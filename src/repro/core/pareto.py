"""Multi-objective Pareto analysis over design-space grids.

The paper's core claim is that the distributed on-sensor architecture wins
on *power, latency, and MIPI traffic simultaneously* — which makes the
partition search a multi-objective problem, not an ``argmin`` over one
channel.  This module extracts exact non-dominated sets from the dense
grids of :func:`repro.core.sweep.evaluate_grid`:

* :func:`non_dominated_mask` — exact dominance filtering over an ``(n, d)``
  objective matrix: a lexicographic sort (dominators always precede the
  points they dominate) followed by chunked, vectorized culling against
  the running front, so cost scales with ``n × front_size`` instead of
  ``n²`` on realistic grids.  Rows with any non-finite entry (the NaN
  invalid-MRAM corners of the grid engine) are masked out up front.
* :func:`pareto_front` — the front of a :class:`~repro.core.sweep.
  SweepResult` over arbitrary objective channels, each minimized by
  default or maximized via ``maximize=``.
* :func:`hypervolume` — exact dominated hypervolume w.r.t. a reference
  point (sweep for d ≤ 2, recursive objective slicing above), the scalar
  front-quality metric benchmarked in ``benchmarks/pareto_bench.py``.
* :func:`knee_point` — the balanced-compromise point: minimum Euclidean
  distance to the ideal point after per-objective [0, 1] normalization.

Dominance convention throughout (minimization): ``a`` dominates ``b`` iff
``a <= b`` in every objective and ``a < b`` in at least one.  Duplicate
points do not dominate each other, so ties survive into the front.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Mapping, Sequence

import numpy as np

from .sweep import SweepResult

#: The paper's three headline objectives, all minimized.
DEFAULT_OBJECTIVES = ("avg_power", "latency", "mipi_bytes_per_s")

_CHUNK = 512   # pairwise-dominance block size (memory ~ chunk × n × d)


def non_dominated_mask(points: np.ndarray) -> np.ndarray:
    """Boolean mask of the non-dominated rows of an ``(n, d)`` matrix.

    Minimization in every column; rows containing NaN/inf are never part
    of the front.  Exact: after a lexicographic sort any dominator
    precedes the points it dominates, and (by transitivity) a point
    dominated by a *discarded* point is also dominated by whichever front
    member discarded it — so checking each chunk against the running
    front plus pairwise within the chunk's survivors loses nothing.
    Worst case (everything mutually non-dominated) degrades gracefully to
    the plain O(n²) pairwise sweep.
    """
    pts = np.asarray(points, np.float64)
    if pts.ndim != 2:
        raise ValueError(f"expected (n, d) objective matrix, got {pts.shape}")
    mask = np.zeros(pts.shape[0], bool)
    idx = np.flatnonzero(np.isfinite(pts).all(axis=1))
    if idx.size == 0:
        return mask
    if idx.size <= 1024:
        # Small-set fast path: one shot of per-column (n, n) pairwise
        # compares — the sorted running-front machinery below has a fixed
        # cost that dwarfs sets this size (~10× slower at n=600,
        # measured).  Same dominance semantics, ties survive.
        Q = pts[idx]
        le = (Q[:, None, 0] <= Q[None, :, 0])
        lt = (Q[:, None, 0] < Q[None, :, 0])
        for c in range(1, Q.shape[1]):
            le &= Q[:, None, c] <= Q[None, :, c]
            lt |= Q[:, None, c] < Q[None, :, c]
        mask[idx] = ~(le & lt).any(axis=0)
        return mask
    order = np.lexsort(pts[idx].T[::-1])    # by col 0, ties by col 1, ...
    Q = pts[idx][order]
    out = np.zeros(Q.shape[0], bool)
    front = Q[:0]
    for lo in range(0, Q.shape[0], _CHUNK):
        blk = Q[lo:lo + _CHUNK]                              # (b, d)
        if front.shape[0]:
            le = (front[None, :, :] <= blk[:, None, :]).all(-1)
            lt = (front[None, :, :] < blk[:, None, :]).any(-1)
            alive = np.flatnonzero(~(le & lt).any(axis=1))
        else:
            alive = np.arange(blk.shape[0])
        if alive.size:
            B = blk[alive]                                   # pairwise
            le = (B[None, :, :] <= B[:, None, :]).all(-1)
            lt = (B[None, :, :] < B[:, None, :]).any(-1)
            sel = alive[~(le & lt).any(axis=1)]
            out[lo + sel] = True
            front = np.concatenate([front, blk[sel]], axis=0)
    mask[idx[order]] = out
    return mask


def merge_fronts(values_a: np.ndarray, indices_a: np.ndarray,
                 values_b: np.ndarray, indices_b: np.ndarray,
                 sign: np.ndarray | None = None
                 ) -> tuple[np.ndarray, np.ndarray]:
    """Merge two partial non-dominated sets into one exact front.

    This is the incremental-front primitive of the streaming executor
    (:mod:`repro.core.stream`): each chunk's surviving candidates are
    merged into the running front, so the exact Pareto front of an
    arbitrarily large grid is built with O(front + chunk) memory.
    ``values_*`` are ``(n, d)`` objective rows in their *natural*
    orientation with ``indices_*`` the flat grid indices; ``sign`` (+1
    minimize / -1 maximize per column, default all minimize) orients the
    dominance test.  Rows are deterministically ordered by flat index, so
    merging is associative and chunk-order independent.
    """
    Va = np.asarray(values_a, np.float64)
    Vb = np.asarray(values_b, np.float64)
    if Va.size == 0 and Va.ndim != 2:
        Va = Va.reshape(0, Vb.shape[1] if Vb.ndim == 2 else 0)
    if Vb.size == 0 and Vb.ndim != 2:
        Vb = Vb.reshape(0, Va.shape[1])
    V = np.concatenate([Va, Vb], axis=0)
    I = np.concatenate([np.asarray(indices_a, np.int64),
                        np.asarray(indices_b, np.int64)])
    if V.shape[0] != I.shape[0]:
        raise ValueError(f"values/indices length mismatch "
                         f"{V.shape[0]} != {I.shape[0]}")
    order = np.argsort(I, kind="stable")
    V, I = V[order], I[order]
    s = np.ones(V.shape[1]) if sign is None else np.asarray(sign, np.float64)
    keep = non_dominated_mask(V * s)
    return V[keep], I[keep]


# ---------------------------------------------------------------------------
# Dominance pre-filter (shared by the streaming executor's device chunk
# step and its host fallback path)
# ---------------------------------------------------------------------------


def _spread_rows(front_signed: np.ndarray, rows: int, d: int) -> np.ndarray:
    """Subsample a signed front into a fixed-size explicit-row filter.

    Rows are drawn at quantiles of the front sorted along *every*
    objective (not just the first) — a front with hundreds of members
    spreads differently along each trade-off axis, and a filter that only
    walks the first objective leaves holes that flood the exact merge
    with false survivors.  Unused rows are ``+inf`` (dominate nothing).
    """
    filt = np.full((rows, d), np.inf)
    k = front_signed.shape[0]
    if k == 0:
        return filt
    if k <= rows:
        filt[:k] = front_signed
        return filt
    per = max(1, rows // d)
    picks: list = []
    for col in range(d):
        order = np.argsort(front_signed[:, col], kind="stable")
        picks.extend(order[np.round(np.linspace(0, k - 1, per))
                           .astype(int)])
    take = np.unique(np.asarray(picks))[:rows]
    filt[:take.size] = front_signed[take]
    return filt


def build_dominance_filter(front_signed: np.ndarray, d: int,
                           rows: int = 24, bins: int = 64) -> dict:
    """Fixed-shape dominance pre-filter state over a signed running front.

    Two sufficient conditions for "this point is dominated" (so discarding
    is always exact; everything uncertain survives into the exact merge):

    * a few explicit front rows (:func:`_spread_rows`), checked directly;
    * for ``2 <= d <= 3``, a quantile-binned prefix-min table over the
      front: ``table[b1(, b2)]`` is the best (signed) first objective
      among front members whose objective-1/2 values fall in a *strictly
      lower* bin — ``table[pb1-1(, pb2-1)] <= p0`` therefore proves a
      member with ``m0 <= p0, m1 < p1 (, m2 < p2)`` exists, i.e. true
      domination.  This scales with front *shape*, not front size, which
      keeps survivor counts flat as fronts grow into the hundreds.

    Every array has a shape that depends only on ``(d, rows, bins)`` —
    never on the front size — so the streaming executor can pass the
    state straight into its compiled chunk step without retracing.
    Returns ``{"rows": (rows, d)}`` plus ``{"edges": (d-1, bins+1),
    "table": (bins+1,)*(d-1)}`` when the bin table applies (all ``+inf``
    when the front is still too small to bin).
    """
    F = np.asarray(front_signed, np.float64).reshape(-1, d)
    state = {"rows": _spread_rows(F, rows, d)}
    if not 2 <= d <= 3:
        return state
    edges = np.full((d - 1, bins + 1), np.inf)
    table = np.full((bins + 1,) * (d - 1), np.inf)
    if F.shape[0] >= 8:
        q = np.linspace(0, 1, bins + 1)
        for c in range(1, d):
            edges[c - 1] = np.quantile(F[:, c], q)
        # Members sit in [edges[0], edges[-1]] (the quantile endpoints are
        # the exact min/max), so searchsorted-1 lands in [0, bins] with no
        # clipping — duplicate edges are fine (some bins just stay empty).
        bin_idx = tuple(
            np.searchsorted(edges[c - 1], F[:, c], side="right") - 1
            for c in range(1, d))
        np.minimum.at(table, bin_idx, F[:, 0])
        for ax in range(table.ndim):
            table = np.minimum.accumulate(table, axis=ax)
    state["edges"] = edges
    state["table"] = table
    return state


def dominance_filter_mask(state: Mapping, Osg, xp=np):
    """Rows of signed ``(d, n)`` channel block ``Osg`` the filter cannot
    prove dominated (finite rows only — masked/infeasible lanes are
    ``inf``/NaN and never survive).

    ``xp`` selects the array namespace: ``numpy`` for the streaming
    executor's host fallback path, ``jax.numpy`` when traced inside its
    compiled chunk step — the two evaluations are the same expression, so
    the device pre-filter and its host mirror cannot drift.  Discarding
    is exact (both filter conditions are sufficient for domination);
    survivors still go through :func:`merge_fronts`.
    """
    rows = state["rows"]
    n_rows, d = rows.shape
    fin = xp.isfinite(Osg[0])
    for c in range(1, d):
        fin = fin & xp.isfinite(Osg[c])
    # Unrolled over the few filter rows so every op stays a flat (n,)
    # vector pass — a (rows, d, n) broadcast materializes ~10× the
    # intermediates and is an order of magnitude slower on CPU, both for
    # numpy and for the XLA lowering (which fuses this whole unrolled
    # chain into one loop over n).
    dom = xp.zeros(Osg.shape[1], bool)
    for r in range(n_rows):
        le = rows[r, 0] <= Osg[0]
        lt = rows[r, 0] < Osg[0]
        for c in range(1, d):
            le = le & (rows[r, c] <= Osg[c])
            lt = lt | (rows[r, c] < Osg[c])
        dom = dom | (le & lt)
    table = state.get("table")
    if table is not None:
        edges = state["edges"]
        ok = None
        idxs = []
        for c in range(1, d):
            # Strictly-lower bin: a member binned below edges[c-1][b+1]
            # has a value < edges[c-1][b+1] <= p, hence strictly smaller.
            b = xp.searchsorted(edges[c - 1], Osg[c], side="right") - 2
            ok = (b >= 0) if ok is None else (ok & (b >= 0))
            idxs.append(xp.clip(b, 0, table.shape[0] - 1))
        dom = dom | (ok & (table[tuple(idxs)] <= Osg[0]))
    return fin & ~dom


def knee_point(points: np.ndarray) -> int:
    """Index of the knee (balanced compromise) of a front.

    Each objective is normalized to [0, 1] over the given points; the knee
    is the point closest (Euclidean) to the normalized ideal ``(0, ..., 0)``
    — extreme points that win one objective by sacrificing the others sit
    at distance ~1, the elbow of the trade-off curve sits closest.
    """
    P = np.asarray(points, np.float64)
    if P.ndim != 2 or P.shape[0] == 0:
        raise ValueError("knee_point needs a non-empty (n, d) matrix")
    lo, hi = P.min(axis=0), P.max(axis=0)
    span = np.where(hi > lo, hi - lo, 1.0)
    return int(np.argmin(np.linalg.norm((P - lo) / span, axis=1)))


#: Largest non-dominated point count the exact d>=3 slicer accepts.
#: The recursive slicing is exponential in the worst case (each slice
#: re-solves a (d-1)-dim subproblem over a growing prefix), so beyond
#: ~1e3 front points it silently turns into hours of compute; d<=2
#: stays an O(n log n) sweep and is unbounded.
HV_EXACT_MAX_POINTS = 1000


def hypervolume(points: np.ndarray, ref: Sequence[float]) -> float:
    """Exact dominated hypervolume of ``points`` w.r.t. ``ref`` (minimize).

    The Lebesgue measure of the region dominated by the point set and
    bounded above by the reference point — the standard scalar quality
    metric for a Pareto front (larger is better).  Points that do not
    strictly dominate ``ref`` contribute nothing.  Exact sweep for d ≤ 2;
    recursive slicing over the last objective for d ≥ 3 (fine for the
    front sizes the grids here produce, typically tens of points).

    For d ≥ 3 the non-dominated survivor count is capped at
    :data:`HV_EXACT_MAX_POINTS` — beyond that the exact slicer's cost
    explodes, so the call raises ``ValueError`` instead of silently
    hanging; reduce to 2 objectives or subsample the front first.
    """
    ref = np.asarray(ref, np.float64)
    P = np.asarray(points, np.float64)
    if P.ndim != 2 or P.shape[1] != ref.shape[0]:
        raise ValueError(f"points {P.shape} incompatible with ref {ref.shape}")
    P = P[np.isfinite(P).all(axis=1)]
    P = P[(P < ref).all(axis=1)]
    if P.shape[0] == 0:
        return 0.0
    P = P[non_dominated_mask(P)]
    if ref.shape[0] >= 3 and P.shape[0] > HV_EXACT_MAX_POINTS:
        raise ValueError(
            f"hypervolume: {P.shape[0]} non-dominated points in "
            f"{ref.shape[0]}-D exceeds the exact slicer's bound of "
            f"{HV_EXACT_MAX_POINTS} — runtime would explode; reduce to "
            f"2 objectives or subsample the front first")
    return _hv(sorted(map(tuple, P)), tuple(ref))


def _hv(pts: list[tuple], ref: tuple) -> float:
    d = len(ref)
    if d == 1:
        return ref[0] - min(p[0] for p in pts)
    if d == 2:
        # Sweep ascending in obj0; on a front, obj1 is then descending.
        hv, y_cover = 0.0, ref[1]
        for x, y in sorted(pts):
            if y < y_cover:
                hv += (ref[0] - x) * (y_cover - y)
                y_cover = y
        return hv
    # Slice along the last objective: between consecutive z values the
    # cross-section is the (d-1)-dim hypervolume of the points at or below.
    order = sorted(pts, key=lambda p: p[-1])
    hv = 0.0
    for i, p in enumerate(order):
        z_hi = order[i + 1][-1] if i + 1 < len(order) else ref[-1]
        if z_hi > p[-1]:
            hv += (z_hi - p[-1]) * _hv([q[:-1] for q in order[:i + 1]],
                                       ref[:-1])
    return hv


@dataclasses.dataclass(frozen=True)
class ParetoFront:
    """The exact non-dominated set of one grid over chosen objectives.

    ``values`` holds the objective channels in their natural orientation
    (rows sorted by the first objective, best first); ``indices`` are flat
    indices into the originating grid, so ``result.config_at(indices[i])``
    recovers the knob settings of front member ``i``.  ``result`` may be a
    dense :class:`~repro.core.sweep.SweepResult` or any duck-typed result
    exposing ``config_at``/``channel_bounds`` (the streaming executor's
    ``StreamResult`` qualifies — its front is this same class).
    """

    result: SweepResult
    objectives: tuple[str, ...]
    maximize: tuple[str, ...]
    indices: np.ndarray          # (k,) flat grid indices
    values: np.ndarray           # (k, d) objective values, natural signs

    @property
    def size(self) -> int:
        return int(self.indices.size)

    def _signed(self, values: np.ndarray) -> np.ndarray:
        sign = np.where([o in self.maximize for o in self.objectives],
                        -1.0, 1.0)
        return values * sign

    def configs(self) -> list[dict]:
        """Knob settings + objective values of every front member."""
        out = []
        for flat, vals in zip(self.indices, self.values):
            cfg = self.result.config_at(int(flat))
            cfg.update(zip(self.objectives, map(float, vals)))
            out.append(cfg)
        return out

    def knee(self) -> dict:
        """Config dict of the balanced-compromise member (see
        :func:`knee_point`)."""
        return self.configs()[knee_point(self._signed(self.values))]

    def hypervolume(self, ref: Mapping[str, float] | None = None) -> float:
        """Dominated hypervolume of the front (larger is better).

        ``ref`` maps objective name -> reference value; when omitted, the
        per-objective worst *valid* value over the whole originating grid
        is used (nudged outward by 1e-9 of the span so nadir points still
        count).  Pass an explicit ``ref`` when comparing fronts extracted
        from different grids.
        """
        if ref is not None:
            r = self._signed(
                np.asarray([ref[o] for o in self.objectives], np.float64))
        else:
            # The originating result only needs to expose channel_bounds()
            # — both the dense SweepResult and the streaming StreamResult
            # do, so fronts from either path price identically.
            r = []
            for o in self.objectives:
                lo, hi = self.result.channel_bounds(o)
                s_lo, s_hi = ((-hi, -lo) if o in self.maximize
                              else (lo, hi))
                span = (s_hi - s_lo) or 1.0
                r.append(s_hi + 1e-9 * span)
            r = np.asarray(r, np.float64)
        return hypervolume(self._signed(self.values), r)


def pareto_front(result: SweepResult,
                 objectives: Sequence[str] = DEFAULT_OBJECTIVES,
                 maximize: Iterable[str] = ()) -> ParetoFront:
    """Extract the exact Pareto front of a sweep over objective channels.

    ``objectives`` name fields of ``result.data`` (see ``sweep.FIELDS``);
    each is minimized unless listed in ``maximize``.  Grid configurations
    with a NaN in any selected channel — the invalid MRAM corners — are
    excluded.  Returns a :class:`ParetoFront` sorted by the first
    objective (best first).
    """
    objectives = tuple(objectives)
    maximize = tuple(maximize)
    if len(objectives) < 1:
        raise ValueError("need at least one objective channel")
    unknown = [o for o in objectives if o not in result.data]
    if unknown:
        raise ValueError(f"unknown objective channels {unknown}; "
                         f"have {sorted(result.data)}")
    stray = [o for o in maximize if o not in objectives]
    if stray:
        raise ValueError(f"maximize entries {stray} not in objectives")

    V = np.stack([np.asarray(result.data[o], np.float64).ravel()
                  for o in objectives], axis=1)
    if V.shape[0] and not np.isfinite(V).all(axis=1).any():
        # Mirror SweepResult.argmin: an all-invalid grid is a configuration
        # error (e.g. MRAM-only on a node with no MRAM vehicle), not an
        # empty front.
        from .sweep import _fully_invalid_axis_values, invalid_message
        nan = ~np.isfinite(V).all(axis=1).reshape(result.shape)
        raise ValueError(invalid_message(
            "/".join(objectives),
            _fully_invalid_axis_values(nan, result.axes)))
    sign = np.where([o in maximize for o in objectives], -1.0, 1.0)
    Vs = V * sign
    if Vs.shape[0] > (1 << 16):
        # Large grids: cull the bulk with the sampled dominance
        # pre-filter before the exact pass — discarding is exact (every
        # culled row is strictly dominated by an evaluated witness), so
        # the front is unchanged while the n·front exact scan only ever
        # sees the near-front band (~60x faster on a 10⁶-row grid).
        sample = Vs[::max(1, Vs.shape[0] // 4096)]
        sample = sample[np.isfinite(sample).all(axis=1)]
        if sample.shape[0] > 64:
            state = build_dominance_filter(sample, Vs.shape[1])
            sample = sample[dominance_filter_mask(
                state, np.ascontiguousarray(sample.T))]
            state = build_dominance_filter(sample, Vs.shape[1])
            band = np.flatnonzero(dominance_filter_mask(
                state, np.ascontiguousarray(Vs.T)))
            mask = np.zeros(Vs.shape[0], bool)
            mask[band[non_dominated_mask(Vs[band])]] = True
        else:
            mask = non_dominated_mask(Vs)
    else:
        mask = non_dominated_mask(Vs)
    idx = np.flatnonzero(mask)
    vals = V[idx]
    order = np.argsort(vals[:, 0] * sign[0], kind="stable")
    return ParetoFront(result=result, objectives=objectives,
                       maximize=maximize, indices=idx[order],
                       values=vals[order])
