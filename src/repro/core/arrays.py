"""Struct-of-arrays lowering of the semi-analytical model (Eqs. 1-11).

The scalar path (:mod:`repro.core.system` / :mod:`repro.core.partition`)
walks Python dataclasses layer by layer for every configuration.  That is
the right shape for a single, fully-annotated report, but a design-space
sweep evaluates the same per-layer reductions thousands of times with only
a handful of scalar knobs changing.  This module lowers everything that is
*configuration independent* into dense ``float64`` arrays once:

* :class:`WorkloadArrays` — per-network prefix sums over the concatenated
  layer tables: MACs, weight bytes, streamed-weight bytes (the DORY-style
  re-fetch of :func:`repro.core.rbe.weight_stream_bytes`), activation
  traffic, RBE cycles at the on-sensor (1/4) and aggregator (1x) scales,
  and prefix/suffix peaks of the activation footprint.  A partition cut
  then becomes two gathers (prefix = sensor side, suffix = aggregator
  side) instead of a rebuild of ``NNWorkload`` objects.
* :class:`ModelArrays` — the above for DetNet/KeyNet plus stacked tech-node
  and memory-technology tables (``TechNode``/``MemorySpec``), link
  constants (``LinkSpec``), and per-cut MIPI payload tables derived from
  :func:`mipi_payloads` (the single source of truth for what crosses MIPI
  at each cut, shared with the scalar path).

:mod:`repro.core.sweep` consumes a :class:`ModelArrays` inside a
``jax.jit``/``jax.vmap`` kernel; the scalar API consumes the same payload
plan through :func:`mipi_payloads`, so the two paths cannot drift.  The
cycle prefix-sums double as the lowering of the per-cut latency model
(:func:`repro.core.latency.cut_latency` — the kernel's ``latency``
channel), and the per-rate payload tables are shared between the Eq. 5
power term and the latency critical path.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import numpy as np

from . import rbe
from .constants import (AGG_L1_BYTES, BOX_COORDS_BYTES, DPS_CAMERA,
                        L1_ENERGY_SCALE, MIPI, ON_SENSOR_SCALE, RBE,
                        SENSOR_L1_BYTES, T_SENSE_S, TECH_NODES, UTSV,
                        MemorySpec, TechNode)
from .handtracking import (FULL_FRAME_BYTES, ROI_BYTES, build_detnet,
                           build_keynet)
from .workloads import NNWorkload

# Rate tags for MIPI payloads: each payload crosses the link at one of the
# three system rates (Eq. 2 multiplies by the rate of the producing module).
RATE_CAMERA = "camera"
RATE_DETNET = "detnet"
RATE_KEYNET = "keynet"

# Weight-memory kinds, in table order (axis 1 of the ``wm_*`` tables).
WEIGHT_MEM_KINDS = ("sram", "mram")


def mipi_payloads(cut: int, detnet: NNWorkload,
                  keynet: NNWorkload) -> list[tuple[float, str]]:
    """What crosses MIPI for partition cut ``cut``: ``[(bytes, rate_tag)]``.

    This is the single source of truth for the cut semantics described in
    :mod:`repro.core.partition` — the scalar ``evaluate_cut`` maps the rate
    tags onto fps values, and :func:`model_arrays` folds the same plan into
    per-cut byte tables for the vectorized engine.
    """
    n_det = len(detnet.layers)
    n_all = n_det + len(keynet.layers)
    if not 0 <= cut <= n_all:
        raise ValueError(f"cut {cut} outside [0, {n_all}]")
    if cut == 0:
        # Fully centralized: the raw frame crosses at camera rate.
        return [(FULL_FRAME_BYTES, RATE_CAMERA)]
    if cut < n_det:
        # DetNet split: the cut activation crosses at DetNet rate, boxes
        # return sensor-ward, and the ROI crop still has to cross at
        # KeyNet rate (the raw frame only exists on-sensor).
        act = detnet.layers[cut - 1].out_act_bytes
        return [(act, RATE_DETNET), (BOX_COORDS_BYTES, RATE_DETNET),
                (ROI_BYTES, RATE_KEYNET)]
    if cut == n_det:
        # The paper's split: ROI (KeyNet rate) + DetNet outputs (tiny).
        return [(detnet.output_bytes, RATE_DETNET), (ROI_BYTES, RATE_KEYNET)]
    # KeyNet split: the KeyNet cut activation crosses at KeyNet rate.
    act = keynet.layers[cut - n_det - 1].out_act_bytes
    return [(act, RATE_KEYNET), (detnet.output_bytes, RATE_DETNET)]


# ---------------------------------------------------------------------------
# Per-workload arrays
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)
class WorkloadArrays:
    """Prefix-sum tables over one network's layer list (all ``float64``).

    Every ``c_*`` array has length ``n_layers + 1`` with ``c[k]`` = the
    reduction over layers ``[0, k)`` — so for a cut that keeps ``k`` layers
    on-sensor, the sensor side reads ``c[k]`` and the aggregator side reads
    ``c[n_layers] - c[k]``.  ``peak_prefix[k]`` / ``peak_suffix[k]`` are the
    running max of the activation footprint over the same ranges.
    """

    name: str
    n_layers: int
    input_bytes: float
    output_bytes: float
    c_macs: np.ndarray            # cumulative MACs per inference
    c_weight_bytes: np.ndarray    # cumulative weight footprint (L2-W capacity)
    c_weight_stream: np.ndarray   # cumulative streamed weight bytes (Eq. 8)
    c_act_traffic: np.ndarray     # cumulative in+out activation bytes (Eq. 8)
    c_cycles_sensor: np.ndarray   # cumulative RBE cycles at ON_SENSOR_SCALE
    c_cycles_agg: np.ndarray      # cumulative RBE cycles at scale 1.0
    peak_prefix: np.ndarray       # max activation footprint, layers [0, k)
    peak_suffix: np.ndarray       # max activation footprint, layers [k, n)
    out_act_bytes: np.ndarray     # per-layer output activation bytes (n,)


def _cumsum0(values: list[float]) -> np.ndarray:
    """Length n+1 prefix sums starting at 0, in float64."""
    return np.concatenate(([0.0], np.cumsum(np.asarray(values, np.float64))))


@functools.lru_cache(maxsize=64)
def workload_arrays(wl: NNWorkload) -> WorkloadArrays:
    """Lower one :class:`NNWorkload` layer table into prefix-sum arrays."""
    layers = wl.layers
    n = len(layers)
    peaks = [float(max(l.in_act_bytes, l.out_act_bytes)) for l in layers]
    peak_prefix = np.zeros(n + 1, np.float64)
    peak_suffix = np.zeros(n + 1, np.float64)
    for k in range(n):
        peak_prefix[k + 1] = max(peak_prefix[k], peaks[k])
        peak_suffix[n - 1 - k] = max(peak_suffix[n - k], peaks[n - 1 - k])
    return WorkloadArrays(
        name=wl.name,
        n_layers=n,
        input_bytes=float(wl.input_bytes),
        output_bytes=float(wl.output_bytes),
        c_macs=_cumsum0([float(l.macs) for l in layers]),
        c_weight_bytes=_cumsum0([float(l.weight_bytes) for l in layers]),
        c_weight_stream=_cumsum0([float(rbe.weight_stream_bytes(l))
                                  for l in layers]),
        c_act_traffic=_cumsum0([float(l.in_act_bytes + l.out_act_bytes)
                                for l in layers]),
        c_cycles_sensor=_cumsum0(
            [l.macs / rbe.mac_per_cycle(l, RBE, ON_SENSOR_SCALE)
             for l in layers]),
        c_cycles_agg=_cumsum0([l.macs / rbe.mac_per_cycle(l, RBE, 1.0)
                               for l in layers]),
        peak_prefix=peak_prefix,
        peak_suffix=peak_suffix,
        out_act_bytes=np.asarray([float(l.out_act_bytes) for l in layers],
                                 np.float64),
    )


# ---------------------------------------------------------------------------
# Technology tables
# ---------------------------------------------------------------------------


def _mem_fields(mem: Optional[MemorySpec]) -> tuple[float, float, float,
                                                    float]:
    if mem is None:
        return (np.nan, np.nan, np.nan, np.nan)
    return (mem.e_read, mem.e_write, mem.leak_on, mem.leak_ret)


@dataclasses.dataclass(frozen=True, eq=False)
class ModelArrays:
    """Everything the jit/vmap kernel needs, as dense constant arrays."""

    det: WorkloadArrays
    key: WorkloadArrays
    node_names: tuple[str, ...]

    # Logic-node tables, shape (n_nodes,)
    e_mac: np.ndarray
    f_clk: np.ndarray
    # Activation-SRAM tables, shape (n_nodes,)
    sram_e_read: np.ndarray
    sram_e_write: np.ndarray
    sram_leak_on: np.ndarray
    sram_leak_ret: np.ndarray
    # Weight-memory tables, shape (n_nodes, len(WEIGHT_MEM_KINDS)); NaN
    # where the (node, kind) pair has no test vehicle — NaN propagation
    # through these fields is what marks invalid grid corners.
    wm_e_read: np.ndarray
    wm_leak_on: np.ndarray
    wm_leak_ret: np.ndarray

    # Per-cut MIPI payload tables, shape (n_cuts,) = n_det + n_key + 1.
    pay_cam_rate: np.ndarray      # bytes crossing at camera rate
    pay_det_rate: np.ndarray      # bytes crossing at DetNet rate
    pay_key_rate: np.ndarray      # bytes crossing at KeyNet rate
    pay_max: np.ndarray           # largest single payload (agg input buffer)

    @property
    def n_cuts(self) -> int:
        return self.det.n_layers + self.key.n_layers + 1

    def node_index(self, node: str | TechNode) -> int:
        name = node if isinstance(node, str) else node.name
        try:
            return self.node_names.index(name)
        except ValueError:
            raise KeyError(f"unknown tech node {name!r}; "
                           f"have {self.node_names}") from None


@functools.lru_cache(maxsize=16)
def model_arrays(detnet: NNWorkload | None = None,
                 keynet: NNWorkload | None = None) -> ModelArrays:
    """Build (and cache) the full constant table set for one workload pair.

    ``None`` selects the canonical MEgATrack reconstruction from
    :mod:`repro.core.handtracking`; custom workloads are hashable frozen
    dataclasses, so each distinct pair gets its own cached lowering.
    """
    detnet = detnet or build_detnet()
    keynet = keynet or build_keynet()
    det = workload_arrays(detnet)
    key = workload_arrays(keynet)
    names = tuple(TECH_NODES)
    nodes = [TECH_NODES[n] for n in names]

    wm_rows = []
    for node in nodes:
        wm_rows.append([_mem_fields(node.sram), _mem_fields(node.mram)])
    wm = np.asarray(wm_rows, np.float64)          # (n_nodes, 2, 4)

    n_cuts = det.n_layers + key.n_layers + 1
    pay_cam = np.zeros(n_cuts, np.float64)
    pay_det = np.zeros(n_cuts, np.float64)
    pay_key = np.zeros(n_cuts, np.float64)
    pay_max = np.zeros(n_cuts, np.float64)
    rate_acc = {RATE_CAMERA: pay_cam, RATE_DETNET: pay_det,
                RATE_KEYNET: pay_key}
    for cut in range(n_cuts):
        plan = mipi_payloads(cut, detnet, keynet)
        for nbytes, rate in plan:
            rate_acc[rate][cut] += nbytes
        pay_max[cut] = max(b for b, _ in plan)

    return ModelArrays(
        det=det, key=key, node_names=names,
        e_mac=np.asarray([n.e_mac for n in nodes], np.float64),
        f_clk=np.asarray([n.f_clk for n in nodes], np.float64),
        sram_e_read=np.asarray([n.sram.e_read for n in nodes], np.float64),
        sram_e_write=np.asarray([n.sram.e_write for n in nodes], np.float64),
        sram_leak_on=np.asarray([n.sram.leak_on for n in nodes], np.float64),
        sram_leak_ret=np.asarray([n.sram.leak_ret for n in nodes],
                                 np.float64),
        wm_e_read=wm[:, :, 0],
        wm_leak_on=wm[:, :, 2],
        wm_leak_ret=wm[:, :, 3],
        pay_cam_rate=pay_cam,
        pay_det_rate=pay_det,
        pay_key_rate=pay_key,
        pay_max=pay_max,
    )


# ---------------------------------------------------------------------------
# Stacked (multi-model) tables — the batched workload axis
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)
class StackedWorkloadArrays:
    """Ragged per-model layer tables padded into one dense leading axis.

    ``n_layers[m]`` is model ``m``'s true layer count; every 2-D table has
    shape ``(n_models, max_layers + 1)`` with the tail of shorter rows
    edge-padded (prefix sums repeat their final total, ``peak_suffix``
    repeats its trailing 0).  The kernel clips its gather indices to the
    per-model ``n_layers``, so padded entries are only ever read through
    the always-poisoned beyond-``n_cuts`` cut indices — see the padded-cut
    masking note in ``docs/equations.md``.
    """

    names: tuple[str, ...]
    n_layers: np.ndarray          # (M,) int32 — true (unpadded) layer counts
    input_bytes: np.ndarray       # (M,)
    output_bytes: np.ndarray      # (M,)
    c_macs: np.ndarray            # (M, Lmax+1) — and the rest of the
    c_weight_bytes: np.ndarray    # WorkloadArrays prefix-sum tables, padded
    c_weight_stream: np.ndarray
    c_act_traffic: np.ndarray
    c_cycles_sensor: np.ndarray
    c_cycles_agg: np.ndarray
    peak_prefix: np.ndarray
    peak_suffix: np.ndarray


_WL_TABLE_FIELDS = ("c_macs", "c_weight_bytes", "c_weight_stream",
                    "c_act_traffic", "c_cycles_sensor", "c_cycles_agg",
                    "peak_prefix", "peak_suffix")


def _stack_workloads(wls: tuple[WorkloadArrays, ...]) -> StackedWorkloadArrays:
    width = max(w.n_layers for w in wls) + 1
    tables = {}
    for f in _WL_TABLE_FIELDS:
        rows = []
        for w in wls:
            a = getattr(w, f)
            # Edge padding: prefix sums repeat their total, peak_suffix its
            # trailing 0 — any accidental read of a padded slot is a no-op.
            rows.append(np.pad(a, (0, width - a.size), mode="edge"))
        tables[f] = np.asarray(rows, np.float64)
    return StackedWorkloadArrays(
        names=tuple(w.name for w in wls),
        n_layers=np.asarray([w.n_layers for w in wls], np.int32),
        input_bytes=np.asarray([w.input_bytes for w in wls], np.float64),
        output_bytes=np.asarray([w.output_bytes for w in wls], np.float64),
        **tables,
    )


@dataclasses.dataclass(frozen=True, eq=False)
class StackedModelArrays:
    """A batch of :class:`ModelArrays` as one extra leading ``model`` axis.

    The technology tables are shared (every model prices against the same
    ``TECH_NODES`` registry); everything workload-derived — the DetNet /
    KeyNet prefix-sum tables and the per-cut MIPI payload tables — gains a
    leading axis of size ``n_models``, padded to the widest model.
    ``n_cuts[m]`` is the per-model *valid-cut* bound: grid cut indices at
    or beyond it evaluate to NaN for model ``m`` (the padded-cut mask), so
    one compiled kernel can sweep architectures with ragged layer counts.
    """

    model_names: tuple[str, ...]
    det: StackedWorkloadArrays
    key: StackedWorkloadArrays
    n_cuts: np.ndarray            # (M,) int32 — per-model valid-cut counts
    node_names: tuple[str, ...]

    # Shared technology tables (same shapes/meaning as ModelArrays).
    e_mac: np.ndarray
    f_clk: np.ndarray
    sram_e_read: np.ndarray
    sram_e_write: np.ndarray
    sram_leak_on: np.ndarray
    sram_leak_ret: np.ndarray
    wm_e_read: np.ndarray
    wm_leak_on: np.ndarray
    wm_leak_ret: np.ndarray

    # Per-model, per-cut MIPI payload tables, shape (M, n_cuts_max),
    # zero-padded beyond each model's n_cuts (poisoned before use).
    pay_cam_rate: np.ndarray
    pay_det_rate: np.ndarray
    pay_key_rate: np.ndarray
    pay_max: np.ndarray

    @property
    def n_models(self) -> int:
        return len(self.model_names)

    @property
    def n_cuts_max(self) -> int:
        return int(self.n_cuts.max())

    def node_index(self, node: str | TechNode) -> int:
        name = node if isinstance(node, str) else node.name
        try:
            return self.node_names.index(name)
        except ValueError:
            raise KeyError(f"unknown tech node {name!r}; "
                           f"have {self.node_names}") from None


@functools.lru_cache(maxsize=16)
def stack_model_arrays(models: tuple) -> StackedModelArrays:
    """Stack already-lowered :class:`ModelArrays` along a new model axis."""
    if not models:
        raise ValueError("need at least one model to stack")
    first = models[0]
    for m in models[1:]:
        if m.node_names != first.node_names:
            raise ValueError("stacked models must share the tech-node "
                             "registry")
    names, seen = [], {}
    for m in models:
        base = f"{m.det.name}+{m.key.name}"
        seen[base] = seen.get(base, 0) + 1
        names.append(base if seen[base] == 1 else f"{base}#{seen[base]}")

    n_cuts = np.asarray([m.n_cuts for m in models], np.int32)
    width = int(n_cuts.max())

    def pay(field):
        return np.asarray([np.pad(getattr(m, field),
                                  (0, width - getattr(m, field).size))
                           for m in models], np.float64)

    return StackedModelArrays(
        model_names=tuple(names),
        det=_stack_workloads(tuple(m.det for m in models)),
        key=_stack_workloads(tuple(m.key for m in models)),
        n_cuts=n_cuts,
        node_names=first.node_names,
        e_mac=first.e_mac, f_clk=first.f_clk,
        sram_e_read=first.sram_e_read, sram_e_write=first.sram_e_write,
        sram_leak_on=first.sram_leak_on, sram_leak_ret=first.sram_leak_ret,
        wm_e_read=first.wm_e_read, wm_leak_on=first.wm_leak_on,
        wm_leak_ret=first.wm_leak_ret,
        pay_cam_rate=pay("pay_cam_rate"), pay_det_rate=pay("pay_det_rate"),
        pay_key_rate=pay("pay_key_rate"), pay_max=pay("pay_max"),
    )


def stacked_model_arrays(workloads=None) -> StackedModelArrays:
    """Lower a batch of workloads into one stacked, padded table set.

    ``workloads`` is a sequence whose entries are either ``(detnet,
    keynet)`` :class:`~repro.core.workloads.NNWorkload` pairs (``None``
    selects the canonical MEgATrack network) or already-lowered
    :class:`ModelArrays`.  The result powers the ``model`` grid axis of
    :func:`repro.core.sweep.evaluate_grid` and
    :func:`repro.core.stream.stream_grid` — one compiled kernel sweeps
    every architecture variant.  Ragged layer counts are fine: shorter
    models NaN out beyond their own cut range.
    """
    if workloads is None:
        entries: tuple = ((None, None),)
    else:
        entries = tuple(workloads)
        if not entries:
            raise ValueError("need at least one workload entry")
    models = []
    for e in entries:
        if isinstance(e, ModelArrays):
            models.append(e)
        else:
            det, key = e
            models.append(model_arrays(det, key))
    return stack_model_arrays(tuple(models))


# Link / camera scalars the kernel closes over (kept here so sweep.py has a
# single import site for every physical constant it consumes).
CAMERA_SENSE_W = DPS_CAMERA.sense
CAMERA_READ_W = DPS_CAMERA.read
CAMERA_IDLE_W = DPS_CAMERA.idle
T_SENSE = T_SENSE_S
MIPI_E_PER_BYTE = MIPI.energy_per_byte
MIPI_BW = MIPI.bandwidth
UTSV_E_PER_BYTE = UTSV.energy_per_byte
UTSV_BW = UTSV.bandwidth
FULL_FRAME = float(FULL_FRAME_BYTES)
