"""Vectorized design-space engine: Eqs. 1-11 as one jit/vmap kernel.

The repo now has **two evaluation paths** over the same analytical model:

* **Scalar path** (:mod:`repro.core.system`, :mod:`repro.core.partition`) —
  builds an explicit, named ``ModuleEnergy`` list for one configuration.
  Use it when you want the full per-module report (the Fig. 5 stacked
  bars, per-sensor groups, labels).
* **Array path** (this module) — consumes the struct-of-arrays lowering
  of :mod:`repro.core.arrays` and evaluates an arbitrary cartesian grid
  over the paper's design knobs in a single ``jax.jit``-compiled,
  ``jax.vmap``-batched device call.  Use it for sweeps: dense sensitivity
  heatmaps, partition × node × memory × rate grids, and as the substrate
  for multi-objective analysis — every configuration evaluates the three
  objective channels (``avg_power``, ``latency``, ``mipi_bytes_per_s``)
  that :mod:`repro.core.pareto` extracts fronts over and
  :mod:`repro.core.optimize` differentiates through.

The two paths are kept numerically interchangeable (``tests/test_sweep.py``
asserts ≤1e-6 relative parity across a sampled grid); the payload plan per
partition cut comes from the shared :func:`repro.core.arrays.mipi_payloads`
so the cut semantics cannot drift.

Grid axes of :func:`evaluate_grid` (cartesian product, in order)::

    cut               partition index over DetNet ++ KeyNet layer list
    agg_node          aggregator tech node        ("7nm" | "16nm" | TechNode)
    sensor_node       on-sensor tech node
    weight_mem        on-sensor weight memory     ("sram" | "mram")
    detnet_fps        DetNet rate (the ROI-reuse knob)
    keynet_fps        KeyNet rate
    num_cameras       camera count
    mipi_energy_scale multiplier on MIPI pJ/B (Eq. 5 sensitivity axis)
    camera_fps        frame delivery rate

Configurations that are physically invalid (MRAM weight memory on a node
with no MRAM test vehicle, with an on-sensor deployment present) evaluate
to NaN rather than raising, so a dense grid can mix valid and invalid
corners.  All arithmetic runs in float64 (scoped ``enable_x64`` — the
global JAX config is left untouched).
"""

from __future__ import annotations

import dataclasses
import functools
import operator
import re
from collections import OrderedDict
from typing import Iterable, Mapping, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from . import arrays as A
from .constants import (CAMERA_FPS, DETNET_FPS, KEYNET_FPS, NUM_CAMERAS,
                        TechNode)
from .workloads import NNWorkload

AXIS_NAMES = ("cut", "agg_node", "sensor_node", "weight_mem", "detnet_fps",
              "keynet_fps", "num_cameras", "mipi_energy_scale", "camera_fps")

#: Name of the optional leading axis over stacked workload batches
#: (``models=`` on :func:`evaluate_grid` / ``stream.stream_grid``).
MODEL_AXIS = "model"

#: Name of the optional trailing axis over scenario traces
#: (``scenarios=`` on :func:`evaluate_grid` / ``stream.stream_grid``);
#: its values are the trace names of the scenario set.
SCENARIO_AXIS = "trace"

#: Output fields of the kernel (each becomes one grid-shaped array).
#: ``avg_power`` + the seven power-breakdown groups, plus the three
#: non-power objective channels: ``mipi_bytes_per_s`` (Eq. 5 link traffic),
#: ``sensor_macs_per_s`` and ``latency`` (the generalized per-cut
#: ``repro.core.latency.cut_latency`` model, lowered into the kernel).
FIELDS = ("avg_power", "camera", "utsv", "mipi", "sensor_compute",
          "sensor_memory", "agg_compute", "agg_memory", "mipi_bytes_per_s",
          "sensor_macs_per_s", "latency")

#: Session channels emitted *in addition to* :data:`FIELDS` when a sweep
#: runs with ``scenarios=`` (see :mod:`repro.core.scenario`).  They are
#: first-class objectives/constraints everywhere a static field is;
#: validity (NaN poisoning of invalid grid corners) is inherited from
#: ``avg_power`` exactly.
SCENARIO_FIELDS = ("session_energy_j", "time_to_empty_s",
                   "peak_case_temp_c", "throttle_fraction")


def kernel_fields(S=None) -> tuple[str, ...]:
    """Channels the kernel of a (possibly scenario-wrapped) lowering
    emits: :data:`FIELDS` for a plain model stack, plus
    :data:`SCENARIO_FIELDS` for a ``scenario.ScenarioStack`` (which
    advertises them via its ``fields`` attribute)."""
    return getattr(S, "fields", FIELDS) if S is not None else FIELDS

#: Comparison operators a constraint predicate may use (see
#: :func:`parse_constraints`), mapped to their array-compatible callables
#: — ``operator.le`` etc. dispatch identically on numpy arrays and traced
#: jax values, so the host post-filter and the streaming executor's
#: in-kernel mask evaluate the same expression.
CONSTRAINT_OPS: Mapping[str, callable] = {
    "<=": operator.le, ">=": operator.ge, "<": operator.lt, ">": operator.gt}

_CONSTRAINT_RE = re.compile(
    r"\s*(\w+)\s*(<=|>=|<|>)\s*([-+]?[\d.]+(?:[eE][-+]?\d+)?)\s*")


def parse_constraints(constraints) -> tuple[tuple[str, str, float], ...]:
    """Canonicalize a constraint spec into ``((field, op, bound), ...)``.

    Accepted forms (freely mixable in the iterable variants):

    * a mapping ``{field: bound}`` — upper bounds, i.e. ``field <= bound``
      (the common case: latency budgets, link caps);
    * a mapping ``{field: (op, bound)}`` with ``op`` one of
      :data:`CONSTRAINT_OPS`;
    * an iterable of ``"field <= bound"`` strings or ``(field, op,
      bound)`` tuples.

    Fields must be kernel channels (:data:`FIELDS`, or
    :data:`SCENARIO_FIELDS` on sweeps run with ``scenarios=``).  A
    configuration is
    *feasible* iff every predicate holds; NaN channel values (invalid
    configurations) never satisfy a predicate, so infeasible and invalid
    configurations are excluded identically.
    """
    if not constraints:
        return ()
    items: list[tuple[str, str, float]] = []
    if isinstance(constraints, Mapping):
        for field, spec in constraints.items():
            if isinstance(spec, (tuple, list)):
                if len(spec) != 2:
                    raise ValueError(f"constraint {field!r}: expected "
                                     f"(op, bound), got {spec!r}")
                op, bound = spec
            else:
                op, bound = "<=", spec
            items.append((field, op, bound))
    else:
        for c in constraints:
            if isinstance(c, str):
                m = _CONSTRAINT_RE.fullmatch(c)
                if not m:
                    raise ValueError(
                        f"cannot parse constraint {c!r}; expected "
                        f"'<field> <op> <value>' with op in "
                        f"{tuple(CONSTRAINT_OPS)}")
                items.append((m.group(1), m.group(2), m.group(3)))
            else:
                field, op, bound = c
                items.append((field, op, bound))
    out = []
    for field, op, bound in items:
        if field not in FIELDS + SCENARIO_FIELDS:
            raise ValueError(f"unknown constraint channel {field!r}; "
                             f"kernel channels are {FIELDS} plus the "
                             f"scenario channels {SCENARIO_FIELDS} "
                             f"(which require scenarios=)")
        if op not in CONSTRAINT_OPS:
            raise ValueError(f"unknown constraint op {op!r}; "
                             f"have {tuple(CONSTRAINT_OPS)}")
        out.append((field, op, float(bound)))
    return tuple(out)


def constraint_mask(data: Mapping[str, np.ndarray],
                    constraints) -> np.ndarray:
    """Boolean feasibility mask of a channel dict under a constraint spec
    (the host twin of the streaming executor's in-kernel predicate mask).
    NaN channel values fail every predicate."""
    cons = parse_constraints(constraints)
    mask = np.ones(np.shape(next(iter(data.values()))), bool)
    with np.errstate(invalid="ignore"):
        for field, op, bound in cons:
            mask &= CONSTRAINT_OPS[op](np.asarray(data[field]), bound)
    return mask


# ---------------------------------------------------------------------------
# The per-configuration kernel (vmapped over flat config arrays)
# ---------------------------------------------------------------------------


def _site_power(macs_per_s, w_read_per_s, act_per_s, cycles_per_s, f_clk,
                e_mac, wm_e_read, wm_leak_on, wm_leak_ret, sram_e_read,
                sram_e_write, sram_leak_on, sram_leak_ret, cap_w, cap_a,
                l1_bytes):
    """Eqs. 7-11 for one processor site, per-second accounting.

    Mirrors ``system.Deployment.modules()``: compute (Eq. 7), L2-weight /
    L2-activation / L1 access energy (Eq. 8), and On/Retention leakage for
    the three memory instances (Eqs. 9-11 with a 1 s window).
    """
    p_compute = macs_per_s * e_mac

    act_read = act_per_s / 2
    act_write = act_per_s / 2
    # L1 sees every streamed byte once more (L2 -> L1 -> engine).
    l1_traffic = w_read_per_s + act_read + act_write
    p_l2w = w_read_per_s * wm_e_read
    p_l2a = act_read * sram_e_read + act_write * sram_e_write
    p_l1 = (l1_traffic / 2 * (A.L1_ENERGY_SCALE * sram_e_read)
            + l1_traffic / 2 * (A.L1_ENERGY_SCALE * sram_e_write))

    t_proc = jnp.minimum(1.0, cycles_per_s / f_clk)
    t_idle = jnp.maximum(0.0, 1.0 - t_proc)
    p_leak = (cap_w * (wm_leak_on * t_proc + wm_leak_ret * t_idle)
              + cap_a * (sram_leak_on * t_proc + sram_leak_ret * t_idle)
              + l1_bytes * (sram_leak_on * t_proc + sram_leak_ret * t_idle))
    return p_compute, p_l2w + p_l2a + p_l1 + p_leak


def _make_config_fn(S: A.StackedModelArrays):
    """Close the Eq. 1-11 kernel over a stacked batch of model tables.

    The first argument of the returned function selects the model along
    the stacked (padded) workload axis; for a single-model stack it is a
    constant 0 and the gathers reduce to the plain per-model reads.
    """
    det, key = S.det, S.key
    M = S
    j = jnp.asarray  # constants fold into the jaxpr at trace time

    def config_fn(model_i, cut, agg_i, sen_i, wm_i, det_fps, key_fps, ncam,
                  mipi_scale, cam_fps):
        m = model_i
        n_det = j(det.n_layers)[m]
        n_key = j(key.n_layers)[m]
        n_all = n_det + n_key
        cd = jnp.clip(cut, 0, n_det)          # DetNet layers on-sensor
        ck = jnp.clip(cut - n_det, 0, n_key)  # KeyNet layers on-sensor
        has_sensor = cut > 0
        has_agg = cut < n_all

        # ---- Eq. 3/4: cameras (readout window set by camera-side link) ----
        t_comm_cam = A.FULL_FRAME / jnp.where(has_sensor, A.UTSV_BW,
                                              A.MIPI_BW)
        t_off = jnp.maximum(0.0, 1.0 / cam_fps - A.T_SENSE - t_comm_cam)
        e_cam = (A.CAMERA_SENSE_W * A.T_SENSE + A.CAMERA_READ_W * t_comm_cam
                 + A.CAMERA_IDLE_W * t_off)
        p_camera = e_cam * cam_fps * ncam

        # ---- Eq. 5: uTSV readout link (distributed only) ----
        p_utsv = jnp.where(
            has_sensor, A.FULL_FRAME * A.UTSV_E_PER_BYTE * cam_fps * ncam,
            0.0)

        # ---- Eq. 5: MIPI payload plan for this cut ----
        bps_per_cam = (j(M.pay_cam_rate)[m, cut] * cam_fps
                       + j(M.pay_det_rate)[m, cut] * det_fps
                       + j(M.pay_key_rate)[m, cut] * key_fps)
        p_mipi = bps_per_cam * (A.MIPI_E_PER_BYTE * mipi_scale) * ncam
        mipi_bps = bps_per_cam * ncam

        # ---- on-sensor site (x ncam replicas) ----
        macs_s = (j(det.c_macs)[m, cd] * det_fps
                  + j(key.c_macs)[m, ck] * key_fps)
        w_read_s = (j(det.c_weight_stream)[m, cd] * det_fps
                    + j(key.c_weight_stream)[m, ck] * key_fps)
        act_s = (j(det.c_act_traffic)[m, cd] * det_fps
                 + j(key.c_act_traffic)[m, ck] * key_fps)
        cyc_s = (j(det.c_cycles_sensor)[m, cd] * det_fps
                 + j(key.c_cycles_sensor)[m, ck] * key_fps)
        cap_w_s = j(det.c_weight_bytes)[m, cd] + j(key.c_weight_bytes)[m, ck]
        cap_a_s = (jnp.maximum(j(det.peak_prefix)[m, cd],
                               j(key.peak_prefix)[m, ck])
                   + j(det.input_bytes)[m])
        p_comp_s, p_mem_s = _site_power(
            macs_s, w_read_s, act_s, cyc_s,
            j(M.f_clk)[sen_i], j(M.e_mac)[sen_i],
            j(M.wm_e_read)[sen_i, wm_i], j(M.wm_leak_on)[sen_i, wm_i],
            j(M.wm_leak_ret)[sen_i, wm_i],
            j(M.sram_e_read)[sen_i], j(M.sram_e_write)[sen_i],
            j(M.sram_leak_on)[sen_i], j(M.sram_leak_ret)[sen_i],
            cap_w_s, cap_a_s, A.SENSOR_L1_BYTES)
        p_sensor_compute = jnp.where(has_sensor, p_comp_s * ncam, 0.0)
        p_sensor_memory = jnp.where(has_sensor, p_mem_s * ncam, 0.0)

        # ---- aggregator site (suffix of each network, rate x ncam) ----
        macs_a = ((j(det.c_macs)[m, n_det] - j(det.c_macs)[m, cd])
                  * (det_fps * ncam)
                  + (j(key.c_macs)[m, n_key] - j(key.c_macs)[m, ck])
                  * (key_fps * ncam))
        w_read_a = ((j(det.c_weight_stream)[m, n_det]
                     - j(det.c_weight_stream)[m, cd]) * (det_fps * ncam)
                    + (j(key.c_weight_stream)[m, n_key]
                       - j(key.c_weight_stream)[m, ck]) * (key_fps * ncam))
        act_a = ((j(det.c_act_traffic)[m, n_det]
                  - j(det.c_act_traffic)[m, cd]) * (det_fps * ncam)
                 + (j(key.c_act_traffic)[m, n_key]
                    - j(key.c_act_traffic)[m, ck]) * (key_fps * ncam))
        cyc_a = ((j(det.c_cycles_agg)[m, n_det] - j(det.c_cycles_agg)[m, cd])
                 * (det_fps * ncam)
                 + (j(key.c_cycles_agg)[m, n_key]
                    - j(key.c_cycles_agg)[m, ck]) * (key_fps * ncam))
        cap_w_a = ((j(det.c_weight_bytes)[m, n_det]
                    - j(det.c_weight_bytes)[m, cd])
                   + (j(key.c_weight_bytes)[m, n_key]
                      - j(key.c_weight_bytes)[m, ck]))
        cap_a_a = (jnp.maximum(j(det.peak_suffix)[m, cd],
                               j(key.peak_suffix)[m, ck])
                   + j(M.pay_max)[m, cut] * ncam)
        p_comp_a, p_mem_a = _site_power(
            macs_a, w_read_a, act_a, cyc_a,
            j(M.f_clk)[agg_i], j(M.e_mac)[agg_i],
            # the aggregator's weight memory is always its node SRAM
            j(M.sram_e_read)[agg_i], j(M.sram_leak_on)[agg_i],
            j(M.sram_leak_ret)[agg_i],
            j(M.sram_e_read)[agg_i], j(M.sram_e_write)[agg_i],
            j(M.sram_leak_on)[agg_i], j(M.sram_leak_ret)[agg_i],
            cap_w_a, cap_a_a, A.AGG_L1_BYTES)
        p_agg_compute = jnp.where(has_agg, p_comp_a, 0.0)
        p_agg_memory = jnp.where(has_agg, p_mem_a, 0.0)

        # ---- end-to-end result latency (cut_latency, lowered: Eq. 6/9) ----
        # DetNet work/payloads are amortized by the ROI-reuse ratio; the
        # aggregator serializes the other cameras' suffix work (t_queue).
        det_amort = jnp.minimum(1.0, det_fps / cam_fps)
        t_det_sen = (j(det.c_cycles_sensor)[m, cd] / j(M.f_clk)[sen_i]
                     * det_amort)
        t_det_agg = ((j(det.c_cycles_agg)[m, n_det]
                      - j(det.c_cycles_agg)[m, cd])
                     / j(M.f_clk)[agg_i] * det_amort)
        t_key_sen = j(key.c_cycles_sensor)[m, ck] / j(M.f_clk)[sen_i]
        t_key_agg = ((j(key.c_cycles_agg)[m, n_key]
                      - j(key.c_cycles_agg)[m, ck])
                     / j(M.f_clk)[agg_i])
        t_comm_cut = (j(M.pay_det_rate)[m, cut] * det_amort
                      + j(M.pay_key_rate)[m, cut]) / A.MIPI_BW
        latency = (A.T_SENSE + t_comm_cam + t_det_sen + t_det_agg
                   + t_comm_cut + (ncam - 1.0) * (t_det_agg + t_key_agg)
                   + t_key_sen + t_key_agg)

        # Invalid (node, weight-mem) corners must poison every objective
        # channel — a Pareto front over non-power objectives would otherwise
        # happily select physically impossible configurations.  The power
        # fields inherit NaN from the wm_* tables; the rest get it here.
        invalid = jnp.where(has_sensor,
                            j(M.wm_e_read)[sen_i, wm_i] * 0.0, 0.0)
        # Padded-cut masking: on the stacked workload axis a grid cut index
        # beyond this model's own cut range addresses padding, not a real
        # partition — poison *every* channel (adds an exact 0.0 for the
        # in-range cuts, so single-model grids are bitwise unaffected).
        pad = jnp.where(cut <= n_all, 0.0, jnp.nan)
        invalid = invalid + pad

        total = (p_camera + p_utsv + p_mipi + p_sensor_compute
                 + p_sensor_memory + p_agg_compute + p_agg_memory)
        return {
            "avg_power": total + pad,
            "camera": p_camera + pad,
            "utsv": p_utsv + pad,
            "mipi": p_mipi + pad,
            "sensor_compute": p_sensor_compute + pad,
            "sensor_memory": p_sensor_memory + pad,
            "agg_compute": p_agg_compute + pad,
            "agg_memory": p_agg_memory + pad,
            "mipi_bytes_per_s": mipi_bps + invalid,
            "sensor_macs_per_s": (jnp.where(has_sensor, macs_s * ncam, 0.0)
                                  + invalid),
            "latency": latency + invalid,
        }

    return config_fn


def config_kernel(model: A.ModelArrays | None = None):
    """The unbatched, differentiable Eq. 1-11 kernel for one model.

    Returns the raw per-configuration function ``f(cut, agg_i, sen_i, wm_i,
    detnet_fps, keynet_fps, num_cameras, mipi_energy_scale, camera_fps) ->
    {field: scalar}`` that :func:`evaluate_grid` vmaps.  The integer
    arguments index the model's tables (``ModelArrays.node_index`` /
    ``arrays.WEIGHT_MEM_KINDS``); every float argument is differentiable —
    :mod:`repro.core.optimize` drives ``jax.grad`` through it for the
    continuous-knob search.  (Internally the kernel is the stacked
    multi-model one with the model coordinate pinned to 0.)
    """
    M = model if model is not None else A.model_arrays()
    fn = _make_config_fn(A.stack_model_arrays((M,)))
    return functools.partial(fn, 0)


def vmapped_kernel(S):
    """The un-jitted vmapped kernel (for embedding in a larger jit — the
    backend layer of :mod:`repro.core.backend` wraps it into both the
    dense evaluator and the fused chunk-reduction step).

    The vmapped signature is ``(model_i, cut, agg_i, sen_i, wm_i,
    detnet_fps, keynet_fps, num_cameras, mipi_energy_scale, camera_fps)``
    over equal-length flat arrays — exactly what the shared flat-index
    decode of :func:`repro.core.backend.decode_gather` produces.  A
    scenario-wrapped lowering (``scenario.ScenarioStack``) provides its
    own batched session kernel (one extra trailing ``trace_i``
    coordinate); dispatching on that hook here means every backend and
    engine built on this function runs scenarios unchanged.
    """
    builder = getattr(S, "vmapped_kernel", None)
    if builder is not None:
        return builder()
    return jax.vmap(_make_config_fn(S))


# ---------------------------------------------------------------------------
# Flat-index coordinate decoding (shared with the streaming executor)
# ---------------------------------------------------------------------------


def decode_flat_index(shape: Sequence[int], flat):
    """Mixed-radix decode of C-order flat indices into per-axis indices.

    Pure arithmetic — no coordinate meshes are ever materialized, so the
    cost is O(n_axes) per index regardless of grid size.  ``flat`` may be
    a Python int, a numpy array, or a traced jax array (the backend
    layer runs this decode on-device per chunk); returns one index per
    axis, in axis order.

    Index spaces beyond int32 (> 2^31-config grids) are guarded: a
    narrow integer array input is promoted to int64 before the stride
    arithmetic, so ``flat // stride`` can never overflow.  For traced
    jax inputs the promotion needs the caller's scoped ``enable_x64``
    context (which every engine here runs under) — without it the
    astype would silently stay 32-bit.
    """
    strides = []
    s = 1
    for size in reversed(shape):
        strides.append(s)
        s *= int(size)
    strides.reverse()
    if s > np.iinfo(np.int32).max and hasattr(flat, "dtype"):
        dt = np.dtype(flat.dtype)
        if np.issubdtype(dt, np.integer) and dt.itemsize < 8:
            flat = flat.astype(np.int64)
    return tuple((flat // stride) % size
                 for stride, size in zip(strides, shape))


def config_from_flat(shape: Sequence[int],
                     axes: "OrderedDict[str, tuple]",
                     flat_index: int) -> dict:
    """Axis values of one flat C-order grid index — the single
    ``config_at`` implementation behind both the dense ``SweepResult``
    and the streaming ``StreamResult`` (their flat indices are
    interchangeable by construction)."""
    n = int(np.prod(shape))
    if not 0 <= flat_index < n:
        raise IndexError(f"flat index {flat_index} outside [0, {n})")
    idx = decode_flat_index(shape, int(flat_index))
    return {name: vals[i] for (name, vals), i in zip(axes.items(), idx)}


def _fully_invalid_axis_values(nan_mask: np.ndarray,
                               axes: "OrderedDict[str, tuple]") -> list[str]:
    """``name=value`` notes for axis values whose whole hyperplane is NaN."""
    notes = []
    for ax, (name, vals) in enumerate(axes.items()):
        for i, v in enumerate(vals):
            if np.take(nan_mask, i, axis=ax).all():
                notes.append(f"{name}={v!r}")
    return notes


def invalid_message(field: str, notes: Sequence[str]) -> str:
    """Shared all-invalid error text (dense and streaming paths)."""
    detail = ("; fully-invalid axis values: " + ", ".join(notes)
              if notes else "")
    return (f"every grid configuration is invalid (all-NaN) in channel "
            f"{field!r} — check the weight_mem / sensor_node combinations "
            f"against the available memory test vehicles and the cut range "
            f"of each stacked model{detail}")


# ---------------------------------------------------------------------------
# Grid evaluation
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SweepResult:
    """Dense grid of Eq. 1/2 evaluations.

    ``axes`` maps axis name -> the axis values (in grid order); every array
    in ``data`` has shape ``tuple(len(v) for v in axes.values())``.  Grids
    evaluated with a stacked workload batch carry a leading ``model`` axis
    before the nine knob axes.
    """

    axes: "OrderedDict[str, tuple]"
    data: Mapping[str, np.ndarray]

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(len(v) for v in self.axes.values())

    @property
    def n_configs(self) -> int:
        return int(np.prod(self.shape))

    @property
    def avg_power(self) -> np.ndarray:
        return self.data["avg_power"]

    @property
    def latency(self) -> np.ndarray:
        """End-to-end result latency (s) — ``latency.cut_latency`` lowered."""
        return self.data["latency"]

    @property
    def mipi_bytes_per_s(self) -> np.ndarray:
        """MIPI link traffic (B/s) across all cameras (Eq. 5 payloads)."""
        return self.data["mipi_bytes_per_s"]

    def config_at(self, flat_index: int) -> dict:
        """Axis values of one flat grid index (arithmetic decode — no
        coordinate meshes)."""
        return config_from_flat(self.shape, self.axes, flat_index)

    def argmin(self, field: str = "avg_power") -> dict:
        """Best (lowest-``field``) configuration; NaN entries ignored.

        Raises a :class:`ValueError` naming the fully-invalid axis values
        when *every* grid corner is NaN in ``field`` (e.g. an MRAM-only
        grid on a node with no MRAM test vehicle).
        """
        arr = self.data[field]
        nan = np.isnan(arr)
        if nan.all():
            raise ValueError(invalid_message(
                field, _fully_invalid_axis_values(nan, self.axes)))
        flat = int(np.nanargmin(arr))
        out = self.config_at(flat)
        out[field] = float(self.data[field].ravel()[flat])
        return out

    def top_k(self, field: str = "avg_power", k: int = 4) -> list[dict]:
        """The ``k`` best (lowest-``field``) configurations, best first.

        Ties are broken by flat grid index (matching :meth:`argmin` and
        the streaming executor); NaN entries never appear.  Returns fewer
        than ``k`` entries when the grid has fewer valid configurations.
        """
        vals = self.data[field].ravel().copy()
        nan = np.isnan(vals)
        if nan.all():
            raise ValueError(invalid_message(
                field, _fully_invalid_axis_values(np.isnan(self.data[field]),
                                                  self.axes)))
        vals[nan] = np.inf
        if k * 4 < vals.size and vals.size > 4096:
            # Selection instead of a full stable sort on big grids; ties
            # at the k-th value resolve by flat index via the lexsort,
            # identical to the stable-argsort path below.
            kth = np.partition(vals, k - 1)[k - 1]
            sel = np.flatnonzero(vals <= kth)
            order = sel[np.lexsort((sel, vals[sel]))][:k]
        else:
            order = np.argsort(vals, kind="stable")[:k]
        out = []
        for flat in order:
            if not np.isfinite(vals[flat]):
                break
            cfg = self.config_at(int(flat))
            cfg[field] = float(vals[flat])
            out.append(cfg)
        return out

    def channel_bounds(self, field: str) -> tuple[float, float]:
        """(min, max) of the finite entries of one channel."""
        vals = self.data[field].ravel()
        finite = vals[np.isfinite(vals)]
        if finite.size == 0:
            raise ValueError(invalid_message(
                field, _fully_invalid_axis_values(np.isnan(self.data[field]),
                                                  self.axes)))
        return float(finite.min()), float(finite.max())

    def breakdown_at(self, flat_index: int) -> dict[str, float]:
        return {f: float(self.data[f].ravel()[flat_index])
                for f in self.data}

    def constrain(self, constraints) -> "SweepResult":
        """Dense post-filter twin of ``stream_grid(constraints=...)``.

        Returns a new :class:`SweepResult` with *every* channel NaN
        wherever any predicate fails (see :func:`parse_constraints`), so
        ``argmin``/``top_k``/``channel_bounds`` and
        :func:`repro.core.pareto.pareto_front` all run over the feasible
        set only — exactly what the streaming executor computes when the
        same constraints are compiled into its chunk step.
        """
        cons = parse_constraints(constraints)
        if not cons:
            return self
        mask = constraint_mask(self.data, cons)
        data = {f: np.where(mask, a, np.nan)
                for f, a in self.data.items()}
        return SweepResult(axes=self.axes, data=data)


def _node_axis(S: A.StackedModelArrays,
               nodes: Sequence[str | TechNode]) -> tuple[np.ndarray, tuple]:
    idx = np.asarray([S.node_index(n) for n in nodes], np.int32)
    labels = tuple(n if isinstance(n, str) else n.name for n in nodes)
    return idx, labels


def build_axes(cuts=None, agg_nodes=("7nm",), sensor_nodes=("7nm",),
               weight_mems=("sram",), detnet_fps=(DETNET_FPS,),
               keynet_fps=(KEYNET_FPS,), num_cameras=(NUM_CAMERAS,),
               mipi_energy_scale=(1.0,), camera_fps=(CAMERA_FPS,),
               detnet=None, keynet=None, model=None, models=None,
               scenarios=None):
    """Validate and lower the grid axes (shared by dense and streaming).

    Returns ``(S, axis_arrays, axes)`` where ``S`` is the stacked model
    lowering, ``axis_arrays`` are the per-axis kernel index/value arrays
    *including a leading model axis* (singleton when ``models`` is not
    given), and ``axes`` is the user-facing axis dict — which includes
    ``model`` only when a workload batch was requested, so single-model
    results keep their 9-axis shape.

    ``scenarios`` (a :class:`repro.core.scenario.ScenarioSet`, profile
    name(s), or trace(s) — see ``scenario.as_scenario_set``) wraps the
    lowering into a ``scenario.ScenarioStack`` and appends a trailing
    ``trace`` axis whose user-facing values are the trace names.
    """
    if models is not None:
        if model is not None or detnet is not None or keynet is not None:
            raise ValueError("pass either models= or a single "
                             "detnet/keynet/model, not both")
        S = (models if isinstance(models, A.StackedModelArrays)
             else A.stacked_model_arrays(models))
    elif model is not None:
        S = A.stack_model_arrays((model,))
    else:
        S = A.stack_model_arrays((A.model_arrays(detnet, keynet),))

    model_ax = np.arange(S.n_models, dtype=np.int32)
    if cuts is None:
        cut_ax = np.arange(S.n_cuts_max, dtype=np.int32)
    else:
        cut_ax = np.asarray(list(cuts), np.int32)
        if cut_ax.size and (cut_ax.min() < 0
                            or cut_ax.max() >= S.n_cuts_max):
            raise ValueError(f"cuts outside [0, {S.n_cuts_max - 1}]")
    agg_idx, agg_labels = _node_axis(S, agg_nodes)
    sen_idx, sen_labels = _node_axis(S, sensor_nodes)
    for m in weight_mems:
        if m not in A.WEIGHT_MEM_KINDS:
            raise ValueError(f"unknown weight_mem {m!r}; "
                             f"have {A.WEIGHT_MEM_KINDS}")
    wm_idx = np.asarray([A.WEIGHT_MEM_KINDS.index(m) for m in weight_mems],
                        np.int32)
    f64 = functools.partial(np.asarray, dtype=np.float64)
    float_axes = [f64(list(detnet_fps)), f64(list(keynet_fps)),
                  f64(list(num_cameras)), f64(list(mipi_energy_scale)),
                  f64(list(camera_fps))]
    if float_axes[2].size and (float_axes[2].min() < 1
                               or (float_axes[2] % 1 != 0).any()):
        raise ValueError(  # matches the scalar evaluate_cut semantics
            "num_cameras must be integers >= 1")

    axis_arrays = [model_ax, cut_ax, agg_idx, sen_idx, wm_idx, *float_axes]
    if 0 in (a.size for a in axis_arrays):
        raise ValueError("every grid axis needs at least one value")
    labels = (tuple(int(c) for c in cut_ax), agg_labels, sen_labels,
              tuple(weight_mems), tuple(float_axes[0]), tuple(float_axes[1]),
              tuple(float_axes[2]), tuple(float_axes[3]),
              tuple(float_axes[4]))
    if models is not None:
        axes = OrderedDict(zip((MODEL_AXIS,) + AXIS_NAMES,
                               (S.model_names,) + labels))
    else:
        axes = OrderedDict(zip(AXIS_NAMES, labels))
    if scenarios is not None:
        # Wrap *after* node/cut validation — those ran against the raw
        # stack above; the wrapper delegates every lookup back to it.
        from . import scenario as _scenario  # deferred: scenario imports us
        sset = _scenario.as_scenario_set(scenarios)
        S = _scenario.scenario_stack(S, sset)
        axis_arrays.append(np.arange(len(sset.traces), dtype=np.int32))
        axes[SCENARIO_AXIS] = sset.names
    return S, axis_arrays, axes


def evaluate_grid(cuts: Optional[Iterable[int]] = None,
                  agg_nodes: Sequence[str | TechNode] = ("7nm",),
                  sensor_nodes: Sequence[str | TechNode] = ("7nm",),
                  weight_mems: Sequence[str] = ("sram",),
                  detnet_fps: Sequence[float] = (DETNET_FPS,),
                  keynet_fps: Sequence[float] = (KEYNET_FPS,),
                  num_cameras: Sequence[float] = (NUM_CAMERAS,),
                  mipi_energy_scale: Sequence[float] = (1.0,),
                  camera_fps: Sequence[float] = (CAMERA_FPS,),
                  detnet: NNWorkload | None = None,
                  keynet: NNWorkload | None = None,
                  model: A.ModelArrays | None = None,
                  models=None,
                  scenarios=None,
                  backend: Optional[str] = None) -> SweepResult:
    """Evaluate Eqs. 1-11 over the cartesian product of the given axes.

    One compiled device call for the whole grid (post first-call jit
    compile, which is cached per workload batch).  ``cuts=None`` selects
    every legal partition point.  Returns a :class:`SweepResult` whose
    arrays are indexed ``[cut, agg, sensor, wmem, dfps, kfps, ncam,
    mipi_scale, cam_fps]`` — with a leading ``model`` axis when ``models``
    (a workload batch, see :func:`repro.core.arrays.stacked_model_arrays`)
    is given, and a trailing ``trace`` axis when ``scenarios`` (a
    :class:`repro.core.scenario.ScenarioSet` or profile name(s)) is:
    each configuration is then driven through every session trace and
    the four ``SCENARIO_FIELDS`` channels join the output.

    The grid runs as *one big chunk* of the shared evaluation-backend
    contract (:mod:`repro.core.backend`): flat indices are decoded to
    coordinates on-device, so no host coordinate meshes exist.
    ``backend`` selects the evaluation backend (``None`` -> ``"xla"``;
    ``"pallas"`` routes through the fused Pallas grid kernel of
    :mod:`repro.kernels.sweep_grid`).  Output memory is O(grid); for
    spaces that do not fit, use the streaming executor
    :func:`repro.core.stream.stream_grid`.
    """
    from . import backend as _backend   # import cycle: backend uses sweep

    S, axis_arrays, axes = build_axes(
        cuts, agg_nodes, sensor_nodes, weight_mems, detnet_fps, keynet_fps,
        num_cameras, mipi_energy_scale, camera_fps, detnet, keynet, model,
        models, scenarios)
    shape = tuple(len(v) for v in axes.values())
    full_shape = tuple(a.size for a in axis_arrays)
    n = int(np.prod(full_shape))

    with enable_x64():
        evalfn = _backend.cached_dense_eval(backend, S, full_shape,
                                            kernel_fields(S))
        out = evalfn(tuple(map(jnp.asarray, axis_arrays)),
                     jnp.arange(n, dtype=jnp.int64))
        data = {k: np.asarray(v).reshape(shape) for k, v in out.items()}
    return SweepResult(axes=axes, data=data)


def scalar_axes(kw: Mapping) -> dict:
    """Map ``partition.evaluate_cut``-style kwargs onto grid axes — the
    one place the kwarg↔axis correspondence is written down (shared by
    :func:`evaluate_one` and ``partition.optimal_partition``).  Scalar
    values become singleton axes; a list/tuple/array value passes through
    as a whole axis, which is how ``optimal_partition`` grows single-knob
    calls into grid (and, past the size threshold, streaming) searches."""
    def ax(name, default):
        v = kw.get(name, default)
        if v is None:
            v = default
        return (tuple(v) if isinstance(v, (list, tuple, np.ndarray))
                else (v,))

    return dict(
        agg_nodes=ax("agg_node", "7nm"),
        sensor_nodes=ax("sensor_node", "7nm"),
        weight_mems=ax("sensor_weight_mem", "sram"),
        detnet_fps=ax("detnet_fps", DETNET_FPS),
        keynet_fps=ax("keynet_fps", KEYNET_FPS),
        num_cameras=ax("num_cameras", NUM_CAMERAS),
        mipi_energy_scale=ax("mipi_energy_scale", 1.0),
        camera_fps=ax("camera_fps", CAMERA_FPS),
        detnet=kw.get("detnet"), keynet=kw.get("keynet"))


def evaluate_one(cut: int, **kw) -> dict[str, float]:
    """Single-configuration convenience wrapper over :func:`evaluate_grid`.

    Scalar keyword arguments match ``partition.evaluate_cut`` (``agg_node``,
    ``sensor_node``, ``sensor_weight_mem``, fps knobs, ...); returns the
    kernel's field dict for that one point.  Sequence-valued kwargs are
    rejected — grid axes belong to :func:`evaluate_grid` (or
    ``partition.optimal_partition``, which accepts them directly).
    """
    seq = sorted(k for k, v in kw.items()
                 if isinstance(v, (list, tuple, np.ndarray)))
    if seq:
        raise ValueError(f"evaluate_one takes scalar knobs only; {seq} "
                         f"are sequences — use evaluate_grid for axes")
    return evaluate_grid(cuts=(cut,), **scalar_axes(kw)).breakdown_at(0)
