"""Vectorized design-space engine: Eqs. 1-11 as one jit/vmap kernel.

The repo now has **two evaluation paths** over the same analytical model:

* **Scalar path** (:mod:`repro.core.system`, :mod:`repro.core.partition`) —
  builds an explicit, named ``ModuleEnergy`` list for one configuration.
  Use it when you want the full per-module report (the Fig. 5 stacked
  bars, per-sensor groups, labels).
* **Array path** (this module) — consumes the struct-of-arrays lowering
  of :mod:`repro.core.arrays` and evaluates an arbitrary cartesian grid
  over the paper's design knobs in a single ``jax.jit``-compiled,
  ``jax.vmap``-batched device call.  Use it for sweeps: dense sensitivity
  heatmaps, partition × node × memory × rate grids, and as the substrate
  for multi-objective analysis — every configuration evaluates the three
  objective channels (``avg_power``, ``latency``, ``mipi_bytes_per_s``)
  that :mod:`repro.core.pareto` extracts fronts over and
  :mod:`repro.core.optimize` differentiates through.

The two paths are kept numerically interchangeable (``tests/test_sweep.py``
asserts ≤1e-6 relative parity across a sampled grid); the payload plan per
partition cut comes from the shared :func:`repro.core.arrays.mipi_payloads`
so the cut semantics cannot drift.

Grid axes of :func:`evaluate_grid` (cartesian product, in order)::

    cut               partition index over DetNet ++ KeyNet layer list
    agg_node          aggregator tech node        ("7nm" | "16nm" | TechNode)
    sensor_node       on-sensor tech node
    weight_mem        on-sensor weight memory     ("sram" | "mram")
    detnet_fps        DetNet rate (the ROI-reuse knob)
    keynet_fps        KeyNet rate
    num_cameras       camera count
    mipi_energy_scale multiplier on MIPI pJ/B (Eq. 5 sensitivity axis)
    camera_fps        frame delivery rate

Configurations that are physically invalid (MRAM weight memory on a node
with no MRAM test vehicle, with an on-sensor deployment present) evaluate
to NaN rather than raising, so a dense grid can mix valid and invalid
corners.  All arithmetic runs in float64 (scoped ``enable_x64`` — the
global JAX config is left untouched).
"""

from __future__ import annotations

import dataclasses
import functools
from collections import OrderedDict
from typing import Iterable, Mapping, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from . import arrays as A
from .constants import (CAMERA_FPS, DETNET_FPS, KEYNET_FPS, NUM_CAMERAS,
                        TechNode)
from .workloads import NNWorkload

AXIS_NAMES = ("cut", "agg_node", "sensor_node", "weight_mem", "detnet_fps",
              "keynet_fps", "num_cameras", "mipi_energy_scale", "camera_fps")

#: Output fields of the kernel (each becomes one grid-shaped array).
#: ``avg_power`` + the seven power-breakdown groups, plus the three
#: non-power objective channels: ``mipi_bytes_per_s`` (Eq. 5 link traffic),
#: ``sensor_macs_per_s`` and ``latency`` (the generalized per-cut
#: ``repro.core.latency.cut_latency`` model, lowered into the kernel).
FIELDS = ("avg_power", "camera", "utsv", "mipi", "sensor_compute",
          "sensor_memory", "agg_compute", "agg_memory", "mipi_bytes_per_s",
          "sensor_macs_per_s", "latency")


# ---------------------------------------------------------------------------
# The per-configuration kernel (vmapped over flat config arrays)
# ---------------------------------------------------------------------------


def _site_power(macs_per_s, w_read_per_s, act_per_s, cycles_per_s, f_clk,
                e_mac, wm_e_read, wm_leak_on, wm_leak_ret, sram_e_read,
                sram_e_write, sram_leak_on, sram_leak_ret, cap_w, cap_a,
                l1_bytes):
    """Eqs. 7-11 for one processor site, per-second accounting.

    Mirrors ``system.Deployment.modules()``: compute (Eq. 7), L2-weight /
    L2-activation / L1 access energy (Eq. 8), and On/Retention leakage for
    the three memory instances (Eqs. 9-11 with a 1 s window).
    """
    p_compute = macs_per_s * e_mac

    act_read = act_per_s / 2
    act_write = act_per_s / 2
    # L1 sees every streamed byte once more (L2 -> L1 -> engine).
    l1_traffic = w_read_per_s + act_read + act_write
    p_l2w = w_read_per_s * wm_e_read
    p_l2a = act_read * sram_e_read + act_write * sram_e_write
    p_l1 = (l1_traffic / 2 * (A.L1_ENERGY_SCALE * sram_e_read)
            + l1_traffic / 2 * (A.L1_ENERGY_SCALE * sram_e_write))

    t_proc = jnp.minimum(1.0, cycles_per_s / f_clk)
    t_idle = jnp.maximum(0.0, 1.0 - t_proc)
    p_leak = (cap_w * (wm_leak_on * t_proc + wm_leak_ret * t_idle)
              + cap_a * (sram_leak_on * t_proc + sram_leak_ret * t_idle)
              + l1_bytes * (sram_leak_on * t_proc + sram_leak_ret * t_idle))
    return p_compute, p_l2w + p_l2a + p_l1 + p_leak


def _make_config_fn(M: A.ModelArrays):
    """Close the Eq. 1-11 kernel over one model's constant tables."""
    det, key = M.det, M.key
    n_det, n_key = det.n_layers, key.n_layers
    n_all = n_det + n_key
    j = jnp.asarray  # constants fold into the jaxpr at trace time

    def config_fn(cut, agg_i, sen_i, wm_i, det_fps, key_fps, ncam,
                  mipi_scale, cam_fps):
        cd = jnp.clip(cut, 0, n_det)          # DetNet layers on-sensor
        ck = jnp.clip(cut - n_det, 0, n_key)  # KeyNet layers on-sensor
        has_sensor = cut > 0
        has_agg = cut < n_all

        # ---- Eq. 3/4: cameras (readout window set by camera-side link) ----
        t_comm_cam = A.FULL_FRAME / jnp.where(has_sensor, A.UTSV_BW,
                                              A.MIPI_BW)
        t_off = jnp.maximum(0.0, 1.0 / cam_fps - A.T_SENSE - t_comm_cam)
        e_cam = (A.CAMERA_SENSE_W * A.T_SENSE + A.CAMERA_READ_W * t_comm_cam
                 + A.CAMERA_IDLE_W * t_off)
        p_camera = e_cam * cam_fps * ncam

        # ---- Eq. 5: uTSV readout link (distributed only) ----
        p_utsv = jnp.where(
            has_sensor, A.FULL_FRAME * A.UTSV_E_PER_BYTE * cam_fps * ncam,
            0.0)

        # ---- Eq. 5: MIPI payload plan for this cut ----
        bps_per_cam = (j(M.pay_cam_rate)[cut] * cam_fps
                       + j(M.pay_det_rate)[cut] * det_fps
                       + j(M.pay_key_rate)[cut] * key_fps)
        p_mipi = bps_per_cam * (A.MIPI_E_PER_BYTE * mipi_scale) * ncam
        mipi_bps = bps_per_cam * ncam

        # ---- on-sensor site (x ncam replicas) ----
        macs_s = (j(det.c_macs)[cd] * det_fps + j(key.c_macs)[ck] * key_fps)
        w_read_s = (j(det.c_weight_stream)[cd] * det_fps
                    + j(key.c_weight_stream)[ck] * key_fps)
        act_s = (j(det.c_act_traffic)[cd] * det_fps
                 + j(key.c_act_traffic)[ck] * key_fps)
        cyc_s = (j(det.c_cycles_sensor)[cd] * det_fps
                 + j(key.c_cycles_sensor)[ck] * key_fps)
        cap_w_s = j(det.c_weight_bytes)[cd] + j(key.c_weight_bytes)[ck]
        cap_a_s = (jnp.maximum(j(det.peak_prefix)[cd], j(key.peak_prefix)[ck])
                   + det.input_bytes)
        p_comp_s, p_mem_s = _site_power(
            macs_s, w_read_s, act_s, cyc_s,
            j(M.f_clk)[sen_i], j(M.e_mac)[sen_i],
            j(M.wm_e_read)[sen_i, wm_i], j(M.wm_leak_on)[sen_i, wm_i],
            j(M.wm_leak_ret)[sen_i, wm_i],
            j(M.sram_e_read)[sen_i], j(M.sram_e_write)[sen_i],
            j(M.sram_leak_on)[sen_i], j(M.sram_leak_ret)[sen_i],
            cap_w_s, cap_a_s, A.SENSOR_L1_BYTES)
        p_sensor_compute = jnp.where(has_sensor, p_comp_s * ncam, 0.0)
        p_sensor_memory = jnp.where(has_sensor, p_mem_s * ncam, 0.0)

        # ---- aggregator site (suffix of each network, rate x ncam) ----
        macs_a = ((j(det.c_macs)[n_det] - j(det.c_macs)[cd])
                  * (det_fps * ncam)
                  + (j(key.c_macs)[n_key] - j(key.c_macs)[ck])
                  * (key_fps * ncam))
        w_read_a = ((j(det.c_weight_stream)[n_det]
                     - j(det.c_weight_stream)[cd]) * (det_fps * ncam)
                    + (j(key.c_weight_stream)[n_key]
                       - j(key.c_weight_stream)[ck]) * (key_fps * ncam))
        act_a = ((j(det.c_act_traffic)[n_det] - j(det.c_act_traffic)[cd])
                 * (det_fps * ncam)
                 + (j(key.c_act_traffic)[n_key] - j(key.c_act_traffic)[ck])
                 * (key_fps * ncam))
        cyc_a = ((j(det.c_cycles_agg)[n_det] - j(det.c_cycles_agg)[cd])
                 * (det_fps * ncam)
                 + (j(key.c_cycles_agg)[n_key] - j(key.c_cycles_agg)[ck])
                 * (key_fps * ncam))
        cap_w_a = ((j(det.c_weight_bytes)[n_det] - j(det.c_weight_bytes)[cd])
                   + (j(key.c_weight_bytes)[n_key]
                      - j(key.c_weight_bytes)[ck]))
        cap_a_a = (jnp.maximum(j(det.peak_suffix)[cd], j(key.peak_suffix)[ck])
                   + j(M.pay_max)[cut] * ncam)
        p_comp_a, p_mem_a = _site_power(
            macs_a, w_read_a, act_a, cyc_a,
            j(M.f_clk)[agg_i], j(M.e_mac)[agg_i],
            # the aggregator's weight memory is always its node SRAM
            j(M.sram_e_read)[agg_i], j(M.sram_leak_on)[agg_i],
            j(M.sram_leak_ret)[agg_i],
            j(M.sram_e_read)[agg_i], j(M.sram_e_write)[agg_i],
            j(M.sram_leak_on)[agg_i], j(M.sram_leak_ret)[agg_i],
            cap_w_a, cap_a_a, A.AGG_L1_BYTES)
        p_agg_compute = jnp.where(has_agg, p_comp_a, 0.0)
        p_agg_memory = jnp.where(has_agg, p_mem_a, 0.0)

        # ---- end-to-end result latency (cut_latency, lowered: Eq. 6/9) ----
        # DetNet work/payloads are amortized by the ROI-reuse ratio; the
        # aggregator serializes the other cameras' suffix work (t_queue).
        det_amort = jnp.minimum(1.0, det_fps / cam_fps)
        t_det_sen = j(det.c_cycles_sensor)[cd] / j(M.f_clk)[sen_i] * det_amort
        t_det_agg = ((j(det.c_cycles_agg)[n_det] - j(det.c_cycles_agg)[cd])
                     / j(M.f_clk)[agg_i] * det_amort)
        t_key_sen = j(key.c_cycles_sensor)[ck] / j(M.f_clk)[sen_i]
        t_key_agg = ((j(key.c_cycles_agg)[n_key] - j(key.c_cycles_agg)[ck])
                     / j(M.f_clk)[agg_i])
        t_comm_cut = (j(M.pay_det_rate)[cut] * det_amort
                      + j(M.pay_key_rate)[cut]) / A.MIPI_BW
        latency = (A.T_SENSE + t_comm_cam + t_det_sen + t_det_agg
                   + t_comm_cut + (ncam - 1.0) * (t_det_agg + t_key_agg)
                   + t_key_sen + t_key_agg)

        # Invalid (node, weight-mem) corners must poison every objective
        # channel — a Pareto front over non-power objectives would otherwise
        # happily select physically impossible configurations.  The power
        # fields inherit NaN from the wm_* tables; the rest get it here.
        invalid = jnp.where(has_sensor,
                            j(M.wm_e_read)[sen_i, wm_i] * 0.0, 0.0)

        total = (p_camera + p_utsv + p_mipi + p_sensor_compute
                 + p_sensor_memory + p_agg_compute + p_agg_memory)
        return {
            "avg_power": total,
            "camera": p_camera,
            "utsv": p_utsv,
            "mipi": p_mipi,
            "sensor_compute": p_sensor_compute,
            "sensor_memory": p_sensor_memory,
            "agg_compute": p_agg_compute,
            "agg_memory": p_agg_memory,
            "mipi_bytes_per_s": mipi_bps + invalid,
            "sensor_macs_per_s": (jnp.where(has_sensor, macs_s * ncam, 0.0)
                                  + invalid),
            "latency": latency + invalid,
        }

    return config_fn


def config_kernel(model: A.ModelArrays | None = None):
    """The unbatched, differentiable Eq. 1-11 kernel for one model.

    Returns the raw per-configuration function ``f(cut, agg_i, sen_i, wm_i,
    detnet_fps, keynet_fps, num_cameras, mipi_energy_scale, camera_fps) ->
    {field: scalar}`` that :func:`evaluate_grid` vmaps.  The integer
    arguments index the model's tables (``ModelArrays.node_index`` /
    ``arrays.WEIGHT_MEM_KINDS``); every float argument is differentiable —
    :mod:`repro.core.optimize` drives ``jax.grad`` through it for the
    continuous-knob search.
    """
    M = model if model is not None else A.model_arrays()
    return _make_config_fn(M)


@functools.lru_cache(maxsize=16)
def _compiled_kernel(M: A.ModelArrays):
    """One jit(vmap(kernel)) per model lowering (cached by identity)."""
    return jax.jit(jax.vmap(_make_config_fn(M)))


# ---------------------------------------------------------------------------
# Grid evaluation
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SweepResult:
    """Dense grid of Eq. 1/2 evaluations.

    ``axes`` maps axis name -> the axis values (in grid order); every array
    in ``data`` has shape ``tuple(len(v) for v in axes.values())``.
    """

    axes: "OrderedDict[str, tuple]"
    data: Mapping[str, np.ndarray]

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(len(v) for v in self.axes.values())

    @property
    def n_configs(self) -> int:
        return int(np.prod(self.shape))

    @property
    def avg_power(self) -> np.ndarray:
        return self.data["avg_power"]

    @property
    def latency(self) -> np.ndarray:
        """End-to-end result latency (s) — ``latency.cut_latency`` lowered."""
        return self.data["latency"]

    @property
    def mipi_bytes_per_s(self) -> np.ndarray:
        """MIPI link traffic (B/s) across all cameras (Eq. 5 payloads)."""
        return self.data["mipi_bytes_per_s"]

    def config_at(self, flat_index: int) -> dict:
        """Axis values of one flat grid index."""
        idx = np.unravel_index(flat_index, self.shape)
        return {name: vals[i]
                for (name, vals), i in zip(self.axes.items(), idx)}

    def argmin(self, field: str = "avg_power") -> dict:
        """Best (lowest-``field``) configuration; NaN entries ignored."""
        arr = self.data[field]
        if np.isnan(arr).all():
            raise ValueError(
                "every grid corner is invalid (all-NaN) — check the "
                "weight_mem / sensor_node combinations against the "
                "available memory test vehicles")
        flat = int(np.nanargmin(arr))
        out = self.config_at(flat)
        out[field] = float(self.data[field].ravel()[flat])
        return out

    def breakdown_at(self, flat_index: int) -> dict[str, float]:
        return {f: float(self.data[f].ravel()[flat_index]) for f in FIELDS}


def _node_axis(M: A.ModelArrays,
               nodes: Sequence[str | TechNode]) -> tuple[np.ndarray, tuple]:
    idx = np.asarray([M.node_index(n) for n in nodes], np.int32)
    labels = tuple(n if isinstance(n, str) else n.name for n in nodes)
    return idx, labels


def evaluate_grid(cuts: Optional[Iterable[int]] = None,
                  agg_nodes: Sequence[str | TechNode] = ("7nm",),
                  sensor_nodes: Sequence[str | TechNode] = ("7nm",),
                  weight_mems: Sequence[str] = ("sram",),
                  detnet_fps: Sequence[float] = (DETNET_FPS,),
                  keynet_fps: Sequence[float] = (KEYNET_FPS,),
                  num_cameras: Sequence[float] = (NUM_CAMERAS,),
                  mipi_energy_scale: Sequence[float] = (1.0,),
                  camera_fps: Sequence[float] = (CAMERA_FPS,),
                  detnet: NNWorkload | None = None,
                  keynet: NNWorkload | None = None,
                  model: A.ModelArrays | None = None) -> SweepResult:
    """Evaluate Eqs. 1-11 over the cartesian product of the given axes.

    One compiled device call for the whole grid (post first-call jit
    compile, which is cached per workload pair).  ``cuts=None`` selects
    every legal partition point.  Returns a :class:`SweepResult` whose
    arrays are indexed ``[cut, agg, sensor, wmem, dfps, kfps, ncam,
    mipi_scale, cam_fps]``.
    """
    M = model if model is not None else A.model_arrays(detnet, keynet)

    if cuts is None:
        cut_ax = np.arange(M.n_cuts, dtype=np.int32)
    else:
        cut_ax = np.asarray(list(cuts), np.int32)
        if cut_ax.size and (cut_ax.min() < 0 or cut_ax.max() >= M.n_cuts):
            raise ValueError(f"cuts outside [0, {M.n_cuts - 1}]")
    agg_idx, agg_labels = _node_axis(M, agg_nodes)
    sen_idx, sen_labels = _node_axis(M, sensor_nodes)
    for m in weight_mems:
        if m not in A.WEIGHT_MEM_KINDS:
            raise ValueError(f"unknown weight_mem {m!r}; "
                             f"have {A.WEIGHT_MEM_KINDS}")
    wm_idx = np.asarray([A.WEIGHT_MEM_KINDS.index(m) for m in weight_mems],
                        np.int32)
    f64 = functools.partial(np.asarray, dtype=np.float64)
    float_axes = [f64(list(detnet_fps)), f64(list(keynet_fps)),
                  f64(list(num_cameras)), f64(list(mipi_energy_scale)),
                  f64(list(camera_fps))]
    if float_axes[2].size and (float_axes[2].min() < 1
                               or (float_axes[2] % 1 != 0).any()):
        raise ValueError(  # matches the scalar evaluate_cut semantics
            "num_cameras must be integers >= 1")

    axis_arrays = [cut_ax, agg_idx, sen_idx, wm_idx, *float_axes]
    shape = tuple(a.size for a in axis_arrays)
    if 0 in shape:
        raise ValueError("every grid axis needs at least one value")
    grids = np.meshgrid(*axis_arrays, indexing="ij")
    flat = [g.ravel() for g in grids]

    with enable_x64():
        out = _compiled_kernel(M)(*map(jnp.asarray, flat))
        data = {k: np.asarray(v).reshape(shape) for k, v in out.items()}

    axes = OrderedDict(zip(AXIS_NAMES, (
        tuple(int(c) for c in cut_ax), agg_labels, sen_labels,
        tuple(weight_mems), tuple(float_axes[0]), tuple(float_axes[1]),
        tuple(float_axes[2]), tuple(float_axes[3]), tuple(float_axes[4]))))
    return SweepResult(axes=axes, data=data)


def scalar_axes(kw: Mapping) -> dict:
    """Map ``partition.evaluate_cut``-style scalar kwargs onto singleton
    grid axes — the one place the kwarg↔axis correspondence is written
    down (shared by :func:`evaluate_one` and
    ``partition.optimal_partition``)."""
    return dict(
        agg_nodes=(kw.get("agg_node", "7nm"),),
        sensor_nodes=(kw.get("sensor_node", "7nm"),),
        weight_mems=(kw.get("sensor_weight_mem", "sram"),),
        detnet_fps=(kw.get("detnet_fps", DETNET_FPS),),
        keynet_fps=(kw.get("keynet_fps", KEYNET_FPS),),
        num_cameras=(kw.get("num_cameras", NUM_CAMERAS),),
        mipi_energy_scale=(kw.get("mipi_energy_scale", 1.0),),
        camera_fps=(kw.get("camera_fps", CAMERA_FPS),),
        detnet=kw.get("detnet"), keynet=kw.get("keynet"))


def evaluate_one(cut: int, **kw) -> dict[str, float]:
    """Single-configuration convenience wrapper over :func:`evaluate_grid`.

    Scalar keyword arguments match ``partition.evaluate_cut`` (``agg_node``,
    ``sensor_node``, ``sensor_weight_mem``, fps knobs, ...); returns the
    kernel's field dict for that one point.
    """
    return evaluate_grid(cuts=(cut,), **scalar_axes(kw)).breakdown_at(0)
