"""Neural-network workload descriptions for the semi-analytical model.

A workload is a list of :class:`LayerSpec` — exactly the granularity the paper
extracts from the GVSoC/DORY/NEMO toolchain: per-layer MAC counts, weight
footprints and activation traffic.  The analytical equations (Eqs. 7-11) only
ever consume these aggregate counts, so any network expressible this way can
be pushed through the model (including, via ``repro.core.tpu_energy``, the
compiled HLO of the assigned LM architectures).
"""

from __future__ import annotations

import dataclasses
import enum
import functools
import math
from typing import Iterable, List, Sequence, Tuple


class LayerKind(enum.Enum):
    CONV = "conv"            # regular KxK convolution
    POINTWISE = "pointwise"  # 1x1 convolution
    DEPTHWISE = "depthwise"  # KxK depthwise convolution
    FC = "fc"                # fully connected / matmul


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """Per-layer counts (all sizes in bytes, 8-bit weights/activations).

    The geometry fields (k/stride/cin/cout) make the table *executable*:
    ``repro.models.cnn`` builds a real JAX model from them and validates
    its traced MACs against these counts.
    """

    name: str
    kind: LayerKind
    macs: int
    weight_bytes: int
    in_act_bytes: int
    out_act_bytes: int
    # geometry (0 for fc layers)
    k: int = 0
    stride: int = 1
    cin: int = 0
    cout: int = 0

    @property
    def arithmetic_intensity_w(self) -> float:
        """MACs per weight byte — the x-axis of the paper's Fig. 4 roofline
        when performance is bounded by weight streaming."""
        return self.macs / max(self.weight_bytes, 1)


@dataclasses.dataclass(frozen=True)
class NNWorkload:
    """A whole network as seen by the energy model."""

    name: str
    layers: Tuple[LayerSpec, ...]
    input_bytes: int      # bytes entering the network (image / ROI / tokens)
    output_bytes: int     # bytes leaving the network (ROI coords, keypoints..)

    # The reductions below are consumed on every Eq. 7-11 evaluation; they
    # are memoized (the dataclass is frozen, so they can never go stale).
    @functools.cached_property
    def total_macs(self) -> int:
        return sum(l.macs for l in self.layers)

    @functools.cached_property
    def total_weight_bytes(self) -> int:
        return sum(l.weight_bytes for l in self.layers)

    @functools.cached_property
    def peak_act_bytes(self) -> int:
        return max((max(l.in_act_bytes, l.out_act_bytes) for l in self.layers),
                   default=0)

    @functools.cached_property
    def total_act_traffic_bytes(self) -> int:
        """Total activation bytes read+written across the network."""
        return sum(l.in_act_bytes + l.out_act_bytes for l in self.layers)

    def scaled(self, factor: float, name: str | None = None) -> "NNWorkload":
        """Uniformly scale MAC/weight/activation counts (ablation knob)."""
        layers = tuple(
            dataclasses.replace(
                l,
                macs=int(l.macs * factor),
                weight_bytes=int(l.weight_bytes * factor),
                in_act_bytes=int(l.in_act_bytes * factor),
                out_act_bytes=int(l.out_act_bytes * factor),
            )
            for l in self.layers
        )
        return NNWorkload(name or f"{self.name}x{factor:g}", layers,
                          int(self.input_bytes * factor),
                          int(self.output_bytes * factor))


# ---------------------------------------------------------------------------
# Layer builders (8-bit weights and activations, stride-aware)
# ---------------------------------------------------------------------------


def conv2d(name: str, h: int, w: int, cin: int, cout: int, k: int = 3,
           stride: int = 1, kind: LayerKind = LayerKind.CONV) -> LayerSpec:
    ho, wo = math.ceil(h / stride), math.ceil(w / stride)
    if kind is LayerKind.DEPTHWISE:
        assert cin == cout, "depthwise requires cin == cout"
        macs = k * k * cin * ho * wo
        weights = k * k * cin
    else:
        macs = k * k * cin * cout * ho * wo
        weights = k * k * cin * cout
    return LayerSpec(
        name=name, kind=kind, macs=macs, weight_bytes=weights,
        in_act_bytes=h * w * cin, out_act_bytes=ho * wo * cout,
        k=k, stride=stride, cin=cin, cout=cout,
    )


def pointwise(name: str, h: int, w: int, cin: int, cout: int) -> LayerSpec:
    return conv2d(name, h, w, cin, cout, k=1, kind=LayerKind.POINTWISE)


def depthwise(name: str, h: int, w: int, c: int, k: int = 3,
              stride: int = 1) -> LayerSpec:
    return conv2d(name, h, w, c, c, k=k, stride=stride,
                  kind=LayerKind.DEPTHWISE)


def fc(name: str, nin: int, nout: int) -> LayerSpec:
    return LayerSpec(name=name, kind=LayerKind.FC, macs=nin * nout,
                     weight_bytes=nin * nout, in_act_bytes=nin,
                     out_act_bytes=nout)


def dw_separable(prefix: str, h: int, w: int, cin: int, cout: int,
                 stride: int = 1) -> List[LayerSpec]:
    """MobileNet-style depthwise-separable block: DW 3x3 + PW 1x1."""
    ho, wo = math.ceil(h / stride), math.ceil(w / stride)
    return [
        depthwise(f"{prefix}.dw", h, w, cin, stride=stride),
        pointwise(f"{prefix}.pw", ho, wo, cin, cout),
    ]
