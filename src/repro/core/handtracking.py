"""The AR/VR Hand-Tracking workload (MEgATrack [8]) as layer tables.

The paper deploys the MEgATrack two-stage pipeline: **DetNet** finds the hand
and produces a region of interest (ROI); **KeyNet** regresses 21 keypoints
from the ROI crop.  MEgATrack does not publish full layer tables, so the
networks below are representative mobile-CNN reconstructions at the published
input resolutions (DetNet: 320x240 monochrome downsample; KeyNet: 96x96 ROI
crop), mixing regular, depthwise and pointwise convolutions so that all three
RBE roofline regimes of the paper's Fig. 4 are exercised.

Magnitudes are in the range the paper implies (DetNet a few hundred MMAC —
"sufficiently computationally intensive to strain many current systems" at
4 cameras x 30 fps; KeyNet lighter, run per-frame on the small crop).
"""

from __future__ import annotations

import functools

from .constants import (BYTES_PER_PIXEL_RAW, DETNET_INPUT_H, DETNET_INPUT_W,
                        IMAGE_H, IMAGE_W, ROI_H, ROI_W)
from .workloads import (LayerSpec, NNWorkload, conv2d, dw_separable, fc,
                        pointwise)


@functools.lru_cache(maxsize=None)
def build_detnet() -> NNWorkload:
    """Hand detector over the downscaled 320x240 monochrome frame."""
    h, w = DETNET_INPUT_H, DETNET_INPUT_W  # 240 x 320
    layers: list[LayerSpec] = []
    layers.append(conv2d("stem", w, h, 1, 16, k=3, stride=2))        # 160x120
    w, h = w // 2, h // 2
    layers += dw_separable("b1", w, h, 16, 48, stride=2)             # 80x60
    w, h = w // 2, h // 2
    layers += dw_separable("b2", w, h, 48, 48)
    layers += dw_separable("b3", w, h, 48, 96, stride=2)             # 40x30
    w, h = w // 2, h // 2
    layers += dw_separable("b4", w, h, 96, 96)
    layers.append(conv2d("mid", w, h, 96, 96, k=3))
    layers += dw_separable("b5", w, h, 96, 192, stride=2)            # 20x15
    w, h = w // 2, (h + 1) // 2
    layers += dw_separable("b6", w, h, 192, 192)
    layers.append(conv2d("neck", w, h, 192, 192, k=3))
    layers.append(conv2d("neck2", w, h, 192, 192, k=3))
    # detection heads: box regression + palm confidence over anchor grid
    layers.append(pointwise("head.cls", w, h, 192, 6))
    layers.append(pointwise("head.box", w, h, 192, 24))
    return NNWorkload(
        name="DetNet",
        layers=tuple(layers),
        input_bytes=DETNET_INPUT_W * DETNET_INPUT_H,  # 1 B/px monochrome
        output_bytes=64,  # a handful of box candidates
    )


@functools.lru_cache(maxsize=None)
def build_keynet() -> NNWorkload:
    """Keypoint regressor over the 96x96 ROI crop."""
    h = w = ROI_H  # 96
    layers: list[LayerSpec] = []
    layers.append(conv2d("stem", w, h, 1, 32, k=3, stride=2))        # 48
    w = h = 48
    layers += dw_separable("b1", w, h, 32, 64, stride=2)             # 24
    w = h = 24
    layers += dw_separable("b2", w, h, 64, 64)
    layers += dw_separable("b3", w, h, 64, 128, stride=2)            # 12
    w = h = 12
    layers += dw_separable("b4", w, h, 128, 128)
    layers.append(conv2d("mid", w, h, 128, 128, k=3))
    layers += dw_separable("b5", w, h, 128, 256, stride=2)           # 6
    w = h = 6
    layers += dw_separable("b6", w, h, 256, 256)
    layers.append(fc("head.kp", 6 * 6 * 256, 21 * 3))  # 21 keypoints x 3
    return NNWorkload(
        name="KeyNet",
        layers=tuple(layers),
        input_bytes=ROI_W * ROI_H,
        output_bytes=21 * 3 * 2,  # 21 keypoints, 16-bit fixed point
    )


ROI_BYTES = ROI_W * ROI_H            # int8 crop shipped over MIPI in DOSC mode
# Raw 10-bit frame (RAW10-packed) shipped over MIPI (centralized) / uTSV (DOSC)
FULL_FRAME_BYTES = int(IMAGE_W * IMAGE_H * BYTES_PER_PIXEL_RAW)
