"""Streaming, sharded sweep executor: memory-bounded giant design spaces.

:func:`repro.core.sweep.evaluate_grid` materializes the full cartesian
product — host coordinate meshes on the way in, eleven dense channel
grids on the way out — so memory is O(grid) and a 9-axis space at
realistic resolution (10⁷–10⁹ configurations) is unreachable.  This
module replaces that with a **streaming executor** over the *same*
compiled Eq. 1-11 kernel, with every per-chunk reduction fused into the
device step so the host never sees a full channel array:

* **Device-side coordinate decoding** — each chunk starts from a flat
  index range; the mixed-radix decode of
  :func:`repro.core.sweep.decode_flat_index` runs on-device, so no
  coordinate arrays are ever materialized anywhere.
* **Fused on-device reductions** — one cached, jit-compiled step decodes
  and evaluates a chunk and folds it into a donated running device carry:
  argmin, feasibility counts and channel bounds per tracked channel,
  per-objective **top-k** (chunk ``lax.top_k`` merged against the running
  ``(n_obj, k)`` table with an exact two-key sort), optional histograms,
  and the Pareto **dominance pre-filter**
  (:func:`repro.core.pareto.dominance_filter_mask`, traced on-device).
  Each step returns only a *compacted survivor set* — the few candidate
  front rows (flat indices + objective values) the filter could not
  discard — instead of ``(n_fields, chunk)`` channel arrays, so
  device→host traffic is O(survivors) per chunk.
* **Compiled constraint predicates** — ``constraints=`` (e.g. a latency
  budget or a MIPI link cap, see
  :func:`repro.core.sweep.parse_constraints`) are masked inside the chunk
  step before any reduction: every result — argmin, top-k, counts,
  bounds, histograms, front — is over the *feasible* set, identical to
  host post-filtering the dense grid (``SweepResult.constrain``).
* **Async double-buffered pipeline** — a producer thread drives the
  chunk chain (XLA releases the GIL while a step executes) with
  ``prefetch=`` chunk results in flight, so the host-side exact front
  merges (filter pre-cull + :func:`_merge_into_front`) hide under
  device compute.  Host memory stays O(chunk + front) for any grid
  size, and argmin/top-k/front are *exactly* the dense-path results.
* **Sharding** — with more than one device the chunk stream is split
  across devices via ``jax.pmap`` (one carry per device, merged once at
  the end), with the same prefetch pipeline, so kernel throughput scales
  with the device count.
* **Unified backend layer** — the chunk step is assembled by
  :mod:`repro.core.backend` from the same decode→evaluate→fold contract
  the dense engine runs: ``backend=`` picks the evaluation backend
  (``"xla"`` default; ``"pallas"`` fuses decode + Eq. 1-11 + block
  reductions into one ``pallas_call``, :mod:`repro.kernels.sweep_grid`)
  and ``scan_chunks=`` fuses K chunk folds per device dispatch via
  ``lax.scan`` — cutting per-step dispatch overhead at 10⁷–10⁸ configs
  with bitwise-identical results.
* **Fault tolerance** — the executor is resumable and self-healing:
  ``checkpoint_dir=`` periodically snapshots the merged running carry,
  the exact Pareto-front buffer and the next flat-index cursor through
  :class:`repro.checkpoint.CheckpointManager` (atomic tmp-dir +
  rename), keyed by a content hash of the sweep specification
  (:func:`repro.core.backend.job_signature`) so a stale snapshot from a
  different spec is rejected loudly; a re-run with the same arguments
  resumes from the newest snapshot with **bitwise-identical** results.
  ``retry_policy=`` bounds in-place retries of transiently failed chunk
  dispatches and full pipeline restarts from the last snapshot; on the
  pmap path a dead device shard triggers an elastic replan
  (:func:`repro.runtime.elastic.drop_worker`) that re-issues only the
  unfinished chunk ranges on the survivors, degrading gracefully to
  single-device execution.  ``fault_injector=``
  (:class:`repro.runtime.fault_injection.FaultInjector`) exercises
  every one of those recovery paths deterministically in CI.
* **Cooperative cancellation & partial snapshots** — ``should_stop=``
  is polled between chunk dispatches (so deadlines and client cancels
  take effect within one chunk); when it fires, the executor folds
  everything already dispatched and returns the consistent prefix
  snapshot as a ``partial=True`` result (``fraction_complete`` in
  ``stats``), still checkpointed for later resume.  This — plus
  :func:`plan_stream`, which splits the reusable job definition
  (:class:`StreamPlan`) out of the executor so compiled chunk steps
  stay cached across calls — is the contract the persistent sweep
  service (:mod:`repro.core.service`) is built on.
* **Batched workload axis** — ``models=`` stacks architecture variants
  (see :func:`repro.core.arrays.stacked_model_arrays`) into a leading
  grid axis evaluated inside the same kernel, for SplitNets-style
  architecture × partition co-design sweeps.

The dense path remains the right tool for small grids where the full
per-channel arrays are wanted (heatmaps, reporting); the two paths are
pinned exactly equal — argmin, top-k, and Pareto front, with and without
constraints, across prefetch depths — by ``tests/test_stream.py`` and
the ``benchmarks/run.py --smoke`` CI gate.
"""

from __future__ import annotations

import dataclasses
import math
import threading
import time
from collections import OrderedDict
from queue import Empty as _Empty
from queue import Full as _Full
from queue import Queue as _Queue
from typing import Iterable, Mapping, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from ..checkpoint import CheckpointManager
from ..runtime.elastic import drop_worker
from ..runtime.fault_injection import DeviceLostError, TransientDeviceError
from ..runtime.fault_tolerance import RetryPolicy, StragglerDetector
from . import arrays as A
from . import backend as B
from . import pareto as P
from . import sweep as SW
from .constants import (CAMERA_FPS, DETNET_FPS, KEYNET_FPS, NUM_CAMERAS,
                        TechNode)
from .workloads import NNWorkload

#: Default flat-index chunk evaluated per device per step.  The executor
#: clamps the chunk to the (quantized) grid size so small grids never pay
#: for padded lanes.  2¹⁷ keeps the chunk's working set inside CPU
#: caches — the same fused step runs ~1.6× more configs/s than at 2¹⁸
#: (measured), and the finer chunking pipelines better.
DEFAULT_CHUNK = 1 << 17

#: Default number of chunks kept in flight ahead of the host merges.
DEFAULT_PREFETCH = 2

_FILTER_ROWS = 24      # explicit front rows in the dominance pre-filter
_FILTER_BINS = 256     # quantile bins of the prefix-min dominance table
_SURVIVOR_CAP = 16384  # per-chunk compacted-survivor capacity
_PROBE = 4096          # strided probe (front seed + histogram ranges)
_MERGE_EVERY = 4096    # candidate-buffer size that triggers an exact merge
_CHUNK_QUANTUM = 4096  # chunk sizes are clamped to multiples of this
_SCAN_MAX = 8          # auto scan fusion: at most this many chunks/dispatch
_SCAN_PER = 16         # ... one fused chunk per this many raw steps

#: Default seconds between checkpoint snapshots when ``checkpoint_dir``
#: is set (wall-clock cadence; ``checkpoint_every_steps`` overrides it
#: with a deterministic step-count cadence).
DEFAULT_CHECKPOINT_EVERY_S = 30.0

#: Failures that trigger a pipeline restart from the last consistent
#: snapshot (vs the in-place retry of pre-dispatch transient faults and
#: the elastic replan of device loss).
try:
    _RESTARTABLE: tuple = (TransientDeviceError, jax.errors.JaxRuntimeError)
except AttributeError:  # pragma: no cover - jax without jax.errors
    _RESTARTABLE = (TransientDeviceError,)


# ---------------------------------------------------------------------------
# Result container
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StreamResult:
    """Reductions of one streamed sweep (never the dense grid itself).

    Holds O(front + k + axes) state: per-channel argmin winners, top-k
    tables for the tracked objectives, feasibility counts, channel
    bounds, optional histograms, and the exact Pareto front.  ``axes``
    matches :class:`~repro.core.sweep.SweepResult` (including the
    optional leading ``model`` axis), and flat indices are
    interchangeable with the dense path, so :meth:`config_at` decodes
    identically.  When the sweep ran with ``constraints=``, every
    reduction is over the *feasible* subset only.
    """

    axes: "OrderedDict[str, tuple]"
    objectives: tuple[str, ...]
    maximize: tuple[str, ...]
    chunk_size: int
    n_devices: int

    min_val: Mapping[str, float]          # per tracked channel: lowest value
    min_idx: Mapping[str, int]            # ... and its flat index
    finite_counts: Mapping[str, int]      # feasible-config counts (exact)
    channel_min: Mapping[str, float]      # feasible min / max per channel
    channel_max: Mapping[str, float]
    #: Valid-config counts per axis value from the strided probe pass —
    #: diagnostics for the all-invalid error messages, not exact tallies.
    axis_valid: "OrderedDict[str, np.ndarray]"

    topk_idx: np.ndarray                  # (n_objectives, k) flat indices
    topk_val: np.ndarray                  # natural-orientation values

    front_indices: np.ndarray             # (f,) flat indices, exact front
    front_values: np.ndarray              # (f, d) natural-orientation values

    hist: Optional[Mapping[str, tuple[np.ndarray, np.ndarray]]]
    stats: Mapping[str, float]
    #: Canonical ``(field, op, bound)`` predicates compiled into the chunk
    #: step (empty when the sweep was unconstrained).
    constraints: tuple[tuple[str, str, float], ...] = ()
    #: ``True`` when the stream halted early (a ``should_stop=`` hook —
    #: deadline or client cancel — fired before the grid was exhausted):
    #: every reduction is then exact over the contiguous flat-index
    #: prefix ``[0, stats["fraction_complete"] * n_configs)`` — the same
    #: consistent snapshot a checkpoint would persist — never a torn or
    #: interleaved subset.
    partial: bool = False

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(len(v) for v in self.axes.values())

    @property
    def n_configs(self) -> int:
        return int(np.prod(self.shape))

    def config_at(self, flat_index: int) -> dict:
        """Axis values of one flat grid index (the shared
        :func:`~repro.core.sweep.config_from_flat` decode — identical to
        the dense ``SweepResult.config_at``)."""
        return SW.config_from_flat(self.shape, self.axes, flat_index)

    def _invalid_notes(self) -> list[str]:
        return [f"{name}={vals[i]!r}"
                for (name, vals), counts in zip(self.axes.items(),
                                                self.axis_valid.values())
                for i in np.flatnonzero(counts == 0)]

    def _all_invalid_error(self, field: str) -> ValueError:
        if self.constraints:
            preds = ", ".join(f"{f} {op} {v:g}"
                              for f, op, v in self.constraints)
            return ValueError(
                f"no grid configuration is feasible in channel {field!r} "
                f"under constraints ({preds}) — loosen the constraints or "
                f"widen the grid axes")
        return ValueError(SW.invalid_message(field, self._invalid_notes()))

    def argmin(self, field: str = "avg_power") -> dict:
        """Best (lowest-``field``) feasible configuration.

        Exactly the dense-path ``SweepResult.argmin``: the *first*
        minimum wins, i.e. ties break toward the lower flat grid index
        (matching ``np.nanargmin`` on the dense channel array).  Raises
        :class:`ValueError` when every configuration is invalid
        (all-NaN) in ``field`` — naming the fully-invalid axis values —
        or, under ``constraints=``, when no configuration is feasible.
        """
        if field not in self.min_val:
            raise ValueError(
                f"channel {field!r} was not tracked; this stream reduced "
                f"{sorted(self.min_val)} — re-run stream_grid with "
                f"track=({field!r},) or track='all'")
        if self.finite_counts[field] == 0:
            raise self._all_invalid_error(field)
        out = self.config_at(self.min_idx[field])
        out[field] = self.min_val[field]
        return out

    def top_k(self, field: str) -> list[dict]:
        """The k best feasible configurations of one tracked objective,
        best first (k was fixed at :func:`stream_grid` time).

        Tie-breaking matches the dense ``SweepResult.top_k`` exactly:
        equal objective values order by ascending flat grid index (a
        stable sort over (value, flat index)).  Invalid (NaN) and
        constraint-infeasible configurations never appear; fewer than k
        entries come back when the feasible set is smaller than k.
        """
        if field not in self.objectives:
            raise ValueError(f"top-k tracks only {self.objectives}; "
                             f"re-run stream_grid with {field!r} in "
                             f"objectives=")
        oi = self.objectives.index(field)
        out = []
        for flat, val in zip(self.topk_idx[oi], self.topk_val[oi]):
            if not np.isfinite(val):
                break
            cfg = self.config_at(int(flat))
            cfg[field] = float(val)
            out.append(cfg)
        return out

    def channel_bounds(self, field: str) -> tuple[float, float]:
        """(min, max) of the feasible entries of one channel (the
        protocol :meth:`repro.core.pareto.ParetoFront.hypervolume` prices
        against).  Raises :class:`ValueError` on all-invalid (or
        all-infeasible) channels, like :meth:`argmin`."""
        if self.finite_counts[field] == 0:
            raise self._all_invalid_error(field)
        return self.channel_min[field], self.channel_max[field]

    def pareto_front(self) -> P.ParetoFront:
        """The exact non-dominated set as a regular
        :class:`~repro.core.pareto.ParetoFront` (identical — indices and
        values — to ``pareto.pareto_front`` on the dense grid, post
        ``SweepResult.constrain`` when constraints were given)."""
        sign0 = -1.0 if self.objectives[0] in self.maximize else 1.0
        order = np.argsort(self.front_values[:, 0] * sign0, kind="stable")
        return P.ParetoFront(
            result=self, objectives=self.objectives, maximize=self.maximize,
            indices=self.front_indices[order],
            values=self.front_values[order])


# ---------------------------------------------------------------------------
# Exact JSON codec + progress snapshots (the wire format of the
# networked service)
# ---------------------------------------------------------------------------


def _jsonable_scalar(v):
    return v.item() if isinstance(v, np.generic) else v


def result_to_json(res: StreamResult) -> dict:
    """Exact JSON-able encoding of a :class:`StreamResult`.

    Floats round-trip bitwise (Python's ``repr`` emits the shortest
    exact decimal, and non-finite values use the Python-extended JSON
    ``NaN``/``Infinity`` tokens), integer index tables stay int64 —
    :func:`result_from_json` reconstructs a result whose every
    reduction compares bitwise-equal to the original.  This is both
    the service's journal format for finished requests and the
    transport's result frame payload.
    """
    hist = None
    if res.hist is not None:
        hist = {f: [c.tolist(), e.tolist()]
                for f, (c, e) in res.hist.items()}
    return {
        "schema": "stream-result/v1",
        "axes": [[k, [_jsonable_scalar(v) for v in vals]]
                 for k, vals in res.axes.items()],
        "objectives": list(res.objectives),
        "maximize": list(res.maximize),
        "chunk_size": int(res.chunk_size),
        "n_devices": int(res.n_devices),
        "min_val": {k: float(v) for k, v in res.min_val.items()},
        "min_idx": {k: int(v) for k, v in res.min_idx.items()},
        "finite_counts": {k: int(v)
                          for k, v in res.finite_counts.items()},
        "channel_min": {k: float(v) for k, v in res.channel_min.items()},
        "channel_max": {k: float(v) for k, v in res.channel_max.items()},
        "axis_valid": [[k, np.asarray(v).tolist()]
                       for k, v in res.axis_valid.items()],
        "topk_idx": res.topk_idx.tolist(),
        "topk_val": res.topk_val.tolist(),
        "front_indices": res.front_indices.tolist(),
        "front_values": res.front_values.tolist(),
        "hist": hist,
        "stats": {k: float(v) for k, v in res.stats.items()},
        "constraints": [list(c) for c in res.constraints],
        "partial": bool(res.partial),
    }


def result_from_json(d: Mapping) -> StreamResult:
    """Inverse of :func:`result_to_json` (bitwise-exact round-trip)."""
    n_obj = len(d["objectives"])
    hist = None
    if d.get("hist") is not None:
        hist = {f: (np.asarray(c), np.asarray(e, np.float64))
                for f, (c, e) in d["hist"].items()}
    front_v = np.asarray(d["front_values"], np.float64)
    return StreamResult(
        axes=OrderedDict((k, tuple(vals)) for k, vals in d["axes"]),
        objectives=tuple(d["objectives"]),
        maximize=tuple(d["maximize"]),
        chunk_size=int(d["chunk_size"]),
        n_devices=int(d["n_devices"]),
        min_val=dict(d["min_val"]),
        min_idx={k: int(v) for k, v in d["min_idx"].items()},
        finite_counts={k: int(v)
                       for k, v in d["finite_counts"].items()},
        channel_min=dict(d["channel_min"]),
        channel_max=dict(d["channel_max"]),
        axis_valid=OrderedDict((k, np.asarray(v))
                               for k, v in d["axis_valid"]),
        topk_idx=np.asarray(d["topk_idx"], np.int64).reshape(n_obj, -1),
        topk_val=np.asarray(d["topk_val"],
                            np.float64).reshape(n_obj, -1),
        front_indices=np.asarray(d["front_indices"], np.int64),
        front_values=front_v.reshape(-1, n_obj),
        hist=hist,
        stats=dict(d["stats"]),
        constraints=tuple((f, op, v) for f, op, v in d["constraints"]),
        partial=bool(d["partial"]),
    )


def _progress_snapshot(folded: int, n_total: int, front_vals, front_idx,
                       objectives, sign) -> dict:
    """JSON-able progress snapshot over the folded contiguous prefix
    ``[0, folded)``: fraction complete, running per-objective best
    (value + flat index, read off the running front — the single-
    objective optimum is always a non-dominated point) and front size.
    The running front is conservatively pre-filtered against probe
    witnesses from the whole grid, so a mid-run ``best`` can only be
    *pessimistic* relative to the prefix; the final result (and any
    cooperative-stop partial) is exact."""
    best = {}
    for oi, f in enumerate(objectives):
        if front_vals.shape[0]:
            j = int(np.argmin(front_vals[:, oi] * sign[oi]))
            best[f] = {"value": float(front_vals[j, oi]),
                       "index": int(front_idx[j])}
    return {"fraction_complete": (folded / n_total if n_total else 1.0),
            "front_size": int(front_vals.shape[0]),
            "partial": True,
            "best": best,
            # Running-front membership, rows sorted by flat index (the
            # merge invariant).  A flat index's objective vector never
            # changes, so the delta codec below can key front changes
            # purely by index (entrant / evict records).
            "front": {"i": [int(i) for i in front_idx],
                      "v": [[float(x) for x in row]
                            for row in front_vals]}}


def result_delta_to_json(prev: Optional[Mapping],
                         cur: Mapping) -> dict:
    """Per-chunk *delta* between two consecutive progress snapshots.

    The networked ``watch`` stream sends one full snapshot (the
    baseline) and then only deltas: changed top-level scalars, changed
    per-objective running-best records, and front entrant/evict
    records keyed by flat index (a config's objective vector is
    immutable, so membership changes are the whole story).  With
    ``prev=None`` the delta is the full snapshot.
    :func:`apply_result_delta` reconstructs ``cur`` exactly — the
    round trip is pinned value-equal in the tests, and the *final*
    result always travels through :func:`result_to_json`, so delta
    streaming can never touch result exactness.
    """
    if prev is None:
        return dict(cur)
    out: dict = {}
    for k in ("fraction_complete", "front_size", "partial"):
        if prev.get(k) != cur.get(k):
            out[k] = cur[k]
    pb, cb = prev.get("best", {}), cur.get("best", {})
    changed = {f: v for f, v in cb.items() if pb.get(f) != v}
    if changed:
        out["best"] = changed
    gone = [f for f in pb if f not in cb]
    if gone:
        out["best_del"] = gone
    pf = prev.get("front") or {"i": [], "v": []}
    cf = cur.get("front") or {"i": [], "v": []}
    pset = set(pf["i"])
    add_i = [i for i in cf["i"] if i not in pset]
    if add_i:
        vmap = dict(zip(cf["i"], cf["v"]))
        out["front_add"] = {"i": add_i, "v": [vmap[i] for i in add_i]}
    dels = sorted(pset - set(cf["i"]))
    if dels:
        out["front_del"] = dels
    return out


def apply_result_delta(prev: Optional[Mapping],
                       delta: Mapping) -> dict:
    """Inverse of :func:`result_delta_to_json`: fold one delta into the
    previous snapshot, reconstructing the full snapshot dict (rows
    re-sorted by flat index — the snapshot invariant)."""
    if prev is None:
        return dict(delta)
    cur = dict(prev)
    for k in ("fraction_complete", "front_size", "partial"):
        if k in delta:
            cur[k] = delta[k]
    best = dict(prev.get("best", {}))
    best.update(delta.get("best", {}))
    for f in delta.get("best_del", ()):
        best.pop(f, None)
    cur["best"] = best
    pf = prev.get("front") or {"i": [], "v": []}
    rows = dict(zip(pf["i"], pf["v"]))
    for i in delta.get("front_del", ()):
        rows.pop(i, None)
    add = delta.get("front_add")
    if add:
        rows.update(zip(add["i"], add["v"]))
    idx = sorted(rows)
    cur["front"] = {"i": idx, "v": [rows[i] for i in idx]}
    return cur


# ---------------------------------------------------------------------------
# Host-side exact merges
# ---------------------------------------------------------------------------


def _np_undominated(cand_sg: np.ndarray, wit_sg: np.ndarray) -> np.ndarray:
    """Candidates (signed ``(n, d)``) no witness row strictly dominates —
    the exact vectorized cull behind :func:`_merge_into_front`.  Built
    from per-column 2-D broadcasts (witness-blocked): a (n, w, d) 3-D
    broadcast materializes d× the temporaries and is several times
    slower at these shapes."""
    keep = np.ones(cand_sg.shape[0], bool)
    d = cand_sg.shape[1]
    for lo in range(0, wit_sg.shape[0], 512):
        blk = wit_sg[lo:lo + 512]
        le = blk[:, None, 0] <= cand_sg[None, :, 0]
        lt = blk[:, None, 0] < cand_sg[None, :, 0]
        for c in range(1, d):
            le &= blk[:, None, c] <= cand_sg[None, :, c]
            lt |= blk[:, None, c] < cand_sg[None, :, c]
        keep &= ~(le & lt).any(axis=0)
    return keep


def _merge_into_front(front_v, front_i, cat_v, cat_i, sign):
    """Exactly merge pre-filtered candidates into the running front.

    Equivalent to :func:`repro.core.pareto.merge_fronts` but exploits the
    invariant that ``front`` is already mutually non-dominated: entrants
    are culled against the front, then against each other, then surviving
    entrants evict any front member they dominate — three small
    vectorized passes instead of re-scanning the whole union.  Rows stay
    sorted by flat index, so tie order matches the dense path exactly.
    """
    if cat_v.shape[0] == 0:
        return front_v, front_i
    cat_sg = cat_v * sign
    if front_v.shape[0]:
        front_sg = front_v * sign
        keep_c = _np_undominated(cat_sg, front_sg)
        cat_v, cat_i, cat_sg = cat_v[keep_c], cat_i[keep_c], cat_sg[keep_c]
        if cat_v.shape[0] == 0:
            return front_v, front_i
        keep_c = P.non_dominated_mask(cat_sg)
        cat_v, cat_i, cat_sg = cat_v[keep_c], cat_i[keep_c], cat_sg[keep_c]
        keep_f = _np_undominated(front_sg, cat_sg)
        V = np.concatenate([front_v[keep_f], cat_v])
        I = np.concatenate([front_i[keep_f], cat_i])
    else:
        keep = P.non_dominated_mask(cat_sg)
        V, I = cat_v[keep], cat_i[keep]
    order = np.argsort(I, kind="stable")
    return V[order], I[order]


def _probe(S, axis_vals, shape, n_total, obj_fields, sign, cons, hist_bins,
           hist_ranges):
    """Strided sample pass: seeds the front filter, histogram ranges and
    the per-axis-value validity diagnostics.

    The probe points are ordinary grid points evaluated through the same
    compiled kernel; they only ever *pre-filter* (the exact front is built
    solely from chunk survivors), so correctness never depends on probe
    coverage.  Constraint predicates mask the probe exactly like the
    chunk step, so an infeasible probe point can never cull a feasible
    candidate.  The seed rows are *not* reduced to their own front — a
    dominated evaluated point is still an exact dominance witness, and
    the quantile/prefix-min filter build only gets tighter with more
    rows.
    """
    m = int(min(_PROBE, max(256, n_total // 128), n_total))
    flat = np.unique(np.linspace(0, n_total - 1, m).astype(np.int64))
    fields = obj_fields + tuple(f for f, _, _ in cons
                                if f not in obj_fields)
    out = B.cached_dense_eval("xla", S, shape, fields)(
        tuple(map(jnp.asarray, axis_vals)), jnp.asarray(flat))
    O = np.stack([np.asarray(out[f]) for f in obj_fields], axis=1)
    coords = SW.decode_flat_index(shape, flat)
    feas = np.ones(flat.size, bool)
    with np.errstate(invalid="ignore"):
        for f, op, v in cons:
            feas &= SW.CONSTRAINT_OPS[op](np.asarray(out[f]), v)
    fin = np.isfinite(O).all(axis=1) & feas
    axis_valid = tuple(np.bincount(c[fin], minlength=sz)
                       for c, sz in zip(coords, shape))
    seed = O[fin] * sign
    if seed.shape[0]:
        # The probe runs through the dense jit while chunks run through
        # the step jit; the two lowerings can disagree in the last ulp.
        # Pad the seed rows outward so a probe twin of a front point can
        # never strictly dominate (and wrongly cull) its chunk-evaluated
        # copy — the filter stays conservative, the host merge is exact.
        seed = seed + (1e-9 * np.abs(seed) + 1e-300)

    edges = None
    if hist_bins:
        edges = np.empty((len(obj_fields), hist_bins + 1))
        for oi, f in enumerate(obj_fields):
            if hist_ranges is not None and f in hist_ranges:
                lo, hi = map(float, hist_ranges[f])
            else:
                col = O[:, oi][np.isfinite(O[:, oi])]
                if col.size == 0:
                    lo, hi = 0.0, 1.0
                else:
                    lo, hi = float(col.min()), float(col.max())
                    pad = 0.05 * ((hi - lo) or max(abs(lo), 1.0))
                    lo, hi = lo - pad, hi + pad
            if hi <= lo:
                hi = lo + 1.0
            edges[oi] = np.linspace(lo, hi, hist_bins + 1)
    return seed, edges, axis_valid


def _resume_into(mgr: CheckpointManager, signature: str, state: dict,
                 counters: dict, chunk: int) -> None:
    """Restore the newest valid snapshot of ``mgr`` into ``state``.

    Snapshots are tried newest-first; one whose manifest is unreadable
    (truncated by a foreign writer — the atomic rename means our own
    crashes can only leave ``.tmp`` debris) falls back to the next
    older.  A snapshot recorded under a *different* job signature is a
    hard error: silently merging carry state across specifications
    would corrupt every deliverable, so stale checkpoints must fail
    loudly.
    """
    for step in reversed(mgr.all_steps()):
        try:
            meta = mgr.metadata(step)
        except (OSError, ValueError, KeyError):
            continue
        saved = meta.get("signature") if isinstance(meta, dict) else None
        if saved != signature:
            raise ValueError(
                f"checkpoint directory {mgr.root!r} (step {step}) was "
                f"written by a different sweep job (signature "
                f"{str(saved)[:12]}... != {signature[:12]}...): refusing "
                f"to resume, a stale snapshot must never merge into a "
                f"new sweep.  The signature covers the model stack, "
                f"axes, objectives/tracked fields, constraints, top_k, "
                f"histogram spec, backend, chunk size and scan fusion "
                f"(chunk and scan_chunks auto-derive from the device "
                f"count unless passed explicitly).  Point "
                f"checkpoint_dir at a fresh directory or delete the "
                f"stale checkpoints.")
        items = mgr.restore_items(step)
        state["carry"] = {kk.split("/", 1)[1]: v
                          for kk, v in items.items()
                          if kk.startswith("carry/")}
        state["front_vals"] = np.asarray(items["front_values"],
                                         np.float64)
        state["front_idx"] = np.asarray(items["front_indices"], np.int64)
        state["base"] = int(meta["next_flat"])
        for kk, v in (meta.get("counters") or {}).items():
            if kk in counters:
                counters[kk] = float(v)
        counters["resumed_from_step"] = float(state["base"] // chunk)
        return


# ---------------------------------------------------------------------------
# Plan: the resolved job definition (reusable across runs)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)
class StreamPlan:
    """Resolved execution plan of one streamed sweep.

    Everything that *defines* the job — model stack, axes, tracked
    fields, constraints, chunk/scan geometry, device pool, the
    :class:`~repro.core.backend.ChunkSpec` and its content
    ``signature`` — split out of :func:`stream_grid` so a long-lived
    process (:mod:`repro.core.service`) can build it once per distinct
    job and reuse it across requests.  ``ChunkSpec`` hashes its model
    stack by identity, so re-running with the *same plan object* is
    what makes :func:`repro.core.backend.cached_step` return the
    already-compiled chunk step instead of re-tracing.  Build with
    :func:`plan_stream`, execute with ``stream_grid(plan=...)``
    (runtime knobs — prefetch, checkpointing, retry policy, hooks —
    stay per-call and do not affect the plan or its signature).
    """

    S: object                       # struct-of-arrays model stack
    axis_vals: tuple                # per-axis value arrays (grid order)
    axes: "OrderedDict[str, tuple]"
    shape: tuple
    n_total: int
    kfields: tuple
    objectives: tuple
    maximize: tuple
    fields: tuple                   # objectives + tracked + constrained
    cons: tuple                     # canonical (field, op, bound)
    sign: tuple                     # +1 minimize / -1 maximize per obj
    d: int
    k: int
    chunk: int
    scan: int
    backend: str
    dev_list: tuple
    explicit_devices: bool
    hist_bins: int
    hist_ranges: Optional[Mapping]
    spec: B.ChunkSpec
    #: Content hash of the job (:func:`repro.core.backend.job_signature`)
    #: — the checkpoint/resume key and the service plan-cache key.
    signature: str


def plan_stream(cuts: Optional[Iterable[int]] = None,
                agg_nodes: Sequence[str | TechNode] = ("7nm",),
                sensor_nodes: Sequence[str | TechNode] = ("7nm",),
                weight_mems: Sequence[str] = ("sram",),
                detnet_fps: Sequence[float] = (DETNET_FPS,),
                keynet_fps: Sequence[float] = (KEYNET_FPS,),
                num_cameras: Sequence[float] = (NUM_CAMERAS,),
                mipi_energy_scale: Sequence[float] = (1.0,),
                camera_fps: Sequence[float] = (CAMERA_FPS,),
                detnet: NNWorkload | None = None,
                keynet: NNWorkload | None = None,
                model: A.ModelArrays | None = None,
                models=None,
                scenarios=None,
                chunk_size: int = DEFAULT_CHUNK,
                top_k: int = 4,
                objectives: Sequence[str] = P.DEFAULT_OBJECTIVES,
                maximize: Iterable[str] = (),
                track: Optional[Sequence[str]] = None,
                constraints=None,
                hist_bins: int = 0,
                hist_ranges: Optional[Mapping] = None,
                devices: Optional[Sequence] = None,
                backend: Optional[str] = None,
                scan_chunks: Optional[int] = None) -> StreamPlan:
    """Resolve a :func:`stream_grid` job definition into a reusable
    :class:`StreamPlan` (axes → :class:`~repro.core.backend.ChunkSpec`
    → content signature) without running anything.

    Identical argument semantics to :func:`stream_grid` (which calls
    this when no ``plan=`` is passed), so ``plan_stream(**kw)`` /
    ``stream_grid(plan=plan)`` splits the cheap spec resolution from
    the execution — the split the sweep service uses to key its plan
    LRU by ``plan.signature`` and keep compiled chunk steps hot across
    requests.
    """
    S, axis_vals, axes = SW.build_axes(
        cuts, agg_nodes, sensor_nodes, weight_mems, detnet_fps, keynet_fps,
        num_cameras, mipi_energy_scale, camera_fps, detnet, keynet, model,
        models, scenarios)
    full_shape = tuple(a.size for a in axis_vals)
    n_total = int(np.prod(full_shape))
    kfields = SW.kernel_fields(S)

    objectives = tuple(objectives)
    maximize = tuple(maximize)
    if not objectives:
        raise ValueError("need at least one objective channel")
    if track == "all":
        extra: tuple = kfields
    else:
        extra = tuple(track) if track is not None else ()
    cons = SW.parse_constraints(constraints)
    extra = extra + tuple(f for f, _, _ in cons)
    fields = objectives + tuple(dict.fromkeys(
        f for f in extra if f not in objectives))
    unknown = [o for o in fields if o not in kfields]
    if unknown:
        hint = (" — session channels require scenarios="
                if any(o in SW.SCENARIO_FIELDS for o in unknown) else "")
        raise ValueError(f"unknown objective channels {unknown}; this "
                         f"sweep evaluates {kfields}{hint}")
    stray = [o for o in maximize if o not in objectives]
    if stray:
        raise ValueError(f"maximize entries {stray} not in objectives")
    sign = np.where([o in maximize for o in objectives], -1.0, 1.0)
    d = len(objectives)
    cons_static = tuple((fields.index(f), op) for f, op, _ in cons)

    be = B.get_backend(backend)          # fail fast on unknown backends
    dev_list = list(devices) if devices is not None else jax.local_devices()
    if devices is None and len(dev_list) > 1 and not be.supports_pmap:
        # Auto-derived device lists must not crash a non-pmap backend —
        # fall back to one device; an *explicit* multi-device devices=
        # still raises clearly in backend.build_step.
        dev_list = dev_list[:1]
    n_dev = max(1, len(dev_list))
    k = max(1, min(int(top_k), n_total))
    # Clamp the chunk to the quantized per-device need: a 10⁵-config grid
    # must not pay for a 2.6×-padded 2¹⁸ chunk, and quantizing keeps the
    # compiled-step cache hot across nearby grid sizes.
    chunk = max(1, int(chunk_size), k)
    per_dev = -(-n_total // n_dev)
    chunk = min(chunk, -(-per_dev // _CHUNK_QUANTUM) * _CHUNK_QUANTUM)
    cap = min(_SURVIVOR_CAP, chunk)
    # Scan fusion: fold K consecutive chunks per device dispatch
    # (lax.scan threads the carry), so per-step dispatch overhead is
    # paid once per K chunks.  Auto mode scales K with the raw step
    # count — small grids keep K=1 (nothing to amortize, and the filter
    # refresh cadence stays fine-grained).
    raw_steps = -(-per_dev // chunk)
    if scan_chunks is None:
        scan = max(1, min(_SCAN_MAX, raw_steps // _SCAN_PER))
    else:
        scan = max(1, int(scan_chunks))
    scan = min(scan, raw_steps)
    per_step = chunk * scan * n_dev

    spec = B.ChunkSpec(
        S=S, shape=full_shape, n_total=n_total, chunk=chunk,
        fields=fields, d=d, k=k, sign=tuple(sign),
        cons_static=cons_static, hist_bins=hist_bins,
        survivor_cap=cap,
        small_index=n_total + per_step < 2**31,
        filter_rows=_FILTER_ROWS, filter_bins=_FILTER_BINS)
    signature = B.job_signature(spec, be.name, scan, cons, axis_vals,
                                hist_ranges)
    return StreamPlan(
        S=S, axis_vals=tuple(axis_vals), axes=axes, shape=full_shape,
        n_total=n_total, kfields=kfields, objectives=objectives,
        maximize=maximize, fields=fields, cons=cons, sign=tuple(sign),
        d=d, k=k, chunk=chunk, scan=scan, backend=be.name,
        dev_list=tuple(dev_list), explicit_devices=devices is not None,
        hist_bins=hist_bins, hist_ranges=hist_ranges, spec=spec,
        signature=signature)


# ---------------------------------------------------------------------------
# The executor
# ---------------------------------------------------------------------------


def stream_grid(cuts: Optional[Iterable[int]] = None,
                agg_nodes: Sequence[str | TechNode] = ("7nm",),
                sensor_nodes: Sequence[str | TechNode] = ("7nm",),
                weight_mems: Sequence[str] = ("sram",),
                detnet_fps: Sequence[float] = (DETNET_FPS,),
                keynet_fps: Sequence[float] = (KEYNET_FPS,),
                num_cameras: Sequence[float] = (NUM_CAMERAS,),
                mipi_energy_scale: Sequence[float] = (1.0,),
                camera_fps: Sequence[float] = (CAMERA_FPS,),
                detnet: NNWorkload | None = None,
                keynet: NNWorkload | None = None,
                model: A.ModelArrays | None = None,
                models=None,
                scenarios=None,
                chunk_size: int = DEFAULT_CHUNK,
                top_k: int = 4,
                objectives: Sequence[str] = P.DEFAULT_OBJECTIVES,
                maximize: Iterable[str] = (),
                track: Optional[Sequence[str]] = None,
                constraints=None,
                prefetch: int = DEFAULT_PREFETCH,
                hist_bins: int = 0,
                hist_ranges: Optional[Mapping] = None,
                devices: Optional[Sequence] = None,
                backend: Optional[str] = None,
                scan_chunks: Optional[int] = None,
                checkpoint_dir: Optional[str] = None,
                checkpoint_every_s: float = DEFAULT_CHECKPOINT_EVERY_S,
                checkpoint_every_steps: Optional[int] = None,
                checkpoint_keep: int = 3,
                retry_policy: Optional[RetryPolicy] = None,
                fault_injector=None,
                plan: Optional[StreamPlan] = None,
                flat_range: Optional[tuple] = None,
                should_stop=None,
                on_progress=None,
                on_snapshot=None,
                snapshot_every_s: float = 0.5) -> StreamResult:
    """Stream Eqs. 1-11 over an arbitrarily large cartesian grid.

    Same axes (and ``models=`` workload batch) as
    :func:`repro.core.sweep.evaluate_grid`, but the grid is never
    materialized: flat indices are decoded to coordinates on-device in
    ``chunk_size`` pieces (per device) and folded into running
    reductions, so host memory is O(chunk + front) for any grid size.
    Argmin, top-k and Pareto front are *exactly* the dense-path results.

    ``scenarios=`` (a :class:`repro.core.scenario.ScenarioSet` or
    profile name(s)) appends a trailing ``trace`` axis and drives every
    (config × trace) pair through the session simulator inside the same
    chunk contract; the four session channels
    (:data:`repro.core.sweep.SCENARIO_FIELDS` — e.g.
    ``time_to_empty_s``, usually with ``maximize=("time_to_empty_s",)``,
    and ``peak_case_temp_c``) then work as objectives, constraints and
    tracked channels exactly like the static fields.

    ``objectives``/``maximize`` select the channels tracked by top-k and
    the incremental Pareto front.  ``track`` adds further channels to the
    argmin/count/bounds reductions (or ``"all"`` for every kernel field)
    — untracked channels are dead-code-eliminated from the compiled step,
    so track only what you need.  ``constraints`` compiles feasibility
    predicates (:func:`repro.core.sweep.parse_constraints` — e.g.
    ``{"latency": budget}``, ``{"mipi_bytes_per_s": ("<=", link_cap)}``
    or ``("latency <= 1e-3",)``) into the chunk step: infeasible
    configurations are masked before any reduction, matching a dense
    ``SweepResult.constrain`` post-filter exactly; constrained channels
    are tracked automatically.  ``prefetch`` keeps that many chunks in
    flight ahead of the host merges (0 = fully synchronous) so merge
    work overlaps device compute.  ``hist_bins`` adds per-objective
    histograms (ranges from ``hist_ranges`` or a strided probe pass, with
    out-of-range values clamped into the end bins).  ``devices`` shards
    the chunk stream across multiple JAX devices via ``pmap``.

    ``backend`` selects the evaluation backend of the chunk step
    (:func:`repro.core.backend.get_backend`; ``None`` -> ``"xla"``,
    ``"pallas"`` fuses decode + Eq. 1-11 + block reductions into the
    Pallas grid kernel of :mod:`repro.kernels.sweep_grid`).
    ``scan_chunks`` fuses that many consecutive chunk folds into one
    device dispatch via ``lax.scan``, cutting per-chunk dispatch
    overhead on 10^7+-config spaces (``None`` auto-scales with the step
    count; 1 disables).  Both knobs are bitwise result-preserving —
    every backend and every scan depth reproduces the dense-path
    argmin/top-k/front exactly (the parity matrix of
    ``tests/test_backend.py``).

    ``checkpoint_dir`` makes the sweep resumable: the executor
    snapshots its consistent state (merged carry + exact front + next
    flat index) there every ``checkpoint_every_s`` seconds — or every
    ``checkpoint_every_steps`` dispatch steps when given, which is
    deterministic — keeping the ``checkpoint_keep`` newest snapshots,
    and a later call with the *same specification* resumes from the
    newest snapshot with bitwise-identical deliverables (a different
    specification is rejected with :class:`ValueError`).
    ``retry_policy`` (default :class:`repro.runtime.fault_tolerance.
    RetryPolicy`) bounds the recovery machinery: in-place retries of
    transient pre-dispatch faults, pipeline restarts from the last
    snapshot, straggler/timeout accounting.  ``fault_injector`` is a
    test hook called as ``injector(chunk_ordinal, flat_start)`` before
    every dispatch (see :mod:`repro.runtime.fault_injection`).
    Resilience counters land in ``StreamResult.stats``: ``retries``,
    ``restarts``, ``resumed_from_step``, ``checkpoints_written``,
    ``checkpoint_write_s``, ``chunks_reissued``, ``elastic_replans``,
    ``stragglers`` and ``step_timeouts``.

    ``plan`` short-circuits the spec resolution with a prebuilt
    :class:`StreamPlan` (see :func:`plan_stream`) — when given, the
    axis/objective/backend arguments above are ignored in its favor;
    a long-lived process reusing one plan object across calls is what
    keeps the compiled chunk step cached.  ``flat_range=(start, stop)``
    restricts the sweep to one contiguous slice of the flat-index
    space — the unit of work a multi-process worker pool leases
    (:mod:`repro.runtime.workers`): every reduction is exact over
    ``[start, stop)``, the carry keeps the device-count-independent
    serialization form, and :func:`merge_results` folds the per-range
    results of a full tiling back into the bitwise single-process
    answer.  Because the compiled step masks lanes only against the
    *grid* end, ``stop`` must land on a dispatch boundary
    (``(stop - start) % (chunk * scan_chunks * n_devices) == 0``)
    unless it is the grid end itself — pin ``chunk_size`` and
    ``scan_chunks`` explicitly when carving ranges.  ``stats`` gains
    ``range_start``/``range_stop``, and ``fraction_complete`` (and
    progress snapshots) are relative to the range.  ``should_stop`` is a
    zero-argument callable polled before every chunk dispatch (on the
    producer thread in the pipelined path): when it returns truthy the
    executor stops issuing work within one chunk, folds everything
    already dispatched and returns the consistent prefix snapshot as a
    ``partial=True`` result (``stats["fraction_complete"]`` < 1) — and
    still writes a terminal checkpoint when ``checkpoint_dir`` is set,
    so a later call resumes where the stop landed.  ``on_progress`` is
    called after each dispatch with the fraction of the grid issued so
    far (also from the producer thread; keep it cheap and
    thread-safe) — and only after any checkpoint due for that step is
    durably on disk, so every observed fraction is resumable: a kill
    right after a progress event never restarts from before it.
    ``on_snapshot`` is called (from the consumer thread, at most every
    ``snapshot_every_s`` seconds) with a JSON-able consistent progress
    summary over the folded contiguous prefix — ``fraction_complete``,
    running per-objective best and front size (see
    :func:`_progress_snapshot`) — the payload the networked service
    streams to subscribed clients; snapshots obey the same durability
    ordering (a step with a checkpoint due emits its snapshot only
    after the checkpoint is on disk).
    """
    if plan is None:
        plan = plan_stream(
            cuts, agg_nodes, sensor_nodes, weight_mems, detnet_fps,
            keynet_fps, num_cameras, mipi_energy_scale, camera_fps,
            detnet, keynet, model, models, scenarios,
            chunk_size=chunk_size, top_k=top_k, objectives=objectives,
            maximize=maximize, track=track, constraints=constraints,
            hist_bins=hist_bins, hist_ranges=hist_ranges, devices=devices,
            backend=backend, scan_chunks=scan_chunks)
    S = plan.S
    axis_vals = list(plan.axis_vals)
    axes = plan.axes
    full_shape = plan.shape
    n_total = plan.n_total
    kfields = plan.kfields
    objectives = plan.objectives
    maximize = plan.maximize
    fields = plan.fields
    cons = plan.cons
    sign = np.asarray(plan.sign)
    d = plan.d
    k = plan.k
    chunk = plan.chunk
    scan = plan.scan
    spec = plan.spec
    hist_bins = plan.hist_bins
    hist_ranges = plan.hist_ranges
    cap = spec.survivor_cap
    dev_list = list(plan.dev_list)
    n_dev = max(1, len(dev_list))
    per_step = chunk * scan * n_dev
    if flat_range is None:
        start0, stop0 = 0, n_total
    else:
        start0, stop0 = int(flat_range[0]), int(flat_range[1])
        if not 0 <= start0 < stop0 <= n_total:
            raise ValueError(
                f"flat_range {flat_range} outside the grid "
                f"[0, {n_total})")
        if stop0 != n_total and (stop0 - start0) % per_step:
            # The compiled step masks lanes only against the grid end
            # (flat < n_total), so an interior stop must land on a
            # dispatch boundary or the last dispatch would fold lanes
            # belonging to the next range.
            raise ValueError(
                f"flat_range length {stop0 - start0} is not a multiple "
                f"of the dispatch quantum {per_step} (chunk {chunk} x "
                f"scan {scan} x {n_dev} device(s)) and stop != n_total "
                f"({n_total}): pass chunk_size/scan_chunks explicitly "
                f"and carve ranges on dispatch boundaries")
    span = stop0 - start0
    n_steps = math.ceil(span / per_step)
    prefetch = max(0, int(prefetch))

    t0 = time.perf_counter()
    policy = retry_policy if retry_policy is not None else RetryPolicy()
    counters = {
        "retries": 0.0, "restarts": 0.0, "resumed_from_step": 0.0,
        "checkpoint_write_s": 0.0, "checkpoints_written": 0.0,
        "chunks_reissued": 0.0, "elastic_replans": 0.0,
        "stragglers": 0.0, "step_timeouts": 0.0,
    }
    with enable_x64():
        seed_signed, hist_edges, axis_valid = _probe(
            S, axis_vals, full_shape, n_total, objectives, sign, cons,
            hist_bins, hist_ranges)

        # The consistent snapshot all recovery pivots on: the merged
        # (device-count-independent) host carry, the exact running
        # front, and the next flat-index cursor — every chunk below
        # ``base`` is folded in, nothing above it is.  Restarts,
        # elastic replans and cross-process resumes all rebuild the
        # pipeline from here.  chunk and scan were derived above from
        # the *full* grid geometry (never the remaining work), so a
        # resumed run recreates the identical ChunkSpec and signature.
        state = {"carry": B.init_carry(spec),
                 "front_vals": np.empty((0, d)),
                 "front_idx": np.empty((0,), np.int64),
                 "base": start0}
        mgr = None
        signature = ""
        if checkpoint_dir is not None:
            mgr = CheckpointManager(checkpoint_dir,
                                    keep=max(1, int(checkpoint_keep)))
            # Ranged runs suffix the signature so a lease's checkpoint
            # can never restore into a different range of the same job.
            signature = (plan.signature if flat_range is None else
                         f"{plan.signature}:r{start0}-{stop0}")
            _resume_into(mgr, signature, state, counters, chunk)

        def write_checkpoint():
            tw = time.perf_counter()
            mgr.save(int(state["base"]),
                     {"carry": state["carry"],
                      "front_values": state["front_vals"],
                      "front_indices": state["front_idx"]},
                     metadata={"signature": signature,
                               "next_flat": int(state["base"]),
                               "counters": dict(counters),
                               "format": B.CARRY_VERSION})
            counters["checkpoint_write_s"] += time.perf_counter() - tw
            counters["checkpoints_written"] += 1.0

        # Pre-cull the probe seed toward its near-front subset once: the
        # filter build draws quantile bins and spread rows from the rows
        # it is given, and a mostly-dominated cloud drags both toward the
        # data mass instead of the front envelope (culls measurably
        # worse).  Filter-based culling is exact, so this is quality-only.
        if seed_signed.shape[0] > 4 * _FILTER_ROWS:
            f0 = P.build_dominance_filter(seed_signed, d, _FILTER_ROWS,
                                          _FILTER_BINS)
            seed_signed = seed_signed[P.dominance_filter_mask(
                f0, np.ascontiguousarray(seed_signed.T), xp=np)]
        t_first = None
        t_wait = 0.0
        t_host = 0.0
        t_dispatch = 0.0
        n_fallback = 0
        detector = StragglerDetector(policy.straggler_factor,
                                     policy.straggler_window)
        dispatched_flat = state["base"]     # dispatch high-water mark
        # Cooperative halt: set when should_stop fires between
        # dispatches; the incarnation then finalizes over exactly the
        # chunks already issued (all of which the consumer folds before
        # the pipeline winds down) instead of the full grid.
        ctl = {"halted": False}
        # Progress-snapshot throttle (consumer-thread clock), shared
        # across pipeline incarnations so restarts don't burst emits.
        snap_t = {"last": time.perf_counter()}

        def drive():
            # One incarnation of the pipeline: rebuild the compiled
            # step, device placement and filter for the *current*
            # device pool, restore carry + front from the snapshot, run
            # every remaining chunk, then advance the snapshot to
            # completion.  Raises on device loss / exhausted retries;
            # the control loop below decides replan vs restart.
            nonlocal t_first, t_wait, t_host, t_dispatch, n_fallback
            nonlocal dispatched_flat
            base = state["base"]
            if base >= stop0:       # resumed-from-complete: nothing left
                return
            n_dev = max(1, len(dev_list))
            run = B.cached_step(spec, plan.backend, scan, n_dev,
                                dev_list if n_dev > 1 else None)
            # One batched device_put per pytree — per-leaf jnp.asarray
            # calls cost ~10 ms of pure dispatch per stream on small
            # grids.  With several devices, broadcast state is
            # replicated up front so the pmap path never re-shards an
            # argument per step.
            if n_dev > 1:
                put = (lambda t: jax.device_put_replicated(t, dev_list))
            else:
                dev_target = dev_list[0] if plan.explicit_devices else None
                put = (lambda t: jax.device_put(t, dev_target))
            axvals_j = put(tuple(axis_vals))
            per_step = chunk * scan * n_dev
            n_steps = -(-(stop0 - base) // per_step)
            # Snapshot carry -> device: merged state on shard 0, fresh
            # inits on the rest (the merge is associative and exact, so
            # a snapshot restores onto any device count).  np.array
            # copies keep the snapshot's buffers out of donation's
            # reach; the first pmap call shards the host stack, later
            # calls donate the already-sharded buffers.
            merged0 = jax.tree_util.tree_map(np.array, state["carry"])
            if n_dev > 1:
                fresh = B.init_carry(spec)
                carry = jax.tree_util.tree_map(
                    lambda m, f: np.stack([m] + [f] * (n_dev - 1)),
                    merged0, fresh)
            else:
                carry = put(merged0)

            front_vals = state["front_vals"].copy()
            front_idx = state["front_idx"].copy()
            buf_vals: list = []             # pending front candidates
            buf_idx: list = []
            buf_n = 0
            filt_np: dict = {}          # host mirror of the device filter
            aux_extra = {}
            if cons:
                aux_extra["cons"] = put(
                    np.asarray([v for _, _, v in cons], np.float64))
            if hist_bins:
                aux_extra["hist_edges"] = put(hist_edges)
            aux = dict(aux_extra)
            last_ckpt = time.perf_counter()

            def rebuild_filter():
                nonlocal filt_np, aux
                base_sg = np.concatenate([front_vals * sign, seed_signed]) \
                    if seed_signed.size else front_vals * sign
                filt_np = P.build_dominance_filter(base_sg, d, _FILTER_ROWS,
                                                   _FILTER_BINS)
                aux = dict(aux_extra, filter=put(filt_np))

            def merge(final=False):
                # Fold the candidate buffer into the running exact
                # front.  In the pipelined path this runs while the
                # producer thread is inside XLA on the next chunks, so
                # its cost hides under device compute; the filter-based
                # pre-cull keeps the exact dominance passes to a few
                # hundred rows.
                nonlocal front_vals, front_idx, buf_vals, buf_idx, buf_n
                if buf_n:
                    cat_v = np.concatenate(buf_vals)
                    cat_i = np.concatenate(buf_idx)
                    cat_sg = cat_v * sign
                    base_sg = np.concatenate([front_vals * sign, cat_sg,
                                              seed_signed])
                    f = P.build_dominance_filter(base_sg, d, _FILTER_ROWS,
                                                 _FILTER_BINS)
                    keep = P.dominance_filter_mask(
                        f, np.ascontiguousarray(cat_sg.T), xp=np)
                    front_vals, front_idx = _merge_into_front(
                        front_vals, front_idx, cat_v[keep], cat_i[keep],
                        sign)
                    buf_vals, buf_idx, buf_n = [], [], 0
                if not final:
                    rebuild_filter()

            def host_chunk_survivors(dstart, vlen):
                # Survivor-capacity overflow (warmup-only in practice):
                # fetch nothing from the device — re-derive this chunk's
                # survivors exactly through the shared dense evaluator
                # (the same decode + evaluate contract the chunk step
                # runs), with the same constraint mask and (host-mirror)
                # pre-filter.
                flat = np.arange(dstart, dstart + vlen, dtype=np.int64)
                # Full kernel-field evaluation on purpose: this is the
                # *same* cached evaluator (same jaxpr) as
                # sweep.evaluate_grid, so the re-derived survivor values
                # are bitwise the dense path's — a narrower field set
                # lowers differently and can drift in the last ulp.
                out = B.cached_dense_eval("xla", S, full_shape, kfields)(
                    tuple(map(jnp.asarray, axis_vals)), jnp.asarray(flat))
                O = np.stack([np.asarray(out[f]) for f in objectives])
                feas = np.ones(vlen, bool)
                with np.errstate(invalid="ignore"):
                    for f, op, v in cons:
                        feas &= SW.CONSTRAINT_OPS[op](np.asarray(out[f]),
                                                      v)
                Osg = np.where(feas[None, :], O * sign[:, None], np.inf)
                keep = P.dominance_filter_mask(filt_np, Osg, xp=np)
                loc = np.flatnonzero(keep)
                return flat[loc], O[:, loc].T

            n_sub = n_dev * scan        # chunks folded per dispatch

            def maybe_snapshot(covered):
                # Emit a consistent progress snapshot over the folded
                # prefix [start0, covered) if the cadence allows it.
                if (on_snapshot is not None
                        and time.perf_counter() - snap_t["last"]
                        >= snapshot_every_s):
                    # Fold the pending buffer first so the snapshot's
                    # running front covers every survivor of the folded
                    # prefix.
                    merge()
                    snap_t["last"] = time.perf_counter()
                    on_snapshot(_progress_snapshot(
                        covered - start0, span,
                        front_vals, front_idx, objectives, sign))

            def process(item, defer_snap=False):
                # Survivor layout per dispatch: [device,][scan,] cap —
                # both optional leading axes flatten device-major /
                # scan-minor, which is exactly ascending chunk order
                # (device di covers the scan contiguous chunks at
                # start + di*scan*chunk).  ``defer_snap`` suppresses
                # the snapshot for steps with a checkpoint due: the
                # driver re-emits it after the checkpoint is durable,
                # so a watcher can never observe progress that a kill
                # right after the frame would roll back past.
                nonlocal buf_n, t_wait, t_host, t_first, n_fallback
                start, surv = item
                tw = time.perf_counter()
                flat_s, val_s, cnt_s = (np.asarray(x) for x in surv)
                t_wait += time.perf_counter() - tw
                th = time.perf_counter()
                flat_s = flat_s.reshape(n_sub, -1)
                val_s = val_s.reshape(n_sub, -1, d)
                cnt_s = cnt_s.reshape(n_sub)
                for j in range(n_sub):
                    dstart = start + chunk * j
                    vlen = min(chunk, n_total - dstart)
                    if vlen <= 0:
                        break
                    cnt = int(cnt_s[j])
                    if cnt > cap:
                        n_fallback += 1
                        fl, vv = host_chunk_survivors(dstart, vlen)
                    else:
                        fl = flat_s[j][:cnt]
                        vv = val_s[j][:cnt]
                    if len(fl):
                        buf_idx.append(np.asarray(fl, np.int64))
                        buf_vals.append(np.asarray(vv, np.float64))
                        buf_n += len(fl)
                if buf_n >= _MERGE_EVERY:
                    merge()
                if not defer_snap:
                    maybe_snapshot(min(start + per_step, stop0))
                if t_first is None:
                    t_first = time.perf_counter() - t0
                t_host += time.perf_counter() - th

            def make_starts(si):
                start = base + si * per_step
                if n_dev > 1:
                    return jnp.asarray(
                        start + chunk * scan * np.arange(n_dev),
                        jnp.int64)
                return jnp.int64(start)

            def snapshot_carry(c):
                # Owning host copy, merged to the device-count-
                # independent serialization form (see
                # backend.merge_device_carries).
                host = B.carry_to_host(c)
                return (B.merge_device_carries(host, k) if n_dev > 1
                        else host)

            def dispatch(si, c):
                # Injector hook + bounded in-place retry + dispatch
                # accounting.  A TransientDeviceError fires *before*
                # the step consumed the donated carry, so re-running
                # the dispatch in place is safe; anything raised by
                # run() itself invalidates the carry and propagates to
                # the restart loop instead.
                nonlocal t_dispatch, dispatched_flat
                start = base + si * per_step
                dispatched_flat = max(dispatched_flat,
                                      min(start + per_step, stop0))
                tstep = time.perf_counter()
                if fault_injector is not None:
                    backoff = policy.backoff_s
                    for attempt in range(policy.max_retries + 1):
                        try:
                            fault_injector(start // chunk, start)
                            break
                        except TransientDeviceError:
                            counters["retries"] += 1.0
                            if attempt >= policy.max_retries:
                                raise
                            time.sleep(backoff)
                            backoff = min(backoff * 2.0,
                                          policy.backoff_max_s)
                td = time.perf_counter()
                c, surv = run(c, axvals_j, aux, make_starts(si))
                t_dispatch += time.perf_counter() - td
                dur = time.perf_counter() - tstep
                if detector.record(dur):
                    counters["stragglers"] += 1.0
                if (policy.step_timeout_s is not None
                        and dur > policy.step_timeout_s):
                    counters["step_timeouts"] += 1.0
                return c, surv

            def report_progress():
                # Called by the drive loops *after* any checkpoint due
                # for the step has been written, so with a step-cadence
                # checkpoint every observed progress fraction is backed
                # by a durable snapshot — a kill right after a progress
                # event can never resume from before it.
                if on_progress is not None:
                    on_progress(min(1.0, (dispatched_flat - start0)
                                    / span))

            def ckpt_due(si):
                # Snapshot cadence, decided dispatch-side.  The last
                # step never snapshots here — completion writes the
                # terminal checkpoint.
                nonlocal last_ckpt
                if mgr is None or si + 1 >= n_steps:
                    return False
                if checkpoint_every_steps is not None:
                    due = ((si + 1) % max(1, int(checkpoint_every_steps))
                           == 0)
                else:
                    due = (time.perf_counter() - last_ckpt
                           >= checkpoint_every_s)
                if due:
                    last_ckpt = time.perf_counter()
                return due

            def commit_state(si, merged):
                # Fold the pending buffer, then advance the snapshot to
                # "every chunk below base + (si+1)*per_step is folded".
                # FIFO queue ordering guarantees every survivor item
                # <= si was processed before the marker that gets here.
                merge()
                state["carry"] = merged
                state["front_vals"] = front_vals.copy()
                state["front_idx"] = front_idx.copy()
                state["base"] = min(base + (si + 1) * per_step, stop0)

            rebuild_filter()                # front/seed filter
            if prefetch == 0 or n_steps == 1:
                # Fully synchronous reference path (and the single-chunk
                # fast path, where there is nothing to overlap).
                for si in range(n_steps):
                    if should_stop is not None and should_stop():
                        ctl["halted"] = True
                        break
                    carry, surv = dispatch(si, carry)
                    due = ckpt_due(si)
                    process((base + si * per_step, surv),
                            defer_snap=due)
                    if si == 0 and n_steps > 1:
                        merge()
                    if due:
                        commit_state(si, snapshot_carry(carry))
                        write_checkpoint()
                        maybe_snapshot(min(base + (si + 1) * per_step,
                                           stop0))
                    report_progress()
            else:
                # Async double-buffered pipeline: a producer thread
                # drives the chunk chain (XLA releases the GIL while a
                # step executes, so the host merges below genuinely
                # overlap device compute); the bounded queue keeps
                # `prefetch` chunk results in flight.  The producer
                # pauses after dispatching chunk 0 until its survivors
                # have been folded into the filter, so every later
                # chunk pre-filters against a real running front.
                # Checkpoint markers ride the same FIFO queue: the
                # producer snapshots the carry right after step si and
                # enqueues the marker *behind* si's survivors, so by
                # the time the consumer sees it, the host front is
                # exactly consistent with the snapshot carry.
                q: _Queue = _Queue(maxsize=prefetch)
                filter_ready = threading.Event()
                ckpt_done = threading.Event()
                stop = threading.Event()
                box: dict = {}

                def put_or_stop(item):
                    # Never block forever: if the consumer died
                    # (exception in a merge), `stop` is set and the
                    # producer exits instead of leaking a thread wedged
                    # in q.put.
                    while not stop.is_set():
                        try:
                            q.put(item, timeout=0.05)
                            return True
                        except _Full:
                            continue
                    return False

                def produce():
                    nonlocal carry
                    try:
                        with enable_x64():
                            for si in range(n_steps):
                                if stop.is_set():
                                    break
                                if (should_stop is not None
                                        and should_stop()):
                                    ctl["halted"] = True
                                    break
                                carry, surv = dispatch(si, carry)
                                due = ckpt_due(si)
                                if not put_or_stop(
                                        ("surv", base + si * per_step,
                                         surv, due)):
                                    break
                                if si == 0:
                                    filter_ready.wait()
                                if due:
                                    # Durability barrier: no later chunk
                                    # dispatches until the snapshot is
                                    # on disk, so a kill at step s can
                                    # never outrun the checkpoint due
                                    # before s.  Costs one pipeline
                                    # stall per checkpoint — nothing at
                                    # the default 30 s cadence.
                                    ckpt_done.clear()
                                    if not put_or_stop(
                                            ("ckpt", si,
                                             snapshot_carry(carry))):
                                        break
                                    while not ckpt_done.wait(0.05):
                                        if stop.is_set():
                                            break
                                report_progress()
                    except BaseException as e:  # pragma: no cover
                        box["err"] = e
                    finally:
                        put_or_stop(None)

                th_prod = threading.Thread(target=produce, daemon=True,
                                           name="stream-producer")
                th_prod.start()
                try:
                    first = True
                    while True:
                        item = q.get()
                        if item is None:
                            break
                        if item[0] == "ckpt":
                            commit_state(item[1], item[2])
                            write_checkpoint()
                            ckpt_done.set()
                            # The deferred snapshot for this step: the
                            # checkpoint is durable, so the progress it
                            # reports can no longer be rolled back.
                            maybe_snapshot(min(base + (item[1] + 1)
                                               * per_step, stop0))
                            continue
                        process((item[1], item[2]),
                                defer_snap=item[3])
                        if first:
                            merge()
                            filter_ready.set()
                            first = False
                finally:
                    # Consumer is done (or raised — including a
                    # KeyboardInterrupt): release the producer from any
                    # blocked put/wait and drain whatever it had in
                    # flight, then collect it — at most one chunk step
                    # runs to completion before it sees `stop`.  The
                    # nested finally keeps the join unconditional: even
                    # if the drain itself is interrupted (a second
                    # Ctrl-C), the producer thread — which holds the
                    # donated device carry — must never outlive this
                    # call.
                    stop.set()
                    filter_ready.set()
                    ckpt_done.set()
                    try:
                        while True:
                            try:
                                q.get_nowait()
                            except _Empty:
                                break
                    finally:
                        th_prod.join()
                if "err" in box:
                    raise box["err"]
            merge(final=True)
            state["carry"] = snapshot_carry(carry)
            state["front_vals"] = front_vals
            state["front_idx"] = front_idx
            # A cooperative halt finalizes at the dispatch high-water
            # mark: every chunk below it was issued *and* folded (the
            # producer enqueues each survivor set before checking the
            # hook again), so the snapshot is the exact contiguous
            # prefix [0, base).
            state["base"] = (min(dispatched_flat, stop0)
                             if ctl["halted"] else stop0)

        def reissue_count():
            # Chunks dispatched past the snapshot when an incarnation
            # died — exactly the ranges the next incarnation re-issues.
            nonlocal dispatched_flat
            n = max(0, -(-(dispatched_flat - state["base"]) // chunk))
            dispatched_flat = state["base"]
            return float(n)

        restarts_left = policy.max_restarts
        while True:
            try:
                drive()
                break
            except DeviceLostError as e:
                counters["chunks_reissued"] += reissue_count()
                if len(dev_list) > 1:
                    # Elastic replan: shrink the worker pool (1-D
                    # data-parallel replan_mesh specialization) and
                    # re-issue only the unfinished chunk ranges on the
                    # survivors.
                    counters["elastic_replans"] += 1.0
                    dev_list = list(drop_worker(dev_list, e.device_index))
                elif restarts_left > 0:
                    # Graceful degradation floor: the last device
                    # "died" — restart it from the snapshot.
                    restarts_left -= 1
                    counters["restarts"] += 1.0
                else:
                    raise
            except _RESTARTABLE:
                # In-place retries exhausted, or the step failed
                # mid-execution (the donated carry is gone either way):
                # restart the pipeline from the last consistent
                # snapshot.
                counters["chunks_reissued"] += reissue_count()
                if restarts_left <= 0:
                    raise
                restarts_left -= 1
                counters["restarts"] += 1.0
                time.sleep(min(
                    policy.backoff_s * (2.0 ** counters["restarts"]),
                    policy.backoff_max_s))
        # Terminal snapshot: resume == done (or, after a cooperative
        # halt, resume == continue from the stop point).
        if mgr is not None and mgr.latest_step() != state["base"]:
            write_checkpoint()
    total_s = time.perf_counter() - t0

    # Deliverables come straight off the committed snapshot — the same
    # arrays a checkpoint would persist, so a resumed run and an
    # uninterrupted run return bitwise-identical results.
    carry = state["carry"]
    front_vals = state["front_vals"]
    front_idx = state["front_idx"]
    partial = int(state["base"]) < stop0
    stats = {
        "n_configs": float(n_total),
        "n_chunks": float(n_steps),
        # Fraction of the (range's) flat-index space folded into this
        # result — 1.0 for a complete sweep; after a cooperative halt
        # (should_stop / deadline) the reductions cover exactly the
        # contiguous prefix [range_start, fraction_complete * span).
        "fraction_complete": ((int(state["base"]) - start0) / span
                              if span else 1.0),
        # The leased flat-index slice this result reduces (the whole
        # grid unless flat_range= was given) — merge_results' tiling
        # contract.
        "range_start": float(start0),
        "range_stop": float(stop0),
        "total_s": total_s,
        "first_chunk_s": t_first if t_first is not None else total_s,
        "configs_per_s": span / total_s if total_s else float("inf"),
        "steady_configs_per_s": (
            (span - min(per_step, span))
            / max(total_s - (t_first or 0.0), 1e-9)
            if n_steps > 1 else span / max(total_s, 1e-9)),
        # Pipeline accounting: host_merge_s is time spent in the exact
        # merges/buffering; device_wait_s is time blocked fetching chunk
        # survivors (≈ un-hidden device compute).  prefetch > 0 shrinks
        # device_wait_s toward the critical path.
        "host_merge_s": t_host,
        "device_wait_s": t_wait,
        # Dispatch accounting: time spent inside step invocation.  On
        # async accelerator backends this isolates the per-step launch
        # overhead scan fusion amortizes (K chunks per dispatch); XLA
        # CPU dispatch is synchronous, so here it also absorbs blocked
        # device compute — the dispatch *count* (n_chunks) is the
        # backend-independent signal, falling K-fold under scan_chunks.
        # A cold step's first call additionally pays trace + compile.
        "dispatch_s": t_dispatch,
        "steps_per_s": n_steps / total_s if total_s else float("inf"),
        "scan_chunks": float(scan),
        "prefetch": float(prefetch),
        "fallback_chunks": float(n_fallback),
        # Resilience accounting (see the stream_grid docstring):
        # in-place retries, pipeline restarts, the chunk ordinal a
        # resume started from, checkpoint count/time, chunk ranges
        # re-issued after failures, elastic device-pool shrinks,
        # flagged stragglers and step-deadline overruns.
        **counters,
    }

    # Normalize the top-k table: entries past the feasible count keep the
    # +inf sentinel value — point their indices at n_total too.
    topk_val = carry["topk_val"] * sign[:, None]
    topk_idx = np.where(np.isfinite(carry["topk_val"]), carry["topk_idx"],
                        n_total)

    hist_out = None
    if hist_bins:
        hist_out = {f: (np.asarray(carry["hist"][oi]), hist_edges[oi].copy())
                    for oi, f in enumerate(objectives)}
    visible_axis_valid = (axis_valid[1:] if len(axis_valid) == len(axes) + 1
                          else axis_valid)     # drop hidden model axis
    return StreamResult(
        axes=axes, objectives=objectives, maximize=maximize,
        chunk_size=chunk, n_devices=n_dev,
        min_val={f: float(carry["min_val"][i])
                 for i, f in enumerate(fields)},
        min_idx={f: int(carry["min_idx"][i]) for i, f in enumerate(fields)},
        finite_counts={f: int(carry["finite"][i])
                       for i, f in enumerate(fields)},
        channel_min={f: float(carry["fmin"][i])
                     for i, f in enumerate(fields)},
        channel_max={f: float(carry["fmax"][i])
                     for i, f in enumerate(fields)},
        axis_valid=OrderedDict(zip(axes, visible_axis_valid)),
        topk_val=topk_val,
        topk_idx=topk_idx,
        front_indices=front_idx, front_values=front_vals,
        hist=hist_out, stats=stats, constraints=cons, partial=partial)


#: Moved to the backend layer as the carry serialization contract.
_merge_device_carries = B.merge_device_carries


# ---------------------------------------------------------------------------
# Cross-range folding (the worker pool's merge step)
# ---------------------------------------------------------------------------


def _carry_from_result(res: StreamResult, sign: np.ndarray,
                       n_total: int) -> dict:
    """Reconstruct the serialization-form carry of one
    :class:`StreamResult` — exact, because the result's deliverables
    *are* the carry fields up to the orientation flip (``topk_val`` is
    stored ``carry * sign`` with ``sign`` in ±1, so multiplying by
    ``sign`` again is a bitwise round trip, including the ±inf
    sentinels)."""
    fields = tuple(res.min_val)
    carry = {
        "min_val": np.array([res.min_val[f] for f in fields],
                            np.float64),
        "min_idx": np.array([res.min_idx[f] for f in fields], np.int64),
        "finite": np.array([res.finite_counts[f] for f in fields],
                           np.int64),
        "fmin": np.array([res.channel_min[f] for f in fields],
                         np.float64),
        "fmax": np.array([res.channel_max[f] for f in fields],
                         np.float64),
        "topk_val": np.asarray(res.topk_val, np.float64)
        * sign[:, None],
        "topk_idx": np.where(
            np.isfinite(res.topk_val),
            np.asarray(res.topk_idx, np.int64), n_total),
    }
    if res.hist is not None:
        carry["hist"] = np.stack(
            [np.asarray(res.hist[f][0], np.int64)
             for f in res.objectives])
    return carry


def merge_results(parts: Sequence[StreamResult]) -> StreamResult:
    """Fold per-range :class:`StreamResult` parts (``flat_range=`` runs
    whose ranges tile ``[0, n_configs)``) into one complete result,
    bitwise-identical to a single-process sweep of the whole grid.

    Exactness comes from the same two ingredients the multi-device
    pmap path uses: every carry reduction is associative with the
    dense-path tie rules (:func:`repro.core.backend.
    merge_device_carries` — lexicographic ``(value, index)`` argmin,
    two-key sorted top-k merge, plain sums/min/max), and the exact
    front merge (:func:`_merge_into_front`) over the parts' disjoint
    exact fronts.  Parts may arrive in any order; they are sorted by
    ``range_start``.  Raises :class:`ValueError` on gaps, overlaps,
    incomplete (``partial=True``) parts, or mismatched specs — a torn
    part set must never fold silently.
    """
    if not parts:
        raise ValueError("merge_results needs at least one part")
    parts = sorted(parts, key=lambda r: r.stats.get("range_start", 0.0))
    first = parts[0]
    n_total = first.n_configs
    fields = tuple(first.min_val)
    sign = np.where([o in first.maximize for o in first.objectives],
                    -1.0, 1.0)
    cursor = 0
    for r in parts:
        if (r.axes != first.axes or r.objectives != first.objectives
                or r.maximize != first.maximize
                or tuple(r.min_val) != fields
                or r.constraints != first.constraints):
            raise ValueError("merge_results: parts from different "
                             "sweep specifications")
        start = int(r.stats.get("range_start", 0))
        stop = int(r.stats.get("range_stop", r.n_configs))
        if start != cursor:
            raise ValueError(
                f"merge_results: range gap/overlap at flat index "
                f"{cursor} (next part starts at {start})")
        if r.partial:
            raise ValueError(
                f"merge_results: part [{start}, {stop}) is partial "
                f"({r.stats.get('fraction_complete', 0.0):.1%})")
        cursor = stop
    if cursor != n_total:
        raise ValueError(f"merge_results: ranges cover [0, {cursor}) "
                         f"of {n_total} configs")

    t0 = time.perf_counter()
    k = first.topk_idx.shape[1]
    stacked = B.stack_host_carries(
        [_carry_from_result(r, sign, n_total) for r in parts])
    carry = B.merge_device_carries(stacked, k)

    d = len(first.objectives)
    front_vals = np.empty((0, d))
    front_idx = np.empty((0,), np.int64)
    for r in parts:
        front_vals, front_idx = _merge_into_front(
            front_vals, front_idx,
            np.asarray(r.front_values, np.float64),
            np.asarray(r.front_indices, np.int64), sign)

    hist_out = None
    if first.hist is not None:
        for r in parts[1:]:
            for oi, f in enumerate(first.objectives):
                if not np.array_equal(r.hist[f][1], first.hist[f][1]):
                    raise ValueError(
                        f"merge_results: histogram edges of {f!r} "
                        f"differ across parts")
        hist_out = {f: (np.asarray(carry["hist"][oi]),
                        np.asarray(first.hist[f][1]).copy())
                    for oi, f in enumerate(first.objectives)}

    summed = ("retries", "restarts", "checkpoints_written",
              "checkpoint_write_s", "chunks_reissued", "elastic_replans",
              "stragglers", "step_timeouts", "fallback_chunks",
              "n_chunks", "host_merge_s", "device_wait_s", "dispatch_s")
    total_s = max(float(r.stats.get("total_s", 0.0)) for r in parts)
    stats = {
        "n_configs": float(n_total),
        "fraction_complete": 1.0,
        "range_start": 0.0,
        "range_stop": float(n_total),
        "n_parts": float(len(parts)),
        # Wall-clock of the slowest part: with parts running
        # concurrently (the worker pool) this is the aggregate job
        # duration, so configs_per_s is the *aggregate* throughput.
        "total_s": total_s,
        "configs_per_s": (n_total / total_s if total_s
                          else float("inf")),
        "first_chunk_s": min(float(r.stats.get("first_chunk_s", 0.0))
                             for r in parts),
        "merge_s": 0.0,
        **{kk: float(sum(r.stats.get(kk, 0.0) for r in parts))
           for kk in summed},
    }

    topk_val = carry["topk_val"] * sign[:, None]
    topk_idx = np.where(np.isfinite(carry["topk_val"]),
                        carry["topk_idx"], n_total)
    stats["merge_s"] = time.perf_counter() - t0
    return StreamResult(
        axes=first.axes, objectives=first.objectives,
        maximize=first.maximize, chunk_size=first.chunk_size,
        n_devices=sum(r.n_devices for r in parts),
        min_val={f: float(carry["min_val"][i])
                 for i, f in enumerate(fields)},
        min_idx={f: int(carry["min_idx"][i])
                 for i, f in enumerate(fields)},
        finite_counts={f: int(carry["finite"][i])
                       for i, f in enumerate(fields)},
        channel_min={f: float(carry["fmin"][i])
                     for i, f in enumerate(fields)},
        channel_max={f: float(carry["fmax"][i])
                     for i, f in enumerate(fields)},
        axis_valid=OrderedDict(
            (kk, np.asarray(v).copy())
            for kk, v in first.axis_valid.items()),
        topk_val=topk_val, topk_idx=topk_idx,
        front_indices=front_idx, front_values=front_vals,
        hist=hist_out, stats=stats, constraints=first.constraints,
        partial=False)
