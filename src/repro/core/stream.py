"""Streaming, sharded sweep executor: memory-bounded giant design spaces.

:func:`repro.core.sweep.evaluate_grid` materializes the full cartesian
product — host coordinate meshes on the way in, eleven dense channel
grids on the way out — so memory is O(grid) and a 9-axis space at
realistic resolution (10⁷–10⁹ configurations) is unreachable.  This
module replaces that with a **streaming executor** over the *same*
compiled Eq. 1-11 kernel:

* **Device-side coordinate decoding** — each chunk starts from a flat
  index range; the mixed-radix decode of
  :func:`repro.core.sweep.decode_flat_index` runs on-device, so no
  coordinate arrays are ever materialized anywhere.
* **Fixed-size donated chunks** — one cached, jit-compiled step decodes
  and evaluates a chunk and folds it into a running device carry
  (argmin, validity counts, channel bounds per tracked channel).  The
  carry is donated back to the device each step, so the reduction state
  never reallocates; only the tracked channel rows leave the device
  (untracked kernel outputs are dead-code-eliminated, which is a large
  part of why streaming keeps up with the dense path while doing
  strictly more work).
* **Exact host merges** — top-k per objective (gated on the chunk
  actually beating the running k-th best, so it is ~free in steady
  state), optional histograms, and an **incremental Pareto front**: a
  subsampled-front dominance pre-filter discards almost every point;
  the rare survivors are buffered and merged exactly with
  :func:`repro.core.pareto.merge_fronts`.  Host memory stays
  O(chunk + front) for any grid size, and argmin/top-k/front are
  *exactly* the dense-path results.
* **Sharding** — with more than one device the chunk stream is split
  across devices via ``jax.pmap`` (one carry per device, merged once at
  the end), so kernel throughput scales with the device count.
* **Batched workload axis** — ``models=`` stacks architecture variants
  (see :func:`repro.core.arrays.stacked_model_arrays`) into a leading
  grid axis evaluated inside the same kernel, for SplitNets-style
  architecture × partition co-design sweeps.

The dense path remains the right tool for small grids where the full
per-channel arrays are wanted (heatmaps, reporting); the two paths are
pinned exactly equal — argmin, top-k, and Pareto front — by
``tests/test_stream.py`` and the ``benchmarks/run.py --smoke`` CI gate.
"""

from __future__ import annotations

import dataclasses
import math
import time
from collections import OrderedDict
from typing import Iterable, Mapping, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from . import arrays as A
from . import pareto as P
from . import sweep as SW
from .constants import (CAMERA_FPS, DETNET_FPS, KEYNET_FPS, NUM_CAMERAS,
                        TechNode)
from .workloads import NNWorkload

#: Default flat-index chunk evaluated per device per step.
DEFAULT_CHUNK = 1 << 18

_FILTER_ROWS = 24      # front subsample rows in the dominance pre-filter
_PROBE = 4096          # strided probe (front seed + histogram ranges)
_MERGE_EVERY = 8192    # host candidate-buffer size that triggers a merge
_STEP_CACHE: "OrderedDict[tuple, object]" = OrderedDict()
_STEP_CACHE_MAX = 32


# ---------------------------------------------------------------------------
# Result container
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StreamResult:
    """Reductions of one streamed sweep (never the dense grid itself).

    Holds O(front + k + axes) state: per-channel argmin winners, top-k
    tables for the tracked objectives, validity counts, channel bounds,
    optional histograms, and the exact Pareto front.  ``axes`` matches
    :class:`~repro.core.sweep.SweepResult` (including the optional leading
    ``model`` axis), and flat indices are interchangeable with the dense
    path, so :meth:`config_at` decodes identically.
    """

    axes: "OrderedDict[str, tuple]"
    objectives: tuple[str, ...]
    maximize: tuple[str, ...]
    chunk_size: int
    n_devices: int

    min_val: Mapping[str, float]          # per tracked channel: lowest value
    min_idx: Mapping[str, int]            # ... and its flat index
    finite_counts: Mapping[str, int]      # valid-config counts (exact)
    channel_min: Mapping[str, float]      # finite min / max per channel
    channel_max: Mapping[str, float]
    #: Valid-config counts per axis value from the strided probe pass —
    #: diagnostics for the all-invalid error messages, not exact tallies.
    axis_valid: "OrderedDict[str, np.ndarray]"

    topk_idx: np.ndarray                  # (n_objectives, k) flat indices
    topk_val: np.ndarray                  # natural-orientation values

    front_indices: np.ndarray             # (f,) flat indices, exact front
    front_values: np.ndarray              # (f, d) natural-orientation values

    hist: Optional[Mapping[str, tuple[np.ndarray, np.ndarray]]]
    stats: Mapping[str, float]

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(len(v) for v in self.axes.values())

    @property
    def n_configs(self) -> int:
        return int(np.prod(self.shape))

    def config_at(self, flat_index: int) -> dict:
        """Axis values of one flat grid index (the shared
        :func:`~repro.core.sweep.config_from_flat` decode — identical to
        the dense ``SweepResult.config_at``)."""
        return SW.config_from_flat(self.shape, self.axes, flat_index)

    def _invalid_notes(self) -> list[str]:
        return [f"{name}={vals[i]!r}"
                for (name, vals), counts in zip(self.axes.items(),
                                                self.axis_valid.values())
                for i in np.flatnonzero(counts == 0)]

    def argmin(self, field: str = "avg_power") -> dict:
        """Best (lowest-``field``) configuration — dense-argmin equal."""
        if field not in self.min_val:
            raise ValueError(
                f"channel {field!r} was not tracked; this stream reduced "
                f"{sorted(self.min_val)} — re-run stream_grid with "
                f"track=({field!r},) or track='all'")
        if self.finite_counts[field] == 0:
            raise ValueError(SW.invalid_message(field, self._invalid_notes()))
        out = self.config_at(self.min_idx[field])
        out[field] = self.min_val[field]
        return out

    def top_k(self, field: str) -> list[dict]:
        """The k best configurations of one tracked objective, best first
        (k was fixed at :func:`stream_grid` time; ties break toward the
        lower flat index, matching the dense ``SweepResult.top_k``)."""
        if field not in self.objectives:
            raise ValueError(f"top-k tracks only {self.objectives}; "
                             f"re-run stream_grid with {field!r} in "
                             f"objectives=")
        oi = self.objectives.index(field)
        out = []
        for flat, val in zip(self.topk_idx[oi], self.topk_val[oi]):
            if not np.isfinite(val):
                break
            cfg = self.config_at(int(flat))
            cfg[field] = float(val)
            out.append(cfg)
        return out

    def channel_bounds(self, field: str) -> tuple[float, float]:
        """(min, max) of the finite entries of one channel (the protocol
        :meth:`repro.core.pareto.ParetoFront.hypervolume` prices against)."""
        if self.finite_counts[field] == 0:
            raise ValueError(SW.invalid_message(field, self._invalid_notes()))
        return self.channel_min[field], self.channel_max[field]

    def pareto_front(self) -> P.ParetoFront:
        """The exact non-dominated set as a regular
        :class:`~repro.core.pareto.ParetoFront` (identical — indices and
        values — to ``pareto.pareto_front`` on the dense grid)."""
        sign0 = -1.0 if self.objectives[0] in self.maximize else 1.0
        order = np.argsort(self.front_values[:, 0] * sign0, kind="stable")
        return P.ParetoFront(
            result=self, objectives=self.objectives, maximize=self.maximize,
            indices=self.front_indices[order],
            values=self.front_values[order])


# ---------------------------------------------------------------------------
# The compiled chunk step (cached across stream_grid calls)
# ---------------------------------------------------------------------------


def _build_step(S, shape, n_total, chunk, fields, n_dev, devices):
    """Evaluate one decoded chunk and fold it into the device carry.

    Returns the tracked channel rows ``F`` (``(n_fields, chunk)``) for the
    host-side top-k / Pareto merges.  Axis-value arrays are *arguments*
    (not closure constants), so the compiled step is reusable across
    grids with the same axis sizes — the cache below makes repeated
    sweeps compile-free, like the dense ``_compiled_kernel``.
    """
    kernel = SW.vmapped_kernel(S)
    # int32 decode arithmetic when the flat index space fits — int64
    # div/mod is measurably slower on CPU.
    small = n_total + chunk * n_dev < 2**31

    def step(carry, axvals, start):
        flat = start + jnp.arange(chunk, dtype=jnp.int64)
        ingrid = flat < n_total
        # Mixed-radix decode (the shared sweep.decode_flat_index, traced
        # on-device) + axis-value gather: the coordinates for this chunk
        # never exist as host arrays, and XLA fuses the decode straight
        # into the kernel body.
        fdec = flat.astype(jnp.int32) if small else flat
        coords = SW.decode_flat_index(shape, fdec)
        out = kernel(*[v[c] for v, c in zip(axvals, coords)])

        F = jnp.stack([out[f] for f in fields])            # (nf, chunk)
        valid = jnp.isfinite(F) & ingrid[None, :]
        Fm = jnp.where(valid, F, jnp.inf)

        # Running argmin per channel; ties toward the lower flat index
        # (jnp.argmin returns the first minimum, matching np.nanargmin).
        loc = jnp.argmin(Fm, axis=1)
        lv = Fm.min(axis=1)          # == Fm[:, loc] — doubles as chunk fmin
        li = flat[loc]
        # isfinite guard: an all-invalid chunk ties at inf == inf and must
        # not swap the sentinel min_idx for an invalid config's index.
        better = (lv < carry["min_val"]) | ((lv == carry["min_val"])
                                            & jnp.isfinite(lv)
                                            & (li < carry["min_idx"]))
        new_carry = {
            "min_val": jnp.where(better, lv, carry["min_val"]),
            "min_idx": jnp.where(better, li, carry["min_idx"]),
            "finite": carry["finite"] + valid.sum(axis=1),
            "fmin": jnp.minimum(carry["fmin"], lv),
            "fmax": jnp.maximum(
                carry["fmax"], jnp.where(valid, F, -jnp.inf).max(axis=1)),
        }
        return new_carry, F

    if n_dev > 1:
        return jax.pmap(step, donate_argnums=(0,), in_axes=(0, None, 0),
                        devices=devices)
    return jax.jit(step, donate_argnums=(0,))


def _cached_step(S, shape, n_total, chunk, fields, n_dev, devices):
    # S is hashed by identity (frozen, eq=False); keying on the object
    # itself (not id()) keeps it alive so a recycled id can never alias
    # a stale compiled step.
    key = (S, shape, chunk, fields, n_dev,
           tuple(str(d) for d in devices or ()))
    fn = _STEP_CACHE.get(key)
    if fn is None:
        fn = _build_step(S, shape, n_total, chunk, fields, n_dev, devices)
        _STEP_CACHE[key] = fn
        while len(_STEP_CACHE) > _STEP_CACHE_MAX:
            _STEP_CACHE.popitem(last=False)
    return fn


def _init_carry(n_total, n_fields):
    # Strong dtypes throughout: a weak-typed init carry would retrace the
    # step on its second call (outputs come back strong-typed).
    return {
        "min_val": jnp.full((n_fields,), jnp.inf, jnp.float64),
        "min_idx": jnp.full((n_fields,), n_total, jnp.int64),
        "finite": jnp.zeros((n_fields,), jnp.int64),
        "fmin": jnp.full((n_fields,), jnp.inf, jnp.float64),
        "fmax": jnp.full((n_fields,), -jnp.inf, jnp.float64),
    }


# ---------------------------------------------------------------------------
# Host-side exact merges
# ---------------------------------------------------------------------------


class _TopK:
    """Running exact top-k per objective over (signed value, flat index).

    Chunk extraction is gated on ``x <= kth`` — after the table tightens
    (a few chunks in) almost every chunk skips in one vectorized compare.
    Ties break toward the lower flat index, matching ``np.argsort(...,
    kind='stable')`` on the dense grid.
    """

    def __init__(self, n_obj: int, k: int, n_total: int):
        self.k = k
        self.val = np.full((n_obj, k), np.inf)
        self.idx = np.full((n_obj, k), n_total, np.int64)

    def update(self, oi: int, x: np.ndarray, base: np.int64):
        kth = self.val[oi, -1]
        sel = np.flatnonzero(x <= kth)       # NaN compares False: excluded
        if sel.size == 0:
            return
        if sel.size > 4 * self.k:
            # Large entrant set (warmup): shrink exactly via a partition.
            xv = x[sel]
            kthv = np.partition(xv, self.k - 1)[self.k - 1]
            sel = sel[xv <= kthv]
        cv = np.concatenate([self.val[oi], x[sel]])
        ci = np.concatenate([self.idx[oi], base + sel.astype(np.int64)])
        order = np.lexsort((ci, cv))[:self.k]
        self.val[oi] = cv[order]
        self.idx[oi] = ci[order]


def _filter_rows(front_signed: np.ndarray, rows: int, d: int) -> np.ndarray:
    """Subsample the running front into the fixed-size dominance filter.

    Rows are drawn at quantiles of the front sorted along *every*
    objective (not just the first) — a front with hundreds of members
    spreads differently along each trade-off axis, and a filter that only
    walks the first objective leaves holes that flood the host merge with
    false survivors.
    """
    filt = np.full((rows, d), np.inf)
    k = front_signed.shape[0]
    if k == 0:
        return filt
    if k <= rows:
        filt[:k] = front_signed
        return filt
    per = max(1, rows // d)
    picks: list = []
    for col in range(d):
        order = np.argsort(front_signed[:, col], kind="stable")
        picks.extend(order[np.round(np.linspace(0, k - 1, per))
                           .astype(int)])
    take = np.unique(np.asarray(picks))[:rows]
    filt[:take.size] = front_signed[take]
    return filt


def _undominated(Osg: np.ndarray, filt: np.ndarray) -> np.ndarray:
    """Finite rows of ``Osg`` (signed ``(d, n)`` channel rows) that no
    filter row dominates — unrolled over the few filter rows so every op
    stays a flat vector pass."""
    d = Osg.shape[0]
    fin = np.isfinite(Osg[0])
    for i in range(1, d):
        fin &= np.isfinite(Osg[i])
    dom = np.zeros(Osg.shape[1], bool)
    for r in range(filt.shape[0]):
        if not np.isfinite(filt[r, 0]):
            break
        le = filt[r, 0] <= Osg[0]
        lt = filt[r, 0] < Osg[0]
        for i in range(1, d):
            le &= filt[r, i] <= Osg[i]
            lt |= filt[r, i] < Osg[i]
        dom |= le & lt
    return fin & ~dom


class _FrontFilter:
    """Dominance pre-filter against the running front.

    Two sufficient conditions for "this point is dominated" (so discarding
    is always exact; everything uncertain survives into the exact merge):

    * a few explicit front rows (:func:`_filter_rows`), checked directly;
    * for d <= 3, a quantile-binned 2-D prefix-min table over the front:
      ``D[b1, b2]`` is the best (signed) first objective among front
      members whose objective-1/2 values fall in a *strictly lower* bin
      in both axes — ``D[pb1-1, pb2-1] <= p0`` therefore proves a member
      with ``m0 <= p0, m1 < p1, m2 < p2`` exists, i.e. true domination.
      This scales with front *shape*, not front size, which is what keeps
      survivor counts (and the exact-merge cost) flat on grids whose
      fronts grow into the hundreds of members.
    """

    def __init__(self, d: int, bins: int = 64):
        self.d = d
        self.bins = bins
        self.rows = np.full((_FILTER_ROWS, d), np.inf)
        self.edges = None
        self.table = None

    def rebuild(self, front_signed: np.ndarray):
        self.rows = _filter_rows(front_signed, _FILTER_ROWS, self.d)
        self.edges = self.table = None
        k = front_signed.shape[0]
        if not (2 <= self.d <= 3) or k < 8:
            return
        cols = list(range(1, self.d))
        edges = [np.unique(np.quantile(front_signed[:, c],
                                       np.linspace(0, 1, self.bins + 1)))
                 for c in cols]
        if any(e.size < 2 for e in edges):
            return
        dims = tuple(e.size for e in edges)
        table = np.full(dims, np.inf)
        bin_idx = [np.clip(np.searchsorted(e, front_signed[:, c],
                                           side="right") - 1,
                           0, e.size - 1)
                   for e, c in zip(edges, cols)]
        np.minimum.at(table, tuple(bin_idx), front_signed[:, 0])
        for ax in range(table.ndim):
            table = np.minimum.accumulate(table, axis=ax)
        self.edges = edges
        self.table = table

    def undominated(self, Osg: np.ndarray) -> np.ndarray:
        keep = _undominated(Osg, self.rows)
        if self.table is None:
            return keep
        idx = []
        ok = np.ones(Osg.shape[1], bool)
        for e, row in zip(self.edges, Osg[1:]):
            # Strictly-lower bin: a member binned below E[pb] has a value
            # < E[pb] <= p, hence strictly smaller in that objective.
            b = np.searchsorted(e, row, side="right") - 2
            ok &= b >= 0
            idx.append(np.clip(b, 0, e.size - 1))
        dom = np.zeros(Osg.shape[1], bool)
        dom[ok] = self.table[tuple(i[ok] for i in idx)] <= Osg[0][ok]
        return keep & ~dom


def _probe(S, axis_vals, shape, n_total, obj_fields, sign, hist_bins,
           hist_ranges):
    """Strided sample pass: seeds the front filter, histogram ranges and
    the per-axis-value validity diagnostics.

    The probe points are ordinary grid points evaluated through the same
    compiled kernel; they only ever *pre-filter* (the exact front is built
    solely from chunk survivors), so correctness never depends on probe
    coverage.
    """
    m = int(min(_PROBE, n_total))
    flat = np.unique(np.linspace(0, n_total - 1, m).astype(np.int64))
    coords = SW.decode_flat_index(shape, flat)
    out = SW._compiled_kernel(S)(
        *[jnp.asarray(a[c]) for a, c in zip(axis_vals, coords)])
    O = np.stack([np.asarray(out[f]) for f in obj_fields], axis=1)
    fin = np.isfinite(O).all(axis=1)
    axis_valid = tuple(np.bincount(c[fin], minlength=sz)
                       for c, sz in zip(coords, shape))
    seed = O[fin] * sign
    if seed.shape[0]:
        seed = seed[P.non_dominated_mask(seed)]
        # The probe runs through the dense jit while chunks run through
        # the step jit; the two lowerings can disagree in the last ulp.
        # Pad the seed rows outward so a probe twin of a front point can
        # never strictly dominate (and wrongly cull) its chunk-evaluated
        # copy — the filter stays conservative, the host merge is exact.
        seed = seed + (1e-9 * np.abs(seed) + 1e-300)

    edges = None
    if hist_bins:
        edges = np.empty((len(obj_fields), hist_bins + 1))
        for oi, f in enumerate(obj_fields):
            if hist_ranges is not None and f in hist_ranges:
                lo, hi = map(float, hist_ranges[f])
            else:
                col = O[:, oi][np.isfinite(O[:, oi])]
                if col.size == 0:
                    lo, hi = 0.0, 1.0
                else:
                    lo, hi = float(col.min()), float(col.max())
                    pad = 0.05 * ((hi - lo) or max(abs(lo), 1.0))
                    lo, hi = lo - pad, hi + pad
            if hi <= lo:
                hi = lo + 1.0
            edges[oi] = np.linspace(lo, hi, hist_bins + 1)
    return seed, edges, axis_valid


# ---------------------------------------------------------------------------
# The executor
# ---------------------------------------------------------------------------


def stream_grid(cuts: Optional[Iterable[int]] = None,
                agg_nodes: Sequence[str | TechNode] = ("7nm",),
                sensor_nodes: Sequence[str | TechNode] = ("7nm",),
                weight_mems: Sequence[str] = ("sram",),
                detnet_fps: Sequence[float] = (DETNET_FPS,),
                keynet_fps: Sequence[float] = (KEYNET_FPS,),
                num_cameras: Sequence[float] = (NUM_CAMERAS,),
                mipi_energy_scale: Sequence[float] = (1.0,),
                camera_fps: Sequence[float] = (CAMERA_FPS,),
                detnet: NNWorkload | None = None,
                keynet: NNWorkload | None = None,
                model: A.ModelArrays | None = None,
                models=None,
                chunk_size: int = DEFAULT_CHUNK,
                top_k: int = 4,
                objectives: Sequence[str] = P.DEFAULT_OBJECTIVES,
                maximize: Iterable[str] = (),
                track: Optional[Sequence[str]] = None,
                hist_bins: int = 0,
                hist_ranges: Optional[Mapping] = None,
                devices: Optional[Sequence] = None) -> StreamResult:
    """Stream Eqs. 1-11 over an arbitrarily large cartesian grid.

    Same axes (and ``models=`` workload batch) as
    :func:`repro.core.sweep.evaluate_grid`, but the grid is never
    materialized: flat indices are decoded to coordinates on-device in
    ``chunk_size`` pieces (per device) and folded into running
    reductions, so host memory is O(chunk + front) for any grid size.
    Argmin, top-k and Pareto front are *exactly* the dense-path results.

    ``objectives``/``maximize`` select the channels tracked by top-k and
    the incremental Pareto front.  ``track`` adds further channels to the
    argmin/count/bounds reductions (or ``"all"`` for every kernel field)
    — untracked channels are dead-code-eliminated from the compiled step,
    which is a large part of why streaming keeps pace with the dense
    path, so track only what you need.  ``hist_bins`` adds per-objective
    histograms (ranges from ``hist_ranges`` or a strided probe pass, with
    out-of-range values clamped into the end bins).  ``devices`` shards
    the chunk stream across multiple JAX devices via ``pmap``.
    """
    S, axis_vals, axes = SW.build_axes(
        cuts, agg_nodes, sensor_nodes, weight_mems, detnet_fps, keynet_fps,
        num_cameras, mipi_energy_scale, camera_fps, detnet, keynet, model,
        models)
    full_shape = tuple(a.size for a in axis_vals)
    n_total = int(np.prod(full_shape))

    objectives = tuple(objectives)
    maximize = tuple(maximize)
    if not objectives:
        raise ValueError("need at least one objective channel")
    if track == "all":
        extra: tuple = SW.FIELDS
    else:
        extra = tuple(track) if track is not None else ()
    fields = objectives + tuple(f for f in extra if f not in objectives)
    unknown = [o for o in fields if o not in SW.FIELDS]
    if unknown:
        raise ValueError(f"unknown objective channels {unknown}; "
                         f"have {SW.FIELDS}")
    stray = [o for o in maximize if o not in objectives]
    if stray:
        raise ValueError(f"maximize entries {stray} not in objectives")
    sign = np.where([o in maximize for o in objectives], -1.0, 1.0)
    d = len(objectives)

    dev_list = list(devices) if devices is not None else jax.local_devices()
    n_dev = max(1, len(dev_list))
    chunk = max(1, int(chunk_size))
    k = max(1, min(int(top_k), n_total))
    per_step = chunk * n_dev
    n_steps = math.ceil(n_total / per_step)

    t0 = time.perf_counter()
    with enable_x64():
        seed_signed, hist_edges, axis_valid = _probe(
            S, axis_vals, full_shape, n_total, objectives, sign,
            hist_bins, hist_ranges)

        run = _cached_step(S, full_shape, n_total, chunk, fields, n_dev,
                           dev_list if n_dev > 1 else None)
        axvals_j = tuple(jnp.asarray(a) for a in axis_vals)
        carry = _init_carry(n_total, len(fields))
        if n_dev > 1:
            carry = jax.tree_util.tree_map(
                lambda x: jnp.stack([x] * n_dev), carry)
        elif devices is not None:
            # A single explicit device: commit the operands there so the
            # jit path honors devices= just like the pmap path does.
            axvals_j = jax.device_put(axvals_j, dev_list[0])
            carry = jax.device_put(carry, dev_list[0])

        topk = _TopK(d, k, n_total)
        front_vals = np.empty((0, d))       # natural orientation
        front_idx = np.empty((0,), np.int64)
        buf_vals: list = []                 # pending front candidates —
        buf_idx: list = []                  # merged in batches, not per chunk
        buf_n = 0
        ffilt = _FrontFilter(d)
        hist_counts = (np.zeros((d, hist_bins), np.int64) if hist_bins
                       else None)
        t_first = None

        def refresh_filter():
            base = np.concatenate([front_vals * sign, seed_signed]) \
                if seed_signed.size else front_vals * sign
            ffilt.rebuild(base)

        def flush():
            nonlocal front_vals, front_idx, buf_vals, buf_idx, buf_n
            if buf_n:
                cat_v = np.concatenate(buf_vals)
                cat_i = np.concatenate(buf_idx)
                if front_vals.shape[0] and cat_v.shape[0] > 64:
                    # Exact pre-cull against the *full* running front (its
                    # members are chunk-evaluated values, so discarding
                    # dominated candidates here loses nothing) — keeps the
                    # n·log-ish merge below from ever seeing the bulk.
                    keep = _undominated(
                        np.ascontiguousarray((cat_v * sign).T),
                        front_vals * sign)
                    cat_v, cat_i = cat_v[keep], cat_i[keep]
                front_vals, front_idx = P.merge_fronts(
                    front_vals, front_idx, cat_v, cat_i, sign)
                buf_vals, buf_idx, buf_n = [], [], 0
            refresh_filter()

        refresh_filter()
        for si in range(n_steps):
            start = si * per_step
            if n_dev > 1:
                carry, F = run(carry, axvals_j,
                               jnp.asarray(start + chunk * np.arange(n_dev),
                                           jnp.int64))
                F_blocks = np.asarray(F)
            else:
                carry, F = run(carry, axvals_j, jnp.int64(start))
                F_blocks = np.asarray(F)[None]

            for di in range(n_dev):
                dstart = start + chunk * di
                vlen = min(chunk, max(0, n_total - dstart))
                if vlen == 0:
                    break
                Fd = F_blocks[di][:, :vlen]
                base_i = np.int64(dstart)
                for oi in range(d):
                    x = Fd[oi] if sign[oi] == 1.0 else Fd[oi] * sign[oi]
                    topk.update(oi, x, base_i)
                Osg = Fd[:d] if (sign == 1.0).all() else Fd[:d] * sign[:,
                                                                       None]
                cand = ffilt.undominated(Osg)
                loc = np.flatnonzero(cand)
                if loc.size:
                    buf_vals.append(Fd[:d].T[loc])
                    buf_idx.append(dstart + loc.astype(np.int64))
                    buf_n += loc.size
                if hist_counts is not None:
                    for oi in range(d):
                        col = Fd[oi]
                        col = col[np.isfinite(col)]
                        hist_counts[oi] += np.histogram(
                            np.clip(col, hist_edges[oi][0],
                                    hist_edges[oi][-1]),
                            bins=hist_edges[oi])[0]
            # An early first flush turns the chunk-0 survivors into a real
            # running front, so the bin-table filter bites from chunk 1 on.
            if buf_n >= _MERGE_EVERY or si == 0:
                flush()
            if t_first is None:
                jax.block_until_ready(carry["min_val"])
                t_first = time.perf_counter() - t0

        flush()
        carry = jax.tree_util.tree_map(np.asarray, carry)
    total_s = time.perf_counter() - t0

    if n_dev > 1:
        carry = _merge_device_carries(carry)
    stats = {
        "n_configs": float(n_total),
        "n_chunks": float(n_steps),
        "total_s": total_s,
        "first_chunk_s": t_first if t_first is not None else total_s,
        "configs_per_s": n_total / total_s if total_s else float("inf"),
        "steady_configs_per_s": (
            (n_total - min(per_step, n_total))
            / max(total_s - (t_first or 0.0), 1e-9)
            if n_steps > 1 else n_total / max(total_s, 1e-9)),
    }

    hist_out = None
    if hist_bins:
        hist_out = {f: (hist_counts[oi].copy(), hist_edges[oi].copy())
                    for oi, f in enumerate(objectives)}
    visible_axis_valid = (axis_valid[1:] if len(axis_valid) == len(axes) + 1
                          else axis_valid)     # drop hidden model axis
    return StreamResult(
        axes=axes, objectives=objectives, maximize=maximize,
        chunk_size=chunk, n_devices=n_dev,
        min_val={f: float(carry["min_val"][i])
                 for i, f in enumerate(fields)},
        min_idx={f: int(carry["min_idx"][i]) for i, f in enumerate(fields)},
        finite_counts={f: int(carry["finite"][i])
                       for i, f in enumerate(fields)},
        channel_min={f: float(carry["fmin"][i])
                     for i, f in enumerate(fields)},
        channel_max={f: float(carry["fmax"][i])
                     for i, f in enumerate(fields)},
        axis_valid=OrderedDict(zip(axes, visible_axis_valid)),
        topk_val=topk.val * sign[:, None],
        topk_idx=topk.idx,
        front_indices=front_idx, front_values=front_vals,
        hist=hist_out, stats=stats)


def _merge_device_carries(carry):
    """Fold per-device reduction carries into one (host side, exact)."""
    mv, mi = carry["min_val"], carry["min_idx"]     # (ndev, nf)
    order = np.lexsort((mi, mv), axis=0)[0]         # per-field best device
    nf = mv.shape[1]
    return {
        "min_val": mv[order, np.arange(nf)],
        "min_idx": mi[order, np.arange(nf)],
        "finite": carry["finite"].sum(axis=0),
        "fmin": carry["fmin"].min(axis=0),
        "fmax": carry["fmax"].max(axis=0),
    }
