"""Gradient-based search over the continuous design knobs.

The Eq. 1-11 kernel of :mod:`repro.core.sweep` is differentiable end to
end with respect to every float knob — DetNet/KeyNet rates, the MIPI
energy scale, the camera frame rate — so instead of densifying a grid
axis until the optimum is resolved, this module drives ``jax.grad``
straight through the analytical model:

* :func:`objective_fn` — a differentiable scalarized objective (a weighted
  sum of kernel output channels, e.g. ``{"avg_power": 1, "latency": 10}``)
  closed over one *discrete* configuration (cut, nodes, weight memory,
  camera count).
* :func:`evaluate` / :func:`gradient` / :func:`evaluate_fields` — scalar
  conveniences that scope ``enable_x64`` for you (the kernel runs in
  float64, same as the grid engine).
* :func:`optimize_knobs` — projected Adam over box-bounded knobs.  Knobs
  are normalized to [0, 1] over their bounds so one learning rate serves
  mixed scales (fps in tens, energy scales near 1); the update reuses the
  :mod:`repro.optim.adamw` machinery with its cosine decay (which anneals
  the terminal oscillation well below grid resolution) and projects back
  into the box after every step.
* :func:`grid_argmin` — the dense-grid cross-check: the same scalarized
  objective minimized by brute force over ``evaluate_grid`` on the same
  bounds.  ``tests/test_optimize.py`` pins the two to within one grid
  step.

Power is monotone in most knobs, so single-objective searches ride the
projection to a bound — the interesting optima are interior points of
*weighted* objectives (e.g. power vs latency over ``camera_fps``, where
faster cameras cost camera power but amortize DetNet latency harder).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from ..optim import adamw
from . import arrays as A
from . import sweep as S
from .constants import CAMERA_FPS, DETNET_FPS, KEYNET_FPS, NUM_CAMERAS

#: The continuous knobs of the kernel, in its argument order.
KNOBS = ("detnet_fps", "keynet_fps", "mipi_energy_scale", "camera_fps")

_KNOB_DEFAULTS = dict(detnet_fps=DETNET_FPS, keynet_fps=KEYNET_FPS,
                      mipi_energy_scale=1.0, camera_fps=CAMERA_FPS)

_CONFIG_KEYS = frozenset(
    ("cut", "agg_node", "sensor_node", "weight_mem", "num_cameras",
     "model")) | frozenset(KNOBS)


def _weights(objective) -> dict[str, float]:
    """Normalize an objective spec to ``{channel: weight}``."""
    if isinstance(objective, str):
        objective = {objective: 1.0}
    w = {k: float(v) for k, v in objective.items()}
    unknown = sorted(set(w) - set(S.FIELDS))
    if unknown or not w:
        raise ValueError(f"bad objective channels {unknown}; "
                         f"have {S.FIELDS}")
    return w


def _check_knobs(names: Sequence[str]):
    unknown = sorted(set(names) - set(KNOBS))
    if unknown:
        raise ValueError(f"unknown knobs {unknown}; have {KNOBS}")


@dataclasses.dataclass(frozen=True)
class _Resolved:
    """A validated discrete configuration + fixed-knob defaults."""

    M: A.ModelArrays
    cut: int
    agg_i: int
    sen_i: int
    wm_i: int
    num_cameras: float
    base_knobs: dict    # every KNOB bound to its fixed (default) value

    def kernel_kwargs(self, knobs: Mapping) -> tuple:
        kw = {**self.base_knobs, **knobs}
        return (self.cut, self.agg_i, self.sen_i, self.wm_i,
                kw["detnet_fps"], kw["keynet_fps"], self.num_cameras,
                kw["mipi_energy_scale"], kw["camera_fps"])


def _resolve(config: Mapping) -> _Resolved:
    unknown = sorted(set(config) - _CONFIG_KEYS)
    if unknown:
        raise ValueError(f"unknown config keys {unknown}; "
                         f"have {sorted(_CONFIG_KEYS)}")
    if "cut" not in config:
        raise ValueError("a discrete configuration needs cut=<int>")
    model = config.get("model")
    M = model if model is not None else A.model_arrays()
    cut = int(config["cut"])
    if not 0 <= cut < M.n_cuts:
        raise ValueError(f"cut {cut} outside [0, {M.n_cuts - 1}]")
    agg_i = M.node_index(config.get("agg_node", "7nm"))
    sen_i = M.node_index(config.get("sensor_node", "7nm"))
    wm = config.get("weight_mem", "sram")
    if wm not in A.WEIGHT_MEM_KINDS:
        raise ValueError(f"unknown weight_mem {wm!r}; "
                         f"have {A.WEIGHT_MEM_KINDS}")
    wm_i = A.WEIGHT_MEM_KINDS.index(wm)
    ncam = config.get("num_cameras", NUM_CAMERAS)
    if ncam < 1 or ncam % 1:
        raise ValueError("num_cameras must be an integer >= 1")
    # Mirror the scalar path: refuse impossible memory choices eagerly
    # instead of silently optimizing a NaN landscape.
    if cut > 0 and np.isnan(M.wm_e_read[sen_i, wm_i]):
        raise ValueError(f"no {wm.upper()} test vehicle at "
                         f"{M.node_names[sen_i]}")
    fixed = {k: float(config[k]) for k in KNOBS if k in config}
    return _Resolved(M=M, cut=cut, agg_i=agg_i, sen_i=sen_i, wm_i=wm_i,
                     num_cameras=float(ncam),
                     base_knobs={**_KNOB_DEFAULTS, **fixed})


def objective_fn(objective="avg_power", **config) -> Callable:
    """Build a differentiable scalarized objective over the continuous knobs.

    ``objective`` is a kernel channel name or a ``{channel: weight}``
    mapping (see ``sweep.FIELDS``); ``config`` fixes the discrete
    configuration (``cut=`` required; ``agg_node``/``sensor_node``/
    ``weight_mem``/``num_cameras``/``model`` optional) and may pin any
    knob of :data:`KNOBS` to a non-default fixed value.

    Returns ``f(knobs: Mapping[str, Array]) -> Array`` where ``knobs``
    binds any subset of :data:`KNOBS`.  The discrete configuration is
    validated eagerly — an MRAM request on a node with no test vehicle
    raises here, mirroring the scalar path, instead of yielding NaN.

    Call (and differentiate) the result under ``jax.experimental
    .enable_x64()`` — or use :func:`evaluate`/:func:`gradient`, which
    scope it for you.
    """
    w = _weights(objective)
    r = _resolve(config)
    kernel = S.config_kernel(r.M)

    def f(knobs: Mapping[str, jnp.ndarray]) -> jnp.ndarray:
        _check_knobs(knobs)
        out = kernel(*r.kernel_kwargs(knobs))
        return sum(wi * out[k] for k, wi in w.items())

    return f


def evaluate(objective="avg_power", knobs: Mapping[str, float] | None = None,
             **config) -> float:
    """Scalarized objective value at one knob setting (float64)."""
    f = objective_fn(objective, **config)
    with enable_x64():
        return float(f({k: jnp.asarray(float(v))
                        for k, v in (knobs or {}).items()}))


def evaluate_fields(knobs: Mapping[str, float] | None = None,
                    **config) -> dict[str, float]:
    """Every kernel channel at one knob setting — like
    ``sweep.evaluate_one`` but resolved through the same config/knob
    plumbing as the optimizer (including custom ``model=`` lowerings)."""
    r = _resolve(config)
    kernel = S.config_kernel(r.M)
    with enable_x64():
        out = kernel(*r.kernel_kwargs(
            {k: jnp.asarray(float(v)) for k, v in (knobs or {}).items()}))
        return {k: float(v) for k, v in out.items()}


def gradient(objective="avg_power", knobs: Mapping[str, float] | None = None,
             **config) -> tuple[float, dict[str, float]]:
    """``(value, {knob: d objective / d knob})`` via ``jax.value_and_grad``
    through the Eq. 1-11 kernel at one knob setting (all four knobs, at
    their config-pinned or default values, when ``knobs`` is omitted)."""
    f = objective_fn(objective, **config)
    at = dict(knobs) if knobs is not None else _resolve(config).base_knobs
    with enable_x64():
        v, g = jax.value_and_grad(f)(
            {k: jnp.asarray(float(x)) for k, x in at.items()})
    return float(v), {k: float(x) for k, x in g.items()}


@dataclasses.dataclass(frozen=True)
class KnobOptResult:
    """Outcome of one projected-Adam knob search."""

    knobs: dict[str, float]        # optimized knob values (de-normalized)
    objective: float               # scalarized objective at ``knobs``
    weights: dict[str, float]      # the scalarization used
    fields: dict[str, float]       # every kernel channel at the optimum
    trajectory: np.ndarray         # objective value at each iterate
    steps: int


def optimize_knobs(bounds: Mapping[str, tuple[float, float]],
                   objective="avg_power", *,
                   steps: int = 200,
                   lr: float = 0.05,
                   init: Mapping[str, float] | None = None,
                   **config) -> KnobOptResult:
    """Projected-Adam minimization of a scalarized objective over knobs.

    ``bounds`` maps knob name -> ``(lo, hi)`` box constraints and selects
    which knobs are optimized (the rest stay fixed); ``config`` carries the
    discrete configuration of :func:`objective_fn` (``cut=...`` required).
    Optimization runs in [0, 1]-normalized knob space with a cosine-decayed
    Adam (``repro.optim.adamw``), clipping back into the box after every
    step, and returns the best iterate seen (the kernel is cheap enough
    that tracking it is free compared to one compile).

    The search is local/gradient-based: cross-check against
    :func:`grid_argmin` when the objective may be multi-modal.
    """
    if not bounds:
        raise ValueError("bounds must select at least one knob")
    _check_knobs(bounds)
    names = tuple(bounds)
    lo = {n: float(bounds[n][0]) for n in names}
    hi = {n: float(bounds[n][1]) for n in names}
    for n in names:
        if not hi[n] > lo[n]:
            raise ValueError(f"degenerate bounds for {n}: {bounds[n]}")
    w = _weights(objective)
    f = objective_fn(w, **config)

    cfg = adamw.AdamWConfig(lr=lr, warmup_steps=0, total_steps=steps,
                            min_lr_ratio=0.02, weight_decay=0.0)

    with enable_x64():
        def denorm(x):
            return {n: lo[n] + x[n] * (hi[n] - lo[n]) for n in names}

        def loss(x):
            return f(denorm(x))

        vg = jax.value_and_grad(loss)

        @jax.jit
        def step(x, st):
            v, g = vg(x)
            x2, st2, _ = adamw.apply(cfg, x, g, st)
            return {n: jnp.clip(x2[n], 0.0, 1.0) for n in names}, st2, v

        if init is None:
            x = {n: jnp.asarray(0.5, jnp.float64) for n in names}
        else:
            x = {n: jnp.clip((jnp.asarray(float(init[n])) - lo[n])
                             / (hi[n] - lo[n]), 0.0, 1.0) for n in names}
        st = adamw.init(cfg, x)
        traj = np.empty(steps + 1, np.float64)
        best_v, best_x = np.inf, x
        for i in range(steps):
            x_before = x
            x, st, v = step(x, st)
            traj[i] = float(v)
            if traj[i] < best_v:
                best_v, best_x = traj[i], x_before
        traj[steps] = float(loss(x))
        if traj[steps] < best_v:
            best_v, best_x = traj[steps], x
        knobs = {n: float(v) for n, v in denorm(best_x).items()}

    return KnobOptResult(knobs=knobs, objective=best_v, weights=w,
                         fields=evaluate_fields(knobs, **config),
                         trajectory=traj, steps=steps)


def grid_argmin(bounds: Mapping[str, tuple[float, float]],
                objective="avg_power", *,
                n: int = 33,
                **config) -> tuple[dict[str, float], float]:
    """Dense-grid brute force of the same scalarized objective.

    Evaluates ``evaluate_grid`` with ``n`` points per bounded knob (other
    knobs fixed as in :func:`objective_fn`) and returns ``(knobs, value)``
    at the grid minimum — the cross-check oracle for
    :func:`optimize_knobs`, accurate to one grid step.
    """
    if not bounds:
        raise ValueError("bounds must select at least one knob")
    _check_knobs(bounds)
    r = _resolve(config)
    axes = {}
    for k in KNOBS:
        if k in bounds:
            axes[k] = tuple(np.linspace(bounds[k][0], bounds[k][1], n))
        else:
            axes[k] = (r.base_knobs[k],)
    res = S.evaluate_grid(
        cuts=(r.cut,),
        agg_nodes=(r.M.node_names[r.agg_i],),
        sensor_nodes=(r.M.node_names[r.sen_i],),
        weight_mems=(A.WEIGHT_MEM_KINDS[r.wm_i],),
        num_cameras=(r.num_cameras,),
        detnet_fps=axes["detnet_fps"], keynet_fps=axes["keynet_fps"],
        mipi_energy_scale=axes["mipi_energy_scale"],
        camera_fps=axes["camera_fps"],
        model=r.M)
    W = scalarize(res, objective)
    flat = int(np.nanargmin(W))
    cfg = res.config_at(flat)
    return ({k: float(cfg[k]) for k in bounds}, float(W.ravel()[flat]))


def scalarize(result: S.SweepResult, objective) -> np.ndarray:
    """Weighted-sum objective over a stored grid — the same scalarization
    as :func:`objective_fn`, evaluated on ``SweepResult`` channels."""
    w = _weights(objective)
    return sum(wi * np.asarray(result.data[k], np.float64)
               for k, wi in w.items())
