"""DOSC partition advisor — the paper's technique as a framework feature.

The paper's decision problem: *given a two-tier communication hierarchy
(cheap local tier, expensive global tier), where do you place compute and
what do you send across the expensive tier?*  On an AR/VR headset that is
on-sensor-vs-aggregator; on a multi-pod TPU machine it is ICI-vs-DCN.

The advisor evaluates candidate distribution plans for a training step using
the adapted semi-analytical model (:mod:`repro.core.tpu_energy`) and picks
the minimum-energy (or minimum-time) plan.  Candidate axes:

* which mesh axes gradient reduction uses (flat all-reduce vs hierarchical
  reduce-scatter(ICI) + all-reduce(DCN) + all-gather(ICI));
* whether the cross-pod payload is compressed (bf16/int8 + error feedback)
  — the paper's 'send the ROI, not the frame';
* how often the cross-pod sync runs (every step vs every k-th step with
  local accumulation) — the paper's 'DetNet at 10 fps, KeyNet at 30 fps'.

This is an *analytical* advisor: it reasons over byte/FLOP counts exactly
like the paper's Eq. 1-11, so it runs in microseconds at job-launch time.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from .constants import TPU_V5E, TPUChipSpec


@dataclasses.dataclass(frozen=True)
class CommPlan:
    """One candidate cross-device communication plan for data parallelism."""

    name: str
    hierarchical: bool          # RS(ICI) -> AR(DCN) -> AG(ICI) vs flat AR
    dcn_dtype_bytes: int        # 4 = f32, 2 = bf16, 1 = int8 (compressed)
    sync_every: int = 1         # cross-pod sync cadence (local accum between)


@dataclasses.dataclass(frozen=True)
class PlanCost:
    plan: CommPlan
    t_comm_s: float
    e_comm_j: float             # per chip per step
    ici_bytes: float            # per chip
    dcn_bytes: float            # per chip
    dcn_edge_bytes: float       # per inter-pod boundary link (time-critical)

    def better_than(self, other: "PlanCost", objective: str) -> bool:
        a, b = (self.t_comm_s, other.t_comm_s) if objective == "time" else \
               (self.e_comm_j, other.e_comm_j)
        return a < b


DEFAULT_PLANS: tuple[CommPlan, ...] = (
    CommPlan("flat-ar-f32", hierarchical=False, dcn_dtype_bytes=4),
    CommPlan("hier-f32", hierarchical=True, dcn_dtype_bytes=4),
    CommPlan("hier-bf16", hierarchical=True, dcn_dtype_bytes=2),
    CommPlan("hier-int8-ef", hierarchical=True, dcn_dtype_bytes=1),
    CommPlan("hier-bf16-k4", hierarchical=True, dcn_dtype_bytes=2,
             sync_every=4),
)


def grad_reduce_cost(plan: CommPlan, grad_elems_per_chip: float,
                     pods: int, intra_pod_chips: int,
                     grad_dtype_bytes: int = 4,
                     chip: TPUChipSpec = TPU_V5E) -> PlanCost:
    """Byte/energy/time cost of one data-parallel gradient reduction.

    Ring formulas (``g`` = gradient bytes, ``n`` = chips/pod, ``p`` = pods,
    ``N = n*p``):

    * **flat all-reduce** over all N chips: every ring edge carries
      ``2 (N-1)/N * g`` bytes — *including the p inter-pod boundary edges*.
      The slow DCN boundary edge therefore gates the whole ring:
      ``t = 2 (N-1)/N * g / BW_dcn``.  This is the paper's centralized
      system: bulk payload rides the expensive link.
    * **hierarchical** (the DOSC plan): reduce-scatter over ICI
      ((n-1)/n * g), all-reduce of the 1/n shard across pods over DCN
      (2 (p-1)/p * g/n, optionally compressed — the 'ROI'), all-gather over
      ICI ((n-1)/n * g).  Only a 1/n-sized, optionally-compressed shard
      ever touches DCN.
    """
    g_bytes = grad_elems_per_chip * grad_dtype_bytes
    n, p = intra_pod_chips, pods
    if plan.hierarchical:
        ici = 2.0 * (n - 1) / n * g_bytes                 # RS + AG
        shard = g_bytes / n
        dcn_payload = shard * plan.dcn_dtype_bytes / grad_dtype_bytes
        dcn_edge = (2.0 * (p - 1) / p * dcn_payload) if p > 1 else 0.0
        dcn = dcn_edge            # per chip == per rail here
        dcn_edge /= plan.sync_every
        dcn /= plan.sync_every
        t = (ici / chip.ici_link_bandwidth
             + dcn_edge / chip.dcn_bandwidth)
    else:
        total = n * p
        per_edge = 2.0 * (total - 1) / total * g_bytes
        # p of the N ring edges are pod boundaries; amortized per chip:
        dcn = per_edge * p / total
        ici = per_edge * (total - p) / total
        dcn_edge = per_edge if p > 1 else 0.0
        dcn_edge /= plan.sync_every
        dcn /= plan.sync_every
        # the slowest edge gates the ring
        t = max(per_edge / chip.ici_link_bandwidth,
                dcn_edge / chip.dcn_bandwidth)
    e = ici * chip.e_ici_per_byte + dcn * chip.e_dcn_per_byte
    return PlanCost(plan=plan, t_comm_s=t, e_comm_j=e,
                    ici_bytes=ici, dcn_bytes=dcn, dcn_edge_bytes=dcn_edge)


def advise(grad_elems_per_chip: float, pods: int, intra_pod_chips: int,
           plans: Sequence[CommPlan] = DEFAULT_PLANS,
           objective: str = "energy",
           chip: TPUChipSpec = TPU_V5E) -> list[PlanCost]:
    """Rank candidate plans (best first) by time or energy.

    Mirrors the paper's partition sweep: enumerate placements, run the
    analytical model, pick the minimum.
    """
    costs = [grad_reduce_cost(p, grad_elems_per_chip, pods, intra_pod_chips,
                              chip=chip) for p in plans]
    key = (lambda c: c.t_comm_s) if objective == "time" else \
          (lambda c: c.e_comm_j)
    return sorted(costs, key=key)
