"""Silicon & system constants for the DOSC semi-analytical power model.

Published constants are taken verbatim from the paper:

* Table 1 — AR/VR custom digital-pixel-sensor (DPS) power states [Liu, IEDM'20].
* Table 2 — communication links: uTSV (5 pJ/B, 100 GB/s) [Vivet, ISSCC'20] and
  MIPI (100 pJ/B, 0.5 GB/s) [Choi'21, Takla'17].
* RBE accelerator — 133 MAC/cycle peak at 8-bit [Conti, TCAD'18].

The paper states that MAC energy and memory read/write/leakage values were
"extracted from post-synthesis simulations and memory compilers" for 7 nm and
16 nm foundry libraries, plus a 16 nm STT-MRAM test vehicle [Guedj, MRAM
Forum'21] — but does not publish the numbers.  The values below are taken from
public literature ranges for those nodes and then *calibrated* (see
``benchmarks/power_tables.py --calibrate`` provenance notes) so that the model
reproduces the paper's three headline results:

* 24 % system power reduction, distributed(7nm) vs centralized(7nm)  (Fig. 5a)
* 16 % system power reduction, distributed(16nm) vs centralized(7nm) (Fig. 5a)
* 39 % on-sensor power reduction, hybrid SRAM+MRAM vs pure SRAM      (Fig. 5b)

TPU-v5e class constants used by the adapted (beyond-paper) TPU energy model
and the roofline analysis are at the bottom.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

# ---------------------------------------------------------------------------
# Table 1 — DPS camera power states (W)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CameraPower:
    """Power draw of the digital pixel sensor in each operating state (W)."""

    sense: float = 15e-3  # "Sensing"  (exposure + ADC)
    read: float = 36e-3   # "Read Out"
    idle: float = 1.5e-3  # "Idle"


DPS_CAMERA = CameraPower()

# Default sensing time: exposure + ADC.  The DPS in [10] supports global
# shutter with short exposures; ~4.8 ms exposure + 1 ms triple-quantization
# ADC is representative for an indoor AR/VR tracking camera.  (Calibrated —
# see module docstring.)
T_EXPOSURE_S = 4.8e-3
T_ADC_S = 1.0e-3
T_SENSE_S = T_EXPOSURE_S + T_ADC_S


# ---------------------------------------------------------------------------
# Table 2 — communication links
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LinkSpec:
    """A point-to-point communication interface (Eq. 5/6)."""

    name: str
    energy_per_byte: float  # J/B
    bandwidth: float        # B/s


UTSV = LinkSpec("uTSV", energy_per_byte=5e-12, bandwidth=100e9)
MIPI = LinkSpec("MIPI", energy_per_byte=100e-12, bandwidth=0.5e9)


# ---------------------------------------------------------------------------
# Memory technology (per-node, per-type) — calibrated, literature-plausible
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MemorySpec:
    """Energy/leakage characteristics of one memory technology instance.

    ``leak_on``/``leak_ret`` are W per byte of capacity; read/write energies
    are J per byte accessed.  STT-MRAM is modelled with negligible array
    leakage (non-volatile; only periphery leaks) and ~2x the density of SRAM
    [Guedj'21], at the price of higher write energy.
    """

    name: str
    e_read: float      # J/B
    e_write: float     # J/B
    leak_on: float     # W/B while the bank is active
    leak_ret: float    # W/B while in retention / standby
    density_rel: float = 1.0  # density relative to SRAM at the same node


# 16 nm values (calibrated; see module docstring).  SRAM leakage
# ~1.8 mW/MiB active / ~0.47 mW/MiB in state-retentive drowsy mode is
# representative of high-speed compiled SRAM at operating temperature.
# MRAM array leakage is negligible (periphery only); reads cost slightly
# more than SRAM, writes ~10x.
SRAM_16NM = MemorySpec(
    name="SRAM-16nm",
    e_read=0.80e-12,
    e_write=1.00e-12,
    leak_on=1.7701e-3 / (1 << 20),
    leak_ret=0.4662e-3 / (1 << 20),
)
MRAM_16NM = MemorySpec(
    name="STT-MRAM-16nm",
    e_read=1.20e-12,
    e_write=10.0e-12,
    leak_on=0.0531e-3 / (1 << 20),  # periphery only (3% of SRAM)
    leak_ret=0.00,                  # non-volatile: full power-off retention
    density_rel=2.0,
)
# 7 nm SRAM: lower dynamic energy, ~0.73x the 16 nm leakage per byte.
SRAM_7NM = MemorySpec(
    name="SRAM-7nm",
    e_read=0.50e-12,
    e_write=0.65e-12,
    leak_on=1.2986e-3 / (1 << 20),
    leak_ret=0.3420e-3 / (1 << 20),
)


# ---------------------------------------------------------------------------
# Logic / accelerator technology nodes
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TechNode:
    """A logic process node for the PULP+RBE compute cluster."""

    name: str
    e_mac: float              # J per 8-bit MAC (incl. local dataflow overhead)
    f_clk: float              # Hz
    sram: MemorySpec
    mram: Optional[MemorySpec] = None


# E_MAC for an 8-bit MAC including operand movement inside the accelerator.
# The RBE descends from the XNOR Neural Engine (21.6 fJ/op binary [5]); an
# 8-bit reconfigurable MAC at ~0.11 pJ (7 nm) / ~0.16 pJ (16 nm, 1.5x node
# scaling) is in line with that lineage.  (Calibrated; see module docstring.)
NODE_16NM = TechNode(name="16nm", e_mac=0.1635e-12, f_clk=500e6,
                     sram=SRAM_16NM, mram=MRAM_16NM)
NODE_7NM = TechNode(name="7nm", e_mac=0.109e-12, f_clk=700e6,
                    sram=SRAM_7NM, mram=None)  # no MRAM test vehicle at 7 nm

TECH_NODES = {"16nm": NODE_16NM, "7nm": NODE_7NM}


# ---------------------------------------------------------------------------
# RBE accelerator (Reconfigurable Binary Engine)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RBESpec:
    """Throughput model parameters for the RBE DNN accelerator [5].

    ``peak_mac_per_cycle`` is the paper's 133 MAC/cycle at 8-bit.
    ``weight_port_bytes_per_cycle`` is the L2-weight streaming port width that
    produces the weight-streaming-bound roofline of Fig. 4.
    ``util`` captures the engine's structural efficiency per layer kind
    (Fig. 4: regular convs near peak, pointwise lower, depthwise lowest —
    depthwise cannot fill the engine's input-channel parallelism).
    """

    peak_mac_per_cycle: float = 133.0
    weight_port_bytes_per_cycle: float = 8.0
    util_conv: float = 0.92
    util_pointwise: float = 0.55
    util_depthwise: float = 0.16
    util_fc: float = 0.50


RBE = RBESpec()

# The paper: "we assume that the on-sensor compute capability and
# corresponding memory size to be one fourth of the aggregator's."
ON_SENSOR_SCALE = 0.25

# L1 scratchpad sizes of the two processor-site classes, and the L1's
# access-energy discount vs L2 SRAM (ProcessorSite.l1_spec).  Shared by the
# scalar builders and the vectorized kernel — a single source of truth so
# the two evaluation paths cannot drift.
SENSOR_L1_BYTES = 16 * 1024
AGG_L1_BYTES = 64 * 1024
L1_ENERGY_SCALE = 0.4


# ---------------------------------------------------------------------------
# Hand-tracking system parameters (MEgATrack [8])
# ---------------------------------------------------------------------------

NUM_CAMERAS = 4                 # four monochrome cameras
IMAGE_W, IMAGE_H = 640, 480     # VGA monochrome
# The DPS of [10] quantizes at 10 bit (triple quantization, 127 dB DR); the
# raw readout stream is MIPI RAW10-packed at 1.25 B/px.  ROI crops are
# normalized to int8 by the on-sensor ISP before transmission (1 B/px).
BYTES_PER_PIXEL_RAW = 1.25
DETNET_INPUT_W, DETNET_INPUT_H = 320, 240
ROI_W, ROI_H = 96, 96           # KeyNet crop
CAMERA_FPS = 30.0               # frame delivery rate
KEYNET_FPS = 30.0               # KeyNet runs every frame
DETNET_FPS = 10.0               # DetNet re-runs every 3rd frame (ROI reuse [8])
BOX_COORDS_BYTES = 64           # detection boxes returned sensor-ward (per frame)


# ---------------------------------------------------------------------------
# Session dynamics: battery + lumped-thermal parameters (scenario engine)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BatterySpec:
    """Glasses-class battery for the session simulator (``core/scenario``).

    ``capacity_j`` is usable energy (a ~2.1 Wh cell is representative of
    the Google-Glass-class devices "Draining our Glass" characterizes).
    ``peukert`` models rate-dependent capacity loss: the effective drain
    power is ``P * (P / p_ref_w) ** (peukert - 1)``, so ``peukert=1``
    (default) is exactly linear coulomb counting — which keeps the
    closed-form battery oracle of ``tests/test_scenario.py`` bitwise.
    """

    name: str = "glass-2.1Wh"
    capacity_j: float = 2.1 * 3600.0   # usable energy (J)
    soc0: float = 1.0                  # initial state of charge [0, 1]
    peukert: float = 1.0               # 1.0 = ideal linear drain
    p_ref_w: float = 1.0               # Peukert reference draw (W)


@dataclasses.dataclass(frozen=True)
class ThermalSpec:
    """One lumped RC node (case) + throttle law for the session simulator.

    ``T' = T_amb + P*R + (T - T_amb - P*R) * exp(-dt / (R*C))`` is the
    exact step response, so the discretized trajectory matches the
    analytic exponential regardless of step size.  The throttle factor
    ``clip(1 - gain * max(0, T - onset), floor, 1)`` multiplies the
    DetNet/KeyNet inference rates; below onset it is exactly 1.0, so a
    cool device reproduces the static operating point bitwise.
    """

    name: str = "ar-frame"
    r_th_k_per_w: float = 25.0         # case-to-ambient resistance (K/W)
    c_th_j_per_k: float = 40.0         # lumped heat capacity (J/K); tau ~17min
    ambient_c: float = 25.0            # ambient temperature (degC)
    throttle_onset_c: float = 35.0     # skin-comfort throttle threshold
    throttle_gain_per_c: float = 0.25  # rate reduction per K above onset
    throttle_floor: float = 0.3        # lowest allowed rate multiplier


DEFAULT_BATTERY = BatterySpec()
DEFAULT_THERMAL = ThermalSpec()


# ---------------------------------------------------------------------------
# TPU v5e-class constants (beyond-paper adaptation + roofline analysis)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TPUChipSpec:
    """Per-chip roofline constants for the TPU target."""

    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12      # FLOP/s
    hbm_bandwidth: float = 819e9         # B/s
    ici_link_bandwidth: float = 50e9     # B/s per link
    dcn_bandwidth: float = 6.25e9        # B/s per host (inter-pod tier)
    hbm_bytes: float = 16 * (1 << 30)    # 16 GiB capacity
    vmem_bytes: float = 128 * (1 << 20)  # ~128 MiB vector memory
    # Energy constants for the adapted semi-analytical model (public
    # literature ranges for 5nm-class accelerators + optics/ICI serdes).
    e_per_flop: float = 0.25e-12         # J/FLOP (bf16 MXU, incl. local SRAM)
    e_hbm_per_byte: float = 15e-12       # J/B HBM access
    e_ici_per_byte: float = 10e-12       # J/B intra-pod ICI
    e_dcn_per_byte: float = 60e-12       # J/B inter-pod DCN (the "MIPI" tier)
    idle_power: float = 70.0             # W/chip static + fixed


TPU_V5E = TPUChipSpec()
