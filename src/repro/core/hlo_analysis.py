"""Static analysis of lowered/compiled XLA HLO text.

This is the TPU analogue of the paper's GVSoC extraction step: instead of an
event-based ISA simulator producing #MAC_j and #(Read/Write), we consume the
compiled program's ``cost_analysis()`` plus a textual parse of the HLO for
collective operations (which ``cost_analysis`` does not expose).

For every ``all-gather`` / ``all-reduce`` / ``reduce-scatter`` /
``all-to-all`` / ``collective-permute`` op we record the participating-group
size (from ``replica_groups``) and both:

* ``payload_bytes`` — the sum of operand sizes (the deliverable's metric), and
* ``wire_bytes``    — per-device bytes actually serialized on links under a
  ring algorithm (all-reduce 2(n-1)/n, all-gather/reduce-scatter (n-1)/n,
  all-to-all (n-1)/n, collective-permute 1x), used by the energy model.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Iterable

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# one shape token, e.g. ``bf16[256,4096]{1,0}`` or ``f32[]``
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\](?:\{[^}]*\})?")
# an HLO instruction line:  %name = <shapes> opcode(
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*(\(?[^)=]*?\)?)\s*"
    r"([\w\-]+)(?:-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([\d,\s]*)\}")
# e.g. replica_groups=[16,32]<=[512] — iota tile format: groups of size 32
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip()]
        return max(1, len(ids))
    return 1


@dataclasses.dataclass
class CollectiveOp:
    opcode: str
    payload_bytes: int   # sum of operand/result sizes
    group_size: int

    @property
    def wire_bytes(self) -> float:
        n = max(self.group_size, 1)
        if n == 1:
            return 0.0
        ring = (n - 1) / n
        if self.opcode == "all-reduce":
            return 2.0 * ring * self.payload_bytes
        if self.opcode in ("all-gather", "reduce-scatter", "all-to-all"):
            return ring * self.payload_bytes
        if self.opcode == "collective-permute":
            return float(self.payload_bytes)
        return float(self.payload_bytes)


@dataclasses.dataclass
class CollectiveSummary:
    ops: list[CollectiveOp]

    @property
    def total_payload_bytes(self) -> int:
        return sum(o.payload_bytes for o in self.ops)

    @property
    def total_wire_bytes(self) -> float:
        return sum(o.wire_bytes for o in self.ops)

    def by_opcode(self) -> dict[str, dict[str, float]]:
        agg: dict[str, dict[str, float]] = defaultdict(
            lambda: {"count": 0, "payload_bytes": 0, "wire_bytes": 0.0})
        for o in self.ops:
            a = agg[o.opcode]
            a["count"] += 1
            a["payload_bytes"] += o.payload_bytes
            a["wire_bytes"] += o.wire_bytes
        return dict(agg)

    def by_group_size(self) -> dict[int, float]:
        """wire bytes keyed by participating-group size.

        Group size is how we tell mesh tiers apart: on the (pod, data, model)
        mesh, collectives whose groups span the ``pod`` axis have group sizes
        that are multiples spanning pods — the DOSC 'MIPI-tier' traffic.
        """
        agg: dict[int, float] = defaultdict(float)
        for o in self.ops:
            agg[o.group_size] += o.wire_bytes
        return dict(agg)


def parse_collectives(hlo_text: str) -> CollectiveSummary:
    """Extract every collective op from HLO text (lowered or compiled)."""
    ops: list[CollectiveOp] = []
    seen_started: set[str] = set()
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        shapes_text, opcode = m.groups()
        base = opcode
        if base.endswith("-start"):
            base = base[: -len("-start")]
        if base not in COLLECTIVE_OPS:
            continue
        # async pairs appear as op-start/op-done: count the -start only;
        # plain (sync) ops have no suffix and are counted directly.
        if "-done(" in line:
            continue
        payload = _shape_bytes(shapes_text)
        # -start ops carry (operand, result) tuples; take result size once.
        if "-start(" in line and payload:
            payload //= 2 if base != "all-gather" else 1
        ops.append(CollectiveOp(base, payload, _group_size(line)))
    return CollectiveSummary(ops)


def count_op(hlo_text: str, opcode: str) -> int:
    """Count occurrences of an HLO opcode (e.g. 'fusion', 'convolution')."""
    pat = re.compile(rf"=\s*[^=]*?\b{re.escape(opcode)}(?:\.\d+)?\(")
    return sum(1 for line in hlo_text.splitlines() if pat.search(line))
