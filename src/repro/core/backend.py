"""Unified evaluation-backend layer: one chunk kernel for every engine.

Before this module existed the Eq. 1-11 evaluate-and-reduce logic lived
in three divergent copies — the scalar path of
:mod:`repro.core.partition`, the dense ``sweep.evaluate_grid`` meshgrid
path, and the streaming executor's private ``_build_step``.  All three
engines now run through the single **chunk-evaluation contract** defined
here::

    decode flat indices -> evaluate tracked channels
                        -> fold block reductions into a donated carry

* :class:`EvalBackend` — the backend protocol.  ``build_dense_eval``
  covers the first arrow only (``fn(axvals, flat) -> {field: values}``):
  the dense engine runs the whole grid as *one big chunk* through it,
  and the streaming probe / survivor-overflow fallback reuse it
  chunk-wise.  ``build_chunk_eval`` adds constraint masking, the Pareto
  dominance pre-filter, and the **block-level reductions** (per-block
  min / first-min index / valid count / max, signed block mins for the
  top-k block select, survivor keep mask) that :func:`fold_chunk`
  consumes.
* :func:`fold_chunk` — backend-independent: folds one chunk's block
  partials into the donated running carry (argmin with exact
  first-minimum tie-breaking, feasibility counts, channel bounds, the
  exact per-objective top-k merge, optional histograms) and compacts
  the dominance survivors to an O(survivors) device->host transfer.
  This is the *only* copy of the reduction code — the XLA backend
  traces it behind its inline evaluation, the Pallas backend feeds it
  from the fused ``pallas_call`` of :mod:`repro.kernels.sweep_grid`.
* :func:`build_step` / :func:`cached_step` — assemble ``eval + fold``
  into the compiled chunk step the streaming executor drives, with
  optional **scan fusion** (``scan_chunks > 1`` runs ``lax.scan`` over
  K chunk carries inside one device dispatch, cutting per-step dispatch
  overhead on 10^8-config spaces) and ``pmap`` sharding across devices.
* The **registry** (:func:`register_backend` / :func:`get_backend`) —
  the ``backend=`` knob of ``sweep.evaluate_grid``,
  ``stream.stream_grid`` and ``partition.optimal_partition``.  The
  ``"pallas"`` backend registers lazily on first request from
  :mod:`repro.kernels.sweep_grid`.

Everything here runs under the caller's scoped ``enable_x64`` context;
flat indices are int64 whenever the index space could overflow int32
(see :func:`repro.core.sweep.decode_flat_index`).
"""

from __future__ import annotations

import collections.abc
import dataclasses
import functools
import hashlib
import importlib
from collections import OrderedDict
from typing import Callable, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from . import pareto as P
from . import sweep as SW

#: Backend used when the ``backend=`` knob is ``None``.
DEFAULT_BACKEND = "xla"

_REGISTRY: "OrderedDict[str, EvalBackend]" = OrderedDict()

#: Backends that register themselves on first request (import-cost /
#: optional-dependency gating): name -> providing module.
_LAZY = {"pallas": "repro.kernels.sweep_grid"}


class EvalBackend:
    """Protocol of an evaluation backend (see the module docstring).

    Subclasses implement the two builders; ``supports_pmap`` gates the
    multi-device ``pmap`` path of :func:`build_step`, and
    ``supports_scenarios`` gates scenario-wrapped lowerings
    (``scenario.ScenarioStack`` — the session ``lax.scan`` kernel; a
    fused block kernel that re-implements the evaluation, like the
    Pallas grid kernel, must opt out until it lowers the scan too).
    """

    name: str = "?"
    supports_pmap: bool = True
    supports_scenarios: bool = True

    def build_dense_eval(self, S, shape: tuple[int, ...],
                         fields: Sequence[str]) -> Callable:
        """``fn(axvals, flat) -> {field: (n,) array}``: decode flat
        C-order indices into per-axis coordinates, gather the axis
        values, evaluate the requested channels.  ``axvals`` is the
        tuple of per-axis kernel index/value arrays (leading model
        axis included), ``flat`` any int array of grid indices."""
        raise NotImplementedError

    def build_chunk_eval(self, spec: "ChunkSpec") -> Callable:
        """``fn(axvals, aux, start) -> partials``: evaluate the chunk
        ``[start, start + spec.chunk)`` and return the block partials
        of :func:`chunk_partials` for :func:`fold_chunk` to fold."""
        raise NotImplementedError


def register_backend(backend: EvalBackend) -> EvalBackend:
    """Register ``backend`` under ``backend.name`` (last one wins)."""
    _REGISTRY[backend.name] = backend
    return backend


def available_backends() -> tuple[str, ...]:
    """Names accepted by the ``backend=`` knob (registered + lazy)."""
    return tuple(dict.fromkeys((*_REGISTRY, *_LAZY)))


def get_backend(name: str | None = None) -> EvalBackend:
    """Resolve a backend name (``None`` -> :data:`DEFAULT_BACKEND`).

    Lazily imports the providing module for deferred backends (the
    Pallas backend lives in ``repro.kernels.sweep_grid`` and registers
    on import).  Raises :class:`ValueError` naming the available
    backends for unknown names.
    """
    name = name or DEFAULT_BACKEND
    if name not in _REGISTRY and name in _LAZY:
        try:
            importlib.import_module(_LAZY[name])
        except ImportError as e:  # pragma: no cover - env-dependent
            raise ValueError(
                f"evaluation backend {name!r} is unavailable "
                f"({e}); available: {tuple(_REGISTRY)}") from e
    be = _REGISTRY.get(name)
    if be is None:
        raise ValueError(f"unknown evaluation backend {name!r}; "
                         f"available: {available_backends()}")
    return be


def check_scenario_support(be: EvalBackend, S) -> None:
    """Reject a scenario-wrapped lowering on a backend that cannot run
    the session ``lax.scan`` kernel (duck-checked via the
    ``is_scenario`` marker, so plain model stacks cost nothing)."""
    if getattr(S, "is_scenario", False) and not be.supports_scenarios:
        scen = tuple(n for n in available_backends()
                     if get_backend(n).supports_scenarios)
        raise ValueError(
            f"evaluation backend {be.name!r} does not support "
            f"scenario sweeps (the session lax.scan kernel); "
            f"scenario-capable backends: {scen}")


# ---------------------------------------------------------------------------
# The chunk contract
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ChunkSpec:
    """Static description of one chunk-evaluation problem.

    This is the compiled-step cache key: everything that shapes the
    traced computation (chunk geometry, tracked channels, constraint
    structure, filter geometry) is here; axis values, constraint bounds
    and the filter *state* are runtime arguments so compiled steps are
    reusable across grids with the same axis sizes and across filter
    refreshes.  ``S`` hashes by identity (frozen, ``eq=False``); keying
    on the object itself keeps it alive so a recycled id can never
    alias a stale compiled step.
    """

    S: object                          # arrays.StackedModelArrays
    shape: tuple[int, ...]             # full axis sizes (incl. model axis)
    n_total: int
    chunk: int
    fields: tuple[str, ...]            # tracked channels; first d objectives
    d: int                             # number of objective channels
    k: int                             # top-k table width
    sign: tuple[float, ...]            # +1 minimize / -1 maximize per obj
    cons_static: tuple[tuple[int, str], ...]   # (field index, op) pairs
    hist_bins: int
    survivor_cap: int
    small_index: bool                  # int32 decode arithmetic is safe
    filter_rows: int = 24              # dominance-filter explicit rows
    filter_bins: int = 256             # ... and prefix-min table bins

    # Block layout of the two-stage reductions: XLA CPU lowers a plain
    # full-axis reduce (and especially lax.top_k) over 2^18 lanes as a
    # scalar loop; reducing (B, W) blocks stage-wise vectorizes, and
    # the exact top-k needs only the k best blocks.
    @property
    def block(self) -> int:            # W — lanes per block
        return min(512, self.chunk)

    @property
    def n_blocks(self) -> int:         # B
        return -(-self.chunk // self.block)

    @property
    def padded(self) -> int:           # CP — lanes incl. block padding
        return self.n_blocks * self.block

    @property
    def nb(self) -> int:               # blocks gathered by the top-k select
        return min(self.k, self.n_blocks)


def decode_gather(shape: Sequence[int], axvals, flat):
    """Decode flat C-order indices and gather the per-axis kernel
    arguments — the one place "flat index -> kernel inputs" is written
    (both backends and the dense engine trace through it)."""
    coords = SW.decode_flat_index(shape, flat)
    return [v[c] for v, c in zip(axvals, coords)]


def chunk_partials(spec: ChunkSpec, F, flat, ingrid, aux) -> dict:
    """Constraint masking + block reductions of one evaluated chunk.

    The backend-independent reference expression: the XLA backend
    traces it directly behind its inline evaluation; the Pallas kernel
    of :mod:`repro.kernels.sweep_grid` computes the same quantities
    per-block inside its ``pallas_call`` (and is parity-tested against
    this).  ``F`` is the ``(n_fields, chunk)`` raw channel matrix,
    ``flat`` the chunk's flat indices, ``ingrid`` the in-grid lane
    mask.  Returns the partials dict :func:`fold_chunk` consumes, all
    lane axes padded to ``spec.padded``.
    """
    d, B, W = spec.d, spec.n_blocks, spec.block
    feas = ingrid
    for ci, (fi, op) in enumerate(spec.cons_static):
        # NaN channel values compare False, so invalid configurations
        # are infeasible under any predicate.
        feas = feas & SW.CONSTRAINT_OPS[op](F[fi], aux["cons"][ci])
    valid = jnp.isfinite(F) & feas[None, :]
    Fm = jnp.where(valid, F, jnp.inf)
    sign = np.asarray(spec.sign)
    if (sign == 1.0).all():
        Fsg = Fm[:d]
    else:
        Fsg = jnp.where(valid[:d], F[:d] * sign[:, None], jnp.inf)
    keep = P.dominance_filter_mask(aux["filter"], Fsg, xp=jnp)

    lane_pad = spec.padded - spec.chunk

    def pad2(x, fill):
        return (jnp.pad(x, ((0, 0), (0, lane_pad)), constant_values=fill)
                if lane_pad else x)

    def pad1(x, fill):
        return (jnp.pad(x, (0, lane_pad), constant_values=fill)
                if lane_pad else x)

    Fb = pad2(Fm, jnp.inf).reshape(-1, B, W)
    bmin = Fb.min(axis=2)
    flatb = pad1(flat, spec.n_total).reshape(B, W)
    bidx = jnp.where(Fb == bmin[:, :, None], flatb[None], spec.n_total
                     ).min(axis=2)
    return {
        "Fd": pad2(F[:d], jnp.nan),
        "Fsg": pad2(Fsg, jnp.inf),
        "valid": pad2(valid[:d], False),
        "keep": pad1(keep, False),
        "bmin": bmin,
        "bidx": bidx,
        "cnt": pad2(valid.astype(jnp.int32), 0).reshape(-1, B, W
                                                        ).sum(axis=2),
        "bmax": pad2(jnp.where(valid, F, -jnp.inf), -jnp.inf
                     ).reshape(-1, B, W).max(axis=2),
        "sgmin": pad2(Fsg, jnp.inf).reshape(d, B, W).min(axis=2),
    }


def init_carry(spec: ChunkSpec) -> dict:
    """Fresh running-reduction carry (numpy; the executor ships it with
    one batched ``device_put``) — strong dtypes throughout: a weak-typed
    init carry would retrace the step on its second call (outputs come
    back strong-typed)."""
    nf = len(spec.fields)
    carry = {
        "min_val": np.full((nf,), np.inf),
        "min_idx": np.full((nf,), spec.n_total, np.int64),
        "finite": np.zeros((nf,), np.int64),
        "fmin": np.full((nf,), np.inf),
        "fmax": np.full((nf,), -np.inf),
        "topk_val": np.full((spec.d, spec.k), np.inf),
        "topk_idx": np.full((spec.d, spec.k), spec.n_total, np.int64),
    }
    if spec.hist_bins:
        carry["hist"] = np.zeros((spec.d, spec.hist_bins), np.int64)
    return carry


def fold_chunk(spec: ChunkSpec, carry, partials, aux, start):
    """Fold one chunk's block partials into the donated running carry.

    The single copy of the reduction fold shared by every backend:

    * running argmin per channel — lexicographic ``(value, index)`` min
      over the block partials, so ties break toward the lower flat
      index exactly like ``np.nanargmin``'s first-minimum rule;
    * feasibility counts and channel bounds;
    * the fused exact top-k: the k best (value, flat index) pairs of
      the chunk live in the k best blocks ranked by (block min, block
      index) — any element of a lower-ranked block is beaten by >= k
      strictly smaller pairs.  ``lax.top_k`` over the signed block
      mins breaks ties toward the lower block; the gathered k*W
      candidates merge against the running ``(d, k)`` table with an
      exact two-key sort;
    * optional histograms;
    * survivor compaction: a binary search over the keep-count prefix
      sum (an order of magnitude faster than an XLA CPU scatter); the
      count is returned so the host can detect (rare) capacity
      overflow and re-derive that chunk's survivors exactly.
    """
    d, k, W = spec.d, spec.k, spec.block
    n_total = spec.n_total

    lv = partials["bmin"].min(axis=1)
    li = jnp.where(partials["bmin"] == lv[:, None], partials["bidx"],
                   n_total).min(axis=1)
    # isfinite guard: an all-invalid chunk ties at inf == inf and must
    # not swap the sentinel min_idx for an invalid config's index.
    better = (lv < carry["min_val"]) | ((lv == carry["min_val"])
                                        & jnp.isfinite(lv)
                                        & (li < carry["min_idx"]))
    new_carry = {
        "min_val": jnp.where(better, lv, carry["min_val"]),
        "min_idx": jnp.where(better, li, carry["min_idx"]),
        "finite": carry["finite"] + partials["cnt"].sum(axis=1,
                                                        dtype=jnp.int64),
        "fmin": jnp.minimum(carry["fmin"], lv),
        "fmax": jnp.maximum(carry["fmax"], partials["bmax"].max(axis=1)),
    }

    _, bsel = jax.lax.top_k(-partials["sgmin"], spec.nb)       # (d, nb)
    sgb = partials["Fsg"].reshape(d, spec.n_blocks, W)
    gath = jnp.take_along_axis(sgb, bsel[:, :, None], axis=1)
    gpos = (bsel[:, :, None] * W
            + jnp.arange(W, dtype=jnp.int64)[None, None, :])
    cand_v = jnp.concatenate(
        [carry["topk_val"], gath.reshape(d, spec.nb * W)], axis=1)
    cand_i = jnp.concatenate(
        [carry["topk_idx"], start + gpos.reshape(d, spec.nb * W)], axis=1)
    sv, si = jax.lax.sort((cand_v, cand_i), dimension=-1, num_keys=2)
    new_carry["topk_val"] = sv[:, :k]
    new_carry["topk_idx"] = si[:, :k]

    if spec.hist_bins:
        he = aux["hist_edges"]                                 # (d, bins+1)
        hist = carry["hist"]
        for oi in range(d):
            col = jnp.clip(partials["Fd"][oi], he[oi, 0], he[oi, -1])
            b = jnp.clip(
                jnp.searchsorted(he[oi], col, side="right") - 1,
                0, spec.hist_bins - 1)
            hist = hist.at[oi, b].add(
                partials["valid"][oi].astype(hist.dtype))
        new_carry["hist"] = hist

    csum = jnp.cumsum(partials["keep"].astype(jnp.int32))
    pos = jnp.minimum(
        jnp.searchsorted(csum,
                         jnp.arange(1, spec.survivor_cap + 1,
                                    dtype=jnp.int32), side="left"),
        spec.padded - 1)
    surv = (start + pos.astype(jnp.int64), partials["Fd"][:, pos].T,
            csum[-1])
    return new_carry, surv


# ---------------------------------------------------------------------------
# The XLA backend (default)
# ---------------------------------------------------------------------------


class XlaBackend(EvalBackend):
    """Pure-XLA backend: decode + evaluate traced inline so the whole
    chunk step fuses into one compiled computation."""

    name = "xla"
    supports_pmap = True

    def build_dense_eval(self, S, shape, fields):
        kernel = SW.vmapped_kernel(S)
        fields = tuple(fields)

        @jax.jit
        def evalfn(axvals, flat):
            out = kernel(*decode_gather(shape, axvals, flat))
            return {f: out[f] for f in fields}

        return evalfn

    def build_chunk_eval(self, spec: ChunkSpec):
        kernel = SW.vmapped_kernel(spec.S)

        def evalfn(axvals, aux, start):
            flat = start + jnp.arange(spec.chunk, dtype=jnp.int64)
            ingrid = flat < spec.n_total
            # int32 decode arithmetic when the flat index space fits —
            # int64 div/mod is measurably slower on CPU.
            fdec = flat.astype(jnp.int32) if spec.small_index else flat
            out = kernel(*decode_gather(spec.shape, axvals, fdec))
            F = jnp.stack([out[f] for f in spec.fields])
            # Without the barrier XLA fuses the (expensive) kernel body
            # into every reduction that consumes F, re-evaluating it
            # several times per chunk; the barrier forces one
            # materialization.
            F = jax.lax.optimization_barrier(F)
            return chunk_partials(spec, F, flat, ingrid, aux)

        return evalfn


register_backend(XlaBackend())


# ---------------------------------------------------------------------------
# Step assembly (chunk eval + fold, scan fusion, sharding) and caches
# ---------------------------------------------------------------------------


def build_step(spec: ChunkSpec, backend: str | None = None,
               scan_chunks: int = 1, n_dev: int = 1, devices=None):
    """Compile the chunk step ``(carry, axvals, aux, start) -> (carry,
    survivors)`` for one backend.

    ``scan_chunks > 1`` fuses that many consecutive chunk folds into a
    single device dispatch via ``lax.scan`` (the carry threads through;
    survivor outputs gain a leading K axis) — per-chunk Python/dispatch
    overhead is paid once per K chunks, which matters at 10^7+ configs
    where the step count runs into the hundreds.  With ``n_dev > 1``
    the step is ``pmap``-sharded (one carry per device; every argument
    device-mapped — the executor pre-replicates broadcast state).
    Results are bitwise identical across ``scan_chunks`` values: the
    fold is applied to the same chunks in the same order.
    """
    be = get_backend(backend)
    if n_dev > 1 and not be.supports_pmap:
        raise ValueError(f"backend {be.name!r} does not support the "
                         f"multi-device pmap path; pass devices= with a "
                         f"single device")
    check_scenario_support(be, spec.S)
    evalfn = be.build_chunk_eval(spec)

    def one(carry, axvals, aux, start):
        partials = evalfn(axvals, aux, start)
        return fold_chunk(spec, carry, partials, aux, start)

    if scan_chunks > 1:
        def step(carry, axvals, aux, start):
            starts = start + spec.chunk * jnp.arange(scan_chunks,
                                                     dtype=jnp.int64)
            return jax.lax.scan(lambda c, s: one(c, axvals, aux, s),
                                carry, starts)
    else:
        step = one

    if n_dev > 1:
        return jax.pmap(step, donate_argnums=(0,),
                        in_axes=(0, 0, 0, 0), devices=devices)
    return jax.jit(step, donate_argnums=(0,))


_STEP_CACHE: "OrderedDict[tuple, object]" = OrderedDict()
_STEP_CACHE_MAX = 32
_STEP_CACHE_STATS = {"hits": 0, "misses": 0, "evictions": 0}


def cached_step(spec: ChunkSpec, backend: str | None = None,
                scan_chunks: int = 1, n_dev: int = 1, devices=None):
    """LRU-cached :func:`build_step` — repeated sweeps over same-shaped
    grids are compile-free.

    The cache keys ``spec`` by the :class:`ChunkSpec` hash, which hashes
    the model stack by *identity*: two processes (or two calls that
    rebuilt their axes from scratch) get different keys even for
    byte-identical jobs.  Long-lived callers that want cross-request
    reuse therefore cache the resolved plan by content signature first
    (:func:`job_signature`, see ``repro.core.service``) and re-submit
    the same spec object.  :func:`step_cache_stats` exposes hit/miss
    counters for such callers' health surfaces.
    """
    key = (spec, backend or DEFAULT_BACKEND, scan_chunks, n_dev,
           tuple(str(dv) for dv in devices or ()))
    fn = _STEP_CACHE.get(key)
    if fn is None:
        _STEP_CACHE_STATS["misses"] += 1
        fn = build_step(spec, backend, scan_chunks, n_dev, devices)
        _STEP_CACHE[key] = fn
        while len(_STEP_CACHE) > _STEP_CACHE_MAX:
            _STEP_CACHE.popitem(last=False)
            _STEP_CACHE_STATS["evictions"] += 1
    else:
        _STEP_CACHE_STATS["hits"] += 1
        _STEP_CACHE.move_to_end(key)
    return fn


def step_cache_stats() -> dict:
    """Snapshot of the compiled-step LRU: ``hits`` / ``misses`` /
    ``evictions`` since process start plus the current ``size`` and
    ``capacity`` — the compile-reuse signal surfaced by the sweep
    service's health endpoint."""
    return dict(_STEP_CACHE_STATS, size=len(_STEP_CACHE),
                capacity=_STEP_CACHE_MAX)


def cached_dense_eval(backend: str | None, S, shape: tuple[int, ...],
                      fields: tuple[str, ...]):
    """LRU-cached :meth:`EvalBackend.build_dense_eval` (keyed by backend
    name, stacked lowering identity, grid shape and field tuple).
    ``None`` normalizes to :data:`DEFAULT_BACKEND` *before* the cache
    key, so the dense engine's default path and the streamer's
    probe/overflow-fallback share one compiled evaluator."""
    return _cached_dense_eval(backend or DEFAULT_BACKEND, S, tuple(shape),
                              tuple(fields))


@functools.lru_cache(maxsize=32)
def _cached_dense_eval(backend: str, S, shape, fields):
    be = get_backend(backend)
    check_scenario_support(be, S)
    return be.build_dense_eval(S, shape, fields)


# ---------------------------------------------------------------------------
# Carry serialization contract (checkpoint/resume, device merging)
# ---------------------------------------------------------------------------

#: Version of the carry layout produced by :func:`init_carry` /
#: :func:`fold_chunk`.  Baked into :func:`job_signature`, so a checkpoint
#: written under an older carry format can never be restored into a newer
#: executor — bump it whenever the carry pytree structure, dtypes or
#: merge semantics change.
CARRY_VERSION = 1


def carry_to_host(carry):
    """Owning host copy of a (possibly device-resident) carry pytree.

    ``np.array`` (not ``np.asarray``): on the CPU backend a zero-copy
    view of the device buffer would be corrupted the moment the next
    step *donates* that buffer, so the snapshot must own its memory.
    """
    return jax.tree_util.tree_map(lambda x: np.array(x), carry)


def merge_device_carries(carry, k: int):
    """Fold per-device reduction carries into one (host side, exact).

    Every carry reduction is associative with the exact dense-path tie
    rules — lexicographic ``(value, index)`` min for the argmin, a
    two-key sorted merge for top-k, plain sums/min/max for counts,
    bounds and histograms — so merging the ``(ndev, ...)`` stacked
    carries is order-independent and bitwise reproducible.  The merged
    tree has the exact structure and dtypes of :func:`init_carry`
    output, which makes it the **serialization form** of a sweep's
    reduction state: device-count independent, so a checkpointed carry
    restores onto any mesh (merged carry on device 0, fresh inits on
    the rest).
    """
    mv, mi = carry["min_val"], carry["min_idx"]     # (ndev, nf)
    order = np.lexsort((mi, mv), axis=0)[0]         # per-field best device
    nf = mv.shape[1]
    merged = {
        "min_val": mv[order, np.arange(nf)],
        "min_idx": mi[order, np.arange(nf)],
        "finite": carry["finite"].sum(axis=0),
        "fmin": carry["fmin"].min(axis=0),
        "fmax": carry["fmax"].max(axis=0),
    }
    tv, ti = carry["topk_val"], carry["topk_idx"]   # (ndev, d, k)
    d = tv.shape[1]
    cat_v = tv.transpose(1, 0, 2).reshape(d, -1)
    cat_i = ti.transpose(1, 0, 2).reshape(d, -1)
    out_v = np.empty((d, k))
    out_i = np.empty((d, k), np.int64)
    for oi in range(d):
        order = np.lexsort((cat_i[oi], cat_v[oi]))[:k]
        out_v[oi], out_i[oi] = cat_v[oi][order], cat_i[oi][order]
    merged["topk_val"], merged["topk_idx"] = out_v, out_i
    if "hist" in carry:
        merged["hist"] = carry["hist"].sum(axis=0)
    return merged


def stack_host_carries(carries: Sequence[dict]) -> dict:
    """Stack N host carries (:func:`init_carry` layout) into the
    ``(n, ...)`` leading-axis form :func:`merge_device_carries` folds.

    This is the bridge the multi-process worker pool uses: each worker
    persists its leased range's merged carry (already in the
    device-count-independent serialization form), and the service
    stacks the per-range carries exactly like per-device shards before
    one associative, bitwise-exact merge.  Histogram-less and
    histogram-carrying carries must not mix — the pytree structures
    differ and ``tree_map`` would fail loudly, which is the right
    outcome for a corrupted part set.
    """
    return jax.tree_util.tree_map(lambda *xs: np.stack(xs), *carries)


def _hash_update(h, obj) -> None:
    """Recursively fold ``obj`` into hash ``h`` content-wise.

    Covers everything a sweep specification is made of: scalars and
    strings, numpy/JAX arrays (dtype + shape + bytes), dataclasses (the
    stacked model arrays and their nested workload arrays, field by
    field), sequences and mappings.  Type tags and delimiters keep the
    encoding prefix-free, so e.g. ``("ab",)`` and ``("a", "b")`` hash
    differently.
    """
    if obj is None or isinstance(obj, (bool, int, float, str,
                                       np.integer, np.floating)):
        h.update(f"<{type(obj).__name__}:{obj!r}>".encode())
    elif isinstance(obj, bytes):
        h.update(b"<bytes:")
        h.update(obj)
        h.update(b">")
    elif isinstance(obj, np.ndarray):
        h.update(f"<arr:{obj.dtype}:{obj.shape}:".encode())
        h.update(np.ascontiguousarray(obj).tobytes())
        h.update(b">")
    elif dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        h.update(f"<dc:{type(obj).__name__}:".encode())
        for f in dataclasses.fields(obj):
            h.update(f.name.encode())
            h.update(b"=")
            _hash_update(h, getattr(obj, f.name))
        h.update(b">")
    elif isinstance(obj, (tuple, list)):
        h.update(b"<seq:")
        for x in obj:
            _hash_update(h, x)
        h.update(b">")
    elif isinstance(obj, collections.abc.Mapping):
        h.update(b"<map:")
        for kk in sorted(obj, key=str):
            h.update(str(kk).encode())
            h.update(b":")
            _hash_update(h, obj[kk])
        h.update(b">")
    else:
        # device arrays and other array-likes
        _hash_update(h, np.asarray(obj))


#: ChunkSpec fields folded into the job signature.  Deliberately *not*
#: ``small_index`` / ``survivor_cap`` / ``filter_*``: those shape only
#: the traced computation, never the reduction semantics (the dominance
#: filter is a pre-cull; survivor-cap overflow falls back to an exact
#: host re-derivation), so they must not invalidate checkpoints.
_SIGNATURE_SPEC_FIELDS = ("shape", "n_total", "chunk", "fields", "d", "k",
                          "sign", "cons_static", "hist_bins")


def job_signature(spec: ChunkSpec, backend: str | None, scan_chunks: int,
                  cons: Sequence[tuple[str, str, float]],
                  axis_vals: Sequence, hist_ranges=None) -> str:
    """Content hash identifying one resumable sweep job.

    Two runs share a signature iff their checkpoints are
    interchangeable: same model stack (hashed by *content*, down to
    every tech-table entry), same axes and axis values, same tracked
    fields / objectives orientation / top-k width, same constraint
    predicates and bounds, same chunk geometry and scan fusion, same
    backend, same histogram spec, same carry format version.  The
    streaming executor refuses to restore a checkpoint whose recorded
    signature differs — a stale snapshot from a different spec must
    fail loudly, never silently merge.
    """
    h = hashlib.sha256()
    _hash_update(h, ("carry-format", CARRY_VERSION))
    _hash_update(h, ("backend", backend or DEFAULT_BACKEND))
    _hash_update(h, ("scan", int(scan_chunks)))
    for name in _SIGNATURE_SPEC_FIELDS:
        _hash_update(h, (name, getattr(spec, name)))
    _hash_update(h, ("model-stack", spec.S))
    _hash_update(h, ("constraints", tuple(cons)))
    _hash_update(h, ("axes", tuple(np.asarray(a) for a in axis_vals)))
    _hash_update(h, ("hist-ranges", hist_ranges))
    return h.hexdigest()
