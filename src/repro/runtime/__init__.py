"""Distributed runtime: fault tolerance, straggler mitigation, elasticity,
deterministic fault injection, multi-tenant fair admission control, and
the framed-socket transport of the networked sweep service."""

from .admission import (AdmissionQueue, BackpressureError,  # noqa: F401
                        Deadline, TenantPolicy)
from .elastic import (MeshPlan, drop_worker, replan_mesh,  # noqa: F401
                      rescale_batch)
from .fault_injection import (DeviceLostError, FaultInjector,  # noqa: F401
                              FaultPlan, TransientDeviceError)
from .fault_tolerance import (FaultToleranceController, FTConfig,  # noqa: F401
                              RetryPolicy, StragglerDetector, WorkerState)
from .transport import SweepServer  # noqa: F401
