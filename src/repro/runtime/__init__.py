"""Distributed runtime: fault tolerance, straggler mitigation, elasticity,
deterministic fault injection, multi-tenant fair admission control, the
framed-socket transport of the networked sweep service, and the
multi-process worker pool (chunk-range leasing over a shared spool)."""

from .admission import (AdmissionQueue, BackpressureError,  # noqa: F401
                        Deadline, TenantPolicy)
from .elastic import (MeshPlan, drop_worker, replan_mesh,  # noqa: F401
                      rescale_batch)
from .fault_injection import (DeviceLostError, FaultInjector,  # noqa: F401
                              FaultPlan, TransientDeviceError)
from .fault_tolerance import (FaultToleranceController, FTConfig,  # noqa: F401
                              RetryPolicy, StragglerDetector, WorkerState)
from .transport import AuthenticationError, SweepServer  # noqa: F401
from .workers import (JobHandle, LeaseBoard, WorkerPool,  # noqa: F401
                      dispatch_job)
