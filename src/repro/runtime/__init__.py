"""Distributed runtime: fault tolerance, straggler mitigation, elasticity."""

from .elastic import MeshPlan, replan_mesh, rescale_batch  # noqa: F401
from .fault_tolerance import (FaultToleranceController, FTConfig,  # noqa: F401
                              WorkerState)
