"""Fault tolerance: heartbeats, failure detection, straggler mitigation.

At thousand-node scale the relevant failures are: a worker dies (hardware,
preemption), a worker *slows down* (thermal throttle, ECC storms — the
straggler problem), or the fabric partitions.  The controller below
implements the standard production loop:

    heartbeat -> detect (miss-count / deadline) -> decide
        dead worker      -> restart job from last checkpoint on the
                            surviving + spare workers (elastic reshape)
        straggler        -> log, then evict after ``straggler_patience``
                            consecutive slow steps (checkpoint-restart
                            without it); synchronous SPMD means one slow
                            chip gates the step, so eviction beats waiting.

Everything is deterministic and clock-injectable so the unit tests can
simulate node loss and slow nodes without wall-clock sleeps.
"""

from __future__ import annotations

import dataclasses
import statistics
from typing import Callable, Optional


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry/backoff policy of the streaming sweep executor.

    Two failure scopes, two budgets:

    * ``max_retries`` — in-place retries of a single chunk dispatch
      (the fault was raised *before* the device consumed the donated
      carry, so the step can simply run again);
    * ``max_restarts`` — full pipeline restarts from the last
      consistent snapshot (the carry may be gone: device loss, errors
      raised mid-execution), re-issuing only the chunk ranges dispatched
      since that snapshot.

    Backoff doubles from ``backoff_s`` up to ``backoff_max_s`` per
    consecutive failure.  ``step_timeout_s`` flags (accounting, not
    abort — a synchronous XLA dispatch cannot be cancelled mid-flight)
    dispatches exceeding the deadline; ``straggler_factor`` /
    ``straggler_window`` parameterize the :class:`StragglerDetector`
    the executor runs over dispatch durations.
    """

    max_retries: int = 3
    max_restarts: int = 2
    backoff_s: float = 0.05
    backoff_max_s: float = 2.0
    step_timeout_s: Optional[float] = None
    straggler_factor: float = 4.0
    straggler_window: int = 32


class StragglerDetector:
    """Single-dispatch-stream adaptation of the controller's straggler
    scan: flags dispatch durations far above the running median.

    The controller above compares workers against each other; the
    streaming executor has one synchronous dispatch stream, so the
    baseline is the rolling median of recent step times instead.
    ``record`` returns True when the duration exceeds ``factor`` times
    the median of the last ``window`` steps (after ``warmup`` samples).
    """

    def __init__(self, factor: float = 4.0, window: int = 32,
                 warmup: int = 3):
        self.factor = factor
        self.window = window
        self.warmup = warmup
        self._times: list[float] = []

    def record(self, duration_s: float) -> bool:
        flagged = (len(self._times) >= self.warmup
                   and duration_s > self.factor
                   * statistics.median(self._times))
        self._times.append(duration_s)
        if len(self._times) > self.window:
            self._times.pop(0)
        return flagged


@dataclasses.dataclass
class WorkerState:
    worker_id: int
    last_heartbeat: float = 0.0
    step_times: list[float] = dataclasses.field(default_factory=list)
    slow_streak: int = 0
    alive: bool = True


@dataclasses.dataclass
class FTConfig:
    heartbeat_interval_s: float = 10.0
    missed_heartbeats_fatal: int = 3
    straggler_factor: float = 1.5     # step_time > factor * median
    straggler_patience: int = 5       # consecutive slow steps before evict
    window: int = 20                  # step-time history window


class FaultToleranceController:
    """Tracks worker health; emits restart/evict decisions."""

    def __init__(self, num_workers: int, cfg: FTConfig = FTConfig(),
                 clock: Callable[[], float] | None = None):
        self.cfg = cfg
        self._clock = clock or (lambda: 0.0)
        self.workers = {i: WorkerState(i) for i in range(num_workers)}
        self.events: list[dict] = []

    # ---- ingest ----
    def heartbeat(self, worker_id: int, now: float | None = None):
        w = self.workers[worker_id]
        w.last_heartbeat = self._clock() if now is None else now

    def report_step(self, worker_id: int, step: int, duration_s: float):
        w = self.workers[worker_id]
        w.step_times.append(duration_s)
        if len(w.step_times) > self.cfg.window:
            w.step_times.pop(0)

    # ---- detect ----
    def dead_workers(self, now: float) -> list[int]:
        deadline = (self.cfg.heartbeat_interval_s
                    * self.cfg.missed_heartbeats_fatal)
        return [w.worker_id for w in self.workers.values()
                if w.alive and now - w.last_heartbeat > deadline]

    def straggler_scan(self) -> list[int]:
        """Flag workers whose recent step time exceeds factor x median."""
        alive = [w for w in self.workers.values() if w.alive
                 and w.step_times]
        if len(alive) < 3:
            return []
        med = statistics.median(w.step_times[-1] for w in alive)
        flagged = []
        for w in alive:
            if w.step_times[-1] > self.cfg.straggler_factor * med:
                w.slow_streak += 1
                if w.slow_streak >= self.cfg.straggler_patience:
                    flagged.append(w.worker_id)
            else:
                w.slow_streak = 0
        return flagged

    # ---- decide ----
    def tick(self, now: float) -> Optional[dict]:
        """One control-loop iteration.  Returns a decision event or None."""
        dead = self.dead_workers(now)
        if dead:
            for wid in dead:
                self.workers[wid].alive = False
            ev = {"kind": "restart_from_checkpoint", "lost": dead,
                  "survivors": self.alive_count(), "at": now}
            self.events.append(ev)
            return ev
        slow = self.straggler_scan()
        if slow:
            for wid in slow:
                self.workers[wid].alive = False
            ev = {"kind": "evict_stragglers", "evicted": slow,
                  "survivors": self.alive_count(), "at": now}
            self.events.append(ev)
            return ev
        return None

    def alive_count(self) -> int:
        return sum(1 for w in self.workers.values() if w.alive)
