"""Networked transport for the sweep service: framed JSON over sockets.

:class:`SweepServer` puts a :class:`repro.core.service.SweepService`
on a TCP or Unix-domain socket — the network face of ROADMAP item 2 —
with a deliberately tiny, dependency-free wire protocol:

* **Framing** — every message is a 4-byte big-endian length prefix
  followed by that many bytes of UTF-8 JSON (Python-extended: ``NaN``
  / ``Infinity`` tokens allowed, so result payloads round-trip
  non-finite floats).  Oversized frames are rejected before allocation
  (``max_frame``), so a corrupt or hostile length prefix cannot OOM
  the server.
* **Connections** — one accept thread plus one reader thread per
  connection; each request frame is handled inline on its connection
  thread and every response frame echoes the request's ``rid``
  correlation id.  A connection failure affects only that client:
  its requests stay admitted and journaled server-side, which is what
  makes the client's idempotent resubmit safe.
* **Liveness** — blocking operations (``result``, ``watch``) emit
  ``{"hb": true}`` heartbeat frames every ``heartbeat_s`` while the
  request runs, so a client can distinguish a slow sweep from a dead
  server without an out-of-band channel; ``ping`` gives an explicit
  round-trip probe.
* **Graceful shutdown** — :meth:`SweepServer.close` stops accepting,
  rejects new submits with a ``shutting_down`` error (retry-after
  carried), optionally drains the admitted backlog to completion, and
  only then closes the listener and connections — in-flight requests
  are never dropped by a planned shutdown.

Operations (request ``{"op": ..., "rid": ...}`` → response frames):

=========  ==========================================================
``ping``    liveness probe → ``{"pong": true}``
``submit``  ``{"request": <SweepRequest.to_json>, "client_id": ...}``
            → ``{"id", "state", "deduped"}``; overload → an ``error``
            frame of kind ``backpressure`` carrying ``queue_depth``,
            ``capacity``, ``retry_after_s`` and ``tenant``
``status``  ``{"id"}`` → the ticket summary
``result``  ``{"id", "timeout"}`` → heartbeats, then
            ``{"done": true, "state", "result": <result_to_json>}``
``watch``   ``{"id", "last_seq"}`` → ``{"snapshot": <snapshot>,
            "seq"}`` frames as consistent prefix snapshots land
            (plus heartbeats), then the final ``done`` frame
``cancel``  ``{"id"}`` → ``{"state": ...}`` (cooperative)
``health``  → the service health dict
=========  ==========================================================

Error frames are ``{"error": <kind>, "message": ...}`` with kinds
``backpressure``, ``bad_request``, ``not_found``, ``cancelled``,
``closed``, ``shutting_down`` and ``internal`` —
:class:`repro.core.client.SweepClient` maps them back to the
exceptions the in-process API raises.

Protocol 2 additions:

* **Greeting + HMAC handshake** — immediately after accept the server
  sends a fixed 21-byte greeting ``b"SWG2" + flags + nonce16``.  When
  the server holds an ``auth_token`` (flag ``0x01``), the client must
  answer with ``HMAC-SHA256(token, nonce)`` (32 raw bytes) before any
  frame; the server replies one verdict byte and drops unauthenticated
  connections *before parsing any JSON*.  Tokens never travel on the
  wire and every connection gets a fresh nonce (no replay).
* **Delta watch frames** — the first ``watch`` progress frame on a
  connection is a full ``{"snapshot", "seq"}`` baseline; subsequent
  ones are ``{"delta", "seq"}`` per-chunk argmin/front deltas
  (:func:`repro.core.stream.result_delta_to_json`), which the client
  folds back with :func:`~repro.core.stream.apply_result_delta`.  The
  final result still travels as a full exact payload.
* **Wire accounting** — ``bytes_in`` / ``bytes_out`` plus
  ``watch_snapshot_bytes`` / ``watch_delta_bytes`` counters, surfaced
  under ``health()["transport"]``.
"""

from __future__ import annotations

import contextlib
import hashlib
import hmac
import json
import os
import secrets
import socket
import struct
import threading
import time
from typing import Optional

from .admission import BackpressureError

#: Wire protocol version, echoed in ``ping`` responses.
PROTOCOL = 2

#: Greeting magic: "SWeep Grid" protocol 2.
MAGIC = b"SWG2"
_FLAG_AUTH = 0x01
_NONCE_LEN = 16
_MAC_LEN = 32
_HANDSHAKE_TIMEOUT_S = 10.0


class AuthenticationError(RuntimeError):
    """Raised client-side when the handshake fails: the server demands
    a token the client does not hold, or rejected the one it sent.
    Deliberately *not* a :class:`ConnectionError` — the client's
    reconnect loop must not retry a hopeless credential."""

#: Default cap on one frame's payload (bytes) — large enough for any
#: realistic result (fronts are O(10^3) rows), small enough that a
#: corrupt length prefix cannot balloon allocation.
MAX_FRAME = 64 * 1024 * 1024

_LEN = struct.Struct(">I")


def encode_frame(payload: dict) -> bytes:
    """Length-prefixed UTF-8 JSON encoding of one message."""
    body = json.dumps(payload, allow_nan=True,
                      separators=(",", ":")).encode("utf-8")
    if len(body) > MAX_FRAME:
        raise ValueError(f"frame of {len(body)} bytes exceeds the "
                         f"{MAX_FRAME}-byte protocol cap")
    return _LEN.pack(len(body)) + body


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise ConnectionError("connection closed mid-frame")
        buf.extend(chunk)
    return bytes(buf)


def read_frame(sock: socket.socket,
               max_frame: int = MAX_FRAME,
               stats: Optional[dict] = None) -> Optional[dict]:
    """Read one framed JSON message (``None`` on clean EOF between
    frames; :class:`ConnectionError` on a torn frame or oversized
    length prefix).  ``stats`` (any dict) gets its ``"bytes_in"`` key
    bumped by the frame's wire size — both endpoints use this for the
    delta-streaming accounting."""
    try:
        head = sock.recv(_LEN.size)
    except (TimeoutError, socket.timeout):
        raise
    if not head:
        return None
    if len(head) < _LEN.size:
        head += _recv_exact(sock, _LEN.size - len(head))
    (n,) = _LEN.unpack(head)
    if n > max_frame:
        raise ConnectionError(
            f"peer announced a {n}-byte frame (cap {max_frame}) — "
            f"corrupt stream or protocol mismatch")
    if stats is not None:
        stats["bytes_in"] = stats.get("bytes_in", 0) + _LEN.size + n
    return json.loads(_recv_exact(sock, n).decode("utf-8"))


def client_handshake(sock: socket.socket,
                     auth: Optional[str] = None) -> None:
    """Client side of the protocol-2 greeting: consume the 21-byte
    ``MAGIC + flags + nonce`` greeting and, when the server demands
    auth, answer the HMAC-SHA256 challenge and check the verdict byte.
    Raises :class:`AuthenticationError` on a missing/rejected token and
    :class:`ConnectionError` on a non-sweep peer."""
    old = sock.gettimeout()
    sock.settimeout(_HANDSHAKE_TIMEOUT_S)
    try:
        head = _recv_exact(sock, len(MAGIC) + 1 + _NONCE_LEN)
        if head[:len(MAGIC)] != MAGIC:
            raise ConnectionError(
                f"peer is not a protocol-{PROTOCOL} sweep server "
                f"(greeting {head[:4]!r})")
        flags = head[len(MAGIC)]
        nonce = head[len(MAGIC) + 1:]
        if flags & _FLAG_AUTH:
            if auth is None:
                raise AuthenticationError(
                    "server requires an auth token — pass "
                    "SweepClient(auth=...) / --auth-token")
            sock.sendall(hmac.new(auth.encode("utf-8"), nonce,
                                  hashlib.sha256).digest())
            if _recv_exact(sock, 1) != b"\x01":
                raise AuthenticationError(
                    "server rejected the auth token")
    finally:
        sock.settimeout(old)


def parse_address(address: str):
    """``"host:port"`` → ``("tcp", host, port)``; anything else is a
    Unix-domain socket path → ``("unix", path, None)``."""
    if ":" in address and not address.startswith(("/", ".")):
        host, _, port = address.rpartition(":")
        return ("tcp", host or "127.0.0.1", int(port))
    return ("unix", address, None)


class SweepServer:
    """Serve one :class:`~repro.core.service.SweepService` over a
    socket.

    Exactly one of ``(host, port)`` or ``unix_path`` selects the
    listener.  ``start()`` (or entering the context manager) binds and
    spawns the accept thread; :attr:`address` is the bound endpoint
    (useful with ``port=0``).  The server owns no service lifecycle by
    default — pass ``own_service=True`` (the CLI does) to have
    :meth:`close` also close the service.
    """

    def __init__(self, service, host: Optional[str] = None,
                 port: Optional[int] = None,
                 unix_path: Optional[str] = None,
                 heartbeat_s: float = 1.0,
                 max_frame: int = MAX_FRAME,
                 own_service: bool = False,
                 auth_token: Optional[str] = None):
        if (unix_path is None) == (port is None):
            raise ValueError("pass exactly one of (host, port) or "
                             "unix_path")
        self.service = service
        self._unix_path = unix_path
        self._host = host or "127.0.0.1"
        self._port = port
        self._heartbeat_s = float(heartbeat_s)
        self._max_frame = int(max_frame)
        self._own_service = bool(own_service)
        self._auth_token = auth_token
        self._listener: Optional[socket.socket] = None
        self._accept_thread: Optional[threading.Thread] = None
        self._conns: set = set()
        self._conn_lock = threading.Lock()
        self._closing = threading.Event()
        self._closed = threading.Event()
        self.counters = {"connections": 0, "frames_in": 0,
                         "frames_out": 0, "errors": 0,
                         "auth_failures": 0, "bytes_in": 0,
                         "bytes_out": 0, "watch_snapshot_bytes": 0,
                         "watch_delta_bytes": 0}

    # -- lifecycle -------------------------------------------------------

    def __enter__(self) -> "SweepServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    @property
    def address(self) -> str:
        if self._unix_path is not None:
            return self._unix_path
        return f"{self._host}:{self._port}"

    def start(self) -> "SweepServer":
        if self._unix_path is not None:
            try:
                os.unlink(self._unix_path)
            except FileNotFoundError:
                pass
            sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            sock.bind(self._unix_path)
        else:
            sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
            sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
            sock.bind((self._host, self._port))
            self._port = sock.getsockname()[1]
        sock.listen(64)
        self._listener = sock
        self._accept_thread = threading.Thread(
            target=self._accept_loop, daemon=True,
            name="sweep-server-accept")
        self._accept_thread.start()
        return self

    def close(self, drain: bool = True,
              timeout: Optional[float] = 60.0) -> None:
        """Graceful shutdown: stop accepting, shed new submits with a
        ``shutting_down`` error, optionally wait for every admitted
        request to finish (``drain``), then close connections and the
        listener.  In-flight requests are never dropped by a planned
        shutdown — only an unplanned kill leaves work behind, and the
        spool recovers that."""
        self._closing.set()
        if self._listener is not None:
            try:
                self._listener.close()
            except OSError:
                pass
        if drain:
            deadline = (None if timeout is None
                        else time.monotonic() + timeout)
            while self.service._queue.depth or self.service._running:
                if deadline is not None \
                        and time.monotonic() >= deadline:
                    break
                time.sleep(0.02)
        self._closed.set()
        with self._conn_lock:
            conns = list(self._conns)
        for c in conns:
            try:
                c.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                c.close()
            except OSError:
                pass
        if self._accept_thread is not None:
            self._accept_thread.join(5.0)
        if self._unix_path is not None:
            try:
                os.unlink(self._unix_path)
            except FileNotFoundError:
                pass
        if self._own_service:
            self.service.close()

    # -- connection handling ----------------------------------------------

    def _accept_loop(self) -> None:
        while not self._closing.is_set():
            try:
                conn, _ = self._listener.accept()
            except OSError:
                return      # listener closed
            with self._conn_lock:
                self._conns.add(conn)
                self.counters["connections"] += 1
            threading.Thread(target=self._serve_conn, args=(conn,),
                             daemon=True,
                             name="sweep-server-conn").start()

    def _handshake(self, conn: socket.socket) -> bool:
        """Server side of the protocol-2 greeting.  With an auth token
        configured, the connection is dropped unless the peer answers
        the fresh-nonce HMAC challenge — *before* the server parses a
        single byte of JSON from it."""
        nonce = secrets.token_bytes(_NONCE_LEN)
        flags = _FLAG_AUTH if self._auth_token is not None else 0
        conn.sendall(MAGIC + bytes([flags]) + nonce)
        self.counters["bytes_out"] += len(MAGIC) + 1 + _NONCE_LEN
        if not flags:
            return True
        old = conn.gettimeout()
        conn.settimeout(_HANDSHAKE_TIMEOUT_S)
        try:
            mac = _recv_exact(conn, _MAC_LEN)
        except (ConnectionError, OSError):
            self.counters["auth_failures"] += 1
            return False
        finally:
            conn.settimeout(old)
        self.counters["bytes_in"] += _MAC_LEN
        want = hmac.new(self._auth_token.encode("utf-8"), nonce,
                        hashlib.sha256).digest()
        if not hmac.compare_digest(mac, want):
            self.counters["auth_failures"] += 1
            with contextlib.suppress(OSError):
                conn.sendall(b"\x00")
            return False
        conn.sendall(b"\x01")
        self.counters["bytes_out"] += 1
        return True

    def _serve_conn(self, conn: socket.socket) -> None:
        wlock = threading.Lock()

        def send(payload: dict) -> int:
            data = encode_frame(payload)
            with wlock:
                conn.sendall(data)
            self.counters["frames_out"] += 1
            self.counters["bytes_out"] += len(data)
            return len(data)

        try:
            if not self._handshake(conn):
                return
            while not self._closed.is_set():
                try:
                    msg = read_frame(conn, self._max_frame,
                                     self.counters)
                except (TimeoutError, socket.timeout):
                    continue
                if msg is None:
                    return
                self.counters["frames_in"] += 1
                rid = msg.get("rid")
                try:
                    self._handle(msg, rid, send)
                except (ConnectionError, BrokenPipeError, OSError):
                    raise
                except BackpressureError as e:
                    send({"rid": rid, "error": "backpressure",
                          "message": str(e),
                          "queue_depth": e.queue_depth,
                          "capacity": e.capacity,
                          "retry_after_s": e.retry_after_s,
                          "tenant": e.tenant})
                except Exception as e:
                    self.counters["errors"] += 1
                    send({"rid": rid, "error": _error_kind(e),
                          "message": str(e)})
        except (ConnectionError, BrokenPipeError, OSError):
            pass        # client went away: its tickets stay admitted
        finally:
            with self._conn_lock:
                self._conns.discard(conn)
            try:
                conn.close()
            except OSError:
                pass

    # -- operations ---------------------------------------------------

    def _handle(self, msg: dict, rid, send) -> None:
        from ..core import service as CS
        op = msg.get("op")
        if op == "ping":
            send({"rid": rid, "pong": True, "protocol": PROTOCOL,
                  "alive": not self._closing.is_set()})
            return
        if op == "health":
            send({"rid": rid,
                  "health": {**self.service.health(),
                             "transport": dict(self.counters)}})
            return
        if op == "submit":
            if self._closing.is_set():
                send({"rid": rid, "error": "shutting_down",
                      "message": "server is draining for shutdown — "
                                 "retry against the restarted server",
                      "retry_after_s": 1.0})
                return
            req = CS.SweepRequest.from_json(msg["request"])
            before = self.service.counters["deduped"]
            t = self.service.submit(req,
                                    client_id=msg.get("client_id"))
            send({"rid": rid, "id": t.id, "state": t.state,
                  "deduped": self.service.counters["deduped"] > before})
            return
        if op in ("status", "result", "watch", "cancel"):
            t = self.service.get(msg.get("id", ""))
            if t is None:
                send({"rid": rid, "error": "not_found",
                      "message": f"unknown request id "
                                 f"{msg.get('id')!r}"})
                return
            if op == "status":
                send({"rid": rid, **t.summary()})
                return
            if op == "cancel":
                t.cancel()
                send({"rid": rid, "id": t.id, "state": t.state,
                      "cancelled": True})
                return
            if op == "result":
                self._stream_until_done(t, rid, send,
                                        msg.get("timeout"),
                                        watch=False, last_seq=0)
                return
            self._stream_until_done(t, rid, send, msg.get("timeout"),
                                    watch=True,
                                    last_seq=int(msg.get("last_seq",
                                                         0)))
            return
        send({"rid": rid, "error": "bad_request",
              "message": f"unknown op {op!r}"})

    def _stream_until_done(self, t, rid, send, timeout, watch: bool,
                           last_seq: int) -> None:
        """Block on one ticket, emitting heartbeat (and, for ``watch``,
        progress-snapshot) frames until it finishes, then the final
        result frame.  Runs on the connection's reader thread."""
        from ..core import stream as ST
        deadline = (None if timeout is None
                    else time.monotonic() + float(timeout))
        prev_snap = None
        while not t.done():
            if deadline is not None and time.monotonic() >= deadline:
                send({"rid": rid, "error": "timeout",
                      "message": f"request {t.id} not finished within "
                                 f"{timeout}s", **t.summary()})
                return
            if self._closed.is_set():
                send({"rid": rid, "error": "closed",
                      "message": "server closed while waiting"})
                return
            seq, snap = t.wait_snapshot(last_seq,
                                        timeout=self._heartbeat_s)
            if watch and seq > last_seq and snap is not None:
                last_seq = seq
                # "snapshot", not "progress": ticket summaries carry a
                # float "progress" field, and the final frame embeds a
                # summary — the streaming key must never collide with
                # it or clients would skip the final frame.
                if prev_snap is None:
                    # Full baseline first (also after a reconnecting
                    # watch — the server cannot know what the client
                    # still holds), per-chunk deltas from then on.
                    n = send({"rid": rid, "snapshot": snap,
                              "seq": seq})
                    self.counters["watch_snapshot_bytes"] += n
                else:
                    n = send({"rid": rid, "seq": seq,
                              "delta": ST.result_delta_to_json(
                                  prev_snap, snap)})
                    self.counters["watch_delta_bytes"] += n
                prev_snap = snap
            elif not t.done():
                send({"rid": rid, "hb": True, **t.summary()})
        out = {"rid": rid, "done": True, **t.summary()}
        if t._error is not None and t._result is None:
            kind = _error_kind(t._error)
            send({**out, "error": kind, "message": str(t._error)})
            return
        out["result"] = ST.result_to_json(t._result)
        send(out)


def _error_kind(e: BaseException) -> str:
    from ..core import service as CS
    if isinstance(e, BackpressureError):
        return "backpressure"
    if isinstance(e, CS.CancelledError):
        return "cancelled"
    if isinstance(e, CS.ServiceClosedError):
        return "closed"
    if isinstance(e, (ValueError, KeyError, TypeError)):
        return "bad_request"
    return "internal"
