"""Elastic scaling: recompute the mesh after losing/gaining workers.

Policy: the model axis is load-bearing (weights are sharded over it —
changing it requires resharding *math*, not just data placement), so we
keep ``model`` fixed whenever the surviving chip count allows and shrink
``data`` (and then ``pod``).  Checkpoints are stored unsharded, so restore
onto the new mesh is a plain ``device_put`` with the new shardings
(see ``repro.checkpoint``).
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    axes: tuple[str, ...]
    shape: tuple[int, ...]
    dropped_chips: int

    @property
    def chips(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


def replan_mesh(available_chips: int, model: int = 16,
                pods: int | None = None) -> MeshPlan:
    """Largest usable (pod, data, model) grid within available chips.

    Keeps ``model`` fixed (weight-sharding invariant); maximizes ``data``;
    drops remainder chips (they become hot spares).
    """
    if available_chips < model:
        # degenerate: shrink the model axis to the largest power-of-two
        # divisor that fits (full reshard)
        m = 1
        while m * 2 <= available_chips:
            m *= 2
        return MeshPlan(("data", "model"), (max(available_chips // m, 1),
                                            m),
                        available_chips - max(available_chips // m, 1) * m)
    if pods and pods > 1:
        per_pod = available_chips // pods
        data = per_pod // model
        if data >= 1:
            used = pods * data * model
            return MeshPlan(("pod", "data", "model"), (pods, data, model),
                            available_chips - used)
    data = available_chips // model
    used = data * model
    return MeshPlan(("data", "model"), (data, model),
                    available_chips - used)


def drop_worker(pool, lost_index: int) -> tuple:
    """Surviving ordered worker pool after losing one worker.

    The 1-D (pure data-parallel) specialization of :func:`replan_mesh`
    used by the streaming sweep executor: its ``pmap`` mesh has a single
    ``data`` axis of interchangeable chunk workers, so the replan is
    simply the ordered survivor pool — every survivor keeps its role
    and the lost shard's chunk ranges are re-issued from the last
    consistent snapshot.  An out-of-range ``lost_index`` (a worker we
    cannot identify) drops the last worker, so the pool always shrinks.
    """
    pool = list(pool)
    if len(pool) <= 1:
        raise ValueError("cannot drop the last worker; degrade the job "
                         "to single-device execution instead")
    if not 0 <= lost_index < len(pool):
        lost_index = len(pool) - 1
    return tuple(pool[:lost_index] + pool[lost_index + 1:])


def rescale_batch(global_batch: int, old_data: int, new_data: int,
                  keep_global: bool = True) -> int:
    """Either keep the global batch (more grad accumulation per chip) or
    scale it with the data axis (keep per-chip batch)."""
    if keep_global:
        return global_batch
    per = global_batch // old_data
    return per * new_data
