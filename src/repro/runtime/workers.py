"""Horizontal scale-out: a process-based worker pool over a shared spool.

One sweep job's flat-index space is split into contiguous chunk-range
*leases* recorded on a board file in the spool.  Worker processes
(``python -m repro.runtime.workers --spool DIR``) claim leases under an
``fcntl.flock`` critical section, run the ordinary
:func:`repro.core.stream.stream_grid` machinery over their range
(``flat_range=``), and persist the range's exact reductions as a JSON
*part*; the coordinator (:class:`repro.core.service.SweepService` or
any :class:`JobHandle` holder) folds the parts into one result with
:func:`repro.core.stream.merge_results` — bitwise-identical to a
single-process run, because the fold reuses the device-count-
independent carry contract of :func:`repro.core.backend.
merge_device_carries`.

Lease state machine (all transitions under the board flock)::

    free ──claim──▶ leased ──complete──▶ done
      ▲               │ heartbeat stale (ttl) ──▶ reclaimed by claim
      │               │                           (attempt += 1)
      └────fail───────┘        attempt > max_attempts ──▶ failed

A worker heartbeats its lease every ``ttl / 3`` seconds; a worker that
dies (crash, SIGKILL, OOM) simply stops heartbeating and the lease is
*reclaimed* by the next claimer, which resumes from the lease's own
checkpoint directory — the per-range carry snapshot written by
``stream_grid``'s ordinary checkpoint machinery — so no finished chunk
is recomputed.  A stolen lease is also safe the other way: the old
owner notices the steal on its next heartbeat and aborts
cooperatively, and even a straggler that completes anyway writes a
byte-identical part (execution is deterministic and part writes are
atomic renames), so "done" always wins.

Spool layout (per job, keyed by the plan's content signature)::

    <spool>/jobs/<sig24>/job.json      request + pinned chunk geometry
                         board.json    lease table (atomic rewrites)
                         board.lock    flock serializing mutations
                         parts/part-<i>.json   exact range reductions
                         ckpt/<i>/     per-lease resume snapshots
                         cancel        cooperative-cancel flag file

``dispatch_job`` is idempotent by signature: re-dispatching an existing
job (service restart, duplicate submit) reattaches to the same board,
leases, parts and checkpoints — the recovery path *is* the normal path.
"""

from __future__ import annotations

import argparse
import contextlib
import fcntl
import json
import math
import os
import subprocess
import sys
import threading
import time
from typing import Any, Mapping, Optional, Sequence

import numpy as np

__all__ = [
    "DEFAULT_TTL_S",
    "DEFAULT_MAX_ATTEMPTS",
    "LeaseBoard",
    "JobHandle",
    "WorkerPool",
    "dispatch_job",
    "run_lease",
    "worker_loop",
    "main",
]

DEFAULT_TTL_S = 10.0
DEFAULT_POLL_S = 0.2
#: A lease is abandoned as ``failed`` once claimed this many times
#: without completing — the brake on crash-looping jobs.
DEFAULT_MAX_ATTEMPTS = 4
BOARD_VERSION = 1


# Heavy imports (jax via core.stream / core.service) stay lazy so the
# runtime package can export this module without paying them, and so
# the service <-> workers imports never cycle at module load.

def _stream():
    from ..core import stream as ST
    return ST


def _service():
    from ..core import service as SV
    return SV


def _write_json(path: str, obj) -> None:
    """Crash-safe JSON write: temp file + fsync + atomic rename, so
    readers (which read board/part files without the lock) only ever
    see complete documents."""
    tmp = f"{path}.tmp.{os.getpid()}.{threading.get_ident()}"
    with open(tmp, "w") as f:
        json.dump(obj, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


class LeaseBoard:
    """The shared lease table of one job directory.

    Mutations (:meth:`claim` / :meth:`heartbeat` / :meth:`complete` /
    :meth:`fail`) run under ``flock(board.lock)`` and rewrite
    ``board.json`` atomically; reads (:meth:`poll`) are lock-free —
    the atomic rename guarantees a consistent document.  The board is
    process-shared state: every worker and the coordinator hold their
    own :class:`LeaseBoard` over the same directory.
    """

    def __init__(self, job_dir: str):
        self.job_dir = str(job_dir)
        self.job_path = os.path.join(self.job_dir, "job.json")
        self.board_path = os.path.join(self.job_dir, "board.json")
        self.lock_path = os.path.join(self.job_dir, "board.lock")
        self.cancel_path = os.path.join(self.job_dir, "cancel")
        self.parts_dir = os.path.join(self.job_dir, "parts")
        self._job: Optional[dict] = None

    # -- paths ----------------------------------------------------------

    def part_path(self, i: int) -> str:
        return os.path.join(self.parts_dir, f"part-{int(i)}.json")

    def ckpt_dir(self, i: int) -> str:
        return os.path.join(self.job_dir, "ckpt", str(int(i)))

    # -- documents ------------------------------------------------------

    def job(self) -> dict:
        if self._job is None:
            with open(self.job_path) as f:
                self._job = json.load(f)
        return self._job

    def read(self) -> dict:
        with open(self.board_path) as f:
            return json.load(f)

    def _write(self, board: dict) -> None:
        _write_json(self.board_path, board)

    @contextlib.contextmanager
    def _lock(self):
        with open(self.lock_path, "a+") as lf:
            fcntl.flock(lf.fileno(), fcntl.LOCK_EX)
            try:
                yield
            finally:
                fcntl.flock(lf.fileno(), fcntl.LOCK_UN)

    # -- cancel flag ----------------------------------------------------

    def cancel(self) -> None:
        with open(self.cancel_path, "w"):
            pass

    def cancelled(self) -> bool:
        return os.path.exists(self.cancel_path)

    def clear_cancel(self) -> None:
        with contextlib.suppress(FileNotFoundError):
            os.unlink(self.cancel_path)

    # -- lease transitions ----------------------------------------------

    def claim(self, wid: str, ttl: float) -> Optional[dict]:
        """Claim the lowest-index claimable lease for worker ``wid``.

        Claimable: ``free``, or ``leased`` with a heartbeat older than
        ``ttl`` seconds (the owner is presumed dead and the lease is
        *reclaimed*).  Each claim increments the lease's ``attempt``
        counter; a lease that would exceed the job's ``max_attempts``
        is marked ``failed`` instead of reissued.  Returns a copy of
        the claimed lease record, or ``None`` when nothing is
        claimable.
        """
        now = time.time()
        max_att = int(self.job().get("max_attempts", DEFAULT_MAX_ATTEMPTS))
        with self._lock():
            board = self.read()
            pick = None
            dirty = False
            for ls in board["leases"]:
                stale = (ls["state"] == "leased"
                         and now - float(ls["hb"]) > float(ttl))
                if ls["state"] != "free" and not stale:
                    continue
                if int(ls["attempt"]) >= max_att:
                    ls["state"] = "failed"
                    ls["error"] = (ls.get("error")
                                   or f"gave up after {ls['attempt']} "
                                      f"attempts")
                    dirty = True
                    continue
                pick = ls
                break
            if pick is not None:
                pick.update(state="leased", owner=os.getpid(), wid=str(wid),
                            hb=now, attempt=int(pick["attempt"]) + 1)
            if pick is not None or dirty:
                self._write(board)
            return dict(pick) if pick is not None else None

    def heartbeat(self, i: int, wid: str, frac: float = 0.0) -> bool:
        """Refresh lease ``i``'s heartbeat (and progress fraction).
        Returns ``False`` when the lease is no longer this worker's —
        stolen after a stale heartbeat, completed by a straggler race,
        or failed — which is the worker's cue to abort its range."""
        with self._lock():
            board = self.read()
            ls = board["leases"][int(i)]
            if ls["state"] != "leased" or ls["wid"] != str(wid):
                return False
            ls["hb"] = time.time()
            ls["frac"] = float(frac)
            self._write(board)
            return True

    def complete(self, i: int, wid: str, result_json: Mapping) -> None:
        """Persist lease ``i``'s exact range reductions and mark it
        ``done``.  The part file lands (atomically) *before* the state
        flips, so a ``done`` lease always has a readable part.  Done
        wins even over a steal: execution is deterministic, so a
        straggler's part is byte-identical to the thief's."""
        _write_json(self.part_path(i), dict(result_json))
        with self._lock():
            board = self.read()
            ls = board["leases"][int(i)]
            ls.update(state="done", frac=1.0, error=None)
            self._write(board)

    def fail(self, i: int, wid: str, error: str) -> None:
        """Release lease ``i`` after an execution error: back to
        ``free`` for another attempt, or ``failed`` once the attempt
        budget is spent.  No-op when the lease was stolen meanwhile."""
        max_att = int(self.job().get("max_attempts", DEFAULT_MAX_ATTEMPTS))
        with self._lock():
            board = self.read()
            ls = board["leases"][int(i)]
            if ls["state"] != "leased" or ls["wid"] != str(wid):
                return
            ls.update(
                state=("failed" if int(ls["attempt"]) >= max_att
                       else "free"),
                owner=None, wid=None, error=str(error)[:500])
            self._write(board)

    # -- coordinator reads ----------------------------------------------

    def poll(self) -> dict:
        """Lock-free job summary: overall ``fraction`` (done spans plus
        in-flight per-lease progress), ``done`` (every lease done),
        terminal ``failed`` lease records, per-state counts, and the
        raw lease list."""
        board = self.read()
        n_total = int(board["n_total"])
        folded = 0.0
        states: dict = {}
        failed = []
        done = True
        for ls in board["leases"]:
            states[ls["state"]] = states.get(ls["state"], 0) + 1
            span = int(ls["stop"]) - int(ls["start"])
            if ls["state"] == "done":
                folded += span
            elif ls["state"] == "leased":
                folded += span * float(ls.get("frac") or 0.0)
            if ls["state"] == "failed":
                failed.append(dict(ls))
            if ls["state"] != "done":
                done = False
        return {"done": done,
                "failed": failed,
                "fraction": (folded / n_total if n_total else 1.0),
                "states": states,
                "leases": board["leases"]}


# ---------------------------------------------------------------------------
# Dispatch + coordinator handle
# ---------------------------------------------------------------------------


def dispatch_job(spool: str, request, *, plan=None,
                 n_leases: Optional[int] = None,
                 max_attempts: int = DEFAULT_MAX_ATTEMPTS,
                 checkpoint_every_steps: Optional[int] = None,
                 prefetch: Optional[int] = None) -> "JobHandle":
    """Materialize (or reattach to) one job's lease board in ``spool``.

    ``request`` is a :class:`repro.core.service.SweepRequest` (it must
    be JSON-able — workers rebuild the plan from the journaled request).
    Lease boundaries are aligned to the plan's single-device dispatch
    quantum ``chunk * scan`` so every interior range stop satisfies
    :func:`~repro.core.stream.stream_grid`'s ``flat_range`` alignment
    contract.  Idempotent by plan signature: an existing job directory
    (crashed coordinator, duplicate submit) is reused as-is — leases,
    parts and checkpoints intact — after clearing any stale cancel
    flag.  ``n_leases`` bounds reclaim granularity (default: up to 8,
    never more than the step count).
    """
    SV, ST = _service(), _stream()
    req = request.normalized()
    if plan is None:
        plan = ST.plan_stream(**SV.plan_kwargs(req))
    sig = plan.signature
    jobs_root = os.path.join(str(spool), "jobs")
    job_dir = os.path.join(jobs_root, sig[:24])
    os.makedirs(jobs_root, exist_ok=True)
    with open(os.path.join(jobs_root, ".dispatch.lock"), "a+") as lf:
        fcntl.flock(lf.fileno(), fcntl.LOCK_EX)
        try:
            board = LeaseBoard(job_dir)
            if os.path.exists(board.job_path):
                if board.job()["signature"] != sig:
                    raise RuntimeError(
                        f"job dir {job_dir} holds signature "
                        f"{board.job()['signature']}, expected {sig}")
                board.clear_cancel()
                return JobHandle(job_dir, plan=plan)
            os.makedirs(board.parts_dir, exist_ok=True)
            q = int(plan.chunk) * int(plan.scan)
            steps = math.ceil(plan.n_total / q)
            want = 8 if n_leases is None else int(n_leases)
            n = max(1, min(want, steps))
            leases = []
            for i in range(n):
                lo = (i * steps) // n * q
                hi = min(((i + 1) * steps) // n * q, plan.n_total)
                leases.append({"i": i, "start": lo, "stop": hi,
                               "state": "free", "owner": None, "wid": None,
                               "hb": 0.0, "attempt": 0, "frac": 0.0,
                               "error": None})
            board._write({"version": BOARD_VERSION, "signature": sig,
                          "n_total": int(plan.n_total), "quantum": q,
                          "leases": leases})
            with open(board.lock_path, "a+"):
                pass
            # job.json lands last: its presence marks a fully-built job.
            _write_json(board.job_path, {
                "version": BOARD_VERSION, "signature": sig,
                "request": req.to_json(), "n_total": int(plan.n_total),
                "chunk": int(plan.chunk), "scan": int(plan.scan),
                "n_leases": n, "max_attempts": int(max_attempts),
                "checkpoint_every_steps": checkpoint_every_steps,
                "prefetch": prefetch, "created": time.time()})
            return JobHandle(job_dir, plan=plan)
        finally:
            fcntl.flock(lf.fileno(), fcntl.LOCK_UN)


class JobHandle:
    """Coordinator-side view of one dispatched job: progress polling,
    synthesized progress snapshots (running front folded from finished
    parts — same shape as the in-process executor's snapshots), cancel,
    and the final exact fold."""

    def __init__(self, job_dir: str, plan=None):
        self.board = LeaseBoard(job_dir)
        self.job_dir = str(job_dir)
        self.plan = plan
        job = self.board.job()
        self.signature = job["signature"]
        self.n_total = int(job["n_total"])
        req = _service().SweepRequest.from_json(job["request"])
        self.objectives = tuple(req.objectives)
        self._sign = np.array([-1.0 if o in req.maximize else 1.0
                               for o in self.objectives])
        self._front_v = np.zeros((0, len(self.objectives)))
        self._front_i = np.zeros((0,), np.int64)
        self._folded: set = set()
        self._parts: dict = {}

    def poll(self) -> dict:
        return self.board.poll()

    def cancel(self) -> None:
        self.board.cancel()

    def _part(self, i: int):
        if i not in self._parts:
            with open(self.board.part_path(i)) as f:
                self._parts[i] = _stream().result_from_json(json.load(f))
        return self._parts[i]

    def snapshot(self, st: Optional[Mapping] = None) -> dict:
        """Progress snapshot in the executor's
        :func:`~repro.core.stream._progress_snapshot` format, with the
        running front folded (exactly) from every finished part so far.
        Mid-run ``best`` can only be pessimistic; the final result goes
        through :meth:`result` and is exact."""
        ST = _stream()
        st = self.poll() if st is None else st
        for ls in st["leases"]:
            if ls["state"] == "done" and ls["i"] not in self._folded:
                try:
                    part = self._part(int(ls["i"]))
                except (FileNotFoundError, json.JSONDecodeError):
                    continue        # part rename racing the state flip
                self._front_v, self._front_i = ST._merge_into_front(
                    self._front_v, self._front_i,
                    np.asarray(part.front_values, np.float64).reshape(
                        -1, len(self.objectives)),
                    np.asarray(part.front_indices, np.int64), self._sign)
                self._folded.add(ls["i"])
        folded = int(round(float(st["fraction"]) * self.n_total))
        return ST._progress_snapshot(folded, self.n_total, self._front_v,
                                     self._front_i, self.objectives,
                                     self._sign)

    def result(self):
        """Fold every part into one bitwise-exact
        :class:`~repro.core.stream.StreamResult` (raises until the
        whole board is ``done``)."""
        st = self.poll()
        if not st["done"]:
            raise RuntimeError(
                f"job {self.signature[:12]} incomplete: {st['states']}")
        parts = [self._part(int(ls["i"])) for ls in st["leases"]]
        return _stream().merge_results(parts)


# ---------------------------------------------------------------------------
# Worker side
# ---------------------------------------------------------------------------


def _plan_for_job(job: Mapping, cache: dict):
    """Rebuild (and cache by signature) the job's plan inside a worker.

    Chunk geometry is pinned from ``job.json`` — not re-derived from
    the request — and the worker always runs single-device, so the
    per-step dispatch quantum equals the lease alignment quantum
    regardless of the coordinator's device pool.  The rebuilt plan's
    signature must equal the job's (the checkpoint key and merge
    precondition); geometry divergence fails loudly."""
    sig = job["signature"]
    if sig in cache:
        return cache[sig]
    SV, ST = _service(), _stream()
    import jax
    req = SV.SweepRequest.from_json(job["request"])
    kw = SV.plan_kwargs(req)
    kw.update(chunk_size=int(job["chunk"]), scan_chunks=int(job["scan"]),
              devices=jax.local_devices()[:1])
    plan = ST.plan_stream(**kw)
    if plan.signature != sig:
        raise RuntimeError(
            f"worker rebuilt plan signature {plan.signature[:12]} != job "
            f"{sig[:12]} (got chunk={plan.chunk} scan={plan.scan}, job "
            f"pinned chunk={job['chunk']} scan={job['scan']})")
    cache[sig] = plan
    return plan


def run_lease(board: LeaseBoard, lease: Mapping, wid: str,
              ttl: float, cache: Optional[dict] = None) -> bool:
    """Execute one claimed lease: heartbeat on a side thread (``ttl/3``
    cadence, steal/cancel detection feeding ``should_stop``), stream
    the leased flat range with per-lease checkpointing (a reclaim
    resumes from the last carry snapshot), then persist the part.
    Returns ``True`` only when the lease completed."""
    ST = _stream()
    job = board.job()
    i = int(lease["i"])
    cache = {} if cache is None else cache
    frac = [0.0]
    halt = threading.Event()
    done = threading.Event()

    def _beat():
        while not done.wait(max(0.05, float(ttl) / 3.0)):
            if not board.heartbeat(i, wid, frac[0]) or board.cancelled():
                halt.set()
                return

    beater = threading.Thread(target=_beat, daemon=True)
    beater.start()
    try:
        kw: dict = {}
        if job.get("checkpoint_every_steps") is not None:
            kw["checkpoint_every_steps"] = int(job["checkpoint_every_steps"])
        if job.get("prefetch") is not None:
            kw["prefetch"] = int(job["prefetch"])
        plan = _plan_for_job(job, cache)
        res = ST.stream_grid(
            plan=plan,
            flat_range=(int(lease["start"]), int(lease["stop"])),
            checkpoint_dir=board.ckpt_dir(i),
            should_stop=halt.is_set,
            on_progress=lambda f: frac.__setitem__(0, float(f)),
            **kw)
    except Exception as e:
        done.set()
        beater.join(timeout=1.0)
        board.fail(i, wid, f"{type(e).__name__}: {e}")
        return False
    done.set()
    beater.join(timeout=1.0)
    if res.partial:
        return False        # stolen or cancelled: checkpoint keeps progress
    board.complete(i, wid, ST.result_to_json(res))
    return True


def _job_dirs(jobs_root: str) -> list:
    """Fully-dispatched job directories, oldest first (FIFO service)."""
    try:
        names = os.listdir(jobs_root)
    except FileNotFoundError:
        return []
    out = []
    for n in names:
        p = os.path.join(jobs_root, n)
        try:
            out.append((os.path.getmtime(os.path.join(p, "job.json")), p))
        except OSError:
            continue
    return [p for _, p in sorted(out)]


def worker_loop(spool: str, wid: Optional[str] = None,
                ttl: float = DEFAULT_TTL_S,
                poll_s: float = DEFAULT_POLL_S,
                once: bool = False) -> int:
    """The worker main loop: scan the spool's jobs oldest-first, claim
    the next lease, run it, repeat.  With ``once=True`` the loop exits
    (status 0) as soon as no lease is claimable — the batch-drain mode
    the tests and benchmarks use.  The loop also exits when the spool
    directory disappears (coordinator torn down)."""
    wid = wid or f"w{os.getpid()}"
    cache: dict = {}
    jobs_root = os.path.join(str(spool), "jobs")
    while True:
        claimed = None
        for job_dir in _job_dirs(jobs_root):
            board = LeaseBoard(job_dir)
            if board.cancelled():
                continue
            try:
                lease = board.claim(wid, ttl)
            except (OSError, json.JSONDecodeError, KeyError):
                continue
            if lease is not None:
                claimed = (board, lease)
                break
        if claimed is None:
            if once:
                return 0
            if not os.path.isdir(str(spool)):
                return 1
            time.sleep(poll_s)
            continue
        run_lease(claimed[0], claimed[1], wid, ttl, cache)


# ---------------------------------------------------------------------------
# Pool manager (coordinator side)
# ---------------------------------------------------------------------------


class WorkerPool:
    """Spawn and supervise ``n`` worker subprocesses over one spool.

    Each child is pinned to a single JAX host device
    (``--xla_force_host_platform_device_count=1``) so its dispatch
    quantum matches the lease alignment, and logs to
    ``<spool>/workers/w<i>.log``.  :meth:`ensure` respawns dead
    workers (unless ``respawn=False``); :meth:`stop` drains the pool
    (SIGTERM, then SIGKILL stragglers).  Killing a worker mid-lease is
    safe by construction — that is the lease-reclaim path.
    """

    def __init__(self, spool: str, n: int, ttl_s: float = DEFAULT_TTL_S,
                 poll_s: float = 0.1, respawn: bool = True):
        self.spool = str(spool)
        self.n = int(n)
        self.ttl_s = float(ttl_s)
        self.poll_s = float(poll_s)
        self.respawn = bool(respawn)
        self._log_dir = os.path.join(self.spool, "workers")
        os.makedirs(self._log_dir, exist_ok=True)
        self._procs: list = [None] * self.n
        self._stopped = False
        for i in range(self.n):
            self._spawn(i)

    def _spawn(self, i: int) -> None:
        env = dict(os.environ)
        flags = [f for f in env.get("XLA_FLAGS", "").split()
                 if not f.startswith("--xla_force_host_platform_device_count")]
        flags.append("--xla_force_host_platform_device_count=1")
        env["XLA_FLAGS"] = " ".join(flags)
        src = os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))))
        pp = env.get("PYTHONPATH", "")
        if src not in pp.split(os.pathsep):
            env["PYTHONPATH"] = src + (os.pathsep + pp if pp else "")
        with open(os.path.join(self._log_dir, f"w{i}.log"), "ab") as log:
            self._procs[i] = subprocess.Popen(
                [sys.executable, "-m", "repro.runtime.workers",
                 "--spool", self.spool,
                 "--wid", f"w{i}.{os.getpid()}",
                 "--ttl", str(self.ttl_s),
                 "--poll", str(self.poll_s)],
                env=env, stdin=subprocess.DEVNULL,
                stdout=log, stderr=subprocess.STDOUT)

    def pids(self) -> list:
        return [p.pid for p in self._procs if p is not None]

    def alive(self) -> int:
        return sum(1 for p in self._procs
                   if p is not None and p.poll() is None)

    def ensure(self) -> int:
        """Respawn any dead worker (when ``respawn``); returns the live
        count afterwards."""
        if not self._stopped and self.respawn:
            for i, p in enumerate(self._procs):
                if p is None or p.poll() is not None:
                    self._spawn(i)
        return self.alive()

    def stop(self, timeout: float = 10.0) -> None:
        self._stopped = True
        for p in self._procs:
            if p is not None and p.poll() is None:
                with contextlib.suppress(OSError):
                    p.terminate()
        deadline = time.time() + timeout
        for p in self._procs:
            if p is None:
                continue
            with contextlib.suppress(Exception):
                p.wait(timeout=max(0.0, deadline - time.time()))
            if p.poll() is None:
                with contextlib.suppress(OSError):
                    p.kill()
                with contextlib.suppress(Exception):
                    p.wait(timeout=5.0)

    def __enter__(self) -> "WorkerPool":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.runtime.workers",
        description="Sweep worker: claim chunk-range leases from a "
                    "shared spool and stream them.")
    ap.add_argument("--spool", required=True,
                    help="spool directory shared with the coordinator")
    ap.add_argument("--wid", default=None,
                    help="worker id recorded on claimed leases "
                         "(default: w<pid>)")
    ap.add_argument("--ttl", type=float, default=DEFAULT_TTL_S,
                    help="lease heartbeat time-to-live in seconds "
                         f"(default {DEFAULT_TTL_S:g})")
    ap.add_argument("--poll", type=float, default=DEFAULT_POLL_S,
                    help="idle poll interval in seconds "
                         f"(default {DEFAULT_POLL_S:g})")
    ap.add_argument("--once", action="store_true",
                    help="exit when no lease is claimable (batch drain)")
    a = ap.parse_args(argv)
    return worker_loop(a.spool, wid=a.wid, ttl=a.ttl, poll_s=a.poll,
                       once=a.once)


if __name__ == "__main__":
    raise SystemExit(main())
