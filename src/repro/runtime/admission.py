"""Bounded, multi-tenant fair admission control for request services.

The sweep service (:mod:`repro.core.service`) accepts work through a
bounded queue: once the backlog reaches a configurable cap, further
submissions are **rejected at the door** with a
:class:`BackpressureError` that names the depth, the cap and a
``retry_after_s`` hint — never buffered without bound (memory growth
until OOM) and never blocked (a deadlock when the submitter is also
the consumer).  Rejection is the only load-shedding mechanism: work
that *was* admitted is never dropped.

Admission is **multi-tenant fair**.  Every item is offered under a
tenant name (default ``"default"``) and a priority class, and the
consumer side schedules across tenants with three composable rules:

* **Weighted fair scheduling (deficit round-robin)** — each tenant
  accrues ``weight × quantum`` of service credit per scheduler
  rotation and spends one unit per claimed request, so under
  sustained overload tenants converge to their weight share of
  completed work regardless of offered load.  A tenant whose backlog
  empties leaves the rotation with its credit reset (no hoarding
  while idle); with a single tenant the scheduler degenerates to the
  plain FIFO the pre-tenant service ran.
* **Priority classes with aging** — within a tenant, the highest
  *effective* priority is claimed first; effective priority is
  ``priority + age // aging_s``, so a low-priority request gains one
  class per ``aging_s`` seconds waited and can never starve behind a
  sustained stream of higher-priority work.  Ties (same effective
  class) serve FIFO.
* **Per-tenant pending caps** — a tenant with
  :class:`TenantPolicy` ``max_pending`` set is rejected at the door
  (with the tenant named in the :class:`BackpressureError`) once its
  queued + in-flight count reaches the cap, so one greedy tenant
  cannot occupy the whole shared backlog.  In-flight counts are
  maintained by :meth:`AdmissionQueue.take_batch` and returned by the
  consumer via :meth:`AdmissionQueue.release`.

The queue itself is deliberately small and lock-based (per-tenant
``deque``\\ s under one mutex with a condition variable): admission
happens on client threads, consumption on the service worker, and the
fusion scan (:meth:`AdmissionQueue.take_batch`) must claim a head item
plus every compatible follower atomically, which the stdlib
``queue.Queue`` cannot express.

:class:`Deadline` is the tiny monotonic-clock companion: requests
carry one, and the executor's ``should_stop`` hook polls it between
chunk dispatches.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict, deque
from typing import Callable, Dict, List, Optional

#: One unit of scheduler credit is spent per claimed request.
_COST = 1.0


class BackpressureError(RuntimeError):
    """A submission was rejected because the admission queue is full.

    Carries ``queue_depth`` (backlog at rejection time), ``capacity``
    (the cap that fired — the global backlog cap, or the tenant's
    ``max_pending`` when ``tenant`` is set), the offending ``tenant``
    (``None`` for global-capacity rejections) and ``retry_after_s``
    (an estimate of when a retry is likely to be admitted, derived
    from the queue's recent service rate) so clients can implement
    retry/backoff without parsing the message.  Raised *instead of*
    blocking or buffering — admitted work is unaffected.
    """

    def __init__(self, queue_depth: int, capacity: int,
                 reason: str = "admission queue full",
                 tenant: Optional[str] = None,
                 retry_after_s: Optional[float] = None):
        self.queue_depth = int(queue_depth)
        self.capacity = int(capacity)
        self.reason = str(reason)
        self.tenant = tenant
        self.retry_after_s = (None if retry_after_s is None
                              else float(retry_after_s))
        who = (f"tenant {tenant!r} pending" if tenant is not None
               else "queue depth")
        hint = (f"retry after ~{self.retry_after_s:.2f}s"
                if self.retry_after_s is not None
                else "retry after in-flight requests drain")
        super().__init__(
            f"{self.reason}: {who} {self.queue_depth} >= capacity "
            f"{self.capacity} — {hint}, or raise the "
            f"{'tenant cap' if tenant is not None else 'service capacity'}")


@dataclasses.dataclass(frozen=True)
class TenantPolicy:
    """Admission policy of one tenant.

    ``weight`` is the deficit-round-robin share (relative to the other
    tenants' weights — 1:3 weights converge to a 25%/75% split of
    claimed work under overload).  ``max_pending`` caps the tenant's
    queued + in-flight requests; beyond it :meth:`AdmissionQueue.offer`
    rejects with a :class:`BackpressureError` naming the tenant
    (``None`` = uncapped).
    """

    weight: float = 1.0
    max_pending: Optional[int] = None

    def __post_init__(self):
        if not (self.weight > 0.0):
            raise ValueError(f"tenant weight must be > 0, "
                             f"got {self.weight}")
        if self.max_pending is not None and int(self.max_pending) < 1:
            raise ValueError(f"max_pending must be >= 1, "
                             f"got {self.max_pending}")


@dataclasses.dataclass(frozen=True)
class Deadline:
    """A wall-deadline on the monotonic clock (``None`` = none).

    Built with :meth:`after`; ``expired()`` is what a service wires
    into ``stream_grid(should_stop=...)`` so an overdue request stops
    within one chunk dispatch and returns its consistent partial
    snapshot.
    """

    at: Optional[float] = None          # time.monotonic() timestamp

    @classmethod
    def after(cls, seconds: Optional[float]) -> "Deadline":
        """Deadline ``seconds`` from now (``None`` → no deadline)."""
        if seconds is None:
            return cls(None)
        return cls(time.monotonic() + float(seconds))

    def expired(self) -> bool:
        return self.at is not None and time.monotonic() >= self.at

    def remaining_s(self) -> Optional[float]:
        """Seconds until expiry (negative once overdue; ``None`` when
        no deadline is set)."""
        if self.at is None:
            return None
        return self.at - time.monotonic()

    @staticmethod
    def earliest(*deadlines: "Deadline") -> "Deadline":
        """The tightest of several deadlines (used when fused requests
        with different deadlines share one execution)."""
        ats = [d.at for d in deadlines if d.at is not None]
        return Deadline(min(ats)) if ats else Deadline(None)


@dataclasses.dataclass
class _Entry:
    """One queued item plus its scheduling metadata."""

    item: object
    tenant: str
    priority: int
    seq: int            # global arrival order (readmits get negatives)
    t_enq: float        # monotonic enqueue time (aging reference)


class AdmissionQueue:
    """Bounded multi-tenant queue with reject-at-capacity admission,
    weighted fair scheduling and atomic batch claiming.

    * :meth:`offer` — non-blocking admission under a tenant/priority;
      raises :class:`BackpressureError` once the global backlog
      reaches ``capacity`` or the tenant's ``max_pending`` (queued +
      in-flight) cap is hit.
    * :meth:`take_batch` — blocking (with timeout) claim of the next
      scheduled item (deficit round-robin across tenants, effective
      priority within a tenant) plus every queued item a
      ``compatible`` predicate accepts against that head, removed
      atomically under one lock (the fusion scan of the sweep
      service).  Claimed items count as in-flight for their tenant
      until :meth:`release`\\ d.
    * :meth:`readmit` — put recovered work back at the *front of its
      tenant's class*, bypassing the capacity checks: crash recovery
      must never lose admitted requests to a full queue, and recovered
      work keeps its original position ahead of new arrivals.
    * :meth:`remove` — withdraw one queued item (client cancel before
      the worker claimed it).
    * :meth:`pause` / :meth:`resume` — stop/restart claiming without
      closing admission: a paused :meth:`take_batch` blocks (up to its
      timeout) even when the backlog is non-empty.

    With every item offered under the default tenant and priority the
    scheduler is exactly the old bounded FIFO.
    """

    def __init__(self, capacity: int,
                 tenants: Optional[Dict[str, TenantPolicy]] = None,
                 aging_s: float = 30.0,
                 quantum: float = 1.0,
                 executors: int = 1):
        if int(capacity) < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        if not (aging_s > 0.0):
            raise ValueError(f"aging_s must be > 0, got {aging_s}")
        self.capacity = int(capacity)
        self.aging_s = float(aging_s)
        self.quantum = float(quantum)
        #: Parallel service width (e.g. the worker-pool size) — scales
        #: the claim-rate fallback of the ``retry_after_s`` estimate.
        self.executors = max(1, int(executors))
        self._policies: Dict[str, TenantPolicy] = dict(tenants or {})
        self._queues: "OrderedDict[str, deque]" = OrderedDict()
        self._rr: deque = deque()           # DRR rotation (active tenants)
        self._deficit: Dict[str, float] = {}
        self._inflight: Dict[str, int] = {}
        self._depth = 0
        self._seq = 0
        self._rseq = 0                      # readmit seqs count downward
        self._paused = False
        self._claim_times: deque = deque(maxlen=32)
        self._done_times: deque = deque(maxlen=32)
        self._lock = threading.Lock()
        self._not_empty = threading.Condition(self._lock)

    # -- tenant policy ----------------------------------------------------

    def set_tenant(self, name: str, weight: float = 1.0,
                   max_pending: Optional[int] = None) -> None:
        """Register (or update) one tenant's fairness policy."""
        with self._lock:
            self._policies[str(name)] = TenantPolicy(float(weight),
                                                     max_pending)

    def policy(self, name: str) -> TenantPolicy:
        with self._lock:
            return self._policies.get(name, TenantPolicy())

    # -- introspection -----------------------------------------------------

    @property
    def depth(self) -> int:
        with self._lock:
            return self._depth

    def pending(self, tenant: str = "default") -> int:
        """Queued + in-flight count of one tenant (what ``max_pending``
        is enforced against)."""
        with self._lock:
            return (len(self._queues.get(tenant, ()))
                    + self._inflight.get(tenant, 0))

    def snapshot(self) -> List:
        """Point-in-time copy of the backlog in arrival order
        (readmitted recovery work first — health reporting)."""
        with self._lock:
            entries = [e for q in self._queues.values() for e in q]
        entries.sort(key=lambda e: e.seq)
        return [e.item for e in entries]

    # -- admission ---------------------------------------------------------

    def offer(self, item, tenant: str = "default",
              priority: int = 0) -> None:
        with self._not_empty:
            pol = self._policies.get(tenant, TenantPolicy())
            if self._depth >= self.capacity:
                raise BackpressureError(
                    self._depth, self.capacity,
                    retry_after_s=self._retry_after_locked(self._depth))
            tq = self._queues.get(tenant)
            t_pending = ((len(tq) if tq is not None else 0)
                         + self._inflight.get(tenant, 0))
            if pol.max_pending is not None \
                    and t_pending >= pol.max_pending:
                raise BackpressureError(
                    t_pending, pol.max_pending,
                    reason="tenant pending cap reached", tenant=tenant,
                    retry_after_s=self._retry_after_locked(t_pending))
            self._seq += 1
            self._enqueue_locked(_Entry(item, tenant, int(priority),
                                        self._seq, time.monotonic()))
            self._not_empty.notify()

    def readmit(self, item, tenant: str = "default",
                priority: int = 0) -> None:
        with self._not_empty:
            self._rseq -= 1
            self._enqueue_locked(_Entry(item, tenant, int(priority),
                                        self._rseq, time.monotonic()),
                                 front=True)
            self._not_empty.notify()

    def _enqueue_locked(self, e: _Entry, front: bool = False) -> None:
        q = self._queues.get(e.tenant)
        if q is None:
            q = self._queues[e.tenant] = deque()
        if not q and e.tenant not in self._rr:
            self._rr.append(e.tenant)
            self._deficit.setdefault(e.tenant, 0.0)
        (q.appendleft if front else q.append)(e)
        self._depth += 1

    def remove(self, item) -> bool:
        with self._lock:
            for tenant, q in self._queues.items():
                for e in q:
                    if e.item == item:
                        q.remove(e)
                        self._depth -= 1
                        if not q:
                            self._deactivate_locked(tenant)
                        return True
            return False

    def release(self, tenant: str = "default") -> None:
        """Return one claimed item's in-flight slot (the consumer calls
        this when the item's execution finishes, successfully or not)."""
        with self._lock:
            self._done_times.append(time.monotonic())
            n = self._inflight.get(tenant, 0)
            if n > 1:
                self._inflight[tenant] = n - 1
            else:
                self._inflight.pop(tenant, None)

    # -- flow control --------------------------------------------------

    def pause(self) -> None:
        """Stop claiming (``take_batch`` blocks/returns ``[]``) while
        leaving admission open — the deterministic knob the
        backpressure/fusion tests are built on."""
        with self._lock:
            self._paused = True

    def resume(self) -> None:
        with self._not_empty:
            self._paused = False
            self._not_empty.notify_all()

    @property
    def paused(self) -> bool:
        with self._lock:
            return self._paused

    # -- scheduling ----------------------------------------------------

    def _deactivate_locked(self, tenant: str) -> None:
        # A tenant leaving the rotation resets its credit: idle tenants
        # must not hoard deficit and burst past their share later.
        try:
            self._rr.remove(tenant)
        except ValueError:
            pass
        self._deficit.pop(tenant, None)
        if not self._queues.get(tenant):
            self._queues.pop(tenant, None)

    def _effective_priority(self, e: _Entry, now: float) -> int:
        # One priority class gained per aging_s waited: a starved
        # low-priority entry eventually outranks fresh high-priority
        # arrivals.  Integer steps keep same-class FIFO ordering exact
        # (no float-age jitter between near-simultaneous arrivals).
        return e.priority + int((now - e.t_enq) // self.aging_s)

    def _pop_best_locked(self, tenant: str, now: float) -> _Entry:
        q = self._queues[tenant]
        best = min(q, key=lambda e: (-self._effective_priority(e, now),
                                     e.seq))
        q.remove(best)
        self._depth -= 1
        if not q:
            self._deactivate_locked(tenant)
        return best

    def _select_head_locked(self) -> Optional[_Entry]:
        """Deficit round-robin across active tenants; the winner's best
        effective-priority entry is popped.  ``None`` when empty."""
        if not self._rr:
            return None
        now = time.monotonic()
        while True:
            tenant = self._rr[0]
            q = self._queues.get(tenant)
            if not q:
                self._deactivate_locked(tenant)
                if not self._rr:
                    return None
                continue
            if self._deficit.get(tenant, 0.0) >= _COST:
                self._deficit[tenant] -= _COST
                return self._pop_best_locked(tenant, now)
            pol = self._policies.get(tenant, TenantPolicy())
            self._deficit[tenant] = (self._deficit.get(tenant, 0.0)
                                     + self.quantum * pol.weight)
            self._rr.rotate(-1)

    def _retry_after_locked(self, n_ahead: int) -> float:
        """Estimate of when a retry is likely to be admitted (clamped
        to [0.05s, 60s]; 1s with no service history).

        Primary signal: the recent *completion* rate — intervals
        between :meth:`release` calls — extrapolated over the backlog
        ahead.  Completions are what actually free capacity, and with
        parallel consumers they interleave, so their observed rate
        already includes the service width.  Fallback before any
        completion lands: the claim rate divided by ``executors`` — a
        single dispatcher feeding an N-wide worker pool claims on one
        thread's clock, so the raw claim interval over-estimates the
        wait by exactly that factor."""
        est = 1.0
        if len(self._done_times) >= 2:
            span = self._done_times[-1] - self._done_times[0]
            if span > 0:
                per_done = span / (len(self._done_times) - 1)
                est = per_done * (int(n_ahead) + 1)
        elif len(self._claim_times) >= 2:
            span = self._claim_times[-1] - self._claim_times[0]
            if span > 0:
                per_claim = (span / (len(self._claim_times) - 1)
                             / self.executors)
                est = per_claim * (int(n_ahead) + 1)
        return float(min(60.0, max(0.05, est)))

    def take_batch(self, timeout: Optional[float] = None,
                   compatible: Optional[Callable] = None,
                   max_batch: Optional[int] = None) -> List:
        """Claim the next scheduled item and its compatible followers.

        Blocks up to ``timeout`` seconds for a claimable item (``[]``
        on timeout, and always ``[]`` while :meth:`pause`\\ d).  The
        head is chosen by deficit round-robin across tenants and
        effective priority (with aging) within the winner; only the
        head's tenant is charged scheduler credit.  With a
        ``compatible(head, other) -> bool`` predicate, every queued
        follower it accepts — scanned across all tenants in arrival
        order — is claimed in the same critical section (at most
        ``max_batch`` items total), so a concurrent ``offer`` can
        never interleave into a claimed batch.  Every claimed item
        counts as in-flight for its tenant until :meth:`release`.
        """
        deadline = (None if timeout is None
                    else time.monotonic() + timeout)
        with self._not_empty:
            while self._paused or self._depth == 0:
                remaining = (None if deadline is None
                             else deadline - time.monotonic())
                if remaining is not None and remaining <= 0:
                    return []
                self._not_empty.wait(remaining)
            head = self._select_head_locked()
            if head is None:            # woken by a racing remove()
                return []
            claimed = [head]
            if compatible is not None:
                cap = (max_batch if max_batch is not None
                       else float("inf"))
                rest = sorted((e for q in self._queues.values()
                               for e in q), key=lambda e: e.seq)
                for e in rest:
                    if len(claimed) >= cap:
                        break
                    if compatible(head.item, e.item):
                        self._queues[e.tenant].remove(e)
                        self._depth -= 1
                        if not self._queues[e.tenant]:
                            self._deactivate_locked(e.tenant)
                        claimed.append(e)
            for e in claimed:
                self._inflight[e.tenant] = \
                    self._inflight.get(e.tenant, 0) + 1
            self._claim_times.append(time.monotonic())
            return [e.item for e in claimed]
