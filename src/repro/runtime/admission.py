"""Bounded admission control for long-lived request-driven services.

The sweep service (:mod:`repro.core.service`) accepts work through a
bounded queue: once the backlog reaches a configurable cap, further
submissions are **rejected at the door** with a
:class:`BackpressureError` that names the depth and the cap — never
buffered without bound (memory growth until OOM) and never blocked
(a deadlock when the submitter is also the consumer).  Rejection is
the only load-shedding mechanism: work that *was* admitted is never
dropped.

The queue itself is deliberately small and lock-based (a ``deque``
under one mutex with a condition variable): admission happens on
client threads, consumption on the service worker, and the fusion
scan (:meth:`AdmissionQueue.take_batch`) must claim a head item plus
every compatible follower atomically, which the stdlib ``queue.Queue``
cannot express.

:class:`Deadline` is the tiny monotonic-clock companion: requests
carry one, and the executor's ``should_stop`` hook polls it between
chunk dispatches.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Callable, List, Optional


class BackpressureError(RuntimeError):
    """A submission was rejected because the admission queue is full.

    Carries ``queue_depth`` (backlog at rejection time) and
    ``capacity`` (the configured cap) so clients can implement their
    own retry/backoff without parsing the message.  Raised *instead
    of* blocking or buffering — admitted work is unaffected.
    """

    def __init__(self, queue_depth: int, capacity: int,
                 reason: str = "admission queue full"):
        self.queue_depth = int(queue_depth)
        self.capacity = int(capacity)
        self.reason = str(reason)
        super().__init__(
            f"{self.reason}: queue depth {self.queue_depth} >= capacity "
            f"{self.capacity} — retry after in-flight requests drain, "
            f"or raise the service's capacity")


@dataclasses.dataclass(frozen=True)
class Deadline:
    """A wall-deadline on the monotonic clock (``None`` = none).

    Built with :meth:`after`; ``expired()`` is what a service wires
    into ``stream_grid(should_stop=...)`` so an overdue request stops
    within one chunk dispatch and returns its consistent partial
    snapshot.
    """

    at: Optional[float] = None          # time.monotonic() timestamp

    @classmethod
    def after(cls, seconds: Optional[float]) -> "Deadline":
        """Deadline ``seconds`` from now (``None`` → no deadline)."""
        if seconds is None:
            return cls(None)
        return cls(time.monotonic() + float(seconds))

    def expired(self) -> bool:
        return self.at is not None and time.monotonic() >= self.at

    def remaining_s(self) -> Optional[float]:
        """Seconds until expiry (negative once overdue; ``None`` when
        no deadline is set)."""
        if self.at is None:
            return None
        return self.at - time.monotonic()

    @staticmethod
    def earliest(*deadlines: "Deadline") -> "Deadline":
        """The tightest of several deadlines (used when fused requests
        with different deadlines share one execution)."""
        ats = [d.at for d in deadlines if d.at is not None]
        return Deadline(min(ats)) if ats else Deadline(None)


class AdmissionQueue:
    """Bounded FIFO with reject-at-capacity admission and atomic
    batch claiming.

    * :meth:`offer` — non-blocking admission; raises
      :class:`BackpressureError` once ``depth >= capacity``.
    * :meth:`take_batch` — blocking (with timeout) claim of the head
      item plus every queued item a ``compatible`` predicate accepts
      against that head, removed atomically under one lock (the fusion
      scan of the sweep service).
    * :meth:`readmit` — put recovered work back at the *front*,
      bypassing the capacity check: crash recovery must never lose
      admitted requests to a full queue, and recovered work keeps its
      original position ahead of new arrivals.
    * :meth:`remove` — withdraw one queued item (client cancel before
      the worker claimed it).
    """

    def __init__(self, capacity: int):
        if int(capacity) < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._items: deque = deque()
        self._lock = threading.Lock()
        self._not_empty = threading.Condition(self._lock)

    @property
    def depth(self) -> int:
        with self._lock:
            return len(self._items)

    def offer(self, item) -> None:
        with self._not_empty:
            if len(self._items) >= self.capacity:
                raise BackpressureError(len(self._items), self.capacity)
            self._items.append(item)
            self._not_empty.notify()

    def readmit(self, item) -> None:
        with self._not_empty:
            self._items.appendleft(item)
            self._not_empty.notify()

    def remove(self, item) -> bool:
        with self._lock:
            try:
                self._items.remove(item)
                return True
            except ValueError:
                return False

    def snapshot(self) -> List:
        """Point-in-time copy of the backlog (health reporting)."""
        with self._lock:
            return list(self._items)

    def take_batch(self, timeout: Optional[float] = None,
                   compatible: Optional[Callable] = None,
                   max_batch: Optional[int] = None) -> List:
        """Claim the head item and its compatible followers.

        Blocks up to ``timeout`` seconds for a head item (``[]`` on
        timeout).  With a ``compatible(head, other) -> bool``
        predicate, every queued follower it accepts is claimed in the
        same critical section — FIFO order preserved, at most
        ``max_batch`` items total — so a concurrent ``offer`` can
        never interleave into a claimed batch.
        """
        with self._not_empty:
            if not self._items and not self._not_empty.wait(timeout):
                return []
            if not self._items:      # woken by a racing remove()
                return []
            batch = [self._items.popleft()]
            if compatible is not None:
                cap = max_batch if max_batch is not None else float("inf")
                rest = []
                while self._items:
                    item = self._items.popleft()
                    if len(batch) < cap and compatible(batch[0], item):
                        batch.append(item)
                    else:
                        rest.append(item)
                self._items.extend(rest)
            return batch
