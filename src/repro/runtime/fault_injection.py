"""Deterministic fault injection for the streaming sweep executor.

Fault tolerance that is only exercised by production outages is fault
tolerance that does not work.  This module provides the executor-side
hook :class:`FaultInjector`: a callable the streaming executor
(:func:`repro.core.stream.stream_grid`, ``fault_injector=``) invokes
immediately before every chunk dispatch, which *deterministically*
injects the failure classes the recovery machinery must survive:

* **raise-on-chunk-k** (``fail_chunks=``) — a
  :class:`TransientDeviceError` fired once when the dispatch cursor
  reaches chunk ``k``; exercises the bounded in-place retry path.
* **seeded transient errors** (``transient_rate=`` + ``seed=``) — a
  per-dispatch Bernoulli draw keyed by ``(seed, flat start)``, so the
  same faults fire at the same chunks on every run (and *only once* per
  chunk, so bounded retries always converge); exercises retry under
  sustained fault rates.
* **artificial stragglers** (``straggle=``) — injected dispatch delays
  that the executor's straggler detector
  (:class:`repro.runtime.fault_tolerance.StragglerDetector`) must flag.
* **device loss** (``lose_device=``) — a :class:`DeviceLostError` naming
  a device shard; exercises the elastic replan path
  (:func:`repro.runtime.elastic.drop_worker` shrink + snapshot restore).
* **SIGKILL** (``kill_at=``) — the injector kills its own process with
  an uncatchable signal, simulating preemption of a subprocess worker;
  exercises checkpoint/resume end-to-end (the kill-resume parity tests
  and the ``benchmarks/run.py --smoke`` CI gate).

Every trigger is expressed in *absolute chunk ordinals* (``flat start //
chunk_size``), which are stable across retries, pipeline restarts,
elastic replans and checkpoint resumes — determinism is what makes the
recovery paths assertable in CI rather than observable in production.
"""

from __future__ import annotations

import dataclasses
import os
import signal
import time
from typing import Mapping, Optional


class TransientDeviceError(RuntimeError):
    """A failure worth retrying: transient device/dispatch error."""


class DeviceLostError(RuntimeError):
    """A device shard died; the executor must replan elastically."""

    def __init__(self, message: str = "device lost", device_index: int = 0):
        super().__init__(message)
        self.device_index = device_index


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Declarative description of the faults to inject (all optional).

    Chunk triggers (``fail_chunks``, ``straggle``, ``lose_device``,
    ``kill_at``) fire **once**, at the first dispatch whose chunk
    ordinal reaches the trigger — with scan fusion or pmap sharding one
    dispatch covers several chunks, so "reaches" is ``>=``, never
    ``==``.
    """

    #: Chunk ordinals at which to raise :class:`TransientDeviceError`
    #: once each (raise-on-chunk-k).
    fail_chunks: tuple[int, ...] = ()
    #: Per-dispatch probability of a seeded transient error.
    transient_rate: float = 0.0
    #: Seed for the transient draws (keyed with the dispatch flat start).
    seed: int = 0
    #: Cap on rate-injected transient errors (None = unbounded).
    max_transient: Optional[int] = None
    #: chunk ordinal -> extra seconds of injected dispatch latency.
    straggle: Optional[Mapping[int, float]] = None
    #: (chunk ordinal, device index): raise :class:`DeviceLostError`.
    lose_device: Optional[tuple[int, int]] = None
    #: Chunk ordinal at which to SIGKILL the current process.
    kill_at: Optional[int] = None


class FaultInjector:
    """Callable executor hook injecting the faults of a :class:`FaultPlan`.

    The executor calls ``injector(chunk_ordinal, flat_start)`` before
    each dispatch.  ``injected`` counts what actually fired (for test
    assertions): ``{"transient": n, "device_lost": n, "straggle": n,
    "kill": n}``.
    """

    def __init__(self, plan: FaultPlan = FaultPlan()):
        self.plan = plan
        self.injected = {"transient": 0, "device_lost": 0,
                         "straggle": 0, "kill": 0}
        self._fired: set = set()

    def _once(self, kind: str, trigger) -> bool:
        """True the first time the cursor reaches ``trigger``."""
        key = (kind, trigger)
        if key in self._fired:
            return False
        self._fired.add(key)
        return True

    def __call__(self, chunk_ordinal: int, flat_start: int) -> None:
        plan = self.plan
        if plan.kill_at is not None and chunk_ordinal >= plan.kill_at \
                and self._once("kill", plan.kill_at):
            self.injected["kill"] += 1
            os.kill(os.getpid(), signal.SIGKILL)   # pragma: no cover
        if plan.lose_device is not None \
                and chunk_ordinal >= plan.lose_device[0] \
                and self._once("lost", plan.lose_device[0]):
            self.injected["device_lost"] += 1
            raise DeviceLostError(
                f"injected device loss at chunk {chunk_ordinal}",
                device_index=plan.lose_device[1])
        if plan.straggle:
            for trig, delay_s in plan.straggle.items():
                if chunk_ordinal >= trig and self._once("slow", trig):
                    self.injected["straggle"] += 1
                    time.sleep(delay_s)
        for trig in plan.fail_chunks:
            if chunk_ordinal >= trig and self._once("fail", trig):
                self.injected["transient"] += 1
                raise TransientDeviceError(
                    f"injected transient fault at chunk {chunk_ordinal}")
        if plan.transient_rate > 0.0 and ("rate", flat_start) not in \
                self._fired:
            import numpy as np
            draw = np.random.default_rng(
                (plan.seed, flat_start)).random()
            if draw < plan.transient_rate and (
                    plan.max_transient is None
                    or self.injected["transient"] < plan.max_transient):
                # Fail each dispatch at most once so bounded retries
                # always converge at any injection rate.
                self._fired.add(("rate", flat_start))
                self.injected["transient"] += 1
                raise TransientDeviceError(
                    f"injected seeded transient fault at chunk "
                    f"{chunk_ordinal} (rate {plan.transient_rate})")
