"""Deterministic synthetic LM data pipeline with sharding and prefetch.

Production properties this pipeline provides:

* **Determinism & resumability** — batch ``i`` is a pure function of
  (seed, step): restarting from a checkpoint at step ``k`` replays the
  exact stream from ``k`` with no state files.
* **Per-rank sharding** — each data-parallel rank draws only its slice
  (keyed by ``(step, rank)``), so no rank ever materializes the global
  batch.
* **Background prefetch** — a thread keeps ``prefetch_depth`` batches
  ready so the accelerator never waits on host-side generation (the
  camera/ISP stage of the paper's pipeline, in LM clothes).
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import jax
import numpy as np

from repro.models.common import ModelConfig
from repro.models.transformer import Batch


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    seed: int = 0
    num_ranks: int = 1
    rank: int = 0
    prefetch_depth: int = 2

    @property
    def per_rank_batch(self) -> int:
        assert self.global_batch % self.num_ranks == 0
        return self.global_batch // self.num_ranks


class SyntheticLM:
    """Zipf-ish synthetic token stream (deterministic per (seed, step, rank)).

    Tokens follow a power-law marginal with short-range repetition so the
    loss curve actually moves during the example training runs.
    """

    def __init__(self, cfg: ModelConfig, dc: DataConfig):
        self.cfg = cfg
        self.dc = dc
        # fixed power-law over the vocab
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = 1.0 / ranks ** 1.1
        self._probs = p / p.sum()

    def batch_at(self, step: int) -> Batch:
        dc = self.dc
        rng = np.random.default_rng(
            np.random.SeedSequence([dc.seed, step, dc.rank]))
        b, s = dc.per_rank_batch, dc.seq_len
        toks = rng.choice(self.cfg.vocab_size, size=(b, s + 1),
                          p=self._probs).astype(np.int32)
        # short-range structure: repeat previous token with prob 0.3
        rep = rng.random((b, s + 1)) < 0.3
        for t in range(1, s + 1):
            toks[:, t] = np.where(rep[:, t], toks[:, t - 1], toks[:, t])
        return Batch(tokens=toks[:, :-1], labels=toks[:, 1:])

    def __iter__(self) -> Iterator[Batch]:
        return self.iterate(0)

    def iterate(self, start_step: int) -> Iterator[Batch]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1


class PrefetchIterator:
    """Background-thread prefetcher over any batch iterator."""

    _SENTINEL = object()

    def __init__(self, it: Iterator[Batch], depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = it
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        try:
            for item in self._it:
                if self._stop.is_set():
                    return
                self._q.put(item)
        finally:
            self._q.put(self._SENTINEL)

    def __iter__(self):
        return self

    def __next__(self) -> Batch:
        item = self._q.get()
        if item is self._SENTINEL:
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass


def make_pipeline(cfg: ModelConfig, dc: DataConfig,
                  start_step: int = 0) -> PrefetchIterator:
    ds = SyntheticLM(cfg, dc)
    return PrefetchIterator(ds.iterate(start_step),
                            depth=dc.prefetch_depth)
