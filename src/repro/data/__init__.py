"""Deterministic synthetic data pipeline with sharding + prefetch."""

from .pipeline import (DataConfig, PrefetchIterator, SyntheticLM,  # noqa: F401
                       make_pipeline)
