"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel ships as a subpackage: ``kernel.py`` (pl.pallas_call +
BlockSpec VMEM tiling), ``ops.py`` (jit'd wrapper), ``ref.py`` (pure-jnp
oracle).  Kernels are validated on CPU with interpret=True; TPU is the
lowering target.

* ``flash_attention`` — fused online-softmax attention (GQA, causal,
  sliding window, logit softcap);
* ``rbe_matmul``      — the paper's 8-bit RBE engine adapted to the MXU:
  int8 x int8 -> int32 blocked matmul with per-channel dequant;
* ``rmsnorm``         — fused bandwidth-bound normalization;
* ``sweep_grid``      — the ``backend="pallas"`` lowering of the
  design-space engines: flat-index decode + Eq. 1-11 evaluation +
  constraint mask + dominance pre-filter + block argmin/top-k/bounds/
  count reductions fused into one pallas_call (registers itself with
  ``repro.core.backend`` on import).
"""
