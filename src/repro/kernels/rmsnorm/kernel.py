"""Fused RMSNorm Pallas kernel.

RMSNorm is bandwidth-bound (one read + one write of the activation, a
handful of flops per element); fusing the variance reduction, rsqrt and
scale into one VMEM-resident pass halves its HBM traffic vs the naive
three-op lowering.  Used by every block of every assigned architecture.

Grid: rows / block_rows; each instance owns (block_rows, d) in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * (1.0 + scale_ref[...].astype(jnp.float32))
                  ).astype(o_ref.dtype)


def rmsnorm_fused(x, scale, *, eps: float = 1e-6, block_rows: int = 256,
                  interpret: bool = True):
    """x: (..., d); scale: (d,). Returns rmsnorm(x) * (1 + scale)."""
    orig_shape = x.shape
    d = x.shape[-1]
    x2 = x.reshape(-1, d)
    rows = x2.shape[0]
    block_rows = min(block_rows, rows)
    while rows % block_rows:
        block_rows //= 2
    block_rows = max(block_rows, 1)

    kernel = functools.partial(_rmsnorm_kernel, eps=eps)
    out = pl.pallas_call(
        kernel,
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x2, scale)
    return out.reshape(orig_shape)
