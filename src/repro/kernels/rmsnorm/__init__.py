from .ops import rmsnorm  # noqa: F401
from .ref import rmsnorm_ref  # noqa: F401
