"""Jit'd public wrapper for the fused RMSNorm kernel."""

from __future__ import annotations

import functools

import jax

from .kernel import rmsnorm_fused


@functools.partial(jax.jit, static_argnames=("eps", "block_rows",
                                             "interpret"))
def rmsnorm(x, scale, *, eps: float = 1e-6, block_rows: int = 256,
            interpret: bool = True):
    return rmsnorm_fused(x, scale, eps=eps, block_rows=block_rows,
                         interpret=interpret)
