"""Jit'd public wrapper for the Pallas flash-attention kernel."""

from __future__ import annotations

import functools

import jax

from .kernel import flash_attention_fwd


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "logit_softcap", "block_q", "block_kv", "scale",
    "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    logit_softcap: float = 0.0, block_q: int = 256,
                    block_kv: int = 512, scale: float | None = None,
                    interpret: bool = True):
    """Fused attention on TPU (interpret=True validates on CPU).

    Constraints (asserted): head_dim % 128 == 0 on TPU targets is
    recommended for MXU alignment; block sizes must tile the sequence.
    """
    b, sq, h, d = q.shape
    _, skv, kvh, _ = k.shape
    assert h % kvh == 0, "q heads must be a multiple of kv heads"
    assert sq % min(block_q, sq) == 0
    assert skv % min(block_kv, skv) == 0
    return flash_attention_fwd(
        q, k, v, causal=causal, window=window,
        logit_softcap=logit_softcap, block_q=block_q, block_kv=block_kv,
        scale=scale, interpret=interpret)
