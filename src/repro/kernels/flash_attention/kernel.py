"""Pallas TPU flash-attention kernel (forward).

Grid: (batch, kv_head, q_blocks) — each program instance owns one
(q_block x head_dim) output tile and loops over kv blocks with the online-
softmax recurrence, keeping the score tile in VMEM.  This is the TPU-native
twin of ``repro.models.flash`` (same algorithm, same block enumeration);
the lowering-path version is what the dry-run compiles, this kernel is what
a real v5e deployment runs.

Tiling:
* ``block_q x head_dim`` q tile and ``block_kv x head_dim`` k/v tiles live
  in VMEM;  with the defaults (256 x 128, 512 x 128, fp32 accumulators)
  the working set is ~1.4 MiB — far below the ~16 MiB/core VMEM budget,
  leaving room for double buffering.
* head_dim and block sizes must be multiples of 128 (MXU lane alignment) —
  asserted in ops.py.

GQA is handled by the grid: all ``g = H / KV`` q-heads of one kv head are
folded into the q tile's second dim, so k/v tiles are fetched once per kv
head (the weight-streaming economy the paper's RBE roofline is about).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -2.0e38


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, *,
                      block_kv: int, seq_kv: int, causal: bool,
                      window: int, logit_softcap: float, scale: float,
                      seq_offset: int, block_q: int):
    """One (batch, kv_head, q_block) program instance.

    q_ref: (block_q, g, d) VMEM tile
    k_ref/v_ref: (seq_kv, d) VMEM (whole kv stream for this head)
    o_ref: (block_q, g, d)
    """
    qi = pl.program_id(2)
    _, bq, _, g, d = q_ref.shape                    # (1, bq, 1, g, d)
    q = q_ref[...].astype(jnp.float32) * scale
    q2 = q.reshape(bq * g, d)
    k_all = k_ref[...].reshape(seq_kv, d)           # VMEM-resident stream
    v_all = v_ref[...].reshape(seq_kv, d)

    n_kv = seq_kv // block_kv

    def body(kj, carry):
        o, m, l = carry
        k = jax.lax.dynamic_slice_in_dim(
            k_all, kj * block_kv, block_kv).astype(jnp.float32)
        v = jax.lax.dynamic_slice_in_dim(
            v_all, kj * block_kv, block_kv).astype(jnp.float32)
        s = jax.lax.dot_general(q2, k, (((1,), (1,)), ((), ())))
        if logit_softcap:
            s = logit_softcap * jnp.tanh(s / logit_softcap)
        # masking in absolute positions
        qpos = (qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (bq, g), 0) + seq_offset).reshape(bq * g, 1)
        kpos = kj * block_kv + jax.lax.broadcasted_iota(
            jnp.int32, (bq * g, block_kv), 1)
        mask = jnp.ones_like(kpos, dtype=jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_blk = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m, m_blk)
        m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
        p = jnp.exp(s - m_safe[:, None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.where(m <= NEG_INF / 2, 0.0, jnp.exp(m - m_safe))
        l_new = l * alpha + jnp.sum(p, axis=-1)
        o_new = o * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        return o_new, m_new, l_new

    o0 = jnp.zeros((bq * g, d), jnp.float32)
    m0 = jnp.full((bq * g,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq * g,), jnp.float32)

    if causal:
        # only kv blocks intersecting the causal triangle for this q block
        hi_pos = qi * block_q + block_q - 1 + seq_offset
        n_iter = jnp.minimum(hi_pos // block_kv + 1, n_kv)
    else:
        n_iter = n_kv
    o, m, l = jax.lax.fori_loop(0, n_iter, body, (o0, m0, l0))
    o = o / jnp.maximum(l[:, None], 1e-30)
    o_ref[...] = o.reshape(1, bq, 1, g, d).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True, window: int = 0,
                        logit_softcap: float = 0.0, block_q: int = 256,
                        block_kv: int = 512, scale: float | None = None,
                        interpret: bool = True):
    """q: (B, Sq, H, D); k/v: (B, Skv, KV, D) -> (B, Sq, H, D)."""
    b, sq, h, d = q.shape
    _, skv, kvh, _ = k.shape
    g = h // kvh
    block_q = min(block_q, sq)
    block_kv = min(block_kv, skv)
    scale = float(scale if scale is not None else d ** -0.5)
    n_q = sq // block_q

    # layout: fold (H) -> (KV, g); kv stream per (batch, kv_head)
    q4 = q.reshape(b, sq, kvh, g, d)

    kernel = functools.partial(
        _flash_fwd_kernel, block_kv=block_kv, seq_kv=skv, causal=causal,
        window=window, logit_softcap=logit_softcap, scale=scale,
        seq_offset=skv - sq, block_q=block_q)

    out = pl.pallas_call(
        kernel,
        grid=(b, kvh, n_q),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, g, d),
                         lambda bi, hi, qi: (bi, qi, hi, 0, 0)),
            pl.BlockSpec((1, skv, 1, d), lambda bi, hi, qi: (bi, 0, hi, 0)),
            pl.BlockSpec((1, skv, 1, d), lambda bi, hi, qi: (bi, 0, hi, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, g, d),
                               lambda bi, hi, qi: (bi, qi, hi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sq, kvh, g, d), q.dtype),
        interpret=interpret,
    )(q4, k, v)
    return out.reshape(b, sq, h, d)
