"""Pure-jnp oracle for the flash-attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        logit_softcap: float = 0.0,
                        scale: float | None = None):
    """q: (B, Sq, H, D); k/v: (B, Skv, KV, D). Full softmax attention."""
    b, sq, h, d = q.shape
    _, skv, kvh, _ = k.shape
    g = h // kvh
    scale = scale if scale is not None else d ** -0.5
    qg = q.reshape(b, sq, kvh, g, d).astype(jnp.float32)
    s = jnp.einsum("bqkgd,btkd->bqkgt", qg,
                   k.astype(jnp.float32)) * scale
    if logit_softcap:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    qpos = jnp.arange(sq) + (skv - sq)
    kpos = jnp.arange(skv)
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqkgt,btkd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, h, d).astype(q.dtype)
