"""Fused design-space grid chunk kernel (Pallas).

The ``backend="pallas"`` lowering of the evaluation-backend contract
(:mod:`repro.core.backend`): one ``pl.pallas_call`` fuses

* the mixed-radix **flat-index decode** (`sweep.decode_flat_index`,
  traced per block) and the axis-value gather,
* the **Eq. 1-11 evaluation** (the same vmapped kernel every engine
  runs, `sweep.vmapped_kernel`),
* the compiled **constraint mask** and the Pareto **dominance
  pre-filter** (`pareto.dominance_filter_mask`, the identical
  expression the XLA backend traces),
* and the per-block **argmin / top-k / bounds / count reductions**
  (block min, first-min flat index, valid count, max, signed block
  mins for the exact top-k block select, survivor keep mask),

so one kernel launch turns a chunk of flat indices into exactly the
block partials :func:`repro.core.backend.fold_chunk` folds into the
donated running carry.  Parity with the XLA backend is pinned by
``tests/test_backend.py`` (and the :mod:`.ref` oracle).

Grid: ``(n_blocks,)`` over the chunk; each program instance evaluates
one ``W``-lane block (``W = spec.block``, 512 — a multiple of the
128-wide VPU lanes; per-block partials land in ``(n_fields, 1)``
blocks).  Grid geometry, tracked channels and the model tables are
compile-time constants; axis values, constraint bounds, the filter
state and the chunk start are runtime inputs, so the compiled call is
reusable across filter refreshes and same-shaped grids (the same
contract as the XLA backend).

Validated on CPU with ``interpret=True`` (the CI parity gate); TPU is
the lowering target.  The kernel body sticks to elementwise math,
small-table gathers and lane-axis reductions — the pieces that lower
to the VPU — but the gathers over the layer tables mean a compiled
TPU build wants the tables staged through SMEM/VMEM scalar prefetch;
interpret mode sidesteps that.
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import pareto as P
from repro.core import sweep as SW


def _full_spec(shape):
    """BlockSpec mapping the whole array into every program instance."""
    nd = len(shape)
    return pl.BlockSpec(shape, lambda i, _nd=nd: (0,) * _nd)


def _split_tables(S):
    """Lift every ndarray field out of the (nested) model-table
    dataclasses.

    Pallas kernels may not capture array constants — the Eq. 1-11 kernel
    closes over the layer/payload/technology tables, so they must enter
    the ``pallas_call`` as explicit inputs.  Returns ``(leaves, spec)``:
    the arrays in deterministic field order plus a nested name->index
    spec :func:`_rebuild_tables` uses to reassemble an identical
    dataclass whose array fields are the kernel-loaded refs.
    """
    leaves: list[np.ndarray] = []

    def collect(obj):
        spec = {}
        for f in dataclasses.fields(obj):
            v = getattr(obj, f.name)
            if isinstance(v, np.ndarray):
                spec[f.name] = len(leaves)
                leaves.append(v)
            elif dataclasses.is_dataclass(v):
                spec[f.name] = collect(v)
        return spec

    return leaves, collect(S)


def _rebuild_tables(obj, spec, arrays):
    """Reassemble a table dataclass with array fields replaced by the
    given (loaded) arrays — the trace-time inverse of
    :func:`_split_tables`."""
    repl = {}
    for name, v in spec.items():
        repl[name] = (_rebuild_tables(getattr(obj, name), v, arrays)
                      if isinstance(v, dict) else arrays[v])
    return dataclasses.replace(obj, **repl)


def build_chunk_call(spec, interpret: bool = True):
    """Compile the fused chunk kernel for one :class:`~repro.core.
    backend.ChunkSpec`.

    Returns ``fn(axvals, aux, start) -> partials`` — the
    ``build_chunk_eval`` contract: ``axvals`` is the tuple of per-axis
    index/value arrays, ``aux`` carries the constraint bounds and the
    dominance-filter state, ``start`` the chunk's first flat index.
    The partials dict matches :func:`repro.core.backend.chunk_partials`
    key-for-key (lane axes padded to ``spec.padded``).
    """
    tables, tspec = _split_tables(spec.S)
    n_tab = len(tables)
    n_ax = len(spec.shape)
    nf, d = len(spec.fields), spec.d
    W, Bn, CP = spec.block, spec.n_blocks, spec.padded
    has_cons = bool(spec.cons_static)
    has_table = 2 <= d <= 3
    pure_min = all(s == 1.0 for s in spec.sign)
    bins = spec.filter_bins

    def body(*refs):
        it = iter(refs)
        tabs = [next(it)[...] for _ in range(n_tab)]
        axrefs = [next(it) for _ in range(n_ax)]
        start_ref = next(it)
        cons_ref = next(it) if has_cons else None
        rows_ref = next(it)
        edges_ref = next(it) if has_table else None
        table_ref = next(it) if has_table else None
        (fd_ref, fsg_ref, valid_ref, keep_ref, bmin_ref, bidx_ref,
         cnt_ref, bmax_ref, sgmin_ref) = it
        kernel = SW.vmapped_kernel(_rebuild_tables(spec.S, tspec, tabs))

        i = pl.program_id(0)
        lanes = i * W + jax.lax.iota(jnp.int64, W)
        flat = start_ref[0] + lanes
        # Lanes beyond the chunk (block padding) or beyond the grid
        # decode to in-range coordinates anyway (mod arithmetic), so
        # they evaluate to garbage-but-finite values — the mask keeps
        # them out of every reduction, exactly like the XLA backend's
        # pad fill.
        inchunk = (lanes < spec.chunk) & (flat < spec.n_total)
        fdec = flat.astype(jnp.int32) if spec.small_index else flat
        coords = SW.decode_flat_index(spec.shape, fdec)
        vals = [r[...][c] for r, c in zip(axrefs, coords)]
        out = kernel(*vals)

        F = jnp.stack([out[f] for f in spec.fields])       # (nf, W)
        feas = inchunk
        if has_cons:
            consv = cons_ref[...]
            for ci, (fi, op) in enumerate(spec.cons_static):
                feas = feas & SW.CONSTRAINT_OPS[op](F[fi], consv[ci])
        valid = jnp.isfinite(F) & feas[None, :]
        Fm = jnp.where(valid, F, jnp.inf)
        # Per-row Python-float scales: sign must not become a captured
        # array constant (scalars inline as literals).
        Fsg = (Fm[:d] if pure_min
               else jnp.where(valid[:d],
                              jnp.stack([F[c] * spec.sign[c]
                                         for c in range(d)]), jnp.inf))

        filt = {"rows": rows_ref[...]}
        if has_table:
            filt["edges"] = edges_ref[...]
            filt["table"] = table_ref[...]
        keep = P.dominance_filter_mask(filt, Fsg, xp=jnp)

        bmin = Fm.min(axis=1)
        fd_ref[...] = F[:d]
        fsg_ref[...] = Fsg
        valid_ref[...] = valid[:d]
        keep_ref[...] = keep[None, :]
        bmin_ref[...] = bmin[:, None]
        bidx_ref[...] = jnp.where(Fm == bmin[:, None], flat[None, :],
                                  spec.n_total).min(axis=1)[:, None]
        cnt_ref[...] = valid.sum(axis=1, dtype=jnp.int32)[:, None]
        bmax_ref[...] = jnp.where(valid, F, -jnp.inf).max(axis=1)[:, None]
        sgmin_ref[...] = Fsg.min(axis=1)[:, None]

    in_specs = [_full_spec(t.shape) for t in tables]        # model tables
    in_specs += [_full_spec((n,)) for n in spec.shape]      # axis values
    in_specs.append(_full_spec((1,)))                       # start
    if has_cons:
        in_specs.append(_full_spec((len(spec.cons_static),)))
    in_specs.append(_full_spec((spec.filter_rows, d)))      # filter rows
    if has_table:
        in_specs.append(_full_spec((d - 1, bins + 1)))
        in_specs.append(_full_spec((bins + 1,) * (d - 1)))

    lane_block = lambda rows: pl.BlockSpec((rows, W), lambda i: (0, i))
    part_block = lambda rows: pl.BlockSpec((rows, 1), lambda i: (0, i))
    out_specs = [lane_block(d), lane_block(d), lane_block(d),
                 lane_block(1), part_block(nf), part_block(nf),
                 part_block(nf), part_block(nf), part_block(d)]
    out_shape = [
        jax.ShapeDtypeStruct((d, CP), jnp.float64),         # Fd
        jax.ShapeDtypeStruct((d, CP), jnp.float64),         # Fsg
        jax.ShapeDtypeStruct((d, CP), jnp.bool_),           # valid
        jax.ShapeDtypeStruct((1, CP), jnp.bool_),           # keep
        jax.ShapeDtypeStruct((nf, Bn), jnp.float64),        # bmin
        jax.ShapeDtypeStruct((nf, Bn), jnp.int64),          # bidx
        jax.ShapeDtypeStruct((nf, Bn), jnp.int32),          # cnt
        jax.ShapeDtypeStruct((nf, Bn), jnp.float64),        # bmax
        jax.ShapeDtypeStruct((d, Bn), jnp.float64),         # sgmin
    ]
    call = pl.pallas_call(body, grid=(Bn,), in_specs=in_specs,
                          out_specs=out_specs, out_shape=out_shape,
                          interpret=interpret)

    def chunk_eval(axvals, aux, start):
        args = [*tables, *axvals, jnp.asarray(start, jnp.int64).reshape(1)]
        if has_cons:
            args.append(aux["cons"])
        filt = aux["filter"]
        args.append(filt["rows"])
        if has_table:
            args.append(filt["edges"])
            args.append(filt["table"])
        Fd, Fsg, valid, keep, bmin, bidx, cnt, bmax, sgmin = call(*args)
        return {"Fd": Fd, "Fsg": Fsg, "valid": valid, "keep": keep[0],
                "bmin": bmin, "bidx": bidx, "cnt": cnt, "bmax": bmax,
                "sgmin": sgmin}

    return chunk_eval


@functools.lru_cache(maxsize=32)
def _flat_call(S, shape, fields, n_lanes, block, interpret):
    """The evaluate-only variant: decode + Eq. 1-11 over an explicit
    flat-index array (the ``build_dense_eval`` contract — the dense
    engine's "one big chunk", also usable for strided probe points)."""
    tables, tspec = _split_tables(S)
    n_tab = len(tables)
    n_ax = len(shape)
    nf = len(fields)
    Bn = n_lanes // block

    def body(*refs):
        tabs = [r[...] for r in refs[:n_tab]]
        axrefs = refs[n_tab:n_tab + n_ax]
        flat_ref = refs[n_tab + n_ax]
        f_ref = refs[n_tab + n_ax + 1]
        kernel = SW.vmapped_kernel(_rebuild_tables(S, tspec, tabs))
        flat = flat_ref[...]
        coords = SW.decode_flat_index(shape, flat)
        vals = [r[...][c] for r, c in zip(axrefs, coords)]
        out = kernel(*vals)
        f_ref[...] = jnp.stack([out[f] for f in fields])

    in_specs = [_full_spec(t.shape) for t in tables]
    in_specs += [_full_spec((n,)) for n in shape]
    in_specs.append(pl.BlockSpec((block,), lambda i: (i,)))
    call = pl.pallas_call(
        body, grid=(Bn,), in_specs=in_specs,
        out_specs=pl.BlockSpec((nf, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((nf, n_lanes), jnp.float64),
        interpret=interpret)
    return lambda *args: call(*tables, *args)


def sweep_grid_eval(S, shape, fields, axvals, flat, *,
                    interpret: bool = True):
    """Evaluate ``fields`` at the given flat grid indices through the
    Pallas kernel; returns ``{field: (n,) array}``.  Pads the lane axis
    to a block multiple internally (padding lanes re-evaluate index 0
    and are sliced away)."""
    fields = tuple(fields)
    n = flat.shape[0]
    W = min(512, n)
    Bn = -(-n // W)
    CP = Bn * W
    fl = jnp.pad(flat, (0, CP - n)) if CP != n else flat
    F = _flat_call(S, tuple(shape), fields, CP, W, interpret)(
        *axvals, fl)
    return {f: F[i, :n] for i, f in enumerate(fields)}
