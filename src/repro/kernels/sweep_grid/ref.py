"""Pure-XLA oracle for the fused sweep-grid chunk kernel.

The reference is not a re-implementation: it is the *shared* chunk
expression of :mod:`repro.core.backend` (``decode_gather`` +
``vmapped_kernel`` + ``chunk_partials``), i.e. exactly what the default
``"xla"`` backend traces.  The Pallas kernel must reproduce these block
partials — ``tests/test_backend.py`` pins every partial array, so the
two lowerings of decode/evaluate/mask/block-reduce can never drift.
"""

from __future__ import annotations

import jax

from repro.core import backend as B


def chunk_partials_ref(spec, axvals, aux, start):
    """Block partials of one chunk through the XLA backend (jitted)."""
    evalfn = B.get_backend("xla").build_chunk_eval(spec)
    return jax.jit(evalfn)(axvals, aux, start)


def sweep_grid_eval_ref(S, shape, fields, axvals, flat):
    """Channel values at flat grid indices through the XLA dense
    evaluator — the oracle for :func:`..kernel.sweep_grid_eval`."""
    return B.get_backend("xla").build_dense_eval(S, tuple(shape),
                                                 tuple(fields))(axvals, flat)
