"""Registry integration: the Pallas evaluation backend.

Importing this module (or calling ``backend.get_backend("pallas")``,
which imports it lazily) registers :class:`PallasGridBackend` under the
name ``"pallas"`` — after which ``sweep.evaluate_grid``,
``stream.stream_grid`` and ``partition.optimal_partition`` all accept
``backend="pallas"``.
"""

from __future__ import annotations

import jax

from repro.core import backend as B

from . import kernel


class PallasGridBackend(B.EvalBackend):
    """Evaluation backend lowering the chunk contract onto
    :mod:`.kernel`'s fused ``pallas_call``.

    ``interpret=None`` (default) auto-selects: interpreter mode on
    non-TPU platforms (the CPU CI/parity configuration), compiled
    Mosaic on TPU.  The multi-device ``pmap`` path is not supported —
    shard across Pallas-capable devices by passing explicit
    single-device ``devices=`` lists per process instead.  Scenario
    sweeps (``scenarios=`` — the session ``lax.scan`` kernel of
    :mod:`repro.core.scenario`) are not supported either: this kernel
    re-implements the Eq. 1-11 evaluation as a fused block body and
    does not lower the per-lane scan; ``backend.check_scenario_support``
    routes such sweeps to the XLA backend with a clear error.
    """

    name = "pallas"
    supports_pmap = False
    supports_scenarios = False

    def __init__(self, interpret: bool | None = None):
        self.interpret = interpret

    def _interpret(self) -> bool:
        if self.interpret is not None:
            return self.interpret
        return jax.local_devices()[0].platform != "tpu"

    def build_chunk_eval(self, spec):
        return kernel.build_chunk_call(spec, interpret=self._interpret())

    def build_dense_eval(self, S, shape, fields):
        fields = tuple(fields)
        shape = tuple(shape)
        interpret = self._interpret()

        @jax.jit
        def evalfn(axvals, flat):
            return kernel.sweep_grid_eval(S, shape, fields, axvals, flat,
                                          interpret=interpret)

        return evalfn


B.register_backend(PallasGridBackend())
