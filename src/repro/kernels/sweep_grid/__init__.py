from .kernel import build_chunk_call, sweep_grid_eval  # noqa: F401
from .ops import PallasGridBackend  # noqa: F401  (registers "pallas")
from .ref import chunk_partials_ref, sweep_grid_eval_ref  # noqa: F401
