"""Jit'd public wrapper: float-in/float-out int8 matmul (RBE-adapted)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import quantize_rowwise, rbe_matmul_raw


@functools.partial(jax.jit, static_argnames=(
    "block_m", "block_n", "block_k", "interpret"))
def rbe_matmul(x, w, *, block_m: int = 128, block_n: int = 128,
               block_k: int = 128, interpret: bool = True):
    """Quantize (x, w) to int8 and multiply on the 8-bit path.

    x: (M, K) float; w: (K, N) float -> (M, N) float32.
    Mirrors the RBE's 8-bit weights/activations datapath [Conti'18].
    """
    x_q, sx = quantize_rowwise(x, axis=-1)
    w_q, sw = quantize_rowwise(w, axis=0)
    return rbe_matmul_raw(x_q, w_q, sx, sw, block_m=block_m,
                          block_n=block_n, block_k=block_k,
                          interpret=interpret)
