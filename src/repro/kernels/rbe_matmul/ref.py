"""Pure-jnp oracle for the RBE int8 matmul kernel."""

from __future__ import annotations

import jax.numpy as jnp


def rbe_matmul_ref(x_q, w_q, sx, sw, out_dtype=jnp.float32):
    """Exact integer accumulation then dequant — matches the kernel
    bit-for-bit up to float rounding of the final scale multiply."""
    acc = x_q.astype(jnp.int32) @ w_q.astype(jnp.int32)
    return (acc.astype(jnp.float32) * sx[:, None] * sw[None, :]
            ).astype(out_dtype)


def dequant_matmul_ref(x, w):
    """Float reference for end-to-end quantization error checks."""
    return x.astype(jnp.float32) @ w.astype(jnp.float32)
