"""RBE-adapted int8 matmul Pallas kernel.

The paper's on-sensor accelerator is the Reconfigurable Binary Engine —
an 8-bit MAC array whose performance is bounded by *weight streaming*
(Fig. 4).  The TPU-native adaptation: an int8 x int8 -> int32 blocked
matmul on the MXU with per-output-channel dequantization, tiled so that

* the weight tile is fetched once per (m_block, n_block) grid step and
  reused across the whole m block — maximizing MACs per streamed weight
  byte, the quantity on the x-axis of the paper's roofline;
* all tiles are multiples of 128 (MXU systolic array alignment);
* the accumulator stays in VMEM as int32 until the final dequant.

Grid: (M / block_m, N / block_n); the K loop runs inside the kernel so the
int32 accumulator never round-trips to HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rbe_matmul_kernel(x_ref, w_ref, sx_ref, sw_ref, o_ref, *,
                       block_k: int, k_total: int):
    """x_ref: (block_m, K) int8; w_ref: (K, block_n) int8;
    sx_ref: (block_m, 1) f32 per-row scale; sw_ref: (1, block_n) f32
    per-channel scale; o_ref: (block_m, block_n) f32."""
    bm = x_ref.shape[0]
    bn = w_ref.shape[1]
    n_k = k_total // block_k

    def body(ki, acc):
        x = jax.lax.dynamic_slice(
            x_ref[...], (0, ki * block_k), (bm, block_k))
        w = jax.lax.dynamic_slice(
            w_ref[...], (ki * block_k, 0), (block_k, bn))
        prod = jax.lax.dot_general(
            x.astype(jnp.int32), w.astype(jnp.int32),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
        return acc + prod

    acc = jnp.zeros((bm, bn), jnp.int32)
    acc = jax.lax.fori_loop(0, n_k, body, acc)
    o_ref[...] = (acc.astype(jnp.float32)
                  * sx_ref[...] * sw_ref[...]).astype(o_ref.dtype)


def rbe_matmul_raw(x_q, w_q, sx, sw, *, block_m: int = 128,
                   block_n: int = 128, block_k: int = 128,
                   out_dtype=jnp.float32, interpret: bool = True):
    """Quantized matmul: (M, K) int8 @ (K, N) int8 -> (M, N) out_dtype.

    ``sx`` (M,) per-row activation scales, ``sw`` (N,) per-channel weight
    scales (the symmetric-quantization layout the RBE uses at 8 bit).
    """
    m, k = x_q.shape
    _, n = w_q.shape

    def _fit(block, dim):
        block = min(block, dim)
        while dim % block:
            block -= 1
        return max(block, 1)

    block_m = _fit(block_m, m)
    block_n = _fit(block_n, n)
    block_k = _fit(block_k, k)

    kernel = functools.partial(_rbe_matmul_kernel, block_k=block_k,
                               k_total=k)
    return pl.pallas_call(
        kernel,
        grid=(m // block_m, n // block_n),
        in_specs=[
            pl.BlockSpec((block_m, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, block_n), lambda i, j: (0, j)),
            pl.BlockSpec((block_m, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, block_n), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        interpret=interpret,
    )(x_q, w_q, sx.reshape(m, 1), sw.reshape(1, n))


def quantize_rowwise(x, axis: int = -1):
    """Symmetric int8 quantization with per-row scales."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis,
                   keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.squeeze(axis)
