from .kernel import quantize_rowwise, rbe_matmul_raw  # noqa: F401
from .ops import rbe_matmul  # noqa: F401
from .ref import dequant_matmul_ref, rbe_matmul_ref  # noqa: F401
