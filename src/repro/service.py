"""Unambiguous entry point for the sweep server: ``python -m
repro.service``.

The implementation lives in :mod:`repro.core.service` (this shim
exists so the service is addressable without knowing the package
layout, and so the name ``repro.service`` can never again be confused
with the unrelated LLM token-serving scaffolding that now lives in
:mod:`repro.launch.token_serve`)."""

from .core.service import (CancelledError, ServiceClosedError,  # noqa: F401
                           SweepRequest, SweepService, Ticket, main)

if __name__ == "__main__":      # pragma: no cover
    import sys
    sys.exit(main())
