"""Optimizers + distributed-optimization tricks (compression, schedules)."""

from . import adamw  # noqa: F401
from .adamw import AdamWConfig, AdamWState  # noqa: F401
