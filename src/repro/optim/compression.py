"""Gradient compression with error feedback — the DOSC 'ROI' for gradients.

The paper's core systems move: *compress the representation before it
crosses the expensive link* (ROI over MIPI instead of raw frames).  The
training-time analogue compresses gradients before the inter-pod (DCN)
all-reduce stage of the hierarchical reduction:

    rs = reduce_scatter(grad, ICI)           # full precision, cheap tier
    c  = compress(rs + ef_buffer)            # bf16 / int8 + scale
    ef_buffer = (rs + ef_buffer) - decompress(c)   # error feedback
    agg = all_reduce(c, DCN)                 # 2-4x fewer bytes on the
    grad = all_gather(decompress(agg), ICI)  # expensive tier

Error feedback makes the quantization bias vanish over steps (the
residual is re-injected), which is what keeps convergence intact at int8.
This module implements the compression math + EF state; the tier routing
lives in :mod:`repro.core.dosc` and the launcher.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    kind: str = "int8"          # "none" | "bf16" | "int8"
    error_feedback: bool = True

    @property
    def bytes_per_element(self) -> float:
        return {"none": 4.0, "bf16": 2.0, "int8": 1.0}[self.kind]


class Compressed(NamedTuple):
    payload: Any     # quantized values
    scale: Any       # per-tensor scale (int8 only; None otherwise)


def compress_leaf(x: Array, kind: str) -> Compressed:
    if kind == "none":
        return Compressed(x, None)
    if kind == "bf16":
        return Compressed(x.astype(jnp.bfloat16), None)
    if kind == "int8":
        amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
        scale = jnp.maximum(amax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        return Compressed(q, scale)
    raise ValueError(kind)


def decompress_leaf(c: Compressed, dtype=jnp.float32) -> Array:
    if c.scale is None:
        return c.payload.astype(dtype)
    return (c.payload.astype(jnp.float32) * c.scale).astype(dtype)


def init_error_feedback(grads: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_with_feedback(grads: Any, ef: Any,
                           cfg: CompressionConfig) -> tuple[Any, Any]:
    """Returns (compressed pytree, new error-feedback pytree).

    The compressed pytree holds :class:`Compressed` leaves; transmit those,
    then :func:`decompress_tree` on the receiving side.
    """
    if cfg.kind == "none":
        return jax.tree.map(lambda g: Compressed(g, None), grads), ef

    def one(g, e):
        target = g.astype(jnp.float32) + (e if cfg.error_feedback else 0.0)
        c = compress_leaf(target, cfg.kind)
        recon = decompress_leaf(c)
        new_e = (target - recon) if cfg.error_feedback \
            else jnp.zeros_like(target)
        return c, new_e

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(ef)
    pairs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    comp = tdef.unflatten([p[0] for p in pairs])
    new_ef = tdef.unflatten([p[1] for p in pairs])
    return comp, new_ef


def decompress_tree(comp: Any, dtype=jnp.float32) -> Any:
    return jax.tree.map(lambda c: decompress_leaf(c, dtype), comp,
                        is_leaf=lambda x: isinstance(x, Compressed))


def compressed_bytes(grads: Any, cfg: CompressionConfig) -> float:
    """Bytes on the wire for one compressed gradient exchange."""
    return sum(g.size * cfg.bytes_per_element
               for g in jax.tree.leaves(grads))
