"""AdamW with gradient clipping, cosine schedule, and configurable moment
dtype (bf16 moments let the 480B-class MoE cells fit a single v5e pod —
see EXPERIMENTS.md §Dry-run memory notes)."""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0
    moment_dtype: str = "float32"    # "bfloat16" for the giant MoE cells
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    step: Array          # () int32
    mu: Any              # first moments (pytree like params)
    nu: Any              # second moments


def cosine_lr(cfg: AdamWConfig, step: Array) -> Array:
    """Linear warmup + cosine decay to min_lr_ratio * lr."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    scale = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * scale


def global_norm(tree) -> Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), tree), norm


def init(cfg: AdamWConfig, params) -> AdamWState:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)  # noqa: E731
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def apply(cfg: AdamWConfig, params, grads, state: AdamWState
          ) -> tuple[Any, AdamWState, dict]:
    """One AdamW update.  Returns (params, state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip_norm)
    step = state.step + 1
    lr = cosine_lr(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:   # decay matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.mu)
    flat_v = tdef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {
        "grad_norm": gnorm, "lr": lr}
