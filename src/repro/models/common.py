"""Model configuration schema shared by all assigned architectures.

One :class:`ModelConfig` describes any of the ten assigned architectures:
dense GQA transformers, MoE variants (top-k routing, shared experts, dense
residual), MLA attention, SSM blocks (Mamba, sLSTM/mLSTM), hybrid
interleaves, and modality-stub backbones (audio / VLM).

Layer stacks are expressed as a repeating **block pattern** (e.g. Jamba's
8-layer ``('mamba',)*4 + ('attn',) + ('mamba',)*3`` unit, Gemma-2's
``('attn_local', 'attn_global')`` unit).  The transformer scans over pattern
repeats so the compiled HLO stays compact at 512 devices.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0       # DeepSeek-V2: always-on experts
    dense_residual: bool = False      # Arctic: dense FFN in parallel w/ MoE
    every_k_layers: int = 1           # Jamba: MoE on every 2nd layer
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense|moe|ssm|hybrid|audio|vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // num_heads

    # ---- attention ----
    attention_kind: str = "gqa"       # gqa | mla
    qkv_bias: bool = False
    use_rope: bool = True             # False -> sinusoidal absolute pos-emb
    rope_theta: float = 10000.0
    sliding_window: int = 0           # window for 'attn_local' blocks
    attn_logit_softcap: float = 0.0   # Gemma-2
    final_logit_softcap: float = 0.0  # Gemma-2
    mrope_sections: Tuple[int, ...] = ()   # Qwen2-VL M-RoPE half-dim split

    # ---- MLA (DeepSeek-V2) ----
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # ---- FFN / MoE ----
    ffn_kind: str = "swiglu"          # swiglu | gelu
    moe: Optional[MoEConfig] = None
    first_k_dense: int = 0            # DeepSeek-V2: first layer dense
    # §Perf knob: keep the expert f dim sharded over "data" through the
    # expert einsums (partial-sum all-reduce of activations) instead of
    # letting SPMD all-gather the FSDP-sharded expert weights per layer.
    moe_partial_sum: bool = False
    # §Perf knob: cast attention probabilities to bf16 for the p@v einsum
    # (fp32 max/denominator kept) — halves the dominant HBM traffic of the
    # lowered blockwise attention.
    attn_p_bf16: bool = False
    # §Perf knob: Megatron-style sequence parallelism — the residual
    # stream stays S-sharded over "model" through norms/FFN; S is gathered
    # only around the mixer.  Turns per-layer TP all-reduces of full
    # activations into bf16 gather/scatter pairs and keeps backward
    # recompute local.
    seq_parallel: bool = False

    # ---- layer pattern ----
    block_pattern: Tuple[str, ...] = ("attn",)
    # block kinds: attn | attn_local | attn_global | mamba | mlstm | slstm

    # ---- SSM ----
    ssm_state_dim: int = 16           # Mamba N
    ssm_conv_width: int = 4
    ssm_expand: int = 2               # Mamba inner = expand * d_model

    # ---- embeddings / misc ----
    tie_embeddings: bool = False
    scale_embeddings: bool = False    # Gemma-2: x *= sqrt(d_model)
    post_block_norm: bool = False     # Gemma-2: extra norms around blocks
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    # modality stub: forward consumes precomputed (B, S, d_model) embeddings
    frontend_stub: bool = False

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.num_layers % len(self.block_pattern) and \
                self.num_layers > self.first_k_dense:
            n = self.num_layers - self.first_k_dense
            if n % len(self.block_pattern):
                raise ValueError(
                    f"{self.name}: num_layers-first_k_dense ({n}) not a "
                    f"multiple of pattern length {len(self.block_pattern)}")

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def num_pattern_repeats(self) -> int:
        return (self.num_layers - self.first_k_dense) \
            // len(self.block_pattern)

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    def layer_kind(self, i: int) -> str:
        """Block kind of global layer index ``i``."""
        if i < self.first_k_dense:
            return self.block_pattern[0] if self.block_pattern else "attn"
        j = (i - self.first_k_dense) % len(self.block_pattern)
        return self.block_pattern[j]

    def layer_uses_moe(self, i: int) -> bool:
        if self.moe is None or i < self.first_k_dense:
            return False
        return (i + 1) % self.moe.every_k_layers == 0

    # ------------------------------------------------------------------
    # analytic parameter / FLOP counts (for MODEL_FLOPS = 6*N*D)
    # ------------------------------------------------------------------
    def _attn_params(self) -> int:
        d, hd = self.d_model, self.resolved_head_dim
        if self.attention_kind == "mla":
            qp = (d * self.q_lora_rank
                  + self.q_lora_rank * self.num_heads
                  * (self.qk_nope_dim + self.qk_rope_dim)) \
                if self.q_lora_rank else \
                d * self.num_heads * (self.qk_nope_dim + self.qk_rope_dim)
            kvp = (d * (self.kv_lora_rank + self.qk_rope_dim)
                   + self.kv_lora_rank * self.num_heads
                   * (self.qk_nope_dim + self.v_head_dim))
            op = self.num_heads * self.v_head_dim * d
            return qp + kvp + op
        q = d * self.num_heads * hd
        kv = 2 * d * self.num_kv_heads * hd
        o = self.num_heads * hd * d
        b = (self.num_heads + 2 * self.num_kv_heads) * hd \
            if self.qkv_bias else 0
        return q + kv + o + b

    def _dense_ffn_params(self) -> int:
        mult = 3 if self.ffn_kind == "swiglu" else 2
        return mult * self.d_model * self.d_ff

    def _moe_ffn_params(self, active_only: bool) -> int:
        m = self.moe
        assert m is not None
        mult = 3 if self.ffn_kind == "swiglu" else 2
        per_expert = mult * self.d_model * m.d_ff_expert
        router = self.d_model * m.num_experts
        n_exp = (m.top_k if active_only else m.num_experts)
        total = n_exp * per_expert + router
        total += m.num_shared_experts * per_expert
        if m.dense_residual:
            total += self._dense_ffn_params()
        return total

    def _ssm_params(self, kind: str) -> int:
        d = self.d_model
        if kind == "mamba":
            di = self.ssm_expand * d
            n = self.ssm_state_dim
            return (d * 2 * di            # in_proj (x, z)
                    + di * self.ssm_conv_width
                    + di * (2 * n + 1) + di  # dt/B/C proj + dt bias (approx)
                    + di * n                 # A
                    + di * d)                # out_proj
        if kind in ("mlstm", "slstm"):
            hd = self.resolved_head_dim
            nh = self.num_heads
            qkv = 3 * d * nh * hd
            gates = 2 * d * nh + 2 * nh  # i/f gate projections + biases
            out = nh * hd * d
            up = 2 * d * self.d_ff if self.d_ff else 0  # optional FFN
            return qkv + gates + out + up
        raise ValueError(kind)

    def param_count(self, active_only: bool = False) -> int:
        """Total (or active, for MoE) parameter count, embeddings included."""
        total = self.vocab_size * self.d_model          # embed
        if not self.tie_embeddings:
            total += self.vocab_size * self.d_model     # unembed
        for i in range(self.num_layers):
            kind = self.layer_kind(i)
            total += 2 * self.d_model                   # norms
            if kind.startswith("attn"):
                total += self._attn_params()
                if self.layer_uses_moe(i):
                    total += self._moe_ffn_params(active_only)
                elif self.d_ff:
                    total += self._dense_ffn_params()
            else:
                total += self._ssm_params(kind)
                if self.layer_uses_moe(i):
                    total += self._moe_ffn_params(active_only)
                elif kind == "mamba" and self.d_ff:
                    total += self._dense_ffn_params()
        return total

    def model_flops(self, tokens: int, decode: bool = False,
                    context_len: int = 0) -> float:
        """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) for training;
        2*N_active*D for a forward-only (serving) step."""
        n_active = self.param_count(active_only=True)
        mult = 2.0 if decode else 6.0
        return mult * n_active * tokens

    def reduced(self, **overrides) -> "ModelConfig":
        """A small same-family config for CPU smoke tests."""
        moe = self.moe
        if moe is not None:
            moe = dataclasses.replace(
                moe, num_experts=min(moe.num_experts, 8),
                top_k=min(moe.top_k, 2),
                d_ff_expert=min(moe.d_ff_expert, 128))
        pat = len(self.block_pattern)
        small = dict(
            num_layers=max(pat, 2 * pat if self.num_layers >= 2 * pat
                           else pat) + self.first_k_dense,
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2),
            d_ff=256 if self.d_ff else 0,
            vocab_size=512,
            head_dim=32 if self.head_dim else 0,
            q_lora_rank=64 if self.q_lora_rank else 0,
            kv_lora_rank=32 if self.kv_lora_rank else 0,
            qk_nope_dim=32 if self.qk_nope_dim else 0,
            qk_rope_dim=16 if self.qk_rope_dim else 0,
            v_head_dim=32 if self.v_head_dim else 0,
            sliding_window=64 if self.sliding_window else 0,
            mrope_sections=(4, 6, 6) if self.mrope_sections else (),
            moe=moe,
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)
